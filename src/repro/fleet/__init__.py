"""In-process multi-replica serving: fleet index, router, fabric."""
from repro.fleet.fabric import (FleetConfig, FleetFabric, FleetMetrics,
                                build_fleet, replicate_model)
from repro.fleet.index import FleetIndex
from repro.fleet.router import POLICIES, Router, RouterConfig

__all__ = ["FleetConfig", "FleetFabric", "FleetMetrics", "FleetIndex",
           "POLICIES", "Router", "RouterConfig", "build_fleet",
           "replicate_model"]
