"""Front-end request router for the in-process engine fleet.

Three policies over N ``UnifiedEngine`` replicas:

* ``round-robin`` — arrival order modulo N.  The locality-blind baseline
  the fleet bench compares against.
* ``least-loaded`` — fewest resident + queued requests, ties to the lowest
  engine id.  Load-aware but still locality-blind.
* ``affinity`` — score every replica and take the argmax:

      score = resident_prefix_fraction            # in [0, 1]
            + adapter_bonus * adapter_resident    # LoRA already in the bank
            - load_penalty * queue_depth          # UNBOUNDED with depth
            - lent_penalty * lent_block_fraction  # over-admission pressure

  The affinity terms are bounded while the load penalty is linear in queue
  depth, so a hot replica holding every popular template still loses the
  argmax once its backlog grows — the policy cannot herd the whole trace
  onto one engine and starve the rest (the fleet analog of the scheduler's
  admission fairness ramp).  Prefix residency reuses the request's
  memoized chain keys (``request_chain_keys``), so the router probe and
  the chosen engine's admission hash each prompt once between them.

The router only *scores*; placement side effects (remote prefix fetch
into the chosen replica's pool) belong to the fabric's dispatch path.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence

from repro.serving.engine import UnifiedEngine
from repro.serving.kvcache import PagedCacheManager, request_chain_keys
from repro.serving.request import Request

POLICIES = ("affinity", "round-robin", "least-loaded")


@dataclasses.dataclass(frozen=True)
class RouterConfig:
    policy: str = "affinity"
    adapter_bonus: float = 0.25   # worth a quarter-prompt of resident prefix
    load_penalty: float = 0.125   # per queued/resident request — unbounded
    #                               growth is the anti-herding guarantee
    lent_penalty: float = 0.25    # per unit lent-block fraction (a replica
    #                               already paying recompute preemptions is
    #                               a bad home for more work)

    def __post_init__(self):
        if self.policy not in POLICIES:
            raise ValueError(f"unknown router policy {self.policy!r}; "
                             f"choose one of {POLICIES}")


def queue_depth(eng: UnifiedEngine) -> int:
    """Requests this replica is already committed to: resident, queued, and
    dispatched-but-not-yet-due (the fabric hands a request to one engine's
    ``future`` at routing time, so those are placed load even before the
    replica's clock reaches their arrival)."""
    return (len(eng.waiting) + len(eng.active) + len(eng.prefilling)
            + len(eng.future))


class Router:
    def __init__(self, engines: Sequence[UnifiedEngine],
                 cfg: Optional[RouterConfig] = None):
        if not engines:
            raise ValueError("router needs at least one engine")
        self.engines = list(engines)
        self.cfg = cfg or RouterConfig()
        self._rr = 0

    # -- per-replica scoring (affinity policy) ------------------------------
    def _prefix_fraction(self, eng: UnifiedEngine, r: Request) -> float:
        mgr = eng.cachemgr
        if (not isinstance(mgr, PagedCacheManager) or not eng.hash_dedup
                or r.aux_embed is not None or r.prompt_len == 0):
            return 0.0
        keys = request_chain_keys(r, mgr.block_size)
        return mgr.probe(r.prompt, r.adapter, keys=keys) / r.prompt_len

    def score(self, eng: UnifiedEngine, r: Request) -> float:
        c = self.cfg
        s = self._prefix_fraction(eng, r)
        if r.adapter and r.adapter in eng.model.store.resident:
            s += c.adapter_bonus
        s -= c.load_penalty * queue_depth(eng)
        mgr = eng.cachemgr
        if isinstance(mgr, PagedCacheManager) and mgr.reserved_debt > 0:
            s -= c.lent_penalty * (mgr.lent_blocks / mgr.reserved_debt)
        return s

    # -- placement ----------------------------------------------------------
    def route(self, r: Request) -> int:
        """Engine id to run ``r`` on.  Deterministic given fleet state."""
        if self.cfg.policy == "round-robin":
            eid = self._rr % len(self.engines)
            self._rr += 1
            return eid
        if self.cfg.policy == "least-loaded":
            return min(range(len(self.engines)),
                       key=lambda i: (queue_depth(self.engines[i]), i))
        scores = [self.score(e, r) for e in self.engines]
        # ties (e.g. a cold fleet) break toward the emptier replica, then
        # the lowest id — deterministic, and cold traffic spreads as soon
        # as the first placement registers as queue depth
        return max(range(len(self.engines)),
                   key=lambda i: (scores[i], -queue_depth(self.engines[i]),
                                  -i))

    def scores(self, r: Request) -> List[float]:
        """All replica scores (tests / debugging)."""
        return [self.score(e, r) for e in self.engines]
