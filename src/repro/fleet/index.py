"""FleetIndex — the fleet-wide content-hash block index.

One location-transparent map ``block_key -> {engine_id: block_id}`` over
every replica's local dedup index (``PagedCacheManager._index``).  It is
fed by the managers' publication lifecycle hooks (``on_publish`` /
``on_depublish``), which fire on EVERY local index mutation — publication
at prefill/decode commit, remote import, and retraction from ``_shed_one``
(the single local removal path; CoW and truncate never invalidate local
entries because published payloads are copy-on-write-immutable).  The
fleet view is therefore exactly as fresh as the local indexes: an entry
``(key, engine, block)`` exists iff that engine's local index holds that
block under that key, so a fleet lookup can never name a dead, rewritten,
or shed block.

A prompt whose prefix is resident ANYWHERE in the fleet can then fetch the
payload blocks into its local pool (``PagedCacheManager.import_block``, a
cross-pool block copy charged at the modeled interconnect cost) instead of
recomputing them — PR 5's content-addressed blocks made location
transparency structural: the key IS the content, so a copy from any holder
is bit-identical to local recompute of published state.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple

from repro.serving.kvcache import PagedCacheManager


class FleetIndex:
    """``block_key -> {engine_id: block_id}`` across attached managers."""

    def __init__(self):
        self._where: Dict[str, Dict[int, int]] = {}
        self._mgrs: Dict[int, PagedCacheManager] = {}

    def attach(self, engine_id: int, mgr: PagedCacheManager) -> None:
        """Subscribe to ``mgr``'s publication lifecycle and ingest whatever
        its local index already holds (attach-after-warmup is legal)."""
        if engine_id in self._mgrs:
            raise ValueError(f"engine {engine_id} already attached")
        if mgr.on_publish is not None or mgr.on_depublish is not None:
            raise ValueError("manager already feeds another fleet index")
        self._mgrs[engine_id] = mgr
        mgr.on_publish = lambda key, bid: self._publish(engine_id, key, bid)
        mgr.on_depublish = lambda key, bid: self._retract(engine_id, key,
                                                          bid)
        for key, bid in mgr._index.items():
            self._publish(engine_id, key, bid)

    # -- lifecycle events (hook targets) ------------------------------------
    def _publish(self, engine_id: int, key: str, bid: int) -> None:
        self._where.setdefault(key, {})[engine_id] = bid

    def _retract(self, engine_id: int, key: str, bid: int) -> None:
        holders = self._where.get(key)
        if holders is None or holders.get(engine_id) != bid:
            raise RuntimeError(     # a retraction we never saw published
                f"fleet index drift: retract of unknown ({key!r:.12}, "
                f"engine {engine_id}, block {bid})")
        del holders[engine_id]
        if not holders:
            del self._where[key]

    # -- queries -------------------------------------------------------------
    def __len__(self) -> int:
        return len(self._where)

    @property
    def entries(self) -> int:
        """Total (key, engine) pairs — one key replicated on two engines
        counts twice."""
        return sum(len(h) for h in self._where.values())

    def holders(self, key: str) -> List[Tuple[int, int]]:
        """Every ``(engine_id, block_id)`` holding ``key``'s payload."""
        return sorted(self._where.get(key, {}).items())

    def locate(self, key: str, prefer: Optional[int] = None
               ) -> Optional[Tuple[int, int]]:
        """One holder of ``key`` (``prefer``'s copy when it has one, else
        the lowest engine id for determinism), or None."""
        holders = self._where.get(key)
        if not holders:
            return None
        if prefer is not None and prefer in holders:
            return prefer, holders[prefer]
        eid = min(holders)
        return eid, holders[eid]

    def resident_run(self, keys: Sequence[str]) -> int:
        """Longest leading run of ``keys`` resident ANYWHERE in the fleet —
        the fleet generalization of ``PagedCacheManager._resident_run``.
        The walk stops at the first gap: a resident child behind a missing
        parent is unreachable (its chained key pins the parent's content,
        which would have to be recomputed anyway)."""
        n = 0
        for k in keys:
            if k not in self._where:
                break
            n += 1
        return n

    # -- integrity (tests / benches) -----------------------------------------
    def check_bijection(self) -> None:
        """Every fleet entry must resolve to a live local index entry and
        vice versa — the no-stale-entries invariant the hypothesis fleet
        conservation property gates on."""
        for key, holders in self._where.items():
            for eid, bid in holders.items():
                mgr = self._mgrs[eid]
                if mgr._index.get(key) != bid:
                    raise AssertionError(
                        f"fleet entry ({key!r:.12}, engine {eid}, block "
                        f"{bid}) has no live local index entry")
        for eid, mgr in self._mgrs.items():
            for key, bid in mgr._index.items():
                if self._where.get(key, {}).get(eid) != bid:
                    raise AssertionError(
                        f"local index entry ({key!r:.12}, engine {eid}, "
                        f"block {bid}) missing from the fleet index")
