"""FleetFabric — N in-process ``UnifiedEngine`` replicas behind one router.

The fleet is a discrete-event simulation over the replicas' own
``VirtualClock``s (wall-clock engines work too, but lose the deterministic
replay): the fabric holds the global arrival-sorted trace, routes every
arrival that has come due against the earliest busy replica's clock, then
ticks whichever busy replica is furthest behind.  No replica ever runs
ahead of a routing decision it should have seen, so a trace replays
identically for a given router policy.  Fleet elapsed time is the MAX over
replica clocks — wall time is global, and a replica sitting idle is not
saving anyone time.

Dispatch is where the fleet index pays off.  Before a request is handed to
its replica, the fabric looks up how much of the prompt's block-key chain
is resident anywhere in the fleet beyond what the target already holds,
and applies the fetch-vs-recompute rule

    fixed + n * remote_per_block  <  n * block_size * prefill_per_tok

(one transfer launch amortized over ``n`` fetched blocks vs recomputing
those blocks' prefill locally).  When fetching wins, the payload blocks
are copied from sibling pools into the target's pool
(``PagedCacheManager.import_block``) and the target's clock is charged the
modeled interconnect cost; the subsequent local admission then adopts the
imported blocks exactly as if a local tenant had published them.  Because
the transfer is a block-granular copy of published (CoW-immutable) K/V,
outputs are byte-identical to computing everything locally — the fleet
bench asserts this against a single-engine run.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

import jax

from repro.core.virtualization import AdapterStore, MixedLoraModel
from repro.fleet.index import FleetIndex
from repro.fleet.router import Router, RouterConfig, queue_depth
from repro.serving.clock import CostModel, VirtualClock
from repro.serving.engine import EngineConfig, UnifiedEngine
from repro.serving.kvcache import PagedCacheManager, request_chain_keys
from repro.serving.request import Request
from repro.serving.slo import Metrics


@dataclasses.dataclass(frozen=True)
class FleetConfig:
    replicas: int = 2
    router: RouterConfig = dataclasses.field(default_factory=RouterConfig)
    remote_fetch: bool = True    # False = independent replicas (the fleet
    #                              index still mirrors, but dispatch never
    #                              imports — the bench's baseline arm)

    def __post_init__(self):
        if self.replicas < 1:
            raise ValueError("fleet needs at least one replica")


@dataclasses.dataclass
class FleetMetrics:
    """Fleet-wide rollup: counters summed, elapsed = max replica clock."""
    elapsed: float = 0.0
    decode_tokens: int = 0
    prefill_tokens: int = 0
    reused_prefix_tokens: int = 0
    finetune_tokens: int = 0
    steps: int = 0
    busy_time: float = 0.0
    hash_hits: int = 0
    remote_fetch_blocks: int = 0
    remote_fetch_time: float = 0.0
    preemptions: int = 0
    routed: Dict[int, int] = dataclasses.field(default_factory=dict)
    per_engine: List[Metrics] = dataclasses.field(default_factory=list)

    def rates(self):
        e = max(self.elapsed, 1e-9)
        return {"DTPS": self.decode_tokens / e,
                "PTPS": self.prefill_tokens / e,
                "FTPS": self.finetune_tokens / e,
                "steps_per_s": self.steps / e}


def replicate_model(model: MixedLoraModel, n: int) -> List[MixedLoraModel]:
    """``n`` virtual models over ONE base pytree (replica 0 is the original).
    The base is shared by reference — the Loquetier virtualization contract:
    replicas cost adapter banks, never base weights.  Each extra replica
    gets its own ``AdapterStore`` loaded with bit-identical copies of every
    adapter resident in the source store (same slot order, same scale), so
    any replica computes byte-identical K/V and logits for the same rows."""
    out = [model]
    src = model.store
    for _ in range(1, n):
        store = AdapterStore(model.cfg, src.lcfg)
        for name in src.resident:
            store.load(name, jax.tree_util.tree_map(lambda x: x,
                                                    src.get_adapter(name)),
                       scale=float(src.scale[src.slot_of(name)]),
                       # true rank carries over so unified adapter paging
                       # meters identical per-replica pool footprints
                       rank=src._ranks.get(name))
        for name, v in src._voided.items():
            # host-voided adapters must replicate too — a small staging
            # bank (unified paging) evicts overflow before the fleet is
            # built, and every replica must be able to serve every adapter
            store.load(name, v.adapter, scale=v.scale, evict=True,
                       rank=src._ranks.get(name))
        out.append(MixedLoraModel(model.cfg, model.base, store))
    return out


def build_fleet(model: MixedLoraModel, ecfg: EngineConfig,
                fcfg: Optional[FleetConfig] = None) -> "FleetFabric":
    """The one-call constructor serve.py and the bench use."""
    fcfg = fcfg or FleetConfig()
    models = replicate_model(model, fcfg.replicas)
    return FleetFabric([UnifiedEngine(m, ecfg) for m in models], fcfg)


class FleetFabric:
    def __init__(self, engines: Sequence[UnifiedEngine],
                 fcfg: Optional[FleetConfig] = None):
        if not engines:
            raise ValueError("fleet needs at least one engine")
        self.engines = list(engines)
        self.fcfg = fcfg or FleetConfig(replicas=len(engines))
        self.router = Router(self.engines, self.fcfg.router)
        self.index = FleetIndex()
        for eid, eng in enumerate(self.engines):
            if isinstance(eng.cachemgr, PagedCacheManager) and eng.hash_dedup:
                self.index.attach(eid, eng.cachemgr)
        self.future: List[Request] = []       # arrival-sorted global trace
        self.routed: Dict[int, int] = {eid: 0 for eid in
                                       range(len(self.engines))}

    # ------------------------------------------------------------------
    def submit(self, r: Request):
        self.future.append(r)
        self.future.sort(key=lambda q: q.arrival)

    def _busy(self, eng: UnifiedEngine) -> bool:
        return bool(eng.waiting or eng.active or eng.prefilling
                    or eng.future or eng.trainers_pending())

    @property
    def drained(self) -> bool:
        return not self.future and not any(self._busy(e)
                                           for e in self.engines)

    # -- dispatch ----------------------------------------------------------
    def _fetch_prefix(self, eid: int, r: Request) -> int:
        """Import the request's fleet-resident-but-locally-missing prefix
        blocks into replica ``eid``'s pool when the fetch-vs-recompute rule
        says the interconnect beats local prefill.  Returns blocks fetched;
        charges the replica's virtual clock for them."""
        eng = self.engines[eid]
        mgr = eng.cachemgr
        if (not self.fcfg.remote_fetch
                or not isinstance(mgr, PagedCacheManager)
                or not eng.hash_dedup or r.aux_embed is not None):
            return 0
        keys = request_chain_keys(r, mgr.block_size)
        local = len(mgr._resident_run(keys))
        fleet_run = self.index.resident_run(keys)
        n = fleet_run - local
        if n <= 0:
            return 0
        clock = eng.clock
        cost = (clock.cost if isinstance(clock, VirtualClock)
                else CostModel())
        if (cost.fixed + n * cost.remote_per_block
                >= n * mgr.block_size * cost.prefill_per_tok):
            return 0          # launch overhead eats the win: recompute
        fetched = 0
        for key in keys[local:fleet_run]:
            where = self.index.locate(key, prefer=eid)
            if where is None:
                break                       # shed between probe and fetch
            src_eid, src_bid = where
            if src_eid == eid:
                continue                    # already local (mid-chain hit)
            if mgr.import_block(key, self.engines[src_eid].cachemgr,
                                src_bid) is None:
                break          # target pool has no spendable capacity; the
                #                chain must stay gapless, so stop here
            fetched += 1
        if fetched and isinstance(clock, VirtualClock):
            t = clock.step_cost(0, 0, 0, remote_blocks=fetched)
            clock.charge(t)
            eng.metrics.remote_fetch_time += t
            eng.metrics.busy_time += t
        return fetched

    def _dispatch(self, r: Request):
        eid = self.router.route(r)
        self.routed[eid] += 1
        self._fetch_prefix(eid, r)
        self.engines[eid].submit(r)

    # -- DES loop ----------------------------------------------------------
    def tick(self) -> bool:
        """Route due arrivals, then tick the furthest-behind busy replica;
        returns False when the whole fleet is idle."""
        busy = [e for e in self.engines if self._busy(e)]
        if not busy and not self.future:
            return False
        horizon = (min(e.clock.now() for e in busy) if busy
                   else self.future[0].arrival)
        while self.future and self.future[0].arrival <= horizon:
            self._dispatch(self.future.pop(0))
        busy = [(e.clock.now(), i) for i, e in enumerate(self.engines)
                if self._busy(e)]
        if not busy:
            return bool(self.future)
        _, eid = min(busy)
        self.engines[eid].tick()
        return True

    def run(self, max_ticks: int = 1000000,
            until_drained: bool = True) -> FleetMetrics:
        for _ in range(max_ticks):
            alive = self.tick()
            if until_drained and self.drained:
                break
            if not alive and not until_drained:
                break
        for eng in self.engines:
            for tr in eng.trainers.values():
                if tr.force_apply_pending():
                    eng._apply_trainer(tr)
            eng.metrics.elapsed = eng.clock.now()
        return self.rollup()

    # -- metrics -----------------------------------------------------------
    def rollup(self) -> FleetMetrics:
        fm = FleetMetrics(routed=dict(self.routed),
                          per_engine=[e.metrics for e in self.engines])
        for eng in self.engines:
            m = eng.metrics
            fm.elapsed = max(fm.elapsed, eng.clock.now())
            fm.decode_tokens += m.decode_tokens
            fm.prefill_tokens += m.prefill_tokens
            fm.reused_prefix_tokens += m.reused_prefix_tokens
            fm.finetune_tokens += m.finetune_tokens
            fm.steps += m.steps
            fm.busy_time += m.busy_time
            fm.hash_hits += m.hash_hits
            fm.remote_fetch_blocks += m.remote_fetch_blocks
            fm.remote_fetch_time += m.remote_fetch_time
            fm.preemptions += m.preemptions
        return fm

    @property
    def all_requests(self) -> List[Request]:
        out: List[Request] = list(self.future)
        for eng in self.engines:
            out.extend(eng.all_requests)
        return out
