"""Exact greedy acceptance.

The verify chunk feeds the model ``[current, d_1, ..., d_k]`` at positions
``L .. L + k``; row ``i`` of the returned logits is the model's next-token
distribution *after* consuming input token ``i``.  Greedy speculation is
exact: accept the longest draft prefix where ``d_{i+1} == argmax(logits_i)``,
then emit one bonus token from the first disagreeing (or final) position —
precisely the tokens plain greedy decode would have produced one tick at a
time, so outputs are byte-identical by construction.
"""
from __future__ import annotations

from typing import List, Tuple

import numpy as np


def accept_greedy(draft: np.ndarray,
                  logits: np.ndarray) -> Tuple[int, List[int]]:
    """draft: [k] proposed tokens; logits: [>= k + 1, V] verify-chunk logits
    (only rows ``0 .. k`` are read).  Returns ``(n_accepted, emitted)`` where
    ``emitted`` is ``draft[:n_accepted]`` plus the bonus token — the exact
    greedy continuation, always at least one token."""
    k = len(draft)
    assert logits.shape[0] >= k + 1, "verify chunk shorter than draft + 1"
    arg = np.argmax(logits[:k + 1], axis=-1)
    n = 0
    while n < k and int(draft[n]) == int(arg[n]):
        n += 1
    return n, [int(t) for t in draft[:n]] + [int(arg[n])]
