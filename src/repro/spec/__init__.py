"""Speculative decoding over the paged KV cache.

Decode is the latency-dominated path of the unified flow: the paper's
merged fine-tune + inference step already packs more work per kernel launch
across *requests*; speculation applies the same lever along the *time* axis.
A model-free drafter proposes ``k`` tokens from the request's own history,
the engine folds a ``(1 + k)``-token *verify chunk* per speculating request
into the ordinary unified batch (fine-tune + prefill + verify + plain decode
co-batch in ONE step), and exact greedy acceptance keeps the longest draft
prefix that matches the model's argmax — byte-identical output to plain
greedy decode, fewer sequential steps.  Rejected drafts roll the paged cache
back via ``PagedCacheManager.truncate``.
"""
from repro.spec.drafter import (Drafter, NgramDrafter, StaticSuffixDrafter,
                                make_drafter)
from repro.spec.policy import AdaptiveK, SpecConfig
from repro.spec.verify import accept_greedy

__all__ = ["Drafter", "NgramDrafter", "StaticSuffixDrafter", "make_drafter",
           "AdaptiveK", "SpecConfig", "accept_greedy"]
