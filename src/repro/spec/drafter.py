"""Model-free draft-token proposers.

Both drafters run on the host between engine ticks and cost no accelerator
time — the bet of prompt-lookup speculation is that real text (and greedy
decode loops) repeat themselves, so the request's OWN token history is a
usable draft model.  A drafter may return fewer than ``k`` tokens (or none:
that row degenerates to plain one-token decode for the tick).
"""
from __future__ import annotations

import abc
from typing import Optional

import numpy as np


class Drafter(abc.ABC):
    """Proposes up to ``k`` continuation tokens for a token history."""

    @abc.abstractmethod
    def draft(self, context: np.ndarray, k: int) -> np.ndarray:
        """context: [L] int tokens (prompt + generated so far); returns
        [<=k] int draft tokens (possibly empty)."""


class NgramDrafter(Drafter):
    """Prompt-lookup decoding: find the most recent earlier occurrence of
    the history's trailing n-gram and propose the tokens that followed it.
    Tries the longest n first (more specific match, better acceptance) and
    backs off to shorter n-grams down to ``min_n``."""

    def __init__(self, max_n: int = 3, min_n: int = 1):
        assert max_n >= min_n >= 1
        self.max_n, self.min_n = max_n, min_n

    def draft(self, context: np.ndarray, k: int) -> np.ndarray:
        ctx = np.asarray(context)
        L = len(ctx)
        if k <= 0 or L < self.min_n + 1:
            return ctx[:0]
        for n in range(min(self.max_n, L - 1), self.min_n - 1, -1):
            tail = ctx[L - n:]
            # windows ending strictly before the end, newest match first
            for start in range(L - n - 1, -1, -1):
                if np.array_equal(ctx[start:start + n], tail):
                    cont = ctx[start + n:start + n + k]
                    if len(cont):
                        return cont.copy()
        return ctx[:0]


class StaticSuffixDrafter(Drafter):
    """Trace-replay drafter: drafts come from a known reference sequence
    (prompt + expected output), indexed by how many tokens the request has
    produced so far.  Acceptance is 1.0 when the trace matches the model's
    greedy path — the upper-bound harness for benchmarks and the exactness
    tests — and 0 when it diverges (the adversarial case)."""

    def __init__(self, sequence: np.ndarray):
        self.sequence = np.asarray(sequence)

    def draft(self, context: np.ndarray, k: int) -> np.ndarray:
        at = len(context)
        return self.sequence[at:at + k].copy()


def make_drafter(kind: str, *, ngram_n: int = 3,
                 suffix: Optional[np.ndarray] = None) -> Drafter:
    if kind == "ngram":
        return NgramDrafter(max_n=ngram_n)
    if kind == "suffix":
        if suffix is None:
            raise ValueError("suffix drafter needs a reference sequence "
                             "(Request.draft_suffix)")
        return StaticSuffixDrafter(suffix)
    raise ValueError(f"unknown drafter kind: {kind!r}")
