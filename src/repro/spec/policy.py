"""Speculation policy: static configuration + per-request adaptive depth.

Drafting is free but *verification* is not: every drafted token adds a query
row to the verify chunk, and every rejected token is wasted compute plus a
cache rollback.  ``AdaptiveK`` tracks a per-request acceptance EWMA and
walks the draft depth ``k`` between ``k_min`` and ``k_max`` so requests
whose history predicts well (templated text, greedy loops) speculate deeply
while adversarial ones fall back toward plain decode.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class SpecConfig:
    enabled: bool = True
    k_max: int = 4                 # draft depth ceiling (chunk is 1 + k_max)
    k_min: int = 1                 # adaptive floor; k_max disables adaptation
    drafter: str = "ngram"         # "ngram" | "suffix" (trace replay)
    ngram_n: int = 3               # longest n-gram the lookup tries
    adaptive: bool = True
    ewma: float = 0.5              # smoothing of the acceptance-rate estimate
    raise_at: float = 0.8          # EWMA above which k steps up
    lower_at: float = 0.4          # EWMA below which k steps down


class AdaptiveK:
    """Per-request draft-depth controller (multiplicative-ish AIMD on k)."""

    def __init__(self, cfg: SpecConfig):
        self.cfg = cfg
        self.k = cfg.k_max if not cfg.adaptive else max(cfg.k_min,
                                                        (cfg.k_max + 1) // 2)
        self.rate = 1.0            # optimistic start: try speculating
        self.drafted = 0
        self.accepted = 0

    def update(self, n_drafted: int, n_accepted: int):
        """Feed one verify step's outcome.  Steps where nothing was drafted
        (no n-gram match) carry no signal and leave the controller alone."""
        if n_drafted <= 0:
            return
        self.drafted += n_drafted
        self.accepted += n_accepted
        c = self.cfg
        step_rate = n_accepted / n_drafted
        self.rate = c.ewma * step_rate + (1.0 - c.ewma) * self.rate
        if not c.adaptive:
            return
        if self.rate >= c.raise_at:
            self.k = min(self.k + 1, c.k_max)
        elif self.rate < c.lower_at:
            self.k = max(self.k - 1, c.k_min)
