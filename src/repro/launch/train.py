"""Fine-tuning driver: concurrent multi-LoRA training through the unified
runtime.

  PYTHONPATH=src python -m repro.launch.train --arch llama3-8b --reduced \\
      --adapters 2 --epochs 2

Full-size configs are for real TPU slices (pair with launch/mesh.py); on this
CPU container always pass ``--reduced``.
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import ARCH_IDS, get_config, get_reduced
from repro.core.lora import LoRAConfig
from repro.core.virtualization import AdapterStore, MixedLoraModel
from repro.data import datasets
from repro.serving.engine import EngineConfig, UnifiedEngine
from repro.training.trainer import MixedLoraTrainer, TrainerConfig


def make_aux(cfg, rng):
    if cfg.encoder is not None:
        return rng.standard_normal((cfg.encoder.n_frames, cfg.d_model),
                                   dtype=np.float32) * 0.1
    if cfg.cross_attn_every:
        return rng.standard_normal((cfg.n_img_tokens, cfg.d_model),
                                   dtype=np.float32) * 0.1
    return None


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="llama3-8b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--adapters", type=int, default=2)
    ap.add_argument("--rows", type=int, default=48)
    ap.add_argument("--epochs", type=int, default=2)
    ap.add_argument("--accum", type=int, default=4)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_reduced(args.arch) if args.reduced else get_config(args.arch)
    from repro.models.schema import init_params
    params = init_params(cfg, jax.random.PRNGKey(args.seed))
    lcfg = LoRAConfig(n_slots=max(4, args.adapters), r=8)
    store = AdapterStore(cfg, lcfg, jax.random.PRNGKey(args.seed + 1))
    model = MixedLoraModel(cfg, params, store)
    eng = UnifiedEngine(model, EngineConfig(capacity=2, pf_capacity=2,
                                            s_max=max(256, 2)))
    rng = np.random.default_rng(args.seed)
    aux = make_aux(cfg, rng)

    gens = [datasets.alpaca_like, datasets.gsm8k_like]
    for i in range(args.adapters):
        name = f"adapter{i}"
        store.load_random(name, jax.random.PRNGKey(100 + i))
        rows = gens[i % 2](args.rows, vocab=cfg.vocab, seed=args.seed + i)
        tr_rows, ev_rows = datasets.split_eval(rows)
        eng.add_trainer(MixedLoraTrainer(
            name, store.slot_of(name), tr_rows, ev_rows,
            TrainerConfig(rows_per_micro=2, accum_steps=args.accum,
                          epochs=args.epochs), aux_embed=aux))

    t0 = time.time()
    metrics = eng.run(max_ticks=100000)
    dt = time.time() - t0
    print(f"arch={cfg.name} adapters={args.adapters} wall={dt:.1f}s "
          f"rates={metrics.rates()}")
    for name, tr in eng.trainers.items():
        first = np.mean(tr.train_losses[:4]) if tr.train_losses else float("nan")
        last = np.mean(tr.train_losses[-4:]) if tr.train_losses else float("nan")
        print(f"  {name}: loss {first:.3f} -> {last:.3f}  "
              f"opt_steps={tr.optimizer_steps} "
              f"eval={np.mean(tr.eval_losses[-4:]) if tr.eval_losses else float('nan'):.3f} "
              f"tokens={tr.tokens_trained}")


if __name__ == "__main__":
    main()
