"""Assigned input shapes and abstract input construction for the dry-run.

Decode shapes lower ``serve_step`` — ONE new token against a ``seq_len``
cache.  ``long_500k`` switches attention architectures to the sliding-window
decode variant (rolling-buffer cache, window 8192) so the step is
sub-quadratic; SSM/hybrid layers use their native O(1)/chunked paths.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.core.lora import LoRAConfig, abstract_lora_bank
from repro.models.configs import ModelConfig
from repro.models.model import abstract_cache
from repro.models.schema import abstract_params, lora_targets
from repro.models.stream import DECBatch, FTBatch, PFBatch, UnifiedBatch


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    kind: str           # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES: Dict[str, InputShape] = {
    "train_4k": InputShape("train_4k", "train", 4096, 256),
    "prefill_32k": InputShape("prefill_32k", "prefill", 32768, 32),
    "decode_32k": InputShape("decode_32k", "decode", 32768, 128),
    "long_500k": InputShape("long_500k", "decode", 524288, 1),
}

LONG_CONTEXT_WINDOW = 8192       # sliding-window for attention archs @500k
DRYRUN_LORA = LoRAConfig(n_slots=4, r=8)   # the paper's r=8


def has_attention(cfg: ModelConfig) -> bool:
    return any(k == "attn" for k in cfg.pattern)


def adapt_config(cfg: ModelConfig, shape: InputShape) -> ModelConfig:
    """Shape-driven config adaptation (bf16 compute; windowed long decode)."""
    cfg = cfg.replace(dtype="bfloat16")
    if shape.name == "long_500k" and has_attention(cfg):
        cfg = cfg.replace(sliding_window=LONG_CONTEXT_WINDOW)
    return cfg


def _i32(shape):
    return jax.ShapeDtypeStruct(shape, jnp.int32)


def _f(shape, dtype=jnp.bfloat16):
    return jax.ShapeDtypeStruct(shape, dtype)


def _bool(shape):
    return jax.ShapeDtypeStruct(shape, jnp.bool_)


def _aux_spec(cfg: ModelConfig, b: int) -> Optional[jax.ShapeDtypeStruct]:
    """Modality-frontend STUB: precomputed frame/patch embeddings."""
    if cfg.encoder is not None:
        return _f((b, cfg.encoder.n_frames, cfg.d_model))
    if cfg.cross_attn_every:
        return _f((b, cfg.n_img_tokens, cfg.d_model))
    return None


def input_specs(cfg: ModelConfig, shape: InputShape) -> Dict:
    """Abstract (ShapeDtypeStruct) inputs for jit lowering — no allocation."""
    b, s = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        ft = FTBatch(tokens=_i32((b, s)), mask=_bool((b, s)),
                     labels=_i32((b, s)), adapter=_i32((b,)),
                     weight=_f((b,), jnp.float32),
                     aux_embed=_aux_spec(cfg, b))
        return {"batch": UnifiedBatch(ft=ft), "cache": None}
    if shape.kind == "prefill":
        pf = PFBatch(tokens=_i32((b, s)), length=_i32((b,)),
                     adapter=_i32((b,)), aux_embed=_aux_spec(cfg, b))
        cache = abstract_cache(cfg, b, s)
        return {"batch": UnifiedBatch(pf=pf), "cache": cache}
    # decode: ONE token per row over a seq_len cache
    dec = DECBatch(tokens=_i32((b,)), pos=_i32((b,)), adapter=_i32((b,)))
    cache = abstract_cache(cfg, b, s)
    return {"batch": UnifiedBatch(dec=dec), "cache": cache}


def abstract_model_state(cfg: ModelConfig, lcfg: LoRAConfig = DRYRUN_LORA):
    """(params, lora bank, scale) as ShapeDtypeStructs."""
    params = abstract_params(cfg)
    bank = abstract_lora_bank(lora_targets(cfg, lcfg.targets), lcfg,
                              dtype=jnp.dtype(cfg.dtype))
    scale = jax.ShapeDtypeStruct((lcfg.n_slots,), jnp.float32)
    return params, bank, scale
