"""Serving driver: multi-LoRA inference (optionally co-running fine-tuning)
through the unified runtime.

  PYTHONPATH=src python -m repro.launch.serve --arch llama3-8b --reduced \\
      --rps 2 --requests 40 --adapters 2 [--finetune]
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.configs import ARCH_IDS, get_config, get_reduced
from repro.core.lora import LoRAConfig
from repro.core.virtualization import AdapterStore, MixedLoraModel
from repro.data import datasets, workload
from repro.serving.engine import EngineConfig, UnifiedEngine
from repro.serving.request import PRIORITY_CLASSES, Request
from repro.serving.slo import SLOConfig, slo_attainment
from repro.training.trainer import MixedLoraTrainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="llama3-8b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--adapters", type=int, default=2)
    ap.add_argument("--requests", type=int, default=40)
    ap.add_argument("--rps", type=float, default=2.0)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--finetune", action="store_true")
    ap.add_argument("--spec", type=int, default=0, metavar="K",
                    help="speculative decoding with up to K drafted tokens "
                         "per step (prompt-lookup drafter; exact greedy)")
    ap.add_argument("--wall-clock", action="store_true",
                    help="real time instead of the calibrated virtual clock")
    ap.add_argument("--prefill-chunk", type=int, default=0, metavar="T",
                    help="per-tick prefill-token budget: long prompts "
                         "prefill as bounded chunks co-batched with decode "
                         "(0 = unchunked)")
    ap.add_argument("--adapter-paging", default=False,
                    action=argparse.BooleanOptionalAction,
                    help="page adapter weights through the KV block pool "
                         "(unified memory: HBM flows between cache capacity "
                         "and adapter residency; scheduler prefers resident-"
                         "adapter waiters and co-batches same-adapter "
                         "requests).  Implies a small LRU adapter bank so "
                         "residency actually pages; default off = static "
                         "bank partition")
    ap.add_argument("--no-hash-dedup", action="store_true",
                    help="disable content-hash KV block dedup (and the "
                         "prefix-aware admission that rides on it): every "
                         "request recomputes and re-stores its whole prompt")
    ap.add_argument("--paged-attn-kernel", default=None,
                    choices=["off", "interpret", "tpu", "splitk",
                             "splitk-interpret"],
                    help="paged attention backend (sets "
                         "REPRO_PAGED_ATTN_KERNEL): off = jnp gather view; "
                         "interpret/tpu = sequential Pallas kernels; "
                         "splitk[-interpret] = flash-decoding split-K "
                         "decode/verify with autotuned fan-out")
    ap.add_argument("--attn-tune-file", default=None, metavar="PATH",
                    help="JSON tuning table for the paged-attention kernel "
                         "family (written by benchmarks/bench_kernels.py); "
                         "shapes it misses fall back to the deterministic "
                         "heuristic")
    ap.add_argument("--over-admit", type=float, default=1.0, metavar="F",
                    help="KV reservation lending factor >= 1.0: the gate "
                         "charges only 1/F of outstanding reservation debt "
                         "and preempts (recompute) when lending comes due "
                         "(1.0 = conservative gate)")
    ap.add_argument("--kv-host-blocks", type=int, default=0, metavar="N",
                    help="tiered KV memory: host-side block pool sized to N "
                         "device blocks' worth of host RAM.  Preemption "
                         "victims swap out D2H (and restore H2D at "
                         "re-admission) when the modeled transfer beats "
                         "suffix recompute, and shed hash-index blocks "
                         "demote to the host tier instead of being dropped "
                         "(0 = recompute-only preemption)")
    ap.add_argument("--kv-host-quant", action="store_true",
                    help="int8-quantize host-tier KV residency (~2x host "
                         "capacity at equal budget).  NOT bit-exact: "
                         "restored K/V is dequantized, so outputs may "
                         "differ from the recompute path")
    ap.add_argument("--priority", default="standard",
                    choices=["interactive", "standard", "batch", "mixed"],
                    help="request priority class: interactive is preempted "
                         "last and never lends its KV reservation; batch is "
                         "preempted first and lends first under "
                         "--over-admit; mixed round-robins the three "
                         "classes across requests")
    ap.add_argument("--replicas", type=int, default=1, metavar="N",
                    help="in-process engine replicas behind one router "
                         "(shared base weights, per-replica KV pools and "
                         "adapter banks, fleet-wide block index with "
                         "remote prefix fetch); 1 = single engine")
    ap.add_argument("--router", default="affinity",
                    choices=["affinity", "round-robin", "least-loaded"],
                    help="replica placement policy (--replicas > 1): "
                         "affinity scores resident prefix + adapter "
                         "residency against queue depth; the others are "
                         "locality-blind baselines")
    ap.add_argument("--no-remote-fetch", action="store_true",
                    help="never copy prefix blocks between replica pools "
                         "(independent replicas with local dedup only)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    if args.paged_attn_kernel is not None:
        import os
        os.environ["REPRO_PAGED_ATTN_KERNEL"] = (
            "" if args.paged_attn_kernel == "off" else args.paged_attn_kernel)
    if args.attn_tune_file:
        from repro.kernels.autotune import load_table
        n = load_table(args.attn_tune_file)
        print(f"attn autotune: loaded {n} entries from {args.attn_tune_file}")

    cfg = get_reduced(args.arch) if args.reduced else get_config(args.arch)
    from repro.models.schema import init_params
    params = init_params(cfg, jax.random.PRNGKey(args.seed))
    # under unified paging the bank is a small staging tier (adapters page
    # in/out of the shared pool); the static baseline sizes it to hold
    # every adapter, the pre-paging behavior
    n_slots = (max(4, min(args.adapters, 16)) if args.adapter_paging
               else max(4, args.adapters))
    lcfg = LoRAConfig(n_slots=n_slots, r=8)
    store = AdapterStore(cfg, lcfg, jax.random.PRNGKey(args.seed + 1))
    names = []
    ranks = [2, 4, 8]       # heterogeneous true ranks => variable footprints
    for i in range(args.adapters):
        name = f"lora{i}"
        store.load_random(name, jax.random.PRNGKey(100 + i),
                          evict=args.adapter_paging,
                          rank=(ranks[i % 3] if args.adapter_paging
                                else None))
        names.append(name)
    model = MixedLoraModel(cfg, params, store)
    spec = None
    if args.spec > 0:
        from repro.spec import SpecConfig
        spec = SpecConfig(k_max=args.spec, drafter="ngram")
    ecfg = EngineConfig(
        capacity=8, pf_capacity=4, s_max=256,
        virtual_time=not args.wall_clock, spec=spec,
        prefill_chunk=args.prefill_chunk,
        hash_dedup=not args.no_hash_dedup,
        over_admit=args.over_admit,
        adapter_paging=args.adapter_paging,
        kv_host_blocks=args.kv_host_blocks,
        kv_host_quant=args.kv_host_quant)
    fleet = None
    if args.replicas > 1:
        from repro.fleet import FleetConfig, RouterConfig, build_fleet
        fleet = build_fleet(model, ecfg, FleetConfig(
            replicas=args.replicas,
            router=RouterConfig(policy=args.router),
            remote_fetch=not args.no_remote_fetch))
        eng = fleet.engines[0]
    else:
        eng = UnifiedEngine(model, ecfg)
    if args.over_admit > 1.0 and not eng.paged:
        print("note: --over-admit needs the paged cache; using the "
              "conservative dense layout for this model")
    if args.prefill_chunk and not eng.chunk_budget:
        print("note: --prefill-chunk is inactive for this model "
              "(needs the paged cache and an attention-only pattern)")
    if eng.hash_dedup and not eng.suffix_prefill:
        print("note: hash dedup shares block STORAGE for this model but "
              "suffix-only prefill (compute skip) is inactive "
              "(needs the paged cache and an attention-only pattern)")

    rng = np.random.default_rng(args.seed)
    aux = None
    if cfg.encoder is not None:
        aux = rng.standard_normal((cfg.encoder.n_frames, cfg.d_model),
                                  dtype=np.float32) * 0.1
    elif cfg.cross_attn_every:
        aux = rng.standard_normal((cfg.n_img_tokens, cfg.d_model),
                                  dtype=np.float32) * 0.1

    prompts = datasets.sharegpt_prompts(args.requests, vocab=cfg.vocab,
                                        seed=args.seed)
    arrivals = workload.poisson_arrivals(args.rps, args.requests, args.seed)
    front = fleet if fleet is not None else eng
    classes = (PRIORITY_CLASSES if args.priority == "mixed"
               else (args.priority,))
    for i, (p, t) in enumerate(zip(prompts, arrivals)):
        front.submit(Request(rid=i, prompt=p, adapter=names[i % len(names)],
                             max_new_tokens=args.max_new, arrival=float(t),
                             aux_embed=aux,
                             priority_class=classes[i % len(classes)]))

    if args.finetune:
        rows = datasets.alpaca_like(32, vocab=cfg.vocab, seed=args.seed)
        tr_rows, ev_rows = datasets.split_eval(rows)
        eng.add_trainer(MixedLoraTrainer(
            names[0], store.slot_of(names[0]), tr_rows, ev_rows,
            TrainerConfig(rows_per_micro=2, accum_steps=4, epochs=1),
            aux_embed=aux))

    m = front.run(max_ticks=500000)
    finished = (eng.finished if fleet is None
                else [r for e in fleet.engines for r in e.finished])
    att = slo_attainment(finished, SLOConfig())
    print(f"arch={cfg.name} requests={args.requests} rps={args.rps} "
          f"finished={len(finished)} SLO={att:.3f}")
    print(f"rates={m.rates()}")
    if fleet is not None:
        print(f"fleet: replicas={args.replicas} router={args.router} "
              f"routed={fleet.routed} "
              f"remote_fetch_blocks={m.remote_fetch_blocks} "
              f"remote_fetch_time={m.remote_fetch_time:.4f} "
              f"fleet_index_keys={len(fleet.index)}")
    if args.spec > 0:
        drafted = (m.spec_drafted if fleet is None
                   else sum(e.spec_drafted for e in m.per_engine))
        accepted = (m.spec_accepted if fleet is None
                    else sum(e.spec_accepted for e in m.per_engine))
        print(f"spec: drafted={drafted} accepted={accepted} "
              f"acceptance={accepted / max(drafted, 1):.2f} steps={m.steps}")
    def tot(field, agg=sum):
        # fleet rollup carries the headline counters; per-engine Metrics
        # hold the rest — aggregate either way
        if fleet is not None:
            return agg(getattr(e, field) for e in m.per_engine)
        return getattr(m, field)

    if args.over_admit > 1.0 or tot("preemptions"):
        print(f"over-admit: factor={args.over_admit} "
              f"preemptions={tot('preemptions')} "
              f"recomputed={tot('preempted_tokens_recomputed')} "
              f"lent_peak={tot('lent_blocks_peak', max)}")
    if m.reused_prefix_tokens or args.prefill_chunk:
        print(f"prefix: reused={m.reused_prefix_tokens} "
              f"computed={m.prefill_tokens} "
              f"max_pf_step={tot('max_pf_tokens_step', max)}")
    if args.adapter_paging or tot("adapter_swap_ins"):
        print(f"adapters: swap_ins={tot('adapter_swap_ins')} "
              f"swap_in_bytes={tot('adapter_swap_in_bytes')} "
              f"resident_hits={tot('adapter_resident_hits')} "
              f"blocks_resident={tot('adapter_blocks_resident')} "
              f"peak_coresident={tot('adapter_peak_coresident', max)}")
    if args.kv_host_blocks > 0:
        print(f"kv-tiers: host_blocks={args.kv_host_blocks} "
              f"quant={args.kv_host_quant} "
              f"swap_outs={tot('kv_swap_outs')} "
              f"restores={tot('kv_restores')} "
              f"skips={tot('kv_swap_skips')} "
              f"demotions={tot('kv_demotions')} "
              f"rehydrated={tot('kv_rehydrated_blocks')} "
              f"host_peak_bytes={tot('host_bytes_peak', max)}")
    if eng.hash_dedup:
        print(f"dedup: hash_hits={m.hash_hits} "
              f"resident_blocks={tot('hash_blocks_resident')} "
              f"probe_admissions={tot('probe_admissions')}")
    if args.finetune:
        tr = eng.trainers[names[0]]
        print(f"finetune: tokens={tr.tokens_trained} "
              f"opt_steps={tr.optimizer_steps}")


if __name__ == "__main__":
    main()
