import os
os.environ["XLA_FLAGS"] = os.environ.get("XLA_FLAGS", "") + " --xla_force_host_platform_device_count=512"
# The two lines above MUST stay first: jax locks the device count on first
# init, and the dry-run needs 512 placeholder host devices for the
# 2 x 16 x 16 production mesh.  Do NOT set this flag anywhere global —
# smoke tests and benchmarks run on 1 device.
#
# Multi-pod dry-run: lower + compile every (architecture x input shape) on
# the production mesh, print memory/cost analyses, and emit roofline terms.
#
# Usage:
#   PYTHONPATH=src python -m repro.launch.dryrun --arch llama3-8b --shape train_4k
#   PYTHONPATH=src python -m repro.launch.dryrun --both-meshes [--out DIR]
import argparse
import json
import time
import traceback
from typing import Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCH_IDS, get_config
from repro.core.unified import make_forward_step, make_train_step
from repro.distributed import sharding as sh
from repro.launch.mesh import make_production_mesh
from repro.launch.shapes import (DRYRUN_LORA, SHAPES, InputShape,
                                 abstract_model_state, adapt_config,
                                 input_specs)
from repro.models import costs
from repro.roofline import analysis as ra
from repro.training.optimizer import AdamWConfig, adamw_init

ASSIGNED_ARCHS = [a for a in ARCH_IDS if a != "llama3-8b"]
DEFAULT_CHUNK = 1024


def model_flops(cfg, shape: InputShape) -> float:
    """MODEL_FLOPS: 6*N*D for training, 2*N*D for inference (N active)."""
    n = cfg.param_count(active_only=True)
    if shape.kind == "train":
        d = shape.global_batch * shape.seq_len
        return 6.0 * n * d
    if shape.kind == "prefill":
        d = shape.global_batch * shape.seq_len
        return 2.0 * n * d
    d = shape.global_batch              # one token per row
    return 2.0 * n * d


def lower_one(arch: str, shape_name: str, multi_pod: bool = False,
              strategy: str = "fsdp_tp", attn_chunk: int = DEFAULT_CHUNK,
              seq_act_shard: bool = True, cache_strategy: str = "auto",
              quant_int8: bool = False, verbose: bool = True) -> Dict:
    shape = SHAPES[shape_name]
    cfg = adapt_config(get_config(arch), shape)
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.size
    t0 = time.time()

    params_abs, bank_abs, scale_abs = abstract_model_state(cfg)
    ins = input_specs(cfg, shape)
    batch_abs, cache_abs = ins["batch"], ins["cache"]

    if quant_int8:
        from repro.models import quant
        params_abs = quant.abstract_quantized(cfg)
        p_shard = quant.quant_shardings(cfg, mesh, strategy)
    else:
        p_shard = sh.param_shardings(cfg, mesh, strategy)
    bank_shard = sh.lora_shardings(bank_abs, mesh)
    scale_shard = sh.replicated(mesh)
    batch_shard = sh.batch_shardings(batch_abs, mesh)

    with sh.mesh_context(mesh):
        if shape.kind == "train":
            opt = AdamWConfig()
            act = sh.act_constraint_fn(mesh) if seq_act_shard else None
            step = make_train_step(cfg, opt, remat=True,
                                   attn_chunk=attn_chunk,
                                   act_constraint=act, jit=False)
            opt_abs = jax.eval_shape(
                lambda b: adamw_init(b, DRYRUN_LORA.n_slots), bank_abs)
            opt_shard = sh.opt_shardings(opt_abs, mesh)
            mask_abs = jax.ShapeDtypeStruct((DRYRUN_LORA.n_slots,),
                                            jnp.float32)
            # donate bank + optimizer state: updated values alias inputs
            jitted = jax.jit(step, in_shardings=(
                p_shard, bank_shard, scale_shard, opt_shard, batch_shard,
                scale_shard), donate_argnums=(1, 3))
            lowered = jitted.lower(params_abs, bank_abs, scale_abs, opt_abs,
                                   batch_abs, mask_abs)
        else:
            chunk = attn_chunk if shape.kind == "prefill" else 0
            step = make_forward_step(cfg, attn_chunk=chunk, jit=False)
            cache_shard = sh.cache_shardings(cfg, cache_abs, mesh,
                                             strategy=cache_strategy)
            # donate the cache: the updated cache aliases the input buffer
            # (decode would otherwise double its HBM footprint)
            jitted = jax.jit(step, in_shardings=(
                p_shard, bank_shard, scale_shard, batch_shard, cache_shard),
                donate_argnums=(4,))
            lowered = jitted.lower(params_abs, bank_abs, scale_abs,
                                   batch_abs, cache_abs)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    mem = ra.memory_summary(compiled)
    hlo = compiled.as_text()
    hcost = ra.hlo_cost(compiled)
    # inner chunk loops (q-map / kv-scan) nest under the layer scan
    inner = max(shape.seq_len // max(attn_chunk, 1), 1)
    coll = ra.collective_bytes(hlo, loop_trips=(cfg.n_periods, inner, inner))
    dp = chips // mesh.shape["model"]
    acost = costs.step_cost(cfg, shape.kind, shape.seq_len,
                            shape.global_batch, dp=dp,
                            tp=mesh.shape["model"], strategy=strategy,
                            attn_chunk=attn_chunk)
    roof = ra.Roofline(flops=acost.flops, hbm_bytes=acost.hbm_bytes,
                       coll_bytes=max(acost.coll_bytes,
                                      float(sum(coll["scaled"].values()))),
                       chips=chips, model_flops=model_flops(cfg, shape))
    rec = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "chips": chips, "strategy": strategy,
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "memory": mem,
        "collectives_hlo": coll,
        "hlo_cost_raw": hcost,
        "analytic_detail": {k: round(v, 3) for k, v in acost.detail.items()},
        "roofline": roof.as_dict(),
        "status": "ok",
    }
    if verbose:
        print(f"[dryrun] {arch} x {shape_name} x {rec['mesh']}: "
              f"compile {t_compile:.0f}s  "
              f"per-dev peak ~{mem['peak_estimate_bytes']/2**30:.2f} GiB  "
              f"dominant={roof.dominant}  "
              f"terms(c/m/n)={roof.compute_s:.4f}/{roof.memory_s:.4f}/"
              f"{roof.collective_s:.4f}s")
        print(f"  memory_analysis: {mem}")
        print(f"  cost: analytic flops/dev={roof.flops:.3e} "
              f"hbm/dev={roof.hbm_bytes:.3e} coll/dev={roof.coll_bytes:.3e} "
              f"| hlo raw flops={hcost['flops']:.3e} "
              f"useful_ratio={roof.useful_flops_ratio:.3f}")
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS + ["all"], default="all")
    ap.add_argument("--shape", choices=list(SHAPES) + ["all"], default="all")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--strategy", default="fsdp_tp",
                    choices=["fsdp_tp", "tp"])
    ap.add_argument("--attn-chunk", type=int, default=DEFAULT_CHUNK)
    ap.add_argument("--no-seq-act-shard", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args()

    archs = ASSIGNED_ARCHS if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    os.makedirs(args.out, exist_ok=True)
    failures = 0
    for arch in archs:
        for shp in shapes:
            for mp in meshes:
                tag = f"{arch}__{shp}__{'2x16x16' if mp else '16x16'}"
                path = os.path.join(args.out, tag + ".json")
                if os.path.exists(path):
                    print(f"[dryrun] skip {tag} (exists)")
                    continue
                try:
                    rec = lower_one(arch, shp, multi_pod=mp,
                                    strategy=args.strategy,
                                    attn_chunk=args.attn_chunk,
                                    seq_act_shard=not args.no_seq_act_shard)
                except Exception as e:  # noqa: BLE001 — record, keep going
                    traceback.print_exc()
                    rec = {"arch": arch, "shape": shp,
                           "mesh": "2x16x16" if mp else "16x16",
                           "status": "fail", "error": f"{type(e).__name__}: {e}"}
                    failures += 1
                with open(path, "w") as f:
                    json.dump(rec, f, indent=1)
    print(f"[dryrun] done; {failures} failures")
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
