"""Production mesh construction.

Defined as FUNCTIONS (never module-level constants) so importing this module
never touches jax device state — the dry-run must set
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before first init.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod (v5e pod slice); multi_pod stacks 2 pods =
    512 chips with a leading "pod" axis."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh():
    """Single-device mesh (CPU tests): every rule degenerates to replicate."""
    return jax.make_mesh((1, 1), ("data", "model"))


def batch_axes(mesh) -> tuple:
    """Mesh axes the batch dimension shards over."""
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def axis_size(mesh, names) -> int:
    if isinstance(names, str):
        names = (names,)
    n = 1
    for a in names:
        n *= mesh.shape[a]
    return n
