"""Distribution: logical-axis sharding rules for params, LoRA banks, batches
and caches."""
