"""Logical-axis -> mesh sharding rules.

Strategies:
  "tp"      — tensor parallel over the "model" axis only; params replicated
              across data/pod.
  "fsdp_tp" — additionally shard the "embed" logical axis of every weight
              over "data" (FSDP); pods replicate (DP across pods).  This is
              the baseline for >=100B configs (they cannot fit replicated).

Divisibility is checked per leaf: a dimension that does not divide the mesh
axis is replicated (e.g. 40 attention heads or 8 KV heads on a 16-way model
axis, the 50280/51865 vocabs).  Head-count sharding is only applied when the
HEAD COUNT divides the axis — sharding the flattened h*hd dim across head
boundaries would force per-layer resharding after the reshape to heads.

SSM projections shard over "model" (head-parallel Mamba TP) because the
schema emits head-ALIGNED component projections (separate z/x/BC/dt weights)
instead of one fused zxBCdt matrix — the fused layout crosses component
boundaries and cannot shard (EXPERIMENTS.md §Perf HC2).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core.lora import LoRAConfig
from repro.models.configs import ModelConfig
from repro.models.schema import build_schema, _is_p
from repro.launch.mesh import batch_axes, axis_size


def _spec_for(cfg: ModelConfig, mesh, strategy: str, shape, logical) -> P:
    msize = mesh.shape["model"]
    dsize = mesh.shape["data"]
    parts = []
    used = set()
    for dim, name in zip(shape, logical):
        ax = None
        if name == "embed":
            if strategy == "fsdp_tp" and dim % dsize == 0:
                ax = "data"
        elif name == "vocab":
            if dim % msize == 0:
                ax = "model"
        elif name == "heads":
            if cfg.n_heads % msize == 0 and dim % msize == 0:
                ax = "model"
        elif name == "kv_heads":
            if cfg.n_kv_heads % msize == 0 and dim % msize == 0:
                ax = "model"
        elif name == "heads_sep":
            if dim % msize == 0:
                ax = "model"
        elif name in ("ffn", "experts"):
            if dim % msize == 0:
                ax = "model"
        elif name in ("ssm", "ssm_heads"):
            # Mamba head-parallel TP: shard d_inner / head dims when the
            # SSM head count divides the model axis (EXPERIMENTS.md §Perf)
            if cfg.ssm is not None and cfg.n_ssm_heads % msize == 0 \
                    and dim % msize == 0:
                ax = "model"
        # periods / enc_layers / None -> replicate
        if ax in used:       # one mesh axis per spec (experts wins over ffn)
            ax = None
        if ax is not None:
            used.add(ax)
        parts.append(ax)
    return P(*parts)


def param_shardings(cfg: ModelConfig, mesh, strategy: str = "fsdp_tp"):
    """Pytree of NamedSharding matching ``schema.init_params`` structure."""
    schema = build_schema(cfg)
    return jax.tree_util.tree_map(
        lambda p: NamedSharding(mesh, _spec_for(cfg, mesh, strategy,
                                                p.shape, p.logical)),
        schema, is_leaf=_is_p)


def replicated(mesh):
    return NamedSharding(mesh, P())


def tree_replicated(tree, mesh):
    rep = replicated(mesh)
    return jax.tree_util.tree_map(lambda _: rep, tree)


def _dim0_batch_spec(mesh, b: int, extra_dims: int) -> P:
    bx = batch_axes(mesh)
    if b % axis_size(mesh, bx) == 0:
        return P(bx, *([None] * extra_dims))
    return P(*([None] * (extra_dims + 1)))


def batch_shardings(batch, mesh):
    """Shard every bucket tensor's leading (row) dim over the batch axes."""
    def spec(x):
        if x is None:
            return None
        return NamedSharding(mesh, _dim0_batch_spec(mesh, x.shape[0],
                                                    x.ndim - 1))
    return jax.tree_util.tree_map(spec, batch)


def cache_shardings(cfg: ModelConfig, cache_abs, mesh,
                    strategy: str = "auto"):
    """Cache leaves are [Pn, B, ...]: B over batch axes when divisible;
    the widest remaining axis over "model" per the rules in the module doc.

    strategy="seq" shards the KV SEQUENCE axis over "model" instead of the
    kv-head/head_dim axes (flash-decoding layout): each model shard holds a
    contiguous slice of every row's history and computes local softmax
    partials; GSPMD then reduces tiny (m, l, acc) statistics instead of
    full attention scores — the §Perf hillclimb for GQA decode where
    n_kv_heads < model axis."""
    msize = mesh.shape["model"]
    bx = batch_axes(mesh)
    bsz = axis_size(mesh, bx)

    def leaf_spec(path, x):
        key = None
        for p in path:
            if hasattr(p, "key"):
                key = str(p.key)
        dims = [None] * x.ndim
        if x.shape[1] % bsz == 0 and x.shape[1] > 1:
            dims[1] = bx
        if key in ("k", "v", "xk", "xv"):
            # [Pn, B, S, kv, hd]
            if strategy == "seq" and x.shape[2] % msize == 0:
                dims[2] = "model"
            elif cfg.n_kv_heads % msize == 0:
                dims[3] = "model"
            elif x.shape[4] % msize == 0:
                dims[4] = "model"
            elif x.shape[2] % msize == 0:
                dims[2] = "model"
        elif key in ("ckv", "kpe"):
            # [Pn, B, S, c] — shard the sequence axis (latent stays whole)
            if x.shape[2] % msize == 0:
                dims[2] = "model"
        elif key == "h":
            # [Pn, B, nh, hd, ds]
            if x.shape[2] % msize == 0:
                dims[2] = "model"
        elif key == "conv_x":
            if x.shape[3] % msize == 0:
                dims[3] = "model"
        # conv_bc stays replicated (small, group-shared)
        return NamedSharding(mesh, P(*dims))

    return jax.tree_util.tree_map_with_path(leaf_spec, cache_abs)


def lora_shardings(bank_abs, mesh):
    """Shard each adapter's wide dim over "model": ``a``'s d_in (contraction
    — GSPMD inserts a small [T,n,r] partial-sum) and ``b``'s d_out (matches
    the base linear's output sharding).  At 100B scale the bank + its f32
    Adam moments are NOT negligible (~GBs replicated)."""
    msize = mesh.shape["model"]

    def leaf(path, x):
        key = None
        for p in path:
            if hasattr(p, "key"):
                key = str(p.key)
        dims = [None] * x.ndim
        if key == "a" and x.ndim >= 2 and x.shape[-2] % msize == 0:
            dims[-2] = "model"
        elif key == "b" and x.ndim >= 1 and x.shape[-1] % msize == 0:
            dims[-1] = "model"
        return NamedSharding(mesh, P(*dims))

    return jax.tree_util.tree_map_with_path(leaf, bank_abs)


def opt_shardings(opt_abs, mesh):
    """AdamW moments follow the bank sharding; counters replicate."""
    bank_like_m = lora_shardings(opt_abs.m, mesh)
    bank_like_v = lora_shardings(opt_abs.v, mesh)
    return type(opt_abs)(m=bank_like_m, v=bank_like_v,
                         t=replicated(mesh))


def act_constraint_fn(mesh):
    """Sequence-parallel activation constraint: shard the flattened token
    axis of the scan carry over (batch axes + model) so per-period saved
    activations fit HBM on long-sequence training."""
    bx = batch_axes(mesh)
    spec = P((*bx, "model"), None)

    def constrain(x):
        return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))

    return constrain


def mesh_context(mesh):
    """Context manager installing ``mesh`` as the ambient mesh —
    ``jax.set_mesh`` on new jax, the Mesh object itself (a context manager)
    on older releases."""
    return jax.set_mesh(mesh) if hasattr(jax, "set_mesh") else mesh


def ambient_mesh():
    """The mesh currently in scope, or None.  Compat wrapper:
    ``jax.sharding.get_abstract_mesh`` only exists on newer jax; older
    releases expose the ambient mesh via the thread-local resource env."""
    fn = getattr(jax.sharding, "get_abstract_mesh", None)
    if fn is not None:
        return fn()
    try:
        from jax._src.mesh import thread_resources
        pm = thread_resources.env.physical_mesh
        return None if pm.empty else pm
    except Exception:
        return None


def maybe_constrain(x, *spec):
    """with_sharding_constraint that no-ops when no mesh (or no "model"
    axis) is in scope — lets model code carry expert-parallel layout hints
    without breaking single-device tests."""
    am = ambient_mesh()
    if am is None or am.empty or "model" not in am.axis_names:
        return x
    ok = all(s is None or (isinstance(s, str) and s in am.axis_names)
             or (isinstance(s, tuple) and all(a in am.axis_names for a in s))
             for s in spec)
    if not ok:
        return x
    return jax.lax.with_sharding_constraint(x, P(*spec))
