"""jamba-1.5-large-398b — hybrid Mamba+attention 1:7 with MoE [arXiv:2403.19887].

72L d_model=8192 64H (GQA kv=8) d_ff=24576 vocab=65536.  Each 8-layer period
contains 1 attention + 7 Mamba layers; MoE (16 experts, top-2) every other
layer, dense FFN on the rest.
"""
from repro.models.configs import ModelConfig, MoEConfig, SSMConfig

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b",
    arch_type="hybrid",
    n_layers=72,
    d_model=8192,
    n_heads=64, n_kv_heads=8,
    d_ff=24576,
    vocab=65536,
    rope_theta=10000.0,
    block_pattern=("mamba", "mamba", "mamba", "mamba",
                   "attn", "mamba", "mamba", "mamba"),
    moe=MoEConfig(num_experts=16, top_k=2, d_ff_expert=24576, num_shared=0,
                  every=2, capacity_factor=1.25),
    ssm=SSMConfig(d_state=128, head_dim=64, expand=2, chunk=128, conv_width=4),
    source="Jamba-1.5 [arXiv:2403.19887]",
)

REDUCED = CONFIG.replace(
    name="jamba-reduced", n_layers=4, d_model=256, n_heads=8, n_kv_heads=2,
    d_ff=512, vocab=512,
    block_pattern=("mamba", "attn", "mamba", "mamba"),
    moe=MoEConfig(num_experts=4, top_k=2, d_ff_expert=512, num_shared=0,
                  every=2, capacity_factor=1.5),
    ssm=SSMConfig(d_state=16, head_dim=32, expand=2, chunk=16, conv_width=4),
)
