"""qwen1.5-110b — dense, QKV bias [hf:Qwen/Qwen1.5-0.5B family scaling].

80L d_model=8192 64H (GQA kv=8) d_ff=49152 vocab=152064.
"""
from repro.models.configs import ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-110b",
    arch_type="dense",
    n_layers=80,
    d_model=8192,
    n_heads=64, n_kv_heads=8,
    d_ff=49152,
    vocab=152064,
    qkv_bias=True,
    rope_theta=1000000.0,
    source="Qwen1.5 [hf:Qwen/Qwen1.5-0.5B]",
)

REDUCED = CONFIG.replace(
    name="qwen1.5-reduced", n_layers=2, d_model=256, n_heads=8, n_kv_heads=2,
    d_ff=512, vocab=512,
)
