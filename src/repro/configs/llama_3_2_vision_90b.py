"""llama-3.2-vision-90b — VLM with cross-attention image layers
[hf:meta-llama/Llama-3.2-11B-Vision family].

100L d_model=8192 64H (GQA kv=8) d_ff=28672 vocab=128256.  Every 5th decoder
layer cross-attends to vision-patch embeddings.  The ViT/projector frontend is
a STUB per the assignment carve-out: ``input_specs`` supplies precomputed
patch embeddings [B, n_img_tokens, d_model].
"""
from repro.models.configs import ModelConfig

CONFIG = ModelConfig(
    name="llama-3.2-vision-90b",
    arch_type="vlm",
    n_layers=100,
    d_model=8192,
    n_heads=64, n_kv_heads=8,
    d_ff=28672,
    vocab=128256,
    rope_theta=500000.0,
    block_pattern=("attn",) * 5,
    cross_attn_every=5,
    n_img_tokens=1600,
    source="Llama 3.2 Vision [hf:meta-llama/Llama-3.2-11B-Vision]",
)

REDUCED = CONFIG.replace(
    name="llama32v-reduced", n_layers=5, d_model=256, n_heads=8, n_kv_heads=2,
    d_ff=512, vocab=512, n_img_tokens=16,
)
