"""phi3-medium-14b — dense, RoPE SwiGLU GQA [arXiv:2404.14219].

40L d_model=5120 40H (GQA kv=10) d_ff=17920 vocab=100352.
"""
from repro.models.configs import ModelConfig

CONFIG = ModelConfig(
    name="phi3-medium-14b",
    arch_type="dense",
    n_layers=40,
    d_model=5120,
    n_heads=40, n_kv_heads=10,
    d_ff=17920,
    vocab=100352,
    rope_theta=10000.0,
    source="Phi-3 [arXiv:2404.14219]",
)

REDUCED = CONFIG.replace(
    name="phi3-reduced", n_layers=2, d_model=256, n_heads=8, n_kv_heads=2,
    d_ff=512, vocab=512,
)
