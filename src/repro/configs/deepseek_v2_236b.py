"""deepseek-v2-236b — MLA + MoE [arXiv:2405.04434].

60L d_model=5120 128H (MLA kv_lora=512) d_ff_expert=1536 vocab=102400,
MoE: 2 shared + 160 routed, top-6.
"""
from repro.models.configs import ModelConfig, MoEConfig, MLAConfig

CONFIG = ModelConfig(
    name="deepseek-v2-236b",
    arch_type="moe",
    n_layers=60,
    d_model=5120,
    n_heads=128, n_kv_heads=128,
    head_dim=128,
    d_ff=0,                       # all FFNs are MoE
    vocab=102400,
    rope_theta=10000.0,
    moe=MoEConfig(num_experts=160, top_k=6, d_ff_expert=1536, num_shared=2,
                  every=1, capacity_factor=1.25),
    mla=MLAConfig(kv_lora_rank=512, qk_rope_dim=64, qk_nope_dim=128,
                  v_head_dim=128),
    source="DeepSeek-V2 [arXiv:2405.04434]",
)

REDUCED = CONFIG.replace(
    name="dsv2-reduced", n_layers=2, d_model=256, n_heads=8, n_kv_heads=8,
    head_dim=32, vocab=512,
    moe=MoEConfig(num_experts=4, top_k=2, d_ff_expert=256, num_shared=1,
                  every=1, capacity_factor=1.5),
    mla=MLAConfig(kv_lora_rank=64, qk_rope_dim=16, qk_nope_dim=32,
                  v_head_dim=32),
)
