"""llama3-8b — the paper's own base model [arXiv:2407.21783].

32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=128256.  Used by the
paper-claim benchmarks (Tables 2-8, Figures 2-6) in reduced form.
"""
from repro.models.configs import ModelConfig

CONFIG = ModelConfig(
    name="llama3-8b",
    arch_type="dense",
    n_layers=32,
    d_model=4096,
    n_heads=32, n_kv_heads=8,
    d_ff=14336,
    vocab=128256,
    rope_theta=500000.0,
    source="Llama 3 [arXiv:2407.21783] (paper base model)",
)

REDUCED = CONFIG.replace(
    name="llama3-reduced", n_layers=2, d_model=256, n_heads=8, n_kv_heads=2,
    d_ff=512, vocab=512,
)
