"""whisper-base — encoder-decoder audio backbone [arXiv:2212.04356].

6L (enc) + 6L (dec) d_model=512 8H d_ff=2048 vocab=51865.  The mel/conv
frontend is a STUB per the assignment carve-out: ``input_specs`` supplies
precomputed frame embeddings [B, 1500, 512]; we implement the transformer
backbone (bidirectional encoder + causal decoder with cross-attention).
"""
from repro.models.configs import ModelConfig, EncoderConfig

CONFIG = ModelConfig(
    name="whisper-base",
    arch_type="audio",
    n_layers=6,                    # decoder layers; encoder layers in EncoderConfig
    d_model=512,
    n_heads=8, n_kv_heads=8,
    d_ff=2048,
    vocab=51865,
    rope_theta=10000.0,
    encoder=EncoderConfig(n_layers=6, n_frames=1500),
    source="Whisper [arXiv:2212.04356]",
)

REDUCED = CONFIG.replace(
    name="whisper-reduced", n_layers=2, d_model=128, n_heads=4, n_kv_heads=4,
    d_ff=256, vocab=512, encoder=EncoderConfig(n_layers=2, n_frames=32),
)
