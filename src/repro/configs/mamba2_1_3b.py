"""mamba2-1.3b — SSD (state-space duality), attention-free [arXiv:2405.21060].

48L d_model=2048, d_ff=0 (no MLP; the Mamba2 block is the whole layer),
vocab=50280, ssm_state=128.
"""
from repro.models.configs import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="mamba2-1.3b",
    arch_type="ssm",
    n_layers=48,
    d_model=2048,
    n_heads=1, n_kv_heads=1,          # unused (attention-free)
    d_ff=0,
    vocab=50280,
    block_pattern=("mamba",),
    ssm=SSMConfig(d_state=128, head_dim=64, expand=2, chunk=128, conv_width=4),
    tie_embeddings=True,
    source="SSD / Mamba2 [arXiv:2405.21060]",
)

REDUCED = CONFIG.replace(
    name="mamba2-reduced", n_layers=2, d_model=256, vocab=512,
    ssm=SSMConfig(d_state=16, head_dim=32, expand=2, chunk=16, conv_width=4),
)
