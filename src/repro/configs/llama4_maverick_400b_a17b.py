"""llama4-maverick-400b-a17b — MoE 128e top-1, early fusion
[hf:meta-llama/Llama-4-Scout-17B-16E family].

48L d_model=5120 40H (GQA kv=8) d_ff=8192 vocab=202048.  MoE (128 routed
top-1 + 1 shared expert) interleaved every other layer, dense FFN otherwise —
matching Maverick's interleaved MoE giving ~400B total / ~17B active params.
"""
from repro.models.configs import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="llama4-maverick-400b-a17b",
    arch_type="moe",
    n_layers=48,
    d_model=5120,
    n_heads=40, n_kv_heads=8,
    d_ff=8192,
    vocab=202048,
    rope_theta=500000.0,
    block_pattern=("attn", "attn"),
    moe=MoEConfig(num_experts=128, top_k=1, d_ff_expert=8192, num_shared=1,
                  every=2, capacity_factor=1.25),
    source="Llama 4 Maverick [hf:meta-llama/Llama-4-Scout-17B-16E]",
)

REDUCED = CONFIG.replace(
    name="llama4-reduced", n_layers=2, d_model=256, n_heads=8, n_kv_heads=2,
    d_ff=512, vocab=512,
    moe=MoEConfig(num_experts=4, top_k=1, d_ff_expert=512, num_shared=1,
                  every=2, capacity_factor=1.5),
)
