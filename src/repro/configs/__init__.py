"""Assigned-architecture registry.

Each module defines ``CONFIG`` (the exact full-size assigned config, citing its
source) and ``REDUCED`` (a tiny same-family variant for CPU smoke tests).
``get_config(name)`` / ``get_reduced(name)`` look them up; ``ARCH_IDS`` lists
all selectable ``--arch`` ids.
"""
from __future__ import annotations

import importlib

ARCH_IDS = [
    "mamba2-1.3b",
    "phi3-medium-14b",
    "qwen1.5-110b",
    "deepseek-7b",
    "llama4-maverick-400b-a17b",
    "deepseek-v2-236b",
    "whisper-base",
    "command-r-35b",
    "jamba-1.5-large-398b",
    "llama-3.2-vision-90b",
    # the paper's own base model
    "llama3-8b",
]

_MOD = {a: a.replace("-", "_").replace(".", "_") for a in ARCH_IDS}


def _load(name: str):
    if name not in _MOD:
        raise KeyError(f"unknown arch {name!r}; available: {ARCH_IDS}")
    return importlib.import_module(f"repro.configs.{_MOD[name]}")


def get_config(name: str):
    cfg = _load(name).CONFIG
    cfg.validate()
    return cfg


def get_reduced(name: str):
    cfg = _load(name).REDUCED
    cfg.validate()
    return cfg
