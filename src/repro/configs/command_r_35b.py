"""command-r-35b — dense GQA, no bias [hf:CohereForAI/c4ai-command-r-v01].

40L d_model=8192 64H (GQA kv=8) d_ff=22528 vocab=256000.
"""
from repro.models.configs import ModelConfig

CONFIG = ModelConfig(
    name="command-r-35b",
    arch_type="dense",
    n_layers=40,
    d_model=8192,
    n_heads=64, n_kv_heads=8,
    d_ff=22528,
    vocab=256000,
    rope_theta=8000000.0,
    tie_embeddings=True,
    source="Command-R [hf:CohereForAI/c4ai-command-r-v01]",
)

REDUCED = CONFIG.replace(
    name="commandr-reduced", n_layers=2, d_model=256, n_heads=8, n_kv_heads=2,
    d_ff=512, vocab=512,
)
