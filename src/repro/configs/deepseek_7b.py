"""deepseek-7b — dense llama-arch [arXiv:2401.02954].

30L d_model=4096 32H (GQA kv=32, i.e. MHA) d_ff=11008 vocab=102400.
"""
from repro.models.configs import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-7b",
    arch_type="dense",
    n_layers=30,
    d_model=4096,
    n_heads=32, n_kv_heads=32,
    d_ff=11008,
    vocab=102400,
    rope_theta=10000.0,
    source="DeepSeek LLM [arXiv:2401.02954]",
)

REDUCED = CONFIG.replace(
    name="deepseek7b-reduced", n_layers=2, d_model=256, n_heads=8, n_kv_heads=8,
    d_ff=512, vocab=512,
)
