"""Data substrate: synthetic corpora and serving workload generators."""
