"""Serving workload generators: Poisson arrivals, the paper's mutable-load
schedule (Table 7), and BurstGPT-like bursty traces (Table 8 statistics)."""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

import numpy as np


def poisson_arrivals(rps: float, n: int, seed: int = 0,
                     t0: float = 0.0) -> np.ndarray:
    rng = np.random.default_rng(seed)
    gaps = rng.exponential(1.0 / max(rps, 1e-9), size=n)
    return t0 + np.cumsum(gaps)


@dataclasses.dataclass(frozen=True)
class Phase:
    rps: float
    start: float
    duration: float
    n: int
    adapter_index: int = 0


# Table 7 — mutable capacity allocation simulation
MUTABLE_PHASES: Tuple[Phase, ...] = (
    Phase(rps=1.0, start=0.0, duration=120.0, n=120, adapter_index=0),
    Phase(rps=2.5, start=120.0, duration=60.0, n=150, adapter_index=1),
    Phase(rps=2.0, start=180.0, duration=120.0, n=240, adapter_index=2),
    Phase(rps=1.0, start=300.0, duration=120.0, n=120, adapter_index=3),
)


def phased_arrivals(phases: Sequence[Phase], seed: int = 0
                    ) -> List[Tuple[float, int]]:
    """[(arrival_time, adapter_index)] sorted by time."""
    rng = np.random.default_rng(seed)
    out = []
    for ph in phases:
        gaps = rng.exponential(1.0 / ph.rps, size=ph.n)
        t = ph.start + np.cumsum(gaps)
        t = t[t <= ph.start + ph.duration * 1.5]
        out.extend((float(x), ph.adapter_index) for x in t)
    out.sort()
    return out


# Table 8 — BurstGPT time-period statistics (mean RPS, peak RPS, requests)
BURSTGPT_PERIODS = {
    "d29_13h": dict(requests=676, mean_rps=0.563, peak_rps=1.5),
    "d29_15h": dict(requests=2145, mean_rps=1.788, peak_rps=11.5),
    "d29_16h": dict(requests=1465, mean_rps=1.226, peak_rps=7.0),
    "d33_1340": dict(requests=2823, mean_rps=2.354, peak_rps=10.0),
    "d33_1140": dict(requests=2360, mean_rps=1.966, peak_rps=12.0),
    "d33_11h": dict(requests=1856, mean_rps=1.547, peak_rps=10.5),
}


def burstgpt_like(period: str, duration: float = 1200.0, seed: int = 0,
                  scale: float = 1.0) -> np.ndarray:
    """Bursty arrival times reproducing a BurstGPT slice's mean/peak RPS:
    a baseline Poisson process plus short spikes reaching the peak rate.
    ``scale`` shrinks the trace (fewer requests, same shape) for CPU runs."""
    st = BURSTGPT_PERIODS[period]
    rng = np.random.default_rng(seed)
    n = int(st["requests"] * scale)
    mean, peak = st["mean_rps"] * scale, st["peak_rps"] * scale
    # 85% of volume as baseline Poisson, 15% inside spikes
    n_spike = int(0.15 * n)
    base = poisson_arrivals(max(mean * 0.85, 1e-6), n - n_spike, seed)
    base = base[base < duration]
    spikes = []
    n_windows = max(1, n_spike // max(int(peak * 2), 1))
    for w in range(n_windows):
        t0 = rng.uniform(0, duration - 2.0)
        k = min(n_spike - len(spikes), max(int(peak * 2), 1))
        spikes.extend(t0 + np.sort(rng.uniform(0, 2.0, size=k)))
        if len(spikes) >= n_spike:
            break
    t = np.sort(np.concatenate([base, np.asarray(spikes)]))
    return t
