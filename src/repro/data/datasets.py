"""Synthetic stand-ins for the paper's datasets (offline container).

Sequences carry learnable structure (orderly markov-style token streams with
per-dataset transition signatures) so fine-tuning loss genuinely decreases
and different adapters genuinely learn different things — enough to exercise
every system path the paper benchmarks with Alpaca / GSM8K / ShareGPT.
"""
from __future__ import annotations

from typing import List, Tuple

import numpy as np


def _markov_rows(n_rows: int, len_lo: int, len_hi: int, vocab: int,
                 seed: int, stride: int) -> List[Tuple[np.ndarray, np.ndarray]]:
    rng = np.random.default_rng(seed)
    rows = []
    for _ in range(n_rows):
        L = int(rng.integers(len_lo, len_hi + 1))
        start = int(rng.integers(0, vocab))
        noise = rng.integers(0, 3, size=L)
        toks = (start + stride * np.arange(L) + noise) % vocab
        toks = toks.astype(np.int32)
        rows.append((toks, toks.copy()))          # causal-LM labels = inputs
    return rows


def alpaca_like(n_rows: int = 64, vocab: int = 512, seed: int = 0,
                len_lo: int = 24, len_hi: int = 96):
    """Instruction-tuning-ish rows (dataset signature: stride 3)."""
    return _markov_rows(n_rows, len_lo, len_hi, vocab, seed, stride=3)


def gsm8k_like(n_rows: int = 64, vocab: int = 512, seed: int = 1,
               len_lo: int = 48, len_hi: int = 160):
    """Math-reasoning-ish rows (longer; dataset signature: stride 7)."""
    return _markov_rows(n_rows, len_lo, len_hi, vocab, seed, stride=7)


def sharegpt_prompts(n: int = 128, vocab: int = 512, seed: int = 2,
                     len_lo: int = 8, len_hi: int = 64) -> List[np.ndarray]:
    """Inference prompts with a ShareGPT-ish length spread."""
    rng = np.random.default_rng(seed)
    out = []
    for _ in range(n):
        L = int(np.clip(rng.lognormal(np.log((len_lo + len_hi) / 2), 0.5),
                        len_lo, len_hi))
        out.append(rng.integers(0, vocab, size=L).astype(np.int32))
    return out


def split_eval(rows, frac: float = 0.125):
    k = max(1, int(len(rows) * frac))
    return rows[k:], rows[:k]
