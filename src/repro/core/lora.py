"""Multi-LoRA adapter algebra.

A *LoRA bank* holds ``n_slots`` adapters stacked on a leading axis so that a
single kernel call serves every token in a mixed-adapter token stream (the
paper's SMLM design).  Per-token adapter ids select the adapter; id ``-1``
(or any out-of-range id) means "base model only" and contributes nothing.

Layout per target linear (stacked over scan periods where applicable):
    a: [..., n_slots, d_in, r]     (gaussian init — matches the paper's
                                    ``init_lora_weights=gaussian``)
    b: [..., n_slots, r, d_out]    (zeros init — standard LoRA)

Static scaling (alpha/r) is folded into ``b`` at materialisation, exactly as
the paper folds it into the weight tensor at ``MixedLoraModel`` instantiation;
dynamic per-request scaling is applied via the ``scale_t`` per-token vector.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class LoRAConfig:
    n_slots: int = 4            # resident adapter slots
    r: int = 8
    alpha: float = 16.0
    dropout: float = 0.05       # used by the trainer (train-time only)
    # which linears receive adapters; mirrors the paper's "Full" setting
    # (q,k,v,o,up,gate,down).  Schema marks eligible leaves; this filters.
    targets: Tuple[str, ...] = ("wq", "wk", "wv", "wo", "wg", "wu", "wd",
                                "wdkv", "in_x", "in_z", "out_proj")

    @property
    def scaling(self) -> float:
        return self.alpha / self.r


def lora_apply_ref(x: jax.Array, a: jax.Array, b: jax.Array,
                   ids: jax.Array, scale_t: Optional[jax.Array] = None
                   ) -> jax.Array:
    """Pure-jnp oracle for SMLM: one-hot mixed multi-LoRA matmul.

    x: [T, d_in]; a: [n, d_in, r]; b: [n, r, d_out]; ids: [T] int32.
    Out-of-range ids produce an all-zero one-hot row -> no adapter.
    """
    n = a.shape[0]
    onehot = jax.nn.one_hot(ids, n, dtype=x.dtype)            # [T, n]
    if scale_t is not None:
        onehot = onehot * scale_t[:, None].astype(x.dtype)
    xa = jnp.einsum("td,ndr->tnr", x, a.astype(x.dtype))       # [T, n, r]
    xa = xa * onehot[:, :, None]
    return jnp.einsum("tnr,nro->to", xa, b.astype(x.dtype))


def lora_apply(x: jax.Array, a: jax.Array, b: jax.Array, ids: jax.Array,
               scale_t: Optional[jax.Array] = None,
               impl: str = "auto") -> jax.Array:
    """Dispatch between the Pallas SMLM kernel (TPU) and the jnp oracle."""
    if impl == "auto":
        impl = "smlm" if jax.default_backend() == "tpu" else "ref"
    if impl == "ref":
        return lora_apply_ref(x, a, b, ids, scale_t)
    from repro.kernels import ops as kops
    return kops.smlm(x, a, b, ids, scale_t, interpret=(impl == "interpret"))


def dense(x: jax.Array, w: jax.Array, bias: Optional[jax.Array],
          lora: Optional[dict], ids: Optional[jax.Array],
          scale_t: Optional[jax.Array] = None, impl: str = "auto"
          ) -> jax.Array:
    """Joint base + multi-LoRA linear over a flattened token stream [T, d].

    This is the paper's unified projection: ONE base matmul for every request
    type plus ONE segmented multi-LoRA multiplication, instead of a per-adapter
    loop (cf. Section 3.3).
    """
    y = x @ w.astype(x.dtype)
    if bias is not None:
        y = y + bias.astype(x.dtype)
    if lora is not None and ids is not None:
        y = y + lora_apply(x, lora["a"], lora["b"], ids, scale_t, impl=impl)
    return y


def init_lora_bank(key: jax.Array, schema_targets, lcfg: LoRAConfig,
                   dtype=jnp.float32, gaussian_b: bool = False):
    """Materialise a LoRA bank for ``schema_targets``: a pytree whose leaves
    are ``repro.models.schema.LoraTarget`` descriptors.  Returns a parallel
    pytree of {"a": ..., "b": ...}.  ``b`` is zeros by default (standard LoRA
    init); ``gaussian_b`` matches the paper's fine-tuning-experiment setting
    of fully gaussian adapters.  Static alpha/r scaling is folded into ``b``
    at materialisation (the paper folds it into the weight at instantiation).
    """
    from repro.models.schema import LoraTarget
    is_leaf = lambda x: isinstance(x, LoraTarget)
    leaves, treedef = jax.tree_util.tree_flatten(schema_targets, is_leaf=is_leaf)
    keys = jax.random.split(key, max(2 * len(leaves), 2))
    out = []
    for i, tgt in enumerate(leaves):
        a_shape = (*tgt.stack, lcfg.n_slots, tgt.d_in, lcfg.r)
        b_shape = (*tgt.stack, lcfg.n_slots, lcfg.r, tgt.d_out)
        a = jax.random.normal(keys[2 * i], a_shape, dtype) \
            * (1.0 / jnp.sqrt(tgt.d_in))
        if gaussian_b:
            b = jax.random.normal(keys[2 * i + 1], b_shape, dtype) \
                * (0.02 * lcfg.scaling)
        else:
            b = jnp.zeros(b_shape, dtype)
        out.append({"a": a, "b": b})
    return jax.tree_util.tree_unflatten(treedef, out)


def abstract_lora_bank(schema_targets, lcfg: LoRAConfig, dtype=jnp.float32):
    """ShapeDtypeStruct mirror of ``init_lora_bank`` (for the dry-run)."""
    from repro.models.schema import LoraTarget
    is_leaf = lambda x: isinstance(x, LoraTarget)
    return jax.tree_util.tree_map(
        lambda t: {"a": jax.ShapeDtypeStruct((*t.stack, lcfg.n_slots, t.d_in, lcfg.r), dtype),
                   "b": jax.ShapeDtypeStruct((*t.stack, lcfg.n_slots, lcfg.r, t.d_out), dtype)},
        schema_targets, is_leaf=is_leaf)


def merge_adapter(w: jax.Array, a: jax.Array, b: jax.Array,
                  slot: int) -> jax.Array:
    """Merge one adapter slot into the base weight (the *static_merge*
    baseline; destroys multi-adapter flexibility — cf. DESIGN.md)."""
    return w + a[slot] @ b[slot]


def slot_token_ids(row_adapter: jax.Array, row_len: int) -> jax.Array:
    """Expand per-row adapter ids to per-token ids for a [B, S] bucket."""
    return jnp.repeat(row_adapter, row_len)
