"""Unified computation-flow planning (host side of Algorithms 1–2).

The planner turns heterogeneous pending work — fine-tuning microbatch rows,
evaluation rows, prefill requests, decode slots — into ONE ``UnifiedBatch``
with static bucket shapes:

* shapes snap to bucket grids so each (Bf,Sf,Bp,Sp,Bd) combination compiles
  once (the TPU-idiomatic replacement for dynamic kernel launches);
* every row's sequence is padded to a multiple of ``block_t`` so all token
  segments are SMLM-tile aligned (property-tested);
* padding rows carry ``adapter=-1`` (base-only, zero LoRA) and zero loss
  weight, so they are numerically inert.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

import numpy as np
import jax.numpy as jnp

from repro.models.configs import ModelConfig
from repro.models.stream import DECBatch, FTBatch, PFBatch, UnifiedBatch


@dataclasses.dataclass(frozen=True)
class FlowConfig:
    block_t: int = 8                 # SMLM token-tile size (128 on real TPU)
    row_buckets: Tuple[int, ...] = (1, 2, 4, 8, 16, 32, 64, 128)
    seq_buckets: Tuple[int, ...] = (16, 32, 64, 128, 256, 512, 1024, 2048,
                                    4096, 8192, 16384, 32768)


@dataclasses.dataclass
class FTRow:
    tokens: np.ndarray               # [L] int
    labels: np.ndarray               # [L] int (-100 ignore)
    slot: int                        # adapter slot (-1 = base)
    weight: float = 1.0              # per-row loss scale (1/accum etc.)
    trainer: Optional[str] = None    # owning trainer (loss bookkeeping)
    is_eval: bool = False
    aux_embed: Optional[np.ndarray] = None


@dataclasses.dataclass
class PFReq:
    tokens: np.ndarray               # [L] prompt (or uncached suffix/chunk)
    slot: int
    rid: int = -1                    # request id (engine bookkeeping)
    aux_embed: Optional[np.ndarray] = None
    block_table: Optional[np.ndarray] = None  # [nbt] int32 (paged layout)
    cached_len: Optional[int] = None  # prefix tokens already in the blocks:
    # ``tokens`` is the suffix starting at this absolute position (suffix-
    # only prefill / chunked prefill).  None = full-prompt prefill.


def bucket(n: int, buckets: Sequence[int]) -> int:
    for b in buckets:
        if b >= n:
            return b
    return buckets[-1] if n <= buckets[-1] else n


def _pad_seq(n: int, fcfg: FlowConfig) -> int:
    b = bucket(n, fcfg.seq_buckets)
    return ((b + fcfg.block_t - 1) // fcfg.block_t) * fcfg.block_t


def plan_ft(rows: List[FTRow], fcfg: FlowConfig,
            d_model: int = 0) -> Optional[FTBatch]:
    if not rows:
        return None
    Bf = bucket(len(rows), fcfg.row_buckets)
    Sf = _pad_seq(max(len(r.tokens) for r in rows), fcfg)
    toks = np.zeros((Bf, Sf), np.int32)
    mask = np.zeros((Bf, Sf), bool)
    labels = np.full((Bf, Sf), -100, np.int32)
    adapter = np.full((Bf,), -1, np.int32)
    weight = np.zeros((Bf,), np.float32)
    aux = None
    if rows[0].aux_embed is not None:
        F, D = rows[0].aux_embed.shape
        aux = np.zeros((Bf, F, D), np.float32)
    for i, r in enumerate(rows):
        L = len(r.tokens)
        toks[i, :L] = r.tokens
        mask[i, :L] = True
        labels[i, :L] = r.labels
        adapter[i] = r.slot
        weight[i] = 0.0 if r.is_eval else r.weight
        if aux is not None:
            aux[i] = r.aux_embed
    return FTBatch(tokens=jnp.asarray(toks), mask=jnp.asarray(mask),
                   labels=jnp.asarray(labels), adapter=jnp.asarray(adapter),
                   weight=jnp.asarray(weight),
                   aux_embed=jnp.asarray(aux) if aux is not None else None)


def plan_pf(reqs: List[PFReq], fcfg: FlowConfig) -> Optional[PFBatch]:
    if not reqs:
        return None
    Bp = bucket(len(reqs), fcfg.row_buckets)
    Sp = _pad_seq(max(len(r.tokens) for r in reqs), fcfg)
    toks = np.zeros((Bp, Sp), np.int32)
    length = np.zeros((Bp,), np.int32)
    adapter = np.full((Bp,), -1, np.int32)
    aux = None
    if reqs[0].aux_embed is not None:
        F, D = reqs[0].aux_embed.shape
        aux = np.zeros((Bp, F, D), np.float32)
    tables = None
    if reqs[0].block_table is not None:
        tables = np.zeros((Bp, len(reqs[0].block_table)), np.int32)
    # suffix-only prefill: one row carrying a cached prefix makes the whole
    # bucket positional (padding rows get cached_len 0, which is inert)
    cached = None
    if any(r.cached_len is not None for r in reqs):
        cached = np.zeros((Bp,), np.int32)
    for i, r in enumerate(reqs):
        L = len(r.tokens)
        toks[i, :L] = r.tokens
        length[i] = L
        adapter[i] = r.slot
        if aux is not None:
            aux[i] = r.aux_embed
        if tables is not None:
            tables[i] = r.block_table
        if cached is not None:
            cached[i] = r.cached_len or 0
    return PFBatch(tokens=jnp.asarray(toks), length=jnp.asarray(length),
                   adapter=jnp.asarray(adapter),
                   aux_embed=jnp.asarray(aux) if aux is not None else None,
                   block_tables=(jnp.asarray(tables) if tables is not None
                                 else None),
                   cached_len=(jnp.asarray(cached) if cached is not None
                               else None))


def plan_dec(tokens: np.ndarray, pos: np.ndarray, slots: np.ndarray,
             tables: Optional[np.ndarray] = None,
             lengths: Optional[np.ndarray] = None) -> Optional[DECBatch]:
    """``tokens`` is [Bd] for plain decode or [Bd, Sd] for speculative
    verify chunks; ``lengths`` gives each row's valid chunk length."""
    if len(tokens) == 0:
        return None
    return DECBatch(tokens=jnp.asarray(tokens, jnp.int32),
                    pos=jnp.asarray(pos, jnp.int32),
                    adapter=jnp.asarray(slots, jnp.int32),
                    block_tables=(jnp.asarray(tables, jnp.int32)
                                  if tables is not None else None),
                    length=(jnp.asarray(lengths, jnp.int32)
                            if lengths is not None else None))


def assemble(ft_rows: List[FTRow], pf_reqs: List[PFReq],
             dec_tokens: np.ndarray, dec_pos: np.ndarray,
             dec_slots: np.ndarray, fcfg: FlowConfig,
             dec_tables: Optional[np.ndarray] = None,
             dec_lens: Optional[np.ndarray] = None) -> UnifiedBatch:
    return UnifiedBatch(ft=plan_ft(ft_rows, fcfg),
                        pf=plan_pf(pf_reqs, fcfg),
                        dec=plan_dec(dec_tokens, dec_pos, dec_slots,
                                     dec_tables, dec_lens))


def token_adapter_ids(batch: UnifiedBatch) -> np.ndarray:
    """Per-token adapter ids of the flattened stream (mirrors model._Plan)."""
    ids = []
    if batch.ft is not None:
        Bf, Sf = batch.ft.tokens.shape
        ids.append(np.repeat(np.asarray(batch.ft.adapter), Sf))
    if batch.pf is not None:
        Bp, Sp = batch.pf.tokens.shape
        ids.append(np.repeat(np.asarray(batch.pf.adapter), Sp))
    if batch.dec is not None:
        tok = np.asarray(batch.dec.tokens)
        Sd = tok.shape[1] if tok.ndim == 2 else 1
        ids.append(np.repeat(np.asarray(batch.dec.adapter), Sd))
    return np.concatenate(ids) if ids else np.zeros((0,), np.int32)


def smlm_tile_aligned(batch: UnifiedBatch, block_t: int) -> bool:
    """The SMLM contract: within the ft+pf portion of the stream, every
    ``block_t`` token tile is adapter-uniform.  (The decode tail uses the
    per-token BGMV kernel, so it is exempt.)"""
    ids = []
    if batch.ft is not None:
        Bf, Sf = batch.ft.tokens.shape
        if Sf % block_t:
            return False
        ids.append(np.repeat(np.asarray(batch.ft.adapter), Sf))
    if batch.pf is not None:
        Bp, Sp = batch.pf.tokens.shape
        if Sp % block_t:
            return False
        ids.append(np.repeat(np.asarray(batch.pf.adapter), Sp))
    if not ids:
        return True
    flat = np.concatenate(ids)
    tiles = flat.reshape(-1, block_t)
    return bool((tiles == tiles[:, :1]).all())
