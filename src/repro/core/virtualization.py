"""Virtualized Module — base-model sharing, adapter slots, and migration.

The paper virtualizes torch ``nn.Module``s by synthesising proxy classes at
runtime.  JAX is functional, so virtualization is structural instead:

* the **base model** is one immutable pytree of arrays, shared by reference
  across every virtual model (zero extra weight memory — Table 2's "0 B");
* an **AdapterStore** owns the stacked LoRA bank (``n_slots`` resident
  adapters) plus the name->slot map; loading an adapter writes one slot,
  unloading frees it — no kernel restart, no base-weight touch;
* a **VirtualModel** is a named view ``(base, store, slot, mode)``.  The
  paper's ``void``/``unvoid`` migration maps to detaching the adapter leaves
  to host memory (serializable, base excluded) and re-binding them on a new
  device/mesh.

``MixedLoraModel`` mirrors the paper's class of the same name: the object the
unified computation flow executes, carrying every resident adapter at once.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.lora import LoRAConfig, init_lora_bank
from repro.models.configs import ModelConfig
from repro.models.schema import lora_targets


def _slot_take(bank, slot: int):
    """Extract one adapter's params from the stacked bank."""
    return jax.tree_util.tree_map(lambda x: x[..., slot, :, :], bank)


def _slot_put(bank, slot: int, adapter):
    return jax.tree_util.tree_map(
        lambda full, one: full.at[..., slot, :, :].set(one.astype(full.dtype)),
        bank, adapter)


def _slot_zero(bank, slot: int):
    return jax.tree_util.tree_map(
        lambda x: x.at[..., slot, :, :].set(0.0), bank)


@dataclasses.dataclass
class VoidedModel:
    """A voided virtual model: adapter weights detached to host numpy, ready
    for serialization / cross-device migration.  The base model is NOT
    included (that is the whole point)."""
    name: str
    cfg_name: str
    adapter: Any                     # pytree of np.ndarray
    scale: float


class AdapterStore:
    """Owns the stacked LoRA bank and the name->slot mapping.

    The bank is also an *evicting pool* (unified paging in spirit with the
    KV-block pool): when every slot is taken, an idle inference adapter —
    least-recently used, not pinned, zero active references — can be voided
    to host memory to make room, and is transparently reloaded on its next
    ``acquire``.  Training adapters are pinned (their slot doubles as
    optimizer state identity); adapters serving in-flight requests are
    protected by ``retain``/``release`` refcounts.
    """

    def __init__(self, cfg: ModelConfig, lcfg: LoRAConfig,
                 key: Optional[jax.Array] = None, dtype=None):
        self.cfg, self.lcfg = cfg, lcfg
        dtype = dtype or jnp.dtype(cfg.dtype)
        key = key if key is not None else jax.random.PRNGKey(0)
        targets = lora_targets(cfg, lcfg.targets)
        self.bank = init_lora_bank(key, targets, lcfg, dtype=dtype)
        # every slot starts empty (zero adapters): id -1 semantics aside,
        # a zero adapter is exactly "no adapter".
        self.bank = jax.tree_util.tree_map(jnp.zeros_like, self.bank)
        self.scale = jnp.ones((lcfg.n_slots,), jnp.float32)
        self._slots: Dict[str, int] = {}
        # eviction-pool bookkeeping
        self._voided: Dict[str, VoidedModel] = {}    # evicted, host-resident
        self._pinned: set = set()
        self._refs: Dict[str, int] = {}
        self._lru: Dict[str, int] = {}               # name -> last-touch tick
        self._tick = 0
        self.evictions = 0
        self.reloads = 0

    # -- slot management ---------------------------------------------------
    def slot_of(self, name: str) -> int:
        return self._slots[name]

    @property
    def resident(self) -> List[str]:
        return list(self._slots)

    @property
    def voided(self) -> List[str]:
        return list(self._voided)

    def _touch(self, name: str):
        self._tick += 1
        self._lru[name] = self._tick

    def _alloc(self, evict: bool = False) -> int:
        used = set(self._slots.values())
        for i in range(self.lcfg.n_slots):
            if i not in used:
                return i
        if evict:
            slot = self._evict_lru()
            if slot is not None:
                return slot
            raise RuntimeError("no free adapter slot and every resident "
                               "adapter is pinned or in use")
        raise RuntimeError("no free adapter slot; unload one first")

    def _evict_lru(self) -> Optional[int]:
        """Void the least-recently-used idle adapter to host; returns its
        freed slot (or None when everything is pinned / referenced)."""
        candidates = [n for n in self._slots
                      if n not in self._pinned and not self._refs.get(n, 0)]
        if not candidates:
            return None
        victim = min(candidates, key=lambda n: self._lru.get(n, 0))
        slot = self._slots[victim]
        self._voided[victim] = VoidedModel(
            name=victim, cfg_name=self.cfg.name,
            adapter=jax.tree_util.tree_map(lambda x: np.asarray(x),
                                           _slot_take(self.bank, slot)),
            scale=float(self.scale[slot]))
        self.unload(victim)
        self.evictions += 1
        return slot

    def load(self, name: str, adapter, scale: float = 1.0,
             evict: bool = False) -> int:
        """Load (or hot-swap in) an adapter pytree into a free slot —
        no recompilation, no base-model copy.  With ``evict=True``, a full
        bank LRU-evicts an idle adapter instead of raising."""
        if name in self._slots:
            raise ValueError(f"adapter {name!r} already resident")
        slot = self._alloc(evict=evict)
        self.bank = _slot_put(self.bank, slot, adapter)
        self.scale = self.scale.at[slot].set(scale)
        self._slots[name] = slot
        self._voided.pop(name, None)
        self._touch(name)
        return slot

    def load_random(self, name: str, key: jax.Array, scale: float = 1.0,
                    gaussian_b: bool = True) -> int:
        targets = lora_targets(self.cfg, self.lcfg.targets)
        fresh = init_lora_bank(key, targets, self.lcfg, gaussian_b=gaussian_b)
        return self.load(name, _slot_take(fresh, 0), scale)

    def unload(self, name: str):
        slot = self._slots.pop(name)
        self.bank = _slot_zero(self.bank, slot)
        self._lru.pop(name, None)

    # -- eviction pool ------------------------------------------------------
    def acquire(self, name: str) -> int:
        """Resolve an adapter to its slot, transparently reloading it from
        host if it was evicted (possibly evicting another idle adapter)."""
        if name in self._slots:
            self._touch(name)
            return self._slots[name]
        if name in self._voided:
            v = self._voided[name]
            slot = self.load(name, jax.tree_util.tree_map(jnp.asarray,
                                                          v.adapter),
                             v.scale, evict=True)
            self.reloads += 1
            return slot
        raise KeyError(f"unknown adapter {name!r}")

    def retain(self, name: str):
        """Mark the adapter as backing in-flight work (eviction-exempt)."""
        self._refs[name] = self._refs.get(name, 0) + 1

    def release(self, name: str):
        n = self._refs.get(name, 0) - 1
        if n <= 0:
            self._refs.pop(name, None)
        else:
            self._refs[name] = n

    def pin(self, name: str):
        """Exempt from eviction permanently (training adapters: their slot
        identity is baked into optimizer state and trainer masks)."""
        self._pinned.add(name)

    def unpin(self, name: str):
        self._pinned.discard(name)

    def get_adapter(self, name: str):
        return _slot_take(self.bank, self._slots[name])

    def set_bank(self, bank):
        """Replace the bank wholesale (after an optimizer update)."""
        self.bank = bank

    def slot_mask(self, names: List[str]) -> jax.Array:
        m = np.zeros((self.lcfg.n_slots,), np.float32)
        for n in names:
            m[self._slots[n]] = 1.0
        return jnp.asarray(m)


class VirtualModel:
    """An isolated adapter view over a shared base model (one per tenant /
    fine-tuning job).  Compatible with any PEFT that keeps the base weights
    untouched (the Virtualized-Module contract)."""

    def __init__(self, name: str, base_params, store: AdapterStore,
                 mode: str = "infer"):
        assert mode in ("infer", "train")
        self.name, self.base, self.store, self.mode = name, base_params, store, mode

    @property
    def slot(self) -> int:
        return self.store.slot_of(self.name)

    # -- migration (the paper's void / unvoid) ------------------------------
    def void(self) -> VoidedModel:
        adapter = jax.tree_util.tree_map(
            lambda x: np.asarray(x), self.store.get_adapter(self.name))
        return VoidedModel(name=self.name, cfg_name=self.store.cfg.name,
                           adapter=adapter,
                           scale=float(self.store.scale[self.slot]))

    @staticmethod
    def unvoid(voided: VoidedModel, base_params, store: AdapterStore,
               device=None, mode: str = "infer") -> "VirtualModel":
        assert store.cfg.name == voided.cfg_name, "config mismatch on migration"
        adapter = jax.tree_util.tree_map(
            lambda x: jax.device_put(jnp.asarray(x), device), voided.adapter)
        store.load(voided.name, adapter, voided.scale)
        return VirtualModel(voided.name, base_params, store, mode)


class MixedLoraModel:
    """The executable unit of the unified flow: shared base + resident
    adapter bank (paper Section 3.3)."""

    def __init__(self, cfg: ModelConfig, base_params, store: AdapterStore):
        self.cfg, self.base, self.store = cfg, base_params, store

    def virtual(self, name: str, mode: str = "infer") -> VirtualModel:
        return VirtualModel(name, self.base, self.store, mode)

    def forward(self, batch, cache=None, **kw):
        from repro.models.model import unified_forward
        return unified_forward(self.cfg, self.base, batch, cache,
                               loras=self.store.bank,
                               lora_scale=self.store.scale, **kw)
