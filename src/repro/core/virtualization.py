"""Virtualized Module — base-model sharing, adapter slots, and migration.

The paper virtualizes torch ``nn.Module``s by synthesising proxy classes at
runtime.  JAX is functional, so virtualization is structural instead:

* the **base model** is one immutable pytree of arrays, shared by reference
  across every virtual model (zero extra weight memory — Table 2's "0 B");
* an **AdapterStore** owns the stacked LoRA bank (``n_slots`` resident
  adapters) plus the name->slot map; loading an adapter writes one slot,
  unloading frees it — no kernel restart, no base-weight touch;
* a **VirtualModel** is a named view ``(base, store, slot, mode)``.  The
  paper's ``void``/``unvoid`` migration maps to detaching the adapter leaves
  to host memory (serializable, base excluded) and re-binding them on a new
  device/mesh.

``MixedLoraModel`` mirrors the paper's class of the same name: the object the
unified computation flow executes, carrying every resident adapter at once.

Unified adapter paging: ``AdapterStore.attach_pager`` binds the store to a
``PagedCacheManager`` so adapter weights page through the SAME refcounted
block pool as KV cache (the S-LoRA unified-memory design).  Residency then
has three tiers per adapter:

* **bank-materialized** — occupies a slot of the stacked bank (the small
  compute staging tier the BGMV/smlm kernels read);
* **pool-resident** — its flattened A/B payload (at TRUE rank, so
  heterogeneous ranks cost proportionally many blocks) lives in shared pool
  blocks; re-materializing into the bank is a cheap gather, no host
  traffic;
* **host-archived** — only the host master copy remains; the next
  ``acquire`` is a counted (and virtual-clock-charged) H2D swap-in.

The host archive is written once at ``load`` and kept current lazily: a
training update marks the adapter dirty (``mark_dirty``) and the payload is
re-flattened from the bank at the next sync point (bank eviction or pool
shed), so shedding never needs a D2H copy on the hot path.
``retain``/``release``/``pin``/``unpin`` forward to pool pins — an adapter
backing any scheduled row can never be shed out from under it.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.lora import LoRAConfig, init_lora_bank
from repro.errors import ConfigInvariantError, MigrationInvariantError
from repro.models.configs import ModelConfig
from repro.models.schema import lora_targets


def _slot_take(bank, slot: int):
    """Extract one adapter's params from the stacked bank."""
    return jax.tree_util.tree_map(lambda x: x[..., slot, :, :], bank)


def _slot_put(bank, slot: int, adapter):
    return jax.tree_util.tree_map(
        lambda full, one: full.at[..., slot, :, :].set(one.astype(full.dtype)),
        bank, adapter)


def _slot_zero(bank, slot: int):
    return jax.tree_util.tree_map(
        lambda x: x.at[..., slot, :, :].set(0.0), bank)


@dataclasses.dataclass
class VoidedModel:
    """A voided virtual model: adapter weights detached to host numpy, ready
    for serialization / cross-device migration.  The base model is NOT
    included (that is the whole point)."""
    name: str
    cfg_name: str
    adapter: Any                     # pytree of np.ndarray
    scale: float


class AdapterStore:
    """Owns the stacked LoRA bank and the name->slot mapping.

    The bank is also an *evicting pool* (unified paging in spirit with the
    KV-block pool): when every slot is taken, an idle inference adapter —
    least-recently used, not pinned, zero active references — can be voided
    to host memory to make room, and is transparently reloaded on its next
    ``acquire``.  Training adapters are pinned (their slot doubles as
    optimizer state identity); adapters serving in-flight requests are
    protected by ``retain``/``release`` refcounts.
    """

    def __init__(self, cfg: ModelConfig, lcfg: LoRAConfig,
                 key: Optional[jax.Array] = None, dtype=None):
        self.cfg, self.lcfg = cfg, lcfg
        dtype = dtype or jnp.dtype(cfg.dtype)
        key = key if key is not None else jax.random.PRNGKey(0)
        targets = lora_targets(cfg, lcfg.targets)
        self.bank = init_lora_bank(key, targets, lcfg, dtype=dtype)
        # every slot starts empty (zero adapters): id -1 semantics aside,
        # a zero adapter is exactly "no adapter".
        self.bank = jax.tree_util.tree_map(jnp.zeros_like, self.bank)
        self.scale = jnp.ones((lcfg.n_slots,), jnp.float32)
        self._slots: Dict[str, int] = {}
        # eviction-pool bookkeeping
        self._voided: Dict[str, VoidedModel] = {}    # evicted, host-resident
        self._pinned: set = set()
        self._refs: Dict[str, int] = {}
        self._lru: Dict[str, int] = {}               # name -> last-touch tick
        self._tick = 0
        self.evictions = 0
        self.reloads = 0
        # unified adapter paging (attach_pager): the flatten/unflatten spec
        # is the deterministic leaf order of the LoRA target tree — both
        # directions walk self._tleaves, so the byte image round-trips
        # bit-exactly (including the bank write, which is built FROM the
        # flattened image so bank and pool can never disagree)
        self._np_dtype = np.dtype(dtype)
        self._tleaves, self._tdef = jax.tree_util.tree_flatten(
            targets, is_leaf=lambda x: hasattr(x, "d_in"))
        self._ranks: Dict[str, int] = {}             # name -> true rank
        self._archive: Dict[str, Tuple[np.ndarray, float]] = {}
        self._dirty: set = set()                     # bank newer than archive
        self.pager = None                            # PagedCacheManager
        # swap accounting, maintained in BOTH modes so the virtual clock
        # charges the static-partition baseline and the unified pool the
        # same H2D price per swap-in (equal-HBM benches stay honest)
        self.swap_ins = 0
        self.swap_in_bytes = 0
        self.resident_hits = 0       # acquires served without host traffic
        self.peak_coresident = 0     # max adapters simultaneously in HBM

    # -- slot management ---------------------------------------------------
    def slot_of(self, name: str) -> int:
        return self._slots[name]

    @property
    def resident(self) -> List[str]:
        return list(self._slots)

    @property
    def voided(self) -> List[str]:
        """Adapters whose only live copy is host memory (the next acquire
        pays a swap-in)."""
        out = list(self._voided)
        if self.pager is not None:
            out += [n for n in self._archive
                    if n not in self._slots
                    and not self.pager.adapter_resident(n)]
        return out

    def _touch(self, name: str):
        self._tick += 1
        self._lru[name] = self._tick

    # -- unified paging: flatten/unflatten + pager binding ------------------
    def adapter_nbytes(self, name: Optional[str] = None,
                       rank: Optional[int] = None) -> int:
        """Byte footprint of an adapter at its TRUE rank (what the pool
        stores and a swap-in transfers)."""
        rk = (int(rank) if rank is not None
              else self._ranks.get(name, self.lcfg.r))
        it = self._np_dtype.itemsize
        tot = 0
        for t in self._tleaves:
            ns = int(np.prod(t.stack)) if t.stack else 1
            tot += ns * rk * (t.d_in + t.d_out) * it
        return tot

    def _flatten(self, adapter, rank: int) -> np.ndarray:
        """Raw byte image of an adapter pytree, sliced to its true rank:
        per target leaf, ``a[..., :, :rank]`` then ``b[..., :rank, :]``, in
        target-tree order.  Columns beyond the true rank are DROPPED — the
        round trip zero-fills them, which is also how a true-rank adapter
        is defined."""
        leaves = jax.tree_util.tree_leaves(
            adapter, is_leaf=lambda x: isinstance(x, dict) and "a" in x)
        if len(leaves) != len(self._tleaves):
            raise ValueError("adapter pytree does not match the LoRA "
                             "target schema")
        parts = []
        for d in leaves:
            a = np.asarray(d["a"]).astype(self._np_dtype,
                                          copy=False)[..., :, :rank]
            b = np.asarray(d["b"]).astype(self._np_dtype,
                                          copy=False)[..., :rank, :]
            parts.append(np.ascontiguousarray(a).reshape(-1).view(np.uint8))
            parts.append(np.ascontiguousarray(b).reshape(-1).view(np.uint8))
        return (np.concatenate(parts) if parts
                else np.zeros((0,), np.uint8))

    def _unflatten(self, flat: np.ndarray, rank: int):
        """Inverse of ``_flatten``, zero-padding each leaf back to the bank
        rank ``lcfg.r`` (the stacked bank is rank-uniform; a true-rank
        adapter simply leaves its tail columns zero)."""
        r_full = self.lcfg.r
        it = self._np_dtype.itemsize
        buf = np.ascontiguousarray(flat).view(np.uint8)
        off = 0
        out = []
        for t in self._tleaves:
            stack = tuple(t.stack)
            ns = int(np.prod(stack)) if stack else 1
            na = ns * t.d_in * rank * it
            a = np.frombuffer(buf[off:off + na].tobytes(),
                              self._np_dtype).reshape(*stack, t.d_in, rank)
            off += na
            nb = ns * rank * t.d_out * it
            b = np.frombuffer(buf[off:off + nb].tobytes(),
                              self._np_dtype).reshape(*stack, rank, t.d_out)
            off += nb
            af = np.zeros((*stack, t.d_in, r_full), self._np_dtype)
            af[..., :rank] = a
            bf = np.zeros((*stack, r_full, t.d_out), self._np_dtype)
            bf[..., :rank, :] = b
            out.append({"a": jnp.asarray(af), "b": jnp.asarray(bf)})
        return jax.tree_util.tree_unflatten(self._tdef, out)

    def attach_pager(self, pager):
        """Bind to a ``PagedCacheManager``: from here on adapter weights
        page through ITS block pool (unified KV + adapter memory).
        Already-loaded adapters are archived (flattened from the bank) and
        preloaded into the pool opportunistically — no shedding at attach;
        already-voided adapters migrate their host copies into the
        archive.  Existing pins/retains are forwarded so a pre-attached
        trainer pin protects its pool blocks too."""
        if self.pager is not None:
            raise RuntimeError("a pager is already attached to this store")
        self.pager = pager
        pager.on_adapter_shed = self._on_pool_shed
        pager.adapter_redundant_fn = (
            lambda n: n in self._slots and n not in self._dirty)
        for n in self._pinned:
            # reprolint: ownership-transfer — mirrors an existing store
            # pin; unpin happens when that pin is dropped
            pager.adapter_pin(n)
        for n, c in self._refs.items():
            for _ in range(c):
                # reprolint: ownership-transfer — mirrors existing retains
                pager.adapter_pin(n)
        for n in list(self._slots):
            self._ranks.setdefault(n, self.lcfg.r)
            self._sync_from_bank(n)
            pager.adapter_admit(n, self._archive[n][0], shed=False)
        for n, v in list(self._voided.items()):
            rk = self._ranks.setdefault(n, self.lcfg.r)
            self._archive[n] = (self._flatten(v.adapter, rk), v.scale)
            del self._voided[n]
            pager.adapter_admit(n, self._archive[n][0], shed=False)
        self._note_coresident()

    def _materialize(self, name: str, adapter, scale: float) -> int:
        """Write an adapter into a bank slot (LRU-evicting if full) without
        the registration semantics of ``load``."""
        slot = self._alloc(evict=True)
        self.bank = _slot_put(self.bank, slot, adapter)
        self.scale = self.scale.at[slot].set(scale)
        self._slots[name] = slot
        self._touch(name)
        return slot

    def _sync_from_bank(self, name: str, refresh: bool = True):
        """Re-flatten ``name`` from its bank slot into the host archive
        (and, when still pool-resident, rewrite its pool payload) — the
        write-back that makes a dirty trained adapter durable before its
        bank slot is reused."""
        rk = self._ranks.get(name, self.lcfg.r)
        flat = self._flatten(self.get_adapter(name), rk)
        self._archive[name] = (flat, float(self.scale[self._slots[name]]))
        if (refresh and self.pager is not None
                and self.pager.adapter_resident(name)):
            self.pager.adapter_refresh(name, flat)
        self._dirty.discard(name)

    def _on_pool_shed(self, name: str):
        """Pool shed callback (fires before the victim's blocks are
        freed): keep the host archive current.  The bank copy, if any,
        stays — it is the staging tier, and its LRU retires it
        independently."""
        if name in self._dirty:
            if name in self._slots:
                # bank holds the newest payload; no point refreshing pool
                # blocks that are about to be freed
                self._sync_from_bank(name, refresh=False)
            else:
                self._dirty.discard(name)

    def mark_dirty(self, name: str):
        """A training step rewrote this adapter's bank slot: archive and
        pool copies are stale until the next sync point."""
        if self.pager is not None and name in self._archive:
            self._dirty.add(name)

    def is_resident(self, name: str) -> bool:
        """Usable without a host swap-in: bank-materialized, or (paged
        mode) blocks live in the shared pool.  The scheduler's
        adapter-residency probe."""
        if name in self._slots:
            return True
        if self.pager is not None:
            return self.pager.adapter_resident(name)
        return False

    @property
    def coresident(self) -> int:
        """Adapters simultaneously in HBM (bank + pool, deduplicated)."""
        names = set(self._slots)
        if self.pager is not None:
            names |= set(self.pager.adapter_tables)
        return len(names)

    def _note_coresident(self):
        self.peak_coresident = max(self.peak_coresident, self.coresident)

    def _alloc(self, evict: bool = False) -> int:
        used = set(self._slots.values())
        for i in range(self.lcfg.n_slots):
            if i not in used:
                return i
        if evict:
            slot = self._evict_lru()
            if slot is not None:
                return slot
            raise RuntimeError("no free adapter slot and every resident "
                               "adapter is pinned or in use")
        raise RuntimeError("no free adapter slot; unload one first")

    def _evict_lru(self) -> Optional[int]:
        """Retire the least-recently-used idle adapter's bank slot; returns
        it (or None when everything is pinned / referenced).  Paged mode
        never writes a ``VoidedModel``: the archive (synced here if the
        victim is dirty) plus any pool residency already make the bank copy
        redundant."""
        candidates = [n for n in self._slots
                      if n not in self._pinned and not self._refs.get(n, 0)]
        if not candidates:
            return None
        victim = min(candidates, key=lambda n: self._lru.get(n, 0))
        slot = self._slots[victim]
        if self.pager is not None:
            if victim in self._dirty:
                self._sync_from_bank(victim)
        else:
            self._voided[victim] = VoidedModel(
                name=victim, cfg_name=self.cfg.name,
                adapter=jax.tree_util.tree_map(lambda x: np.asarray(x),
                                               _slot_take(self.bank, slot)),
                scale=float(self.scale[slot]))
        self.unload(victim)
        self.evictions += 1
        return slot

    def load(self, name: str, adapter, scale: float = 1.0,
             evict: bool = False, rank: Optional[int] = None) -> int:
        """Load (or hot-swap in) an adapter pytree into a free slot —
        no recompilation, no base-model copy.  With ``evict=True``, a full
        bank LRU-evicts an idle adapter instead of raising.  ``rank`` is
        the adapter's TRUE rank (<= the bank rank): columns beyond it are
        zeroed, and under unified paging its pool/transfer footprint is
        proportional to it (heterogeneous ranks => variable block
        counts)."""
        if name in self._slots:
            raise ValueError(f"adapter {name!r} already resident")
        rk = int(rank) if rank is not None else self.lcfg.r
        if not 1 <= rk <= self.lcfg.r:
            raise ValueError(f"rank {rk} outside [1, {self.lcfg.r}]")
        self._ranks[name] = rk
        # canonicalize through the byte image so bank contents are
        # identical whether the adapter arrives via pool round-trip or a
        # direct load (tail columns zeroed the same way in both modes)
        flat = self._flatten(adapter, rk)
        canon = self._unflatten(flat, rk)
        if self.pager is not None:
            self._archive[name] = (flat, float(scale))
            self._dirty.discard(name)
            self.pager.adapter_admit(name, flat)     # best effort
            slot = self._materialize(name, canon, scale)
            self._note_coresident()
            return slot
        slot = self._alloc(evict=evict)
        self.bank = _slot_put(self.bank, slot, canon)
        self.scale = self.scale.at[slot].set(scale)
        self._slots[name] = slot
        self._voided.pop(name, None)
        self._touch(name)
        self._note_coresident()
        return slot

    def load_random(self, name: str, key: jax.Array, scale: float = 1.0,
                    gaussian_b: bool = True, evict: bool = False,
                    rank: Optional[int] = None) -> int:
        targets = lora_targets(self.cfg, self.lcfg.targets)
        fresh = init_lora_bank(key, targets, self.lcfg, gaussian_b=gaussian_b)
        return self.load(name, _slot_take(fresh, 0), scale, evict=evict,
                         rank=rank)

    def unload(self, name: str):
        slot = self._slots.pop(name)
        self.bank = _slot_zero(self.bank, slot)
        self._lru.pop(name, None)

    # -- eviction pool ------------------------------------------------------
    def acquire(self, name: str) -> int:
        """Resolve an adapter to a bank slot, transparently
        re-materializing it.  Tiered under unified paging: a bank hit or a
        pool-resident gather costs no host traffic (``resident_hits``); a
        host-archived adapter is first swapped into the pool (counted +
        clock-charged by the engine) then gathered.  Raises ``KeyError``
        for an unknown adapter and ``RuntimeError`` when neither the bank
        nor the pool can take it this tick."""
        if name in self._slots:
            self._touch(name)
            self.resident_hits += 1
            return self._slots[name]
        if self.pager is not None and name in self._archive:
            flat, scale = self._archive[name]
            rk = self._ranks[name]
            if self.pager.adapter_resident(name):
                self.resident_hits += 1
            else:
                if not self.pager.adapter_admit(name, flat):
                    raise RuntimeError(
                        f"no pool capacity to swap in adapter {name!r}")
                self.swap_ins += 1
                self.swap_in_bytes += int(flat.nbytes)
            # gather from the pool — the production read path — rather
            # than trusting the archive we may just have written
            slot = self._materialize(
                name, self._unflatten(self.pager.adapter_gather(name), rk),
                scale)
            self.reloads += 1
            self._note_coresident()
            return slot
        if name in self._voided:
            v = self._voided[name]
            slot = self.load(name, jax.tree_util.tree_map(jnp.asarray,
                                                          v.adapter),
                             v.scale, evict=True,
                             rank=self._ranks.get(name))
            self.reloads += 1
            self.swap_ins += 1
            self.swap_in_bytes += self.adapter_nbytes(name)
            return slot
        raise KeyError(f"unknown adapter {name!r}")

    def retain(self, name: str):
        """Mark the adapter as backing in-flight work (eviction-exempt;
        under unified paging the pool blocks are pinned too)."""
        self._refs[name] = self._refs.get(name, 0) + 1
        if self.pager is not None:
            self.pager.adapter_pin(name)

    def release(self, name: str):
        n = self._refs.get(name, 0) - 1
        if n <= 0:
            self._refs.pop(name, None)
        else:
            self._refs[name] = n
        if self.pager is not None:
            self.pager.adapter_unpin(name)

    def pin(self, name: str):
        """Exempt from eviction permanently (training adapters: their slot
        identity is baked into optimizer state and trainer masks)."""
        if name not in self._pinned and self.pager is not None:
            self.pager.adapter_pin(name)
        self._pinned.add(name)

    def unpin(self, name: str):
        if name in self._pinned and self.pager is not None:
            self.pager.adapter_unpin(name)
        self._pinned.discard(name)

    def get_adapter(self, name: str):
        return _slot_take(self.bank, self._slots[name])

    def set_bank(self, bank):
        """Replace the bank wholesale (after an optimizer update)."""
        self.bank = bank

    def slot_mask(self, names: List[str]) -> jax.Array:
        m = np.zeros((self.lcfg.n_slots,), np.float32)
        for n in names:
            m[self._slots[n]] = 1.0
        return jnp.asarray(m)


class VirtualModel:
    """An isolated adapter view over a shared base model (one per tenant /
    fine-tuning job).  Compatible with any PEFT that keeps the base weights
    untouched (the Virtualized-Module contract)."""

    def __init__(self, name: str, base_params, store: AdapterStore,
                 mode: str = "infer"):
        if mode not in ("infer", "train"):
            raise ConfigInvariantError(f"unknown VirtualModel mode {mode!r}")
        self.name, self.base, self.store, self.mode = name, base_params, store, mode

    @property
    def slot(self) -> int:
        return self.store.slot_of(self.name)

    # -- migration (the paper's void / unvoid) ------------------------------
    def void(self) -> VoidedModel:
        adapter = jax.tree_util.tree_map(
            lambda x: np.asarray(x), self.store.get_adapter(self.name))
        return VoidedModel(name=self.name, cfg_name=self.store.cfg.name,
                           adapter=adapter,
                           scale=float(self.store.scale[self.slot]))

    @staticmethod
    def unvoid(voided: VoidedModel, base_params, store: AdapterStore,
               device=None, mode: str = "infer") -> "VirtualModel":
        if store.cfg.name != voided.cfg_name:
            raise MigrationInvariantError(
                f"config mismatch on migration: store={store.cfg.name!r} "
                f"voided={voided.cfg_name!r}")
        adapter = jax.tree_util.tree_map(
            lambda x: jax.device_put(jnp.asarray(x), device), voided.adapter)
        store.load(voided.name, adapter, voided.scale)
        return VirtualModel(voided.name, base_params, store, mode)


class MixedLoraModel:
    """The executable unit of the unified flow: shared base + resident
    adapter bank (paper Section 3.3)."""

    def __init__(self, cfg: ModelConfig, base_params, store: AdapterStore):
        self.cfg, self.base, self.store = cfg, base_params, store

    def virtual(self, name: str, mode: str = "infer") -> VirtualModel:
        return VirtualModel(name, self.base, self.store, mode)

    def forward(self, batch, cache=None, **kw):
        from repro.models.model import unified_forward
        return unified_forward(self.cfg, self.base, batch, cache,
                               loras=self.store.bank,
                               lora_scale=self.store.scale, **kw)
