"""Step builders: the jit-compiled units the runtime executes.

All steps share the SAME unified forward (paper Section 3.3); training steps
differentiate it w.r.t. the LoRA bank only.  Because the scalar loss depends
solely on fine-tune/eval rows, XLA prunes the backward of inference segments
— the analogue of the paper's FlashInfer-forward / Autograd-backward split
with zero code duplication.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.kernels.autotune import table_version
from repro.models.configs import ModelConfig
from repro.models.model import _paged_kernel_mode, unified_forward
from repro.models.stream import ModelOut, UnifiedBatch
from repro.training.optimizer import AdamWConfig, AdamWState, adamw_apply


def scalar_loss(out: ModelOut, batch: UnifiedBatch) -> jax.Array:
    """Algorithm 2: per-row mean CE, scaled by the row weight (which encodes
    1/accumulation-steps per trainer), summed — one shared backward."""
    loss = out.aux_loss
    if out.ft_loss_sum is not None:
        per_row = out.ft_loss_sum / jnp.maximum(out.ft_tok_count, 1.0)
        loss = loss + jnp.sum(per_row * batch.ft.weight)
    return loss


class StepOut(NamedTuple):
    out: ModelOut
    loss: jax.Array
    grads: Optional[Any]


# Step-function cache: ModelConfig is a frozen dataclass (hashable), so
# engines/benchmarks built around the same config share ONE jitted callable
# — and therefore one XLA compile cache — instead of recompiling per engine.
_STEP_CACHE: dict = {}


def _cached(kind, key, build):
    full = (kind, *key)
    if full not in _STEP_CACHE:
        _STEP_CACHE[full] = build()
    return _STEP_CACHE[full]


def make_forward_step(cfg: ModelConfig, *, remat: bool = False,
                      attn_chunk: int = 0, donate_cache: bool = False,
                      return_ft_logits: bool = False,
                      jit: bool = True, _jit_now: bool = False) -> Callable:
    """Inference-only unified step (serve/prefill/decode/eval)."""
    if jit:
        # the paged-attention backend flag AND the autotune table version
        # are read at trace time inside the forward — key the cache on both
        # so flag flips / tuning-table loads don't hit stale steps that
        # baked in the old kernel choice
        return _cached("fwd", (cfg, remat, attn_chunk, donate_cache,
                               return_ft_logits, _paged_kernel_mode(),
                               table_version()),
                       lambda: make_forward_step(
                           cfg, remat=remat, attn_chunk=attn_chunk,
                           donate_cache=donate_cache,
                           return_ft_logits=return_ft_logits, jit=False,
                           _jit_now=True))

    def step(base, bank, scale, batch: UnifiedBatch, cache):
        out = unified_forward(cfg, base, batch, cache, loras=bank,
                              lora_scale=scale, remat=remat,
                              attn_chunk=attn_chunk,
                              return_ft_logits=return_ft_logits)
        return out

    if not _jit_now:
        return step
    return jax.jit(step, donate_argnums=(4,) if donate_cache else ())


def make_grad_step(cfg: ModelConfig, *, remat: bool = False,
                   attn_chunk: int = 0) -> Callable:
    """Unified step with gradients w.r.t. the LoRA bank (no update) — used by
    the engine's accumulation loop."""
    key = ("grad", cfg, remat, attn_chunk, _paged_kernel_mode(),
           table_version())
    if key in _STEP_CACHE:
        return _STEP_CACHE[key]

    def _loss(bank, base, scale, batch, cache):
        out = unified_forward(cfg, base, batch, cache, loras=bank,
                              lora_scale=scale, remat=remat,
                              attn_chunk=attn_chunk)
        return scalar_loss(out, batch), out

    def step(base, bank, scale, batch: UnifiedBatch, cache) -> StepOut:
        (loss, out), grads = jax.value_and_grad(_loss, has_aux=True)(
            bank, base, scale, batch, cache)
        return StepOut(out=out, loss=loss, grads=grads)

    _STEP_CACHE[key] = jax.jit(step)
    return _STEP_CACHE[key]


def make_train_step(cfg: ModelConfig, opt: AdamWConfig, *,
                    remat: bool = False, attn_chunk: int = 0,
                    act_constraint=None, jit: bool = True) -> Callable:
    """Fused fine-tuning step: unified forward + backward + masked AdamW.
    This is what the dry-run lowers for the ``train_4k`` shape."""

    def _loss(bank, base, scale, batch, cache):
        out = unified_forward(cfg, base, batch, cache, loras=bank,
                              lora_scale=scale, remat=remat,
                              attn_chunk=attn_chunk,
                              act_constraint=act_constraint)
        return scalar_loss(out, batch), out

    def step(base, bank, scale, opt_state: AdamWState, batch: UnifiedBatch,
             slot_mask, cache=None):
        (loss, out), grads = jax.value_and_grad(_loss, has_aux=True)(
            bank, base, scale, batch, cache)
        new_bank, new_state = adamw_apply(opt, grads, opt_state, bank,
                                          slot_mask)
        return loss, new_bank, new_state, out.aux_loss

    return jax.jit(step) if jit else step


def make_apply_step(opt: AdamWConfig) -> Callable:
    """Masked optimizer apply for accumulated gradients (engine path)."""

    @jax.jit
    def apply(grads, opt_state: AdamWState, bank, slot_mask):
        return adamw_apply(opt, grads, opt_state, bank, slot_mask)

    return apply
