"""Loquetier core: multi-LoRA adapter algebra, the Virtualized Module, and the
unified fine-tuning/inference computation flow (the paper's contribution)."""
