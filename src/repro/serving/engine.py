"""UnifiedEngine — the Loquetier runtime: one loop, four request kinds.

Every tick assembles ONE unified batch (fine-tune + eval + prefill + decode),
executes ONE jit'd step (with a shared backward pass when fine-tune rows are
present), then scatters results back: sampled tokens to inference requests,
per-row losses to their trainers, accumulated gradients to the masked
optimizer on each trainer's accumulation boundary.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import flow
from repro.core.unified import make_apply_step, make_forward_step, make_grad_step
from repro.core.virtualization import MixedLoraModel
from repro.models.stream import UnifiedBatch
from repro.serving.clock import CostModel, VirtualClock, WallClock
from repro.serving.kvcache import (CacheManager, OutOfBlocksError,
                                   PagedCacheManager, request_chain_keys,
                                   swap_beats_recompute)
from repro.serving.request import Request, State
from repro.serving.scheduler import Scheduler, SchedulerConfig
from repro.serving.slo import Metrics, SLOConfig, spread_token_times
from repro.spec import AdaptiveK, Drafter, SpecConfig, accept_greedy, \
    make_drafter
from repro.training.optimizer import (AdamWConfig, adamw_init, tree_add,
                                      tree_mask_slots, tree_zeros_like)
from repro.training.trainer import MixedLoraTrainer


@dataclasses.dataclass
class EngineConfig:
    capacity: int = 8                 # max concurrent decode requests
    pf_capacity: int = 4              # prefill scratch rows
    s_max: int = 256                  # cache sequence capacity
    scheduler: SchedulerConfig = dataclasses.field(default_factory=SchedulerConfig)
    slo: SLOConfig = dataclasses.field(default_factory=SLOConfig)
    opt: AdamWConfig = dataclasses.field(default_factory=AdamWConfig)
    flow: flow.FlowConfig = dataclasses.field(default_factory=flow.FlowConfig)
    attn_chunk: int = 0
    virtual_time: bool = False        # deterministic trace replay
    paged: bool = True                # block-table KV layout (falls back to
    #                                   dense rows for sliding-window models)
    block_size: int = 32              # KV tokens per block (paged layout)
    n_blocks: int = 0                 # pool size; 0 = match dense capacity
    over_admit: float = 1.0           # reservation lending factor (>= 1):
    #                                   the admission gate charges only a
    #                                   1/over_admit slice of outstanding
    #                                   reservation debt and lends the rest
    #                                   to new admissions; growth failures
    #                                   are repaid by recompute preemption
    #                                   (1.0 = conservative gate, no lending)
    spec: Optional[SpecConfig] = None  # speculative decoding (paged,
    #                                   attention-only models; exact greedy)
    prefill_chunk: int = 0            # per-tick prefill-token budget: long
    #                                   prompts prefill as a sequence of
    #                                   bounded chunks that co-batch with
    #                                   decode/ft rows (0 = unchunked)
    hash_dedup: bool = True           # content-hash block dedup: every full
    #                                   KV block is indexed by a chained
    #                                   sha1(adapter, parent, tokens) key,
    #                                   admission adopts resident runs (no
    #                                   recompute, no caller-side id), and
    #                                   the scheduler prefers admitting
    #                                   requests whose prefixes are resident
    #                                   (False = escape hatch: no reuse)
    adapter_paging: bool = False      # unified KV + adapter paging: adapter
    #                                   weights page through the SAME block
    #                                   pool as the KV cache (S-LoRA
    #                                   unified memory — HBM flows between
    #                                   cache capacity and adapter
    #                                   residency instead of being
    #                                   statically partitioned), the
    #                                   scheduler becomes adapter-
    #                                   residency-aware, and swap-ins are
    #                                   charged to the virtual clock.
    #                                   Default OFF: the static bank
    #                                   partition is the baseline
    cost: Optional[CostModel] = None  # virtual-clock cost model override
    kv_host_blocks: int = 0           # tiered KV memory: host-side block
    #                                   pool budget, in device blocks' worth
    #                                   of host RAM.  > 0 enables swap-to-
    #                                   host preemption (victims' blocks
    #                                   D2H, restored H2D at re-admission
    #                                   when the modeled transfer beats
    #                                   suffix recompute) and demotion of
    #                                   shed index blocks to the host tier.
    #                                   0 = recompute-only preemption
    #                                   (byte-identical baseline)
    kv_host_quant: bool = False       # int8-quantize host-tier residency
    #                                   (~2x host capacity at equal budget).
    #                                   EXACTNESS-EXEMPT: dequantized KV is
    #                                   not bit-identical, so outputs may
    #                                   differ from the recompute path —
    #                                   hence an explicit opt-in


class UnifiedEngine:
    def __init__(self, model: MixedLoraModel, ecfg: Optional[EngineConfig] = None):
        self.model = model
        self.ecfg = ecfg or EngineConfig()
        self.cfg = model.cfg
        e = self.ecfg
        self.paged = e.paged and self.cfg.sliding_window == 0
        self._cost = e.cost or CostModel()
        if self.paged:
            self.cachemgr = PagedCacheManager(
                self.cfg, e.capacity, e.pf_capacity, e.s_max,
                block_size=e.block_size, n_blocks=e.n_blocks,
                over_admit=e.over_admit, hash_dedup=e.hash_dedup,
                host_blocks=e.kv_host_blocks, host_quant=e.kv_host_quant,
                cost=self._cost)
        else:
            self.cachemgr = CacheManager(self.cfg, e.capacity, e.pf_capacity,
                                         e.s_max)
        # unified adapter paging: adapter weights share the KV block pool
        self.adapter_paging = self.paged and e.adapter_paging
        if self.adapter_paging:
            model.store.attach_pager(self.cachemgr)
        # swap counters are store-lifetime; baseline them so pre-serving
        # loads are not billed to (or reported for) this engine
        st = model.store
        self._swaps_base = (st.swap_ins, st.swap_in_bytes, st.resident_hits)
        self._swaps_seen = self._swaps_base[:2]
        self.sched = Scheduler(e.scheduler, e.capacity)
        self.clock = VirtualClock(e.cost) if e.virtual_time else WallClock()
        self.metrics = Metrics()
        # suffix-only prefill reads shared-prefix K/V through the block
        # tables instead of recomputing it; chunked prefill additionally
        # needs per-chunk resumability.  Both require a positional paged
        # cache — mamba SSM state cannot resume mid-prompt from blocks.
        self.suffix_prefill = self.paged and "mamba" not in self.cfg.pattern
        self.chunk_budget = (e.prefill_chunk
                             if e.prefill_chunk > 0 and self.suffix_prefill
                             else 0)
        self.prefilling: Dict[int, Request] = {}  # slot -> partial prefill
        self.hash_dedup = self.paged and e.hash_dedup
        # tiered KV memory: swap-outs are only worth taking when restore
        # can actually skip the restored span — which is the suffix-prefill
        # cached_len path.  Models that must recompute the full prompt
        # (hybrid/mamba) keep recompute-only preemption; demote/rehydrate
        # of index blocks rides the normal adoption path and stays on.
        self.kv_tiering = (self.paged and e.kv_host_blocks > 0
                           and self.suffix_prefill)
        self._kv_seen = (0, 0)                 # (d2h, h2d) bytes charged
        # every swap-vs-recompute decision, in order — bench_tiers replays
        # the rule analytically against this log and gates on an exact
        # match (the "decision hit rate")
        self.swap_decisions: List[dict] = []

        self.forward_step = make_forward_step(self.cfg, attn_chunk=e.attn_chunk)
        self.grad_step = make_grad_step(self.cfg, attn_chunk=e.attn_chunk)
        self.apply_step = make_apply_step(e.opt)
        self.opt_state = adamw_init(model.store.bank,
                                    model.store.lcfg.n_slots)
        self.grad_accum = tree_zeros_like(model.store.bank)

        self.future: List[Request] = []       # arrival-sorted
        self.waiting: List[Request] = []
        self.active: Dict[int, Request] = {}  # decode slot -> request
        self.finished: List[Request] = []
        self.trainers: Dict[str, MixedLoraTrainer] = {}
        self._last_tokens = np.zeros((e.capacity,), np.int64)
        # speculative decoding: needs rollback-able K/V (paged blocks) and a
        # positional cache — mamba SSM state cannot un-consume drafts
        self.spec = e.spec if (e.spec is not None and e.spec.enabled
                               and self.paged
                               and "mamba" not in self.cfg.pattern) else None
        self._spec: Dict[int, Tuple[Optional[Drafter], AdaptiveK]] = {}

    @property
    def spec_headroom(self) -> int:
        """Transient +k draft tokens each resident request may hold
        mid-verify — charged to its block budget at admission."""
        return self.spec.k_max if self.spec else 0

    def _headroom_for(self, r: Request) -> int:
        """Per-request draft headroom: when the +k charge would push the
        request past the whole pool (it fits its plain projection but not
        the inflated one), admit it with NO reserved draft room instead of
        stranding it un-admittable — its drafts then ride the best-effort
        overshoot path in ``grow`` and are trimmed when the pool is dry."""
        h = self.spec_headroom
        if h and self.cachemgr.projected_blocks(
                r.prompt_len, r.remaining_new + h) \
                > self.cachemgr.total_blocks:
            return 0
        return h

    # ------------------------------------------------------------------
    def submit(self, req: Request):
        if req.arrival > self.clock.now():
            self.future.append(req)
            self.future.sort(key=lambda r: r.arrival)
        else:
            self.waiting.append(req)

    def add_trainer(self, tr: MixedLoraTrainer):
        self.trainers[tr.name] = tr
        # training adapters must never be evicted: their slot identity is
        # baked into the trainer mask and optimizer state (pinning a name
        # before its load is fine — the pin is checked against residents)
        # reprolint: ownership-transfer — pin lives for the trainer's life
        self.model.store.pin(tr.name)

    def trainers_pending(self) -> bool:
        return any(t.pending() for t in self.trainers.values())

    def _keys_of(self, r: Request) -> Optional[List[str]]:
        """The request's block-key chain for the dedup index, or None when
        the request must not share — modality embeddings make deeper-layer
        K/V depend on aux_embed, which the (adapter, tokens) content
        identity cannot capture.  The memoization itself lives in
        ``kvcache.request_chain_keys`` so the fleet router and engine
        admission hash each prompt ONCE between them, not once per layer
        that asks."""
        if not self.hash_dedup or r.aux_embed is not None:
            return None
        return request_chain_keys(r, self.cachemgr.block_size)

    def _resident_tokens(self, r: Request) -> int:
        """Prompt tokens the dedup index would serve without recompute."""
        keys = self._keys_of(r)
        if keys is None:
            return 0
        return self.cachemgr.probe(r.prompt, r.adapter, keys=keys)

    def _pull_arrivals(self):
        now = self.clock.now()
        while self.future and self.future[0].arrival <= now:
            self.waiting.append(self.future.pop(0))

    # ------------------------------------------------------------------
    def tick(self) -> bool:
        """One scheduling + execution round; returns False when idle."""
        self._pull_arrivals()
        e = self.ecfg
        # prefill rows this tick: continuing partial-prefill chunks first
        # (they already hold slots), then fresh admissions.  ``chunks``
        # parallels ``pf_reqs``: (request, computed tokens, final chunk?).
        pf_reqs: List[flow.PFReq] = []
        chunks: List[Tuple[Request, int, bool]] = []
        budget_left = self.chunk_budget if self.chunk_budget else None
        if self.paged:
            for slot, r in list(self.prefilling.items()):
                if len(pf_reqs) >= e.pf_capacity:
                    break
                if budget_left is not None and budget_left <= 0:
                    break
                rem = r.prompt_len - r.prefilled
                take = rem if budget_left is None else min(rem, budget_left)
                if budget_left is not None:
                    budget_left -= take
                pf_reqs.append(flow.PFReq(
                    tokens=r.prompt[r.prefilled:r.prefilled + take],
                    rid=r.rid,
                    slot=(self.model.store.slot_of(r.adapter)
                          if r.adapter else -1),
                    aux_embed=r.aux_embed,
                    block_table=self.cachemgr.table_of(slot),
                    cached_len=r.prefilled))
                chunks.append((r, take, r.prefilled + take >= r.prompt_len))
        if self.paged:
            # a request is unservable only when its FRESH block need —
            # projected blocks minus index-resident blocks it would adopt —
            # can never fit the pool.  Gating on raw projected blocks
            # wrongly FAILED long prompts that fit suffix-only over a
            # shared prefix.  Only ref >= 2 shared blocks discount
            # (fresh_need's held_elsewhere): an index-only (ref == 1) block
            # has no concurrent payer, so this request's own footprint
            # would include it — adopting it does not shrink the total the
            # pool must hold for this request, and if that total exceeds
            # the pool the request truly can never fit (admitting it would
            # just wedge in the waiting queue forever instead).
            for r in list(self.waiting):
                # cheap precheck: fresh_need <= projected_blocks always, so
                # the prefix-token compare can only change the verdict when
                # the raw projection already overflows the pool — a deep
                # backlog must not pay an O(prefix) sweep per tick
                if self.cachemgr.projected_blocks(
                        r.prompt_len, r.remaining_new) \
                        <= self.cachemgr.total_blocks:
                    continue
                need = self.cachemgr.fresh_need(
                    r.prompt_len, r.remaining_new, r.prompt, r.adapter,
                    keys=self._keys_of(r),
                    shareable=r.aux_embed is None)
                if need > self.cachemgr.total_blocks:
                    r.state = State.FAILED
                    r.t_finish = self.clock.now()
                    self._drop_retain(r)
                    self._drop_swap(r)
                    self.waiting.remove(r)
                    self.finished.append(r)
            suffix_fn = None
            if self.suffix_prefill:
                suffix_fn = lambda r: r.prompt_len - self._resident_tokens(r)
            decision = self.sched.decide(
                self.waiting, len(self.active) + len(self.prefilling),
                self.cachemgr.n_free,
                e.pf_capacity, self.trainers_pending(),
                # index-held blocks are sheddable inside try_admit, so the
                # gate must count them as available
                free_blocks=(self.cachemgr.free_blocks
                             + self.cachemgr.reclaimable_blocks),
                total_blocks=self.cachemgr.total_blocks,
                block_size=self.cachemgr.block_size, s_max=e.s_max,
                need_fn=lambda r: self.cachemgr.fresh_need(
                    r.prompt_len, r.remaining_new, r.prompt, r.adapter,
                    headroom=self._headroom_for(r), keys=self._keys_of(r),
                    shareable=r.aux_embed is None),
                spec_headroom=self.spec_headroom,
                pf_rows_used=len(pf_reqs), pf_token_budget=budget_left,
                suffix_fn=suffix_fn, chunked=bool(self.chunk_budget),
                # actually-lent debt fraction: the preemption precursor
                # that makes fine-tuning yield before inference is evicted
                lent_frac=(self.cachemgr.lent_blocks
                           / max(self.cachemgr.reserved_debt, 1)),
                # prefix-aware admission: prefer requests whose prompt heads
                # are index-resident (their prefill is mostly free), bounded
                # by the fairness ramp so cold requests cannot starve
                probe_fn=(self._resident_tokens if self.hash_dedup
                          else None),
                # adapter-residency-aware admission: only under unified
                # paging — the static-bank baseline keeps the pre-paging
                # ordering byte-for-byte
                adapter_fn=((lambda r: self.model.store.is_resident(
                    r.adapter)) if self.adapter_paging else None),
                now=self.clock.now())
        else:
            decision = self.sched.decide(self.waiting, len(self.active),
                                         self.cachemgr.n_free, e.pf_capacity,
                                         self.trainers_pending())

        # fine-tuning rows (round-robin over trainers)
        ft_rows: List[flow.FTRow] = []
        budget = decision.ft_rows
        for tr in self.trainers.values():
            if budget <= 0:
                break
            got = tr.next_rows(budget)
            ft_rows.extend(got)
            budget -= len(got)

        # prefill admissions.  Adapters are resolved ONCE per tick per name
        # (memoized below): the first same-adapter admit pays the swap-in,
        # every co-scheduled follower rides it free — the amortization the
        # scheduler's greedy affinity pass set up.  Each resolved adapter is
        # held (retain) for the rest of the loop so a later resolve cannot
        # evict it out from under an earlier admit; the temporary holds are
        # dropped in the ``finally`` whether or not admission succeeded.
        resolved: Dict[str, int] = {}
        unknown: set = set()
        deferred: set = set()

        # reprolint: ownership-transfer — holds land in ``resolved``; the
        # finally around _admit_loop releases every one exactly once
        def _resolve(name: str):
            if name in resolved or name in unknown or name in deferred:
                return
            try:
                resolved[name] = self.model.store.acquire(name)
                self.model.store.retain(name)
            except KeyError:
                unknown.add(name)
            except RuntimeError:
                deferred.add(name)     # bank/pool saturated this tick

        try:
            self._admit_loop(decision, pf_reqs, chunks, budget_left,
                             resolved, unknown, deferred, _resolve)
        finally:
            for name in resolved:
                self.model.store.release(name)

        # decode / verify bucket (static: full table when any request is
        # active; chunk width 1 + k_max whenever speculation is on, so the
        # bucket shape compiles once)
        use_dec = bool(self.active)
        Sd = 1 + (self.spec.k_max if (self.spec and use_dec) else 0)
        drafts: Dict[int, np.ndarray] = {}
        dec_lens = None
        plans: List[Tuple[int, Request, int, np.ndarray]] = []
        if use_dec:
            # phase 1 — drafts + block growth, with recompute preemption as
            # the growth-failure backstop.  Slots carrying a prefill row
            # this tick are pinned: their PFReq already snapshot a block
            # table, so freeing them would hand the model dangling blocks.
            pinned = frozenset(c[0].dec_slot for c in chunks)
            for slot, r in list(self.active.items()):
                if slot not in self.active:
                    continue              # preempted as an earlier victim
                L = int(self.cachemgr.lens[slot])
                draft = np.zeros((0,), np.int64)
                if Sd > 1:
                    drafter, ctl = self._spec[slot]
                    # clamp drafts to what the request can still emit and
                    # to the context limit (writes land at L .. L + k)
                    k = min(ctl.k, r.max_new_tokens - len(r.output) - 1,
                            e.s_max - 1 - L)
                    if k > 0 and drafter is not None:
                        # prompt already embeds output[:rolled] after a
                        # preemption — append only the unrolled tail, or
                        # the history duplicates tokens and the suffix
                        # drafter's position index drifts
                        draft = np.asarray(drafter.draft(
                            np.concatenate([np.asarray(r.prompt, np.int64),
                                            np.asarray(r.output[r.rolled:],
                                                       np.int64)]),
                            k), np.int64)
                if self.paged:
                    # grow the block table over the chunk's positions and
                    # copy-on-write any shared block in the write range; a
                    # dry pool trims the transient draft tail, and — under
                    # over-admission — preempts when even the committed
                    # token at L no longer fits
                    writable = self._grow_or_preempt(slot, r, L,
                                                     1 + len(draft), pinned)
                    if slot not in self.active:
                        continue          # became its own victim
                    draft = draft[:max(writable - 1, 0)]
                plans.append((slot, r, L, draft))
            # a slot planned early may have been preempted as a victim of a
            # later grower — only survivors get a decode row
            plans = [p for p in plans if p[0] in self.active]
            use_dec = bool(plans)
        planned = frozenset(p[0] for p in plans)
        if use_dec:
            # phase 2 — assemble the bucket from surviving rows
            dec_tokens = (np.zeros((e.capacity, Sd), np.int64) if Sd > 1
                          else np.zeros((e.capacity,), np.int64))
            dec_pos = np.zeros((e.capacity,), np.int64)
            dec_slots = np.full((e.capacity,), -1, np.int64)
            if Sd > 1:
                dec_lens = np.zeros((e.capacity,), np.int64)
            for slot, r, L, draft in plans:
                if Sd > 1:
                    dec_tokens[slot, 0] = self._last_tokens[slot]
                    if len(draft):
                        dec_tokens[slot, 1:1 + len(draft)] = draft
                    dec_lens[slot] = 1 + len(draft)
                    drafts[slot] = draft
                else:
                    dec_tokens[slot] = self._last_tokens[slot]
                dec_pos[slot] = L
                dec_slots[slot] = (self.model.store.slot_of(r.adapter)
                                   if r.adapter else -1)
            dec_tables = (self.cachemgr.dec_tables(self.active)
                          if self.paged else None)
        else:
            dec_tokens = dec_pos = dec_slots = np.zeros((0,), np.int64)
            dec_tables = None

        if not ft_rows and not pf_reqs and not use_dec:
            # idle: jump to next arrival if replaying a trace
            if self.future:
                self.clock.advance_to(self.future[0].arrival)
                return True
            return False

        batch = flow.assemble(ft_rows, pf_reqs, dec_tokens, dec_pos,
                              dec_slots, e.flow, dec_tables=dec_tables,
                              dec_lens=dec_lens)
        # chunked-prefill SLO invariant, checked on the ASSEMBLED batch: a
        # step that runs prefill while requests are mid-decode must carry
        # their decode bucket — any future path that builds a prefill step
        # without one trips this (gated to 0 in bench_prefix / CI)
        if pf_reqs and self.active and batch.dec is None:
            self.metrics.starved_ticks += 1
        cache = self.cachemgr.step_cache() if (pf_reqs or use_dec) else None

        store = self.model.store
        if ft_rows:
            res = self.grad_step(self.model.base, store.bank, store.scale,
                                 batch, cache)
            out, grads = res.out, res.grads
        else:
            out = self.forward_step(self.model.base, store.bank, store.scale,
                                    batch, cache)
            grads = None
        # the ONE deliberate step barrier: the clock charges a finished
        # step, and the scatter below needs its logits anyway
        jax.block_until_ready(  # reprolint: sync-point
            out.dec_logits if out.dec_logits is not None
            else (out.pf_logits if out.pf_logits is not None
                  else out.ft_loss_sum))

        # ---- time accounting (suffix tokens only: skipped prefix spans
        # cost nothing, which is the whole point of the reuse) ----
        pf_tok = int(sum(take for _, take, _ in chunks))
        ft_tok = int(sum(len(r.tokens) for r in ft_rows))
        dec_extra = int(sum(len(d) for d in drafts.values()))
        if isinstance(self.clock, VirtualClock):
            # adapter swap-ins since the last charge (paged pool admits AND
            # static-bank voided reloads both count — equal H2D price)
            swaps = store.swap_ins - self._swaps_seen[0]
            swap_bytes = store.swap_in_bytes - self._swaps_seen[1]
            self._swaps_seen = (store.swap_ins, store.swap_in_bytes)
            # KV host-tier traffic since the last charge (same cumulative-
            # counter delta pattern as adapter swaps; bytes moved on a tick
            # that returned early are picked up by the next charging tick)
            kvd = kvh = 0
            if self.paged:
                kvd = self.cachemgr.kv_d2h_bytes - self._kv_seen[0]
                kvh = self.cachemgr.kv_h2d_bytes - self._kv_seen[1]
                self._kv_seen = (self.cachemgr.kv_d2h_bytes,
                                 self.cachemgr.kv_h2d_bytes)
            cost = self.clock.step_cost(pf_tok, len(self.active), ft_tok,
                                        dec_extra_tokens=dec_extra,
                                        adapter_swaps=swaps,
                                        adapter_swap_bytes=swap_bytes,
                                        kv_d2h_bytes=kvd,
                                        kv_h2d_bytes=kvh)
            self.clock.charge(cost)
            self.metrics.busy_time += cost
        now = self.clock.now()

        # ---- scatter results back ----
        if out.cache is not None:
            self.cachemgr.update(out.cache)
        if pf_reqs:
            # scheduling reads the sampled token: a required sync boundary
            pf_logits = np.asarray(out.pf_logits)  # reprolint: sync-point
            assignments, lengths = [], []
            finals: List[Request] = []
            for i, (r, take, final) in enumerate(chunks):
                r.prefilled += take
                if r.recount_pending:
                    # post-preemption recompute, charged per chunk actually
                    # computed (never the whole suffix up front — a second
                    # preemption mid-prefill would double-count the rest)
                    self.metrics.preempted_tokens_recomputed += take
                    if final:
                        r.recount_pending = False
                assignments.append((i, r.dec_slot))
                lengths.append(r.prefilled)
                if final:
                    tok = int(pf_logits[i].argmax())
                    r.output.append(tok)
                    if r.t_first_token is None:
                        # a preempted request keeps its original first-token
                        # time: the re-prefill is recompute, not a new TTFT
                        r.t_first_token = now
                    r.token_times.append(now)
                    r.state = State.DECODE
                    self._last_tokens[r.dec_slot] = tok
                    self.active[r.dec_slot] = r
                    self.prefilling.pop(r.dec_slot, None)
                    finals.append(r)
                else:
                    # partial prefill: K/V through ``prefilled`` is in the
                    # blocks; the next chunk attends to it via cached_len
                    self.prefilling[r.dec_slot] = r
            # the model wrote prefill rows at [Bd, Bd+Bp): tell the manager
            # where they start (state rows only under the paged layout — the
            # K/V itself went straight into the request's blocks)
            # commit is also the dedup publication point: every full prompt
            # block the chunk filled enters the hash index (no caller-side
            # registration — content is the identity)
            self.cachemgr.commit_prefill(assignments, lengths,
                                         src_base=e.capacity if use_dec
                                         else 0)
            self.metrics.prefill_tokens += pf_tok
            self.metrics.max_pf_tokens_step = max(
                self.metrics.max_pf_tokens_step, pf_tok)
            for r in finals:
                self._maybe_finish(r, now)
        if use_dec:
            # argmax/accept decisions drive the next tick's inputs
            dec_logits = np.asarray(out.dec_logits)  # reprolint: sync-point
            for slot, r in list(self.active.items()):
                if r.state is not State.DECODE or slot not in planned:
                    continue    # just (re-)prefilled this tick: no dec row
                if Sd > 1:
                    self._scatter_verify(slot, r, dec_logits[slot],
                                         drafts.get(slot), now)
                else:
                    tok = int(dec_logits[slot].argmax())
                    r.output.append(tok)
                    r.token_times.append(now)
                    # position L holds the K/V of this step's INPUT token —
                    # committing it may fill (and publish) a block
                    self.cachemgr.commit_tokens(
                        slot, [int(self._last_tokens[slot])])
                    self._last_tokens[slot] = tok
                    self.metrics.decode_tokens += 1
                    self._maybe_finish(r, now)

        if ft_rows:
            # per-trainer loss bookkeeping happens on host once per step
            losses = np.asarray(out.ft_loss_sum)  # reprolint: sync-point
            counts = np.asarray(out.ft_tok_count)  # reprolint: sync-point
            per_row = losses / np.maximum(counts, 1.0)
            self.grad_accum = tree_add(self.grad_accum, grads)
            by_trainer: Dict[str, List] = {}
            train_tok = eval_tok = 0.0
            for i, row in enumerate(ft_rows):
                by_trainer.setdefault(row.trainer, []).append(
                    (row, float(per_row[i]), float(counts[i])))
                if row.is_eval:
                    eval_tok += float(counts[i])
                else:
                    train_tok += float(counts[i])
            for name, items in by_trainer.items():
                tr = self.trainers[name]
                rows = [it[0] for it in items]
                ls = [it[1] for it in items]
                cs = [it[2] for it in items]
                if tr.record(rows, ls, cs):
                    self._apply_trainer(tr)
            self.metrics.finetune_tokens += int(train_tok)
            self.metrics.eval_tokens += int(eval_tok)

        self.metrics.steps += 1
        self.metrics.elapsed = self.clock.now()
        self.metrics.probe_admissions += decision.probe_admissions
        # adapter residency accounting (store-lifetime counters, baselined
        # at engine construction so pre-serving loads are not reported)
        self.metrics.adapter_swap_ins = store.swap_ins - self._swaps_base[0]
        self.metrics.adapter_swap_in_bytes = (store.swap_in_bytes
                                              - self._swaps_base[1])
        self.metrics.adapter_resident_hits = (store.resident_hits
                                              - self._swaps_base[2])
        self.metrics.adapter_peak_coresident = store.peak_coresident
        if self.paged:
            self.metrics.lent_blocks_peak = self.cachemgr.lent_blocks_peak
            self.metrics.hash_hits = self.cachemgr.hash_hits
            self.metrics.hash_blocks_resident = \
                self.cachemgr.hash_blocks_resident
            self.metrics.remote_fetch_blocks = self.cachemgr.remote_imports
            if self.cachemgr.host_pool is not None:
                m, hp = self.cachemgr, self.cachemgr.host_pool
                self.metrics.kv_swap_outs = m.kv_swap_outs
                self.metrics.kv_swap_out_bytes = m.kv_swap_out_bytes
                self.metrics.kv_restores = m.kv_restores
                self.metrics.kv_restore_bytes = m.kv_restore_bytes
                self.metrics.kv_demotions = m.kv_demotions
                self.metrics.kv_rehydrated_blocks = m.kv_rehydrations
                self.metrics.host_bytes_used = hp.used_bytes
                self.metrics.host_bytes_peak = hp.peak_used_bytes
            if self.adapter_paging:
                self.metrics.adapter_blocks_resident = \
                    self.cachemgr.adapter_blocks_resident
        return True

    # ------------------------------------------------------- admission body
    def _admit_loop(self, decision, pf_reqs: List[flow.PFReq],
                    chunks: List[Tuple[Request, int, bool]],
                    budget_left: Optional[int],
                    resolved: Dict[str, int], unknown: set, deferred: set,
                    resolve):
        """Admission body of ``tick``, split out so the per-tick adapter
        holds can wrap it in try/finally.  Appends to ``pf_reqs``/``chunks``
        in place; ``budget_left`` is the remaining chunked-prefill token
        budget this tick (None = unchunked)."""
        e = self.ecfg
        for r in decision.admit:
            if len(pf_reqs) >= e.pf_capacity:
                break
            # resolve the adapter BEFORE reserving cache resources: acquire
            # can fail (unknown adapter, or bank/pool saturated) and must
            # not leak a reservation or abort the tick.  A saturated
            # adapter defers only ITS requests — co-admitted requests on
            # other (or no) adapters still run this tick
            if r.adapter:
                resolve(r.adapter)
                if r.adapter in unknown:
                    r.state = State.FAILED
                    r.t_finish = self.clock.now()
                    self._drop_retain(r)
                    self._drop_swap(r)
                    self.waiting.remove(r)
                    self.finished.append(r)
                    continue
                if r.adapter not in resolved:
                    continue       # saturated: stays waiting, retries later
                aslot = resolved[r.adapter]
            else:
                aslot = -1
            reused = 0
            if self.paged:
                adm = self.cachemgr.try_admit(r.prompt, r.remaining_new,
                                              r.adapter,
                                              headroom=self._headroom_for(r),
                                              shareable=r.aux_embed is None,
                                              keys=self._keys_of(r),
                                              priority=r.priority_class)
                slot = adm[0] if adm is not None else None
                reused = adm[1] if adm is not None else 0
            else:
                slot = self.cachemgr.alloc()
            if slot is None:
                break
            if r.swap_sid is not None:
                # re-admission of a swapped-out victim: the H2D restore
                # covers its rolled context minus one live token, so the
                # suffix prefill below recomputes exactly that token —
                # byte-identical to the recompute path, without the
                # recompute
                restored = self.cachemgr.restore_swap(slot, r.swap_sid)
                r.swap_sid = None
                if restored > reused:
                    self.metrics.kv_restored_tokens += restored - reused
                    reused = restored
            if r.adapter and not r.adapter_retained:
                # a preempted request kept its retain across the requeue
                # (anti-thrash) — only first admission takes a new hold
                # reprolint: ownership-transfer — the hold moves onto the
                # request (adapter_retained); _drop_retain releases it at
                # finish/failure, never at preemption
                self.model.store.retain(r.adapter)
                r.adapter_retained = True
            r.dec_slot = slot
            r.state = State.PREFILL
            if self.spec:
                kind = ("suffix" if (self.spec.drafter == "suffix"
                                     and r.draft_suffix is not None)
                        else "ngram")
                self._spec[slot] = (
                    make_drafter(kind, ngram_n=self.spec.ngram_n,
                                 suffix=r.draft_suffix),
                    AdaptiveK(self.spec))
            self.waiting.remove(r)
            if self.suffix_prefill:
                # suffix-only prefill: shared-prefix K/V is read through the
                # full block table; this chunk's writes land at positions
                # >= cached_len, so they can never touch a shared block.
                # A COLD start (no reused prefix) keeps the cheaper prompt-
                # local attention path (cached_len=None) — there is nothing
                # in the pool for its first chunk to read back.
                r.prefilled = reused
                suffix = r.prompt_len - r.prefilled
                take = (suffix if budget_left is None
                        else min(suffix, budget_left))
                self.metrics.reused_prefix_tokens += reused
                if take <= 0:
                    # an earlier try_admit this tick shed the prefix this
                    # request's suffix was priced against, draining the
                    # budget: park it as a partial prefill (its slot and
                    # blocks are held) instead of assembling a dead row
                    self.prefilling[slot] = r
                    continue
                if budget_left is not None:
                    budget_left -= take
                pf_reqs.append(flow.PFReq(
                    tokens=r.prompt[r.prefilled:r.prefilled + take],
                    rid=r.rid, slot=aslot, aux_embed=r.aux_embed,
                    block_table=(self.cachemgr.table_of(slot) if reused
                                 else self.cachemgr.write_table_of(slot)),
                    cached_len=r.prefilled if reused else None))
                chunks.append((r, take, r.prefilled + take >= r.prompt_len))
            else:
                # full-prompt recompute (dense layout, or hybrid models
                # whose SSM state must see every prompt token): prefill
                # writes through write_table_of — shared prefix entries are
                # nulled so prefill never rewrites blocks it doesn't own
                r.prefilled = 0
                pf_reqs.append(flow.PFReq(
                    tokens=r.prompt, rid=r.rid, slot=aslot,
                    aux_embed=r.aux_embed,
                    block_table=(self.cachemgr.write_table_of(slot)
                                 if self.paged else None)))
                chunks.append((r, r.prompt_len, True))

    # ---------------------------------------------- preemption (over-admit)
    def _grow_or_preempt(self, slot: int, r: Request, L: int, n: int,
                         pinned: frozenset) -> int:
        """``prepare_write`` with the over-admission backstop.  A short grow
        (or a copy-on-write that finds the pool dry) that cannot cover even
        the committed token at ``L`` means a lent-out reservation came due:
        preempt the lowest-priority resident and retry.  ``pinned`` slots
        hold prefill rows already assembled this tick and must survive; the
        requesting slot competes on priority like everyone else and preempts
        itself when it IS the lowest."""
        while True:
            try:
                writable = self.cachemgr.prepare_write(slot, L, n)
            except OutOfBlocksError:
                writable = 0
            if writable >= 1:
                return writable
            victim = self._pick_victim(exclude=pinned)
            if victim is None or victim == slot:
                self._preempt(slot)
                return 0
            self._preempt(victim)

    def _pick_victim(self, exclude: frozenset) -> Optional[int]:
        """Lowest-priority resident.  Priority CLASS dominates — batch
        residents are evicted before standard, interactive last ("batch
        lends first, interactive preempts last") — then, within a class:
        latest arrival, tie-broken toward the lowest speculative acceptance
        rate (the row burning the most verify compute per emitted token),
        then the latest rid for determinism.  All-standard traffic (the
        default) makes the class rank a constant and reproduces the
        pre-class victim order exactly."""
        cands = [(s, r) for s, r in list(self.active.items())
                 + list(self.prefilling.items()) if s not in exclude]
        if not cands:
            return None

        def badness(item):
            s, r = item
            ctl = self._spec.get(s)
            acc = ctl[1].rate if ctl is not None else 0.0
            return (r.class_rank, r.arrival, -acc, r.rid)

        return max(cands, key=badness)[0]

    def _preempt(self, slot: int):
        """Recompute preemption (vLLM-style, cheap here because suffix-only
        prefill re-adopts any of the victim's published blocks that stay
        index-resident — its own full blocks are its resume capital): roll
        the victim's emitted tokens into its prompt, free its blocks
        (decref-only through shared/index-held blocks), and requeue it at
        the HEAD of ``waiting``.  ``arrival`` and ``t_first_token`` are kept
        — preemption shows up as decode latency, never as a reset — and
        re-prefill of prompt+output re-derives the exact greedy state, so
        outputs stay byte-identical to the conservative gate."""
        r = self.active.pop(slot, None)
        if r is None:
            r = self.prefilling.pop(slot)
        if len(r.output) > r.rolled:
            # only the not-yet-rolled tail: a request preempted twice must
            # not duplicate its first resume's tokens inside the prompt
            r.prompt = np.concatenate(
                [np.asarray(r.prompt),
                 np.asarray(r.output[r.rolled:],
                            np.asarray(r.prompt).dtype)])
            r.rolled = len(r.output)
        r.prefilled = 0
        r.dec_slot = -1
        r.state = State.WAITING
        r.preemptions += 1
        r.recount_pending = True
        self._spec.pop(slot, None)
        if self.kv_tiering:
            # tiered KV memory: swap the victim's blocks to host instead of
            # recomputing when the modeled transfer beats suffix recompute.
            # Must run BEFORE free() — the D2H gather reads the table.
            r.swap_sid = self._maybe_swap_out(slot, r)
        self.cachemgr.free(slot)
        # the victim KEEPS its adapter retain: it resumes from the head of
        # the waiting queue, and evicting (or pool-shedding) its adapter
        # just to swap it straight back in would be pure thrash
        self.waiting.insert(0, r)
        self.metrics.preemptions += 1

    def _maybe_swap_out(self, slot: int, r: Request) -> Optional[int]:
        """Price one preemption victim's swap with the virtual cost model
        and take it only when it wins; every decision is appended to
        ``swap_decisions`` so the bench can replay the rule analytically.
        ``recompute_tokens`` is the victim's committed tokens minus the
        blocks OTHER holders keep device-resident through the free (sibling
        tables, multi-adopter index entries) — what suffix prefill would
        actually recompute if the remaining index-only blocks are shed
        before re-admission, which is precisely the memory-pressure regime
        preemption runs in."""
        m = self.cachemgr
        nb = m.swap_payload_blocks(slot)
        tokens = int(m.lens[slot])
        surviving = m.surviving_blocks(slot, nb)
        recompute = max(tokens - surviving * m.block_size, 0)
        stored = nb * m.host_block_bytes
        chose = nb > 0 and swap_beats_recompute(stored, recompute,
                                                self._cost)
        sid = m.swap_out(slot) if chose else None
        self.swap_decisions.append({
            "rid": r.rid, "tokens": tokens, "blocks": nb,
            "stored_bytes": stored, "recompute_tokens": recompute,
            "chose_swap": bool(chose), "swapped": sid is not None})
        if sid is None:
            self.metrics.kv_swap_skips += 1
        return sid

    def _drop_swap(self, r: Request):
        """Release the request's host swap set (if any) exactly once — on
        failure paths that retire the request before re-admission ever
        consumes it."""
        if r.swap_sid is not None and self.paged:
            self.cachemgr.drop_swap(r.swap_sid)
            r.swap_sid = None

    def _scatter_verify(self, slot: int, r: Request, logits: np.ndarray,
                        draft: Optional[np.ndarray], now: float):
        """Greedy acceptance for one verify chunk: keep the longest draft
        prefix matching the model's argmax plus the bonus token, then roll
        the paged cache back past the accepted length (releasing blocks the
        rejected drafts transiently occupied)."""
        if draft is None:
            draft = np.zeros((0,), np.int64)
        L = int(self.cachemgr.lens[slot])
        n_acc, emitted = accept_greedy(draft, logits)
        # exactness clamps: never emit past max_new_tokens, stop at eos —
        # the same cuts plain greedy decode would have made tick by tick
        emitted = emitted[:r.max_new_tokens - len(r.output)]
        if r.eos_token >= 0 and r.eos_token in emitted:
            emitted = emitted[:emitted.index(r.eos_token) + 1]
        n_kept = len(emitted)
        t_prev = r.token_times[-1] if r.token_times else now
        r.token_times.extend(spread_token_times(t_prev, now, n_kept))
        r.output.extend(emitted)
        # cache holds K/V for [current, accepted drafts]; the bonus token is
        # the next step's input.  Rejected draft positions are rolled back,
        # then the accepted INPUT tokens ([current] + accepted drafts) are
        # committed — which may fill and publish blocks into the dedup index
        self.cachemgr.truncate(slot, L + n_kept)
        self.cachemgr.commit_tokens(
            slot, [int(self._last_tokens[slot])] + list(emitted[:-1]))
        self._last_tokens[slot] = emitted[-1]
        self.metrics.decode_tokens += n_kept
        if len(draft):
            self.metrics.spec_drafted += len(draft)
            self.metrics.spec_accepted += n_acc
            self.metrics.spec_steps += 1
            self._spec[slot][1].update(len(draft), n_acc)
        self._maybe_finish(r, now)

    def _apply_trainer(self, tr: MixedLoraTrainer):
        store = self.model.store
        mask = store.slot_mask([tr.name])
        new_bank, self.opt_state = self.apply_step(self.grad_accum,
                                                   self.opt_state,
                                                   store.bank, mask)
        store.set_bank(new_bank)
        # the bank slot now holds newer weights than the host archive /
        # pool payload — sync happens lazily at the next shed or eviction
        store.mark_dirty(tr.name)
        inv = 1.0 - mask
        self.grad_accum = tree_mask_slots(self.grad_accum, inv)

    def _maybe_finish(self, r: Request, now: float):
        done_len = len(r.output) >= r.max_new_tokens
        eos = r.eos_token >= 0 and r.output and r.output[-1] == r.eos_token
        ctx_full = self.cachemgr.lens[r.dec_slot] + 1 >= self.ecfg.s_max
        if done_len or eos or ctx_full:
            r.state = State.DONE
            r.t_finish = now
            self.active.pop(r.dec_slot, None)
            self._spec.pop(r.dec_slot, None)
            self.cachemgr.free(r.dec_slot)
            self._drop_retain(r)
            self.finished.append(r)

    def _drop_retain(self, r: Request):
        """Drop the request's adapter hold (if it took one) exactly once —
        at finish or failure, never at preemption."""
        if r.adapter and r.adapter_retained:
            self.model.store.release(r.adapter)
            r.adapter_retained = False

    # ------------------------------------------------------------------
    def run(self, max_ticks: int = 100000, until_drained: bool = True):
        """Run until all inference requests finish and trainers complete."""
        for _ in range(max_ticks):
            busy = self.tick()
            drained = (not self.waiting and not self.active
                       and not self.prefilling and not self.future
                       and not self.trainers_pending())
            if until_drained and drained:
                break
            if not busy and not until_drained:
                break
        for tr in self.trainers.values():
            if tr.force_apply_pending():
                self._apply_trainer(tr)
        self.metrics.elapsed = self.clock.now()
        return self.metrics

    @property
    def all_requests(self) -> List[Request]:
        return self.finished + list(self.active.values()) \
            + list(self.prefilling.values()) + self.waiting + self.future
