"""Time sources for the engine.

``WallClock`` charges real elapsed time (the default when benchmarking the
actual CPU runtime).  ``VirtualClock`` charges a token-based cost model so
SLO experiments replay deterministically and can emulate the paper's GPU
timescales on this CPU-only container (constants calibrated in DESIGN.md /
benchmarks)."""
from __future__ import annotations

import dataclasses
import time
from typing import Optional


class WallClock:
    def __init__(self):
        self._t0 = time.monotonic()

    def now(self) -> float:
        return time.monotonic() - self._t0

    def charge(self, cost: float):                 # real time already passed
        pass

    def advance_to(self, t: float):
        pass                                        # cannot time-travel


@dataclasses.dataclass
class CostModel:
    """Step latency model: fixed overhead + per-token costs (seconds).
    Defaults emulate an A6000-class device serving an 8B model (paper Fig.2
    scale): ~35 ms fixed step overhead, prefill ~9 us/tok, decode ~1.5
    ms/tok-row, fine-tune ~28 us/tok (fwd+bwd).  ``remote_per_block`` is
    the modeled interconnect cost of fetching one KV block's payload from a
    sibling replica's pool (fleet remote fetch) — NVLink/ICI-class D2D copy
    of a 32-token block across all layers; cheaper than recomputing the
    block's prefill (32 x ``prefill_per_tok``) at these defaults, which is
    what makes fetch-over-recompute the default-winning move."""
    fixed: float = 0.035
    prefill_per_tok: float = 9e-6
    decode_per_row: float = 1.5e-3
    ft_per_tok: float = 28e-6
    remote_per_block: float = 1e-4
    # adapter swap-in (unified adapter paging / LRU bank reload): one H2D
    # transfer of an adapter's true-rank A/B payload.  The fixed term is an
    # 8B-scale rank-16 adapter (~60 MB over ~25 GB/s PCIe, plus launch); it
    # dominates at this repo's reduced model sizes ON PURPOSE — the clock
    # emulates paper-scale hardware, where swap-ins are far from free.
    adapter_swap_fixed: float = 2.5e-3
    adapter_h2d_per_byte: float = 4e-11
    # tiered KV memory (host block pool): per-byte PCIe-class transfer
    # rates for KV block payloads moving between HBM and host RAM.  These
    # feed the swap-vs-recompute decision rule (``kvcache.transfer_cost``
    # vs suffix-prefill recompute at ``prefill_per_tok``): at these
    # defaults a reduced-model block (~KBs) transfers orders of magnitude
    # cheaper than recomputing its 16-32 tokens of prefill, so swap wins
    # whenever the victim's context is not already index-resident —
    # exactly the regime the paper-scale hardware sits in.
    h2d_per_byte: float = 4e-11
    d2h_per_byte: float = 4e-11


class VirtualClock:
    def __init__(self, cost: Optional[CostModel] = None):
        self._t = 0.0
        self.cost = cost or CostModel()

    def now(self) -> float:
        return self._t

    def charge(self, cost: float):
        self._t += cost

    def advance_to(self, t: float):
        self._t = max(self._t, t)

    def step_cost(self, pf_tokens: int, dec_rows: int, ft_tokens: int,
                  dec_extra_tokens: int = 0, remote_blocks: int = 0,
                  adapter_swaps: int = 0,
                  adapter_swap_bytes: int = 0,
                  kv_d2h_bytes: int = 0,
                  kv_h2d_bytes: int = 0) -> float:
        """``dec_extra_tokens``: drafted tokens verified alongside the
        row's current token.  Decode is memory-bound — the row already pays
        ``decode_per_row`` for streaming weights + cache once — so extra
        verify queries ride that stream at compute-bound (prefill-like)
        marginal cost.  That asymmetry is the whole speculation win.

        ``remote_blocks``: KV blocks fetched from a sibling replica's pool
        this step (fleet remote fetch), charged at the modeled interconnect
        rate.  A pure-fetch step still pays ``fixed`` — the transfer launch
        is not free — which is what makes the fetch-vs-recompute rule a
        real per-request decision rather than a per-block tautology.

        ``adapter_swaps`` / ``adapter_swap_bytes``: adapter weight payloads
        brought in from host this step (unified adapter paging swap-ins, or
        the LRU bank's voided-adapter reloads — both pay the same H2D
        price, which keeps equal-HBM comparisons honest).  Charged per
        transfer plus per byte; co-scheduling same-adapter requests
        amortizes the whole term to one swap per adapter per tick.

        ``kv_d2h_bytes`` / ``kv_h2d_bytes``: KV block payload moved between
        HBM and the host block pool this step (swap-outs + demotions going
        down, restores + rehydrations coming back up), charged at the
        modeled PCIe rates — the same per-byte terms the swap-vs-recompute
        decision rule prices, so a chosen swap costs on the clock exactly
        what the rule predicted."""
        c = self.cost
        if (pf_tokens == 0 and dec_rows == 0 and ft_tokens == 0
                and remote_blocks == 0 and adapter_swaps == 0
                and kv_d2h_bytes == 0 and kv_h2d_bytes == 0):
            return 0.0
        return (c.fixed + c.prefill_per_tok * pf_tokens
                + c.decode_per_row * dec_rows + c.ft_per_tok * ft_tokens
                + c.prefill_per_tok * dec_extra_tokens
                + c.remote_per_block * remote_blocks
                + c.adapter_swap_fixed * adapter_swaps
                + c.adapter_h2d_per_byte * adapter_swap_bytes
                + c.d2h_per_byte * kv_d2h_bytes
                + c.h2d_per_byte * kv_h2d_bytes)
