"""Serving substrate: requests, KV-cache management, SLO tracking, the
continuous-batching scheduler with mutable capacity allocation, and the
unified fine-tuning/serving engine."""
