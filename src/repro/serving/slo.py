"""SLO definitions and attainment accounting (paper Appendix C/D: max waiting
time 6 s, mean decode latency 200 ms, max decode latency 1000 ms)."""
from __future__ import annotations

import dataclasses
from typing import Iterable

import numpy as np

from repro.errors import AccountingInvariantError
from repro.serving.request import Request, State


@dataclasses.dataclass(frozen=True)
class SLOConfig:
    max_waiting_s: float = 6.0
    mean_decode_ms: float = 200.0
    max_decode_ms: float = 1000.0


def spread_token_times(t_prev: float, now: float, n: int) -> list:
    """Per-token completion times for a multi-token (speculative verify)
    step: one step of latency ``now - t_prev`` produced ``n`` accepted
    tokens, so each is charged ``step_latency / n`` — NOT one inflated
    inter-step gap — keeping ``request_meets_slo`` meaningful under
    speculation."""
    if n < 1:
        raise AccountingInvariantError(
            f"spread_token_times needs n >= 1 accepted tokens, got {n}")
    dt = (now - t_prev) / n
    return [t_prev + (i + 1) * dt for i in range(n)]


def request_meets_slo(r: Request, slo: SLOConfig) -> bool:
    if r.state is not State.DONE:
        return False
    w = r.waiting_time()
    if w is None or w > slo.max_waiting_s:
        return False
    lats = r.decode_latencies()
    if lats.size:
        if lats.mean() * 1e3 > slo.mean_decode_ms:
            return False
        if lats.max() * 1e3 > slo.max_decode_ms:
            return False
    return True


def slo_attainment(requests: Iterable[Request], slo: SLOConfig) -> float:
    rs = list(requests)
    if not rs:
        return 1.0
    return sum(request_meets_slo(r, slo) for r in rs) / len(rs)


@dataclasses.dataclass
class Metrics:
    """Aggregate throughput metrics (paper Appendix C)."""
    decode_tokens: int = 0
    prefill_tokens: int = 0
    finetune_tokens: int = 0
    eval_tokens: int = 0
    steps: int = 0
    elapsed: float = 0.0
    busy_time: float = 0.0       # virtual-clock time spent executing steps
    # speculative decoding accounting
    spec_drafted: int = 0        # draft tokens submitted for verification
    spec_accepted: int = 0       # drafts that matched the greedy argmax
    spec_steps: int = 0          # verify steps with at least one draft
    # prefix caching / chunked prefill accounting.  ``prefill_tokens``
    # counts COMPUTED suffix tokens only (what the clock charges);
    # ``reused_prefix_tokens`` is the skipped shared-prefix span, so
    # prompt tokens served = prefill_tokens + reused_prefix_tokens.
    reused_prefix_tokens: int = 0
    max_pf_tokens_step: int = 0  # per-step prefill-token high-water mark
    starved_ticks: int = 0       # steps that ran prefill while decoders
    #                              were active but got no decode rows
    # content-hash dedup / prefix-aware admission accounting
    hash_hits: int = 0           # full blocks adopted from the hash index
    #                              (each one skipped a block of recompute
    #                              AND a block of storage)
    hash_blocks_resident: int = 0  # gauge: index population at last step
    probe_admissions: int = 0    # admissions reordered ahead of an older
    #                              waiter because their prefix was resident
    #                              (bounded by the scheduler fairness ramp)
    # fleet remote fetch accounting (multi-engine serving: blocks whose
    # K/V was copied in from a sibling replica's pool instead of being
    # recomputed locally — charged at CostModel.remote_per_block)
    remote_fetch_blocks: int = 0
    remote_fetch_time: float = 0.0
    # adapter residency accounting (unified adapter paging / LRU bank).
    # ``adapter_swap_ins`` counts host->device adapter payload transfers
    # during serving (charged at CostModel.adapter_swap_fixed + per byte);
    # ``adapter_resident_hits`` counts acquires served with no host
    # traffic (bank hit or pool-resident gather).
    adapter_swap_ins: int = 0
    adapter_swap_in_bytes: int = 0
    adapter_resident_hits: int = 0
    adapter_blocks_resident: int = 0   # gauge: pool blocks holding adapter
    #                              payloads at last step (unified paging)
    adapter_peak_coresident: int = 0   # max adapters simultaneously in HBM
    # over-admission / preemption accounting.  Preempted requests keep
    # their arrival and t_first_token, so the SLO cost of a preemption is
    # visible as decode latency; these count the mechanism itself.
    preemptions: int = 0         # recompute preemptions (victim requeued)
    preempted_tokens_recomputed: int = 0  # context tokens re-prefilled
    #                              after preemption (net of surviving
    #                              registry-resident prefix blocks)
    lent_blocks_peak: int = 0    # peak reservation debt not backed by the
    #                              free list (capacity actually lent out)
    # tiered KV memory (host block pool).  Swap-outs move a preemption
    # victim's blocks D2H instead of discarding them; restores bring them
    # back H2D at re-admission; demotions/rehydrations are the same tiering
    # applied to shed hash-index blocks.  Transfer bytes are charged to the
    # virtual clock at CostModel.d2h_per_byte / h2d_per_byte.
    kv_swap_outs: int = 0        # preemption victims swapped to host
    kv_swap_out_bytes: int = 0
    kv_swap_skips: int = 0       # preemptions where the decision rule (or
    #                              a full host pool) chose recompute
    kv_restores: int = 0         # swap sets restored H2D at re-admission
    kv_restore_bytes: int = 0
    kv_restored_tokens: int = 0  # prompt tokens served from restored K/V
    #                              beyond what index adoption already covered
    kv_demotions: int = 0        # shed index blocks captured to the host tier
    kv_rehydrated_blocks: int = 0  # demoted blocks re-published on demand
    host_bytes_used: int = 0     # gauge: host pool bytes at last step
    host_bytes_peak: int = 0     # high-water mark of host pool residency

    @property
    def acceptance_rate(self) -> float:
        return self.spec_accepted / max(self.spec_drafted, 1)

    def rates(self):
        e = max(self.elapsed, 1e-9)
        return {
            "DTPS": self.decode_tokens / e,
            "PTPS": self.prefill_tokens / e,
            "FTPS": self.finetune_tokens / e,
            "ETPS": self.eval_tokens / e,
            "steps_per_s": self.steps / e,
        }
