"""Engine-side cache management: dense rows (legacy) and paged blocks.

Two layout contracts with the model (``repro.models.model``):

**Dense** (``CacheManager``): the cache pytree has ``capacity + pf_capacity``
rows of ``s_max`` key/value slots each; rows ``[0, capacity)`` are the
persistent decode table, rows ``[Bd, Bd + Bp)`` receive each step's prefill
writes (``Bd`` is that tick's decode-bucket size).  After a step,
``commit_prefill`` copies freshly-prefilled rows into their assigned
decode-table slots.  HBM is sized for the worst case: every resident request
pays ``s_max`` slots whether it uses them or not.

**Paged** (``PagedCacheManager``): attention K/V lives in a flat pool of
fixed-size blocks ``[n_blocks, block_size, ...]``; each request owns a *block
table* (list of block ids).  Admission is a block budget, not a slot: a
request is admitted only when its projected life
``ceil(min(prompt + max_new [+ spec headroom], s_max) / block_size)`` fits
the pool (the S-LoRA unified-paging design, on TPU with static shapes), but
blocks are *allocated on demand*: admission allocates only the blocks the
prompt needs now, the rest stay a **reservation** (``reserved`` /
``reserved_debt``) that ``grow`` converts to real blocks as decoding
advances.  The debt is subtracted from the free count the scheduler sees, so
the admission gate can never hand out a block an admitted request will later
need.  ``truncate`` is the inverse mutation: speculative-decoding rollback
(and any other sequence shrink) releases now-unused tail blocks back to the
pool, re-crediting the reservation.  Block 0 is a reserved null block that
absorbs writes from padding rows.  Prefill writes land directly in the
request's blocks via the table carried in the batch — commit assigns table
entries instead of copying rows.  Only per-request *state* (Mamba SSM state,
conv tails, cross-attention K/V), which does not grow with sequence length,
still uses dense rows ``[0, capacity + pf_capacity)`` with the row-copy
commit.

Over-admission (``over_admit`` >= 1.0): the reservation gate above is
conservative — reserved-but-unfilled blocks are never lent out, so
worst-case-length requests strand pool capacity they may never claim.  With
``over_admit > 1`` the gate charges only a ``1 / over_admit`` slice of the
outstanding debt (``charged_debt``) and lends the rest to new admissions
(the vLLM/S-LoRA bet: most requests stop early).  The price is that a
within-reservation ``grow`` can now find the pool empty; instead of the
conservative mode's ``KVAccountingError`` it returns a short capacity — the
growth-failure signal the engine answers with recompute preemption (free a
victim's blocks, requeue it at the head of the waiting queue, re-prefill its
context suffix-only over whatever prefix blocks survived).

Content-hash block dedup (``hash_dedup``, vLLM-style): every *full,
immutable* block is content-addressed by a chained key
``sha1(adapter, parent_key, block_tokens)`` — the key pins the block's whole
left context (and the LoRA, since K/V depend on it), so two blocks with
equal keys hold K/V for identical (adapter, position, token-history) and are
interchangeable.  ``try_admit`` walks the prompt's key chain and *adopts*
the longest resident run (incref — no recompute, no re-storage; the span
suffix-only prefill then skips), ``commit_prefill`` / ``commit_tokens``
*publish* each newly-filled full block into the index (the index holds its
own refcount, so published blocks outlive their request and a write into one
always copy-on-writes first — a published block's payload is immutable by
construction, the index can never go stale), and eviction sheds index-only
(ref == 1) blocks on demand, zero-hit blocks first, then the lowest hit
count.  This subsumes both the explicit ``prefix_id`` registry and the
two-sighting ``auto_prefix`` heuristic of earlier revisions: reuse needs no
caller-side id and starts at the SECOND sighting of any shared head, at
per-block granularity.

Unified adapter paging (the S-LoRA unified-memory design): the SAME
``BlockAllocator`` free list also backs a second block class — LoRA adapter
weights.  An adapter's A/B matrices are flattened to a raw byte payload at
its TRUE rank (heterogeneous ranks => variable block counts) and scattered
into an adapter payload pool shaped ``[n_blocks, adapter_block_bytes]``,
where ``adapter_block_bytes`` equals the per-block K/V footprint of the KV
pool — so one allocator unit is one HBM unit for both classes and capacity
flows freely between cache residency and adapter residency instead of being
statically partitioned.  (A real device implementation would alias both
classes into one arena; here they are two same-shaped pools governed by the
single allocator, which preserves the accounting exactly.)  Adapter blocks
are held by ``adapter_tables`` (refcount 1 per table entry, same
conservation law as KV tables), pinned while any scheduled row uses the
adapter (``adapter_pin``), and shed cold-LRU under pressure — redundant
copies first (adapters whose bank materialization makes the pool copy free
to drop), never while pinned.  The shed loops of ``try_admit`` / ``grow`` /
copy-on-write fall back to ``_shed_adapter`` after the hash index runs dry,
and over-admission lending sees adapter blocks automatically: they spend
from the same ``n_free`` every debt property is computed against.
"""
from __future__ import annotations

import functools
import hashlib
import math
from collections import OrderedDict, deque
from typing import Callable, Deque, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.errors import ConfigInvariantError, InvariantError
from repro.models.configs import ModelConfig
from repro.models.model import init_cache, init_paged_cache, STATE_KEYS
from repro.models.quant import dequant_leaf, quantize_leaf
from repro.serving.clock import CostModel
from repro.serving.request import priority_rank


# cache leaves are [n_periods, n_rows, ...]: rows live on axis 1
@jax.jit
def _commit(tree, src_rows: jax.Array, dst_rows: jax.Array):
    def mv(x):
        return x.at[:, dst_rows].set(x[:, src_rows])
    return jax.tree_util.tree_map(mv, tree)


@jax.jit
def _zero_rows(tree, rows: jax.Array):
    def z(x):
        return x.at[:, rows].set(0.0)
    return jax.tree_util.tree_map(z, tree)


# donate the cache: every caller immediately replaces it with the result,
# and without aliasing a one-block logical copy would materialize the whole
# pool afresh
@functools.partial(jax.jit, donate_argnums=(0,))
def _copy_block(cache, src: jax.Array, dst: jax.Array):
    # pool leaves are [n_periods, n_blocks, block_size, ...]: copy axis 1.
    # state leaves are per-request rows, NOT block-addressed — leave them be.
    layers = tuple(
        {k: (v if k in STATE_KEYS else v.at[:, dst].set(v[:, src]))
         for k, v in d.items()}
        for d in cache["layers"])
    return {"layers": layers}


# adapter payload pool scatter: write N flattened-weight blocks at once.
# Donated for the same reason as _copy_block — the caller always replaces
# the pool with the result.
@functools.partial(jax.jit, donate_argnums=(0,))
def _adapter_write(pool, bids: jax.Array, payload: jax.Array):
    return pool.at[bids].set(payload)


# cross-pool sibling of _copy_block: fetch one block's K/V payload from
# ANOTHER manager's pool (the fleet remote-fetch path).  Only the
# destination is donated — the source pool is read-only here.
@functools.partial(jax.jit, donate_argnums=(0,))
def _copy_block_from(dst_cache, src_cache, src: jax.Array, dst: jax.Array):
    layers = tuple(
        {k: (v if k in STATE_KEYS else v.at[:, dst].set(sd[k][:, src]))
         for k, v in d.items()}
        for d, sd in zip(dst_cache["layers"], src_cache["layers"]))
    return {"layers": layers}


# host-tier sibling of _copy_block_from: scatter a restored payload (one
# per-layer dict of [n_periods, n, block_size, ...] leaves, STATE leaves
# absent) into ``bids`` of the destination pool.  Donated destination —
# the caller always replaces the cache with the result.
@functools.partial(jax.jit, donate_argnums=(0,))
def _blocks_write(cache, bids: jax.Array, payload):
    layers = tuple(
        {k: (v.at[:, bids].set(pd[k]) if k in pd else v)
         for k, v in d.items()}
        for d, pd in zip(cache["layers"], payload))
    return {"layers": layers}


class KVAccountingError(InvariantError):
    """A block-accounting invariant was violated: refcount misuse, or a
    within-reservation ``grow`` finding an empty pool under the conservative
    gate (which guarantees ``n_free >= debt``).  A real exception — not an
    ``assert`` — because these checks are load-bearing control flow and must
    survive ``python -O``."""


class OutOfBlocksError(RuntimeError):
    """The pool could not supply a block for a *mandatory* write (a
    copy-on-write fork, or within-reservation growth whose earmarked block
    was lent out by over-admission).  Not a bug: under ``over_admit > 1``
    this is the growth-failure signal the engine answers by preempting a
    resident request to reclaim capacity."""


def swap_beats_recompute(stored_bytes: int, recompute_tokens: int,
                         cost: CostModel) -> bool:
    """THE tiered-memory decision rule: a D2H + H2D round-trip of the
    stored payload vs suffix-prefill recompute of the tokens no other
    holder keeps device-resident.  Strict ``<`` — at a tie recompute wins
    (not transferring is simpler than a free transfer).  Module-level and
    pure so the bench can replay every engine decision analytically and
    gate on an exact hit rate."""
    transfer = stored_bytes * (cost.d2h_per_byte + cost.h2d_per_byte)
    return transfer < recompute_tokens * cost.prefill_per_tok


class HostBlockPool:
    """Host-RAM tier behind the device block pool (tiered KV memory).

    One byte budget (``capacity_bytes``: the host RAM the operator grants,
    expressed by the engine as N device blocks' worth of raw K/V payload),
    two entry kinds:

    * **swap sets** — a preemption victim's gathered blocks, PINNED until
      the victim is re-admitted (restored H2D) or dropped.  Owned by the
      waiting request through ``Request.swap_sid`` — working state, not
      cache, so ``pristine`` requires none outstanding.
    * **demoted blocks** — single index-shed blocks keyed by the same
      content hash the device index uses; an LRU-evictable cache (oldest
      untouched entry dropped first when space is needed), never pinned.

    Entry byte sizes are the manager's STATIC per-block footprint (raw or
    int8-quantized), not ``ndarray.nbytes`` — accounting must be exactly
    reproducible by the bench's analytic replay.  With ``quant`` the same
    byte budget holds roughly twice the blocks; the price is that restored
    K/V is no longer bit-identical (the engine exposes that only behind an
    explicit exactness-exempt flag)."""

    def __init__(self, capacity_bytes: int, quant: bool = False):
        if capacity_bytes <= 0:
            raise ConfigInvariantError(
                "host block pool needs a positive byte budget")
        self.capacity_bytes = int(capacity_bytes)
        self.quant = bool(quant)
        self._swap_sets: Dict[int, dict] = {}
        self._demoted: "OrderedDict[str, dict]" = OrderedDict()
        self._next_sid = 0
        self.used_bytes = 0
        self.peak_used_bytes = 0
        self.evictions = 0            # demoted entries LRU-dropped for space

    # -- gauges --------------------------------------------------------------
    @property
    def n_swap_sets(self) -> int:
        return len(self._swap_sets)

    @property
    def n_demoted(self) -> int:
        return len(self._demoted)

    @property
    def free_bytes(self) -> int:
        return self.capacity_bytes - self.used_bytes

    def demoted_keys(self):
        """Snapshot of host-resident demoted keys (tests: the two-tier
        disjointness invariant checks this against the device index)."""
        return set(self._demoted)

    # -- space ---------------------------------------------------------------
    def _evict_for(self, nbytes: int) -> bool:
        """Make room by LRU-dropping demoted entries; swap sets are pinned
        and never touched.  False when even a full demoted flush cannot
        fit ``nbytes`` (the pinned tier has the budget)."""
        if nbytes > self.capacity_bytes:
            return False
        while (self.used_bytes + nbytes > self.capacity_bytes
               and self._demoted):
            _, old = self._demoted.popitem(last=False)
            self.used_bytes -= old["bytes"]
            self.evictions += 1
        return self.used_bytes + nbytes <= self.capacity_bytes

    def _charge(self, nbytes: int):
        self.used_bytes += nbytes
        self.peak_used_bytes = max(self.peak_used_bytes, self.used_bytes)

    # -- swap sets (pinned) --------------------------------------------------
    def put_swap(self, entry: dict) -> Optional[int]:
        if not self._evict_for(entry["bytes"]):
            return None
        sid = self._next_sid
        self._next_sid += 1
        self._swap_sets[sid] = entry
        self._charge(entry["bytes"])
        return sid

    def pop_swap(self, sid: int, missing_ok: bool = False) -> Optional[dict]:
        entry = self._swap_sets.pop(sid, None)
        if entry is None:
            if missing_ok:
                return None
            raise KVAccountingError(f"unknown swap set {sid}")
        self.used_bytes -= entry["bytes"]
        return entry

    # -- demoted blocks (LRU cache) ------------------------------------------
    def put_demoted(self, key: str, entry: dict) -> bool:
        if key in self._demoted:      # refresh: same content by construction
            self._demoted.move_to_end(key)
            return True
        if not self._evict_for(entry["bytes"]):
            return False
        self._demoted[key] = entry
        self._charge(entry["bytes"])
        return True

    def has_demoted(self, key: str) -> bool:
        return key in self._demoted

    def pop_demoted(self, key: str) -> Optional[dict]:
        entry = self._demoted.pop(key, None)
        if entry is not None:
            self.used_bytes -= entry["bytes"]
        return entry

    def drop_demoted(self, key: str) -> bool:
        """Forget a demoted entry (disjointness: fired when its key becomes
        device-resident again through publish/import)."""
        return self.pop_demoted(key) is not None

    def flush_demoted(self) -> int:
        """Drop every demoted entry (drain/leak checks — demoted blocks are
        cache; swap sets are NOT flushed, they are owned by waiting
        requests and must be restored or dropped through them).  Returns
        entries dropped."""
        n = len(self._demoted)
        for e in self._demoted.values():
            self.used_bytes -= e["bytes"]
        self._demoted.clear()
        return n


def projected_blocks(prompt_len: int, max_new: int, block_size: int,
                     s_max: int) -> int:
    """Blocks a request reserves on admission: its whole projected life
    (prompt + generated tokens), clipped to the context limit.  The single
    source of truth for both the scheduler's admission gate and the
    manager's reservation."""
    tokens = min(prompt_len + max_new, s_max)
    return -(-tokens // block_size)


def block_key(adapter: str, parent: str, tokens: np.ndarray) -> str:
    """Content-hash identity of one full KV block: the adapter (K/V depend
    on the LoRA), the parent block's key (pins the whole left context —
    identical tokens at different positions must not collide), and the
    block's own tokens."""
    h = hashlib.sha1()
    h.update(adapter.encode())
    h.update(b"\x00")
    h.update(parent.encode())
    h.update(b"\x00")
    h.update(np.ascontiguousarray(np.asarray(tokens, np.int64)).tobytes())
    return h.hexdigest()


def prompt_chain_keys(prompt: np.ndarray, adapter: str,
                      block_size: int) -> List[str]:
    """A prompt's block-key chain: one chained content hash per leading
    full block, capped so at least ONE prompt token is always left uncached
    — suffix-only prefill needs a live query to produce the first-token
    logits, and that token's K/V write must never land in a block the index
    still owns.  Module-level (manager-independent) so the fleet router can
    hash prompts without holding any one engine's manager."""
    p = np.asarray(prompt)
    keys: List[str] = []
    parent = ""
    for i in range(max(len(p) - 1, 0) // block_size):
        parent = block_key(adapter, parent,
                           p[i * block_size:(i + 1) * block_size])
        keys.append(parent)
    return keys


def request_chain_keys(r, block_size: int) -> List[str]:
    """Per-request memoized chain keys — the ONE place a prompt is hashed,
    shared by engine admission, the scheduler's residency probe, and the
    fleet router (each used to hash independently; a deep backlog must not
    pay O(prompt) sha1 once per layer that asks per tick).  Memo keyed by
    (prompt length, block size): the prompt only changes when a preemption
    rolls output tokens into it, which changes its length."""
    memo = getattr(r, "_hash_keys", None)
    tag = (r.prompt_len, block_size)
    if memo is None or memo[0] != tag:
        memo = (tag, prompt_chain_keys(r.prompt, r.adapter, block_size))
        r._hash_keys = memo
    return memo[1]


class CacheManager:
    """Dense slot-per-request cache (legacy layout; kept for sliding-window
    models and as the equivalence baseline for the paged path)."""

    def __init__(self, cfg: ModelConfig, capacity: int, pf_capacity: int,
                 s_max: int, dtype=None):
        self.cfg = cfg
        self.capacity = capacity          # decode-table rows
        self.pf_capacity = pf_capacity    # scratch rows for prefill buckets
        self.s_max = s_max
        self.cache = init_cache(cfg, capacity + pf_capacity, s_max, dtype)
        self._free: Deque[int] = deque(range(capacity))
        self.lens = np.zeros((capacity,), np.int64)   # absolute positions

    # -- slot lifecycle ------------------------------------------------------
    def alloc(self) -> Optional[int]:
        return self._free.popleft() if self._free else None

    def free(self, slot: int):
        self.lens[slot] = 0
        self._free.append(slot)

    @property
    def n_free(self) -> int:
        return len(self._free)

    def truncate(self, slot: int, new_len: int):
        """Roll the sequence back (speculation rollback).  Dense rows are
        position-indexed and masked by ``k_valid``, so stale K/V beyond
        ``new_len`` is simply invisible — only the length moves."""
        self.lens[slot] = new_len

    def commit_tokens(self, slot: int, toks: Sequence[int]):
        """Advance the committed length past freshly-written decode/verify
        positions.  The dense layout has no block identity to publish — only
        the length moves (mirrors ``PagedCacheManager.commit_tokens``)."""
        self.lens[slot] += len(toks)

    # -- step plumbing ---------------------------------------------------------
    def step_cache(self):
        return self.cache

    def update(self, new_cache):
        self.cache = new_cache

    def commit_prefill(self, assignments: List[Tuple[int, int]],
                       lengths: List[int], src_base: Optional[int] = None):
        """assignments: (pf_row_index_within_bucket, decode_slot).

        ``src_base`` is the decode-bucket size of the step that produced the
        prefill rows (the model writes prefill at rows ``[Bd, Bd + Bp)``);
        defaults to ``capacity`` for the full-table decode bucket.
        """
        if not assignments:
            return
        base = self.capacity if src_base is None else src_base
        src = jnp.asarray([base + i for i, _ in assignments])
        dst = jnp.asarray([s for _, s in assignments])
        self.cache = _commit(self.cache, src, dst)
        for (_, slot), ln in zip(assignments, lengths):
            self.lens[slot] = ln


# ---------------------------------------------------------------------------
# paged layout
# ---------------------------------------------------------------------------

class BlockAllocator:
    """Fixed-size KV-block free list with refcounts.

    Block 0 is reserved as the null block (never allocated): padding rows in
    the batch carry table entries of 0, so their writes land there harmlessly.
    """

    def __init__(self, n_blocks: int):
        if n_blocks < 2:
            raise ConfigInvariantError(
                "need at least one usable block beyond null")
        self.n_blocks = n_blocks
        self._free: Deque[int] = deque(range(1, n_blocks))
        self.ref = np.zeros((n_blocks,), np.int64)
        self.ref[0] = 1                   # null block is permanently held
        self.peak_used = 0

    @property
    def n_free(self) -> int:
        return len(self._free)

    @property
    def usable(self) -> int:
        return self.n_blocks - 1

    @property
    def n_used(self) -> int:
        return self.usable - self.n_free

    def can_alloc(self, n: int) -> bool:
        return n <= self.n_free

    def alloc(self) -> Optional[int]:
        if not self._free:
            return None
        bid = self._free.popleft()
        self.ref[bid] = 1
        self.peak_used = max(self.peak_used, self.n_used)
        return bid

    def alloc_many(self, n: int) -> Optional[List[int]]:
        if not self.can_alloc(n):
            return None
        return [self.alloc() for _ in range(n)]

    def incref(self, bid: int):
        if bid == 0 or self.ref[bid] <= 0:
            raise KVAccountingError(f"incref of dead block {bid}")
        self.ref[bid] += 1

    def decref(self, bid: int):
        if bid == 0 or self.ref[bid] <= 0:
            raise KVAccountingError(f"decref of dead block {bid}")
        self.ref[bid] -= 1
        if self.ref[bid] == 0:
            self._free.append(bid)

    def is_shared(self, bid: int) -> bool:
        return self.ref[bid] > 1


class PagedCacheManager:
    """Block-table paged KV cache + dense state rows.

    Engine-facing surface mirrors ``CacheManager`` (``alloc`` is replaced by
    ``try_admit`` which takes the request's projected token need), plus block
    bookkeeping: ``table_of``, ``dec_tables``, ``ensure_writable`` (COW), and
    the content-hash dedup index (``chain_keys`` / ``probe`` / adoption
    inside ``try_admit`` / publication inside ``commit_prefill`` and
    ``commit_tokens``).
    """

    def __init__(self, cfg: ModelConfig, capacity: int, pf_capacity: int,
                 s_max: int, block_size: int = 32, n_blocks: int = 0,
                 over_admit: float = 1.0, hash_dedup: bool = True,
                 host_blocks: int = 0, host_quant: bool = False,
                 cost: Optional[CostModel] = None, dtype=None):
        if cfg.sliding_window > 0:
            raise ValueError("paged cache does not support sliding windows; "
                             "use the dense CacheManager")
        if over_admit < 1.0:
            raise ValueError("over_admit is a lending factor >= 1.0")
        self.cfg = cfg
        self.over_admit = float(over_admit)
        self.hash_dedup = bool(hash_dedup)
        self.lent_blocks_peak = 0
        self.hash_hits = 0                # blocks adopted via the index
        self.remote_imports = 0           # blocks fetched from sibling pools
        # fleet wiring: a FleetIndex subscribes to the local index's
        # publication lifecycle so the fleet-wide key -> (engine, block) map
        # is exactly as fresh as the local one (an entry exists iff the
        # local index holds the block — retraction fires from the ONLY
        # local removal path, _depublish, so the fleet view can never name
        # a dead or rewritten block)
        self.on_publish: Optional[Callable[[str, int], None]] = None
        self.on_depublish: Optional[Callable[[str, int], None]] = None
        self.capacity = capacity          # state rows == max concurrent reqs
        self.pf_capacity = pf_capacity
        self.s_max = s_max
        self.block_size = block_size
        self.nbt = -(-s_max // block_size)          # table width (blocks/req)
        if n_blocks <= 0:
            # never more constrained than the dense layout by default
            n_blocks = 1 + capacity * self.nbt
        self.allocator = BlockAllocator(n_blocks)
        self.cache = init_paged_cache(cfg, n_blocks, block_size,
                                      capacity + pf_capacity, dtype)
        self._free_slots: Deque[int] = deque(range(capacity))
        self.lens = np.zeros((capacity,), np.int64)
        self.tables: Dict[int, List[int]] = {}      # state slot -> block ids
        self.shared_count: Dict[int, int] = {}      # leading adopted blocks
        # blocks earmarked for a slot's projected life beyond what it holds
        # now (allocate-on-demand): the gate must not spend these
        self.reserved: Dict[int, int] = {}          # slot -> reserved blocks
        self._debt = 0                              # sum of unfilled reserves
        # content-hash index: chained block key -> block id.  The index holds
        # its OWN refcount on every published block, so index residents can
        # never be rewritten in place (any write copy-on-writes first) and an
        # index entry is stale-proof by construction.  Ordered for LRU
        # (publication order, moved-to-end on adoption).
        self._index: "OrderedDict[str, int]" = OrderedDict()
        self._hashed: Dict[int, str] = {}           # inverse: block id -> key
        self._hits: Dict[str, int] = {}             # key -> adoption count
        # per-slot dedup state: the token record (an s_max-sized buffer — a
        # per-token np.concatenate would make decode bookkeeping O(L^2) per
        # request — holding the prompt at admission, extended in place by
        # commit_tokens, valid through _seq_len), the key chain of its
        # leading full blocks, the adapter the keys were derived under, and
        # whether the slot may share at all (aux-embedding requests must
        # not — their K/V depend on state the token identity cannot capture)
        self._seqs: Dict[int, np.ndarray] = {}
        self._seq_len: Dict[int, int] = {}
        self._chains: Dict[int, List[str]] = {}
        self._adapters: Dict[int, str] = {}
        self._share: Dict[int, bool] = {}
        # ---- adapter block class (unified paging, see module docstring).
        # One allocator unit is one HBM unit for both classes: a block's
        # adapter payload capacity equals its K/V footprint (bytes of every
        # block-addressed cache leaf per block, across periods).
        bb = 0
        qb = 0
        for d in self.cache["layers"]:
            for k, v in d.items():
                if k in STATE_KEYS:
                    continue
                e = v.size // v.shape[1]          # elements per block
                bb += e * v.dtype.itemsize
                # int8 residency footprint: 1-byte payload plus f32 scales
                # over quantize_leaf's axis=-2 reduction of the leaf
                qb += e + (e // v.shape[-2]) * 4
        self.adapter_block_bytes = max(int(bb), 1)
        # ---- tiered KV memory: host block pool (see HostBlockPool).  The
        # budget is ``host_blocks`` RAW device blocks' worth of host RAM;
        # quantized residency stores each block at the smaller int8
        # footprint, which is exactly how quant ~doubles host capacity at
        # equal budget.  Byte accounting everywhere uses these two STATIC
        # per-block numbers, never ndarray.nbytes — the bench replays the
        # swap decisions analytically and must reproduce them bit-for-bit.
        self.cost = cost                  # CostModel; None = transfers free
        self.host_quant = bool(host_quant)
        self.host_block_bytes = max(int(qb), 1) if host_quant \
            else self.adapter_block_bytes
        self.host_pool: Optional[HostBlockPool] = (
            HostBlockPool(host_blocks * self.adapter_block_bytes,
                          quant=host_quant)
            if host_blocks > 0 else None)
        self.kv_swap_outs = 0             # preemption swap-outs (D2H)
        self.kv_swap_out_bytes = 0
        self.kv_restores = 0              # re-admission restores (H2D)
        self.kv_restore_bytes = 0
        self.kv_swap_drops = 0            # swap sets released unrestored
        self.kv_demotions = 0             # index sheds captured to host (D2H)
        self.kv_demote_bytes = 0
        self.kv_rehydrations = 0          # demoted blocks re-published (H2D)
        self.kv_rehydrate_bytes = 0
        # per-priority-rank reservation debt (interactive/standard/batch):
        # shapes the over-admission lending ORDER in charged_debt.  The
        # rank-indexed sum always equals self._debt.
        self._class_debt = [0, 0, 0]
        self._slot_rank: Dict[int, int] = {}
        self._adapter_pool = None                   # lazy [n_blocks, abb] u8
        self.adapter_tables: Dict[str, List[int]] = {}
        self._adapter_bytes: Dict[str, int] = {}    # true payload bytes
        self._adapter_pins: Dict[str, int] = {}     # name -> pin count
        self._adapter_lru: Dict[str, int] = {}      # name -> last-touch tick
        self._adapter_tick = 0
        self.adapter_swap_ins = 0                   # pool admits (H2D writes)
        self.adapter_swap_in_bytes = 0
        self.adapter_sheds = 0
        # set by AdapterStore.attach_pager: notified before a shed victim's
        # blocks are freed (the store re-archives dirty payloads), and a
        # predicate marking adapters whose pool copy is REDUNDANT (bank-
        # materialized and clean) — the cheapest shed victims
        self.on_adapter_shed: Optional[Callable[[str], None]] = None
        self.adapter_redundant_fn: Optional[Callable[[str], bool]] = None

    # -- budget --------------------------------------------------------------
    @property
    def n_free(self) -> int:
        return len(self._free_slots)

    @property
    def charged_debt(self) -> int:
        """Reservation debt the admission gate actually charges.  The
        conservative gate (``over_admit == 1``) charges all of it; a lending
        gate charges only a ``1 / over_admit`` slice and lends the rest out,
        betting that admitted requests rarely all reach their worst-case
        length at once — ``grow`` failures (and the engine's recompute
        preemption) cover the bet when it loses.

        The lending ORDER is priority-shaped: the lendable slice comes out
        of batch-class debt first, then standard — interactive debt is
        never lent, so an interactive request's ``grow`` can only be
        starved by over-admission of its own class ("batch lends first,
        interactive preempts last").  With every request standard (the
        default) this reduces exactly to ``ceil(debt / over_admit)``."""
        lend = self._debt - math.ceil(self._debt / self.over_admit)
        lend_batch = min(lend, self._class_debt[2])
        lend_std = min(lend - lend_batch, self._class_debt[1])
        return self._debt - lend_batch - lend_std

    def _debt_add(self, slot: int, delta: int):
        """The ONLY mutation path for reservation debt: keeps the
        per-priority-class split (lending order) in lockstep with the
        total every other budget property is derived from."""
        self._debt += delta
        self._class_debt[self._slot_rank.get(slot, 1)] += delta

    @property
    def free_blocks(self) -> int:
        """Blocks the admission gate may spend: the allocator's free list
        minus the *charged* reservation debt of already-admitted requests
        (blocks they will ``grow`` into later).  Negative while lent-out
        reservations are actually being claimed."""
        return self.allocator.n_free - self.charged_debt

    @property
    def reserved_debt(self) -> int:
        return self._debt

    @property
    def lent_blocks(self) -> int:
        """Reservation-debt blocks not currently backed by the free list —
        capacity the gate has *actually* lent out (0 under the conservative
        gate, which keeps ``n_free >= debt`` invariant)."""
        return max(self._debt - self.allocator.n_free, 0)

    def _touch_lent(self):
        self.lent_blocks_peak = max(self.lent_blocks_peak, self.lent_blocks)

    @property
    def total_blocks(self) -> int:
        return self.allocator.usable

    def projected_blocks(self, prompt_len: int, max_new: int) -> int:
        return projected_blocks(prompt_len, max_new, self.block_size,
                                self.s_max)

    def _debt_of(self, slot: int) -> int:
        return max(self.reserved.get(slot, 0) - len(self.tables[slot]), 0)

    @property
    def reclaimable_adapter_blocks(self) -> int:
        """Blocks held by UNPINNED resident adapters — sheddable on demand
        (cold-LRU) when KV admission or growth needs the capacity.  A
        pinned adapter (any scheduled row uses it) is working state, not
        cache, and never counts."""
        return sum(len(t) for n, t in self.adapter_tables.items()
                   if not self._adapter_pins.get(n, 0))

    @property
    def reclaimable_blocks(self) -> int:
        """Blocks held only by the hash index (ref == 1) plus unpinned
        resident adapters' blocks — pure cache, sheddable on demand by
        ``try_admit``/``grow``/CoW.  The scheduler's admission gate must
        count these as available, or cache-held blocks would starve
        admission forever.  Evaluated every tick, and the index can
        approach pool size — so one vectorized refcount gather, not a
        per-block Python loop."""
        n = self.reclaimable_adapter_blocks
        if not self._hashed:
            return n
        bids = np.fromiter(self._hashed, np.int64, len(self._hashed))
        return n + int(np.count_nonzero(self.allocator.ref[bids] == 1))

    @property
    def hash_blocks_resident(self) -> int:
        """Current index population (full blocks adoptable by content)."""
        return len(self._index)

    @property
    def pristine(self) -> bool:
        """Post-drain invariant: no live tables, no reservation debt, and
        every non-free block is held ONLY by the hash index or an unpinned
        resident adapter (pure cache, fully reclaimable).  The leak check
        benches and tests gate on — cache residency is not a leak.  With a
        host tier: no live swap sets either (a swap set is a preempted
        request's working state; DEMOTED host entries are cache, like the
        index, and are reclaimed by ``flush_host``)."""
        return (not self.tables and self._debt == 0
                and self.allocator.n_free + self.reclaimable_blocks
                == self.allocator.usable
                and (self.host_pool is None
                     or self.host_pool.n_swap_sets == 0))

    # -- content-hash chain --------------------------------------------------
    def chain_keys(self, prompt: np.ndarray, adapter: str = "") -> List[str]:
        """The prompt's block-key chain (see ``prompt_chain_keys``)."""
        return prompt_chain_keys(prompt, adapter, self.block_size)

    def _resident_run(self, keys: Sequence[str]) -> List[int]:
        """Longest leading run of index-resident blocks for a key chain.
        The walk stops at the first gap: a resident child behind a missing
        parent is unreachable (its key pins the parent's content, which we
        would have to recompute anyway)."""
        bids: List[int] = []
        for k in keys:
            bid = self._index.get(k)
            if bid is None:
                break
            bids.append(bid)
        return bids

    def probe(self, prompt: np.ndarray, adapter: str = "",
              keys: Optional[Sequence[str]] = None) -> int:
        """Prompt tokens the index would serve from resident K/V — the span
        suffix-only prefill skips.  Pure preview (no incref, no LRU touch):
        the scheduler uses it both to charge only the computed suffix
        against its prefill-token budget and to score waiting requests for
        prefix-aware admission."""
        if not self.hash_dedup:
            return 0
        if keys is None:
            keys = self.chain_keys(prompt, adapter)
        return len(self._resident_run(keys)) * self.block_size

    def fresh_need(self, prompt_len: int, max_new: int,
                   prompt: Optional[np.ndarray] = None, adapter: str = "",
                   headroom: int = 0, keys: Optional[Sequence[str]] = None,
                   shareable: bool = True) -> int:
        """The request's charge against the gate's ``free + reclaimable``
        budget.  Adoptable blocks with ref >= 2 cost nothing; adoptable
        blocks held only by the index (ref == 1) are discounted from *need*
        but were also counted reclaimable, so they must still be charged —
        otherwise the gate admits requests the manager then refuses.
        ``headroom`` is extra projected tokens (speculative-decoding
        transient drafts)."""
        held_elsewhere = 0
        if self.hash_dedup and shareable and prompt is not None:
            if keys is None:
                keys = self.chain_keys(prompt, adapter)
            held_elsewhere = sum(1 for b in self._resident_run(keys)
                                 if self.allocator.ref[b] >= 2)
        return (self.projected_blocks(prompt_len, max_new + headroom)
                - held_elsewhere)

    # -- admission -----------------------------------------------------------
    def try_admit(self, prompt: np.ndarray, max_new: int, adapter: str = "",
                  headroom: int = 0, shareable: bool = True,
                  keys: Optional[Sequence[str]] = None,
                  priority: str = "standard"
                  ) -> Optional[Tuple[int, int]]:
        """Reserve a state slot + the request's projected block budget,
        adopting the longest index-resident run of the prompt's block-key
        chain (incref — those blocks arrive by refcount instead of
        recompute), but only *allocate* the blocks the prompt needs now —
        the remainder is a reservation ``grow`` fills on demand.
        ``headroom`` adds transient speculative-draft tokens to the
        projected budget; ``shareable=False`` (aux-embedding requests)
        disables both adoption and later publication.  Returns
        ``(state slot, reused prefix tokens)`` — the reused span is what
        suffix-only prefill may skip — or None when slots or spendable
        blocks are exhausted."""
        if not self._free_slots:
            return None
        need = self.projected_blocks(len(prompt), max_new + headroom)
        share = bool(self.hash_dedup and shareable)
        adopt_keys: List[str] = []
        shared: List[int] = []
        if share:
            if keys is None:
                keys = self.chain_keys(prompt, adapter)
            shared = self._resident_run(keys)
            if self.host_pool is not None:
                # two-tier walk: extend the device-resident run by
                # rehydrating consecutive DEMOTED host blocks (entries only
                # exist when the demote-time cost rule said the H2D beats
                # recomputing the block, so rehydration here is always the
                # cheaper move)
                while len(shared) < len(keys):
                    bid = self._rehydrate(keys[len(shared)],
                                          protect=frozenset(shared))
                    if bid is None:
                        break
                    shared.append(bid)
            adopt_keys = list(keys[:len(shared)])
        # blocks that must exist before prefill writes: the whole prompt
        now_need = min(self.projected_blocks(len(prompt), 0), need)
        fresh_need = need - len(shared)          # lifetime charge at the gate
        fresh_now = max(now_need - len(shared), 0)
        if fresh_need > self.free_blocks:
            # shed idle cache to make room — index blocks first (zero-hit,
            # then coldest), unpinned adapters after — but never the run
            # this admission is about to adopt
            protect = frozenset(shared)
            while (fresh_need > self.free_blocks
                   and self._shed_any(protect_blocks=protect)):
                pass
            if fresh_need > self.free_blocks:
                return None
        for k, bid in zip(adopt_keys, shared):
            # reprolint: ownership-transfer — the adopted ref is owned by
            # this slot's table; ``free``/``truncate`` decref it
            self.allocator.incref(bid)
            self._hits[k] = self._hits.get(k, 0) + 1
            self._index.move_to_end(k)                # LRU touch
            self.hash_hits += 1
        fresh = self.allocator.alloc_many(fresh_now)
        if fresh is None:       # gate passed but the pool cannot back it:
            raise KVAccountingError(  # free_blocks <= n_free was violated
                "admission gate passed but the pool cannot back the prompt")
        slot = self._free_slots.popleft()
        self.tables[slot] = shared + fresh
        self.shared_count[slot] = len(shared)
        self.reserved[slot] = max(need, len(self.tables[slot]))
        self._slot_rank[slot] = priority_rank(priority)
        self._debt_add(slot, self._debt_of(slot))
        self.lens[slot] = 0
        n_rec = min(len(prompt), self.s_max)
        buf = np.zeros((self.s_max,), np.int64)
        buf[:n_rec] = np.asarray(prompt[:n_rec], np.int64)
        self._seqs[slot] = buf
        self._seq_len[slot] = n_rec
        self._chains[slot] = adopt_keys
        self._adapters[slot] = adapter
        self._share[slot] = share
        self._touch_lent()
        return slot, len(shared) * self.block_size

    def free(self, slot: int):
        self._debt_add(slot, -self._debt_of(slot))
        self._slot_rank.pop(slot, None)
        self.reserved.pop(slot, None)
        for bid in self.tables.pop(slot, []):
            self.allocator.decref(bid)
        self.shared_count.pop(slot, None)
        self._seqs.pop(slot, None)
        self._seq_len.pop(slot, None)
        self._chains.pop(slot, None)
        self._adapters.pop(slot, None)
        self._share.pop(slot, None)
        self.lens[slot] = 0
        self._free_slots.append(slot)

    # -- sequence growth / rollback ------------------------------------------
    def grow(self, slot: int, new_len: int) -> int:
        """Extend ``slot``'s table to cover ``new_len`` tokens.  Under the
        conservative gate, growth within the slot's reservation always
        succeeds (the debt accounting guarantees the blocks exist) and an
        empty pool there raises ``KVAccountingError``.  Under over-admission
        the earmarked block may have been lent out: growth stops early and
        the SHORT RETURN VALUE is the failure signal — the engine compares
        the returned token capacity against what it must write and preempts
        a resident request when the committed token no longer fits.  Growth
        beyond the reservation (speculative drafts past the projected life)
        is best-effort from the spendable pool in either mode."""
        table = self.tables[slot]
        target = min(-(-new_len // self.block_size), self.nbt)
        while len(table) < target:
            within = len(table) < self.reserved.get(slot, 0)
            if not within and self.free_blocks <= 0:
                break                       # transient overshoot, pool dry
            d0 = self._debt_of(slot)
            bid = self.allocator.alloc()
            # shedding an idle index block (ref == 1) or a cold unpinned
            # adapter is free compared with the alternatives — a
            # KVAccountingError here or, under lending, an engine
            # preemption that recomputes a whole context
            while bid is None and self._shed_any():
                bid = self.allocator.alloc()
            if bid is None:
                if within and self.over_admit <= 1.0:
                    raise KVAccountingError(
                        "reservation debt accounting violated: within-"
                        "reservation grow found an empty pool under the "
                        "conservative gate")
                break                       # lent-out reservation: growth
            #                                 fails, engine preempts
            table.append(bid)
            self._debt_add(slot, self._debt_of(slot) - d0)
        self._touch_lent()
        return min(len(table) * self.block_size, self.s_max)

    def truncate(self, slot: int, new_len: int):
        """Roll ``slot`` back to ``new_len`` tokens (speculation rollback):
        release table blocks past the new length back to the pool, restoring
        the slot's reservation debt.  Shared (adopted/CoW/index-held) blocks
        are only dereferenced — the index's or a sibling's refcount keeps
        them alive, so rollback never destroys shared state.  The slot's own
        dedup bookkeeping is de-published: its committed-token record and
        key chain shrink with the length, so a later re-fill with different
        content publishes fresh keys (the index entries for the OLD content
        stay valid — they still name blocks that hold exactly that
        content)."""
        new_len = max(int(new_len), 0)
        table = self.tables[slot]
        nb = -(-new_len // self.block_size)
        if nb < len(table):
            d0 = self._debt_of(slot)
            dropped = len(table) - nb
            freed = 0
            for bid in table[nb:]:
                self.allocator.decref(bid)
                if self.allocator.ref[bid] == 0:
                    freed += 1
            del table[nb:]
            self.shared_count[slot] = min(self.shared_count.get(slot, 0), nb)
            # a dropped block other holders keep alive never re-enters the
            # free list, so the slot's re-grow claim on that position is
            # surrendered with it — re-crediting the full drop would make
            # the debt exceed the blocks actually available and break
            # grow()'s within-reservation guarantee
            self.reserved[slot] = max(
                self.reserved.get(slot, 0) - (dropped - freed), len(table))
            self._debt_add(slot, self._debt_of(slot) - d0)
        if slot in self._seqs:
            self._seq_len[slot] = min(self._seq_len[slot], new_len)
            chain = self._chains[slot]
            del chain[new_len // self.block_size:]
        self.lens[slot] = new_len

    def prepare_write(self, slot: int, start: int, n: int) -> int:
        """Make positions ``[start, start + n)`` writable: grow the table to
        cover them and copy-on-write every shared block in the range.
        Returns how many of the ``n`` tokens can actually be written (less
        than ``n`` only when drafts overshoot a dry pool)."""
        cap = self.grow(slot, start + n)
        end = min(start + n, cap)
        if end <= start:
            return 0
        for bi in range(start // self.block_size,
                        (end - 1) // self.block_size + 1):
            self.ensure_writable(slot, pos=bi * self.block_size)
        return end - start

    # -- content-hash publication --------------------------------------------
    def commit_tokens(self, slot: int, toks: Sequence[int]):
        """Record freshly-committed decode/verify input tokens (the token
        whose K/V was written at each position) and publish any block the
        advance fills.  The committed length lands at the end of the
        recorded sequence — callers that wrote a verify chunk first
        ``truncate`` back past the rejected drafts, then commit the
        accepted inputs here.  Writes in place into the slot's s_max
        buffer: O(n) per call, not O(history)."""
        sl = self._seq_len[slot]
        n = min(len(toks), self.s_max - sl)
        if n:
            self._seqs[slot][sl:sl + n] = np.asarray(toks[:n], np.int64)
            self._seq_len[slot] = sl + n
        self.lens[slot] = self._seq_len[slot]
        self._publish_upto(slot)

    def _publish_upto(self, slot: int):
        """Publish ``slot``'s newly-filled full blocks into the hash index.
        The index increfs each published block, which makes its payload
        immutable: any later write into it (rollback past a block boundary,
        then regrowth) is forced through copy-on-write, so an index entry
        can never describe content that changed under it.  A key that is
        already resident keeps the incumbent block — our copy stays private
        (publishing both would strand one of them)."""
        if not self._share.get(slot, False):
            return
        bs = self.block_size
        seq = self._seqs[slot]
        chain = self._chains[slot]
        table = self.tables[slot]
        adapter = self._adapters.get(slot, "")
        n_full = min(int(self.lens[slot]), self._seq_len[slot]) // bs
        n_full = min(n_full, len(table))
        while len(chain) < n_full:
            i = len(chain)
            parent = chain[-1] if chain else ""
            key = block_key(adapter, parent, seq[i * bs:(i + 1) * bs])
            chain.append(key)
            bid = table[i]
            if bid == 0 or key in self._index or bid in self._hashed:
                continue
            self._index[key] = bid
            self._hashed[bid] = key
            self._hits.setdefault(key, 0)
            # reprolint: ownership-transfer — the index owns this ref;
            # _depublish / shed decref it
            self.allocator.incref(bid)
            if self.host_pool is not None:
                # two-tier disjointness: a key lives in exactly one tier
                self.host_pool.drop_demoted(key)
            if self.on_publish is not None:
                self.on_publish(key, bid)

    def _depublish(self, key: str):
        bid = self._index.pop(key)
        del self._hashed[bid]
        self._hits.pop(key, None)
        if self.on_depublish is not None:
            self.on_depublish(key, bid)
        self.allocator.decref(bid)

    def _shed_one(self, protect: frozenset = frozenset()) -> bool:
        """Evict one index entry whose block only the index holds
        (ref == 1; blocks still held by live tables are not cache, they are
        working state — never sheddable from here).  Preference: zero-hit
        blocks first (publication-order LRU among them), then the lowest
        adoption count — the blocks whose loss costs the least recompute.

        Hit-count AGING: every shed scan halves every entry's hit count
        after the victim is chosen.  Hits are evidence of warmth, and
        shedding only happens under memory pressure — so each scan is a
        unit of pressure survived, and a once-hot dead template's counts
        decay geometrically toward zero while a warm template's are
        replenished by fresh adoptions.  Without this, a template that was
        hot last hour pins index-only blocks forever against templates that
        are hot NOW but younger (ROADMAP tiered-memory follow-on)."""
        best = None
        for k, bid in self._index.items():
            if bid in protect or self.allocator.ref[bid] != 1:
                continue
            score = self._hits.get(k, 0)
            if best is None or score < best[0]:
                best = (score, k)
                if score == 0:
                    break         # oldest zero-hit entry: cannot do better
        # decay AFTER selection: this scan judges entries by the hits they
        # actually earned; only their standing in FUTURE scans erodes
        for k in self._hits:
            self._hits[k] >>= 1
        if best is None:
            return False
        if self.host_pool is not None:
            # tiered shed: capture the victim's payload D2H into the host
            # pool's demoted cache BEFORE de-publication drops the device
            # copy — a dead-then-revived template then rehydrates by
            # transfer instead of recompute.  Best-effort and cost-gated;
            # the de-publish below happens either way.
            self._demote(best[1])
        self._depublish(best[1])
        return True

    def flush_index(self) -> int:
        """Shed every reclaimable index entry (tests/benches: distinguishes
        cache residency from a real leak — after a drain plus a flush the
        allocator must be fully free).  Returns entries shed."""
        n = 0
        while self._shed_one():
            n += 1
        return n

    # -- adapter block class (unified paging) --------------------------------
    def adapter_blocks_of(self, nbytes: int) -> int:
        """Pool blocks a payload of ``nbytes`` occupies (>= 1: even a
        zero-rank curiosity owns a block — its table must hold the
        residency)."""
        return max(-(-int(nbytes) // self.adapter_block_bytes), 1)

    def adapter_resident(self, name: str) -> bool:
        return name in self.adapter_tables

    @property
    def adapter_blocks_resident(self) -> int:
        """Gauge: pool blocks currently holding adapter payloads."""
        return sum(len(t) for t in self.adapter_tables.values())

    def _adapter_touch(self, name: str):
        self._adapter_tick += 1
        self._adapter_lru[name] = self._adapter_tick

    def adapter_admit(self, name: str, payload: np.ndarray,
                      shed: bool = True) -> bool:
        """Admit an adapter's flattened weight payload into the shared pool
        (the H2D swap-in): allocate ``adapter_blocks_of(payload)`` blocks
        from the SAME free list KV admission spends, scatter the bytes into
        the adapter payload pool, and record the table.  Spends only the
        gate's spendable budget (``free_blocks`` — so outstanding KV
        reservation debt is honored and the conservative ``n_free >= debt``
        invariant survives), shedding idle index blocks then colder
        unpinned adapters when short (``shed=False`` = opportunistic
        preload: admit only into genuinely free capacity).  Returns False
        when the pool cannot take the payload — the caller falls back to
        bank-only residency or defers the request."""
        if name in self.adapter_tables:
            self._adapter_touch(name)
            return True
        flat = np.ascontiguousarray(payload).view(np.uint8).reshape(-1)
        n = self.adapter_blocks_of(flat.nbytes)
        if shed:
            while (n > self.free_blocks
                   and self._shed_any(protect_adapters=frozenset((name,)))):
                pass
        if n > self.free_blocks:
            return False
        bids = self.allocator.alloc_many(n)
        if bids is None:                     # free_blocks <= n_free always
            raise KVAccountingError(
                "adapter admission gate passed but the pool cannot back it")
        abb = self.adapter_block_bytes
        buf = np.zeros((n, abb), np.uint8)
        buf.reshape(-1)[:flat.size] = flat
        if self._adapter_pool is None:
            self._adapter_pool = jnp.zeros(
                (self.allocator.n_blocks, abb), jnp.uint8)
        self._adapter_pool = _adapter_write(
            self._adapter_pool, jnp.asarray(bids, jnp.int32),
            jnp.asarray(buf))
        self.adapter_tables[name] = bids
        self._adapter_bytes[name] = int(flat.size)
        self._adapter_touch(name)
        self.adapter_swap_ins += 1
        self.adapter_swap_in_bytes += int(flat.size)
        self._touch_lent()
        return True

    def adapter_gather(self, name: str) -> np.ndarray:
        """Materialize a resident adapter's payload from its pool blocks
        (the read side of the paged view: the store unflattens this into
        the BGMV/smlm bank layout on acquire)."""
        bids = self.adapter_tables[name]
        self._adapter_touch(name)
        # swap path, not the tick loop: gathers happen once per acquire
        flat = np.asarray(  # reprolint: sync-point
            self._adapter_pool[jnp.asarray(bids, jnp.int32)]).reshape(-1)
        return flat[:self._adapter_bytes[name]]

    def adapter_refresh(self, name: str, payload: np.ndarray):
        """Rewrite a resident adapter's payload in place (training
        write-back: rank is fixed per adapter, so the footprint cannot
        change).  Not counted as a swap-in — the fresh bytes were produced
        on-device."""
        if name not in self.adapter_tables:
            return
        flat = np.ascontiguousarray(payload).view(np.uint8).reshape(-1)
        bids = self.adapter_tables[name]
        if self.adapter_blocks_of(flat.nbytes) != len(bids):
            raise KVAccountingError(
                f"adapter {name!r} refresh changed its block footprint")
        buf = np.zeros((len(bids), self.adapter_block_bytes), np.uint8)
        buf.reshape(-1)[:flat.size] = flat
        self._adapter_pool = _adapter_write(
            self._adapter_pool, jnp.asarray(bids, jnp.int32),
            jnp.asarray(buf))
        self._adapter_bytes[name] = int(flat.size)
        self._adapter_touch(name)

    def adapter_free(self, name: str):
        """Drop an adapter's pool residency: decref every table block back
        to the shared free list."""
        for bid in self.adapter_tables.pop(name, []):
            self.allocator.decref(bid)
        self._adapter_bytes.pop(name, None)
        self._adapter_lru.pop(name, None)

    def adapter_pin(self, name: str):
        """Pin an adapter against shedding while any scheduled row uses it.
        Pins are counted by NAME, so pinning before (or without) pool
        residency is valid — a later admit is born protected."""
        self._adapter_pins[name] = self._adapter_pins.get(name, 0) + 1

    def adapter_unpin(self, name: str):
        n = self._adapter_pins.get(name, 0) - 1
        if n <= 0:
            self._adapter_pins.pop(name, None)
        else:
            self._adapter_pins[name] = n

    def _shed_adapter(self, protect: frozenset = frozenset()) -> bool:
        """Evict one unpinned adapter's pool blocks.  Victim order:
        REDUNDANT copies first (``adapter_redundant_fn``: bank-materialized
        and clean — dropping the pool copy costs nothing while the bank
        copy lives), then coldest LRU.  ``on_adapter_shed`` fires before
        the blocks are freed so the owner can archive a dirty payload.
        Pinned adapters are never candidates — a pinned adapter block can
        be neither shed nor lent (it is already allocated; lending only
        hands out FREE blocks)."""
        cands = [n for n in self.adapter_tables
                 if n not in protect and not self._adapter_pins.get(n, 0)]
        if not cands:
            return False
        redundant = ([n for n in cands if self.adapter_redundant_fn(n)]
                     if self.adapter_redundant_fn is not None else [])
        pool = redundant or cands
        victim = min(pool, key=lambda n: self._adapter_lru.get(n, 0))
        if self.on_adapter_shed is not None:
            self.on_adapter_shed(victim)
        self.adapter_free(victim)
        self.adapter_sheds += 1
        return True

    def _shed_any(self, protect_blocks: frozenset = frozenset(),
                  protect_adapters: frozenset = frozenset()) -> bool:
        """One unit of cache pressure: shed an idle index block if any,
        else an unpinned adapter.  Index blocks go first — re-admitting a
        shed adapter costs one H2D transfer; recomputing a shed prefix
        block costs a prefill pass, but the index's hit-aging already
        orders those well and adapters tend to be the hotter working
        set."""
        return (self._shed_one(protect=protect_blocks)
                or self._shed_adapter(protect=protect_adapters))

    def flush_adapters(self) -> int:
        """Shed every unpinned resident adapter (drain/leak checks).
        Returns adapters shed."""
        n = 0
        while self._shed_adapter():
            n += 1
        return n

    def import_block(self, key: str, src: "PagedCacheManager",
                     src_bid: int) -> Optional[int]:
        """Fetch one content-addressed block from a sibling manager's pool
        into this one (the fleet remote-fetch path): allocate a local
        block, copy the K/V payload across pools, and publish it into the
        LOCAL index under the same key — from then on it is
        indistinguishable from a locally-computed published block (ref == 1
        index-only cache: adoptable by ``try_admit``, sheddable under
        pressure, counted by ``reclaimable_blocks``/``pristine``).

        The key is the content identity, so the copied payload is exactly
        what local recompute would have produced (published blocks are
        CoW-immutable at the source).  Import spends only truly spendable
        capacity — it is a cache fill, never worth a reservation violation
        or a preemption — shedding idle index entries first and returning
        None when the pool cannot take the block (the caller falls back to
        recompute).  Returns the local block id."""
        if not self.hash_dedup:
            return None
        got = self._index.get(key)
        if got is not None:
            return got                       # already resident locally
        while self._index and self.free_blocks <= 0:
            if not self._shed_one():
                break
        if self.free_blocks <= 0:
            return None
        bid = self.allocator.alloc()
        if bid is None:                      # free_blocks > 0 => n_free > 0
            raise KVAccountingError(
                "spendable budget positive but the pool has no free block")
        self.cache = _copy_block_from(self.cache, src.cache,
                                      jnp.int32(src_bid), jnp.int32(bid))
        # alloc's ref of 1 IS the index's hold: 0 table holders + 1 index
        # entry, exactly the accounting of a locally published idle block
        self._index[key] = bid
        self._hashed[bid] = key
        self._hits.setdefault(key, 0)
        self.remote_imports += 1
        if self.host_pool is not None:
            # the remote copy supersedes any stale host-demoted copy (two-
            # tier disjointness: one tier per key)
            self.host_pool.drop_demoted(key)
        if self.on_publish is not None:
            self.on_publish(key, bid)
        return bid

    # -- tiered host memory (swap-to-host preemption + demote/rehydrate) -----
    @property
    def kv_d2h_bytes(self) -> int:
        """Cumulative device-to-host KV payload bytes (swap-outs +
        demotions) — the engine charges per-tick deltas to the clock."""
        return self.kv_swap_out_bytes + self.kv_demote_bytes

    @property
    def kv_h2d_bytes(self) -> int:
        """Cumulative host-to-device KV payload bytes (restores +
        rehydrations)."""
        return self.kv_restore_bytes + self.kv_rehydrate_bytes

    def _encode_blocks(self, bids: Sequence[int]):
        """Gather ``bids``'s K/V payload D2H as host-resident numpy leaves,
        one per-layer dict of ``[n_periods, n, block_size, ...]`` arrays
        (STATE leaves excluded — they are request rows, not
        block-addressed), int8-quantized when the host tier is quantized.
        This is the swap/demote path, not the tick hot loop — the gathers
        happen once per preemption or shed."""
        idx = jnp.asarray(list(bids), jnp.int32)
        layers = []
        for d in self.cache["layers"]:
            ld = {}
            for k, v in d.items():
                if k in STATE_KEYS:
                    continue
                blk = v[:, idx]
                if self.host_quant:
                    q = quantize_leaf(blk)
                    ld[k] = {
                        "_q8": np.asarray(q["_q8"]),  # reprolint: sync-point
                        "_qs": np.asarray(q["_qs"]),  # reprolint: sync-point
                    }
                else:
                    ld[k] = np.asarray(blk)  # reprolint: sync-point
            layers.append(ld)
        return tuple(layers)

    def _decode_payload(self, layers):
        """Materialize stored host leaves back into device arrays of the
        pool leaf's dtype for the H2D scatter.  Quantized leaves dequantize
        here — NOT bit-exact, which is why the quant tier sits behind an
        explicit exactness-exempt flag."""
        out = []
        for d, ld in zip(self.cache["layers"], layers):
            dd = {}
            for k, arr in ld.items():
                if isinstance(arr, dict):
                    dd[k] = dequant_leaf(
                        {"_q8": jnp.asarray(arr["_q8"]),
                         "_qs": jnp.asarray(arr["_qs"])}, d[k].dtype)
                else:
                    dd[k] = jnp.asarray(arr)
            out.append(dd)
        return tuple(out)

    def swap_payload_blocks(self, slot: int) -> int:
        """Blocks a swap-out of ``slot`` would store: the leading table
        blocks covering its committed tokens.  The engine prices the
        decision (``swap_beats_recompute``) from this BEFORE committing to
        the D2H gather."""
        tokens = int(self.lens[slot])
        if tokens <= 0:
            return 0
        return min(-(-tokens // self.block_size),
                   len(self.tables.get(slot, ())))

    def surviving_blocks(self, slot: int, nb: Optional[int] = None) -> int:
        """Of ``slot``'s leading ``nb`` table blocks, how many stay
        device-resident through a ``free`` + swap-out de-publish: blocks
        some OTHER holder (a sibling table, or an index entry with >= 2
        adopters) keeps alive.  The swap decision must not charge their
        recompute — they would be re-adopted for free either way."""
        table = self.tables.get(slot, [])
        nb = len(table) if nb is None else min(nb, len(table))
        n = 0
        for bid in table[:nb]:
            holders = int(self.allocator.ref[bid]) - 1    # minus this table
            if bid in self._hashed:
                holders -= 1                              # minus the index
            if holders >= 1:
                n += 1
        return n

    def swap_out(self, slot: int) -> Optional[int]:
        """Preemption swap-out: D2H-copy the blocks covering ``slot``'s
        committed tokens into the host pool as a PINNED swap set, then
        de-publish this slot's private index entries (table + index is all
        that holds them, ref == 2) so the preemption actually reclaims
        them — and so a fleet mirror retracts keys whose payload now rides
        on a host buffer instead of a device block.  Restore re-publishes
        through the normal commit path.  Blocks other holders share are
        left published (they survive the free and cost the swap nothing).

        Returns the swap-set id to park on the victim request, or None
        when there is no host pool, nothing is committed, or the pool
        cannot pin the payload — the caller falls back to recompute
        preemption exactly as before.  The caller still ``free``s the
        slot."""
        if self.host_pool is None:
            return None
        nb = self.swap_payload_blocks(slot)
        if nb <= 0:
            return None
        nbytes = nb * self.host_block_bytes
        bids = self.tables[slot][:nb]
        entry = {"layers": self._encode_blocks(bids), "n": nb,
                 "tokens": int(self.lens[slot]), "bytes": nbytes}
        sid = self.host_pool.put_swap(entry)
        if sid is None:
            return None
        for bid in bids:
            key = self._hashed.get(bid)
            if key is not None and self.allocator.ref[bid] == 2:
                self._depublish(key)
        self.kv_swap_outs += 1
        self.kv_swap_out_bytes += nbytes
        return sid

    def restore_swap(self, slot: int, sid: int) -> int:
        """Re-admission H2D restore: scatter a swap set's payload into
        ``slot``'s freshly-admitted table and consume the set.  Positions
        inside the adopted shared run are SKIPPED — those blocks arrived
        by refcount, already hold exactly this content, and may be shared
        with live siblings (writing even a bit-identical payload into a
        shared block is a CoW violation; a dequantized one would corrupt
        them outright).  The restorable span is clipped one token short of
        the recorded prompt so suffix prefill always has a live query —
        at a decode-time preemption the stored length IS prompt - 1, so
        nothing is lost and the result is byte-identical to recompute.

        Restored full blocks re-publish at commit via ``_publish_upto``:
        the fleet mirror learns the keys again exactly when the local
        index does.  Returns the prompt tokens now covered by adopted +
        restored K/V — what suffix-only prefill may skip."""
        entry = self.host_pool.pop_swap(sid)
        table = self.tables[slot]
        shared = min(self.shared_count.get(slot, 0), len(table))
        tokens = min(entry["tokens"],
                     max(self._seq_len.get(slot, 1) - 1, 0))
        nb = min(-(-tokens // self.block_size) if tokens > 0 else 0,
                 entry["n"], len(table))
        if nb > shared:
            payload = self._decode_payload(entry["layers"])
            if shared or nb < entry["n"]:
                payload = tuple({k: v[:, shared:nb] for k, v in d.items()}
                                for d in payload)
            self.cache = _blocks_write(
                self.cache, jnp.asarray(table[shared:nb], jnp.int32),
                payload)
            self.kv_restores += 1
            self.kv_restore_bytes += (nb - shared) * self.host_block_bytes
        covered = min(tokens, nb * self.block_size)
        return max(covered, shared * self.block_size)

    def drop_swap(self, sid: Optional[int]) -> bool:
        """Release a swap set without restoring it — the victim failed (or
        was dropped) before re-admission, or the caller decided to
        recompute after all.  Idempotent-safe on unknown ids so failure
        paths cannot double-release."""
        if self.host_pool is None or sid is None:
            return False
        if self.host_pool.pop_swap(sid, missing_ok=True) is None:
            return False
        self.kv_swap_drops += 1
        return True

    def _rehydrate_wins(self) -> bool:
        """Cost gate for the demote/rehydrate tier: one block's D2H + H2D
        round-trip must beat recomputing its ``block_size`` tokens of
        prefill.  No cost model attached (tests constructing the manager
        directly) means transfers are modeled free and the tier always
        wins."""
        if self.cost is None:
            return True
        return swap_beats_recompute(self.host_block_bytes, self.block_size,
                                    self.cost)

    def _demote(self, key: str) -> bool:
        """Capture one about-to-be-shed index block's payload into the host
        pool's demoted cache under the SAME content key.  Cost-gated
        (pointless when the round-trip costs more than recomputing the
        block) and best-effort (the host LRU may refuse)."""
        if not self._rehydrate_wins():
            return False
        entry = {"layers": self._encode_blocks([self._index[key]]), "n": 1,
                 "tokens": self.block_size, "bytes": self.host_block_bytes}
        if not self.host_pool.put_demoted(key, entry):
            return False
        self.kv_demotions += 1
        self.kv_demote_bytes += self.host_block_bytes
        return True

    def _rehydrate(self, key: str,
                   protect: frozenset = frozenset()) -> Optional[int]:
        """Bring one demoted host block back H2D and publish it into the
        local index — the host-tier sibling of ``import_block``: the
        alloc's ref of 1 IS the index hold, ``on_publish`` re-announces
        the key to the fleet, and the host entry is REMOVED (move, not
        copy — a key is resident in exactly one tier).  Spends only truly
        spendable capacity (a cache fill is never worth a reservation
        violation), shedding idle cache first; returns None when the key
        is not demoted or the pool cannot take it (entry put back
        untouched)."""
        if self.host_pool is None or not self.hash_dedup:
            return None
        got = self._index.get(key)
        if got is not None:
            return got
        entry = self.host_pool.pop_demoted(key)
        if entry is None:
            return None
        # pop BEFORE shedding: the shed loop below may itself demote
        # blocks into the host LRU, which must not evict this entry out
        # from under us
        while (self.free_blocks <= 0
               and self._shed_any(protect_blocks=protect)):
            pass
        if self.free_blocks <= 0:
            self.host_pool.put_demoted(key, entry)
            return None
        bid = self.allocator.alloc()
        if bid is None:                      # free_blocks > 0 => n_free > 0
            raise KVAccountingError(
                "spendable budget positive but the pool has no free block")
        self.cache = _blocks_write(self.cache,
                                   jnp.asarray([bid], jnp.int32),
                                   self._decode_payload(entry["layers"]))
        self._index[key] = bid
        self._hashed[bid] = key
        self._hits.setdefault(key, 0)
        self.kv_rehydrations += 1
        self.kv_rehydrate_bytes += self.host_block_bytes
        if self.on_publish is not None:
            self.on_publish(key, bid)
        return bid

    def flush_host(self) -> int:
        """Drop every DEMOTED host entry (drain/leak checks: demoted blocks
        are cache; live swap sets are owned by waiting requests and must be
        restored or dropped through them).  Returns entries dropped."""
        if self.host_pool is None:
            return 0
        return self.host_pool.flush_demoted()

    # -- copy-on-write -------------------------------------------------------
    def ensure_writable(self, slot: int, pos: Optional[int] = None) -> int:
        """Guarantee the block holding ``pos`` (default: the next write at
        ``lens[slot]``) is exclusively owned; copy-on-write it if shared.
        Returns the (possibly new) block id."""
        p = int(self.lens[slot]) if pos is None else pos
        bi = p // self.block_size
        table = self.tables[slot]
        if bi >= len(table):                # allocate-on-demand growth
            self.grow(slot, p + 1)
        bid = table[bi]
        if not self.allocator.is_shared(bid):
            return bid
        # conservative gate: CoW must not spend blocks earmarked for
        # admitted requests' growth.  Over-admission lends those earmarks
        # out anyway, and a CoW fork is a MANDATORY write — spend any truly
        # free block and let preemption settle the debt if it comes due.
        # The shed loop uses the SAME spendable notion as the alloc below:
        # under lending, free_blocks sits <= 0 for long stretches while the
        # free list is non-empty, and shedding then would destroy exactly
        # the index-resident blocks that make preemption cheap.
        def _spendable():
            return (self.free_blocks if self.over_admit <= 1.0
                    else self.allocator.n_free)
        while _spendable() <= 0 and self._shed_any():
            pass
        new = self.allocator.alloc() if _spendable() > 0 else None
        if new is None:
            raise OutOfBlocksError("out of KV blocks during copy-on-write")
        self.cache = _copy_block(self.cache, jnp.int32(bid), jnp.int32(new))
        self.allocator.decref(bid)
        table[bi] = new
        # the fork de-publishes the slot's claim on this position: its key
        # chain must not extend past a block whose payload is about to
        # diverge from the hashed content (the index entry itself stays —
        # it names the ORIGINAL block, whose payload is untouched)
        chain = self._chains.get(slot)
        if chain is not None:
            del chain[bi:]
        self._touch_lent()
        return new

    # -- batch assembly ------------------------------------------------------
    def table_of(self, slot: int) -> np.ndarray:
        """Null-padded ``[nbt]`` int32 table for the batch."""
        t = np.zeros((self.nbt,), np.int32)
        bids = self.tables[slot]
        t[:len(bids)] = bids
        return t

    def write_table_of(self, slot: int) -> np.ndarray:
        """Prefill-write table: adopted prefix entries are nulled so prefill
        never rewrites blocks it does not exclusively own.  The adopted
        blocks already hold the publisher's K/V (same adapter + tokens +
        positions); rewriting them would be benign only if recompute were
        bitwise-identical, which batch-composition-dependent paths (MoE
        capacity dropping) do not guarantee."""
        t = self.table_of(slot)
        t[:self.shared_count.get(slot, 0)] = 0
        return t

    def dec_tables(self, active_slots) -> np.ndarray:
        """Decode-bucket tables ``[capacity, nbt]``.  Only ``active_slots``
        get their real tables: padding rows (and slots admitted this tick,
        which prefill in the same step) must stay on the null block so the
        dummy decode write cannot corrupt freshly-prefilled positions."""
        out = np.zeros((self.capacity, self.nbt), np.int32)
        for slot in active_slots:
            bids = self.tables[slot]
            out[slot, :len(bids)] = bids
        return out

    # -- step plumbing -------------------------------------------------------
    def step_cache(self):
        return self.cache

    def update(self, new_cache):
        self.cache = new_cache

    def commit_prefill(self, assignments: List[Tuple[int, int]],
                       lengths: List[int], src_base: Optional[int] = None):
        """Prefill K/V was written straight into the request's blocks via its
        table — committing is just the per-request *state* row copy (Mamba
        SSM/conv state, cross-attention K/V) plus length assignment, and the
        publication point for the prompt blocks the chunk filled (chunked
        prefill publishes as it goes, so a sibling admitted mid-prefill
        already adopts the committed span)."""
        if not assignments:
            return
        state = self._state_subtree()
        if state is not None:
            base = self.capacity if src_base is None else src_base
            src = jnp.asarray([base + i for i, _ in assignments])
            dst = jnp.asarray([s for _, s in assignments])
            self._merge_state(_commit(state, src, dst))
        for (_, slot), ln in zip(assignments, lengths):
            self.lens[slot] = ln
            self._publish_upto(slot)

    def _state_subtree(self):
        layers = tuple({k: d[k] for k in d if k in STATE_KEYS}
                       for d in self.cache["layers"])
        if not any(layers):
            return None
        return {"layers": layers}

    def _merge_state(self, state):
        merged = []
        for d, s in zip(self.cache["layers"], state["layers"]):
            nd = dict(d)
            nd.update(s)
            merged.append(nd)
        self.cache = {"layers": tuple(merged)}
