"""Engine-side cache management: dense rows (legacy) and paged blocks.

Two layout contracts with the model (``repro.models.model``):

**Dense** (``CacheManager``): the cache pytree has ``capacity + pf_capacity``
rows of ``s_max`` key/value slots each; rows ``[0, capacity)`` are the
persistent decode table, rows ``[Bd, Bd + Bp)`` receive each step's prefill
writes (``Bd`` is that tick's decode-bucket size).  After a step,
``commit_prefill`` copies freshly-prefilled rows into their assigned
decode-table slots.  HBM is sized for the worst case: every resident request
pays ``s_max`` slots whether it uses them or not.

**Paged** (``PagedCacheManager``): attention K/V lives in a flat pool of
fixed-size blocks ``[n_blocks, block_size, ...]``; each request owns a *block
table* (list of block ids).  Admission is a block budget, not a slot: a
request is admitted only when its projected life
``ceil(min(prompt + max_new [+ spec headroom], s_max) / block_size)`` fits
the pool (the S-LoRA unified-paging design, on TPU with static shapes), but
blocks are *allocated on demand*: admission allocates only the blocks the
prompt needs now, the rest stay a **reservation** (``reserved`` /
``reserved_debt``) that ``grow`` converts to real blocks as decoding
advances.  The debt is subtracted from the free count the scheduler sees, so
the admission gate can never hand out a block an admitted request will later
need.  ``truncate`` is the inverse mutation: speculative-decoding rollback
(and any other sequence shrink) releases now-unused tail blocks back to the
pool, re-crediting the reservation.  Block 0 is a reserved null block that
absorbs writes from padding rows.  Prefill writes land directly in the
request's blocks via the table carried in the batch — commit assigns table
entries instead of copying rows.  Only per-request *state* (Mamba SSM state,
conv tails, cross-attention K/V), which does not grow with sequence length,
still uses dense rows ``[0, capacity + pf_capacity)`` with the row-copy
commit.

Over-admission (``over_admit`` >= 1.0): the reservation gate above is
conservative — reserved-but-unfilled blocks are never lent out, so
worst-case-length requests strand pool capacity they may never claim.  With
``over_admit > 1`` the gate charges only a ``1 / over_admit`` slice of the
outstanding debt (``charged_debt``) and lends the rest to new admissions
(the vLLM/S-LoRA bet: most requests stop early).  The price is that a
within-reservation ``grow`` can now find the pool empty; instead of the
conservative mode's ``KVAccountingError`` it returns a short capacity — the
growth-failure signal the engine answers with recompute preemption (free a
victim's blocks, requeue it at the head of the waiting queue, re-prefill its
context suffix-only over whatever prefix blocks survived).

Prefix reuse: full blocks of a registered prompt prefix (same adapter, same
tokens, same positions) are shared across requests by refcount; a write into
a shared block goes through copy-on-write (``ensure_writable``).  On
``truncate`` a shared block is simply dereferenced — the registrar's (or any
sibling's) refcount keeps it alive, so rollback never destroys a shared
prefix (the CoW-unshare half of the speculation contract).
"""
from __future__ import annotations

import functools
import math
from collections import OrderedDict, deque
from typing import Deque, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.configs import ModelConfig
from repro.models.model import init_cache, init_paged_cache, STATE_KEYS


# cache leaves are [n_periods, n_rows, ...]: rows live on axis 1
@jax.jit
def _commit(tree, src_rows: jax.Array, dst_rows: jax.Array):
    def mv(x):
        return x.at[:, dst_rows].set(x[:, src_rows])
    return jax.tree_util.tree_map(mv, tree)


@jax.jit
def _zero_rows(tree, rows: jax.Array):
    def z(x):
        return x.at[:, rows].set(0.0)
    return jax.tree_util.tree_map(z, tree)


# donate the cache: every caller immediately replaces it with the result,
# and without aliasing a one-block logical copy would materialize the whole
# pool afresh
@functools.partial(jax.jit, donate_argnums=(0,))
def _copy_block(cache, src: jax.Array, dst: jax.Array):
    # pool leaves are [n_periods, n_blocks, block_size, ...]: copy axis 1.
    # state leaves are per-request rows, NOT block-addressed — leave them be.
    layers = tuple(
        {k: (v if k in STATE_KEYS else v.at[:, dst].set(v[:, src]))
         for k, v in d.items()}
        for d in cache["layers"])
    return {"layers": layers}


class KVAccountingError(RuntimeError):
    """A block-accounting invariant was violated: refcount misuse, or a
    within-reservation ``grow`` finding an empty pool under the conservative
    gate (which guarantees ``n_free >= debt``).  A real exception — not an
    ``assert`` — because these checks are load-bearing control flow and must
    survive ``python -O``."""


class OutOfBlocksError(RuntimeError):
    """The pool could not supply a block for a *mandatory* write (a
    copy-on-write fork, or within-reservation growth whose earmarked block
    was lent out by over-admission).  Not a bug: under ``over_admit > 1``
    this is the growth-failure signal the engine answers by preempting a
    resident request to reclaim capacity."""


def projected_blocks(prompt_len: int, max_new: int, block_size: int,
                     s_max: int) -> int:
    """Blocks a request reserves on admission: its whole projected life
    (prompt + generated tokens), clipped to the context limit.  The single
    source of truth for both the scheduler's admission gate and the
    manager's reservation."""
    tokens = min(prompt_len + max_new, s_max)
    return -(-tokens // block_size)


class CacheManager:
    """Dense slot-per-request cache (legacy layout; kept for sliding-window
    models and as the equivalence baseline for the paged path)."""

    def __init__(self, cfg: ModelConfig, capacity: int, pf_capacity: int,
                 s_max: int, dtype=None):
        self.cfg = cfg
        self.capacity = capacity          # decode-table rows
        self.pf_capacity = pf_capacity    # scratch rows for prefill buckets
        self.s_max = s_max
        self.cache = init_cache(cfg, capacity + pf_capacity, s_max, dtype)
        self._free: Deque[int] = deque(range(capacity))
        self.lens = np.zeros((capacity,), np.int64)   # absolute positions

    # -- slot lifecycle ------------------------------------------------------
    def alloc(self) -> Optional[int]:
        return self._free.popleft() if self._free else None

    def free(self, slot: int):
        self.lens[slot] = 0
        self._free.append(slot)

    @property
    def n_free(self) -> int:
        return len(self._free)

    def truncate(self, slot: int, new_len: int):
        """Roll the sequence back (speculation rollback).  Dense rows are
        position-indexed and masked by ``k_valid``, so stale K/V beyond
        ``new_len`` is simply invisible — only the length moves."""
        self.lens[slot] = new_len

    # -- step plumbing ---------------------------------------------------------
    def step_cache(self):
        return self.cache

    def update(self, new_cache):
        self.cache = new_cache

    def commit_prefill(self, assignments: List[Tuple[int, int]],
                       lengths: List[int], src_base: Optional[int] = None):
        """assignments: (pf_row_index_within_bucket, decode_slot).

        ``src_base`` is the decode-bucket size of the step that produced the
        prefill rows (the model writes prefill at rows ``[Bd, Bd + Bp)``);
        defaults to ``capacity`` for the full-table decode bucket.
        """
        if not assignments:
            return
        base = self.capacity if src_base is None else src_base
        src = jnp.asarray([base + i for i, _ in assignments])
        dst = jnp.asarray([s for _, s in assignments])
        self.cache = _commit(self.cache, src, dst)
        for (_, slot), ln in zip(assignments, lengths):
            self.lens[slot] = ln


# ---------------------------------------------------------------------------
# paged layout
# ---------------------------------------------------------------------------

class BlockAllocator:
    """Fixed-size KV-block free list with refcounts.

    Block 0 is reserved as the null block (never allocated): padding rows in
    the batch carry table entries of 0, so their writes land there harmlessly.
    """

    def __init__(self, n_blocks: int):
        assert n_blocks >= 2, "need at least one usable block beyond null"
        self.n_blocks = n_blocks
        self._free: Deque[int] = deque(range(1, n_blocks))
        self.ref = np.zeros((n_blocks,), np.int64)
        self.ref[0] = 1                   # null block is permanently held
        self.peak_used = 0

    @property
    def n_free(self) -> int:
        return len(self._free)

    @property
    def usable(self) -> int:
        return self.n_blocks - 1

    @property
    def n_used(self) -> int:
        return self.usable - self.n_free

    def can_alloc(self, n: int) -> bool:
        return n <= self.n_free

    def alloc(self) -> Optional[int]:
        if not self._free:
            return None
        bid = self._free.popleft()
        self.ref[bid] = 1
        self.peak_used = max(self.peak_used, self.n_used)
        return bid

    def alloc_many(self, n: int) -> Optional[List[int]]:
        if not self.can_alloc(n):
            return None
        return [self.alloc() for _ in range(n)]

    def incref(self, bid: int):
        if bid == 0 or self.ref[bid] <= 0:
            raise KVAccountingError(f"incref of dead block {bid}")
        self.ref[bid] += 1

    def decref(self, bid: int):
        if bid == 0 or self.ref[bid] <= 0:
            raise KVAccountingError(f"decref of dead block {bid}")
        self.ref[bid] -= 1
        if self.ref[bid] == 0:
            self._free.append(bid)

    def is_shared(self, bid: int) -> bool:
        return self.ref[bid] > 1


class PagedCacheManager:
    """Block-table paged KV cache + dense state rows.

    Engine-facing surface mirrors ``CacheManager`` (``alloc`` is replaced by
    ``try_admit`` which takes the request's projected token need), plus block
    bookkeeping: ``table_of``, ``dec_tables``, ``ensure_writable`` (COW), and
    the prefix registry (``reuse``/``register`` inside ``try_admit`` /
    ``register_prefix``).
    """

    def __init__(self, cfg: ModelConfig, capacity: int, pf_capacity: int,
                 s_max: int, block_size: int = 32, n_blocks: int = 0,
                 over_admit: float = 1.0, dtype=None):
        if cfg.sliding_window > 0:
            raise ValueError("paged cache does not support sliding windows; "
                             "use the dense CacheManager")
        if over_admit < 1.0:
            raise ValueError("over_admit is a lending factor >= 1.0")
        self.cfg = cfg
        self.over_admit = float(over_admit)
        self.lent_blocks_peak = 0
        self.capacity = capacity          # state rows == max concurrent reqs
        self.pf_capacity = pf_capacity
        self.s_max = s_max
        self.block_size = block_size
        self.nbt = -(-s_max // block_size)          # table width (blocks/req)
        if n_blocks <= 0:
            # never more constrained than the dense layout by default
            n_blocks = 1 + capacity * self.nbt
        self.allocator = BlockAllocator(n_blocks)
        self.cache = init_paged_cache(cfg, n_blocks, block_size,
                                      capacity + pf_capacity, dtype)
        self._free_slots: Deque[int] = deque(range(capacity))
        self.lens = np.zeros((capacity,), np.int64)
        self.tables: Dict[int, List[int]] = {}      # state slot -> block ids
        self.shared_count: Dict[int, int] = {}      # leading shared blocks
        # blocks earmarked for a slot's projected life beyond what it holds
        # now (allocate-on-demand): the gate must not spend these
        self.reserved: Dict[int, int] = {}          # slot -> reserved blocks
        self._debt = 0                              # sum of unfilled reserves
        # prefix_id -> (adapter, prefix tokens, block ids); ordered for LRU
        self._prefixes: "OrderedDict[str, Tuple[str, np.ndarray, List[int]]]" \
            = OrderedDict()

    # -- budget --------------------------------------------------------------
    @property
    def n_free(self) -> int:
        return len(self._free_slots)

    @property
    def charged_debt(self) -> int:
        """Reservation debt the admission gate actually charges.  The
        conservative gate (``over_admit == 1``) charges all of it; a lending
        gate charges only a ``1 / over_admit`` slice and lends the rest out,
        betting that admitted requests rarely all reach their worst-case
        length at once — ``grow`` failures (and the engine's recompute
        preemption) cover the bet when it loses."""
        return math.ceil(self._debt / self.over_admit)

    @property
    def free_blocks(self) -> int:
        """Blocks the admission gate may spend: the allocator's free list
        minus the *charged* reservation debt of already-admitted requests
        (blocks they will ``grow`` into later).  Negative while lent-out
        reservations are actually being claimed."""
        return self.allocator.n_free - self.charged_debt

    @property
    def reserved_debt(self) -> int:
        return self._debt

    @property
    def lent_blocks(self) -> int:
        """Reservation-debt blocks not currently backed by the free list —
        capacity the gate has *actually* lent out (0 under the conservative
        gate, which keeps ``n_free >= debt`` invariant)."""
        return max(self._debt - self.allocator.n_free, 0)

    def _touch_lent(self):
        self.lent_blocks_peak = max(self.lent_blocks_peak, self.lent_blocks)

    @property
    def total_blocks(self) -> int:
        return self.allocator.usable

    def projected_blocks(self, prompt_len: int, max_new: int) -> int:
        return projected_blocks(prompt_len, max_new, self.block_size,
                                self.s_max)

    def _debt_of(self, slot: int) -> int:
        return max(self.reserved.get(slot, 0) - len(self.tables[slot]), 0)

    @property
    def reclaimable_blocks(self) -> int:
        """Blocks held only by the prefix registry — sheddable on demand by
        ``try_admit``.  The scheduler's admission gate must count these as
        available, or registry-held prefixes starve admission forever."""
        return int(sum(1 for _, _, bids in self._prefixes.values()
                       for bid in bids if self.allocator.ref[bid] == 1))

    # -- admission -----------------------------------------------------------
    def _lookup_shared(self, prompt: np.ndarray, adapter: str,
                       prefix_id: str, touch: bool = False) -> List[int]:
        """Registered prefix blocks this prompt can reuse: the LONGEST run
        of leading full blocks whose tokens match (same adapter too — K/V
        depend on the LoRA).  A prompt that diverges from the registered
        template mid-way still shares the blocks before the divergence.
        Capped so at least ONE prompt token is always left uncached:
        suffix-only prefill needs a live query to produce the first-token
        logits, and that token's K/V write must never land in a block the
        registry still owns."""
        if not prefix_id or prefix_id not in self._prefixes:
            return []
        p_adapter, p_toks, p_bids = self._prefixes[prefix_id]
        bs = self.block_size
        n_cap = min(len(p_bids), max(len(prompt) - 1, 0) // bs)
        if p_adapter != adapter or n_cap == 0:
            return []
        eq = (p_toks[:n_cap * bs] == np.asarray(prompt)[:n_cap * bs]) \
            .reshape(n_cap, bs).all(axis=1)
        n_full = int(np.argmin(eq)) if not eq.all() else n_cap
        if n_full == 0:
            return []
        if touch:
            self._prefixes.move_to_end(prefix_id)         # LRU touch
        return p_bids[:n_full]

    def fresh_need(self, prompt_len: int, max_new: int, prompt: np.ndarray,
                   adapter: str = "", prefix_id: str = "",
                   headroom: int = 0) -> int:
        """The request's charge against the gate's ``free + reclaimable``
        budget.  Shared blocks with ref >= 2 cost nothing; shared blocks held
        only by the registry (ref == 1) are discounted from *need* but were
        also counted reclaimable, so they must still be charged — otherwise
        the gate admits requests the manager then refuses.  ``headroom`` is
        extra projected tokens (speculative-decoding transient drafts)."""
        shared = self._lookup_shared(prompt, adapter, prefix_id)
        held_elsewhere = sum(1 for b in shared if self.allocator.ref[b] >= 2)
        return (self.projected_blocks(prompt_len, max_new + headroom)
                - held_elsewhere)

    def reused_tokens(self, prompt: np.ndarray, adapter: str = "",
                      prefix_id: str = "") -> int:
        """Prompt tokens a registered prefix would serve from shared K/V —
        the span suffix-only prefill skips.  Pure preview (no LRU touch);
        the scheduler charges only ``prompt_len - reused_tokens`` against
        its prefill-token budget."""
        return len(self._lookup_shared(np.asarray(prompt), adapter,
                                       prefix_id)) * self.block_size

    def try_admit(self, prompt: np.ndarray, max_new: int, adapter: str = "",
                  prefix_id: str = "",
                  headroom: int = 0) -> Optional[Tuple[int, int]]:
        """Reserve a state slot + the request's projected block budget
        (sharing registered prefix blocks when ``prefix_id`` matches), but
        only *allocate* the blocks the prompt needs now — the remainder is a
        reservation ``grow`` fills on demand.  ``headroom`` adds transient
        speculative-draft tokens to the projected budget.  Returns
        ``(state slot, reused prefix tokens)`` — the reused span is the
        leading prompt tokens whose K/V arrived by refcount instead of
        recompute, i.e. what suffix-only prefill may skip — or None when
        slots or spendable blocks are exhausted."""
        if not self._free_slots:
            return None
        need = self.projected_blocks(len(prompt), max_new + headroom)
        shared = self._lookup_shared(prompt, adapter, prefix_id, touch=True)
        # blocks that must exist before prefill writes: the whole prompt
        now_need = min(self.projected_blocks(len(prompt), 0), need)
        fresh_need = need - len(shared)          # lifetime charge at the gate
        fresh_now = max(now_need - len(shared), 0)
        if fresh_need > self.free_blocks:
            # shed idle prefixes (oldest first) to make room
            while self._prefixes and fresh_need > self.free_blocks:
                if not self._drop_oldest_prefix(keep=prefix_id if shared
                                                else ""):
                    break
            if fresh_need > self.free_blocks:
                return None
        for bid in shared:
            self.allocator.incref(bid)
        fresh = self.allocator.alloc_many(fresh_now)
        if fresh is None:       # gate passed but the pool cannot back it:
            raise KVAccountingError(  # free_blocks <= n_free was violated
                "admission gate passed but the pool cannot back the prompt")
        slot = self._free_slots.popleft()
        self.tables[slot] = shared + fresh
        self.shared_count[slot] = len(shared)
        self.reserved[slot] = max(need, len(self.tables[slot]))
        self._debt += self._debt_of(slot)
        self.lens[slot] = 0
        self._touch_lent()
        return slot, len(shared) * self.block_size

    def free(self, slot: int):
        self._debt -= self._debt_of(slot)
        self.reserved.pop(slot, None)
        for bid in self.tables.pop(slot, []):
            self.allocator.decref(bid)
        self.shared_count.pop(slot, None)
        self.lens[slot] = 0
        self._free_slots.append(slot)

    # -- sequence growth / rollback ------------------------------------------
    def grow(self, slot: int, new_len: int) -> int:
        """Extend ``slot``'s table to cover ``new_len`` tokens.  Under the
        conservative gate, growth within the slot's reservation always
        succeeds (the debt accounting guarantees the blocks exist) and an
        empty pool there raises ``KVAccountingError``.  Under over-admission
        the earmarked block may have been lent out: growth stops early and
        the SHORT RETURN VALUE is the failure signal — the engine compares
        the returned token capacity against what it must write and preempts
        a resident request when the committed token no longer fits.  Growth
        beyond the reservation (speculative drafts past the projected life)
        is best-effort from the spendable pool in either mode."""
        table = self.tables[slot]
        target = min(-(-new_len // self.block_size), self.nbt)
        while len(table) < target:
            within = len(table) < self.reserved.get(slot, 0)
            if not within and self.free_blocks <= 0:
                break                       # transient overshoot, pool dry
            d0 = self._debt_of(slot)
            bid = self.allocator.alloc()
            # shedding an idle registry prefix (ref == 1) is free compared
            # with the alternatives — a KVAccountingError here or, under
            # lending, an engine preemption that recomputes a whole context
            while bid is None and self._drop_oldest_prefix():
                bid = self.allocator.alloc()
            if bid is None:
                if within and self.over_admit <= 1.0:
                    raise KVAccountingError(
                        "reservation debt accounting violated: within-"
                        "reservation grow found an empty pool under the "
                        "conservative gate")
                break                       # lent-out reservation: growth
            #                                 fails, engine preempts
            table.append(bid)
            self._debt += self._debt_of(slot) - d0
        self._touch_lent()
        return min(len(table) * self.block_size, self.s_max)

    def truncate(self, slot: int, new_len: int):
        """Roll ``slot`` back to ``new_len`` tokens (speculation rollback):
        release table blocks past the new length back to the pool, restoring
        the slot's reservation debt.  Shared (prefix/CoW) blocks are only
        dereferenced — the registry's or a sibling's refcount keeps them
        alive, so rollback never destroys shared state."""
        new_len = max(int(new_len), 0)
        table = self.tables[slot]
        nb = -(-new_len // self.block_size)
        if nb < len(table):
            d0 = self._debt_of(slot)
            dropped = len(table) - nb
            freed = 0
            for bid in table[nb:]:
                self.allocator.decref(bid)
                if self.allocator.ref[bid] == 0:
                    freed += 1
            del table[nb:]
            self.shared_count[slot] = min(self.shared_count.get(slot, 0), nb)
            # a dropped block other holders keep alive never re-enters the
            # free list, so the slot's re-grow claim on that position is
            # surrendered with it — re-crediting the full drop would make
            # the debt exceed the blocks actually available and break
            # grow()'s within-reservation guarantee
            self.reserved[slot] = max(
                self.reserved.get(slot, 0) - (dropped - freed), len(table))
            self._debt += self._debt_of(slot) - d0
        self.lens[slot] = new_len

    def prepare_write(self, slot: int, start: int, n: int) -> int:
        """Make positions ``[start, start + n)`` writable: grow the table to
        cover them and copy-on-write every shared block in the range.
        Returns how many of the ``n`` tokens can actually be written (less
        than ``n`` only when drafts overshoot a dry pool)."""
        cap = self.grow(slot, start + n)
        end = min(start + n, cap)
        if end <= start:
            return 0
        for bi in range(start // self.block_size,
                        (end - 1) // self.block_size + 1):
            self.ensure_writable(slot, pos=bi * self.block_size)
        return end - start

    # -- prefix registry -----------------------------------------------------
    def register_prefix(self, prefix_id: str, slot: int, prompt: np.ndarray,
                        adapter: str = ""):
        """Publish the full blocks of ``slot``'s prompt for reuse.  The
        registry holds its own refcount, so the blocks outlive the request."""
        if not prefix_id or prefix_id in self._prefixes:
            return
        # clamp to blocks the table still holds: a slot truncated (or only
        # partially grown) below the prompt's full-block span must register
        # the span it can actually vouch for — an over-long (or empty)
        # block list would poison lookups and wedge the shed loop
        n_full = min(len(prompt) // self.block_size, len(self.tables[slot]))
        if n_full == 0:
            return
        bids = self.tables[slot][:n_full]
        for bid in bids:
            self.allocator.incref(bid)
        self._prefixes[prefix_id] = (adapter,
                                     np.asarray(prompt)[:n_full *
                                                        self.block_size]
                                     .copy(), bids)

    def _drop_oldest_prefix(self, keep: str = "") -> bool:
        """Shed the oldest prefix registration that would actually free at
        least one block (some block at ref == 1).  Dropping a prefix whose
        blocks are all still held by active consumers frees nothing and
        only destroys reusable sharing metadata."""
        for pid, (_, _, bids) in self._prefixes.items():
            if pid == keep:
                continue
            if not bids or any(self.allocator.ref[b] == 1 for b in bids):
                self._prefixes.pop(pid)
                for bid in bids:
                    self.allocator.decref(bid)
                return True
        return False

    @property
    def prefixes(self) -> List[str]:
        return list(self._prefixes)

    # -- copy-on-write -------------------------------------------------------
    def ensure_writable(self, slot: int, pos: Optional[int] = None) -> int:
        """Guarantee the block holding ``pos`` (default: the next write at
        ``lens[slot]``) is exclusively owned; copy-on-write it if shared.
        Returns the (possibly new) block id."""
        p = int(self.lens[slot]) if pos is None else pos
        bi = p // self.block_size
        table = self.tables[slot]
        if bi >= len(table):                # allocate-on-demand growth
            self.grow(slot, p + 1)
        bid = table[bi]
        if not self.allocator.is_shared(bid):
            return bid
        # conservative gate: CoW must not spend blocks earmarked for
        # admitted requests' growth.  Over-admission lends those earmarks
        # out anyway, and a CoW fork is a MANDATORY write — spend any truly
        # free block and let preemption settle the debt if it comes due.
        # The shed loop uses the SAME spendable notion as the alloc below:
        # under lending, free_blocks sits <= 0 for long stretches while the
        # free list is non-empty, and shedding then would destroy exactly
        # the registry-resident prefixes that make preemption cheap.
        def _spendable():
            return (self.free_blocks if self.over_admit <= 1.0
                    else self.allocator.n_free)
        while self._prefixes and _spendable() <= 0:
            if not self._drop_oldest_prefix():
                break
        new = self.allocator.alloc() if _spendable() > 0 else None
        if new is None:
            raise OutOfBlocksError("out of KV blocks during copy-on-write")
        self.cache = _copy_block(self.cache, jnp.int32(bid), jnp.int32(new))
        self.allocator.decref(bid)
        table[bi] = new
        self._touch_lent()
        return new

    # -- batch assembly ------------------------------------------------------
    def table_of(self, slot: int) -> np.ndarray:
        """Null-padded ``[nbt]`` int32 table for the batch."""
        t = np.zeros((self.nbt,), np.int32)
        bids = self.tables[slot]
        t[:len(bids)] = bids
        return t

    def write_table_of(self, slot: int) -> np.ndarray:
        """Prefill-write table: shared prefix entries are nulled so prefill
        never rewrites blocks it does not exclusively own.  The shared
        blocks already hold the registrar's K/V (same adapter + tokens +
        positions); rewriting them would be benign only if recompute were
        bitwise-identical, which batch-composition-dependent paths (MoE
        capacity dropping) do not guarantee."""
        t = self.table_of(slot)
        t[:self.shared_count.get(slot, 0)] = 0
        return t

    def dec_tables(self, active_slots) -> np.ndarray:
        """Decode-bucket tables ``[capacity, nbt]``.  Only ``active_slots``
        get their real tables: padding rows (and slots admitted this tick,
        which prefill in the same step) must stay on the null block so the
        dummy decode write cannot corrupt freshly-prefilled positions."""
        out = np.zeros((self.capacity, self.nbt), np.int32)
        for slot in active_slots:
            bids = self.tables[slot]
            out[slot, :len(bids)] = bids
        return out

    # -- step plumbing -------------------------------------------------------
    def step_cache(self):
        return self.cache

    def update(self, new_cache):
        self.cache = new_cache

    def commit_prefill(self, assignments: List[Tuple[int, int]],
                       lengths: List[int], src_base: Optional[int] = None):
        """Prefill K/V was written straight into the request's blocks via its
        table — committing is just the per-request *state* row copy (Mamba
        SSM/conv state, cross-attention K/V) plus length assignment."""
        if not assignments:
            return
        state = self._state_subtree()
        if state is not None:
            base = self.capacity if src_base is None else src_base
            src = jnp.asarray([base + i for i, _ in assignments])
            dst = jnp.asarray([s for _, s in assignments])
            self._merge_state(_commit(state, src, dst))
        for (_, slot), ln in zip(assignments, lengths):
            self.lens[slot] = ln

    def _state_subtree(self):
        layers = tuple({k: d[k] for k in d if k in STATE_KEYS}
                       for d in self.cache["layers"])
        if not any(layers):
            return None
        return {"layers": layers}

    def _merge_state(self, state):
        merged = []
        for d, s in zip(self.cache["layers"], state["layers"]):
            nd = dict(d)
            nd.update(s)
            merged.append(nd)
        self.cache = {"layers": tuple(merged)}
