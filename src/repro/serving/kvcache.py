"""Engine-side cache management.

Layout contract with the model (``repro.models.model``): the cache pytree has
``capacity + pf_capacity`` rows; rows ``[0, capacity)`` are the persistent
decode table, rows ``[capacity, capacity + Bp)`` receive each step's prefill
writes.  After a step, ``commit_prefill`` copies freshly-prefilled rows into
their assigned decode-table slots (one fused jit'd gather/scatter).

This is the static-shape TPU replacement for GPU paged attention: slots are
fixed-size rows, admission is slot allocation, eviction is slot release.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.configs import ModelConfig
from repro.models.model import init_cache


@jax.jit
def _commit(tree, src_rows: jax.Array, dst_rows: jax.Array):
    def mv(x):
        return x.at[dst_rows].set(x[src_rows])
    return jax.tree_util.tree_map(mv, tree)


@jax.jit
def _zero_rows(tree, rows: jax.Array):
    def z(x):
        return x.at[rows].set(0.0)
    return jax.tree_util.tree_map(z, tree)


class CacheManager:
    def __init__(self, cfg: ModelConfig, capacity: int, pf_capacity: int,
                 s_max: int, dtype=None):
        self.cfg = cfg
        self.capacity = capacity          # decode-table rows
        self.pf_capacity = pf_capacity    # scratch rows for prefill buckets
        self.s_max = s_max
        self.cache = init_cache(cfg, capacity + pf_capacity, s_max, dtype)
        self._free: List[int] = list(range(capacity))
        self.lens = np.zeros((capacity,), np.int64)   # absolute positions

    # -- slot lifecycle ------------------------------------------------------
    def alloc(self) -> Optional[int]:
        return self._free.pop(0) if self._free else None

    def free(self, slot: int):
        self.lens[slot] = 0
        self._free.append(slot)

    @property
    def n_free(self) -> int:
        return len(self._free)

    # -- step plumbing ---------------------------------------------------------
    def step_cache(self):
        return self.cache

    def update(self, new_cache):
        self.cache = new_cache

    def commit_prefill(self, assignments: List[Tuple[int, int]],
                       lengths: List[int]):
        """assignments: (pf_row_index_within_bucket, decode_slot)."""
        if not assignments:
            return
        src = jnp.asarray([self.capacity + i for i, _ in assignments])
        dst = jnp.asarray([s for _, s in assignments])
        self.cache = _commit(self.cache, src, dst)
        for (_, slot), ln in zip(assignments, lengths):
            self.lens[slot] = ln
