"""Inference request lifecycle."""
from __future__ import annotations

import dataclasses
import enum
from typing import List, Optional

import numpy as np


class State(enum.Enum):
    WAITING = "waiting"
    PREFILL = "prefill"
    DECODE = "decode"
    DONE = "done"
    FAILED = "failed"       # dropped (e.g. SLO-expired before admission)


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray                 # [L] int32
    adapter: str                       # adapter name ("" = base model)
    max_new_tokens: int = 64
    arrival: float = 0.0               # submit time (clock units)
    eos_token: int = -1                # -1 = never stop early
    aux_embed: Optional[np.ndarray] = None
    prefix_id: str = ""                # shared-prompt handle: requests with
    # the same (prefix_id, adapter) and identical leading tokens share the
    # full KV blocks of that prefix by refcount (paged layout only)
    draft_suffix: Optional[np.ndarray] = None  # reference token stream
    # (prompt + expected output) for the static-suffix drafter (trace replay)

    state: State = State.WAITING
    output: List[int] = dataclasses.field(default_factory=list)
    t_first_token: Optional[float] = None
    t_finish: Optional[float] = None
    token_times: List[float] = dataclasses.field(default_factory=list)
    dec_slot: int = -1                 # decode-table row while active
    prefilled: int = 0                 # prompt tokens whose K/V is already in
    # the cache (reused shared prefix + committed prefill chunks); the
    # request leaves PREFILL when this reaches prompt_len

    @property
    def prompt_len(self) -> int:
        return int(len(self.prompt))

    @property
    def done(self) -> bool:
        return self.state in (State.DONE, State.FAILED)

    def waiting_time(self) -> Optional[float]:
        if self.t_first_token is None:
            return None
        return self.t_first_token - self.arrival

    def decode_latencies(self) -> np.ndarray:
        if len(self.token_times) < 2:
            return np.zeros((0,))
        return np.diff(np.asarray(self.token_times))
