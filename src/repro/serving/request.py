"""Inference request lifecycle."""
from __future__ import annotations

import dataclasses
import enum
from typing import List, Optional

import numpy as np


class State(enum.Enum):
    WAITING = "waiting"
    PREFILL = "prefill"
    DECODE = "decode"
    DONE = "done"
    FAILED = "failed"       # dropped (e.g. SLO-expired before admission)


# Per-request priority classes (tiered KV memory): rank 0 preempts LAST
# and its reservation debt is never lent out; rank 2 preempts FIRST and
# lends first under over-admission.  "standard" is the default everywhere,
# under which every priority-aware order degenerates to the pre-class
# behavior byte-for-byte.
PRIORITY_CLASSES = ("interactive", "standard", "batch")
PRIORITY_RANK = {c: i for i, c in enumerate(PRIORITY_CLASSES)}


def priority_rank(priority_class: str) -> int:
    """Victim/lending rank of a class (unknown classes rank as standard —
    a misspelled class must not silently become un-preemptable)."""
    return PRIORITY_RANK.get(priority_class, PRIORITY_RANK["standard"])


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray                 # [L] int32
    adapter: str                       # adapter name ("" = base model)
    max_new_tokens: int = 64
    arrival: float = 0.0               # submit time (clock units)
    eos_token: int = -1                # -1 = never stop early
    aux_embed: Optional[np.ndarray] = None
    # NOTE: cross-request KV reuse needs no caller-side handle — the paged
    # cache content-addresses full blocks (chained hash of adapter + tokens),
    # so identical prompt heads share automatically (engine ``hash_dedup``)
    draft_suffix: Optional[np.ndarray] = None  # reference token stream
    # (prompt + expected output) for the static-suffix drafter (trace replay)
    priority_class: str = "standard"   # "interactive" | "standard" | "batch":
    # shapes the preemption victim order (batch evicted first, interactive
    # last) and over-admission lending (batch debt lent first, interactive
    # debt never lent); orthogonal to the scheduler's fairness ramp

    state: State = State.WAITING
    output: List[int] = dataclasses.field(default_factory=list)
    t_first_token: Optional[float] = None
    t_finish: Optional[float] = None
    token_times: List[float] = dataclasses.field(default_factory=list)
    dec_slot: int = -1                 # decode-table row while active
    prefilled: int = 0                 # prompt tokens whose K/V is already in
    # the cache (reused shared prefix + committed prefill chunks); the
    # request leaves PREFILL when this reaches prompt_len
    preemptions: int = 0               # recompute-preemption count: each one
    # rolled the emitted tokens into ``prompt`` and requeued the request;
    # ``arrival``/``t_first_token`` are never reset, so preemption surfaces
    # as decode latency in the SLO accounting, not as a fresh request
    rolled: int = 0                    # leading ``output`` tokens already
    # rolled into ``prompt`` by preemption: a second preemption must append
    # only ``output[rolled:]`` (or the prompt would duplicate tokens), and
    # the drafter context is ``prompt + output[rolled:]``
    recount_pending: bool = False      # preempted and not yet re-prefilled:
    # the next admission charges its recomputed suffix to
    # ``Metrics.preempted_tokens_recomputed``
    adapter_retained: bool = False     # this request holds a retain (and,
    # under unified paging, a pool pin) on its adapter.  Kept across
    # preemption — evicting the victim's adapter while it waits at the
    # head of the queue would just swap it straight back (thrash) — and
    # dropped at finish/failure
    swap_sid: Optional[int] = None     # host-pool swap-set id while the
    # request waits preempted with its KV blocks swapped out (tiered KV
    # memory).  Consumed (restored H2D or dropped) at re-admission; must be
    # dropped explicitly if the request fails before it is ever re-admitted

    @property
    def class_rank(self) -> int:
        return priority_rank(self.priority_class)

    @property
    def prompt_len(self) -> int:
        return int(len(self.prompt))

    @property
    def remaining_new(self) -> int:
        """Tokens the request may still emit.  Equals ``max_new_tokens``
        until a preemption rolls already-emitted tokens into the prompt —
        admission must project the remainder, not the original budget,
        or a resumed request could double-reserve its own output."""
        return max(self.max_new_tokens - len(self.output), 0)

    @property
    def done(self) -> bool:
        return self.state in (State.DONE, State.FAILED)

    def waiting_time(self) -> Optional[float]:
        if self.t_first_token is None:
            return None
        return self.t_first_token - self.arrival

    def decode_latencies(self) -> np.ndarray:
        if len(self.token_times) < 2:
            return np.zeros((0,))
        return np.diff(np.asarray(self.token_times))
