"""Continuous-batching scheduler with mutable capacity allocation.

Each tick the scheduler decides (a) how many waiting requests to admit into
the prefill bucket and (b) how many fine-tuning microbatch rows to co-run.
The fine-tuning budget shrinks as inference load rises (decode occupancy +
queue pressure) and recovers when load drops — the paper's Figure-5
behaviour ("the fine-tuning task makes concessions for the inference task").
"""
from __future__ import annotations

import dataclasses
from typing import List

from repro.serving.request import Request


@dataclasses.dataclass(frozen=True)
class SchedulerConfig:
    max_prefill_per_tick: int = 4
    max_prefill_tokens: int = 4096     # token budget per prefill bucket
    ft_rows_max: int = 4               # fine-tuning rows when idle
    ft_token_budget: int = 2048        # cap ft tokens per tick
    concede_at_queue: int = 1          # waiting reqs at which ft fully yields


@dataclasses.dataclass
class Decision:
    admit: List[Request]
    ft_rows: int
    load: float


class Scheduler:
    def __init__(self, cfg: SchedulerConfig, capacity: int):
        self.cfg, self.capacity = cfg, capacity

    def decide(self, waiting: List[Request], n_active: int,
               n_free_slots: int, pf_capacity: int,
               trainers_pending: bool) -> Decision:
        c = self.cfg
        admit: List[Request] = []
        budget = c.max_prefill_tokens
        for r in waiting:
            if len(admit) >= min(c.max_prefill_per_tick, n_free_slots,
                                 pf_capacity):
                break
            if r.prompt_len > budget and admit:
                break
            admit.append(r)
            budget -= r.prompt_len

        occupancy = n_active / max(self.capacity, 1)
        queue_pressure = min(1.0, (len(waiting) - len(admit))
                             / max(c.concede_at_queue, 1))
        load = max(occupancy, queue_pressure)
        if not trainers_pending:
            ft_rows = 0
        else:
            ft_rows = int(round(c.ft_rows_max * (1.0 - load)))
            if len(waiting) - len(admit) >= c.concede_at_queue:
                ft_rows = 0
        return Decision(admit=admit, ft_rows=ft_rows, load=load)
