"""Continuous-batching scheduler with mutable capacity allocation.

Each tick the scheduler decides (a) how many waiting requests to admit into
the prefill bucket and (b) how many fine-tuning microbatch rows to co-run.
The fine-tuning budget shrinks as inference load rises (decode occupancy +
queue pressure) and recovers when load drops — the paper's Figure-5
behaviour ("the fine-tuning task makes concessions for the inference task").

Admission is a *memory* budget, not a slot count: under the paged KV layout
a request is admitted only if its projected block need (prompt + max new
tokens, in ``block_size`` units) fits the free pool, so short requests keep
flowing when long ones would have pinned whole dense rows.  The dense layout
degenerates to the old slot check (``free_blocks=None``).

Prefix-aware admission (``probe_fn``): with the content-hash dedup index
live, a request whose prompt head is already resident costs a fraction of a
cold request — its prefill skips the resident span and its block charge
drops by the adopted blocks.  The scheduler therefore scores waiting
requests by resident-prefix fraction and admits high-residency requests
first (the RadixAttention/SGLang insight: cache-aware scheduling compounds
the cache's win).  A fairness ramp bounds the reordering: a request's score
also rises with its queue wait and saturates at 1.0 — strictly above any
possible residency fraction — after ``prefix_ramp_s``, so a zero-residency
request can be passed over for at most the ramp window before it outranks
every fresh high-residency arrival (FIFO among ramped requests).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional

from repro.serving.kvcache import projected_blocks as _projected_blocks
from repro.serving.request import Request


@dataclasses.dataclass(frozen=True)
class SchedulerConfig:
    max_prefill_per_tick: int = 4
    max_prefill_tokens: int = 4096     # token budget per prefill bucket
    ft_rows_max: int = 4               # fine-tuning rows when idle
    ft_token_budget: int = 2048        # cap ft tokens per tick
    concede_at_queue: int = 1          # waiting reqs at which ft fully yields
    lent_full_yield: float = 0.25      # lent-debt fraction at which ft fully
    #                                    yields: over-admitted lending is a
    #                                    preemption precursor, so fine-tuning
    #                                    concedes BEFORE inference requests
    #                                    start getting preempted
    prefix_ramp_s: float = 1.0         # fairness ramp for prefix-aware
    #                                    admission: queue wait at which a
    #                                    cold (zero-residency) request's
    #                                    score saturates and it outranks any
    #                                    fresh high-residency arrival
    adapter_affinity: float = 0.35     # admission bonus for a request whose
    #                                    adapter needs no swap-in — already
    #                                    resident, OR being swapped in by an
    #                                    earlier admit THIS tick (same-
    #                                    adapter co-scheduling amortizes one
    #                                    H2D transfer).  Capped strictly
    #                                    below 1.0, so the fairness ramp's
    #                                    saturated wait still dominates


@dataclasses.dataclass
class Decision:
    admit: List[Request]
    ft_rows: int
    load: float
    probe_admissions: int = 0      # admits reordered ahead of an older
    #                                waiter by prefix residency this tick


def projected_blocks(r: Request, block_size: int, s_max: int,
                     headroom: int = 0) -> int:
    """Blocks the request reserves for its whole projected life (the
    manager's formula, on a Request); ``headroom`` adds transient
    speculative-draft tokens."""
    return _projected_blocks(r.prompt_len, r.max_new_tokens + headroom,
                             block_size, s_max)


class Scheduler:
    def __init__(self, cfg: SchedulerConfig, capacity: int):
        self.cfg, self.capacity = cfg, capacity

    def decide(self, waiting: List[Request], n_active: int,
               n_free_slots: int, pf_capacity: int,
               trainers_pending: bool, *,
               free_blocks: Optional[int] = None, total_blocks: int = 0,
               block_size: int = 0, s_max: int = 0,
               need_fn: Optional[Callable[[Request], int]] = None,
               spec_headroom: int = 0, pf_rows_used: int = 0,
               pf_token_budget: Optional[int] = None,
               suffix_fn: Optional[Callable[[Request], int]] = None,
               chunked: bool = False,
               lent_frac: float = 0.0,
               probe_fn: Optional[Callable[[Request], int]] = None,
               adapter_fn: Optional[Callable[[Request], bool]] = None,
               now: float = 0.0) -> Decision:
        """``need_fn`` (paged engines) returns the blocks a request would
        actually consume — projected blocks minus index-resident adopted
        blocks — so the gate mirrors what admission will really reserve.
        ``spec_headroom`` widens the fallback projection by the transient
        speculative-draft tokens a resident request may hold mid-verify.

        Prefix-aware accounting: ``suffix_fn`` returns the tokens prefill
        will actually *compute* for a request (prompt minus the resident
        shared-prefix span) — the token budget charges that, not the raw
        prompt length.  ``pf_rows_used``/``pf_token_budget`` subtract the
        bucket rows and tokens already claimed by in-flight partial-prefill
        chunks.  With ``chunked`` set, a long suffix no longer monopolizes
        a tick: admission charges only the first chunk (``min(suffix,
        remaining budget)``) and stops when the per-tick budget is spent —
        the engine feeds the rest as later chunks.

        Prefix-aware admission ORDER: ``probe_fn`` returns the resident
        prompt tokens the dedup index would serve; waiting requests are
        visited by ``max(residency fraction, wait / prefix_ramp_s)`` (see
        module docstring — the wait term saturates at 1.0, strictly above
        any residency fraction, so no request starves past the ramp).

        ``lent_frac`` is the fraction of outstanding reservation debt the
        over-admission gate has actually lent out (0 under the conservative
        gate).  Lending is the precursor of preemption, so it feeds the
        fine-tuning concession directly: ft rows ramp to zero by
        ``lent_full_yield`` — the trainer yields capacity *before* any
        inference request has to be preempted.

        Adapter-residency-aware admission (``adapter_fn``, unified adapter
        paging): ``adapter_fn(r)`` says whether the request's adapter needs
        no swap-in.  Warm requests earn ``adapter_affinity`` on top of
        their residency fraction (capped strictly below the ramp's
        saturation, so the starvation bound is untouched), and selection
        turns GREEDY: each pick re-scores the queue with the adapters of
        already-picked requests counted warm — so same-adapter waiters
        cluster into one tick and amortize a single swap-in, the LoRAFusion
        batching insight."""
        c = self.cfg
        admit: List[Request] = []
        remaining = list(waiting)
        ramp = max(c.prefix_ramp_s, 1e-9)
        pending_adapters: set = set()

        def score(r: Request) -> float:
            # residency fraction is < 1 by construction (at least one
            # prompt token is never cached), so a ramp-saturated wait
            # strictly dominates any fresh high-residency arrival
            resid = (probe_fn(r) / max(r.prompt_len, 1)
                     if probe_fn is not None else 0.0)
            if adapter_fn is not None and (
                    not r.adapter or adapter_fn(r)
                    or r.adapter in pending_adapters):
                resid = min(resid + c.adapter_affinity, 1.0 - 1e-9)
            return max(resid, min((now - r.arrival) / ramp, 1.0))

        reorder = (probe_fn is not None or adapter_fn is not None) \
            and len(waiting) > 1
        if reorder and adapter_fn is None:
            # static scores: one sort up front (the pre-paging behavior,
            # byte-identical ordering).  Priority class breaks score ties
            # only (interactive ahead of standard ahead of batch) — with
            # all-standard traffic the rank is a constant and the order is
            # exactly the pre-class one
            remaining.sort(key=lambda r: (-score(r), r.class_rank,
                                          r.arrival, r.rid))
        budget = (c.max_prefill_tokens if pf_token_budget is None
                  else pf_token_budget)
        row_cap = max(min(c.max_prefill_per_tick, n_free_slots,
                          pf_capacity) - pf_rows_used, 0)
        blocks_left = free_blocks
        while remaining:
            if len(admit) >= row_cap:
                break
            if reorder and adapter_fn is not None:
                # greedy: every pick can warm its adapter for the rest of
                # the queue, so scores are recomputed per pick (the queue
                # is tick-bounded; this is O(n^2 log n) over a small n)
                remaining.sort(key=lambda r: (-score(r), r.class_rank,
                                              r.arrival, r.rid))
            r = remaining[0]
            tok = suffix_fn(r) if suffix_fn is not None else r.prompt_len
            if chunked:
                if budget <= 0:
                    break
                tok = min(tok, budget)
            elif tok > budget and admit:
                break
            if blocks_left is not None:
                need = (need_fn(r) if need_fn is not None
                        else projected_blocks(r, block_size, s_max,
                                              headroom=spec_headroom))
                if need > blocks_left:
                    break              # memory-bound: stop admitting this tick
                blocks_left -= need
            admit.append(r)
            remaining.pop(0)
            if r.adapter:
                pending_adapters.add(r.adapter)
            # an over-budget FIRST request still runs (unchunked prefill
            # cannot split it), but its charge is clamped to the budget it
            # actually had — a negative balance would wrongly veto requests
            # whose suffix is fully cached (0 computed tokens) and disagree
            # with the chunked boundary, which never over-charges
            budget = max(budget - tok, 0)

        probe_admissions = 0
        if reorder and admit:
            admitted = set(id(r) for r in admit)
            passed = [w for w in waiting if id(w) not in admitted]
            probe_admissions = sum(
                1 for r in admit
                if any((w.arrival, w.rid) < (r.arrival, r.rid)
                       for w in passed))

        occupancy = n_active / max(self.capacity, 1)
        if free_blocks is not None and total_blocks > 0:
            # free_blocks goes negative while over-admitted lending is
            # claimed; occupancy saturates at 1 rather than overshooting
            occupancy = max(occupancy,
                            min(1.0, 1.0 - (free_blocks / total_blocks)))
        queue_pressure = min(1.0, (len(waiting) - len(admit))
                             / max(c.concede_at_queue, 1))
        lent_load = min(1.0, lent_frac / max(c.lent_full_yield, 1e-9))
        load = max(occupancy, queue_pressure, lent_load)
        if not trainers_pending:
            ft_rows = 0
        else:
            ft_rows = max(int(round(c.ft_rows_max * (1.0 - load))), 0)
            if len(waiting) - len(admit) >= c.concede_at_queue:
                ft_rows = 0
        return Decision(admit=admit, ft_rows=ft_rows, load=load,
                        probe_admissions=probe_admissions)
