"""Typed invariant exceptions for the serving/core/fleet runtime.

The CI tier-1 matrix runs ``python -O``, which strips ``assert``
statements — so every load-bearing invariant raises a real exception.
``InvariantError`` is the common base: anything that inherits it means
"the engine's internal contract was violated; the process state can no
longer be trusted", as opposed to capacity signals like
``OutOfBlocksError`` that the engine answers with policy (preemption).

Subclassing ``RuntimeError`` keeps every existing ``except RuntimeError``
site (adapter-saturation deferral, fleet drift checks) behaving exactly
as before.  ``reprolint``'s no-bare-invariant-assert rule enforces usage.
"""
from __future__ import annotations


class InvariantError(RuntimeError):
    """Base for violated engine invariants (survives ``python -O``)."""


class ConfigInvariantError(InvariantError):
    """A construction-time contract was violated: an impossible pool
    geometry, an unknown mode string — caller bugs caught at the door."""


class AccountingInvariantError(InvariantError):
    """A counting contract was violated mid-flight: token/latency
    attribution asked to spread over zero tokens, and similar."""


class MigrationInvariantError(InvariantError):
    """A void/unvoid migration was attempted across incompatible model
    configs — the adapter bytes would be reinterpreted under the wrong
    schema."""
