"""SMLM — Segmented Multi-LoRA Multiplication (the paper's core kernel),
adapted for TPU with Pallas.

One kernel call computes ``Y[t] = scale(seg(t)) * X[t] @ A[a(t)] @ B[a(t)]``
for a token stream whose contiguous *segments* each use one LoRA adapter.
The host-side flow planner pads every segment to a multiple of the token
tile ``block_t``, so each grid tile has exactly one adapter — its id (and
dynamic scale) arrive via scalar prefetch, and the BlockSpec index maps DMA
only that adapter's A/B blocks from HBM into VMEM.  The low-rank
intermediate ``[block_t, r]`` lives entirely in VMEM (shrink and expand are
fused — the GPU original needs two kernel launches or a CUTLASS fused
epilogue; on TPU the fusion is structural).

Grid: (num token tiles, num output tiles).  MXU alignment: pick
``block_t``/``block_o`` as multiples of 128 in production; tests sweep tiny
shapes in interpret mode.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _smlm_kernel(tile_ids_ref, tile_scale_ref, x_ref, a_ref, b_ref, o_ref):
    i = pl.program_id(0)
    xa = jnp.dot(x_ref[...], a_ref[0],
                 preferred_element_type=jnp.float32)        # [bt, r] in VMEM
    y = jnp.dot(xa, b_ref[0].astype(jnp.float32),
                preferred_element_type=jnp.float32)         # [bt, bo]
    o_ref[...] = (y * tile_scale_ref[i]).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("block_t", "block_o", "interpret"))
def smlm(x: jax.Array, a: jax.Array, b: jax.Array, tile_ids: jax.Array,
         tile_scale: jax.Array, *, block_t: int = 128, block_o: int = 128,
         interpret: bool = False) -> jax.Array:
    """x: [T, d_in]; a: [n, d_in, r]; b: [n, r, d_out];
    tile_ids: [T/block_t] int32 adapter per token tile (clipped to range);
    tile_scale: [T/block_t] f32 per-tile scale (0.0 disables a tile).
    Returns [T, d_out]."""
    T, d_in = x.shape
    n, _, r = a.shape
    d_out = b.shape[-1]
    assert T % block_t == 0, (T, block_t)
    assert d_out % block_o == 0, (d_out, block_o)
    nt, no = T // block_t, d_out // block_o

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(nt, no),
        in_specs=[
            pl.BlockSpec((block_t, d_in), lambda i, j, ids, sc: (i, 0)),
            pl.BlockSpec((1, d_in, r), lambda i, j, ids, sc: (ids[i], 0, 0)),
            pl.BlockSpec((1, r, block_o), lambda i, j, ids, sc: (ids[i], 0, j)),
        ],
        out_specs=pl.BlockSpec((block_t, block_o), lambda i, j, ids, sc: (i, j)),
    )
    return pl.pallas_call(
        _smlm_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((T, d_out), x.dtype),
        interpret=interpret,
    )(tile_ids.astype(jnp.int32), tile_scale.astype(jnp.float32), x, a, b)
