"""BGMV — batched-gather multi-LoRA matmul for the decode bucket.

Each decode token belongs to its own request and therefore its own adapter,
so segments degenerate to single tokens.  The grid runs one program per
(token, output tile); the token's adapter id arrives via scalar prefetch and
selects the A/B blocks the BlockSpec DMAs into VMEM.  This is the TPU
analogue of Punica's BGMV: throughput is DMA-bound (one [d_in, r] + [r, bo]
weight fetch per token), which is the right trade at decode batch sizes.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _bgmv_kernel(ids_ref, scale_ref, x_ref, a_ref, b_ref, o_ref):
    t = pl.program_id(0)
    xa = jnp.dot(x_ref[...], a_ref[0],
                 preferred_element_type=jnp.float32)        # [1, r]
    y = jnp.dot(xa, b_ref[0].astype(jnp.float32),
                preferred_element_type=jnp.float32)         # [1, bo]
    o_ref[...] = (y * scale_ref[t]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_o", "interpret"))
def bgmv(x: jax.Array, a: jax.Array, b: jax.Array, ids: jax.Array,
         scale: jax.Array, *, block_o: int = 128,
         interpret: bool = False) -> jax.Array:
    """x: [T, d_in]; a: [n, d_in, r]; b: [n, r, d_out]; ids: [T] int32
    (clipped); scale: [T] f32 (0.0 disables).  Returns [T, d_out]."""
    T, d_in = x.shape
    n, _, r = a.shape
    d_out = b.shape[-1]
    assert d_out % block_o == 0, (d_out, block_o)
    no = d_out // block_o

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(T, no),
        in_specs=[
            pl.BlockSpec((1, d_in), lambda t, j, ids, sc: (t, 0)),
            pl.BlockSpec((1, d_in, r), lambda t, j, ids, sc: (ids[t], 0, 0)),
            pl.BlockSpec((1, r, block_o), lambda t, j, ids, sc: (ids[t], 0, j)),
        ],
        out_specs=pl.BlockSpec((1, block_o), lambda t, j, ids, sc: (t, j)),
    )
    return pl.pallas_call(
        _bgmv_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((T, d_out), x.dtype),
        interpret=interpret,
    )(ids.astype(jnp.int32), scale.astype(jnp.float32), x, a, b)
