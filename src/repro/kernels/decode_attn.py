"""Batch-decode attention Pallas kernel — the TPU analogue of FlashInfer's
batch-decode path (one query token per request over a long KV cache).

Grid (B, h, nk): the KV cache streams through VMEM in blocks along the
sequence axis with an online softmax; per-request valid lengths arrive via
scalar prefetch.  GQA maps query head -> kv head in the BlockSpec index map,
so the cache is read once per kv head group.  Optional rolling-buffer
support: positions are reconstructed from ``lengths`` exactly like the model
does (slot j holds absolute position j + W*floor((pos - j)/W)).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _decode_kernel(pos_ref, q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref,
                   l_ref, *, block_k: int, nk: int, scale: float,
                   window: int, sc: int):
    b = pl.program_id(0)
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0, :]                                         # [hd]
    k = k_ref[0, :, 0, :]                                      # [bk, hd]
    v = v_ref[0, :, 0, :]
    pos = pos_ref[b]                                           # query position
    j = ik * block_k + jax.lax.broadcasted_iota(jnp.int32, (1, block_k), 1)[0]
    # absolute position held by rolling slot j (== j for a linear cache)
    if window > 0:
        k_pos = j + sc * jax.lax.div(pos - j, sc)
    else:
        k_pos = j
    mask = (k_pos <= pos) & (k_pos >= 0)
    if window > 0:
        mask = mask & (pos - k_pos < window)
    s = jnp.dot(k, q, preferred_element_type=jnp.float32) * scale  # [bk]
    s = jnp.where(mask, s, NEG_INF)
    m_prev, l_prev = m_ref[0], l_ref[0]
    m_new = jnp.maximum(m_prev, s.max())
    p = jnp.where(mask, jnp.exp(s - m_new), 0.0)
    corr = jnp.exp(jnp.minimum(m_prev - m_new, 0.0))
    l_ref[0] = l_prev * corr + p.sum()
    acc_ref[...] = acc_ref[...] * corr + jnp.dot(
        p.astype(v.dtype), v, preferred_element_type=jnp.float32)[None]
    m_ref[0] = m_new

    @pl.when(ik == nk - 1)
    def _finalize():
        l = jnp.maximum(l_ref[0], 1e-30)
        o_ref[0, 0, :] = (acc_ref[0] / l).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_k", "window",
                                              "interpret"))
def decode_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                     pos: jax.Array, *, block_k: int = 512, window: int = 0,
                     interpret: bool = False) -> jax.Array:
    """q: [B, h, hd] (the current token's query);
    k/v: [B, S, g, hd] cache (rolling buffer when window > 0);
    pos: [B] int32 current positions (cache holds <= pos tokens).
    Returns [B, h, hd]."""
    B, h, hd = q.shape
    S, g = k.shape[1], k.shape[2]
    m = h // g
    pad = (-S) % block_k
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    nk = k.shape[1] // block_k
    scale = hd ** -0.5

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, h, nk),
        in_specs=[
            pl.BlockSpec((1, 1, hd), lambda b, hq, ik, P_: (b, hq, 0)),
            pl.BlockSpec((1, block_k, 1, hd),
                         lambda b, hq, ik, P_: (b, ik, hq // m, 0)),
            pl.BlockSpec((1, block_k, 1, hd),
                         lambda b, hq, ik, P_: (b, ik, hq // m, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, hd), lambda b, hq, ik, P_: (b, hq, 0)),
        scratch_shapes=[
            pltpu.VMEM((1, hd), jnp.float32),
            pltpu.VMEM((1,), jnp.float32),
            pltpu.VMEM((1,), jnp.float32),
        ],
    )
    kern = functools.partial(_decode_kernel, block_k=block_k, nk=nk,
                             scale=scale, window=window, sc=S)
    return pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, h, hd), q.dtype),
        interpret=interpret,
    )(pos.astype(jnp.int32), q, k, v)


# ---------------------------------------------------------------------------
# paged variant: the KV cache is a flat block pool, each request's blocks are
# gathered through a scalar-prefetched block table in the BlockSpec index map
# — the DMA engine walks the table, the kernel never sees the indirection.
# ---------------------------------------------------------------------------

def _paged_decode_kernel(tbl_ref, pos_ref, q_ref, k_ref, v_ref, o_ref,
                         acc_ref, m_ref, l_ref, *, bs: int, nbt: int,
                         scale: float):
    b = pl.program_id(0)
    ib = pl.program_id(2)

    @pl.when(ib == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0, :]                                         # [hd]
    k = k_ref[0, :, 0, :]                                      # [bs, hd]
    v = v_ref[0, :, 0, :]
    pos = pos_ref[b]
    # absolute position of slot j within block ib of this request's table
    j = ib * bs + jax.lax.broadcasted_iota(jnp.int32, (1, bs), 1)[0]
    mask = j <= pos                    # null-padded table rows fail this too
    s = jnp.dot(k, q, preferred_element_type=jnp.float32) * scale  # [bs]
    s = jnp.where(mask, s, NEG_INF)
    m_prev, l_prev = m_ref[0], l_ref[0]
    m_new = jnp.maximum(m_prev, s.max())
    p = jnp.where(mask, jnp.exp(s - m_new), 0.0)
    corr = jnp.exp(jnp.minimum(m_prev - m_new, 0.0))
    l_ref[0] = l_prev * corr + p.sum()
    acc_ref[...] = acc_ref[...] * corr + jnp.dot(
        p.astype(v.dtype), v, preferred_element_type=jnp.float32)[None]
    m_ref[0] = m_new

    @pl.when(ib == nbt - 1)
    def _finalize():
        l = jnp.maximum(l_ref[0], 1e-30)
        o_ref[0, 0, :] = (acc_ref[0] / l).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_decode_attention(q: jax.Array, k_pool: jax.Array,
                           v_pool: jax.Array, block_tables: jax.Array,
                           pos: jax.Array, *,
                           interpret: bool = False) -> jax.Array:
    """Block-table batch-decode attention over a paged KV pool.

    q: [B, h, hd] current-token queries;
    k_pool/v_pool: [n_blocks, bs, g, hd] flat block pool (the persistent
        cache — only the blocks a request's table names are streamed in);
    block_tables: [B, nbt] int32 per-request block ids, null-padded (padding
        entries are clamped to block 0 and masked out via ``pos``);
    pos: [B] int32 current positions (block ``pos // bs`` holds the newest
        token).  Returns [B, h, hd].

    Grid (B, h, nbt): one grid step per table entry; the BlockSpec index map
    reads the scalar-prefetched table so each step DMAs exactly one block of
    the pool — the gather lives in the index map, not in HBM.
    """
    B, h, hd = q.shape
    bs, g = k_pool.shape[1], k_pool.shape[2]
    m = h // g
    nbt = block_tables.shape[1]
    tbl = jnp.maximum(block_tables.astype(jnp.int32), 0)
    scale = hd ** -0.5

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, h, nbt),
        in_specs=[
            pl.BlockSpec((1, 1, hd), lambda b, hq, ib, T_, P_: (b, hq, 0)),
            pl.BlockSpec((1, bs, 1, hd),
                         lambda b, hq, ib, T_, P_: (T_[b, ib], 0, hq // m, 0)),
            pl.BlockSpec((1, bs, 1, hd),
                         lambda b, hq, ib, T_, P_: (T_[b, ib], 0, hq // m, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, hd),
                               lambda b, hq, ib, T_, P_: (b, hq, 0)),
        scratch_shapes=[
            pltpu.VMEM((1, hd), jnp.float32),
            pltpu.VMEM((1,), jnp.float32),
            pltpu.VMEM((1,), jnp.float32),
        ],
    )
    kern = functools.partial(_paged_decode_kernel, bs=bs, nbt=nbt,
                             scale=scale)
    return pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, h, hd), q.dtype),
        interpret=interpret,
    )(tbl, pos.astype(jnp.int32), q, k_pool, v_pool)


# ---------------------------------------------------------------------------
# verify variant: chunked query over block tables — the speculative-decoding
# attention.  Each request contributes Sq = 1 + k query tokens (current token
# + drafts) at positions pos .. pos + Sq - 1; the online softmax streams the
# same block walk as batch decode but scores an [Sq, bs] tile per block, so
# verifying k drafts costs one cache pass instead of k sequential decodes.
# ---------------------------------------------------------------------------

def _paged_verify_kernel(tbl_ref, pos_ref, len_ref, q_ref, k_ref, v_ref,
                         o_ref, acc_ref, m_ref, l_ref, *, bs: int, nbt: int,
                         sq: int, scale: float):
    b = pl.program_id(0)
    ib = pl.program_id(2)

    @pl.when(ib == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, :, 0, :]                                      # [sq, hd]
    k = k_ref[0, :, 0, :]                                      # [bs, hd]
    v = v_ref[0, :, 0, :]
    pos, ln = pos_ref[b], len_ref[b]
    j = ib * bs + jax.lax.broadcasted_iota(jnp.int32, (sq, bs), 1)
    qi = pos + jax.lax.broadcasted_iota(jnp.int32, (sq, bs), 0)
    # causal within the chunk, valid through the chunk's written length
    # (null-padded table rows exceed pos + ln and fail this too)
    mask = (j <= qi) & (j < pos + ln)
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale  # [sq,bs]
    s = jnp.where(mask, s, NEG_INF)
    m_prev, l_prev = m_ref[...], l_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=1))
    p = jnp.where(mask, jnp.exp(s - m_new[:, None]), 0.0)
    corr = jnp.exp(jnp.minimum(m_prev - m_new, 0.0))
    l_ref[...] = l_prev * corr + p.sum(axis=1)
    acc_ref[...] = acc_ref[...] * corr[:, None] + jnp.dot(
        p.astype(v.dtype), v, preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(ib == nbt - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, :, 0, :] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_verify_attention(q: jax.Array, k_pool: jax.Array,
                           v_pool: jax.Array, block_tables: jax.Array,
                           pos: jax.Array, lens: jax.Array, *,
                           interpret: bool = False) -> jax.Array:
    """Speculative verify attention over a paged KV pool.

    q: [B, Sq, h, hd] chunk queries (current token + drafts, already roped);
    k_pool/v_pool: [n_blocks, bs, g, hd] flat block pool — the chunk's own
        K/V must already be written at positions ``pos .. pos + lens - 1``;
    block_tables: [B, nbt] int32 per-request block ids, null-padded;
    pos: [B] int32 chunk start positions (= cache length before the chunk);
    lens: [B] int32 valid chunk lengths (1 = plain decode row, 0 = padding —
        such rows produce zeros).  Returns [B, Sq, h, hd].

    Grid (B, h, nbt): identical block walk to ``paged_decode_attention``,
    but each step scores all Sq chunk queries against the streamed block —
    the time-axis analogue of batching more requests per launch.
    """
    B, Sq, h, hd = q.shape
    bs, g = k_pool.shape[1], k_pool.shape[2]
    m = h // g
    nbt = block_tables.shape[1]
    tbl = jnp.maximum(block_tables.astype(jnp.int32), 0)
    scale = hd ** -0.5

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(B, h, nbt),
        in_specs=[
            pl.BlockSpec((1, Sq, 1, hd),
                         lambda b, hq, ib, T_, P_, L_: (b, 0, hq, 0)),
            pl.BlockSpec((1, bs, 1, hd),
                         lambda b, hq, ib, T_, P_, L_:
                         (T_[b, ib], 0, hq // m, 0)),
            pl.BlockSpec((1, bs, 1, hd),
                         lambda b, hq, ib, T_, P_, L_:
                         (T_[b, ib], 0, hq // m, 0)),
        ],
        out_specs=pl.BlockSpec((1, Sq, 1, hd),
                               lambda b, hq, ib, T_, P_, L_: (b, 0, hq, 0)),
        scratch_shapes=[
            pltpu.VMEM((Sq, hd), jnp.float32),
            pltpu.VMEM((Sq,), jnp.float32),
            pltpu.VMEM((Sq,), jnp.float32),
        ],
    )
    kern = functools.partial(_paged_verify_kernel, bs=bs, nbt=nbt, sq=Sq,
                             scale=scale)
    return pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Sq, h, hd), q.dtype),
        interpret=interpret,
    )(tbl, pos.astype(jnp.int32), lens.astype(jnp.int32), q, k_pool, v_pool)
