"""Flash-decoding split-K paged attention — partition + LSE-merge.

The sequential paged kernels (``decode_attn``/``prefill_attn``) walk a
request's whole block table along ONE grid axis: grid ``(B, h, nbt)`` with
the online-softmax accumulator carried in VMEM scratch across the walk.  At
decode batch sizes that leaves most of the chip idle — a single long-context
request occupies ``B*h`` grid cells no matter how many blocks it spans.

Flash-decoding (lite_llama's ``flash_decoding``/``softmax_split``, the
FlashInfer batch-decode design) adds the missing degree of parallelism:
partition the block-table walk into ``num_splits`` INDEPENDENT grid cells —
grid ``(B, h, num_splits, npb)`` with ``npb = ceil(nbt / num_splits)`` —
each producing a partial ``(acc, m, l)`` triple via the same online softmax,
then merge the partials with a numerically-stable log-sum-exp combine.  The
merge is a tiny jnp epilogue (`lse_merge`): for the [B, h] outputs it is a
reduction over ``num_splits`` fp32 triples, negligible next to the walk.

One kernel serves both latency-critical short-query paths: decode is the
``Sq == 1`` special case of verify (a one-token chunk), so
``paged_decode_attention_splitk`` simply widens its query to a chunk of one.
Splits that see only masked keys (table padding, or a padding row with
``lens == 0``) emit ``(0, -inf, 0)`` partials which the merge discards —
an empty partial cannot poison the combine.

Tile/split choices come from ``kernels.autotune`` (per-shape table with a
deterministic heuristic fallback); callers pass the chosen ``num_splits``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def lse_merge(o_part: jax.Array, m_part: jax.Array,
              l_part: jax.Array) -> jax.Array:
    """Numerically-stable combine of split-K softmax partials.

    o_part: [B, ns, Sq, h, hd] fp32 UN-normalized accumulators
        (``sum_j exp(s_j - m) v_j`` per split, with ``m`` that split's max);
    m_part: [B, ns, Sq, h] fp32 per-split score maxima (``-inf`` when the
        split saw no valid key);
    l_part: [B, ns, Sq, h] fp32 per-split softmax denominators.
    Returns [B, Sq, h, hd] fp32 — the same value a single-pass online
    softmax over the concatenated splits produces (up to fp32 rounding).

    Empty splits are inert by construction: ``m = -inf`` gives weight
    ``exp(min(m - m_max, 0))`` of either 0 (some split was non-empty) or 1
    with ``l = 0`` (ALL empty), so the output degenerates to zeros exactly
    like the sequential kernels' all-masked finalize.
    """
    m_max = jnp.max(m_part, axis=1, keepdims=True)             # [B,1,Sq,h]
    w = jnp.exp(jnp.minimum(m_part - m_max, 0.0))              # [B,ns,Sq,h]
    l_tot = jnp.sum(l_part * w, axis=1)                        # [B,Sq,h]
    o = jnp.sum(o_part * w[..., None], axis=1)                 # [B,Sq,h,hd]
    return o / jnp.maximum(l_tot, 1e-30)[..., None]


def _splitk_kernel(tbl_ref, pos_ref, len_ref, q_ref, k_ref, v_ref,
                   o_ref, m_ref, l_ref, acc_ref, ms_ref, ls_ref, *,
                   bs: int, npb: int, sq: int, scale: float):
    b = pl.program_id(0)
    s = pl.program_id(2)
    jb = pl.program_id(3)

    @pl.when(jb == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        ms_ref[...] = jnp.full_like(ms_ref, NEG_INF)
        ls_ref[...] = jnp.zeros_like(ls_ref)

    q = q_ref[0, :, 0, :]                                      # [sq, hd]
    k = k_ref[0, :, 0, :]                                      # [bs, hd]
    v = v_ref[0, :, 0, :]
    pos, ln = pos_ref[b], len_ref[b]
    ib = s * npb + jb                    # global index into the padded table
    j = ib * bs + jax.lax.broadcasted_iota(jnp.int32, (sq, bs), 1)
    qi = pos + jax.lax.broadcasted_iota(jnp.int32, (sq, bs), 0)
    # causal within the chunk, valid through the written length; padded
    # table entries (ib >= nbt) land beyond pos + ln and fail this too
    mask = (j <= qi) & (j < pos + ln)
    sc = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
    sc = jnp.where(mask, sc, NEG_INF)
    m_prev, l_prev = ms_ref[...], ls_ref[...]
    m_new = jnp.maximum(m_prev, sc.max(axis=1))
    p = jnp.where(mask, jnp.exp(sc - m_new[:, None]), 0.0)
    corr = jnp.exp(jnp.minimum(m_prev - m_new, 0.0))
    ls_ref[...] = l_prev * corr + p.sum(axis=1)
    acc_ref[...] = acc_ref[...] * corr[:, None] + jnp.dot(
        p.astype(v.dtype), v, preferred_element_type=jnp.float32)
    ms_ref[...] = m_new

    @pl.when(jb == npb - 1)
    def _emit():
        # UN-normalized partial: the LSE merge owns the division
        o_ref[0, 0, :, 0, :] = acc_ref[...]
        m_ref[0, 0, :, 0] = ms_ref[...]
        l_ref[0, 0, :, 0] = ls_ref[...]


@functools.partial(jax.jit, static_argnames=("num_splits", "interpret"))
def paged_verify_attention_splitk(q: jax.Array, k_pool: jax.Array,
                                  v_pool: jax.Array, block_tables: jax.Array,
                                  pos: jax.Array, lens: jax.Array, *,
                                  num_splits: int = 4,
                                  interpret: bool = False) -> jax.Array:
    """Split-K speculative verify attention over a paged KV pool.

    Same contract as ``decode_attn.paged_verify_attention`` (q: [B, Sq, h,
    hd]; k_pool/v_pool: [n_blocks, bs, g, hd]; block_tables: [B, nbt]
    null-padded; pos/lens: [B]; returns [B, Sq, h, hd]), but the block walk
    is partitioned across ``num_splits`` independent grid cells per (b, h)
    and the partial ``(acc, m, l)`` triples are combined by ``lse_merge``.

    Grid (B, h, ns, npb): the inner axis walks ``npb = ceil(nbt / ns)``
    consecutive table entries of one split; the split axis is parallel —
    nothing is carried across it.  ``num_splits`` may exceed the occupied
    table span: surplus splits read only null-padded entries and emit empty
    partials that the merge ignores.
    """
    B, Sq, h, hd = q.shape
    bs, g = k_pool.shape[1], k_pool.shape[2]
    m = h // g
    nbt = block_tables.shape[1]
    ns = max(1, int(num_splits))
    npb = -(-nbt // ns)
    tbl = jnp.maximum(block_tables.astype(jnp.int32), 0)
    if ns * npb > nbt:                   # pad with null blocks (masked out)
        tbl = jnp.pad(tbl, ((0, 0), (0, ns * npb - nbt)))
    scale = hd ** -0.5

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(B, h, ns, npb),
        in_specs=[
            pl.BlockSpec((1, Sq, 1, hd),
                         lambda b, hq, s, jb, T_, P_, L_: (b, 0, hq, 0)),
            pl.BlockSpec((1, bs, 1, hd),
                         lambda b, hq, s, jb, T_, P_, L_:
                         (T_[b, s * npb + jb], 0, hq // m, 0)),
            pl.BlockSpec((1, bs, 1, hd),
                         lambda b, hq, s, jb, T_, P_, L_:
                         (T_[b, s * npb + jb], 0, hq // m, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, Sq, 1, hd),
                         lambda b, hq, s, jb, T_, P_, L_: (b, s, 0, hq, 0)),
            pl.BlockSpec((1, 1, Sq, 1),
                         lambda b, hq, s, jb, T_, P_, L_: (b, s, 0, hq)),
            pl.BlockSpec((1, 1, Sq, 1),
                         lambda b, hq, s, jb, T_, P_, L_: (b, s, 0, hq)),
        ],
        scratch_shapes=[
            pltpu.VMEM((Sq, hd), jnp.float32),
            pltpu.VMEM((Sq,), jnp.float32),
            pltpu.VMEM((Sq,), jnp.float32),
        ],
    )
    kern = functools.partial(_splitk_kernel, bs=bs, npb=npb, sq=Sq,
                             scale=scale)
    o_part, m_part, l_part = pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((B, ns, Sq, h, hd), jnp.float32),
            jax.ShapeDtypeStruct((B, ns, Sq, h), jnp.float32),
            jax.ShapeDtypeStruct((B, ns, Sq, h), jnp.float32),
        ],
        interpret=interpret,
    )(tbl, pos.astype(jnp.int32), lens.astype(jnp.int32), q, k_pool, v_pool)
    return lse_merge(o_part, m_part, l_part).astype(q.dtype)


@functools.partial(jax.jit, static_argnames=("num_splits", "interpret"))
def paged_decode_attention_splitk(q: jax.Array, k_pool: jax.Array,
                                  v_pool: jax.Array, block_tables: jax.Array,
                                  pos: jax.Array, *, num_splits: int = 4,
                                  interpret: bool = False) -> jax.Array:
    """Split-K batch-decode attention: the ``Sq == 1`` case of the split-K
    verify kernel (one query token is a one-token chunk).  Same contract as
    ``decode_attn.paged_decode_attention``: q [B, h, hd] -> [B, h, hd]."""
    lens = jnp.ones((q.shape[0],), jnp.int32)
    out = paged_verify_attention_splitk(q[:, None], k_pool, v_pool,
                                        block_tables, pos, lens,
                                        num_splits=num_splits,
                                        interpret=interpret)
    return out[:, 0]
