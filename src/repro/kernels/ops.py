"""jit'd dispatch wrappers around the Pallas kernels.

On TPU the real kernels run compiled; on CPU (this container) they run in
``interpret=True`` mode, and callers that only need numerics (the model's
default path) use the jnp oracles in ``ref.py`` directly.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import ref as _ref
from repro.kernels.bgmv import bgmv as _bgmv_kernel
from repro.kernels.decode_attn import decode_attention as _decode_kernel
from repro.kernels.flash_attn import flash_attention as _flash_kernel
from repro.kernels.smlm import smlm as _smlm_kernel


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def smlm(x: jax.Array, a: jax.Array, b: jax.Array, ids: jax.Array,
         scale_t: Optional[jax.Array] = None, *, block_t: int = 128,
         block_o: int = 128, interpret: Optional[bool] = None) -> jax.Array:
    """Segmented multi-LoRA matmul over a tile-aligned token stream.

    ``ids``/``scale_t`` are PER-TOKEN; the flow planner guarantees each
    ``block_t`` tile is adapter-uniform, so the wrapper derives per-tile
    scalars by striding.

    A ragged stream (``T % block_t != 0`` — e.g. a decode tail appended to
    the tile-aligned ft+pf segments) no longer silently falls back to the
    dense one-hot oracle for the WHOLE stream: the tile-aligned head keeps
    the fused kernel and only the sub-tile remainder goes through the
    per-token BGMV path — which is also what keeps a remainder with MIXED
    adapters (decode rows) exact, since per-token ids never get collapsed
    into a tile scalar there.
    """
    T = x.shape[0]
    n = a.shape[0]
    if b.shape[-1] % block_o != 0:
        sc = scale_t if scale_t is not None else jnp.ones((T,), jnp.float32)
        return _ref.bgmv_ref(x, a, b, ids, sc)
    rem = T % block_t
    if rem:
        t0 = T - rem
        tail = bgmv(x[t0:], a, b, ids[t0:],
                    scale_t[t0:] if scale_t is not None else None,
                    block_o=block_o, interpret=interpret)
        if t0 == 0:
            return tail
        head = smlm(x[:t0], a, b, ids[:t0],
                    scale_t[:t0] if scale_t is not None else None,
                    block_t=block_t, block_o=block_o, interpret=interpret)
        return jnp.concatenate([head, tail], axis=0)
    tile_ids = ids[::block_t]
    valid = (tile_ids >= 0) & (tile_ids < n)
    if scale_t is None:
        tile_scale = valid.astype(jnp.float32)
    else:
        tile_scale = jnp.where(valid, scale_t[::block_t], 0.0)
    tile_ids = jnp.clip(tile_ids, 0, n - 1)
    interpret = (not _on_tpu()) if interpret is None else interpret
    return _smlm_kernel(x, a, b, tile_ids, tile_scale,
                        block_t=block_t, block_o=block_o, interpret=interpret)


def bgmv(x: jax.Array, a: jax.Array, b: jax.Array, ids: jax.Array,
         scale_t: Optional[jax.Array] = None, *, block_o: int = 128,
         interpret: Optional[bool] = None) -> jax.Array:
    """Per-token multi-LoRA matmul (decode bucket)."""
    T = x.shape[0]
    n = a.shape[0]
    valid = (ids >= 0) & (ids < n)
    if scale_t is None:
        scale = valid.astype(jnp.float32)
    else:
        scale = jnp.where(valid, scale_t, 0.0)
    ids = jnp.clip(ids, 0, n - 1)
    if b.shape[-1] % block_o != 0:
        return _ref.bgmv_ref(x, a, b, ids, scale)
    interpret = (not _on_tpu()) if interpret is None else interpret
    return _bgmv_kernel(x, a, b, ids, scale, block_o=block_o,
                        interpret=interpret)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                    lengths: jax.Array, *, causal: bool = True,
                    block_q: int = 128, block_k: int = 128,
                    interpret: Optional[bool] = None) -> jax.Array:
    """Flash attention (prefill).  Falls back to the oracle off-TPU unless
    ``interpret`` is forced (tests)."""
    interpret = (not _on_tpu()) if interpret is None else interpret
    return _flash_kernel(q, k, v, lengths, causal=causal, block_q=block_q,
                         block_k=block_k, interpret=interpret)


def decode_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                     pos: jax.Array, *, window: int = 0,
                     block_k: int = 512,
                     interpret: Optional[bool] = None) -> jax.Array:
    """Batch-decode attention (one token per request over a long cache)."""
    interpret = (not _on_tpu()) if interpret is None else interpret
    return _decode_kernel(q, k, v, pos, block_k=block_k, window=window,
                          interpret=interpret)
