"""Causal flash-attention (prefill) Pallas kernel — the TPU replacement for
the paper's FlashInfer batch-prefill path.

Online-softmax over KV blocks streamed HBM->VMEM; running (max, sum, acc)
live in VMEM scratch; blocks strictly above the causal diagonal are skipped
at grid level.  GQA is handled in the BlockSpec index map (query head ->
kv head = hq // (h // kv)), so K/V are never replicated in memory.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(len_ref, q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref,
                  *, block_q: int, block_k: int, nk: int, scale: float,
                  causal: bool):
    b = pl.program_id(0)
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_last = iq * block_q + block_q - 1
    live = (ik * block_k <= q_last) if causal else True

    @pl.when(live)
    def _compute():
        q = q_ref[0, :, 0, :]                                   # [bq, hd]
        k = k_ref[0, :, 0, :]                                   # [bk, hd]
        v = v_ref[0, :, 0, :]
        qpos = iq * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
        kpos = ik * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
        mask = kpos < len_ref[b]
        if causal:
            mask = mask & (kpos <= qpos)
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
        s = jnp.where(mask, s, NEG_INF)
        m_prev, l_prev = m_ref[...], l_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=-1))
        # fully-masked rows: m_new = NEG_INF would make exp(s - m_new) = 1
        p = jnp.where(mask, jnp.exp(s - m_new[:, None]), 0.0)
        corr = jnp.exp(jnp.minimum(m_prev - m_new, 0.0))
        l_ref[...] = l_prev * corr + p.sum(axis=-1)
        acc_ref[...] = acc_ref[...] * corr[:, None] + jnp.dot(
            p.astype(v.dtype), v, preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ik == nk - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, :, 0, :] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_q", "block_k", "causal",
                                              "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                    lengths: jax.Array, *, block_q: int = 128,
                    block_k: int = 128, causal: bool = True,
                    interpret: bool = False) -> jax.Array:
    """q: [B, S, h, hd]; k/v: [B, T, g, hd]; lengths: [B] valid KV lengths.
    Assumes q position i attends to kv positions <= i (prefill layout).
    Returns [B, S, h, hd]."""
    B, S, h, hd = q.shape
    T, g = k.shape[1], k.shape[2]
    m = h // g
    pad_q, pad_k = (-S) % block_q, (-T) % block_k
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
    Sq, Tk = S + pad_q, T + pad_k
    nq, nk = Sq // block_q, Tk // block_k
    scale = hd ** -0.5

    from jax.experimental.pallas import tpu as pltpu
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, h, nq, nk),
        in_specs=[
            pl.BlockSpec((1, block_q, 1, hd),
                         lambda b, hq, iq, ik, L: (b, iq, hq, 0)),
            pl.BlockSpec((1, block_k, 1, hd),
                         lambda b, hq, iq, ik, L: (b, ik, hq // m, 0)),
            pl.BlockSpec((1, block_k, 1, hd),
                         lambda b, hq, iq, ik, L: (b, ik, hq // m, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, 1, hd),
                               lambda b, hq, iq, ik, L: (b, iq, hq, 0)),
        scratch_shapes=[
            pltpu.VMEM((block_q, hd), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
        ],
    )
    kern = functools.partial(_flash_kernel, block_q=block_q, block_k=block_k,
                             nk=nk, scale=scale, causal=causal)
    out = pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Sq, h, hd), q.dtype),
        interpret=interpret,
    )(lengths.astype(jnp.int32), q, k, v)
    return out[:, :S]
