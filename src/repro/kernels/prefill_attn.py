"""Paged prefill attention — suffix-only prefill over a block-table KV pool.

The prefix-caching counterpart of ``decode_attn.paged_verify_attention``:
each request contributes a *suffix* of ``seg_len`` query tokens at absolute
positions ``cached_len .. cached_len + seg_len - 1``, while its keys/values
— the ``cached_len`` shared-prefix tokens written by an earlier request (or
an earlier chunk of this one) PLUS the suffix tokens written this step —
live in the flat block pool and are reached through a scalar-prefetched
block table.  Generalizes the verify kernel's chunked-query walk to prefill
widths: the query axis is tiled by ``block_q`` (grid axis), so a 4k-token
suffix streams the same per-block online softmax as a 5-token verify chunk.

Grid (B, nq, h, nbt): for a fixed (request, query tile, head) the block walk
is innermost, so the VMEM accumulator carries the online softmax across the
table exactly like the decode/verify kernels.  GQA maps query head -> kv
head in the BlockSpec index map.  The suffix K/V must already be written to
the pool at ``cached_len .. cached_len + seg_len - 1`` before the call (the
model scatters them via ``_paged_write_chunk`` first) — the kernel then
never distinguishes cached from fresh keys, which is the whole point: the
prefix is *read*, not recomputed.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _paged_prefill_kernel(tbl_ref, cached_ref, seg_ref, q_ref, k_ref, v_ref,
                          o_ref, acc_ref, m_ref, l_ref, *, bs: int, nbt: int,
                          block_q: int, scale: float):
    b = pl.program_id(0)
    iq = pl.program_id(1)
    ib = pl.program_id(3)

    @pl.when(ib == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, :, 0, :]                                      # [bq, hd]
    k = k_ref[0, :, 0, :]                                      # [bs, hd]
    v = v_ref[0, :, 0, :]
    cached, seg = cached_ref[b], seg_ref[b]
    # absolute positions: key slot j of block ib; query row i of tile iq
    j = ib * bs + jax.lax.broadcasted_iota(jnp.int32, (block_q, bs), 1)
    qi = cached + iq * block_q \
        + jax.lax.broadcasted_iota(jnp.int32, (block_q, bs), 0)
    # causal over absolute positions; keys valid through the end of the
    # written span (prefix + suffix).  Padding rows (seg == 0) mask out
    # everything and finalize to zeros; padding query rows past ``seg``
    # produce garbage that the caller never reads (per-token independence).
    mask = (j <= qi) & (j < cached + seg)
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
    s = jnp.where(mask, s, NEG_INF)
    m_prev, l_prev = m_ref[...], l_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=1))
    p = jnp.where(mask, jnp.exp(s - m_new[:, None]), 0.0)
    corr = jnp.exp(jnp.minimum(m_prev - m_new, 0.0))
    l_ref[...] = l_prev * corr + p.sum(axis=1)
    acc_ref[...] = acc_ref[...] * corr[:, None] + jnp.dot(
        p.astype(v.dtype), v, preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(ib == nbt - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, :, 0, :] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_q", "interpret"))
def paged_prefill_attention(q: jax.Array, k_pool: jax.Array,
                            v_pool: jax.Array, block_tables: jax.Array,
                            cached_len: jax.Array, seg_len: jax.Array, *,
                            block_q: int = 128,
                            interpret: bool = False) -> jax.Array:
    """Suffix-only prefill attention over a paged KV pool.

    q: [B, Sq, h, hd] suffix queries (already roped at positions
        ``cached_len + 0 .. cached_len + Sq - 1``);
    k_pool/v_pool: [n_blocks, bs, g, hd] flat block pool — the suffix's own
        K/V must already be written at ``cached_len .. cached_len + seg - 1``;
    block_tables: [B, nbt] int32 per-request block ids, null-padded;
    cached_len: [B] int32 tokens of already-valid prefix K/V per request;
    seg_len: [B] int32 valid suffix lengths (0 = padding row -> zeros).
    Returns [B, Sq, h, hd].
    """
    B, Sq, h, hd = q.shape
    bs, g = k_pool.shape[1], k_pool.shape[2]
    m = h // g
    nbt = block_tables.shape[1]
    tbl = jnp.maximum(block_tables.astype(jnp.int32), 0)
    scale = hd ** -0.5
    bq = min(block_q, max(Sq, 1))
    pad = (-Sq) % bq
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
    nq = q.shape[1] // bq

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(B, nq, h, nbt),
        in_specs=[
            pl.BlockSpec((1, bq, 1, hd),
                         lambda b, iq, hq, ib, T_, C_, S_: (b, iq, hq, 0)),
            pl.BlockSpec((1, bs, 1, hd),
                         lambda b, iq, hq, ib, T_, C_, S_:
                         (T_[b, ib], 0, hq // m, 0)),
            pl.BlockSpec((1, bs, 1, hd),
                         lambda b, iq, hq, ib, T_, C_, S_:
                         (T_[b, ib], 0, hq // m, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, 1, hd),
                               lambda b, iq, hq, ib, T_, C_, S_:
                               (b, iq, hq, 0)),
        scratch_shapes=[
            pltpu.VMEM((bq, hd), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
        ],
    )
    kern = functools.partial(_paged_prefill_kernel, bs=bs, nbt=nbt,
                             block_q=bq, scale=scale)
    out = pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Sq + pad, h, hd), q.dtype),
        interpret=interpret,
    )(tbl, cached_len.astype(jnp.int32), seg_len.astype(jnp.int32),
      q, k_pool, v_pool)
    return out[:, :Sq]
