"""Pure-jnp oracles for every Pallas kernel (allclose targets for tests)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def smlm_ref(x: jax.Array, a: jax.Array, b: jax.Array, tile_ids: jax.Array,
             tile_scale: jax.Array, block_t: int) -> jax.Array:
    """Tile-segmented multi-LoRA matmul oracle."""
    ids = jnp.repeat(tile_ids, block_t)
    scale = jnp.repeat(tile_scale, block_t)
    return bgmv_ref(x, a, b, ids, scale)


def bgmv_ref(x: jax.Array, a: jax.Array, b: jax.Array, ids: jax.Array,
             scale: jax.Array) -> jax.Array:
    """Per-token multi-LoRA matmul oracle (one-hot form)."""
    n = a.shape[0]
    onehot = jax.nn.one_hot(ids, n, dtype=jnp.float32) * scale[:, None]
    xa = jnp.einsum("td,ndr->tnr", x.astype(jnp.float32),
                    a.astype(jnp.float32))
    xa = xa * onehot[:, :, None]
    y = jnp.einsum("tnr,nro->to", xa, b.astype(jnp.float32))
    return y.astype(x.dtype)


def decode_attention_ref(q: jax.Array, k: jax.Array,
                         v: jax.Array, pos: jax.Array) -> jax.Array:
    """Linear/rolling-cache batch-decode oracle.  The cache holds ``sc``
    slots; for positions past ``sc`` the buffer has wrapped, so each slot's
    absolute position is reconstructed the same way the model does it
    (``_dec_cache_pos``): slot ``j`` holds the latest written position
    ``<= pos`` that is congruent to ``j`` mod ``sc``.
    q: [B, h, hd]; k/v: [B, sc, g, hd]; pos: [B] (absolute)."""
    from repro.models.layers import attention
    sc = k.shape[1]
    j = jnp.arange(sc, dtype=jnp.int32)[None, :]
    p = pos[:, None].astype(jnp.int32)
    k_pos = j + sc * jnp.floor_divide(p - j, sc)
    k_valid = j <= p
    return attention(q[:, None], k, v, q_pos=p, k_pos=k_pos,
                     k_valid=k_valid, causal=True, window=0)[:, 0]


def paged_decode_ref(q: jax.Array, k_pool: jax.Array, v_pool: jax.Array,
                     block_tables: jax.Array, pos: jax.Array) -> jax.Array:
    """Block-table batch-decode oracle: gather each request's blocks into a
    contiguous view, then run masked single-token attention.
    q: [B, h, hd]; k_pool/v_pool: [n_blocks, bs, g, hd];
    block_tables: [B, nbt] (null-padded with 0); pos: [B]."""
    from repro.models.layers import attention
    B = q.shape[0]
    bs = k_pool.shape[1]
    tbl = jnp.maximum(block_tables, 0)
    nbt = tbl.shape[1]
    k = k_pool[tbl].reshape(B, nbt * bs, *k_pool.shape[2:])
    v = v_pool[tbl].reshape(B, nbt * bs, *v_pool.shape[2:])
    j = jnp.arange(nbt * bs, dtype=jnp.int32)[None, :]
    k_pos = jnp.broadcast_to(j, (B, nbt * bs))
    k_valid = j <= pos[:, None]
    return attention(q[:, None], k, v, q_pos=pos[:, None], k_pos=k_pos,
                     k_valid=k_valid, causal=True, window=0)[:, 0]


def paged_verify_ref(q: jax.Array, k_pool: jax.Array, v_pool: jax.Array,
                     block_tables: jax.Array, pos: jax.Array,
                     lens: jax.Array) -> jax.Array:
    """Verify-chunk oracle: ``Sq`` query tokens per request at positions
    ``pos .. pos + Sq - 1`` over the gathered block view; keys are valid
    through ``pos + lens - 1`` and causality is positional.
    q: [B, Sq, h, hd]; k_pool/v_pool: [n_blocks, bs, g, hd];
    block_tables: [B, nbt]; pos/lens: [B]."""
    from repro.models.layers import attention
    B, Sq = q.shape[:2]
    bs = k_pool.shape[1]
    tbl = jnp.maximum(block_tables, 0)
    nbt = tbl.shape[1]
    k = k_pool[tbl].reshape(B, nbt * bs, *k_pool.shape[2:])
    v = v_pool[tbl].reshape(B, nbt * bs, *v_pool.shape[2:])
    j = jnp.arange(nbt * bs, dtype=jnp.int32)[None, :]
    k_pos = jnp.broadcast_to(j, (B, nbt * bs))
    k_valid = j < pos[:, None] + lens[:, None]
    q_pos = pos[:, None] + jnp.arange(Sq, dtype=jnp.int32)[None, :]
    return attention(q, k, v, q_pos=q_pos, k_pos=k_pos, k_valid=k_valid,
                     causal=True, window=0)


def paged_prefill_ref(q: jax.Array, k_pool: jax.Array, v_pool: jax.Array,
                      block_tables: jax.Array, cached_len: jax.Array,
                      seg_len: jax.Array) -> jax.Array:
    """Suffix-only prefill oracle: ``Sq`` suffix queries per request at
    absolute positions ``cached_len .. cached_len + Sq - 1`` over the
    gathered block view; keys (shared prefix + this suffix, both already in
    the pool) are valid through ``cached_len + seg_len - 1`` and causality
    is positional.  q: [B, Sq, h, hd]; k_pool/v_pool: [n_blocks, bs, g, hd];
    block_tables: [B, nbt]; cached_len/seg_len: [B]."""
    from repro.models.layers import attention
    B, Sq = q.shape[:2]
    bs = k_pool.shape[1]
    tbl = jnp.maximum(block_tables, 0)
    nbt = tbl.shape[1]
    k = k_pool[tbl].reshape(B, nbt * bs, *k_pool.shape[2:])
    v = v_pool[tbl].reshape(B, nbt * bs, *v_pool.shape[2:])
    j = jnp.arange(nbt * bs, dtype=jnp.int32)[None, :]
    k_pos = jnp.broadcast_to(j, (B, nbt * bs))
    k_valid = j < cached_len[:, None] + seg_len[:, None]
    q_pos = cached_len[:, None] + jnp.arange(Sq, dtype=jnp.int32)[None, :]
    return attention(q, k, v, q_pos=q_pos, k_pos=k_pos, k_valid=k_valid,
                     causal=True, window=0)


def flash_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                        lengths: jax.Array, causal: bool = True) -> jax.Array:
    """Masked GQA attention oracle (full-scores form)."""
    from repro.models.layers import attention
    B, S = q.shape[:2]
    T = k.shape[1]
    q_pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    k_pos = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32), (B, T))
    k_valid = k_pos < lengths[:, None]
    return attention(q, k, v, q_pos=q_pos, k_pos=k_pos, k_valid=k_valid,
                     causal=causal, window=0)
