"""Tile/split autotuning for the paged-attention kernel family.

The sequential kernels hard-coded their tiles (``block_k = 512`` for the
linear-cache decode kernel, one pool block per grid step for the paged
family) and the split-K kernels need a ``num_splits``.  This module owns
that choice, per shape key ``(head_dim, block_size, nbt, bh)`` where ``bh =
B * n_heads`` is the batch-parallelism the grid already has:

* a tuning TABLE — in-memory dict, loadable from / savable to a small JSON
  file — populated by a ``benchmarks/bench_kernels.py``-driven sweep
  (wall-clock ``measure`` on real TPU, the occupancy model below in
  interpret/CPU mode);
* a deterministic HEURISTIC fallback for any shape the table misses, so CI
  and cold starts never depend on a tuning run having happened.

The occupancy model: a device runs ``lanes`` grid cells concurrently
(GPU SMs / TPU megacore+DMA pipelining; calibrate per device with the
``REPRO_ATTN_LANES`` env var — validated, garbage fails loudly).  The
sequential walk costs ``ceil(bh / lanes) * nbt`` block-tile visits; a
``ns``-way split costs ``ceil(bh * ns / lanes) * ceil(nbt / ns)`` plus a
small LSE-merge epilogue.  Splitting wins exactly when ``bh`` alone cannot
fill the lanes — long context, small batch — and is useless (ns = 1) once
``bh >= lanes``, which is also what flash-decoding observes on real
hardware.

Every table mutation bumps ``table_version()``; the jit step caches in
``core.unified`` key on it, so loading a tuning table mid-process can never
hit a stale trace that baked in the old choice.
"""
from __future__ import annotations

import json
import os
from typing import Callable, Dict, Iterable, NamedTuple, Optional, Tuple

# modeled concurrent grid cells (default); calibrate per device with the
# REPRO_ATTN_LANES env override — see effective_lanes()
LANES = 16
ENV_LANES = "REPRO_ATTN_LANES"
SPLIT_CANDIDATES = (1, 2, 4, 8, 16)
# below this many blocks per split the per-split fixed costs (q load, merge
# traffic) dominate — don't shard a walk that short
MIN_BLOCKS_PER_SPLIT = 4
_MERGE_FIXED = 1.0          # merge epilogue launch, in block-tile-visit units
_MERGE_PER_SPLIT = 0.25     # per-partial merge traffic, same units

ShapeKey = Tuple[int, int, int, int]       # (head_dim, block_size, nbt, bh)


class AttnConfig(NamedTuple):
    """One kernel-family tuning decision for a shape key."""
    block_k: int             # KV tile of the linear-cache decode kernel;
    #                          the paged kernels stream one pool block per
    #                          step, so there it only documents the tile
    num_splits: int          # split-K fan-out (1 = sequential walk)


_TABLE: Dict[ShapeKey, AttnConfig] = {}
_VERSION = 0
_ENV_LOADED = False

ENV_TUNE_FILE = "REPRO_ATTN_TUNE_FILE"


def table_version() -> int:
    """Monotone counter bumped on every table mutation — step-compile
    caches key on it (see core.unified)."""
    return _VERSION


def effective_lanes() -> int:
    """The occupancy model's concurrent-grid-cell count: the
    ``REPRO_ATTN_LANES`` env override when set (per-device calibration
    without editing source — a TPU v5e megacore pipelines differently from
    an H100's SM count), else the ``LANES`` default.  Garbage values fail
    LOUDLY: a typo silently falling back to 16 would bake the wrong
    fan-outs into every heuristic choice on that host."""
    raw = os.environ.get(ENV_LANES, "").strip()
    if not raw:
        return LANES
    try:
        lanes = int(raw)
    except ValueError:
        raise ValueError(
            f"{ENV_LANES}={raw!r} is not an integer; set the modeled "
            f"concurrent grid-cell count (e.g. 16), or unset it for the "
            f"default {LANES}") from None
    if lanes < 1:
        raise ValueError(f"{ENV_LANES}={raw!r} must be >= 1")
    return lanes


def put_config(key: ShapeKey, cfg: AttnConfig) -> None:
    global _VERSION
    _TABLE[tuple(int(k) for k in key)] = AttnConfig(int(cfg[0]), int(cfg[1]))
    _VERSION += 1


def clear_table() -> None:
    global _VERSION
    _TABLE.clear()
    _VERSION += 1


def get_config(key: ShapeKey) -> Optional[AttnConfig]:
    return _TABLE.get(tuple(int(k) for k in key))


def modeled_grid_time(bh: int, nbt: int, num_splits: int,
                      lanes: Optional[int] = None) -> float:
    """Occupancy-model cost (in block-tile visits) of one attention launch:
    waves of ``lanes`` concurrent cells, each cell walking its share of the
    table, plus the LSE-merge epilogue when split."""
    lanes = effective_lanes() if lanes is None else lanes
    ns = max(1, int(num_splits))
    npb = -(-nbt // ns)
    waves = -(-bh * ns // lanes)
    t = float(waves * npb)
    if ns > 1:
        t += _MERGE_FIXED + _MERGE_PER_SPLIT * ns * (-(-bh // lanes))
    return t


def candidate_splits(nbt: int) -> Tuple[int, ...]:
    """Split counts worth trying for a table of ``nbt`` blocks."""
    return tuple(ns for ns in SPLIT_CANDIDATES
                 if ns == 1 or -(-nbt // ns) >= MIN_BLOCKS_PER_SPLIT)


def default_block_k(head_dim: int) -> int:
    """Linear-cache decode KV tile: fill roughly one VMEM-friendly
    [block_k, head_dim] strip."""
    return 512 if head_dim <= 64 else 256


def heuristic(head_dim: int, block_size: int, nbt: int, bh: int,
              lanes: Optional[int] = None) -> AttnConfig:
    """Deterministic fallback: minimize the occupancy model over the
    candidate splits (ties -> fewer splits, less merge traffic).  ``lanes``
    defaults to ``effective_lanes()`` — the REPRO_ATTN_LANES per-device
    calibration reaches every heuristic choice through here."""
    lanes = effective_lanes() if lanes is None else lanes
    best, best_t = 1, modeled_grid_time(bh, nbt, 1, lanes)
    for ns in candidate_splits(nbt):
        t = modeled_grid_time(bh, nbt, ns, lanes)
        if t < best_t:
            best, best_t = ns, t
    return AttnConfig(default_block_k(head_dim), best)


def choose(head_dim: int, block_size: int, nbt: int, bh: int) -> AttnConfig:
    """Table lookup with heuristic fallback — the one entry point the model
    calls at trace time."""
    _maybe_load_env()
    got = get_config((head_dim, block_size, nbt, bh))
    return got if got is not None else heuristic(head_dim, block_size,
                                                 nbt, bh)


def _maybe_load_env() -> None:
    global _ENV_LOADED
    if _ENV_LOADED:
        return
    _ENV_LOADED = True
    path = os.environ.get(ENV_TUNE_FILE, "").strip()
    if path:
        load_table(path)


# ------------------------------------------------------------- persistence

def save_table(path: str) -> int:
    """Write the in-memory table as JSON; returns the entry count."""
    doc = {"lanes": effective_lanes(),
           "entries": {",".join(str(k) for k in key): list(cfg)
                       for key, cfg in sorted(_TABLE.items())}}
    with open(path, "w") as f:
        json.dump(doc, f, indent=2, sort_keys=True)
        f.write("\n")
    return len(_TABLE)


def load_table(path: str) -> int:
    """Merge a JSON tuning table into the in-memory one (one version bump);
    returns the number of entries loaded."""
    global _VERSION
    with open(path) as f:
        doc = json.load(f)
    entries = doc.get("entries", {})
    for skey, val in entries.items():
        key = tuple(int(p) for p in skey.split(","))
        if len(key) != 4 or len(val) != 2:
            raise ValueError(f"malformed tuning entry {skey!r}: {val!r}")
        _TABLE[key] = AttnConfig(int(val[0]), int(val[1]))
    _VERSION += 1
    return len(entries)


# ------------------------------------------------------------------ sweep

def sweep(shapes: Iterable[ShapeKey],
          measure: Optional[Callable[[ShapeKey, AttnConfig], float]] = None,
          lanes: Optional[int] = None) -> Dict[ShapeKey, AttnConfig]:
    """Populate the table for ``shapes``: score every candidate split with
    ``measure((hd, bs, nbt, bh), cfg) -> seconds`` (wall-clock on a real
    TPU) or, when None, with the occupancy model (interpret/CPU mode, where
    grid parallelism is not observable).  Deterministic given its inputs;
    returns the chosen configs (also stored via ``put_config``)."""
    lanes = effective_lanes() if lanes is None else lanes
    chosen: Dict[ShapeKey, AttnConfig] = {}
    for key in shapes:
        hd, bs, nbt, bh = (int(k) for k in key)
        best_cfg, best_t = None, None
        for ns in candidate_splits(nbt):
            cfg = AttnConfig(default_block_k(hd), ns)
            t = (measure((hd, bs, nbt, bh), cfg) if measure is not None
                 else modeled_grid_time(bh, nbt, ns, lanes))
            if best_t is None or t < best_t:
                best_cfg, best_t = cfg, t
        chosen[(hd, bs, nbt, bh)] = best_cfg
        put_config((hd, bs, nbt, bh), best_cfg)
    return chosen
