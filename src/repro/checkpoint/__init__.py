"""Checkpointing: pytree <-> npz, adapter-only checkpoints, migration blobs."""
