"""Checkpoint I/O: flatten pytrees to path-keyed npz archives.

Adapter checkpoints hold ONLY the LoRA leaves (plus optimizer moments when
requested) — the paper's "0 B additional storage" property: the base model is
never duplicated on disk per adapter.
"""
from __future__ import annotations

import io
import os
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def _flatten_with_paths(tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(_path_str(p) for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return str(p.idx)
    if hasattr(p, "name"):
        return str(p.name)
    return str(p)


def save_pytree(path: str, tree) -> int:
    """Write tree to ``path`` (npz).  Returns bytes written."""
    flat = _flatten_with_paths(tree)
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    np.savez(path, **flat)
    return os.path.getsize(path)


def load_pytree(path: str, like) -> Any:
    """Load into the structure of ``like`` (shape/dtype-checked)."""
    with np.load(path) as z:
        flat = {k: z[k] for k in z.files}
    leaves_like, treedef = jax.tree_util.tree_flatten_with_path(like)
    out = []
    for path_k, leaf in leaves_like:
        key = "/".join(_path_str(p) for p in path_k)
        arr = flat[key]
        assert arr.shape == tuple(leaf.shape), (key, arr.shape, leaf.shape)
        out.append(jnp.asarray(arr, dtype=leaf.dtype))
    return jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(like), out)


def serialize_pytree(tree) -> bytes:
    """In-memory blob (migration payloads)."""
    flat = _flatten_with_paths(tree)
    buf = io.BytesIO()
    np.savez(buf, **flat)
    return buf.getvalue()


def deserialize_pytree(blob: bytes, like) -> Any:
    buf = io.BytesIO(blob)
    with np.load(buf) as z:
        flat = {k: z[k] for k in z.files}
    leaves_like, treedef = jax.tree_util.tree_flatten_with_path(like)
    out = []
    for path_k, leaf in leaves_like:
        key = "/".join(_path_str(p) for p in path_k)
        out.append(jnp.asarray(flat[key], dtype=leaf.dtype))
    return jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(like), out)
