"""AdamW in pure JAX with per-adapter-slot masking.

Every LoRA bank leaf has an adapter axis at ``-3`` (``[..., n_slots, d_in, r]``
/ ``[..., n_slots, r, d_out]``).  ``slot_mask`` gates both the moment update
and the parameter step so concurrent trainers touch only their own slot —
the functional form of the paper's ``MixedLoRAModelForTrainer`` parameter
masking.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 2e-5                 # the paper's fine-tuning LR
    b1: float = 0.9
    b2: float = 0.999
    eps: float = 1e-8
    weight_decay: float = 0.0
    grad_clip: float = 1.0           # global-norm clip (0 = off)


class AdamWState(NamedTuple):
    m: Any
    v: Any
    t: jax.Array                     # [n_slots] per-slot step counters


def adamw_init(params, n_slots: int) -> AdamWState:
    z = lambda p: jnp.zeros_like(p, dtype=jnp.float32)
    return AdamWState(m=jax.tree_util.tree_map(z, params),
                      v=jax.tree_util.tree_map(z, params),
                      t=jnp.zeros((n_slots,), jnp.int32))


def _mask_like(leaf: jax.Array, slot_mask: jax.Array) -> jax.Array:
    """Broadcast [n_slots] over the adapter axis at -3."""
    shape = [1] * leaf.ndim
    shape[-3] = slot_mask.shape[0]
    return slot_mask.reshape(shape).astype(jnp.float32)


def global_norm(tree) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def adamw_apply(cfg: AdamWConfig, grads, state: AdamWState, params,
                slot_mask: jax.Array):
    """Masked AdamW step.  Only slots with mask=1 are updated (their moments,
    their counters, their params); everything else passes through untouched.
    Returns (new_params, new_state)."""
    if cfg.grad_clip > 0:
        gn = global_norm(grads)
        scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gn, 1e-9))
        grads = jax.tree_util.tree_map(lambda g: g * scale, grads)

    t_new = state.t + slot_mask.astype(jnp.int32)

    def upd(p, g, m, v):
        msk = _mask_like(p, slot_mask)
        g32 = g.astype(jnp.float32)
        m_new = jnp.where(msk > 0, cfg.b1 * m + (1 - cfg.b1) * g32, m)
        v_new = jnp.where(msk > 0, cfg.b2 * v + (1 - cfg.b2) * g32 * g32, v)
        # per-slot bias correction
        shape = [1] * p.ndim
        shape[-3] = t_new.shape[0]
        t_b = jnp.maximum(t_new, 1).reshape(shape).astype(jnp.float32)
        mhat = m_new / (1 - cfg.b1 ** t_b)
        vhat = v_new / (1 - cfg.b2 ** t_b)
        step = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if cfg.weight_decay:
            step = step + cfg.weight_decay * p.astype(jnp.float32)
        p_new = p.astype(jnp.float32) - cfg.lr * step * msk
        return p_new.astype(p.dtype), m_new, v_new

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.m)
    flat_v = treedef.flatten_up_to(state.v)
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree_util.tree_unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree_util.tree_unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree_util.tree_unflatten(treedef, [o[2] for o in out])
    return new_p, AdamWState(m=new_m, v=new_v, t=t_new)


def tree_add(a, b):
    return jax.tree_util.tree_map(jnp.add, a, b)


def tree_zeros_like(a):
    return jax.tree_util.tree_map(jnp.zeros_like, a)


def tree_mask_slots(tree, slot_mask: jax.Array):
    """Zero every slot not in the mask (used to retire one trainer's
    accumulated gradients after its apply)."""
    return jax.tree_util.tree_map(
        lambda x: x * _mask_like(x, slot_mask).astype(x.dtype), tree)
