"""Training substrate: optimizer and interruptible multi-LoRA trainers."""
