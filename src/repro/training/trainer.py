"""Interruptible multi-LoRA trainers sharing one computation flow.

Each ``MixedLoraTrainer`` owns one adapter slot, walks its dataset in
microbatch rows, and tracks its own gradient-accumulation window — several
trainers' rows ride in the same unified batch and share a single backward
pass, while the masked optimizer keeps their parameter updates isolated
(the functional ``MixedLoRAModelForTrainer``).

Trainers are interruptible by construction: the engine may give a trainer a
zero row-budget for any number of ticks (inference load spike), and training
resumes exactly where it stopped; void/unvoid migrates the adapter plus the
trainer cursor.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.core.flow import FTRow


@dataclasses.dataclass
class TrainerConfig:
    rows_per_micro: int = 2          # per_device_train_batch_size
    accum_steps: int = 4             # gradient_accumulation_steps
    epochs: int = 4
    eval_each_epoch: bool = True
    eval_rows_per_micro: int = 2


class MixedLoraTrainer:
    def __init__(self, name: str, slot: int,
                 train_data: Sequence[Tuple[np.ndarray, np.ndarray]],
                 eval_data: Sequence[Tuple[np.ndarray, np.ndarray]] = (),
                 tcfg: Optional[TrainerConfig] = None,
                 aux_embed: Optional[np.ndarray] = None):
        self.name, self.slot = name, slot
        self.train_data, self.eval_data = list(train_data), list(eval_data)
        self.cfg = tcfg or TrainerConfig()
        self.aux_embed = aux_embed
        self.epoch = 0
        self.cursor = 0
        self.phase = "train"          # train | eval
        self.eval_cursor = 0
        self.rows_since_update = 0
        self.train_losses: List[float] = []
        self.eval_losses: List[float] = []
        self.tokens_trained = 0
        self.tokens_evaled = 0
        self.optimizer_steps = 0

    # ------------------------------------------------------------------
    def pending(self) -> bool:
        return self.epoch < self.cfg.epochs

    @property
    def rows_per_apply(self) -> int:
        return self.cfg.rows_per_micro * self.cfg.accum_steps

    def next_rows(self, budget: int) -> List[FTRow]:
        """Up to ``budget`` rows of work (train or eval, per current phase)."""
        if not self.pending() or budget <= 0:
            return []
        rows: List[FTRow] = []
        if self.phase == "train":
            take = min(budget, self.cfg.rows_per_micro,
                       len(self.train_data) - self.cursor)
            for _ in range(take):
                toks, labels = self.train_data[self.cursor]
                rows.append(FTRow(tokens=toks, labels=labels, slot=self.slot,
                                  weight=1.0 / self.cfg.accum_steps,
                                  trainer=self.name, is_eval=False,
                                  aux_embed=self.aux_embed))
                self.cursor += 1
        else:
            take = min(budget, self.cfg.eval_rows_per_micro,
                       len(self.eval_data) - self.eval_cursor)
            for _ in range(take):
                toks, labels = self.eval_data[self.eval_cursor]
                rows.append(FTRow(tokens=toks, labels=labels, slot=self.slot,
                                  weight=0.0, trainer=self.name, is_eval=True,
                                  aux_embed=self.aux_embed))
                self.eval_cursor += 1
        return rows

    def record(self, rows: List[FTRow], losses: List[float],
               counts: List[float]) -> bool:
        """Account executed rows; returns True when this trainer's gradient
        accumulation window is full (engine should apply the optimizer)."""
        apply = False
        for r, l, c in zip(rows, losses, counts):
            if r.is_eval:
                self.eval_losses.append(l)
                self.tokens_evaled += int(c)
            else:
                self.train_losses.append(l)
                self.tokens_trained += int(c)
                self.rows_since_update += 1
        if self.rows_since_update >= self.rows_per_apply:
            self.rows_since_update = 0
            self.optimizer_steps += 1
            apply = True
        self._advance_phase()
        return apply

    def _advance_phase(self):
        if self.phase == "train" and self.cursor >= len(self.train_data):
            if self.cfg.eval_each_epoch and self.eval_data:
                self.phase = "eval"
                self.eval_cursor = 0
            else:
                self._next_epoch()
        elif self.phase == "eval" and self.eval_cursor >= len(self.eval_data):
            self._next_epoch()

    def _next_epoch(self):
        self.epoch += 1
        self.cursor = 0
        self.phase = "train"

    def force_apply_pending(self) -> bool:
        """Flush a partial accumulation window (end of training)."""
        if self.rows_since_update > 0:
            self.rows_since_update = 0
            self.optimizer_steps += 1
            return True
        return False
