"""Roofline terms from compiled XLA artifacts (no real hardware needed).

Hardware model: TPU v5e — 197 TFLOP/s bf16 per chip, 819 GB/s HBM per chip,
~50 GB/s per ICI link.  ``cost_analysis`` supplies per-device HLO FLOPs and
bytes; collective bytes are NOT in cost_analysis, so we parse the
post-optimization HLO text and sum operand sizes of every all-gather /
all-reduce / reduce-scatter / all-to-all / collective-permute.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional

PEAK_FLOPS = 197e12          # bf16 per chip
HBM_BW = 819e9               # bytes/s per chip
LINK_BW = 50e9               # bytes/s per ICI link (conservative: 1 link)

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    b = _DTYPE_BYTES.get(dtype)
    if b is None:
        return 0
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * b


_OPNAME_RE = re.compile(r'op_name="([^"]*)"')


def collective_bytes(hlo_text: str, loop_trips=()) -> Dict[str, Dict[str, int]]:
    """Per-collective-kind operand bytes summed over the per-device program.

    XLA lists each while-loop body computation ONCE; an op whose op_name
    metadata sits inside k nested ``/while`` scopes executes
    prod(loop_trips[:k]) times.  Returns {"raw": {...}, "scaled": {...}} —
    raw is the body-once sum, scaled multiplies by the enclosing trip counts
    (loop_trips = (n_periods, inner, ...); missing entries count as 1).
    """
    raw = {k: 0 for k in _COLLECTIVES}
    scaled = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        stripped = line.strip()
        for kind in _COLLECTIVES:
            # match the opcode invocation, not tuple-element accessors;
            # XLA prints operands WITHOUT types, so measure the RESULT
            # shapes on the lhs (== operand bytes for all-reduce /
            # all-to-all / collective-permute; == gathered bytes for
            # all-gather; *n for reduce-scatter — close enough for a
            # wire-traffic roofline).
            idx = stripped.find(f" {kind}(")
            if idx < 0:
                idx = stripped.find(f" {kind}-start(")
            if idx < 0:
                continue
            lhs = stripped[:idx]
            total = sum(_shape_bytes(dt, dims)
                        for dt, dims in _SHAPE_RE.findall(lhs))
            m = _OPNAME_RE.search(stripped)
            depth = m.group(1).count("/while") if m else 0
            mult = 1
            for i in range(depth):
                mult *= loop_trips[i] if i < len(loop_trips) else 1
            raw[kind] += total
            scaled[kind] += total * mult
            break
    return {"raw": raw, "scaled": scaled}


@dataclasses.dataclass
class Roofline:
    flops: float                 # per-device HLO FLOPs
    hbm_bytes: float             # per-device bytes accessed
    coll_bytes: float            # per-device collective operand bytes
    chips: int
    model_flops: float = 0.0     # 6*N*D (train) / 2*N*D (inference), global

    @property
    def compute_s(self) -> float:
        return self.flops / PEAK_FLOPS

    @property
    def memory_s(self) -> float:
        return self.hbm_bytes / HBM_BW

    @property
    def collective_s(self) -> float:
        return self.coll_bytes / LINK_BW

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / (per-device HLO FLOPs x chips)."""
        total = self.flops * self.chips
        return self.model_flops / total if total else 0.0

    def as_dict(self) -> Dict:
        return {
            "flops_per_device": self.flops,
            "hbm_bytes_per_device": self.hbm_bytes,
            "collective_bytes_per_device": self.coll_bytes,
            "chips": self.chips,
            "model_flops_global": self.model_flops,
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "dominant": self.dominant,
            "useful_flops_ratio": self.useful_flops_ratio,
        }


def hlo_cost(compiled) -> Dict[str, float]:
    """Raw cost_analysis numbers (NOTE: while-loop bodies counted once)."""
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0]
    return {"flops": float(cost.get("flops", 0.0)),
            "bytes_accessed": float(cost.get("bytes accessed", 0.0))}


def memory_summary(compiled) -> Dict:
    ma = compiled.memory_analysis()
    keys = ("argument_size_in_bytes", "output_size_in_bytes",
            "temp_size_in_bytes", "alias_size_in_bytes",
            "generated_code_size_in_bytes")
    out = {}
    for k in keys:
        v = getattr(ma, k, None)
        if v is not None:
            out[k] = int(v)
    tot = (out.get("argument_size_in_bytes", 0)
           + out.get("output_size_in_bytes", 0)
           + out.get("temp_size_in_bytes", 0)
           - out.get("alias_size_in_bytes", 0))
    out["peak_estimate_bytes"] = tot
    return out
