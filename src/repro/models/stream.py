"""Unified token-stream batch types (the paper's four request kinds).

Fine-tune and evaluation requests share ``FTBatch`` (the paper notes they are
structurally identical; evaluation rows simply carry no gradient — the trainer
controls that).  Buckets are optional: any subset of (ft, pf, dec) may be
present, each with static shapes so every bucket combination compiles once.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax

Array = jax.Array


class FTBatch(NamedTuple):
    tokens: Array                    # [Bf, Sf] int32 (right-padded)
    mask: Array                      # [Bf, Sf] bool  valid tokens
    labels: Array                    # [Bf, Sf] int32 (-100 = ignore)
    adapter: Array                   # [Bf] int32 (-1 = base only)
    weight: Array                    # [Bf] f32 per-row loss scale (1/accum)
    aux_embed: Optional[Array] = None  # [Bf, F, d] modality stub embeddings


class PFBatch(NamedTuple):
    """Prefill bucket.  With ``cached_len`` set (paged layout only), rows
    are *suffixes*: ``tokens``/``length`` cover only the uncached span of
    each prompt, whose absolute positions start at ``cached_len`` — the
    shared-prefix (or earlier-chunk) K/V already sits in the request's
    blocks and is read, not recomputed.  RoPE, the causal mask, validity,
    and last-token logit extraction are all offset by the cached span."""
    tokens: Array                    # [Bp, Sp] int32 (right-padded)
    length: Array                    # [Bp] int32 true (suffix) lengths
    adapter: Array                   # [Bp] int32
    aux_embed: Optional[Array] = None  # [Bp, F, d]
    block_tables: Optional[Array] = None  # [Bp, nbt] int32 (paged KV layout;
    #                                  null-padded with block 0); None = dense
    cached_len: Optional[Array] = None  # [Bp] int32 tokens of prefix K/V
    #                                  already valid in the blocks; None = 0


class DECBatch(NamedTuple):
    """Decode/verify bucket.  ``tokens`` is ``[Bd]`` for plain one-token
    decode, or ``[Bd, Sd]`` for the speculative *verify* chunk: each row
    carries its current token plus up to ``Sd - 1`` drafted tokens, verified
    in ONE forward (the prefill varlen idea applied along the time axis).
    ``length`` gives each row's real chunk length (1 = plain decode row,
    0 = padding row); trailing positions are inert (writes land on the null
    block under the paged layout)."""
    tokens: Array                    # [Bd] or [Bd, Sd] int32
    pos: Array                       # [Bd] int32 start positions (= cache len)
    adapter: Array                   # [Bd] int32
    block_tables: Optional[Array] = None  # [Bd, nbt] int32; None = dense
    length: Optional[Array] = None   # [Bd] int32 valid chunk lengths


class UnifiedBatch(NamedTuple):
    ft: Optional[FTBatch] = None
    pf: Optional[PFBatch] = None
    dec: Optional[DECBatch] = None


class ModelOut(NamedTuple):
    ft_loss_sum: Optional[Array]     # [Bf] f32 summed token CE (shifted)
    ft_tok_count: Optional[Array]    # [Bf] f32 valid target tokens
    ft_logits: Optional[Array]       # [Bf, Sf, V] (only if requested)
    pf_logits: Optional[Array]       # [Bp, V] logits at last valid position
    dec_logits: Optional[Array]      # [Bd, V]; [Bd, Sd, V] for verify chunks
    cache: Optional[dict]
    aux_loss: Array                  # scalar (MoE load-balance etc.)
