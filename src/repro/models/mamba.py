"""Mamba2 / SSD (state-space duality) block [arXiv:2405.21060].

Training/prefill use the chunked SSD algorithm (intra-chunk attention-like
form + inter-chunk state recurrence carried by ``lax.scan``); decode is the
O(1) single-step recurrence.  Padding is handled by forcing ``dt = 0`` on pad
tokens, which makes the recurrence a no-op (decay 1, update 0).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.configs import ModelConfig


def causal_conv(x: jax.Array, w: jax.Array, b: jax.Array,
                state: Optional[jax.Array]) -> Tuple[jax.Array, jax.Array]:
    """Depthwise causal conv1d.  x: [B, S, C]; w: [K, C]; state: [B, K-1, C].
    Returns (y [B,S,C], new_state [B,K-1,C])."""
    K = w.shape[0]
    if state is None:
        state = jnp.zeros((x.shape[0], K - 1, x.shape[-1]), x.dtype)
    xp = jnp.concatenate([state, x], axis=1)                     # [B, S+K-1, C]
    S = x.shape[1]
    y = sum(xp[:, i:i + S] * w[i].astype(x.dtype) for i in range(K))
    y = y + b.astype(x.dtype)
    new_state = xp[:, -(K - 1):] if K > 1 else state
    return y, new_state


def ssd_chunked(x: jax.Array, dt: jax.Array, A: jax.Array, Bm: jax.Array,
                Cm: jax.Array, chunk: int,
                h0: Optional[jax.Array] = None
                ) -> Tuple[jax.Array, jax.Array]:
    """Chunked SSD scan.

    x:  [B, S, nh, hd]   inputs per head
    dt: [B, S, nh]       softplus'd step sizes (0 on pad tokens)
    A:  [nh]             negative per-head decay rates
    Bm/Cm: [B, S, nh, ds] input/output projections (groups pre-expanded)
    h0: [B, nh, hd, ds]  initial state
    Returns (y [B,S,nh,hd], h_final [B,nh,hd,ds]).
    """
    Bsz, S, nh, hd = x.shape
    ds = Bm.shape[-1]
    pad = (-S) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0), (0, 0)))
    Sp = S + pad
    nc = Sp // chunk
    # [B, nc, Q, ...]
    xq = x.reshape(Bsz, nc, chunk, nh, hd)
    dtq = dt.reshape(Bsz, nc, chunk, nh).astype(jnp.float32)
    Bq = Bm.reshape(Bsz, nc, chunk, nh, ds)
    Cq = Cm.reshape(Bsz, nc, chunk, nh, ds)

    if h0 is None:
        h0 = jnp.zeros((Bsz, nh, hd, ds), x.dtype)
    causal = jnp.tril(jnp.ones((chunk, chunk), bool))

    # One chunk per scan step; intra-chunk work happens INSIDE the
    # (checkpointed) body so the [Q, Q] decay matrix never materialises for
    # more than one chunk at a time — bounding both forward transients and
    # backward residuals.
    def body(h, xs):
        xc, dtc, Bc, Cc = xs               # [B,Q,nh,hd], [B,Q,nh], [B,Q,nh,ds]
        dA = dtc * A.astype(jnp.float32)                         # [B,Q,nh]
        cs = jnp.cumsum(dA, axis=1)                              # inclusive
        total = cs[:, -1, :]                                     # [B,nh]
        # intra: M[t,s] = exp(cs_t - cs_s) * dt_s * (C_t . B_s), s <= t
        cb = jnp.einsum("bqhd,bkhd->bhqk", Cc, Bc).astype(jnp.float32)
        delta = cs.transpose(0, 2, 1)[:, :, :, None] \
            - cs.transpose(0, 2, 1)[:, :, None, :]               # [B,nh,q,k]
        M = jnp.where(causal, jnp.exp(delta)
                      * dtc.transpose(0, 2, 1)[:, :, None, :], 0.0) * cb
        y_c = jnp.einsum("bhqk,bkhd->bqhd", M.astype(xc.dtype), xc)
        # inter: y_t += C_t . (exp(cs_t) * h_start)
        w_out = jnp.exp(cs)                                      # [B,Q,nh]
        y_c = y_c + jnp.einsum("bqhd,bhpd,bqh->bqhp", Cc, h,
                               w_out.astype(xc.dtype))
        # state update: h' = exp(total) h + sum_s exp(total - cs_s) dt_s B_s x_s
        w_in = (jnp.exp(total[:, None, :] - cs) * dtc).astype(xc.dtype)
        ingest = jnp.einsum("bkh,bkhd,bkhp->bhpd", w_in, Bc, xc)
        h_new = h * jnp.exp(total).astype(h.dtype)[:, :, None, None] + ingest
        return h_new, y_c

    xs = (xq.transpose(1, 0, 2, 3, 4), dtq.transpose(1, 0, 2, 3),
          Bq.transpose(1, 0, 2, 3, 4), Cq.transpose(1, 0, 2, 3, 4))
    h_final, ys = jax.lax.scan(jax.checkpoint(body), h0, xs)
    y = ys.transpose(1, 0, 2, 3, 4).reshape(Bsz, Sp, nh, hd)[:, :S]
    return y, h_final


def ssd_step(h: jax.Array, x_t: jax.Array, dt_t: jax.Array, A: jax.Array,
             B_t: jax.Array, C_t: jax.Array
             ) -> Tuple[jax.Array, jax.Array]:
    """Single decode step.  h: [B,nh,hd,ds]; x_t: [B,nh,hd]; dt_t: [B,nh];
    B_t/C_t: [B,nh,ds].  Returns (y [B,nh,hd], h_new)."""
    dA = dt_t.astype(jnp.float32) * A.astype(jnp.float32)        # [B,nh]
    decay = jnp.exp(dA).astype(h.dtype)[:, :, None, None]
    update = jnp.einsum("bh,bhp,bhd->bhpd",
                        dt_t.astype(x_t.dtype), x_t, B_t)
    h_new = h * decay + update
    y = jnp.einsum("bhpd,bhd->bhp", h_new, C_t)
    return y, h_new


def split_zxbcdt(cfg: ModelConfig, zxbcdt: jax.Array):
    """Split the fused in-projection output along its last axis."""
    s = cfg.ssm
    di = cfg.d_inner
    gds = s.n_groups * s.d_state
    nh = cfg.n_ssm_heads
    z, xs, b, c, dt = jnp.split(
        zxbcdt, [di, 2 * di, 2 * di + gds, 2 * di + 2 * gds], axis=-1)
    return z, xs, b, c, dt


def expand_groups(t: jax.Array, nh: int) -> jax.Array:
    """[..., g, ds] -> [..., nh, ds] by repeating each group nh//g times."""
    g = t.shape[-2]
    return jnp.repeat(t, nh // g, axis=-2)
