"""Weight-only int8 quantization for serving (beyond-paper, §Perf HC1-iter3).

Decode at 100B scale is bound by weight traffic (HBM reads + FSDP gathers),
not FLOPs — storing matrix weights as per-output-channel symmetric int8
halves both.  Dequantization happens INSIDE the layer scan on the current
period's slice only, so HBM holds int8 and only one layer's bf16 weights
exist transiently.

A quantized leaf is a dict ``{"_q8": int8[..., d_in, d_out],
"_qs": f32[..., 1, d_out]}``; everything else (norms, biases, small vectors)
stays in the original dtype.  Training keeps bf16 — this is a serving
feature (the LoRA bank is never quantized: adapters must stay trainable and
hot-swappable).
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.models.configs import ModelConfig
from repro.models.schema import P, _is_p, build_schema


def _eligible(p: P) -> bool:
    """Quantize big matmul weights only (gaussian-init, >=2D, wide)."""
    return (p.init == "normal" and len(p.shape) >= 2
            and p.shape[-1] >= 64 and p.shape[-2] >= 64)


def is_q8(node) -> bool:
    return isinstance(node, dict) and "_q8" in node


def quantize_leaf(w: jax.Array) -> Dict[str, jax.Array]:
    s = jnp.max(jnp.abs(w.astype(jnp.float32)), axis=-2, keepdims=True) / 127.0
    s = jnp.maximum(s, 1e-8)
    q = jnp.clip(jnp.round(w.astype(jnp.float32) / s), -127, 127
                 ).astype(jnp.int8)
    return {"_q8": q, "_qs": s.astype(jnp.float32)}


def dequant_leaf(node, dtype=jnp.bfloat16) -> jax.Array:
    return (node["_q8"].astype(jnp.float32) * node["_qs"]).astype(dtype)


def quantize_params(cfg: ModelConfig, params) -> Any:
    """Quantize eligible leaves of a materialised param tree."""
    schema = build_schema(cfg)

    def walk(node, spec):
        if _is_p(spec):
            return quantize_leaf(node) if _eligible(spec) else node
        if isinstance(spec, dict):
            return {k: walk(node[k], v) for k, v in spec.items()}
        if isinstance(spec, tuple):
            return tuple(walk(n, v) for n, v in zip(node, spec))
        return node

    return walk(params, schema)


def abstract_quantized(cfg: ModelConfig, dtype=jnp.bfloat16) -> Any:
    """ShapeDtypeStruct tree of the quantized layout (for the dry-run)."""
    schema = build_schema(cfg)

    def leaf(p: P):
        if _eligible(p):
            return {"_q8": jax.ShapeDtypeStruct(p.shape, jnp.int8),
                    "_qs": jax.ShapeDtypeStruct((*p.shape[:-2], 1, p.shape[-1]),
                                                jnp.float32)}
        return jax.ShapeDtypeStruct(p.shape, dtype)

    return jax.tree_util.tree_map(leaf, schema, is_leaf=_is_p)


def quant_shardings(cfg: ModelConfig, mesh, strategy: str = "fsdp_tp") -> Any:
    """Shardings matching ``abstract_quantized``: int8 payload inherits the
    bf16 leaf's spec; scales inherit it minus the (reduced) input dim."""
    from jax.sharding import NamedSharding, PartitionSpec
    from repro.distributed.sharding import _spec_for
    schema = build_schema(cfg)

    def leaf(p: P):
        spec = _spec_for(cfg, mesh, strategy, p.shape, p.logical)
        if _eligible(p):
            parts = list(spec) + [None] * (len(p.shape) - len(spec))
            s_parts = parts[:-2] + [None, parts[-1]]
            return {"_q8": NamedSharding(mesh, PartitionSpec(*parts)),
                    "_qs": NamedSharding(mesh, PartitionSpec(*s_parts))}
        return NamedSharding(mesh, spec)

    return jax.tree_util.tree_map(leaf, schema, is_leaf=_is_p)


def dequant_tree(node, dtype=jnp.bfloat16, specs=None):
    """Dequantize every _q8 leaf in a (sub)tree; identity on bf16 trees.
    ``specs`` (optional, same structure with PartitionSpec leaves) constrains
    the INT8 payload to its post-gather sharding before the convert — so the
    FSDP all-gather moves int8 bytes, not the dequantized bf16 (2x wire
    saving; GSPMD otherwise sinks the gather below the convert)."""
    if is_q8(node):
        if specs is not None:
            q8 = jax.lax.with_sharding_constraint(node["_q8"], specs)
            node = {"_q8": q8, "_qs": node["_qs"]}
        return dequant_leaf(node, dtype)
    if isinstance(node, dict):
        return {k: dequant_tree(v, dtype,
                                specs.get(k) if isinstance(specs, dict)
                                else None)
                for k, v in node.items()}
    if isinstance(node, (tuple, list)):
        return type(node)(dequant_tree(v, dtype,
                                       specs[i] if specs is not None else None)
                          for i, v in enumerate(node))
    return node


def block_gather_specs(cfg: ModelConfig):
    """Per-pattern-position {leaf: PartitionSpec} for the period-sliced int8
    payloads: the parameter spec with the leading periods axis dropped and
    every "data" entry removed (keep TP, gather FSDP as int8).  Returns None
    when no mesh is in scope (CPU tests)."""
    from jax.sharding import PartitionSpec
    from repro.distributed.sharding import ambient_mesh
    am = ambient_mesh()
    if am is None or am.empty or "model" not in am.axis_names:
        return None
    from repro.distributed.sharding import _spec_for

    class _M:
        shape = {a: am.shape[a] for a in am.axis_names}
    schema = build_schema(cfg)

    def walk(node):
        if _is_p(node):
            if not _eligible(node):
                return None
            spec = _spec_for(cfg, _M(), "fsdp_tp", node.shape, node.logical)
            parts = [None if a == "data" else a for a in list(spec)[1:]]
            parts += [None] * (len(node.shape) - 1 - len(parts))
            return PartitionSpec(*parts)
        if isinstance(node, dict):
            return {k: walk(v) for k, v in node.items()}
        if isinstance(node, tuple):
            return tuple(walk(v) for v in node)
        return None

    return walk(schema["blocks"])


def has_q8(tree) -> bool:
    found = False

    def walk(node):
        nonlocal found
        if is_q8(node):
            found = True
            return
        if isinstance(node, dict):
            for v in node.values():
                walk(v)
        elif isinstance(node, (tuple, list)):
            for v in node:
                walk(v)

    walk(tree)
    return found
