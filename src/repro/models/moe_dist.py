"""Explicit expert-parallel MoE (shard_map + all-to-all).

GSPMD cannot partition the sort-based dispatch scatter — it replicates the
full [G, Sk, d] update tensor across the mesh (observed: a 64 GiB all-gather
per MoE layer on jamba's train shape).  So the distributed path is explicit
SPMD: every device routes and packs ITS OWN tokens locally (scatter over a
[E, cap_local, d] buffer is device-local), one all-to-all over the "model"
axis re-shards expert buffers from token-major to expert-major, the expert
FFN runs as a dense local einsum against the device's expert slice, and a
reverse all-to-all brings results home.  Collective volume per device is
2 x k x cap_factor x T_local x d bytes — the textbook expert-parallel
schedule the paper's MoE-serving setting implies.

Falls back to ``moe.moe_apply`` (grouped vmap) when no mesh is in scope
(single-device tests / the CPU engine).
"""
from __future__ import annotations

import functools
import math
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.configs import MoEConfig
from repro.models.moe import load_balance_loss, moe_apply


def _dist_axes():
    from repro.distributed.sharding import ambient_mesh
    am = ambient_mesh()
    if am is None or am.empty or "model" not in am.axis_names:
        return None
    bx = tuple(a for a in ("pod", "data") if a in am.axis_names)
    return am, bx


def moe_apply_auto(x: jax.Array, params: dict, mcfg: MoEConfig,
                   fsdp: bool = True) -> Tuple[jax.Array, jax.Array]:
    """Dispatch: shard_map expert parallelism under a mesh, vmap fallback
    otherwise."""
    ctx = _dist_axes()
    if ctx is None:
        return moe_apply(x, params, mcfg)
    am, bx = ctx
    msize = am.shape["model"]
    E = mcfg.num_experts
    T = x.shape[0]
    chips = msize
    for a in bx:
        chips *= am.shape[a]
    if E % msize != 0 or T % chips != 0:
        return moe_apply(x, params, mcfg)

    # aux loss on the full (GSPMD-sharded) token stream — tiny einsum
    logits = x @ params["router"].astype(x.dtype)
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    _, idx_full = jax.lax.top_k(probs, mcfg.top_k)
    aux = load_balance_loss(probs, idx_full, E) * mcfg.router_aux_weight

    tok_spec = P((*bx, "model"), None)
    dsize = am.shape["data"] if "data" in am.axis_names else 1
    d = x.shape[1]
    w_embed_spec = "data" if (fsdp and d % dsize == 0) else None
    w_in_spec = P("model", w_embed_spec, None)     # [E, d, f]
    w_out_spec = P("model", None, w_embed_spec)    # [E, f, d]
    r_spec = P(w_embed_spec, "model" if E % msize == 0 else None)

    cap = max(int(math.ceil((T // chips) * mcfg.top_k / E
                            * mcfg.capacity_factor)), 1)

    if hasattr(jax, "shard_map"):
        smap, sm_kw = jax.shard_map, {"check_vma": False}
    else:                              # jax < 0.5: experimental namespace
        from jax.experimental.shard_map import shard_map as smap
        sm_kw = {"check_rep": False}

    @functools.partial(
        smap, mesh=am,
        in_specs=(tok_spec, r_spec, w_in_spec, w_in_spec, w_out_spec),
        out_specs=tok_spec, **sm_kw)
    def inner(xb, rb, wgb, wub, wdb):
        # un-FSDP the weight blocks (the manual analogue of GSPMD's
        # per-layer FSDP all-gather)
        if w_embed_spec is not None:
            wgb = jax.lax.all_gather(wgb, "data", axis=1, tiled=True)
            wub = jax.lax.all_gather(wub, "data", axis=1, tiled=True)
            wdb = jax.lax.all_gather(wdb, "data", axis=2, tiled=True)
            rb = jax.lax.all_gather(rb, "data", axis=0, tiled=True)
        if E % msize == 0 and rb.shape[1] != E:
            rb = jax.lax.all_gather(rb, "model", axis=1, tiled=True)
        Tl, dd = xb.shape
        k = mcfg.top_k
        lg = xb @ rb.astype(xb.dtype)
        pr = jax.nn.softmax(lg.astype(jnp.float32), axis=-1)
        gates, idx = jax.lax.top_k(pr, k)
        gates = (gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
                 ).astype(xb.dtype)
        flat_e = idx.reshape(Tl * k)
        tok_of = jnp.arange(Tl * k, dtype=jnp.int32) // k
        order = jnp.argsort(flat_e)
        sorted_e = flat_e[order]
        seg = jnp.searchsorted(sorted_e, jnp.arange(E, dtype=sorted_e.dtype))
        pos_sorted = jnp.arange(Tl * k, dtype=jnp.int32) - seg[sorted_e]
        pos = jnp.zeros((Tl * k,), jnp.int32).at[order].set(pos_sorted)
        buf = jnp.zeros((E, cap, dd), xb.dtype)
        buf = buf.at[flat_e, pos].add(xb[tok_of], mode="drop")
        # token-major -> expert-major: [E, cap, d] -> [E/m, m*cap, d]
        buf = jax.lax.all_to_all(buf, "model", split_axis=0, concat_axis=1,
                                 tiled=True)
        h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf,
                                   wgb.astype(xb.dtype)))
        h = h * jnp.einsum("ecd,edf->ecf", buf, wub.astype(xb.dtype))
        out = jnp.einsum("ecf,efd->ecd", h, wdb.astype(xb.dtype))
        out = jax.lax.all_to_all(out, "model", split_axis=1, concat_axis=0,
                                 tiled=True)
        kept = (pos < cap)
        y_tok = out[flat_e, jnp.minimum(pos, cap - 1)]
        y_tok = jnp.where(kept[:, None], y_tok, 0.0)
        return jnp.einsum("tkd,tk->td", y_tok.reshape(Tl, k, dd), gates)

    y = inner(x, params["router"], params["w_gate"], params["w_up"],
              params["w_down"])
    return y, aux.astype(jnp.float32)
