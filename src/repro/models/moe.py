"""Mixture-of-Experts layer with sort-based (scatter/gather) dispatch.

Tokens are routed top-k, sorted by expert, and packed into a static-capacity
buffer [E, C, d]; expert FFNs run as one batched einsum so the ``experts``
axis shards cleanly over the mesh "model" axis (expert parallelism).  Tokens
over capacity are dropped (standard capacity-factor semantics); the router
aux loss balances load during fine-tuning.
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.configs import MoEConfig


def router_topk(logits: jax.Array, k: int) -> Tuple[jax.Array, jax.Array]:
    """Softmax-then-top-k routing (DeepSeek/Jamba style), gates renormalised."""
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    gates, idx = jax.lax.top_k(probs, k)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
    return gates, idx


def load_balance_loss(probs: jax.Array, idx: jax.Array, n_experts: int
                      ) -> jax.Array:
    """Switch-style aux loss: E * sum_e f_e * p_e."""
    assign = jax.nn.one_hot(idx, n_experts, dtype=jnp.float32)    # [T,k,E]
    f = assign.sum(axis=(0, 1)) / jnp.maximum(assign.sum(), 1.0)
    p = probs.mean(axis=0)
    return n_experts * jnp.sum(f * p)


GROUP_TOKENS = 4096      # dispatch group size (capacity is per group)
DROPLESS_MAX = 512       # groups with <= this many routed slots never drop:
# capacity dropping is a training-throughput trade, and it makes outputs
# depend on batch composition — decode-sized groups must be exact so
# prefill-then-decode equals a single full forward


def _group_dispatch(xg: jax.Array, idx: jax.Array, gates: jax.Array,
                    params: dict, cap: int, E: int, k: int):
    """Per-group sort-based pack -> expert einsum -> unpack.

    xg: [S, d]; idx/gates: [S, k].  vmapped over groups, so all scatter /
    gather indices are group-LOCAL — the batched ops keep their group dim
    shardable over the data axes (a global-index gather would force GSPMD to
    replicate the full activation tensor).
    """
    S, d = xg.shape
    flat_e = idx.reshape(S * k)
    tok_of = jnp.arange(S * k, dtype=jnp.int32) // k
    order = jnp.argsort(flat_e)
    sorted_e = flat_e[order]
    seg_start = jnp.searchsorted(sorted_e, jnp.arange(E, dtype=sorted_e.dtype))
    pos_sorted = jnp.arange(S * k, dtype=jnp.int32) - seg_start[sorted_e]
    pos = jnp.zeros((S * k,), jnp.int32).at[order].set(pos_sorted)

    buf = jnp.zeros((E, cap, d), xg.dtype)
    # scatter-ADD, not set: (expert, pos) pairs are injective by
    # construction, so add==set — but add's VJP is a plain gather, while
    # set's VJP materialises u32 duplicate-winner buffers of the full
    # [E, C, d] operand shape (hundreds of GiB at 1M-token batches).
    buf = buf.at[flat_e, pos].add(xg[tok_of], mode="drop")
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf,
                               params["w_gate"].astype(xg.dtype)))
    h = h * jnp.einsum("ecd,edf->ecf", buf, params["w_up"].astype(xg.dtype))
    out = jnp.einsum("ecf,efd->ecd", h, params["w_down"].astype(xg.dtype))

    kept = (pos < cap)
    y_tok = out[flat_e, jnp.minimum(pos, cap - 1)]                # [Sk, d]
    y_tok = jnp.where(kept[:, None], y_tok, 0.0)
    return jnp.einsum("tkd,tk->td", y_tok.reshape(S, k, d), gates)


def moe_apply(x: jax.Array, params: dict, mcfg: MoEConfig
              ) -> Tuple[jax.Array, jax.Array]:
    """x: [T, d] -> (y: [T, d], aux_loss scalar).

    params: router [d,E]; w_gate/w_up [E,d,f]; w_down [E,f,d].
    Tokens are split into dispatch groups of ~GROUP_TOKENS; groups shard
    over the data axes, experts over "model" (expert parallelism) — GSPMD
    inserts the all-to-all at the group/expert resharding boundary.
    """
    T, d = x.shape
    E, k = mcfg.num_experts, mcfg.top_k
    logits = x @ params["router"].astype(x.dtype)                 # [T, E]
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    gates, idx = jax.lax.top_k(probs, k)
    gates = (gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
             ).astype(x.dtype)
    aux = load_balance_loss(probs, idx, E) * mcfg.router_aux_weight

    G = max(T // GROUP_TOKENS, 1)
    while T % G:
        G -= 1
    S = T // G
    if S * k <= DROPLESS_MAX:
        cap = S * k          # worst case: every token on one expert
    else:
        cap = max(int(math.ceil(S * k / E * mcfg.capacity_factor)), 1)
    from repro.distributed.sharding import maybe_constrain
    xg = x.reshape(G, S, d)
    wts = {kk: params[kk] for kk in ("w_gate", "w_up", "w_down")}
    y = jax.vmap(lambda xb, ib, gb: _group_dispatch(xb, ib, gb, wts,
                                                    cap, E, k)
                 )(xg, idx.reshape(G, S, k), gates.reshape(G, S, k))
    y = y.reshape(T, d)
    return y, aux.astype(jnp.float32)


def moe_apply_dense_ref(x: jax.Array, params: dict, mcfg: MoEConfig
                        ) -> jax.Array:
    """Capacity-free oracle: every expert computed for every token, combined
    with routing gates.  Used by tests to bound the dispatch drop error."""
    T, d = x.shape
    E, k = mcfg.num_experts, mcfg.top_k
    logits = x @ params["router"].astype(x.dtype)
    gates, idx = router_topk(logits, k)
    h = jax.nn.silu(jnp.einsum("td,edf->tef", x, params["w_gate"].astype(x.dtype)))
    h = h * jnp.einsum("td,edf->tef", x, params["w_up"].astype(x.dtype))
    out = jnp.einsum("tef,efd->ted", h, params["w_down"].astype(x.dtype))
    comb = jnp.zeros((T, E), x.dtype)
    comb = comb.at[jnp.arange(T)[:, None], idx].set(gates.astype(x.dtype))
    y = jnp.einsum("ted,te->td", out, comb)
    if "shared_wg" in params:
        y = y + (jax.nn.silu(x @ params["shared_wg"].astype(x.dtype))
                 * (x @ params["shared_wu"].astype(x.dtype))
                 ) @ params["shared_wd"].astype(x.dtype)
    return y
