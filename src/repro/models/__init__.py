"""Model substrate: configs, layers, and the generic scanned decoder that
serves every assigned architecture family."""
