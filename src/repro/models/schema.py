"""Parameter schema: one declarative walker produces (a) initialised params,
(b) abstract ShapeDtypeStructs for the dry-run, (c) PartitionSpecs from
logical-axis rules, and (d) the LoRA-target table — so all four can never
drift apart.

Layers are stacked over a leading ``periods`` axis (scan axis).  Logical axis
names used here are mapped to mesh axes by ``repro.distributed.sharding``.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.configs import ModelConfig


@dataclasses.dataclass(frozen=True)
class P:
    shape: Tuple[int, ...]
    logical: Tuple[Optional[str], ...]
    init: str = "normal"          # normal | zeros | ones | a_log | dt_bias
    scale: float = 0.02
    lora: bool = False            # eligible LoRA target (last 2 dims = in/out)

    def __post_init__(self):
        assert len(self.shape) == len(self.logical), (self.shape, self.logical)


def _attn_block(cfg: ModelConfig, pos: int, stack: int, axis0: str) -> Dict:
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    blk: Dict[str, Any] = {"ln1": P((stack, d), (axis0, None), "ones")}
    if cfg.mla is not None:
        m = cfg.mla
        qd = m.qk_nope_dim + m.qk_rope_dim
        blk["wq"] = P((stack, d, h * qd), (axis0, "embed", "heads"), lora=True)
        blk["wdkv"] = P((stack, d, m.kv_lora_rank + m.qk_rope_dim),
                        (axis0, "embed", None), lora=True)
        blk["wuk"] = P((stack, m.kv_lora_rank, h, m.qk_nope_dim),
                       (axis0, None, "heads_sep", None))
        blk["wuv"] = P((stack, m.kv_lora_rank, h, m.v_head_dim),
                       (axis0, None, "heads_sep", None))
        blk["wo"] = P((stack, h * m.v_head_dim, d), (axis0, "heads", "embed"),
                      lora=True)
    else:
        blk["wq"] = P((stack, d, h * hd), (axis0, "embed", "heads"), lora=True)
        blk["wk"] = P((stack, d, kv * hd), (axis0, "embed", "kv_heads"), lora=True)
        blk["wv"] = P((stack, d, kv * hd), (axis0, "embed", "kv_heads"), lora=True)
        blk["wo"] = P((stack, h * hd, d), (axis0, "heads", "embed"), lora=True)
        if cfg.qkv_bias:
            blk["bq"] = P((stack, h * hd), (axis0, "heads"), "zeros")
            blk["bk"] = P((stack, kv * hd), (axis0, "kv_heads"), "zeros")
            blk["bv"] = P((stack, kv * hd), (axis0, "kv_heads"), "zeros")
    if cfg.is_cross_layer(pos):
        blk["xln"] = P((stack, d), (axis0, None), "ones")
        blk["xwq"] = P((stack, d, h * hd), (axis0, "embed", "heads"), lora=True)
        blk["xwk"] = P((stack, d, kv * hd), (axis0, "embed", "kv_heads"))
        blk["xwv"] = P((stack, d, kv * hd), (axis0, "embed", "kv_heads"))
        blk["xwo"] = P((stack, h * hd, d), (axis0, "heads", "embed"), lora=True)
        blk["xgate"] = P((stack,), (axis0,), "zeros")
    return blk


def _mamba_block(cfg: ModelConfig, stack: int, axis0: str) -> Dict:
    """Head-ALIGNED component projections (z, x, BC, dt are separate weights,
    NOT one fused zxBCdt matrix): the d_inner/head dims then shard cleanly
    over the mesh "model" axis (Mamba tensor parallelism) — a fused
    projection's output crosses component boundaries and would force
    per-layer resharding."""
    d, s = cfg.d_model, cfg.ssm
    di, nh = cfg.d_inner, cfg.n_ssm_heads
    gds = s.n_groups * s.d_state
    return {
        "ln1": P((stack, d), (axis0, None), "ones"),
        "in_z": P((stack, d, di), (axis0, "embed", "ssm"), lora=True),
        "in_x": P((stack, d, di), (axis0, "embed", "ssm"), lora=True),
        "in_bc": P((stack, d, 2 * gds), (axis0, "embed", None)),
        "in_dt": P((stack, d, nh), (axis0, "embed", "ssm_heads")),
        "conv_x": P((stack, s.conv_width, di), (axis0, None, "ssm")),
        "conv_bx": P((stack, di), (axis0, "ssm"), "zeros"),
        "conv_bc": P((stack, s.conv_width, 2 * gds), (axis0, None, None)),
        "conv_bbc": P((stack, 2 * gds), (axis0, None), "zeros"),
        "a_log": P((stack, nh), (axis0, "ssm_heads"), "a_log"),
        "d_skip": P((stack, nh), (axis0, "ssm_heads"), "ones"),
        "dt_bias": P((stack, nh), (axis0, "ssm_heads"), "dt_bias"),
        "mnorm": P((stack, di), (axis0, "ssm"), "ones"),
        "out_proj": P((stack, di, d), (axis0, "ssm", "embed"), lora=True),
    }


def _ffn_block(cfg: ModelConfig, pos: int, stack: int, axis0: str) -> Dict:
    d = cfg.d_model
    out: Dict[str, Any] = {"ln2": P((stack, d), (axis0, None), "ones")}
    if cfg.is_moe_layer(pos):
        e = cfg.moe
        out["router"] = P((stack, d, e.num_experts), (axis0, "embed", "experts"),
                          scale=0.006)
        out["w_gate"] = P((stack, e.num_experts, d, e.d_ff_expert),
                          (axis0, "experts", "embed", "ffn"))
        out["w_up"] = P((stack, e.num_experts, d, e.d_ff_expert),
                        (axis0, "experts", "embed", "ffn"))
        out["w_down"] = P((stack, e.num_experts, e.d_ff_expert, d),
                          (axis0, "experts", "ffn", "embed"))
        if e.num_shared:
            fs = e.num_shared * e.d_ff_expert
            out["shared"] = {
                "wg": P((stack, d, fs), (axis0, "embed", "ffn"), lora=True),
                "wu": P((stack, d, fs), (axis0, "embed", "ffn"), lora=True),
                "wd": P((stack, fs, d), (axis0, "ffn", "embed"), lora=True),
            }
    elif cfg.d_ff > 0:
        out["wg"] = P((stack, d, cfg.d_ff), (axis0, "embed", "ffn"), lora=True)
        out["wu"] = P((stack, d, cfg.d_ff), (axis0, "embed", "ffn"), lora=True)
        out["wd"] = P((stack, cfg.d_ff, d), (axis0, "ffn", "embed"), lora=True)
    else:
        return {}
    return out


def build_schema(cfg: ModelConfig) -> Dict:
    d, V = cfg.d_model, cfg.vocab
    Pn = cfg.n_periods
    blocks = []
    for pos, kind in enumerate(cfg.pattern):
        if kind == "attn":
            blk = _attn_block(cfg, pos, Pn, "periods")
        elif kind == "mamba":
            blk = _mamba_block(cfg, Pn, "periods")
        else:
            raise ValueError(kind)
        blk.update(_ffn_block(cfg, pos, Pn, "periods"))
        blocks.append(blk)
    schema: Dict[str, Any] = {
        "embed": P((V, d), ("vocab", "embed"), scale=0.02),
        "blocks": tuple(blocks),
        "final_norm": P((d,), (None,), "ones"),
    }
    if not cfg.tie_embeddings:
        schema["lm_head"] = P((d, V), ("embed", "vocab"))
    if cfg.encoder is not None:
        ne = cfg.encoder.n_layers
        h, kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
        eblk = {
            "ln1": P((ne, d), ("enc_layers", None), "ones"),
            "wq": P((ne, d, h * hd), ("enc_layers", "embed", "heads")),
            "wk": P((ne, d, kv * hd), ("enc_layers", "embed", "kv_heads")),
            "wv": P((ne, d, kv * hd), ("enc_layers", "embed", "kv_heads")),
            "wo": P((ne, h * hd, d), ("enc_layers", "heads", "embed")),
            "ln2": P((ne, d), ("enc_layers", None), "ones"),
            "wg": P((ne, d, cfg.d_ff), ("enc_layers", "embed", "ffn")),
            "wu": P((ne, d, cfg.d_ff), ("enc_layers", "embed", "ffn")),
            "wd": P((ne, cfg.d_ff, d), ("enc_layers", "ffn", "embed")),
        }
        schema["encoder"] = {"blocks": eblk,
                             "final_norm": P((d,), (None,), "ones")}
    return schema


def _is_p(x) -> bool:
    return isinstance(x, P)


def _init_leaf(key: jax.Array, p: P, dtype) -> jax.Array:
    if p.init == "zeros":
        return jnp.zeros(p.shape, dtype)
    if p.init == "ones":
        return jnp.ones(p.shape, dtype)
    if p.init == "a_log":
        nh = p.shape[-1]
        base = jnp.log(jnp.linspace(1.0, 16.0, nh))
        return jnp.broadcast_to(base, p.shape).astype(dtype)
    if p.init == "dt_bias":
        # inverse-softplus of dt in [1e-3, 0.1]
        nh = p.shape[-1]
        dt = jnp.exp(jnp.linspace(np.log(1e-3), np.log(0.1), nh))
        base = jnp.log(jnp.expm1(dt))
        return jnp.broadcast_to(base, p.shape).astype(dtype)
    fan_in = p.shape[-2] if len(p.shape) >= 2 else p.shape[-1]
    std = min(p.scale, fan_in ** -0.5) if p.init == "normal" else p.scale
    return (jax.random.normal(key, p.shape) * std).astype(dtype)


def init_params(cfg: ModelConfig, key: jax.Array,
                dtype=None) -> Dict:
    dtype = dtype or jnp.dtype(cfg.dtype)
    schema = build_schema(cfg)
    leaves, treedef = jax.tree_util.tree_flatten(schema, is_leaf=_is_p)
    keys = jax.random.split(key, len(leaves))
    vals = [_init_leaf(k, p, dtype) for k, p in zip(keys, leaves)]
    return jax.tree_util.tree_unflatten(treedef, vals)


def abstract_params(cfg: ModelConfig, dtype=None) -> Dict:
    dtype = dtype or jnp.dtype(cfg.dtype)
    schema = build_schema(cfg)
    return jax.tree_util.tree_map(
        lambda p: jax.ShapeDtypeStruct(p.shape, dtype), schema, is_leaf=_is_p)


@dataclasses.dataclass(frozen=True)
class LoraTarget:
    stack: Tuple[int, ...]
    d_in: int
    d_out: int


def lora_targets(cfg: ModelConfig, target_names: Tuple[str, ...]) -> Dict:
    """Pytree of LoraTarget for every eligible LoRA leaf whose key name is in
    ``target_names`` (the LoRA bank mirrors this structure)."""
    schema = build_schema(cfg)

    def walk(node, name):
        if _is_p(node):
            if node.lora and name in target_names and len(node.shape) >= 3:
                return LoraTarget(node.shape[:-2], node.shape[-2], node.shape[-1])
            return None
        if isinstance(node, dict):
            out = {k: walk(v, k) for k, v in node.items()}
            return {k: v for k, v in out.items() if v is not None}
        if isinstance(node, tuple):
            return tuple(walk(v, name) or {} for v in node)
        return None

    return {"blocks": walk(schema["blocks"], "blocks")}


def param_logical_axes(cfg: ModelConfig) -> Dict:
    """Same-structure pytree of logical-axis tuples (for sharding rules)."""
    schema = build_schema(cfg)
    return jax.tree_util.tree_map(lambda p: p.logical, schema, is_leaf=_is_p)
