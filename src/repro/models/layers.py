"""Shared layer math: RMSNorm, RoPE, masked/chunked attention, SwiGLU.

Attention is one generic routine covering every bucket of the unified flow:
fine-tune/eval (differentiable causal self-attention), prefill (causal with
cache write), decode (one query over a cache), cross-attention (no causal
mask), and sliding-window variants.  The mask is always expressed through
explicit per-token positions, so rolling-buffer caches work transparently.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def rms_norm(x: jax.Array, w: jax.Array, eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    y = x32 * jax.lax.rsqrt(jnp.mean(x32 * x32, axis=-1, keepdims=True) + eps)
    return (y * w.astype(jnp.float32)).astype(dt)


def rope(x: jax.Array, pos: jax.Array, theta: float) -> jax.Array:
    """Rotary embedding.  x: [..., n_heads, hd]; pos broadcastable to x's
    leading dims (e.g. [B, S] for [B, S, h, hd])."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = theta ** (-jnp.arange(half, dtype=jnp.float32) / half)
    ang = pos.astype(jnp.float32)[..., None, None] * freqs      # [..., 1, half]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., :half].astype(jnp.float32), x[..., half:].astype(jnp.float32)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def swiglu(x: jax.Array, wg: jax.Array, wu: jax.Array, wd: jax.Array
           ) -> jax.Array:
    return (jax.nn.silu(x @ wg.astype(x.dtype)) * (x @ wu.astype(x.dtype))
            ) @ wd.astype(x.dtype)


def _build_mask(q_pos: jax.Array, k_pos: jax.Array, k_valid: jax.Array,
                causal: bool, window: int) -> jax.Array:
    """[B, S, T] boolean mask from per-token positions."""
    m = k_valid[:, None, :]
    if causal:
        m = m & (k_pos[:, None, :] <= q_pos[:, :, None])
    if window > 0:
        m = m & (q_pos[:, :, None] - k_pos[:, None, :] < window)
    return m


def attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
              q_pos: jax.Array, k_pos: jax.Array, k_valid: jax.Array,
              causal: bool = True, window: int = 0, chunk: int = 0,
              scale: Optional[float] = None) -> jax.Array:
    """Generic GQA attention.

    q: [B, S, h, hd]; k/v: [B, T, g, hd] with h % g == 0.
    q_pos: [B, S]; k_pos/k_valid: [B, T].
    chunk > 0 streams the KV axis in blocks with an online softmax
    (flash-attention schedule in pure jnp — the differentiable oracle of the
    Pallas kernel, and the memory-bounded path used by big dry-run configs).
    """
    B, S, h, hd = q.shape
    T, g = k.shape[1], k.shape[2]
    m = h // g
    scale = scale if scale is not None else hd ** -0.5
    qg = q.reshape(B, S, g, m, hd)
    if chunk and chunk < T:
        if S > chunk:
            # q-chunked outer loop (memory-bounded both ways): serial map
            # over query blocks, online-softmax scan over KV blocks inside.
            pad = (-S) % chunk
            if pad:
                qg = jnp.pad(qg, ((0, 0), (0, pad), (0, 0), (0, 0), (0, 0)))
                q_pos = jnp.pad(q_pos, ((0, 0), (0, pad)))
            nqb = qg.shape[1] // chunk
            qb = qg.reshape(B, nqb, chunk, g, m, hd).transpose(1, 0, 2, 3, 4, 5)
            pb = q_pos.reshape(B, nqb, chunk).transpose(1, 0, 2)

            def one(args):
                qblk, posblk = args
                return _attention_chunked(qblk, k, v, posblk, k_pos, k_valid,
                                          causal, window, chunk, scale)

            # rematerialise per query block: backward recomputes instead of
            # saving per-block score matrices (flash-attention semantics)
            out = jax.lax.map(jax.checkpoint(one), (qb, pb))
            out = out.transpose(1, 0, 2, 3, 4, 5).reshape(B, S + pad, h, hd)
            return out[:, :S]
        return _attention_chunked(qg, k, v, q_pos, k_pos, k_valid, causal,
                                  window, chunk, scale).reshape(B, S, h, hd)
    mask = _build_mask(q_pos, k_pos, k_valid, causal, window)    # [B, S, T]
    scores = jnp.einsum("bsgmd,btgd->bgmst", qg, k).astype(jnp.float32) * scale
    scores = jnp.where(mask[:, None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bgmst,btgd->bsgmd", probs, v)
    # fully-masked queries (pad rows) are defined as 0 — matches the
    # online-softmax paths, whose l stays 0 there
    out = jnp.where(mask.any(-1)[:, :, None, None, None], out, 0.0)
    return out.reshape(B, S, h, hd)


def _attention_chunked(qg, k, v, q_pos, k_pos, k_valid, causal, window,
                       chunk, scale):
    B, S, g, m, hd = qg.shape
    T = k.shape[1]
    pad = (-T) % chunk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k_pos = jnp.pad(k_pos, ((0, 0), (0, pad)))
        k_valid = jnp.pad(k_valid, ((0, 0), (0, pad)))
    nc = k.shape[1] // chunk
    kc = k.reshape(B, nc, chunk, g, hd).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(B, nc, chunk, g, hd).transpose(1, 0, 2, 3, 4)
    kpc = k_pos.reshape(B, nc, chunk).transpose(1, 0, 2)
    kvc = k_valid.reshape(B, nc, chunk).transpose(1, 0, 2)

    def body(carry, xs):
        acc, m_run, l_run = carry
        kb, vb, kp, kv_ = xs                                     # [B,c,g,hd]...
        mask = kv_[:, None, :]
        if causal:
            mask = mask & (kp[:, None, :] <= q_pos[:, :, None])
        if window > 0:
            mask = mask & (q_pos[:, :, None] - kp[:, None, :] < window)
        s = jnp.einsum("bsgmd,bcgd->bgmsc", qg, kb).astype(jnp.float32) * scale
        s = jnp.where(mask[:, None, None], s, NEG_INF)
        m_new = jnp.maximum(m_run, s.max(axis=-1))
        # mask p explicitly: for fully-masked rows m_new = NEG_INF and
        # exp(s - m_new) would be exp(0) = 1 on masked entries
        p = jnp.where(mask[:, None, None], jnp.exp(s - m_new[..., None]), 0.0)
        corr = jnp.exp(jnp.minimum(m_run - m_new, 0.0))
        l_new = l_run * corr + p.sum(axis=-1)
        acc = acc * corr[..., None] + jnp.einsum(
            "bgmsc,bcgd->bgmsd", p.astype(qg.dtype), vb).astype(jnp.float32)
        return (acc, m_new, l_new), None

    acc0 = jnp.zeros((B, g, m, S, hd), jnp.float32)
    m0 = jnp.full((B, g, m, S), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, g, m, S), jnp.float32)
    (acc, _, l), _ = jax.lax.scan(jax.checkpoint(body), (acc0, m0, l0),
                                  (kc, vc, kpc, kvc))
    out = acc / jnp.maximum(l[..., None], 1e-30)
    return out.transpose(0, 3, 1, 2, 4).astype(qg.dtype)         # [B,S,g,m,hd]


def mla_attention_chunked(q_nope, q_pe, ckv, kpe, w_uk, w_uv, *,
                          q_pos, k_pos, k_valid, causal=True, window=0,
                          chunk=1024) -> jax.Array:
    """Memory-bounded MLA for long prefill/training (FlashMLA-style):
    K/V are expanded from the compressed latent one KV block at a time inside
    an online-softmax scan; the full K/V are never materialised.  Outer
    serial map over query blocks bounds the score tile to [chunk, chunk]."""
    B, S, h, dn = q_nope.shape
    dr = q_pe.shape[-1]
    dv = w_uv.shape[-1]
    T = ckv.shape[1]
    scale = (dn + dr) ** -0.5
    padk = (-T) % chunk
    if padk:
        ckv = jnp.pad(ckv, ((0, 0), (0, padk), (0, 0)))
        kpe = jnp.pad(kpe, ((0, 0), (0, padk), (0, 0)))
        k_pos = jnp.pad(k_pos, ((0, 0), (0, padk)))
        k_valid = jnp.pad(k_valid, ((0, 0), (0, padk)))
    nk = ckv.shape[1] // chunk
    ckv_b = ckv.reshape(B, nk, chunk, -1).transpose(1, 0, 2, 3)
    kpe_b = kpe.reshape(B, nk, chunk, -1).transpose(1, 0, 2, 3)
    kp_b = k_pos.reshape(B, nk, chunk).transpose(1, 0, 2)
    kv_b = k_valid.reshape(B, nk, chunk).transpose(1, 0, 2)

    padq = (-S) % chunk
    if padq:
        q_nope = jnp.pad(q_nope, ((0, 0), (0, padq), (0, 0), (0, 0)))
        q_pe = jnp.pad(q_pe, ((0, 0), (0, padq), (0, 0), (0, 0)))
        q_pos = jnp.pad(q_pos, ((0, 0), (0, padq)))
    nq = q_nope.shape[1] // chunk
    qn_b = q_nope.reshape(B, nq, chunk, h, dn).transpose(1, 0, 2, 3, 4)
    qp_b = q_pe.reshape(B, nq, chunk, h, dr).transpose(1, 0, 2, 3, 4)
    pos_b = q_pos.reshape(B, nq, chunk).transpose(1, 0, 2)

    def q_block(args):
        qn, qp, qpos = args                       # [B,c,h,dn], ..., [B,c]

        def body(carry, xs):
            acc, m_run, l_run = carry
            cb, pb, kp, kv_ = xs
            kn = jnp.einsum("btc,chd->bthd", cb, w_uk.astype(cb.dtype))
            vv = jnp.einsum("btc,chd->bthd", cb, w_uv.astype(cb.dtype))
            s = jnp.einsum("bshd,bthd->bhst", qn, kn).astype(jnp.float32)
            s = s + jnp.einsum("bshd,btd->bhst", qp, pb).astype(jnp.float32)
            s = s * scale
            mask = kv_[:, None, :]
            if causal:
                mask = mask & (kp[:, None, :] <= qpos[:, :, None])
            if window > 0:
                mask = mask & (qpos[:, :, None] - kp[:, None, :] < window)
            s = jnp.where(mask[:, None], s, NEG_INF)
            m_new = jnp.maximum(m_run, s.max(axis=-1))
            p = jnp.where(mask[:, None], jnp.exp(s - m_new[..., None]), 0.0)
            corr = jnp.exp(jnp.minimum(m_run - m_new, 0.0))
            l_new = l_run * corr + p.sum(axis=-1)
            acc = acc * corr[..., None] + jnp.einsum(
                "bhst,bthd->bhsd", p.astype(vv.dtype), vv).astype(jnp.float32)
            return (acc, m_new, l_new), None

        acc0 = jnp.zeros((B, h, chunk, dv), jnp.float32)
        m0 = jnp.full((B, h, chunk), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, h, chunk), jnp.float32)
        (acc, _, l), _ = jax.lax.scan(jax.checkpoint(body), (acc0, m0, l0),
                                      (ckv_b, kpe_b, kp_b, kv_b))
        out = acc / jnp.maximum(l[..., None], 1e-30)
        return out.transpose(0, 2, 1, 3).astype(q_nope.dtype)   # [B,c,h,dv]

    out = jax.lax.map(jax.checkpoint(q_block), (qn_b, qp_b, pos_b))
    out = out.transpose(1, 0, 2, 3, 4).reshape(B, S + padq, h, dv)
    return out[:, :S]


def mla_attention(q_nope: jax.Array, q_pe: jax.Array, ckv: jax.Array,
                  kpe: jax.Array, w_uk: jax.Array, w_uv: jax.Array, *,
                  q_pos: jax.Array, k_pos: jax.Array, k_valid: jax.Array,
                  causal: bool = True, window: int = 0,
                  chunk: int = 0) -> jax.Array:
    """Absorbed-form MLA attention (DeepSeek-V2) — the TPU-native adaptation:
    K/V are never materialised; scores and outputs are computed against the
    compressed latent cache directly.

    q_nope: [B, S, h, dn]; q_pe: [B, S, h, dr] (already roped)
    ckv: [B, T, c]; kpe: [B, T, dr] (already roped)
    w_uk: [c, h, dn]; w_uv: [c, h, dv]
    """
    B, S, h, dn = q_nope.shape
    dr = q_pe.shape[-1]
    T = ckv.shape[1]
    if chunk and (S > chunk or T > chunk):
        return mla_attention_chunked(q_nope, q_pe, ckv, kpe, w_uk, w_uv,
                                     q_pos=q_pos, k_pos=k_pos,
                                     k_valid=k_valid, causal=causal,
                                     window=window, chunk=chunk)
    scale = (dn + dr) ** -0.5
    q_lat = jnp.einsum("bshd,chd->bshc", q_nope, w_uk.astype(q_nope.dtype))
    s = jnp.einsum("bshc,btc->bhst", q_lat, ckv).astype(jnp.float32)
    s = s + jnp.einsum("bshd,btd->bhst", q_pe, kpe).astype(jnp.float32)
    s = s * scale
    mask = _build_mask(q_pos, k_pos, k_valid, causal, window)
    s = jnp.where(mask[:, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1).astype(q_nope.dtype)
    o_lat = jnp.einsum("bhst,btc->bshc", p, ckv)
    return jnp.einsum("bshc,chd->bshd", o_lat, w_uv.astype(q_nope.dtype))
