"""Model configuration schema for all supported architecture families.

A single ``ModelConfig`` describes dense GQA transformers, MLA (DeepSeek-V2),
MoE, Mamba2/SSD, hybrid (Jamba), encoder-decoder (Whisper backbone) and
VLM cross-attention decoders.  Layers are organised as ``n_periods`` repeats
of ``block_pattern`` so the model can be ``lax.scan``-ed over periods with an
O(1)-size HLO body regardless of depth.
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass, field
from typing import Optional, Tuple


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int                 # routed experts
    top_k: int
    d_ff_expert: int                 # per-expert FFN hidden size
    num_shared: int = 0              # always-on shared experts
    every: int = 1                   # MoE on layers with (idx % every == every-1); others dense
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01


@dataclass(frozen=True)
class MLAConfig:
    kv_lora_rank: int = 512          # compressed KV latent width (cached)
    qk_rope_dim: int = 64            # rope sub-head width (shared across heads)
    qk_nope_dim: int = 128
    v_head_dim: int = 128


@dataclass(frozen=True)
class SSMConfig:
    d_state: int = 128
    head_dim: int = 64
    expand: int = 2
    chunk: int = 128                 # SSD chunk length (train/prefill)
    conv_width: int = 4
    n_groups: int = 1


@dataclass(frozen=True)
class EncoderConfig:
    """Whisper-style encoder backbone.  The mel+conv frontend is a STUB:
    ``input_specs`` supplies precomputed frame embeddings [B, n_frames, d_model]."""
    n_layers: int = 6
    n_frames: int = 1500


@dataclass(frozen=True)
class ModelConfig:
    name: str
    arch_type: str                   # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0                # 0 -> d_model // n_heads
    qkv_bias: bool = False
    rope_theta: float = 500000.0
    rms_eps: float = 1e-5
    tie_embeddings: bool = False
    block_pattern: Tuple[str, ...] = ("attn",)   # cycled; entries: attn | mamba
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    cross_attn_every: int = 0        # >0: cross-attn on layers with idx % every == every-1
    n_img_tokens: int = 1600         # VLM stub: vision tokens per image
    encoder: Optional[EncoderConfig] = None
    sliding_window: int = 0          # 0 = full attention; >0 = window size (decode variant)
    dtype: str = "float32"           # activation/param dtype ("bfloat16" for dry-run)
    # citation / provenance for assigned-architecture configs
    source: str = ""

    # ---- derived ----
    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def pattern(self) -> Tuple[str, ...]:
        return self.block_pattern

    @property
    def n_periods(self) -> int:
        p = len(self.block_pattern)
        assert self.n_layers % p == 0, (self.name, self.n_layers, p)
        return self.n_layers // p

    def layer_kind(self, pos: int) -> str:
        """Block kind at pattern position ``pos`` (same for every period)."""
        return self.block_pattern[pos]

    def is_moe_layer(self, pos: int) -> bool:
        if self.moe is None:
            return False
        return (pos % self.moe.every) == (self.moe.every - 1)

    def has_ffn(self, pos: int) -> bool:
        """A dense FFN / MoE follows the mixer at this pattern position."""
        if self.is_moe_layer(pos):
            return True
        return self.d_ff > 0

    def is_cross_layer(self, pos: int) -> bool:
        """Cross-attention (VLM / enc-dec decoder) at this pattern position."""
        if self.encoder is not None:
            return self.block_pattern[pos] == "attn"   # every decoder layer cross-attends
        if self.cross_attn_every <= 0:
            return False
        return (pos % self.cross_attn_every) == (self.cross_attn_every - 1)

    @property
    def d_inner(self) -> int:
        assert self.ssm is not None
        return self.ssm.expand * self.d_model

    @property
    def n_ssm_heads(self) -> int:
        assert self.ssm is not None
        return self.d_inner // self.ssm.head_dim

    def validate(self) -> None:
        assert self.arch_type in ("dense", "moe", "ssm", "hybrid", "audio", "vlm")
        assert self.n_heads % self.n_kv_heads == 0
        if self.cross_attn_every:
            assert len(self.block_pattern) % self.cross_attn_every == 0 or \
                self.cross_attn_every % len(self.block_pattern) == 0
        _ = self.n_periods

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    # Rough parameter counts for roofline MODEL_FLOPS = 6*N*D.
    def param_count(self, active_only: bool = False) -> int:
        d, hd = self.d_model, self.hd
        n = self.vocab * d  # embed
        if not self.tie_embeddings:
            n += d * self.vocab
        for pos in range(len(self.block_pattern)):
            kind = self.block_pattern[pos]
            per = 0
            if kind == "attn":
                if self.mla is not None:
                    m = self.mla
                    qd = m.qk_nope_dim + m.qk_rope_dim
                    per += d * self.n_heads * qd                       # W_q
                    per += d * (m.kv_lora_rank + m.qk_rope_dim)        # down-proj
                    per += m.kv_lora_rank * self.n_heads * (m.qk_nope_dim + m.v_head_dim)
                    per += self.n_heads * m.v_head_dim * d             # W_o
                else:
                    per += d * self.n_heads * hd
                    per += 2 * d * self.n_kv_heads * hd
                    per += self.n_heads * hd * d
                if self.is_cross_layer(pos):
                    per += d * self.n_heads * hd + 2 * d * self.n_kv_heads * hd \
                        + self.n_heads * hd * d
            elif kind == "mamba":
                s = self.ssm
                di = self.d_inner
                zxbcdt = 2 * di + 2 * s.n_groups * s.d_state + self.n_ssm_heads
                per += d * zxbcdt + di * d
            # FFN
            if self.is_moe_layer(pos):
                e = self.moe
                ff_all = 3 * d * e.d_ff_expert
                routed = e.num_experts * ff_all
                shared = e.num_shared * 3 * d * e.d_ff_expert if e.num_shared else 0
                per += d * e.num_experts  # router
                if active_only:
                    per += e.top_k * ff_all + shared
                else:
                    per += routed + shared
            elif self.d_ff > 0:
                per += 3 * d * self.d_ff
            n += per * self.n_periods
        if self.encoder is not None:
            enc_per = d * self.n_heads * hd + 2 * d * self.n_kv_heads * hd \
                + self.n_heads * hd * d + 3 * d * self.d_ff
            n += enc_per * self.encoder.n_layers
        return n
