"""Analytical per-step cost model: FLOPs, HBM traffic, collective bytes.

Why analytical: XLA's ``compiled.cost_analysis()`` counts each while-loop
BODY once, not times its trip count — a scanned 80-layer model under-reports
by ~80x and chunked-attention inner loops by another ~S/chunk.  The dry-run
records both: these napkin-math numbers (exact for the matmul-dominated
flows) as the primary roofline input, and the HLO-parsed numbers (raw +
trip-count-scaled) for cross-checking.

All quantities are PER DEVICE PER STEP, derived from the config, the input
shape, the mesh, and the sharding strategy in
``repro.distributed.sharding`` (fsdp_tp baseline).
"""
from __future__ import annotations

import dataclasses
from typing import Dict

from repro.models.configs import ModelConfig


@dataclasses.dataclass
class StepCost:
    flops: float = 0.0               # per-device
    hbm_bytes: float = 0.0           # per-device
    coll_bytes: float = 0.0          # per-device (sent)
    detail: Dict[str, float] = dataclasses.field(default_factory=dict)

    def add(self, key: str, flops=0.0, hbm=0.0, coll=0.0):
        self.flops += flops
        self.hbm_bytes += hbm
        self.coll_bytes += coll
        if flops:
            self.detail[f"flops/{key}"] = self.detail.get(f"flops/{key}", 0.0) + flops
        if hbm:
            self.detail[f"hbm/{key}"] = self.detail.get(f"hbm/{key}", 0.0) + hbm
        if coll:
            self.detail[f"coll/{key}"] = self.detail.get(f"coll/{key}", 0.0) + coll


BYTES = 2            # bf16


def _tp_shardable_heads(cfg: ModelConfig, tp: int) -> bool:
    return cfg.n_heads % tp == 0


def step_cost(cfg: ModelConfig, kind: str, seq_len: int, global_batch: int,
              *, dp: int, tp: int, strategy: str = "fsdp_tp",
              attn_chunk: int = 1024) -> StepCost:
    """kind: train | prefill | decode.  dp = product of batch axes."""
    c = StepCost()
    d, hd, V = cfg.d_model, cfg.hd, cfg.vocab
    P = cfg.n_periods
    heads_tp = tp if _tp_shardable_heads(cfg, tp) else 1
    ffn_tp = tp
    # tokens processed this step
    if kind == "decode":
        tokens = global_batch
        s_kv = min(seq_len, cfg.sliding_window) if cfg.sliding_window else seq_len
    else:
        tokens = global_batch * seq_len
        s_kv = seq_len
    t_loc = tokens / min(dp, global_batch)      # batch may not shard fully
    if global_batch % dp != 0:
        t_loc = tokens                           # replicated batch (long_500k)
    # training backward ~2x fwd matmuls (dX only; base dW frozen) + remat fwd
    train_mult = 3.0 if kind == "train" else 1.0

    # ---------------- per pattern position ----------------
    for pos, kindp in enumerate(cfg.pattern):
        if kindp == "attn":
            if cfg.mla is not None:
                m = cfg.mla
                qd = m.qk_nope_dim + m.qk_rope_dim
                w_attn = d * cfg.n_heads * qd + d * (m.kv_lora_rank + m.qk_rope_dim) \
                    + cfg.n_heads * m.v_head_dim * d
                w_absorb = m.kv_lora_rank * cfg.n_heads * (m.qk_nope_dim + m.v_head_dim)
                c.add("mla_proj", flops=2 * t_loc * w_attn / heads_tp * train_mult * P)
                if kind == "decode":
                    # absorbed: q_lat prep + scores/out against latent
                    f = t_loc * cfg.n_heads * (2 * m.qk_nope_dim * m.kv_lora_rank * 2
                                               + 2 * s_kv * (m.kv_lora_rank * 2 + m.qk_rope_dim))
                    c.add("mla_attn", flops=f / heads_tp * P)
                else:
                    # chunked: expand K/V per block + scores
                    f = 2 * t_loc * cfg.n_heads * s_kv * (m.qk_nope_dim + m.qk_rope_dim + m.v_head_dim) \
                        + 2 * (s_kv / max(dp, 1)) * m.kv_lora_rank * cfg.n_heads * (m.qk_nope_dim + m.v_head_dim) * (tokens / t_loc)
                    c.add("mla_attn", flops=f / heads_tp * train_mult * P)
            else:
                w_attn = d * cfg.n_heads * hd + 2 * d * cfg.n_kv_heads * hd \
                    + cfg.n_heads * hd * d
                c.add("attn_proj", flops=2 * t_loc * w_attn / heads_tp * train_mult * P)
                win = cfg.sliding_window or 0
                eff_kv = min(s_kv, win) if win else s_kv
                f = 2 * 2 * t_loc * cfg.n_heads * hd * eff_kv
                c.add("attn_sdpa", flops=f / heads_tp * train_mult * P)
            if cfg.is_cross_layer(pos):
                n_x = cfg.encoder.n_frames if cfg.encoder else cfg.n_img_tokens
                w_x = 2 * d * cfg.n_heads * hd + 2 * d * cfg.n_kv_heads * hd
                c.add("xattn", flops=(2 * t_loc * w_x + 4 * t_loc * cfg.n_heads * hd * n_x)
                      / heads_tp * train_mult * P)
        else:  # mamba (head-parallel TP when head count divides the axis)
            di = cfg.d_inner
            s = cfg.ssm
            gds = s.n_groups * s.d_state
            ssm_tp = tp if cfg.n_ssm_heads % tp == 0 else 1
            w_m = d * (2 * di + 2 * gds + cfg.n_ssm_heads) + di * d
            c.add("ssm_proj", flops=2 * t_loc * w_m / ssm_tp * train_mult * P)
            if kind == "decode":
                f = t_loc * cfg.n_ssm_heads * s.head_dim * s.d_state * 4
            else:
                Q = min(s.chunk, s_kv)
                # intra-chunk (Q^2) + state ingest/emit
                f = 2 * t_loc * cfg.n_ssm_heads * (Q * (s.d_state + s.head_dim)
                                                   + 2 * s.head_dim * s.d_state)
            c.add("ssm_scan", flops=f / ssm_tp * train_mult * P)

        # FFN / MoE
        if cfg.is_moe_layer(pos):
            e = cfg.moe
            w_e = 3 * d * e.d_ff_expert
            c.add("moe_expert", flops=2 * t_loc * e.top_k * e.capacity_factor
                  * w_e / ffn_tp * train_mult * P)
            c.add("moe_router", flops=2 * t_loc * d * e.num_experts * train_mult * P)
            if e.num_shared:
                c.add("moe_shared", flops=2 * t_loc * e.num_shared * w_e / ffn_tp
                      * train_mult * P)
        elif cfg.d_ff > 0:
            c.add("ffn", flops=2 * t_loc * 3 * d * cfg.d_ff / ffn_tp * train_mult * P)

    # encoder (whisper) — runs on prefill/train rows only
    if cfg.encoder is not None and kind != "decode":
        rows_loc = max(global_batch / min(dp, global_batch), 1)
        ft = rows_loc * cfg.encoder.n_frames
        w_enc = (2 + 2 * cfg.n_kv_heads / cfg.n_heads) * d * cfg.n_heads * hd \
            + 3 * d * cfg.d_ff
        c.add("encoder", flops=(2 * ft * w_enc
                                + 4 * ft * cfg.n_heads * hd * cfg.encoder.n_frames)
              * cfg.encoder.n_layers * train_mult)

    # head + embed
    vtp = tp if V % tp == 0 else 1
    if kind == "train":
        c.add("lm_head", flops=2 * t_loc * d * V / vtp * train_mult)
    else:
        rows_loc = max(global_batch / min(dp, global_batch), 1)
        c.add("lm_head", flops=2 * rows_loc * d * V / vtp)

    # ---------------- HBM traffic ----------------
    n_params = cfg.param_count()
    if strategy == "fsdp_tp":
        local_w = n_params * BYTES / tp
        c.add("weights", hbm=2 * local_w)      # AG write + matmul read
        c.add("weights_ag", coll=local_w)      # received bytes per device
    else:
        c.add("weights", hbm=n_params * BYTES / tp)
    act_traffic = 12 * t_loc * d * BYTES * P * (2 if kind == "train" else 1)
    c.add("activations", hbm=act_traffic)
    if kind != "train":
        cache_b = _cache_bytes_local(cfg, global_batch, s_kv, dp, tp)
        c.add("cache", hbm=cache_b * (1.0 if kind == "decode" else 2.0))

    # ---------------- collectives ----------------
    if heads_tp > 1 or ffn_tp > 1:
        # 2 reduce ops per layer on [t_loc, d] activations (TP row-parallel)
        c.add("tp_allreduce", coll=2 * 2 * t_loc * d * BYTES * P
              * (2 if kind == "train" else 1))
    if cfg.moe is not None:
        n_moe_layers = sum(cfg.is_moe_layer(p)
                           for p in range(len(cfg.pattern))) * P
        # the shard_map dispatch shards tokens over (batch axes x model),
        # so per-device a2a volume is T/chips x k x cf x d each way
        # (v1 of this model used T/dp and over-estimated by the TP factor —
        # caught by the HLO collective audit, see EXPERIMENTS.md §Perf)
        t_moe = tokens / (dp * tp) if tokens % (dp * tp) == 0 else t_loc
        c.add("moe_a2a", coll=2 * t_moe * cfg.moe.top_k
              * cfg.moe.capacity_factor * d * BYTES
              * n_moe_layers * (2 if kind == "train" else 1))
    if kind == "decode" and cfg.mla is None and _has_attn(cfg) \
            and cfg.n_kv_heads % tp != 0:
        # hd-sharded cache -> per-layer partial-score all-reduce (f32 scores)
        rows_loc = max(global_batch / min(dp, global_batch), 1)
        n_attn_layers = sum(1 for k in cfg.pattern if k == "attn") * P
        sc = min(s_kv, cfg.sliding_window) if cfg.sliding_window else s_kv
        c.add("score_allreduce",
              coll=2 * rows_loc * cfg.n_heads * sc * 4 * n_attn_layers)
    if kind == "train":
        # LoRA grad all-reduce over dp (banks are replicated)
        lora_b = _lora_bytes(cfg)
        c.add("grad_allreduce", coll=2 * lora_b)
    return c


def _has_attn(cfg: ModelConfig) -> bool:
    return any(k == "attn" for k in cfg.pattern)


def _cache_bytes_local(cfg: ModelConfig, b: int, s_kv: int, dp: int,
                       tp: int) -> float:
    rows_loc = b / dp if b % dp == 0 else b
    total = 0.0
    for pos, kindp in enumerate(cfg.pattern):
        if kindp == "attn":
            if cfg.mla is not None:
                per = s_kv * (cfg.mla.kv_lora_rank + cfg.mla.qk_rope_dim)
                per_tp = tp if s_kv % tp == 0 else 1
            else:
                per = 2 * s_kv * cfg.n_kv_heads * cfg.hd
                per_tp = tp if (cfg.n_kv_heads % tp == 0 or cfg.hd % tp == 0) else 1
            if cfg.is_cross_layer(pos):
                nx = cfg.encoder.n_frames if cfg.encoder else cfg.n_img_tokens
                per += 2 * nx * cfg.n_kv_heads * cfg.hd
        else:
            s = cfg.ssm
            per = cfg.n_ssm_heads * s.head_dim * s.d_state \
                + (s.conv_width - 1) * (cfg.d_inner + 2 * s.n_groups * s.d_state)
            per_tp = 1
        total += rows_loc * per * BYTES * cfg.n_periods / per_tp
    return total


def _lora_bytes(cfg: ModelConfig, n_slots: int = 4, r: int = 16) -> float:
    # rough: every eligible linear gets (d_in + d_out) * r per slot
    from repro.models.schema import lora_targets
    from repro.core.lora import LoRAConfig
    tg = lora_targets(cfg, LoRAConfig().targets)
    import jax
    total = 0
    for t in jax.tree_util.tree_leaves(
            tg, is_leaf=lambda x: hasattr(x, "d_in")):
        stack = 1
        for s in t.stack:
            stack *= s
        total += stack * n_slots * (t.d_in + t.d_out) * r
    return total * BYTES
