"""Generic scanned decoder covering every assigned architecture family, with
the paper's unified computation flow built in.

``unified_forward`` implements Algorithms 1–2 of the paper: one joint
projection per linear for ALL request buckets (fine-tune/eval, prefill,
decode) via ``core.lora.dense`` (base matmul + SMLM multi-LoRA), per-bucket
attention/SSM paths, joint output projection, and per-row losses for
fine-tune/eval rows.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.lora import dense
from repro.models import layers as L
from repro.models import mamba as M
from repro.models import quant
from repro.models.configs import ModelConfig
from repro.models.stream import DECBatch, FTBatch, ModelOut, PFBatch, UnifiedBatch


# ---------------------------------------------------------------------------
# stream plan: bucket sizes, per-token adapter ids, split/merge
# ---------------------------------------------------------------------------

class _Plan:
    def __init__(self, cfg: ModelConfig, batch: UnifiedBatch,
                 lora_scale: Optional[jax.Array]):
        ft, pf, dec = batch.ft, batch.pf, batch.dec
        self.ft, self.pf, self.dec = ft, pf, dec
        self.Bf, self.Sf = (ft.tokens.shape if ft is not None else (0, 0))
        self.Bp, self.Sp = (pf.tokens.shape if pf is not None else (0, 0))
        # decode bucket: [Bd] plain decode or [Bd, Sd] verify chunks
        if dec is not None:
            self.Bd = dec.tokens.shape[0]
            self.Sd = dec.tokens.shape[1] if dec.tokens.ndim == 2 else 1
        else:
            self.Bd, self.Sd = 0, 1
        self.Bc = self.Bd + self.Bp          # cache rows: dec first, then pf
        sizes = [self.Bf * self.Sf, self.Bp * self.Sp, self.Bd * self.Sd]
        self.sizes = sizes
        self.T = sum(sizes)
        ids = []
        if ft is not None:
            ids.append(jnp.repeat(ft.adapter, self.Sf))
        if pf is not None:
            ids.append(jnp.repeat(pf.adapter, self.Sp))
        if dec is not None:
            ids.append(jnp.repeat(dec.adapter, self.Sd))
        self.ids = jnp.concatenate(ids) if ids else None
        if lora_scale is not None and self.ids is not None:
            n = lora_scale.shape[0]
            safe = jnp.clip(self.ids, 0, n - 1)
            self.scale_t = lora_scale[safe]
        else:
            self.scale_t = None
        # positions / validity per bucket
        if ft is not None:
            self.ft_pos = jnp.broadcast_to(jnp.arange(self.Sf, dtype=jnp.int32),
                                           (self.Bf, self.Sf))
            self.ft_valid = ft.mask
        if pf is not None:
            ar = jnp.arange(self.Sp, dtype=jnp.int32)
            # suffix-only prefill: row positions start at the cached span
            self.pf_cached = pf.cached_len
            if pf.cached_len is not None:
                self.pf_pos = pf.cached_len[:, None] + ar[None, :]
            else:
                self.pf_pos = jnp.broadcast_to(ar, (self.Bp, self.Sp))
            self.pf_valid = ar[None, :] < pf.length[:, None]
        else:
            self.pf_cached = None
        if dec is not None:
            self.dec_pos = dec.pos
            # per-query positions of the (1 + k)-token chunk, and per-row
            # valid chunk lengths (trailing draft slots may be padding)
            ard = jnp.arange(self.Sd, dtype=jnp.int32)
            self.dec_qpos = dec.pos[:, None] + ard[None, :]
            self.dec_len = (dec.length if dec.length is not None
                            else jnp.full((self.Bd,), self.Sd, jnp.int32))
        # paged-layout block tables (None -> dense row layout per bucket)
        self.pf_tables = pf.block_tables if pf is not None else None
        self.dec_tables = dec.block_tables if dec is not None else None

    def split(self, x: jax.Array):
        """[T, ...] -> (xf [Bf,Sf,...], xp [Bp,Sp,...], xd [Bd,Sd,...])"""
        t0, t1, _ = self.sizes
        rest = x.shape[1:]
        xf = x[:t0].reshape(self.Bf, self.Sf, *rest) if t0 else None
        xp = x[t0:t0 + t1].reshape(self.Bp, self.Sp, *rest) if t1 else None
        xd = x[t0 + t1:].reshape(self.Bd, self.Sd, *rest) if self.Bd else None
        return xf, xp, xd

def _merge_flat(plan: _Plan, xf, xp, xd) -> jax.Array:
    parts = []
    if xf is not None:
        parts.append(xf.reshape(plan.sizes[0], -1))
    if xp is not None:
        parts.append(xp.reshape(plan.sizes[1], -1))
    if xd is not None:
        parts.append(xd.reshape(plan.sizes[2], -1))
    return jnp.concatenate(parts, axis=0)


# ---------------------------------------------------------------------------
# KV / state cache
#
# Two layouts share the same pytree structure ({"layers": tuple of dicts}):
#
# * dense  (init_cache):       every leaf is [n_periods, n_rows, ...] — one
#   row per resident request, s_max key/value slots each.  Prefill writes at
#   rows [Bd, Bd+Bp); decode updates rows [0, Bd) in place.
# * paged  (init_paged_cache): attention K/V (or MLA latents) become a flat
#   block pool [n_periods, n_blocks, block_size, ...] addressed through
#   per-request block tables carried in the batch (PFBatch/DECBatch
#   .block_tables); only per-request state that does not grow with the
#   sequence (Mamba SSM/conv state, cross-attention K/V) keeps dense rows.
#   Paged mode is selected per bucket by the presence of block tables.
# ---------------------------------------------------------------------------

# cache leaves that stay per-request rows even in the paged layout
STATE_KEYS = frozenset({"h", "conv_x", "conv_bc", "xk", "xv"})


def cache_seq_len(cfg: ModelConfig, s_max: int) -> int:
    w = cfg.sliding_window
    return min(s_max, w) if w > 0 else s_max


def init_cache(cfg: ModelConfig, n_rows: int, s_max: int,
               dtype=None) -> Dict:
    """Allocate the cache pytree: a tuple over pattern positions, each leaf
    stacked [n_periods, n_rows, ...]."""
    dtype = dtype or jnp.dtype(cfg.dtype)
    Pn, kv, hd = cfg.n_periods, cfg.n_kv_heads, cfg.hd
    sc = cache_seq_len(cfg, s_max)
    per_pos = []
    for pos, kind in enumerate(cfg.pattern):
        c: Dict[str, jax.Array] = {}
        if kind == "attn":
            if cfg.mla is not None:
                m = cfg.mla
                c["ckv"] = jnp.zeros((Pn, n_rows, sc, m.kv_lora_rank), dtype)
                c["kpe"] = jnp.zeros((Pn, n_rows, sc, m.qk_rope_dim), dtype)
            else:
                c["k"] = jnp.zeros((Pn, n_rows, sc, kv, hd), dtype)
                c["v"] = jnp.zeros((Pn, n_rows, sc, kv, hd), dtype)
            if cfg.is_cross_layer(pos):
                f = cfg.encoder.n_frames if cfg.encoder else cfg.n_img_tokens
                c["xk"] = jnp.zeros((Pn, n_rows, f, kv, hd), dtype)
                c["xv"] = jnp.zeros((Pn, n_rows, f, kv, hd), dtype)
        elif kind == "mamba":
            s = cfg.ssm
            nh, hdm = cfg.n_ssm_heads, s.head_dim
            gds = s.n_groups * s.d_state
            c["h"] = jnp.zeros((Pn, n_rows, nh, hdm, s.d_state), dtype)
            c["conv_x"] = jnp.zeros((Pn, n_rows, s.conv_width - 1,
                                     cfg.d_inner), dtype)
            c["conv_bc"] = jnp.zeros((Pn, n_rows, s.conv_width - 1,
                                      2 * gds), dtype)
        per_pos.append(c)
    return {"layers": tuple(per_pos)}


def init_paged_cache(cfg: ModelConfig, n_blocks: int, block_size: int,
                     n_rows: int, dtype=None) -> Dict:
    """Allocate the paged cache pytree: attention K/V as a flat block pool
    ``[n_periods, n_blocks, block_size, ...]`` shared by all requests via
    block tables; sequence-length-independent state keeps ``n_rows`` dense
    rows.  Rolling (sliding-window) buffers and paging don't compose."""
    if cfg.sliding_window > 0:
        raise ValueError("paged cache does not support sliding windows")
    dtype = dtype or jnp.dtype(cfg.dtype)
    Pn, kv, hd = cfg.n_periods, cfg.n_kv_heads, cfg.hd
    per_pos = []
    for pos, kind in enumerate(cfg.pattern):
        c: Dict[str, jax.Array] = {}
        if kind == "attn":
            if cfg.mla is not None:
                m = cfg.mla
                c["ckv"] = jnp.zeros((Pn, n_blocks, block_size,
                                      m.kv_lora_rank), dtype)
                c["kpe"] = jnp.zeros((Pn, n_blocks, block_size,
                                      m.qk_rope_dim), dtype)
            else:
                c["k"] = jnp.zeros((Pn, n_blocks, block_size, kv, hd), dtype)
                c["v"] = jnp.zeros((Pn, n_blocks, block_size, kv, hd), dtype)
            if cfg.is_cross_layer(pos):
                f = cfg.encoder.n_frames if cfg.encoder else cfg.n_img_tokens
                c["xk"] = jnp.zeros((Pn, n_rows, f, kv, hd), dtype)
                c["xv"] = jnp.zeros((Pn, n_rows, f, kv, hd), dtype)
        elif kind == "mamba":
            s = cfg.ssm
            nh, hdm = cfg.n_ssm_heads, s.head_dim
            gds = s.n_groups * s.d_state
            c["h"] = jnp.zeros((Pn, n_rows, nh, hdm, s.d_state), dtype)
            c["conv_x"] = jnp.zeros((Pn, n_rows, s.conv_width - 1,
                                     cfg.d_inner), dtype)
            c["conv_bc"] = jnp.zeros((Pn, n_rows, s.conv_width - 1,
                                      2 * gds), dtype)
        per_pos.append(c)
    return {"layers": tuple(per_pos)}


def abstract_cache(cfg: ModelConfig, n_rows: int, s_max: int, dtype=None):
    dtype = dtype or jnp.dtype(cfg.dtype)
    tree = jax.eval_shape(lambda: init_cache(cfg, n_rows, s_max, dtype))
    return tree


# -- paged pool access (block tables are null-padded with 0; block 0 is the
#    reserved garbage block, and invalid positions are masked by k_valid) ----

def _paged_write_prompt(pool: jax.Array, xh: jax.Array,
                        tables: jax.Array) -> jax.Array:
    """Scatter prefill writes ``[Bp, Sp, ...]`` into pool blocks via tables
    ``[Bp, nbt]``; positions beyond ``nbt * block_size`` are dropped (they
    are padding beyond the context limit)."""
    bs = pool.shape[1]
    Bp, Sp = xh.shape[:2]
    nbp = min(-(-Sp // bs), tables.shape[1])
    Lp = nbp * bs
    if Sp < Lp:
        xh = jnp.pad(xh, ((0, 0), (0, Lp - Sp)) + ((0, 0),) * (xh.ndim - 2))
    else:
        xh = xh[:, :Lp]
    xb = xh.reshape(Bp, nbp, bs, *xh.shape[2:])
    tbl = jnp.maximum(tables[:, :nbp], 0)
    return pool.at[tbl].set(xb.astype(pool.dtype))


def _paged_write_token(pool: jax.Array, xh: jax.Array, tables: jax.Array,
                       pos: jax.Array) -> jax.Array:
    """Write one decode token per row ``[Bd, ...]`` at its position."""
    bs = pool.shape[1]
    tbl = jnp.maximum(tables, 0)
    rows = jnp.arange(tbl.shape[0])
    bid = tbl[rows, jnp.clip(pos // bs, 0, tbl.shape[1] - 1)]
    return pool.at[bid, pos % bs].set(xh.astype(pool.dtype))


def _paged_write_chunk(pool: jax.Array, xh: jax.Array, tables: jax.Array,
                       pos: jax.Array, length: jax.Array) -> jax.Array:
    """Scatter a verify chunk ``[Bd, Sd, ...]`` into pool blocks: row ``b``'s
    token ``j`` lands at position ``pos[b] + j``; positions at or beyond
    ``length[b]`` (padding / unfilled draft slots) are redirected to the null
    block so they cannot corrupt live cache state."""
    bs = pool.shape[1]
    Bd, Sd = xh.shape[:2]
    tbl = jnp.maximum(tables, 0)
    j = jnp.arange(Sd, dtype=jnp.int32)[None, :]
    p = pos[:, None].astype(jnp.int32) + j                     # [Bd, Sd]
    valid = j < length[:, None]
    bi = jnp.clip(p // bs, 0, tbl.shape[1] - 1)
    bid = jnp.where(valid, jnp.take_along_axis(tbl, bi, axis=1), 0)
    flat = xh.reshape(Bd * Sd, *xh.shape[2:])
    return pool.at[bid.reshape(-1), (p % bs).reshape(-1)].set(
        flat.astype(pool.dtype))


def _paged_view(pool: jax.Array, tables: jax.Array) -> jax.Array:
    """Gather per-request contiguous K/V views ``[Bd, nbt*bs, ...]`` — the
    jnp reference of what kernels.decode_attn.paged_decode_attention streams
    block-by-block without materializing."""
    tbl = jnp.maximum(tables, 0)
    Bd, nbt = tbl.shape
    v = pool[tbl]
    return v.reshape(Bd, nbt * pool.shape[1], *pool.shape[2:])


def _paged_dec_mask(tables: jax.Array, block_size: int,
                    pos: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """(k_pos, k_valid) over the gathered view: positions are the natural
    0..nbt*bs range, valid through the current token."""
    Bd, nbt = tables.shape
    j = jnp.arange(nbt * block_size, dtype=jnp.int32)[None, :]
    k_pos = jnp.broadcast_to(j, (Bd, nbt * block_size))
    k_valid = j <= pos[:, None]
    return k_pos, k_valid


def _paged_chunk_mask(tables: jax.Array, block_size: int, pos: jax.Array,
                      length: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """(k_pos, k_valid) for a verify chunk: after the chunk write the cache
    holds positions ``0 .. pos + length - 1``; within-chunk causality comes
    from the attention mask's q_pos/k_pos comparison."""
    Bd, nbt = tables.shape
    j = jnp.arange(nbt * block_size, dtype=jnp.int32)[None, :]
    k_pos = jnp.broadcast_to(j, (Bd, nbt * block_size))
    k_valid = j < pos[:, None] + length[:, None]
    return k_pos, k_valid


# recognized REPRO_PAGED_ATTN_KERNEL values (after lowercasing); "1" is a
# legacy alias normalized to "tpu" so step-cache keys stay canonical
_KERNEL_OFF = ("", "0", "off", "false")
_KERNEL_MODES = ("interpret", "tpu", "splitk", "splitk-interpret")


def _paged_kernel_mode() -> str:
    """Paged attention backend flag (ROADMAP item): empty = jnp gather view
    (the CPU default); ``interpret`` = sequential Pallas kernels in
    interpret mode (CI-testable); ``tpu`` (or ``1``) = compiled sequential
    kernels (real-TPU path); ``splitk`` / ``splitk-interpret`` = the
    flash-decoding split-K decode/verify kernels (``kernels.splitk``) with
    per-shape ``kernels.autotune`` tile/split selection.  Anything else is
    a loud error — a typo must not silently select the compiled-TPU path.
    Read at trace time — step builders key their compile cache on it."""
    import os
    v = os.environ.get("REPRO_PAGED_ATTN_KERNEL", "").strip().lower()
    if v in _KERNEL_OFF:
        return ""
    if v == "1":
        return "tpu"
    if v not in _KERNEL_MODES:
        raise ValueError(
            f"REPRO_PAGED_ATTN_KERNEL={v!r} is not a recognized paged "
            f"attention kernel mode; expected one of "
            f"{('off',) + _KERNEL_MODES} (or '1' as an alias for 'tpu')")
    return v


def _dec_cache_pos(pos: jax.Array, sc: int) -> Tuple[jax.Array, jax.Array]:
    """Per-row (k_pos [Bd, sc], k_valid [Bd, sc]) for a (possibly rolling)
    cache AFTER the current token at ``pos`` has been written."""
    j = jnp.arange(sc, dtype=jnp.int32)[None, :]
    p = pos[:, None]
    k_pos = j + sc * jnp.floor_divide(p - j, sc)
    k_valid = j <= p
    return k_pos, k_valid


# ---------------------------------------------------------------------------
# attention block
# ---------------------------------------------------------------------------

def _rope_heads(x: jax.Array, pos: jax.Array, n: int, theta: float) -> jax.Array:
    """reshape [B,S,n*hd] -> rope -> [B,S,n,hd]"""
    B, S = x.shape[:2]
    return L.rope(x.reshape(B, S, n, -1), pos, theta)


def _attn_apply(cfg: ModelConfig, pos_idx: int, p: Dict, lr: Dict,
                plan: _Plan, x: jax.Array, cache: Dict,
                attn_chunk: int) -> Tuple[jax.Array, Dict]:
    h, kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    W = cfg.sliding_window
    xn = L.rms_norm(x, p["ln1"], cfg.rms_eps)
    dn = functools.partial(dense, ids=plan.ids, scale_t=plan.scale_t)
    new_cache = dict(cache)
    Bd = plan.Bd

    if cfg.mla is not None:
        out = _mla_apply(cfg, p, lr, plan, xn, cache, new_cache, attn_chunk)
    else:
        q = dn(xn, p["wq"], p.get("bq"), lr.get("wq"))
        k = dn(xn, p["wk"], p.get("bk"), lr.get("wk"))
        v = dn(xn, p["wv"], p.get("bv"), lr.get("wv"))
        qf, qp, qd = plan.split(q)
        kf, kp, kd = plan.split(k)
        vf, vp, vd = plan.split(v)
        outs = [None, None, None]
        if qf is not None:       # fine-tune / eval: differentiable causal path
            qh = _rope_heads(qf, plan.ft_pos, h, cfg.rope_theta)
            kh = _rope_heads(kf, plan.ft_pos, kv, cfg.rope_theta)
            vh = vf.reshape(plan.Bf, plan.Sf, kv, hd)
            outs[0] = L.attention(qh, kh, vh, q_pos=plan.ft_pos,
                                  k_pos=plan.ft_pos, k_valid=plan.ft_valid,
                                  causal=True, window=W, chunk=attn_chunk)
        if qp is not None:       # prefill: causal + cache write
            qh = _rope_heads(qp, plan.pf_pos, h, cfg.rope_theta)
            kh = _rope_heads(kp, plan.pf_pos, kv, cfg.rope_theta)
            vh = vp.reshape(plan.Bp, plan.Sp, kv, hd)
            if plan.pf_tables is not None and plan.pf_cached is not None:
                # suffix-only prefill: scatter the suffix K/V at its offset
                # (never touching shared prefix blocks — all writes land at
                # positions >= cached_len), then attend over the pooled view
                # so cached prefix tokens are READ instead of recomputed
                ck = _paged_write_chunk(new_cache["k"], kh, plan.pf_tables,
                                        plan.pf_cached, plan.pf.length)
                cv = _paged_write_chunk(new_cache["v"], vh, plan.pf_tables,
                                        plan.pf_cached, plan.pf.length)
                new_cache["k"], new_cache["v"] = ck, cv
                mode = _paged_kernel_mode()
                if mode:
                    from repro.kernels.prefill_attn import \
                        paged_prefill_attention
                    outs[1] = paged_prefill_attention(
                        qh, ck, cv, plan.pf_tables, plan.pf_cached,
                        plan.pf.length,
                        interpret=mode.endswith("interpret"))
                else:
                    k_pos, k_valid = _paged_chunk_mask(
                        plan.pf_tables, ck.shape[1], plan.pf_cached,
                        plan.pf.length)
                    outs[1] = L.attention(
                        qh, _paged_view(ck, plan.pf_tables),
                        _paged_view(cv, plan.pf_tables), q_pos=plan.pf_pos,
                        k_pos=k_pos, k_valid=k_valid, causal=True, window=0,
                        chunk=attn_chunk)
            else:
                outs[1] = L.attention(qh, kh, vh, q_pos=plan.pf_pos,
                                      k_pos=plan.pf_pos,
                                      k_valid=plan.pf_valid,
                                      causal=True, window=W,
                                      chunk=attn_chunk)
            if plan.pf_tables is not None and plan.pf_cached is None:
                # paged full-prompt prefill: straight into the blocks
                new_cache["k"] = _paged_write_prompt(new_cache["k"], kh,
                                                     plan.pf_tables)
                new_cache["v"] = _paged_write_prompt(new_cache["v"], vh,
                                                     plan.pf_tables)
            elif plan.pf_tables is None:
                sc = cache["k"].shape[1]
                if plan.Sp <= sc:
                    new_cache["k"] = new_cache["k"].at[Bd:Bd + plan.Bp, :plan.Sp].set(kh)
                    new_cache["v"] = new_cache["v"].at[Bd:Bd + plan.Bp, :plan.Sp].set(vh)
                else:             # rolling buffer: keep last sc positions
                    sl = (jnp.arange(plan.Sp - sc, plan.Sp) % sc)
                    new_cache["k"] = new_cache["k"].at[Bd:Bd + plan.Bp, sl].set(kh[:, -sc:])
                    new_cache["v"] = new_cache["v"].at[Bd:Bd + plan.Bp, sl].set(vh[:, -sc:])
        if qd is not None:       # decode / verify: (1 + k)-token chunk
            Sd = plan.Sd
            dpos = plan.dec_qpos                               # [Bd, Sd]
            qh = _rope_heads(qd, dpos, h, cfg.rope_theta)      # [Bd,Sd,h,hd]
            kh = _rope_heads(kd, dpos, kv, cfg.rope_theta)
            vh = vd.reshape(plan.Bd, Sd, kv, hd)
            if plan.dec_tables is not None:  # paged: block-table gather
                ck = _paged_write_chunk(new_cache["k"], kh, plan.dec_tables,
                                        plan.dec_pos, plan.dec_len)
                cv = _paged_write_chunk(new_cache["v"], vh, plan.dec_tables,
                                        plan.dec_pos, plan.dec_len)
                new_cache["k"], new_cache["v"] = ck, cv
                mode = _paged_kernel_mode()
                if mode:
                    # real-TPU path: block tables walked by the DMA engine.
                    # splitk modes partition the walk across grid cells and
                    # LSE-merge the partials (flash decoding); the autotune
                    # table/heuristic picks the fan-out per shape at trace
                    # time.
                    interp = mode.endswith("interpret")
                    if mode.startswith("splitk"):
                        from repro.kernels.autotune import choose
                        from repro.kernels.splitk import (
                            paged_decode_attention_splitk,
                            paged_verify_attention_splitk)
                        kc = choose(hd, ck.shape[1],
                                    plan.dec_tables.shape[1], plan.Bd * h)
                        if Sd == 1:
                            o = paged_decode_attention_splitk(
                                qh[:, 0], ck, cv, plan.dec_tables,
                                plan.dec_pos, num_splits=kc.num_splits,
                                interpret=interp)
                            outs[2] = o[:, None]
                        else:
                            outs[2] = paged_verify_attention_splitk(
                                qh, ck, cv, plan.dec_tables, plan.dec_pos,
                                plan.dec_len, num_splits=kc.num_splits,
                                interpret=interp)
                    elif Sd == 1:
                        from repro.kernels.decode_attn import \
                            paged_decode_attention
                        o = paged_decode_attention(
                            qh[:, 0], ck, cv, plan.dec_tables, plan.dec_pos,
                            interpret=interp)
                        outs[2] = o[:, None]
                    else:
                        from repro.kernels.decode_attn import \
                            paged_verify_attention
                        outs[2] = paged_verify_attention(
                            qh, ck, cv, plan.dec_tables, plan.dec_pos,
                            plan.dec_len, interpret=interp)
                else:
                    k_pos, k_valid = _paged_chunk_mask(
                        plan.dec_tables, ck.shape[1], plan.dec_pos,
                        plan.dec_len)
                    outs[2] = L.attention(
                        qh, _paged_view(ck, plan.dec_tables),
                        _paged_view(cv, plan.dec_tables), q_pos=dpos,
                        k_pos=k_pos, k_valid=k_valid, causal=True, window=0)
            else:
                if Sd > 1 and W > 0:
                    raise NotImplementedError(
                        "verify chunks need a non-rolling cache: rolled-back "
                        "draft positions would alias live window slots")
                sc = cache["k"].shape[1]
                slot = dpos % sc                               # [Bd, Sd]
                rows = jnp.arange(plan.Bd)[:, None]
                ck = new_cache["k"].at[rows, slot].set(kh)
                cv = new_cache["v"].at[rows, slot].set(vh)
                new_cache["k"], new_cache["v"] = ck, cv
                k_pos, k_valid = _dec_cache_pos(
                    plan.dec_pos + plan.dec_len - 1, sc)
                outs[2] = L.attention(qh, ck[:Bd], cv[:Bd],
                                      q_pos=dpos, k_pos=k_pos,
                                      k_valid=k_valid, causal=True, window=0)
        out = _merge_flat(plan, *outs)
    o = dn(out, p["wo"], None, lr.get("wo"))
    x = x + o

    if cfg.is_cross_layer(pos_idx):
        x = _cross_apply(cfg, p, lr, plan, x, cache, new_cache, attn_chunk)
    return x, new_cache


def _mla_apply(cfg: ModelConfig, p: Dict, lr: Dict, plan: _Plan,
               xn: jax.Array, cache: Dict, new_cache: Dict,
               attn_chunk: int = 0) -> jax.Array:
    """Absorbed-form MLA for all buckets; the cache holds the latent."""
    m, h = cfg.mla, cfg.n_heads
    dnp, dr, c_rank = m.qk_nope_dim, m.qk_rope_dim, m.kv_lora_rank
    dn = functools.partial(dense, ids=plan.ids, scale_t=plan.scale_t)
    q = dn(xn, p["wq"], None, lr.get("wq"))              # [T, h*(dn+dr)]
    ckv_full = dn(xn, p["wdkv"], None, lr.get("wdkv"))   # [T, c + dr]
    Bd = plan.Bd
    qf, qp, qd = plan.split(q)
    cf, cp, cd = plan.split(ckv_full)
    outs = [None, None, None]

    def _split_q(qb, B, S):
        qb = qb.reshape(B, S, h, dnp + dr)
        return qb[..., :dnp], qb[..., dnp:]

    def _split_c(cb):
        return cb[..., :c_rank], cb[..., c_rank:]

    if qf is not None:
        qn, qr = _split_q(qf, plan.Bf, plan.Sf)
        qr = L.rope(qr, plan.ft_pos, cfg.rope_theta)
        ckv, kpe = _split_c(cf)
        kpe = L.rope(kpe[..., None, :], plan.ft_pos, cfg.rope_theta)[..., 0, :]
        outs[0] = L.mla_attention(qn, qr, ckv, kpe, p["wuk"], p["wuv"],
                                  q_pos=plan.ft_pos, k_pos=plan.ft_pos,
                                  k_valid=plan.ft_valid, causal=True,
                                  window=cfg.sliding_window,
                                  chunk=attn_chunk)
    if qp is not None:
        qn, qr = _split_q(qp, plan.Bp, plan.Sp)
        qr = L.rope(qr, plan.pf_pos, cfg.rope_theta)
        ckv, kpe = _split_c(cp)
        kpe = L.rope(kpe[..., None, :], plan.pf_pos, cfg.rope_theta)[..., 0, :]
        if plan.pf_tables is not None and plan.pf_cached is not None:
            # suffix-only prefill: offset-scatter the latent, attend over
            # the pooled view so the cached prefix latent is read, not
            # recomputed (same contract as the standard-attention path)
            cc = _paged_write_chunk(new_cache["ckv"], ckv, plan.pf_tables,
                                    plan.pf_cached, plan.pf.length)
            ce = _paged_write_chunk(new_cache["kpe"], kpe, plan.pf_tables,
                                    plan.pf_cached, plan.pf.length)
            new_cache["ckv"], new_cache["kpe"] = cc, ce
            k_pos, k_valid = _paged_chunk_mask(plan.pf_tables, cc.shape[1],
                                               plan.pf_cached, plan.pf.length)
            outs[1] = L.mla_attention(qn, qr, _paged_view(cc, plan.pf_tables),
                                      _paged_view(ce, plan.pf_tables),
                                      p["wuk"], p["wuv"], q_pos=plan.pf_pos,
                                      k_pos=k_pos, k_valid=k_valid,
                                      causal=True, window=0,
                                      chunk=attn_chunk)
        else:
            outs[1] = L.mla_attention(qn, qr, ckv, kpe, p["wuk"], p["wuv"],
                                      q_pos=plan.pf_pos, k_pos=plan.pf_pos,
                                      k_valid=plan.pf_valid, causal=True,
                                      window=cfg.sliding_window,
                                      chunk=attn_chunk)
        if plan.pf_tables is not None and plan.pf_cached is None:
            # paged full-prompt prefill: straight into the blocks
            new_cache["ckv"] = _paged_write_prompt(new_cache["ckv"], ckv,
                                                   plan.pf_tables)
            new_cache["kpe"] = _paged_write_prompt(new_cache["kpe"], kpe,
                                                   plan.pf_tables)
        elif plan.pf_tables is None:
            sc = cache["ckv"].shape[1]
            if plan.Sp <= sc:
                new_cache["ckv"] = new_cache["ckv"].at[Bd:Bd + plan.Bp, :plan.Sp].set(ckv)
                new_cache["kpe"] = new_cache["kpe"].at[Bd:Bd + plan.Bp, :plan.Sp].set(kpe)
            else:
                sl = (jnp.arange(plan.Sp - sc, plan.Sp) % sc)
                new_cache["ckv"] = new_cache["ckv"].at[Bd:Bd + plan.Bp, sl].set(ckv[:, -sc:])
                new_cache["kpe"] = new_cache["kpe"].at[Bd:Bd + plan.Bp, sl].set(kpe[:, -sc:])
    if qd is not None:
        Sd = plan.Sd
        dpos = plan.dec_qpos                                   # [Bd, Sd]
        qn, qr = _split_q(qd, plan.Bd, Sd)
        qr = L.rope(qr, dpos, cfg.rope_theta)
        ckv, kpe = _split_c(cd)
        kpe = L.rope(kpe[..., None, :], dpos, cfg.rope_theta)[..., 0, :]
        if plan.dec_tables is not None:      # paged: block-table gather
            cc = _paged_write_chunk(new_cache["ckv"], ckv, plan.dec_tables,
                                    plan.dec_pos, plan.dec_len)
            ce = _paged_write_chunk(new_cache["kpe"], kpe, plan.dec_tables,
                                    plan.dec_pos, plan.dec_len)
            new_cache["ckv"], new_cache["kpe"] = cc, ce
            k_pos, k_valid = _paged_chunk_mask(plan.dec_tables, cc.shape[1],
                                               plan.dec_pos, plan.dec_len)
            outs[2] = L.mla_attention(qn, qr, _paged_view(cc, plan.dec_tables),
                                      _paged_view(ce, plan.dec_tables),
                                      p["wuk"], p["wuv"], q_pos=dpos,
                                      k_pos=k_pos, k_valid=k_valid,
                                      causal=True, window=0)
        else:
            if Sd > 1 and cfg.sliding_window > 0:
                raise NotImplementedError(
                    "verify chunks need a non-rolling cache: rolled-back "
                    "draft positions would alias live window slots")
            sc = cache["ckv"].shape[1]
            slot = dpos % sc
            rows = jnp.arange(plan.Bd)[:, None]
            cc = new_cache["ckv"].at[rows, slot].set(ckv)
            ce = new_cache["kpe"].at[rows, slot].set(kpe)
            new_cache["ckv"], new_cache["kpe"] = cc, ce
            k_pos, k_valid = _dec_cache_pos(plan.dec_pos + plan.dec_len - 1,
                                            sc)
            outs[2] = L.mla_attention(qn, qr, cc[:Bd], ce[:Bd], p["wuk"],
                                      p["wuv"], q_pos=dpos, k_pos=k_pos,
                                      k_valid=k_valid, causal=True, window=0)
    return _merge_flat(plan, *outs)


def _cross_apply(cfg: ModelConfig, p: Dict, lr: Dict, plan: _Plan,
                 x: jax.Array, cache: Dict, new_cache: Dict,
                 attn_chunk: int = 0) -> jax.Array:
    """Cross-attention sublayer (VLM image layers / enc-dec decoder)."""
    h, kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    dn = functools.partial(dense, ids=plan.ids, scale_t=plan.scale_t)
    xn = L.rms_norm(x, p["xln"], cfg.rms_eps)
    q = dn(xn, p["xwq"], None, lr.get("xwq"))
    qf, qp, qd = plan.split(q)
    Bd = plan.Bd
    outs = [None, None, None]

    def _kv_from(src):           # src: [B, F, d] cross source embeddings
        B, F = src.shape[:2]
        kx = (src.reshape(B * F, -1) @ p["xwk"].astype(src.dtype)
              ).reshape(B, F, kv, hd)
        vx = (src.reshape(B * F, -1) @ p["xwv"].astype(src.dtype)
              ).reshape(B, F, kv, hd)
        return kx, vx

    def _xattn(qb, kx, vx, qpos):
        B, S = qb.shape[:2]
        F = kx.shape[1]
        valid = jnp.ones((B, F), bool)
        kpos = jnp.zeros((B, F), jnp.int32)
        return L.attention(qb.reshape(B, S, h, hd), kx, vx,
                           q_pos=qpos, k_pos=kpos, k_valid=valid,
                           causal=False, window=0, chunk=attn_chunk)

    if qf is not None:
        src = plan.ft.aux_embed.astype(x.dtype)
        kx, vx = _kv_from(src)
        outs[0] = _xattn(qf, kx, vx, plan.ft_pos)
    if qp is not None:
        src = plan.pf.aux_embed.astype(x.dtype)
        kx, vx = _kv_from(src)
        outs[1] = _xattn(qp, kx, vx, plan.pf_pos)
        new_cache["xk"] = new_cache["xk"].at[Bd:Bd + plan.Bp].set(kx)
        new_cache["xv"] = new_cache["xv"].at[Bd:Bd + plan.Bp].set(vx)
    if qd is not None:
        kx, vx = cache["xk"][:Bd], cache["xv"][:Bd]
        outs[2] = _xattn(qd, kx, vx, plan.dec_qpos)
    o = _merge_flat(plan, *outs)
    o = dn(o, p["xwo"], None, lr.get("xwo"))
    if "xgate" in p:
        o = jnp.tanh(p["xgate"]).astype(o.dtype) * o
    return x + o


# ---------------------------------------------------------------------------
# mamba block
# ---------------------------------------------------------------------------

def _mamba_apply(cfg: ModelConfig, p: Dict, lr: Dict, plan: _Plan,
                 x: jax.Array, cache: Dict) -> Tuple[jax.Array, Dict]:
    s = cfg.ssm
    di, nh, hdm = cfg.d_inner, cfg.n_ssm_heads, s.head_dim
    gds = s.n_groups * s.d_state
    xn = L.rms_norm(x, p["ln1"], cfg.rms_eps)
    dnf = functools.partial(dense, ids=plan.ids, scale_t=plan.scale_t)
    # head-aligned component projections (shardable Mamba TP; see schema)
    z_all = dnf(xn, p["in_z"], None, lr.get("in_z"))         # [T, di]
    x_all = dnf(xn, p["in_x"], None, lr.get("in_x"))         # [T, di]
    bc_all = xn @ p["in_bc"].astype(xn.dtype)                # [T, 2*gds]
    dt_all = xn @ p["in_dt"].astype(xn.dtype)                # [T, nh]
    A = -jnp.exp(p["a_log"].astype(jnp.float32))
    new_cache = dict(cache)
    zf, zp, zd = plan.split(z_all)
    xf, xp_, xd = plan.split(x_all)
    bf, bp, bd = plan.split(bc_all)
    df, dp_, dd = plan.split(dt_all)
    outs = [None, None, None]
    Bd = plan.Bd

    def _expand_bc(y_bc, lead):
        b2 = y_bc[..., :gds].reshape(*lead, s.n_groups, s.d_state)
        c2 = y_bc[..., gds:].reshape(*lead, s.n_groups, s.d_state)
        return M.expand_groups(b2, nh), M.expand_groups(c2, nh)

    def _seq(xb, bcb, dtb, valid, conv0x, conv0bc, h0):
        B, S = xb.shape[:2]
        y_x, convx_fin = M.causal_conv(xb, p["conv_x"], p["conv_bx"], conv0x)
        y_bc, convbc_fin = M.causal_conv(bcb, p["conv_bc"], p["conv_bbc"],
                                         conv0bc)
        y_x, y_bc = jax.nn.silu(y_x), jax.nn.silu(y_bc)
        xs2 = y_x.reshape(B, S, nh, hdm)
        b2, c2 = _expand_bc(y_bc, (B, S))
        dtv = jax.nn.softplus(dtb.astype(jnp.float32)
                              + p["dt_bias"].astype(jnp.float32))
        dtv = dtv * valid[..., None].astype(jnp.float32)   # pad -> no-op
        y, h_fin = M.ssd_chunked(xs2, dtv, A, b2, c2, s.chunk, h0)
        y = y + p["d_skip"].astype(y.dtype)[None, None, :, None] * xs2
        return y.reshape(B, S, di), h_fin, convx_fin, convbc_fin

    if zf is not None:
        y, _, _, _ = _seq(xf, bf, df, plan.ft_valid, None, None, None)
        outs[0] = _gated_out(y, zf, p)
    if zp is not None:
        y, h_fin, cx_fin, cbc_fin = _seq(xp_, bp, dp_, plan.pf_valid,
                                         None, None, None)
        outs[1] = _gated_out(y, zp, p)
        new_cache["h"] = new_cache["h"].at[Bd:Bd + plan.Bp].set(h_fin)
        new_cache["conv_x"] = new_cache["conv_x"].at[Bd:Bd + plan.Bp].set(cx_fin)
        new_cache["conv_bc"] = new_cache["conv_bc"].at[Bd:Bd + plan.Bp].set(cbc_fin)
    if zd is not None:
        if plan.Sd > 1:
            raise NotImplementedError(
                "mamba decode state cannot roll back rejected drafts; "
                "speculative verify chunks are attention-only")
        B = plan.Bd
        y_x, cx_new = M.causal_conv(xd, p["conv_x"], p["conv_bx"],
                                    cache["conv_x"][:Bd])
        y_bc, cbc_new = M.causal_conv(bd, p["conv_bc"], p["conv_bbc"],
                                      cache["conv_bc"][:Bd])
        y_x, y_bc = jax.nn.silu(y_x[:, 0]), jax.nn.silu(y_bc[:, 0])
        xs2 = y_x.reshape(B, nh, hdm)
        b2, c2 = _expand_bc(y_bc, (B,))
        dtv = jax.nn.softplus(dd[:, 0].astype(jnp.float32)
                              + p["dt_bias"].astype(jnp.float32))
        y, h_new = M.ssd_step(cache["h"][:Bd], xs2, dtv.astype(xs2.dtype),
                              A, b2, c2)
        y = y + p["d_skip"].astype(y.dtype)[None, :, None] * xs2
        new_cache["h"] = new_cache["h"].at[:Bd].set(h_new)
        new_cache["conv_x"] = new_cache["conv_x"].at[:Bd].set(cx_new)
        new_cache["conv_bc"] = new_cache["conv_bc"].at[:Bd].set(cbc_new)
        outs[2] = _gated_out(y.reshape(B, 1, di), zd, p)
    y = _merge_flat(plan, *outs)
    o = dense(y, p["out_proj"], None, lr.get("out_proj"),
              plan.ids, plan.scale_t)
    return x + o, new_cache


def _gated_out(y: jax.Array, z: jax.Array, p: Dict) -> jax.Array:
    """Mamba2 gated RMSNorm: norm(y * silu(z))."""
    return L.rms_norm(y * jax.nn.silu(z), p["mnorm"])


# ---------------------------------------------------------------------------
# FFN / MoE sublayer (token-parallel: operates on the joint stream)
# ---------------------------------------------------------------------------

def _ffn_apply(cfg: ModelConfig, pos_idx: int, p: Dict, lr: Dict,
               plan: _Plan, x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    aux = jnp.zeros((), jnp.float32)
    if "ln2" not in p:
        return x, aux
    xn = L.rms_norm(x, p["ln2"], cfg.rms_eps)
    if cfg.is_moe_layer(pos_idx):
        from repro.models.moe_dist import moe_apply_auto
        y, aux = moe_apply_auto(xn, p, cfg.moe)
        if "shared" in p:
            sh, shl = p["shared"], lr.get("shared", {})
            g = dense(xn, sh["wg"], None, shl.get("wg"), plan.ids, plan.scale_t)
            u = dense(xn, sh["wu"], None, shl.get("wu"), plan.ids, plan.scale_t)
            y = y + dense(jax.nn.silu(g) * u, sh["wd"], None, shl.get("wd"),
                          plan.ids, plan.scale_t)
    else:
        g = dense(xn, p["wg"], None, lr.get("wg"), plan.ids, plan.scale_t)
        u = dense(xn, p["wu"], None, lr.get("wu"), plan.ids, plan.scale_t)
        y = dense(jax.nn.silu(g) * u, p["wd"], None, lr.get("wd"),
                  plan.ids, plan.scale_t)
    return x + y, aux


# ---------------------------------------------------------------------------
# encoder (whisper backbone; bidirectional, LoRA-free)
# ---------------------------------------------------------------------------

def encoder_forward(cfg: ModelConfig, enc_params: Dict,
                    frames: jax.Array) -> jax.Array:
    h, kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    B, F, d = frames.shape
    valid = jnp.ones((B, F), bool)
    kpos = jnp.zeros((B, F), jnp.int32)

    def body(x, p):
        xn = L.rms_norm(x, p["ln1"], cfg.rms_eps)
        flat = xn.reshape(B * F, d)
        q = (flat @ p["wq"].astype(x.dtype)).reshape(B, F, h, hd)
        k = (flat @ p["wk"].astype(x.dtype)).reshape(B, F, kv, hd)
        v = (flat @ p["wv"].astype(x.dtype)).reshape(B, F, kv, hd)
        o = L.attention(q, k, v, q_pos=kpos, k_pos=kpos, k_valid=valid,
                        causal=False)
        x = x + (o.reshape(B * F, h * hd) @ p["wo"].astype(x.dtype)
                 ).reshape(B, F, d)
        xn = L.rms_norm(x, p["ln2"], cfg.rms_eps)
        y = L.swiglu(xn.reshape(B * F, d), p["wg"], p["wu"], p["wd"])
        return x + y.reshape(B, F, d), None

    x, _ = jax.lax.scan(body, frames, enc_params["blocks"])
    return L.rms_norm(x, enc_params["final_norm"], cfg.rms_eps)


# ---------------------------------------------------------------------------
# unified forward (Algorithms 1 + 2)
# ---------------------------------------------------------------------------

def unified_forward(cfg: ModelConfig, params: Dict, batch: UnifiedBatch,
                    cache: Optional[Dict] = None, *,
                    loras: Optional[Dict] = None,
                    lora_scale: Optional[jax.Array] = None,
                    remat: bool = False, attn_chunk: int = 0,
                    return_ft_logits: bool = False,
                    act_constraint: Optional[Callable] = None) -> ModelOut:
    dtype = jnp.dtype(cfg.dtype)
    plan = _Plan(cfg, batch, lora_scale)
    if (batch.pf is not None or batch.dec is not None) and cache is None:
        raise ValueError("prefill/decode buckets require a cache")
    # int8 weight-only serving: top-level leaves dequantize here (sharded,
    # small per device); block leaves dequantize per-period inside the scan
    # so HBM holds int8 and only one layer's bf16 weights exist at a time.
    quantized = quant.has_q8(params)
    gather_specs = None
    if quantized:
        params = dict(params)
        for key in ("embed", "lm_head", "encoder"):
            if key in params:
                params[key] = quant.dequant_tree(params[key], dtype)
        gather_specs = quant.block_gather_specs(cfg)

    # encoder / modality stubs -> replace aux_embed by encoder output
    if cfg.encoder is not None:
        if batch.ft is not None and batch.ft.aux_embed is not None:
            enc = encoder_forward(cfg, params["encoder"],
                                  batch.ft.aux_embed.astype(dtype))
            plan.ft = plan.ft._replace(aux_embed=enc)
        if batch.pf is not None and batch.pf.aux_embed is not None:
            enc = encoder_forward(cfg, params["encoder"],
                                  batch.pf.aux_embed.astype(dtype))
            plan.pf = plan.pf._replace(aux_embed=enc)

    # joint embedding over the whole token stream
    toks = []
    if batch.ft is not None:
        toks.append(batch.ft.tokens.reshape(-1))
    if batch.pf is not None:
        toks.append(batch.pf.tokens.reshape(-1))
    if batch.dec is not None:
        toks.append(batch.dec.tokens.reshape(-1))
    tokens = jnp.concatenate(toks)
    x = params["embed"].astype(dtype)[tokens]                     # [T, d]

    lora_blocks = (loras["blocks"] if loras is not None
                   else tuple({} for _ in cfg.pattern))
    cache_layers = (cache["layers"] if cache is not None
                    else tuple({} for _ in cfg.pattern))

    # The cache rides in the scan CARRY (updated in place with
    # dynamic_update_index_in_dim) rather than as scan xs/ys: XLA buffer
    # assignment then keeps ONE cache buffer alive instead of
    # double-buffering sliced-in xs against stacked-out ys (which costs an
    # extra full cache copy of HBM at decode time).
    def period(carry, xs):
        xx, aux_acc, cl, idx = carry
        pp, ll = xs
        cc = jax.tree_util.tree_map(
            lambda c: jax.lax.dynamic_index_in_dim(c, idx, 0, keepdims=False),
            cl)
        if quantized:
            pp = quant.dequant_tree(pp, jnp.dtype(cfg.dtype), gather_specs)
        new_cc = []
        for pos, kind in enumerate(cfg.pattern):
            if kind == "attn":
                xx, c_out = _attn_apply(cfg, pos, pp[pos], ll[pos], plan, xx,
                                        cc[pos], attn_chunk)
            else:
                xx, c_out = _mamba_apply(cfg, pp[pos], ll[pos], plan, xx,
                                         cc[pos])
            xx, aux = _ffn_apply(cfg, pos, pp[pos], ll[pos], plan, xx)
            aux_acc = aux_acc + aux
            new_cc.append(c_out)
        cl = jax.tree_util.tree_map(
            lambda c, n: jax.lax.dynamic_update_index_in_dim(c, n, idx, 0),
            cl, tuple(new_cc))
        if act_constraint is not None:
            xx = act_constraint(xx)
        return (xx, aux_acc, cl, idx + 1), None

    body = jax.checkpoint(period) if remat else period
    (x, aux_loss, new_layers, _), _ = jax.lax.scan(
        body, (x, jnp.zeros((), jnp.float32), cache_layers,
               jnp.zeros((), jnp.int32)),
        (params["blocks"], lora_blocks))
    new_cache = {"layers": new_layers} if cache is not None else None

    x = L.rms_norm(x, params["final_norm"], cfg.rms_eps)
    head = (params["embed"].T if cfg.tie_embeddings
            else params["lm_head"]).astype(dtype)

    xf, xp, xd = plan.split(x)
    ft_loss = ft_cnt = ft_logits = pf_logits = dec_logits = None
    if xd is not None:
        # [Bd, V] for plain decode; [Bd, Sd, V] for verify chunks (one
        # next-token distribution per chunk position, the acceptance oracle)
        dec_logits = xd[:, 0] @ head if plan.Sd == 1 else xd @ head
    if xp is not None:
        last = jnp.maximum(batch.pf.length - 1, 0)
        h_last = xp[jnp.arange(plan.Bp), last]
        pf_logits = h_last @ head
    if xf is not None:
        ft = batch.ft
        logits = (xf.reshape(-1, cfg.d_model) @ head
                  ).reshape(plan.Bf, plan.Sf, -1)
        if return_ft_logits:
            ft_logits = logits
        lg = logits[:, :-1].astype(jnp.float32)
        lbl = ft.labels[:, 1:]
        valid = (lbl != -100) & ft.mask[:, 1:]
        lbl_safe = jnp.maximum(lbl, 0)
        lse = jax.nn.logsumexp(lg, axis=-1)
        picked = jnp.take_along_axis(lg, lbl_safe[..., None], axis=-1)[..., 0]
        ce = jnp.where(valid, lse - picked, 0.0)
        ft_loss = ce.sum(axis=1)
        ft_cnt = valid.sum(axis=1).astype(jnp.float32)

    return ModelOut(ft_loss_sum=ft_loss, ft_tok_count=ft_cnt,
                    ft_logits=ft_logits, pf_logits=pf_logits,
                    dec_logits=dec_logits, cache=new_cache, aux_loss=aux_loss)
