"""Sharding-rule logic (pure PartitionSpec reasoning, no big meshes) and
workload/data generators."""
import numpy as np
import pytest

from repro.configs import get_config
from repro.data import datasets, workload
from repro.distributed.sharding import _spec_for
from repro.launch.shapes import SHAPES, adapt_config, has_attention


class _FakeMesh:
    shape = {"data": 16, "model": 16}


@pytest.mark.parametrize("arch,heads_sharded", [
    ("qwen1.5-110b", True),     # 64 heads % 16 == 0
    ("phi3-medium-14b", False), # 40 heads % 16 != 0 -> replicate
    ("deepseek-v2-236b", True), # 128 heads
])
def test_head_sharding_requires_divisible_head_count(arch, heads_sharded):
    cfg = get_config(arch)
    h_dim = cfg.n_heads * (cfg.mla.qk_nope_dim + cfg.mla.qk_rope_dim
                           if cfg.mla else cfg.hd)
    spec = _spec_for(cfg, _FakeMesh(), "fsdp_tp",
                     (cfg.n_periods, cfg.d_model, h_dim),
                     ("periods", "embed", "heads"))
    assert (spec[2] == "model") == heads_sharded
    assert spec[1] == ("data" if cfg.d_model % 16 == 0 else None)


def test_vocab_replicated_when_not_divisible():
    cfg = get_config("mamba2-1.3b")            # vocab 50280 % 16 != 0
    spec = _spec_for(cfg, _FakeMesh(), "fsdp_tp",
                     (cfg.vocab, cfg.d_model), ("vocab", "embed"))
    assert spec[0] is None


def test_no_duplicate_mesh_axes_in_moe_specs():
    cfg = get_config("deepseek-v2-236b")
    spec = _spec_for(cfg, _FakeMesh(), "fsdp_tp",
                     (cfg.n_periods, 160, cfg.d_model, 1536),
                     ("periods", "experts", "embed", "ffn"))
    axes = [s for s in spec if s is not None]
    assert len(axes) == len(set(axes))
    assert spec[1] == "model"                  # experts win the model axis


def test_long_context_adaptation():
    for arch in ("qwen1.5-110b", "command-r-35b", "llama-3.2-vision-90b"):
        cfg = adapt_config(get_config(arch), SHAPES["long_500k"])
        assert cfg.sliding_window == 8192      # sub-quadratic decode variant
    cfg = adapt_config(get_config("mamba2-1.3b"), SHAPES["long_500k"])
    assert cfg.sliding_window == 0             # SSM native O(1)
    assert not has_attention(cfg)


# ------------------------------------------------------------ data / workload
def test_generators_deterministic():
    a = datasets.alpaca_like(8, seed=3)
    b = datasets.alpaca_like(8, seed=3)
    for (t1, l1), (t2, l2) in zip(a, b):
        np.testing.assert_array_equal(t1, t2)
    t1 = workload.burstgpt_like("d29_15h", duration=300, seed=1, scale=0.1)
    t2 = workload.burstgpt_like("d29_15h", duration=300, seed=1, scale=0.1)
    np.testing.assert_array_equal(t1, t2)


def test_poisson_rate_approximation():
    arr = workload.poisson_arrivals(5.0, 2000, seed=0)
    rate = len(arr) / arr[-1]
    assert 4.0 < rate < 6.0


def test_burstgpt_trace_is_bursty():
    t = workload.burstgpt_like("d33_1140", duration=1200, seed=0)
    assert len(t) > 100
    # peak 2-second-window RPS should exceed 2x the mean rate
    mean_rps = len(t) / 1200
    best = 0
    for w in np.arange(0, 1198, 1.0):
        best = max(best, ((t >= w) & (t < w + 2)).sum() / 2)
    assert best > 2 * mean_rps


def test_mutable_phases_match_table7():
    arr = workload.phased_arrivals(workload.MUTABLE_PHASES, seed=0)
    assert arr == sorted(arr)
    idxs = {a for _, a in arr}
    assert idxs == {0, 1, 2, 3}
