"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret mode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref


def _mk(key, shape, dtype):
    x = jax.random.normal(key, shape, jnp.float32)
    return x.astype(dtype)


TOLS = {jnp.float32: 2e-5, jnp.bfloat16: 6e-2}


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("T,d,r,n,o,bt,bo", [
    (16, 8, 2, 2, 8, 8, 8),
    (32, 24, 4, 3, 16, 8, 8),
    (64, 33, 8, 5, 24, 16, 8),
    (128, 64, 16, 4, 64, 32, 16),
])
def test_smlm_sweep(dtype, T, d, r, n, o, bt, bo):
    ks = jax.random.split(jax.random.PRNGKey(T + d), 4)
    x = _mk(ks[0], (T, d), dtype)
    a = _mk(ks[1], (n, d, r), dtype)
    b = _mk(ks[2], (n, r, o), dtype)
    # tile-uniform ids incl. out-of-range (-1 = base only)
    tiles = T // bt
    tile_ids = jax.random.randint(ks[3], (tiles,), -1, n)
    ids = jnp.repeat(tile_ids, bt)
    y = ops.smlm(x, a, b, ids, block_t=bt, block_o=bo, interpret=True)
    scale = ((ids >= 0) & (ids < n)).astype(jnp.float32)
    yr = ref.bgmv_ref(x, a, b, ids, scale)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(yr, np.float32),
                               atol=TOLS[dtype] * max(1.0, float(jnp.abs(yr).max())),
                               rtol=TOLS[dtype])
    # the tile-form oracle must agree too (smlm <-> smlm_ref is the pairing
    # reprolint's kernel-oracle rule enforces; the wrapper derives the same
    # per-tile scalars from the per-token stream)
    tile_valid = (tile_ids >= 0) & (tile_ids < n)
    yt = ref.smlm_ref(x, a, b, jnp.clip(tile_ids, 0, n - 1),
                      tile_valid.astype(jnp.float32), bt)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(yt, np.float32),
                               atol=TOLS[dtype] * max(1.0, float(jnp.abs(yt).max())),
                               rtol=TOLS[dtype])


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("T,d,r,n,o,bo", [
    (8, 16, 4, 3, 8, 8),
    (33, 24, 8, 4, 16, 16),
    (64, 40, 2, 6, 32, 8),
])
def test_bgmv_sweep(dtype, T, d, r, n, o, bo):
    ks = jax.random.split(jax.random.PRNGKey(T * d), 4)
    x = _mk(ks[0], (T, d), dtype)
    a = _mk(ks[1], (n, d, r), dtype)
    b = _mk(ks[2], (n, r, o), dtype)
    ids = jax.random.randint(ks[3], (T,), -1, n)
    y = ops.bgmv(x, a, b, ids, block_o=bo, interpret=True)
    scale = ((ids >= 0) & (ids < n)).astype(jnp.float32)
    yr = ref.bgmv_ref(x, a, b, ids, scale)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(yr, np.float32),
                               atol=TOLS[dtype] * max(1.0, float(jnp.abs(yr).max())),
                               rtol=TOLS[dtype])


@pytest.mark.parametrize("T", [29, 41, 9, 5])
def test_smlm_ragged_stream_keeps_fused_head(T):
    """A ragged stream (e.g. a decode tail after the tile-aligned ft+pf
    segments) must not fall back to the dense oracle wholesale: the aligned
    head runs the fused kernel and the sub-tile remainder runs per-token
    BGMV — so a remainder with MIXED adapters (decode rows) stays exact."""
    ks = jax.random.split(jax.random.PRNGKey(T), 5)
    d, r, n, o, bt = 16, 4, 3, 16, 8
    x = _mk(ks[0], (T, d), jnp.float32)
    a = _mk(ks[1], (n, d, r), jnp.float32)
    b = _mk(ks[2], (n, r, o), jnp.float32)
    t0 = (T // bt) * bt
    head_ids = jnp.repeat(jax.random.randint(ks[3], (T // bt,), -1, n), bt)
    tail_ids = jax.random.randint(ks[4], (T - t0,), -1, n)  # per-token mix
    ids = jnp.concatenate([head_ids, tail_ids])
    y = ops.smlm(x, a, b, ids, block_t=bt, block_o=8, interpret=True)
    assert y.shape == (T, o)
    scale = ((ids >= 0) & (ids < n)).astype(jnp.float32)
    yr = ref.bgmv_ref(x, a, b, ids, scale)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), rtol=2e-5,
                               atol=2e-5)


def test_smlm_dynamic_scale():
    ks = jax.random.split(jax.random.PRNGKey(0), 4)
    T, d, r, n, o, bt = 32, 16, 4, 3, 16, 8
    x = _mk(ks[0], (T, d), jnp.float32)
    a = _mk(ks[1], (n, d, r), jnp.float32)
    b = _mk(ks[2], (n, r, o), jnp.float32)
    ids = jnp.repeat(jnp.array([0, 1, 2, 0]), bt)
    scale_t = jnp.repeat(jnp.array([0.5, 2.0, 0.0, 1.0]), bt)
    y = ops.smlm(x, a, b, ids, scale_t, block_t=bt, block_o=8, interpret=True)
    yr = ref.bgmv_ref(x, a, b, ids, scale_t)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), rtol=2e-5,
                               atol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,S,T,h,g,hd,bq,bk", [
    (1, 8, 8, 2, 2, 8, 8, 8),
    (2, 20, 20, 4, 2, 16, 8, 8),
    (2, 17, 33, 8, 8, 32, 8, 16),   # MHA, ragged sizes -> padding paths
    (3, 40, 40, 8, 2, 16, 16, 8),
])
def test_flash_attention_sweep(dtype, B, S, T, h, g, hd, bq, bk):
    ks = jax.random.split(jax.random.PRNGKey(B * S + T), 4)
    q = _mk(ks[0], (B, S, h, hd), dtype)
    k = _mk(ks[1], (B, T, g, hd), dtype)
    v = _mk(ks[2], (B, T, g, hd), dtype)
    lens = jax.random.randint(ks[3], (B,), 1, T + 1)
    y = ops.flash_attention(q, k, v, lens, block_q=bq, block_k=bk,
                            interpret=True)
    yr = ref.flash_attention_ref(q, k, v, lens)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(yr, np.float32),
                               atol=TOLS[dtype] * 2, rtol=TOLS[dtype] * 2)


def test_flash_attention_non_causal():
    ks = jax.random.split(jax.random.PRNGKey(5), 4)
    B, S, T, h, g, hd = 2, 12, 16, 4, 4, 16
    q = _mk(ks[0], (B, S, h, hd), jnp.float32)
    k = _mk(ks[1], (B, T, g, hd), jnp.float32)
    v = _mk(ks[2], (B, T, g, hd), jnp.float32)
    lens = jnp.array([16, 9])
    y = ops.flash_attention(q, k, v, lens, block_q=8, block_k=8,
                            causal=False, interpret=True)
    yr = ref.flash_attention_ref(q, k, v, lens, causal=False)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), rtol=3e-5,
                               atol=3e-5)
