"""Batch-decode attention Pallas kernel vs the model's decode oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.decode_attn import decode_attention
from repro.models.layers import attention
from repro.models.model import _dec_cache_pos


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,h,g,hd,S,bk", [
    (2, 4, 4, 8, 16, 8),       # MHA
    (3, 8, 2, 16, 40, 8),      # GQA, ragged length -> padding path
    (1, 8, 8, 32, 64, 16),
])
def test_decode_linear_cache(dtype, B, h, g, hd, S, bk):
    ks = jax.random.split(jax.random.PRNGKey(B * S), 4)
    q = jax.random.normal(ks[0], (B, h, hd)).astype(dtype)
    k = jax.random.normal(ks[1], (B, S, g, hd)).astype(dtype)
    v = jax.random.normal(ks[2], (B, S, g, hd)).astype(dtype)
    pos = jax.random.randint(ks[3], (B,), 0, S)
    y = decode_attention(q, k, v, pos, block_k=bk, interpret=True)
    kp, kv = _dec_cache_pos(pos, S)
    yr = attention(q[:, None], k, v, q_pos=pos[:, None], k_pos=kp,
                   k_valid=kv, causal=True)[:, 0]
    tol = 2e-5 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(yr, np.float32), rtol=tol, atol=tol)


@pytest.mark.parametrize("pos_val", [3, 15, 16, 47, 1000])
def test_decode_rolling_window(pos_val):
    """Rolling-buffer cache: slot->absolute-position reconstruction must
    match the model's _dec_cache_pos for positions below and above W."""
    B, h, g, hd, W = 2, 4, 2, 16, 16
    ks = jax.random.split(jax.random.PRNGKey(pos_val), 3)
    q = jax.random.normal(ks[0], (B, h, hd))
    k = jax.random.normal(ks[1], (B, W, g, hd))
    v = jax.random.normal(ks[2], (B, W, g, hd))
    pos = jnp.array([pos_val, max(pos_val - 2, 0)])
    y = decode_attention(q, k, v, pos, block_k=8, window=W, interpret=True)
    kp, kv = _dec_cache_pos(pos, W)
    yr = attention(q[:, None], k, v, q_pos=pos[:, None], k_pos=kp,
                   k_valid=kv, causal=True)[:, 0]
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                               rtol=3e-5, atol=3e-5)
