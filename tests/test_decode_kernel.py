"""Batch-decode attention Pallas kernel vs the model's decode oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.decode_attn import (decode_attention,
                                       paged_decode_attention,
                                       paged_verify_attention)
from repro.kernels.ref import (decode_attention_ref, paged_decode_ref,
                               paged_verify_ref)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,h,g,hd,S,bk", [
    (2, 4, 4, 8, 16, 8),       # MHA
    (3, 8, 2, 16, 40, 8),      # GQA, ragged length -> padding path
    (1, 8, 8, 32, 64, 16),
])
def test_decode_linear_cache(dtype, B, h, g, hd, S, bk):
    ks = jax.random.split(jax.random.PRNGKey(B * S), 4)
    q = jax.random.normal(ks[0], (B, h, hd)).astype(dtype)
    k = jax.random.normal(ks[1], (B, S, g, hd)).astype(dtype)
    v = jax.random.normal(ks[2], (B, S, g, hd)).astype(dtype)
    pos = jax.random.randint(ks[3], (B,), 0, S)
    y = decode_attention(q, k, v, pos, block_k=bk, interpret=True)
    yr = decode_attention_ref(q, k, v, pos)
    tol = 2e-5 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(yr, np.float32), rtol=tol, atol=tol)


@pytest.mark.parametrize("pos_val", [3, 15, 16, 47, 1000])
def test_decode_rolling_window(pos_val):
    """Rolling-buffer cache: slot->absolute-position reconstruction must
    match the model's _dec_cache_pos for positions below and above W."""
    B, h, g, hd, W = 2, 4, 2, 16, 16
    ks = jax.random.split(jax.random.PRNGKey(pos_val), 3)
    q = jax.random.normal(ks[0], (B, h, hd))
    k = jax.random.normal(ks[1], (B, W, g, hd))
    v = jax.random.normal(ks[2], (B, W, g, hd))
    pos = jnp.array([pos_val, max(pos_val - 2, 0)])
    y = decode_attention(q, k, v, pos, block_k=8, window=W, interpret=True)
    yr = decode_attention_ref(q, k, v, pos)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                               rtol=3e-5, atol=3e-5)


def _paged_setup(B, g, hd, bs, nbt, n_blocks, pos, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    k_pool = jax.random.normal(ks[0], (n_blocks, bs, g, hd))
    v_pool = jax.random.normal(ks[1], (n_blocks, bs, g, hd))
    rng = np.random.default_rng(seed)
    tables = np.zeros((B, nbt), np.int32)
    for b in range(B):
        need = pos[b] // bs + 1
        tables[b, :need] = rng.choice(np.arange(1, n_blocks), size=need,
                                      replace=False)
    return k_pool, v_pool, jnp.asarray(tables), ks[2]


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,h,g,hd,bs,nbt", [
    (2, 4, 4, 8, 8, 3),        # MHA
    (3, 8, 2, 16, 8, 5),       # GQA, ragged positions
    (1, 8, 8, 32, 16, 4),
])
def test_paged_decode_kernel_matches_ref(dtype, B, h, g, hd, bs, nbt):
    """Block-table Pallas kernel == gather-then-attend oracle, with scattered
    non-contiguous blocks and null-padded tables."""
    pos = np.minimum(np.arange(B) * 7 + 3, nbt * bs - 1)
    k_pool, v_pool, tables, kq = _paged_setup(B, g, hd, bs, nbt,
                                              nbt * B + 2, pos)
    q = jax.random.normal(kq, (B, h, hd)).astype(dtype)
    k_pool, v_pool = k_pool.astype(dtype), v_pool.astype(dtype)
    posj = jnp.asarray(pos, jnp.int32)
    y = paged_decode_attention(q, k_pool, v_pool, tables, posj,
                               interpret=True)
    yr = paged_decode_ref(q, k_pool, v_pool, tables, posj)
    tol = 2e-5 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(yr, np.float32), rtol=tol, atol=tol)


@pytest.mark.parametrize("B,h,g,hd,bs,nbt,Sq", [
    (2, 4, 4, 8, 8, 3, 4),     # MHA
    (3, 8, 2, 16, 8, 5, 5),    # GQA, ragged chunk lengths
    (1, 8, 8, 32, 16, 4, 2),
])
def test_paged_verify_kernel_matches_ref(B, h, g, hd, bs, nbt, Sq):
    """Speculative verify attention (chunked query over block tables) ==
    gather-then-attend oracle, including padding rows (len 0) and partially
    filled chunks."""
    ks = jax.random.split(jax.random.PRNGKey(B * Sq), 3)
    n_blocks = nbt * B + 2
    k_pool = jax.random.normal(ks[0], (n_blocks, bs, g, hd))
    v_pool = jax.random.normal(ks[1], (n_blocks, bs, g, hd))
    rng = np.random.default_rng(B)
    pos = np.minimum(np.arange(B) * 5 + 2, nbt * bs - Sq - 1)
    lens = rng.integers(0, Sq + 1, B)
    tables = np.zeros((B, nbt), np.int32)
    for b in range(B):
        need = (pos[b] + Sq) // bs + 1
        tables[b, :need] = rng.choice(np.arange(1, n_blocks), size=need,
                                      replace=False)
    q = jax.random.normal(ks[2], (B, Sq, h, hd))
    posj = jnp.asarray(pos, jnp.int32)
    lensj = jnp.asarray(lens, jnp.int32)
    tj = jnp.asarray(tables)
    y = paged_verify_attention(q, k_pool, v_pool, tj, posj, lensj,
                               interpret=True)
    yr = paged_verify_ref(q, k_pool, v_pool, tj, posj, lensj)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                               rtol=3e-5, atol=3e-5)


def test_verify_kernel_sq1_matches_decode_kernel():
    """A one-token verify chunk IS batch decode: both kernels must agree."""
    B, h, g, hd, bs, nbt = 2, 4, 2, 16, 8, 4
    pos = np.array([13, 30])
    k_pool, v_pool, tables, kq = _paged_setup(B, g, hd, bs, nbt, 16, pos)
    q = jax.random.normal(kq, (B, h, hd))
    posj = jnp.asarray(pos, jnp.int32)
    y1 = paged_verify_attention(q[:, None], k_pool, v_pool, tables, posj,
                                jnp.ones((B,), jnp.int32), interpret=True)
    y0 = paged_decode_attention(q, k_pool, v_pool, tables, posj,
                                interpret=True)
    np.testing.assert_allclose(np.asarray(y1[:, 0]), np.asarray(y0),
                               rtol=2e-5, atol=2e-5)


def test_model_decode_bucket_kernel_flag(monkeypatch):
    """REPRO_PAGED_ATTN_KERNEL wires kernels.decode_attn into the model's
    paged decode bucket (ROADMAP item): logits must match the jnp
    gather-view reference path."""
    from repro.configs import get_reduced
    from repro.models.model import init_paged_cache, unified_forward
    from repro.models.schema import init_params
    from repro.models.stream import DECBatch, PFBatch, UnifiedBatch

    cfg = get_reduced("llama3-8b")
    params = init_params(cfg, jax.random.PRNGKey(0))
    B, S = 2, 10
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S + 1), 0,
                              cfg.vocab)
    base = jnp.full((B,), -1)
    tbl = jnp.asarray(np.array([[3, 1, 7, 5], [2, 6, 4, 8]], np.int32))

    def drive():
        cache = init_paged_cache(cfg, 9, 8, B)
        pf = PFBatch(tokens=toks[:, :S], length=jnp.full((B,), S),
                     adapter=base, block_tables=tbl)
        cache = unified_forward(cfg, params, UnifiedBatch(pf=pf),
                                cache=cache).cache
        dec = DECBatch(tokens=toks[:, S], pos=jnp.full((B,), S),
                       adapter=base, block_tables=tbl)
        return np.asarray(unified_forward(cfg, params, UnifiedBatch(dec=dec),
                                          cache=cache).dec_logits)

    monkeypatch.delenv("REPRO_PAGED_ATTN_KERNEL", raising=False)
    ref = drive()
    monkeypatch.setenv("REPRO_PAGED_ATTN_KERNEL", "interpret")
    got = drive()
    np.testing.assert_allclose(got, ref, rtol=2e-4, atol=2e-4)


def test_model_verify_bucket_kernel_flag(monkeypatch):
    """REPRO_PAGED_ATTN_KERNEL must also drive the VERIFY bucket (multi-token
    chunks) through kernels.decode_attn — previously only prefill and
    one-token decode dispatched to Pallas and verify silently fell back to
    the jnp gather view.  Valid rows only: padding slots are never read."""
    from repro.configs import get_reduced
    from repro.models.model import init_paged_cache, unified_forward
    from repro.models.schema import init_params
    from repro.models.stream import DECBatch, PFBatch, UnifiedBatch

    cfg = get_reduced("llama3-8b")
    params = init_params(cfg, jax.random.PRNGKey(0))
    B, S, k = 2, 10, 3
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S + k + 1), 0,
                              cfg.vocab)
    base = jnp.full((B,), -1)
    tbl = jnp.asarray(np.array([[3, 1, 7, 5], [2, 6, 4, 8]], np.int32))
    lens = np.array([k + 1, k])

    def drive():
        cache = init_paged_cache(cfg, 9, 8, B)
        pf = PFBatch(tokens=toks[:, :S], length=jnp.full((B,), S),
                     adapter=base, block_tables=tbl)
        cache = unified_forward(cfg, params, UnifiedBatch(pf=pf),
                                cache=cache).cache
        dec = DECBatch(tokens=toks[:, S:S + k + 1], pos=jnp.full((B,), S),
                       adapter=base, block_tables=tbl,
                       length=jnp.asarray(lens, jnp.int32))
        return np.asarray(unified_forward(cfg, params, UnifiedBatch(dec=dec),
                                          cache=cache).dec_logits)

    monkeypatch.delenv("REPRO_PAGED_ATTN_KERNEL", raising=False)
    ref = drive()
    monkeypatch.setenv("REPRO_PAGED_ATTN_KERNEL", "interpret")
    got = drive()
    valid = lens[:, None] > np.arange(k + 1)[None, :]
    np.testing.assert_allclose(got[valid], ref[valid], rtol=2e-4, atol=2e-4)


def test_paged_kernel_matches_dense_kernel():
    """The paged path and the dense path are the same attention: materialize
    each request's blocks contiguously and the dense kernel must agree."""
    B, h, g, hd, bs, nbt = 2, 4, 2, 16, 8, 4
    pos = np.array([13, 30])
    k_pool, v_pool, tables, kq = _paged_setup(B, g, hd, bs, nbt, 16, pos)
    q = jax.random.normal(kq, (B, h, hd))
    posj = jnp.asarray(pos, jnp.int32)
    y = paged_decode_attention(q, k_pool, v_pool, tables, posj,
                               interpret=True)
    tn = np.asarray(tables)
    kd = np.asarray(k_pool)[tn].reshape(B, nbt * bs, g, hd)
    vd = np.asarray(v_pool)[tn].reshape(B, nbt * bs, g, hd)
    yd = decode_attention(q, jnp.asarray(kd), jnp.asarray(vd), posj,
                          block_k=8, interpret=True)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yd),
                               rtol=2e-5, atol=2e-5)
