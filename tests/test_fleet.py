"""Fleet serving: the fleet-wide content-hash index (event-fed, never
stale), cross-pool block import with the fetch-vs-recompute rule, router
policies (affinity locality + anti-herding, round-robin, least-loaded),
N-replica byte-exactness against a single engine, and the fleet-wide
block-conservation property."""
import jax
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.core.lora import LoRAConfig
from repro.core.virtualization import AdapterStore, MixedLoraModel
from repro.fleet import (FleetConfig, FleetFabric, FleetIndex, Router,
                         RouterConfig, build_fleet, replicate_model)
from repro.models.schema import init_params
from repro.serving.clock import CostModel, VirtualClock
from repro.serving.engine import EngineConfig, UnifiedEngine
from repro.serving.kvcache import (STATE_KEYS, PagedCacheManager,
                                   request_chain_keys)
from repro.serving.request import Request
from _hyputil import given, hyp as _hyp, settings, st
from test_preempt import _check_conservation

CFG = get_reduced("llama3-8b")
LCFG = LoRAConfig(n_slots=4, r=4)
# prefill-bound regime (same as bench_dedup): per-block recompute costs
# 16 tokens x 1e-4 s while a remote copy costs fixed 1e-3 + 1e-4 per
# block, so fetching wins from the second block on
FETCH_COST = CostModel(fixed=1e-3, prefill_per_tok=1e-4)


def _mgr(capacity=4, n_blocks=16, s_max=64, bs=8, **kw):
    return PagedCacheManager(CFG, capacity, 2, s_max, block_size=bs,
                             n_blocks=n_blocks, **kw)


def _publish(m, prompt, adapter="", max_new=4):
    """Admit + commit + free: leaves the prompt's full blocks index-only."""
    s, _ = m.try_admit(np.asarray(prompt, np.int32), max_new=max_new,
                       adapter=adapter)
    m.commit_prefill([(0, s)], [m._seq_len[s]])
    m.free(s)


# ---------------------------------------------------------------- FleetIndex
def test_fleet_index_mirrors_publish_and_retract():
    a, b = _mgr(), _mgr()
    fi = FleetIndex()
    fi.attach(0, a)
    fi.attach(1, b)
    p = np.arange(20, dtype=np.int32)          # 2 full blocks at bs=8
    _publish(a, p)
    keys = a.chain_keys(p)
    assert len(fi) == 2 and fi.entries == 2
    for k in keys:
        assert fi.locate(k) == (0, a._index[k])
    assert fi.resident_run(keys) == 2
    _publish(b, p)                             # replicated on both
    assert len(fi) == 2 and fi.entries == 4
    assert fi.holders(keys[0]) == [(0, a._index[keys[0]]),
                                   (1, b._index[keys[0]])]
    assert fi.locate(keys[0], prefer=1) == (1, b._index[keys[0]])
    fi.check_bijection()
    a.flush_index()                            # retraction via _depublish
    assert fi.entries == 2
    for k in keys:
        assert fi.locate(k)[0] == 1
    b.flush_index()
    assert len(fi) == 0 and fi.entries == 0
    fi.check_bijection()


def test_fleet_index_attach_ingests_and_guards():
    a = _mgr()
    p = np.arange(17, dtype=np.int32)
    _publish(a, p)
    fi = FleetIndex()
    fi.attach(0, a)                            # attach-after-warmup ingests
    assert len(fi) == 2
    fi.check_bijection()
    with pytest.raises(ValueError):
        fi.attach(0, _mgr())                   # engine id taken
    with pytest.raises(ValueError):
        FleetIndex().attach(1, a)              # manager already subscribed


def test_fleet_index_stale_free_under_truncate_and_cow_churn():
    """Decode commits publish, speculative truncate rolls back, CoW
    rewrites shared blocks — through all of it the fleet view must keep
    matching the local indexes exactly (the bijection IS the no-stale
    guarantee: a stale fleet entry would name a key the local index no
    longer holds)."""
    m = _mgr(n_blocks=12)
    fi = FleetIndex()
    fi.attach(0, m)
    rng = np.random.default_rng(3)
    live = []
    for i in range(8):
        got = m.try_admit(rng.integers(0, 3, 9 + i).astype(np.int32),
                          max_new=16)
        if got is None:
            continue
        live.append(got[0])
        m.commit_prefill([(0, got[0])], [m._seq_len[got[0]]])
        fi.check_bijection()
    for s in live:
        cap = m.grow(s, int(m.lens[s]) + 6)
        n = min(cap, int(m.lens[s]) + 6) - m._seq_len[s]
        if n > 0:
            m.commit_tokens(s, rng.integers(0, 3, n))
        fi.check_bijection()
        m.truncate(s, max(int(m.lens[s]) - 3, 0))
        fi.check_bijection()
    for s in live:
        m.free(s)
    fi.check_bijection()
    assert m.pristine
    assert len(fi) == len(m._index)


def test_fleet_index_tracks_swap_out_and_rehydrate():
    """Tiered-KV moves must keep the fleet mirror exact: swap-out
    de-publishes a victim's private blocks (the fleet retracts — their
    payload now rides a host buffer no sibling can import), restore
    re-publishes through the normal commit path, index shedding demotes to
    host (retract), and admission-time rehydration re-announces the key.
    The bijection check at every step IS the no-stale guarantee."""
    m = _mgr(n_blocks=16, host_blocks=8)
    fi = FleetIndex()
    fi.attach(0, m)
    p = np.arange(20, dtype=np.int32)              # 2 full blocks at bs=8
    s, _ = m.try_admit(p, max_new=4)
    m.commit_prefill([(0, s)], [20])
    keys = m.chain_keys(p)
    assert fi.entries == 2
    sid = m.swap_out(s)
    assert sid is not None
    assert fi.entries == 0                         # retracted on de-publish
    fi.check_bijection()
    m.free(s)
    s2, reused = m.try_admit(p, max_new=4)
    assert reused == 0
    m.restore_swap(s2, sid)
    m.commit_prefill([(0, s2)], [20])              # re-publish on commit
    assert fi.entries == 2
    assert all(fi.locate(k) == (0, m._index[k]) for k in keys)
    fi.check_bijection()
    m.free(s2)                                     # index-only now (ref 1)
    while m._shed_any():                           # demote both to host
        pass
    assert fi.entries == 0                         # retracted on demote
    assert m.host_pool.n_demoted == 2
    fi.check_bijection()
    s3, reused = m.try_admit(p, max_new=4)         # rehydration republishes
    assert reused == 16
    assert fi.entries == 2
    assert all(fi.locate(k) == (0, m._index[k]) for k in keys)
    fi.check_bijection()
    m.free(s3)
    assert m.pristine
    m.flush_index()
    m.flush_host()
    assert len(fi) == 0 and fi.entries == 0


# -------------------------------------------------------------- import_block
def test_import_block_copies_payload_and_adopts():
    a, b = _mgr(), _mgr()
    p = np.arange(20, dtype=np.int32)
    _publish(a, p)
    keys = a.chain_keys(p)
    for k in keys:
        bid = b.import_block(k, a, a._index[k])
        assert bid is not None
        # the copy is the literal published payload, every layer
        src_bid = a._index[k]
        for dl, sl in zip(b.cache["layers"], a.cache["layers"]):
            for name in dl:
                if name in STATE_KEYS:
                    continue
                np.testing.assert_array_equal(np.asarray(dl[name][:, bid]),
                                              np.asarray(sl[name][:, src_bid]))
        assert int(b.allocator.ref[bid]) == 1          # index-only cache
        assert b._index[k] == bid and b._hashed[bid] == k
    assert b.remote_imports == 2
    # a second import of a resident key is a no-op returning the local bid
    assert b.import_block(keys[0], a, a._index[keys[0]]) == b._index[keys[0]]
    assert b.remote_imports == 2
    # local admission now adopts the imported run exactly like a local
    # publication: both full blocks reused, byte-served from the copies
    s, reused = b.try_admit(p, max_new=4)
    assert reused == 16 and b.hash_hits == 2
    b.free(s)
    assert b.pristine


def test_import_block_refuses_when_pool_is_committed():
    b = _mgr(n_blocks=4)                       # 3 usable blocks
    a = _mgr()
    p = np.arange(20, dtype=np.int32)
    _publish(a, p)
    # 16-token prompt + 8 new = 3 projected blocks: 2 in the table, 1 of
    # reservation debt -> free_blocks == 0 with nothing sheddable
    s, _ = b.try_admit(np.arange(16, dtype=np.int32), max_new=8)
    assert b.free_blocks <= 0 and b.reclaimable_blocks == 0
    key = a.chain_keys(p)[0]
    assert b.import_block(key, a, a._index[key]) is None
    b.free(s)
    # with room back, the same import succeeds (shedding if needed)
    assert b.import_block(key, a, a._index[key]) is not None


# ------------------------------------------------------------------- routing
def _model(seed=0, adapters=("serve",)):
    params = init_params(CFG, jax.random.PRNGKey(seed))
    store = AdapterStore(CFG, LCFG, jax.random.PRNGKey(seed + 1))
    for i, name in enumerate(adapters):
        store.load_random(name, jax.random.PRNGKey(seed + 2 + i))
    return MixedLoraModel(CFG, params, store)


def _ecfg(**kw):
    kw = {"capacity": 4, "pf_capacity": 2, "s_max": 96, "block_size": 16,
          "virtual_time": True, **kw}
    return EngineConfig(**kw)


def _req(rid, prompt, adapter="serve", max_new=6, arrival=0.0):
    return Request(rid=rid, prompt=np.asarray(prompt, np.int32),
                   adapter=adapter, max_new_tokens=max_new,
                   arrival=arrival)


def test_router_round_robin_and_least_loaded():
    engines = [UnifiedEngine(m, _ecfg()) for m in
               replicate_model(_model(), 3)]
    rr = Router(engines, RouterConfig(policy="round-robin"))
    assert [rr.route(_req(i, np.arange(8))) for i in range(5)] \
        == [0, 1, 2, 0, 1]
    ll = Router(engines, RouterConfig(policy="least-loaded"))
    engines[0].waiting.append(_req(90, np.arange(8)))
    engines[1].waiting.append(_req(91, np.arange(8)))
    assert ll.route(_req(92, np.arange(8))) == 2
    with pytest.raises(ValueError):
        RouterConfig(policy="fastest")


def test_router_affinity_prefers_residency_but_not_forever():
    engines = [UnifiedEngine(m, _ecfg()) for m in
               replicate_model(_model(), 2)]
    p = np.arange(48, dtype=np.int32)
    _publish(engines[1].cachemgr, p, adapter="serve")
    r = _req(0, p)
    af = Router(engines, RouterConfig(policy="affinity"))
    assert af.route(r) == 1                    # 3 resident blocks win
    # anti-herding: affinity is bounded, load penalty is not — a deep
    # enough backlog on the resident replica flips the argmax, so one hot
    # template cannot starve the rest of the fleet
    c = af.cfg
    depth = int((1.0 + c.adapter_bonus) / c.load_penalty) + 1
    engines[1].waiting.extend(_req(100 + i, np.arange(8))
                              for i in range(depth))
    assert af.route(r) == 0


def test_router_affinity_adapter_residency_bonus():
    ma = _model(adapters=("hot",))
    mb = _model(adapters=("cold",))
    engines = [UnifiedEngine(ma, _ecfg()), UnifiedEngine(mb, _ecfg())]
    af = Router(engines, RouterConfig(policy="affinity"))
    assert af.route(_req(0, np.arange(8), adapter="hot")) == 0
    assert af.route(_req(1, np.arange(8), adapter="cold")) == 1


# --------------------------------------------------- fetch-vs-recompute rule
def test_fetch_rule_weighs_launch_overhead_against_prefill():
    fleet = build_fleet(_model(), _ecfg(), FleetConfig(replicas=2))
    warm, cold = fleet.engines
    p = np.arange(48, dtype=np.int32)          # 3 full blocks at bs=16
    _publish(warm.cachemgr, p, adapter="serve")
    r = _req(0, p)
    # the request's admission chain covers 2 of the 3 published blocks —
    # one prompt token must stay computable, so the third is unadoptable
    # and never worth fetching
    assert len(request_chain_keys(r, 16)) == 2
    # default cost model: fixed = 35 ms dwarfs 2 blocks' prefill — the
    # rule must refuse to fetch (recompute is cheaper)
    assert fleet._fetch_prefix(1, r) == 0
    assert cold.cachemgr.remote_imports == 0
    # prefill-bound regime: the same 2 blocks are worth one transfer
    cold.clock = VirtualClock(FETCH_COST)
    t0 = cold.clock.now()
    assert fleet._fetch_prefix(1, r) == 2
    assert cold.cachemgr.remote_imports == 2
    assert cold.metrics.remote_fetch_time > 0
    assert cold.clock.now() == pytest.approx(
        t0 + FETCH_COST.fixed + 2 * FETCH_COST.remote_per_block)
    # idempotent: everything already local now
    assert fleet._fetch_prefix(1, r) == 0
    fleet.index.check_bijection()


# ------------------------------------------------------- E2E byte-exactness
def _trace(n=6, max_new=6, seed=0, head_len=48):
    head = np.arange(head_len, dtype=np.int32) % CFG.vocab
    rng = np.random.default_rng(seed)
    return [_req(i, np.concatenate([head, rng.integers(
                     0, CFG.vocab, rng.integers(4, 12)).astype(np.int32)]),
                 arrival=0.05 * i, max_new=max_new) for i in range(n)]


def _outputs(finished):
    return {r.rid: list(r.output) for r in finished}


@pytest.mark.parametrize("policy", ["affinity", "round-robin"])
def test_fleet_of_three_byte_identical_to_single_engine(policy):
    """N=3 replicas behind either router must emit byte-identical outputs
    to one engine serving the same trace — remote fetch copies published
    (CoW-immutable) K/V, replicas share base weights by reference and
    carry identically-loaded adapters, so placement must be invisible."""
    ecfg = _ecfg(cost=FETCH_COST)
    ref_eng = UnifiedEngine(_model(), ecfg)
    for r in _trace():
        ref_eng.submit(r)
    ref_eng.run(max_ticks=8000)
    ref = _outputs(ref_eng.finished)
    assert len(ref) == 6

    fleet = build_fleet(_model(), ecfg, FleetConfig(
        replicas=3, router=RouterConfig(policy=policy)))
    for r in _trace():
        fleet.submit(r)
    fm = fleet.run()
    assert _outputs(r for e in fleet.engines for r in e.finished) == ref
    if policy == "round-robin":
        # spreading a shared-prefix trace forces cross-replica fetches
        assert sum(fleet.routed.values()) == 6
        assert min(fleet.routed.values()) >= 1
        assert fm.remote_fetch_blocks > 0 and fm.remote_fetch_time > 0
    fleet.index.check_bijection()
    assert all(e.cachemgr.pristine for e in fleet.engines)
    assert fm.elapsed == pytest.approx(max(e.clock.now()
                                           for e in fleet.engines))


def test_fleet_exact_under_preemption_churn():
    """Over-admission preemption inside replicas must not leak into the
    fleet index (retraction fires from the one local removal path) nor
    change outputs."""
    ecfg = _ecfg(cost=FETCH_COST, n_blocks=12, over_admit=2.0)
    ref_eng = UnifiedEngine(_model(), ecfg)
    for r in _trace(n=4, max_new=24, head_len=16):
        ref_eng.submit(r)
    ref_eng.run(max_ticks=8000)
    ref = _outputs(ref_eng.finished)
    assert len(ref) == 4

    fleet = build_fleet(_model(), ecfg, FleetConfig(
        replicas=3, router=RouterConfig(policy="round-robin")))
    for r in _trace(n=4, max_new=24, head_len=16):
        fleet.submit(r)
    fleet.run()
    assert _outputs(r for e in fleet.engines for r in e.finished) == ref
    fleet.index.check_bijection()
    assert all(e.cachemgr.pristine for e in fleet.engines)


# ------------------------------------------- fleet conservation (hypothesis)
@_hyp(lambda: [settings(max_examples=15, deadline=None),
               given(ops=st.lists(st.tuples(st.integers(0, 1),
                                            st.integers(0, 8),
                                            st.integers(0, 7),
                                            st.integers(0, 80)),
                                  min_size=1, max_size=50),
                     over_admit=st.sampled_from([1.0, 1.75]))])
def test_fleet_block_conservation_property(ops, over_admit):
    """The single-pool conservation property, extended across a 2-replica
    fleet with cross-pool imports AND per-replica adapter paging in the op
    mix: every manager keeps refcount == table + index + adapter-table
    holds with a mirrored free list, the fleet index stays a bijection
    with the local indexes (no stale entries, ever), pinned adapters are
    never shed by cross-class pressure, and a full drain of ALL replicas
    leaves every pool pristine with flush reclaiming everything."""
    ms = [_mgr(capacity=4, n_blocks=13, s_max=96, bs=8,
               over_admit=over_admit) for _ in range(2)]
    fi = FleetIndex()
    for i, m in enumerate(ms):
        fi.attach(i, m)
    live = [[], []]
    pins = [{}, {}]
    rng = np.random.default_rng(0)
    for who, kind, pick, amount in ops:
        m, lv = ms[who], live[who]
        pinned_resident = {n for n, c in pins[who].items()
                           if c > 0 and n in m.adapter_tables}
        if kind == 0:                                     # admit (+ adopt)
            prompt = rng.integers(0, 3, 1 + amount % 40).astype(np.int32)
            got = m.try_admit(prompt, max_new=amount % 48)
            if got is not None:
                lv.append(got[0])
        elif kind == 1 and lv:                            # decode advance
            slot = lv[pick % len(lv)]
            cap = m.grow(slot, int(m.lens[slot]) + 1 + amount % 24)
            n = min(cap, int(m.lens[slot]) + 1 + amount % 24) \
                - m._seq_len[slot]
            if n > 0:
                m.commit_tokens(slot, rng.integers(0, 3, n))
        elif kind == 2 and lv:                            # truncate (spec)
            slot = lv[pick % len(lv)]
            m.truncate(slot, max(int(m.lens[slot]) - amount % 16, 0))
        elif kind == 3 and lv:                            # preempt / finish
            m.free(lv.pop(pick % len(lv)))
        elif kind == 4 and lv:                            # commit the prompt
            slot = lv[pick % len(lv)]
            n = min(m._seq_len[slot], len(m.tables[slot]) * m.block_size)
            m.commit_prefill([(0, slot)], [n])
        elif kind == 5 and lv:                            # grow to capacity
            slot = lv[pick % len(lv)]
            m.grow(slot, m.reserved.get(slot, 1) * m.block_size)
        elif kind == 6:                                   # remote import
            src = ms[1 - who]
            if src._index:
                key = sorted(src._index)[pick % len(src._index)]
                m.import_block(key, src, src._index[key])
        elif kind == 7:                                   # adapter admit
            name = f"A{pick % 3}"
            if name not in m.adapter_tables:
                nb = 1 + (amount * 211) % (2 * m.adapter_block_bytes - 1)
                m.adapter_admit(name,
                                rng.integers(0, 256, nb).astype(np.uint8))
        elif kind == 8:                                   # pin / unpin cycle
            name = f"A{pick % 3}"
            if pins[who].get(name, 0) and amount % 2:
                m.adapter_unpin(name)
                pins[who][name] -= 1
            else:
                m.adapter_pin(name)
                pins[who][name] = pins[who].get(name, 0) + 1
        assert pinned_resident <= set(m.adapter_tables), \
            "a pinned adapter was shed"
        for mm in ms:
            _check_conservation(mm, over_admit)
        fi.check_bijection()
    for who, m in enumerate(ms):                          # full fleet drain
        for slot in live[who]:
            m.free(slot)
        _check_conservation(m, over_admit)
        for name, c in list(pins[who].items()):
            for _ in range(c):
                m.adapter_unpin(name)
        assert m.pristine
    fi.check_bijection()
    assert fi.entries == sum(len(m._index) for m in ms)
    for m in ms:
        m.flush_adapters()
        m.flush_index()
        assert m.allocator.n_free == m.allocator.usable
        assert not m.adapter_tables and not m._adapter_pins
    assert len(fi) == 0 and fi.entries == 0


# ------------------------------------------------------------- replication
def test_replicate_model_shares_base_and_clones_adapters():
    model = _model(adapters=("a", "b"))
    reps = replicate_model(model, 3)
    assert reps[0] is model
    for rep in reps[1:]:
        assert rep.base is model.base          # zero extra base memory
        assert rep.store is not model.store
        assert set(rep.store.resident) == {"a", "b"}
        for name in ("a", "b"):
            np.testing.assert_array_equal(
                np.asarray(jax.tree_util.tree_leaves(
                    rep.store.get_adapter(name))[0]),
                np.asarray(jax.tree_util.tree_leaves(
                    model.store.get_adapter(name))[0]))
            assert float(rep.store.scale[rep.store.slot_of(name)]) \
                == float(model.store.scale[model.store.slot_of(name)])
