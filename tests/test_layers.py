"""Layer math: chunked attention == full attention, windows, MLA forms."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import layers as L


def _attn_inputs(seed, B, S, T, h, g, hd, q_off=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    q = jax.random.normal(ks[0], (B, S, h, hd))
    k = jax.random.normal(ks[1], (B, T, g, hd))
    v = jax.random.normal(ks[2], (B, T, g, hd))
    q_pos = jnp.broadcast_to(jnp.arange(S), (B, S)) + q_off
    k_pos = jnp.broadcast_to(jnp.arange(T), (B, T))
    lens = jax.random.randint(ks[3], (B,), 1, T + 1)
    k_valid = k_pos < lens[:, None]
    return q, k, v, q_pos, k_pos, k_valid


@pytest.mark.parametrize("chunk", [4, 8, 16])
@pytest.mark.parametrize("window", [0, 8])
def test_chunked_vs_full(chunk, window):
    q, k, v, qp, kp, kv = _attn_inputs(1, 2, 37, 53, 8, 2, 16, q_off=16)
    full = L.attention(q, k, v, q_pos=qp, k_pos=kp, k_valid=kv,
                       causal=True, window=window)
    ck = L.attention(q, k, v, q_pos=qp, k_pos=kp, k_valid=kv,
                     causal=True, window=window, chunk=chunk)
    np.testing.assert_allclose(np.asarray(full), np.asarray(ck),
                               rtol=3e-5, atol=3e-5)


def test_chunked_grad_matches_full():
    q, k, v, qp, kp, kv = _attn_inputs(2, 1, 24, 24, 4, 2, 8)
    f_full = lambda q_: L.attention(q_, k, v, q_pos=qp, k_pos=kp,
                                    k_valid=kv, causal=True).sum()
    f_ck = lambda q_: L.attention(q_, k, v, q_pos=qp, k_pos=kp, k_valid=kv,
                                  causal=True, chunk=8).sum()
    np.testing.assert_allclose(np.asarray(jax.grad(f_full)(q)),
                               np.asarray(jax.grad(f_ck)(q)),
                               rtol=1e-4, atol=1e-4)


def test_mla_chunked_vs_absorbed():
    ks = jax.random.split(jax.random.PRNGKey(3), 9)
    B, S, T, h, c, dn, dr, dv = 2, 19, 29, 4, 24, 16, 8, 16
    qn = jax.random.normal(ks[0], (B, S, h, dn))
    qp = jax.random.normal(ks[1], (B, S, h, dr))
    ckv = jax.random.normal(ks[2], (B, T, c))
    kpe = jax.random.normal(ks[3], (B, T, dr))
    wuk = jax.random.normal(ks[4], (c, h, dn)) * 0.2
    wuv = jax.random.normal(ks[5], (c, h, dv)) * 0.2
    q_pos = jnp.broadcast_to(jnp.arange(S), (B, S)) + 10
    k_pos = jnp.broadcast_to(jnp.arange(T), (B, T))
    k_valid = k_pos < jnp.array([[29], [20]])
    a = L.mla_attention(qn, qp, ckv, kpe, wuk, wuv, q_pos=q_pos, k_pos=k_pos,
                        k_valid=k_valid, causal=True)
    b = L.mla_attention(qn, qp, ckv, kpe, wuk, wuv, q_pos=q_pos, k_pos=k_pos,
                        k_valid=k_valid, causal=True, chunk=8)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=2e-4, atol=2e-4)


def test_rope_relative_shift_invariance():
    """RoPE scores depend only on relative positions."""
    ks = jax.random.split(jax.random.PRNGKey(4), 2)
    q = jax.random.normal(ks[0], (1, 4, 2, 16))
    k = jax.random.normal(ks[1], (1, 4, 2, 16))
    def scores(off):
        pos = jnp.arange(4)[None, :] + off
        qr, kr = L.rope(q, pos, 1e4), L.rope(k, pos, 1e4)
        return jnp.einsum("bshd,bthd->bhst", qr, kr)
    np.testing.assert_allclose(np.asarray(scores(0)), np.asarray(scores(100)),
                               rtol=1e-4, atol=1e-4)


def test_rms_norm():
    x = jax.random.normal(jax.random.PRNGKey(0), (3, 16)) * 5
    y = L.rms_norm(x, jnp.ones((16,)))
    rms = np.sqrt((np.asarray(y) ** 2).mean(-1))
    np.testing.assert_allclose(rms, 1.0, rtol=1e-3)


def test_window_masks_old_tokens():
    """With window W, attention output must be independent of keys older
    than W positions."""
    q, k, v, qp, kp, kv = _attn_inputs(5, 1, 1, 32, 4, 4, 8)
    qp = jnp.full((1, 1), 31)
    kv = jnp.ones((1, 32), bool)
    out1 = L.attention(q, k, v, q_pos=qp, k_pos=kp, k_valid=kv, window=8)
    k2 = k.at[:, :20].set(99.0)   # mutate tokens far outside the window
    v2 = v.at[:, :20].set(99.0)
    out2 = L.attention(q, k2, v2, q_pos=qp, k_pos=kp, k_valid=kv, window=8)
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2))
