"""Speculative decoding subsystem: drafters, exact greedy acceptance, the
multi-token verify bucket, cache rollback, engine exactness (spec == greedy
token-for-token, including under mixed fine-tune + inference batches), and
per-token SLO accounting."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.core.lora import LoRAConfig
from repro.core.virtualization import AdapterStore, MixedLoraModel
from repro.data import datasets
from repro.models.model import init_paged_cache, unified_forward
from repro.models.schema import init_params
from repro.models.stream import DECBatch, PFBatch, UnifiedBatch
from repro.serving.engine import EngineConfig, UnifiedEngine
from repro.serving.request import Request
from repro.serving.slo import spread_token_times
from repro.spec import (AdaptiveK, NgramDrafter, SpecConfig,
                        StaticSuffixDrafter, accept_greedy)
from repro.training.trainer import MixedLoraTrainer, TrainerConfig

LCFG = LoRAConfig(n_slots=4, r=4)


# ------------------------------------------------------------------ drafters
def test_ngram_drafter_prompt_lookup():
    d = NgramDrafter(max_n=3)
    ctx = np.array([5, 6, 7, 8, 1, 2, 5, 6, 7, 9, 3, 5, 6, 7])
    # trailing trigram (5,6,7) most recently recurred at index 6 -> followed
    # by 9, 3, 5, 6
    np.testing.assert_array_equal(d.draft(ctx, 4), [9, 3, 5, 6])
    np.testing.assert_array_equal(d.draft(ctx, 2), [9, 3])


def test_ngram_drafter_backoff_and_miss():
    d = NgramDrafter(max_n=3)
    # no trigram/bigram recurrence, but unigram 4 recurs -> follows with 9
    ctx = np.array([1, 2, 4, 9, 3, 4])
    np.testing.assert_array_equal(d.draft(ctx, 1), [9])
    # nothing recurs at all -> empty draft (row degenerates to plain decode)
    assert len(d.draft(np.array([1, 2, 3]), 4)) == 0
    assert len(d.draft(np.array([7]), 4)) == 0


def test_static_suffix_drafter_trace_replay():
    seq = np.arange(10)
    d = StaticSuffixDrafter(seq)
    np.testing.assert_array_equal(d.draft(seq[:6], 3), [6, 7, 8])
    np.testing.assert_array_equal(d.draft(seq[:9], 3), [9])   # tail clamp
    assert len(d.draft(seq, 3)) == 0                          # exhausted


# ---------------------------------------------------------------- acceptance
def test_accept_greedy_exactness_cases():
    lg = np.zeros((4, 5), np.float32)
    lg[0, 2] = lg[1, 3] = lg[2, 4] = lg[3, 1] = 1.0
    assert accept_greedy(np.array([2, 3, 4]), lg) == (3, [2, 3, 4, 1])
    assert accept_greedy(np.array([9, 9, 9]), lg) == (0, [2])
    assert accept_greedy(np.array([2, 9, 9]), lg) == (1, [2, 3])
    # empty draft == plain greedy decode of one token
    assert accept_greedy(np.zeros((0,), int), lg[:1]) == (0, [2])


def test_adaptive_k_walks_with_acceptance():
    ctl = AdaptiveK(SpecConfig(k_max=4, k_min=1))
    for _ in range(5):
        ctl.update(4, 4)
    assert ctl.k == 4
    for _ in range(10):
        ctl.update(4, 0)
    assert ctl.k == 1
    k_before = ctl.k
    ctl.update(0, 0)                     # draftless steps carry no signal
    assert ctl.k == k_before


# ------------------------------------------------- model-level verify bucket
@pytest.mark.parametrize("arch", ["llama3-8b", "deepseek-v2-236b"])
def test_verify_chunk_matches_sequential_decode(arch):
    """A (1 + k)-token verify chunk must produce, at every position, the
    same logits sequential single-token decode would — for standard
    attention and MLA, through scattered non-contiguous blocks, with ragged
    chunk lengths."""
    cfg = get_reduced(arch)
    params = init_params(cfg, jax.random.PRNGKey(0))
    B, S, k = 2, 10, 3
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S + k + 1), 0,
                              cfg.vocab)
    base = jnp.full((B,), -1)
    tbl = jnp.asarray(np.array([[3, 1, 7, 5], [2, 6, 4, 8]], np.int32))

    def prefill():
        cache = init_paged_cache(cfg, 9, 8, B)
        pf = PFBatch(tokens=toks[:, :S], length=jnp.full((B,), S),
                     adapter=base, block_tables=tbl)
        return unified_forward(cfg, params, UnifiedBatch(pf=pf),
                               cache=cache).cache

    cache = prefill()
    seq = []
    for i in range(k + 1):
        dec = DECBatch(tokens=toks[:, S + i], pos=jnp.full((B,), S + i),
                       adapter=base, block_tables=tbl)
        out = unified_forward(cfg, params, UnifiedBatch(dec=dec), cache=cache)
        cache = out.cache
        seq.append(np.asarray(out.dec_logits))
    seq = np.stack(seq, axis=1)                        # [B, k+1, V]

    lens = jnp.asarray([k + 1, k], jnp.int32)          # row 1 has a pad slot
    dec = DECBatch(tokens=toks[:, S:S + k + 1], pos=jnp.full((B,), S),
                   adapter=base, block_tables=tbl, length=lens)
    out = unified_forward(cfg, params, UnifiedBatch(dec=dec),
                          cache=prefill())
    chunk = np.asarray(out.dec_logits)
    assert chunk.shape[:2] == (B, k + 1)
    np.testing.assert_allclose(chunk[0], seq[0], rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(chunk[1, :k], seq[1, :k], rtol=2e-4, atol=2e-4)


# ------------------------------------------------------------------- engine
def _engine(cfg, spec, seed=0, trainers=0, **kw):
    params = init_params(cfg, jax.random.PRNGKey(seed))
    store = AdapterStore(cfg, LCFG, jax.random.PRNGKey(seed + 1))
    store.load_random("serve", jax.random.PRNGKey(seed + 2))
    kw = {"capacity": 4, "pf_capacity": 2, "s_max": 96, "block_size": 16,
          **kw}
    eng = UnifiedEngine(MixedLoraModel(cfg, params, store),
                        EngineConfig(virtual_time=True, spec=spec, **kw))
    for i in range(trainers):
        name = f"tr{i}"
        store.load_random(name, jax.random.PRNGKey(seed + 10 + i))
        rows, ev = datasets.split_eval(
            datasets.alpaca_like(12, vocab=cfg.vocab, seed=i))
        eng.add_trainer(MixedLoraTrainer(name, store.slot_of(name), rows, ev,
                                         TrainerConfig(rows_per_micro=2,
                                                       accum_steps=2,
                                                       epochs=1)))
    return eng


def _reqs(cfg, n=6, seed=3, max_new=10, eos=-1):
    rng = np.random.default_rng(seed)
    return [Request(rid=i, prompt=rng.integers(0, cfg.vocab, rng.integers(
                        6, 24)).astype(np.int32),
                    adapter="serve", max_new_tokens=max_new, eos_token=eos,
                    arrival=0.2 * i) for i in range(n)]


def test_spec_equals_greedy_token_for_token():
    cfg = get_reduced("llama3-8b")
    eng_p = _engine(cfg, None)
    eng_s = _engine(cfg, SpecConfig(k_max=4, drafter="ngram"))
    for eng in (eng_p, eng_s):
        for r in _reqs(cfg):
            eng.submit(r)
        eng.run(max_ticks=5000)
        assert len(eng.finished) == 6
    assert ({r.rid: r.output for r in eng_p.finished}
            == {r.rid: r.output for r in eng_s.finished})


def test_spec_equals_greedy_with_mixed_finetune_batches():
    """Exactness must survive co-batching: fine-tune rows + prefill + verify
    chunks + plain decode share every unified step, and the trainers must
    still complete."""
    cfg = get_reduced("llama3-8b")
    eng_p = _engine(cfg, None, trainers=1)
    eng_s = _engine(cfg, SpecConfig(k_max=3, drafter="ngram"), trainers=1)
    for eng in (eng_p, eng_s):
        for r in _reqs(cfg, n=4):
            eng.submit(r)
        m = eng.run(max_ticks=20000)
        assert len(eng.finished) == 4
        assert m.finetune_tokens > 0
        for tr in eng.trainers.values():
            assert not tr.pending() and tr.optimizer_steps >= 1
    assert ({r.rid: r.output for r in eng_p.finished}
            == {r.rid: r.output for r in eng_s.finished})


def test_trace_replay_accepts_everything_and_saves_steps():
    """Suffix drafting from the recorded greedy trace: acceptance 1.0,
    byte-identical outputs, strictly fewer engine steps."""
    cfg = get_reduced("llama3-8b")
    eng_p = _engine(cfg, None)
    for r in _reqs(cfg):
        eng_p.submit(r)
    eng_p.run(max_ticks=5000)
    trace = {r.rid: r.output for r in eng_p.finished}

    eng_t = _engine(cfg, SpecConfig(k_max=4, drafter="suffix",
                                    adaptive=False))
    for r in _reqs(cfg):
        r.draft_suffix = np.concatenate(
            [r.prompt, np.asarray(trace[r.rid], np.int64)])
        eng_t.submit(r)
    eng_t.run(max_ticks=5000)
    m = eng_t.metrics
    assert {r.rid: r.output for r in eng_t.finished} == trace
    assert m.acceptance_rate == 1.0
    assert m.steps < eng_p.metrics.steps
    assert m.decode_tokens == eng_p.metrics.decode_tokens


def test_spec_respects_eos_and_max_new():
    """The bonus/draft tail must be cut exactly where plain greedy would
    stop: at eos or at the max_new_tokens budget — never beyond."""
    cfg = get_reduced("llama3-8b")
    eng_p = _engine(cfg, None)
    for r in _reqs(cfg, n=4, max_new=6):
        eng_p.submit(r)
    eng_p.run(max_ticks=5000)
    plain = {r.rid: r.output for r in eng_p.finished}
    # pick each request's 3rd greedy token as its eos so speculation has to
    # stop mid-chunk
    eos_of = {rid: out[2] for rid, out in plain.items()}
    for spec in (None, SpecConfig(k_max=4, drafter="ngram")):
        engs = _engine(cfg, spec)
        for r in _reqs(cfg, n=4, max_new=6):
            r.eos_token = int(eos_of[r.rid])
            engs.submit(r)
        engs.run(max_ticks=5000)
        outs = {r.rid: r.output for r in engs.finished}
        if spec is None:
            baseline = outs
        else:
            assert outs == baseline
        for rid, out in outs.items():
            assert len(out) <= 6
            if eos_of[rid] in out:
                assert out.index(eos_of[rid]) == len(out) - 1


def test_spec_per_token_slo_accounting():
    """A verify step emitting n tokens must record n per-token latencies of
    step_latency / n (not one inflated gap), and token_times must stay in
    lockstep with output length."""
    ts = spread_token_times(1.0, 2.0, 4)
    np.testing.assert_allclose(ts, [1.25, 1.5, 1.75, 2.0])
    cfg = get_reduced("llama3-8b")
    eng = _engine(cfg, SpecConfig(k_max=4, drafter="ngram"))
    for r in _reqs(cfg):
        eng.submit(r)
    eng.run(max_ticks=5000)
    assert eng.metrics.spec_drafted > 0
    for r in eng.finished:
        assert len(r.token_times) == len(r.output)
        lat = r.decode_latencies()
        assert (lat >= 0).all()
        # multi-token steps spread evenly: every latency is positive under
        # the virtual clock (each tick charges nonzero cost)
        assert lat.size == len(r.output) - 1


def test_spec_admission_accounts_draft_headroom():
    """With speculation on, admission must charge each request the +k
    transient draft tokens: a pool exactly sized for the plain projection
    admits fewer concurrent requests when spec headroom is added."""
    cfg = get_reduced("llama3-8b")
    # 8 usable blocks of 16; plain projection = 2 blocks per request
    plain = _engine(cfg, None, n_blocks=9)
    spec = _engine(cfg, SpecConfig(k_max=4), n_blocks=9)
    prompt = np.arange(20, dtype=np.int32)
    h = spec.spec_headroom
    assert h == 4
    need_plain = plain.cachemgr.fresh_need(20, 12, prompt)
    need_spec = spec.cachemgr.fresh_need(20, 12, prompt, headroom=h)
    assert need_spec == need_plain + 1        # 20+12+4 tokens -> 3 blocks
    s1 = spec.cachemgr.try_admit(prompt, 12, headroom=h)
    s2 = spec.cachemgr.try_admit(prompt, 12, headroom=h)
    s3 = spec.cachemgr.try_admit(prompt, 12, headroom=h)
    assert s1 is not None and s2 is not None
    assert s3 is None                         # 3rd x 3 blocks > 8 usable
    assert plain.cachemgr.try_admit(prompt, 12) is not None  # plain fits 3


def test_headroom_never_strands_a_servable_request():
    """A request that fits its plain projection but NOT projection + k_max
    must still be admitted (with zero reserved draft room) and decode to
    the exact greedy output — not sit in WAITING forever."""
    cfg = get_reduced("llama3-8b")
    # 2 usable blocks of 16: prompt 20 + max_new 8 -> exactly 2 blocks,
    # while +4 headroom would project 3 > pool
    outs = {}
    for name, spec in (("plain", None),
                       ("spec", SpecConfig(k_max=4, drafter="ngram"))):
        eng = _engine(cfg, spec, n_blocks=3, s_max=32)
        eng.submit(Request(rid=0,
                           prompt=(np.arange(20) % cfg.vocab)
                           .astype(np.int32),
                           adapter="serve", max_new_tokens=8))
        eng.run(max_ticks=500)
        assert len(eng.finished) == 1 and not eng.waiting
        outs[name] = eng.finished[0].output
    assert outs["spec"] == outs["plain"]
