"""LoRA core + unified-flow semantics (the paper's Section 3.3 invariants)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hyputil import given, hyp as _hyp, settings, st

from repro.configs import get_reduced
from repro.core.lora import (LoRAConfig, dense, lora_apply_ref,
                             merge_adapter)
from repro.core.virtualization import AdapterStore
from repro.models.model import init_cache, unified_forward
from repro.models.schema import init_params
from repro.models.stream import DECBatch, FTBatch, PFBatch, UnifiedBatch

LCFG = LoRAConfig(n_slots=4, r=4)


@_hyp(lambda: [settings(max_examples=20, deadline=None),
               given(seed=st.integers(0, 1000), T=st.integers(1, 40))])
def test_lora_ref_matches_per_token_loop(seed, T):
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    d, r, n, o = 8, 2, 3, 6
    x = jax.random.normal(ks[0], (T, d))
    a = jax.random.normal(ks[1], (n, d, r))
    b = jax.random.normal(ks[2], (n, r, o))
    ids = jax.random.randint(ks[3], (T,), -2, n + 1)   # incl invalid both ways
    y = lora_apply_ref(x, a, b, ids)
    for t in range(T):
        i = int(ids[t])
        exp = (x[t] @ a[i] @ b[i]) if 0 <= i < n else jnp.zeros((o,))
        np.testing.assert_allclose(np.asarray(y[t]), np.asarray(exp),
                                   rtol=2e-5, atol=2e-5)


def test_single_adapter_equals_merged_weights():
    """Multi-LoRA path with one adapter == statically merged base weight
    (the static_merge baseline equivalence)."""
    ks = jax.random.split(jax.random.PRNGKey(0), 4)
    T, d, o, r, n = 12, 16, 8, 4, 3
    x = jax.random.normal(ks[0], (T, d))
    w = jax.random.normal(ks[1], (d, o)) * 0.2
    a = jax.random.normal(ks[2], (n, d, r)) * 0.2
    b = jax.random.normal(ks[3], (n, r, o)) * 0.2
    ids = jnp.full((T,), 1)
    y_multi = dense(x, w, None, {"a": a, "b": b}, ids)
    w_merged = merge_adapter(w, a, b, 1)
    np.testing.assert_allclose(np.asarray(y_multi), np.asarray(x @ w_merged),
                               rtol=1e-4, atol=1e-4)


def test_adapter_isolation_in_unified_batch():
    """Changing adapter k's weights must not change outputs of rows served by
    adapter j or by the base model (the Virtualized-Module isolation)."""
    cfg = get_reduced("llama3-8b")
    params = init_params(cfg, jax.random.PRNGKey(0))
    store = AdapterStore(cfg, LCFG, jax.random.PRNGKey(1))
    store.load_random("j", jax.random.PRNGKey(2))
    store.load_random("k", jax.random.PRNGKey(3))
    toks = jax.random.randint(jax.random.PRNGKey(4), (3, 8), 0, cfg.vocab)
    pf = PFBatch(tokens=toks, length=jnp.full((3,), 8),
                 adapter=jnp.array([store.slot_of("j"), store.slot_of("k"),
                                    -1]))
    out1 = unified_forward(cfg, params, UnifiedBatch(pf=pf),
                           cache=init_cache(cfg, 3, 16), loras=store.bank,
                           lora_scale=store.scale)
    # perturb adapter k
    store.unload("k")
    store.load_random("k", jax.random.PRNGKey(99))
    out2 = unified_forward(cfg, params, UnifiedBatch(pf=pf),
                           cache=init_cache(cfg, 3, 16), loras=store.bank,
                           lora_scale=store.scale)
    np.testing.assert_allclose(np.asarray(out1.pf_logits[0]),
                               np.asarray(out2.pf_logits[0]), rtol=1e-5,
                               atol=1e-5)
    np.testing.assert_allclose(np.asarray(out1.pf_logits[2]),
                               np.asarray(out2.pf_logits[2]), rtol=1e-5,
                               atol=1e-5)
    assert float(jnp.abs(out1.pf_logits[1] - out2.pf_logits[1]).max()) > 1e-4


def test_unified_batch_equals_separate_passes():
    """One unified step == running ft, pf and dec buckets in separate steps
    (Algorithm 1's joint projections change nothing numerically)."""
    cfg = get_reduced("llama3-8b")
    params = init_params(cfg, jax.random.PRNGKey(0))
    store = AdapterStore(cfg, LCFG, jax.random.PRNGKey(1))
    store.load_random("x", jax.random.PRNGKey(2))
    ks = jax.random.split(jax.random.PRNGKey(5), 4)
    ft = FTBatch(tokens=jax.random.randint(ks[0], (2, 12), 0, cfg.vocab),
                 mask=jnp.ones((2, 12), bool),
                 labels=jax.random.randint(ks[1], (2, 12), 0, cfg.vocab),
                 adapter=jnp.array([0, -1]), weight=jnp.ones((2,)))
    pf = PFBatch(tokens=jax.random.randint(ks[2], (2, 8), 0, cfg.vocab),
                 length=jnp.array([8, 6]), adapter=jnp.array([0, -1]))
    # seed a decode row by prefilling first
    cache0 = init_cache(cfg, 1, 32)
    seed_pf = PFBatch(tokens=jax.random.randint(ks[3], (1, 8), 0, cfg.vocab),
                      length=jnp.array([8]), adapter=jnp.array([0]))
    seeded = unified_forward(cfg, params, UnifiedBatch(pf=seed_pf),
                             cache=cache0, loras=store.bank,
                             lora_scale=store.scale)
    dec = DECBatch(tokens=jnp.array([5]), pos=jnp.array([8]),
                   adapter=jnp.array([0]))

    # separate passes
    sep_ft = unified_forward(cfg, params, UnifiedBatch(ft=ft),
                             loras=store.bank, lora_scale=store.scale)
    cache_pf = init_cache(cfg, 2, 32)
    sep_pf = unified_forward(cfg, params, UnifiedBatch(pf=pf),
                             cache=cache_pf, loras=store.bank,
                             lora_scale=store.scale)
    sep_dec = unified_forward(cfg, params, UnifiedBatch(dec=dec),
                              cache=seeded.cache, loras=store.bank,
                              lora_scale=store.scale)

    # one unified pass (dec rows first, then pf rows in the cache)
    cache_u = jax.tree_util.tree_map(
        lambda a, b: jnp.concatenate([a, b], axis=1),
        seeded.cache, init_cache(cfg, 2, 32))
    uni = unified_forward(cfg, params, UnifiedBatch(ft=ft, pf=pf, dec=dec),
                          cache=cache_u, loras=store.bank,
                          lora_scale=store.scale)
    np.testing.assert_allclose(np.asarray(uni.ft_loss_sum),
                               np.asarray(sep_ft.ft_loss_sum),
                               rtol=5e-4, atol=5e-4)
    np.testing.assert_allclose(np.asarray(uni.pf_logits),
                               np.asarray(sep_pf.pf_logits),
                               rtol=5e-4, atol=5e-4)
    np.testing.assert_allclose(np.asarray(uni.dec_logits),
                               np.asarray(sep_dec.dec_logits),
                               rtol=5e-4, atol=5e-4)


def test_grad_only_on_ft_rows():
    """Inference rows contribute nothing to the LoRA gradient: grads with and
    without pf/dec buckets are identical (XLA prunes inference backward)."""
    from repro.core.unified import make_grad_step
    cfg = get_reduced("llama3-8b")
    params = init_params(cfg, jax.random.PRNGKey(0))
    store = AdapterStore(cfg, LCFG, jax.random.PRNGKey(1))
    store.load_random("x", jax.random.PRNGKey(2))
    ks = jax.random.split(jax.random.PRNGKey(6), 2)
    ft = FTBatch(tokens=jax.random.randint(ks[0], (2, 8), 0, cfg.vocab),
                 mask=jnp.ones((2, 8), bool),
                 labels=jax.random.randint(ks[1], (2, 8), 0, cfg.vocab),
                 adapter=jnp.array([0, 0]), weight=jnp.ones((2,)))
    pf = PFBatch(tokens=jnp.ones((1, 8), jnp.int32), length=jnp.array([8]),
                 adapter=jnp.array([0]))
    step = make_grad_step(cfg)
    g1 = step(params, store.bank, store.scale, UnifiedBatch(ft=ft), None)
    g2 = step(params, store.bank, store.scale, UnifiedBatch(ft=ft, pf=pf),
              init_cache(cfg, 1, 16))
    d = jax.tree_util.tree_map(lambda a, b: float(jnp.abs(a - b).max()),
                               g1.grads, g2.grads)
    assert max(jax.tree_util.tree_leaves(d)) < 5e-4
