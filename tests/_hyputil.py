"""Optional-hypothesis shim: the suite must collect without hypothesis
installed, while the property tests still run when it is available."""
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    given = settings = st = None


def hyp(deco_thunk):
    """Apply hypothesis decorators built by ``deco_thunk`` when the library is
    available; otherwise replace the property test with a skip (non-property
    tests in the module keep running)."""
    def wrap(fn):
        if st is None:
            def skipped():
                pytest.skip("hypothesis not installed")
            skipped.__name__ = fn.__name__
            return skipped
        for d in reversed(deco_thunk()):
            fn = d(fn)
        return fn
    return wrap
