"""Optional-hypothesis shim: the suite must collect without hypothesis
installed, while the property tests still run when it is available.  Also
owns the CI settings profile: ``HYPOTHESIS_PROFILE=ci`` pins a fixed,
derandomized configuration (no wall-clock deadline, examples replayed from
a deterministic seed) so CI property runs are reproducible and cannot
flake a merge on an unlucky draw."""
import os

import pytest

try:
    from hypothesis import given, settings, strategies as st

    settings.register_profile("ci", derandomize=True, deadline=None,
                              max_examples=40, print_blob=True)
    # the scheduled nightly job's budget: RANDOMIZED (fresh draws each
    # night, unlike the derandomized merge-blocking profile) and an order
    # of magnitude more examples — depth where a flake costs nobody a merge
    settings.register_profile("nightly", deadline=None, max_examples=300,
                              print_blob=True)
    _profile = os.environ.get("HYPOTHESIS_PROFILE")
    if _profile:
        settings.load_profile(_profile)
except ImportError:
    given = settings = st = None


def hyp(deco_thunk):
    """Apply hypothesis decorators built by ``deco_thunk`` when the library is
    available; otherwise replace the property test with a skip (non-property
    tests in the module keep running)."""
    def wrap(fn):
        if st is None:
            def skipped():
                pytest.skip("hypothesis not installed")
            skipped.__name__ = fn.__name__
            return skipped
        for d in reversed(deco_thunk()):
            fn = d(fn)
        return fn
    return wrap
