"""Prefix-aware suffix-only prefill + chunked prefill: kernel vs oracle,
model-level equivalence (standard attention AND MLA, chunked and unchunked),
and engine end-to-end — hash-deduped / chunked runs must emit byte-identical
tokens to full-prompt prefill, with the per-tick prefill budget bounding
every step."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.core.lora import LoRAConfig
from repro.core.virtualization import AdapterStore, MixedLoraModel
from repro.data import datasets
from repro.kernels.prefill_attn import paged_prefill_attention
from repro.kernels.ref import paged_prefill_ref
from repro.models.model import init_paged_cache, unified_forward
from repro.models.schema import init_params
from repro.models.stream import PFBatch, UnifiedBatch
from repro.serving.engine import EngineConfig, UnifiedEngine
from repro.serving.request import Request
from repro.spec import SpecConfig
from repro.training.trainer import MixedLoraTrainer, TrainerConfig

LCFG = LoRAConfig(n_slots=4, r=4)


# ------------------------------------------------------------------ kernel
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,h,g,hd,bs,nbt,Sq,bq", [
    (2, 4, 4, 8, 8, 4, 12, 4),     # MHA, multi-tile query walk
    (3, 8, 2, 16, 8, 6, 7, 8),     # GQA, ragged query pad
    (1, 8, 8, 32, 16, 5, 33, 16),  # wide suffix, several tiles
])
def test_paged_prefill_kernel_matches_ref(dtype, B, h, g, hd, bs, nbt, Sq,
                                          bq):
    """Query-tiled block-table prefill kernel == gather-then-attend oracle,
    with non-contiguous blocks, per-row cached prefixes, ragged suffix
    lengths, and padding rows (seg 0)."""
    rng = np.random.default_rng(B * Sq + bq)
    ks = jax.random.split(jax.random.PRNGKey(B * Sq), 3)
    n_blocks = nbt * B + 2
    k_pool = jax.random.normal(ks[0], (n_blocks, bs, g, hd)).astype(dtype)
    v_pool = jax.random.normal(ks[1], (n_blocks, bs, g, hd)).astype(dtype)
    cached = rng.integers(0, nbt * bs - Sq, B)
    seg = np.concatenate([[0], rng.integers(1, Sq + 1, B - 1)]) \
        if B > 1 else rng.integers(1, Sq + 1, B)
    tables = np.zeros((B, nbt), np.int32)
    for b in range(B):
        need = max((cached[b] + Sq - 1) // bs + 1, 1)
        tables[b, :need] = rng.choice(np.arange(1, n_blocks), size=need,
                                      replace=False)
    q = jax.random.normal(ks[2], (B, Sq, h, hd)).astype(dtype)
    cj = jnp.asarray(cached, jnp.int32)
    sj = jnp.asarray(seg, jnp.int32)
    tj = jnp.asarray(tables)
    y = np.asarray(paged_prefill_attention(q, k_pool, v_pool, tj, cj, sj,
                                           block_q=bq, interpret=True),
                   np.float32)
    yr = np.asarray(paged_prefill_ref(q, k_pool, v_pool, tj, cj, sj),
                    np.float32)
    tol = 3e-5 if dtype == jnp.float32 else 5e-2
    for b in range(B):  # rows past seg are padding (garbage in both paths)
        np.testing.assert_allclose(y[b, :seg[b]], yr[b, :seg[b]],
                                   rtol=tol, atol=tol)


# ----------------------------------------------------- model equivalence
def _split_points(S, n_chunks, rng):
    cuts = sorted(rng.choice(np.arange(1, S), size=n_chunks - 1,
                             replace=False)) if n_chunks > 1 else []
    return [0] + list(int(c) for c in cuts) + [S]


@pytest.mark.parametrize("arch", ["llama3-8b", "deepseek-v2-236b"])
@pytest.mark.parametrize("n_chunks", [2, 3])
def test_chunked_suffix_prefill_matches_full(arch, n_chunks):
    """Driving a prompt through ``n_chunks`` suffix-only prefill calls
    (cached_len = tokens already written, arbitrary non-aligned chunk
    boundaries) must reproduce the full-prompt prefill logits and leave the
    block pool byte-identical — for standard attention AND MLA."""
    cfg = get_reduced(arch)
    params = init_params(cfg, jax.random.PRNGKey(0))
    B, S = 2, 23
    rng = np.random.default_rng(n_chunks)
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab)
    base = jnp.full((B,), -1)
    tbl = jnp.asarray(np.array([[3, 1, 7, 5], [2, 6, 4, 8]], np.int32))

    cache = init_paged_cache(cfg, 9, 8, B)
    pf = PFBatch(tokens=toks, length=jnp.full((B,), S), adapter=base,
                 block_tables=tbl)
    full = unified_forward(cfg, params, UnifiedBatch(pf=pf), cache=cache)

    cache = init_paged_cache(cfg, 9, 8, B)
    pts = _split_points(S, n_chunks, rng)
    out = None
    for lo, hi in zip(pts, pts[1:]):
        pf = PFBatch(tokens=toks[:, lo:hi],
                     length=jnp.full((B,), hi - lo), adapter=base,
                     block_tables=tbl,
                     cached_len=jnp.full((B,), lo, jnp.int32))
        out = unified_forward(cfg, params, UnifiedBatch(pf=pf), cache=cache)
        cache = out.cache
    a, b = np.asarray(full.pf_logits), np.asarray(out.pf_logits)
    assert (a.argmax(-1) == b.argmax(-1)).all()
    np.testing.assert_allclose(a, b, rtol=2e-5, atol=2e-5)
    for la, lb in zip(full.cache["layers"], cache["layers"]):
        for key in la:
            np.testing.assert_allclose(np.asarray(la[key]),
                                       np.asarray(lb[key]),
                                       rtol=2e-5, atol=2e-5)


def test_model_prefill_kernel_flag(monkeypatch):
    """REPRO_PAGED_ATTN_KERNEL wires kernels.prefill_attn into the model's
    suffix-prefill bucket: logits must match the jnp gather-view path."""
    cfg = get_reduced("llama3-8b")
    params = init_params(cfg, jax.random.PRNGKey(0))
    B, S, cached = 2, 14, 8
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab)
    base = jnp.full((B,), -1)
    tbl = jnp.asarray(np.array([[3, 1, 7, 5], [2, 6, 4, 8]], np.int32))

    def drive():
        cache = init_paged_cache(cfg, 9, 8, B)
        pf = PFBatch(tokens=toks[:, :cached],
                     length=jnp.full((B,), cached), adapter=base,
                     block_tables=tbl,
                     cached_len=jnp.zeros((B,), jnp.int32))
        cache = unified_forward(cfg, params, UnifiedBatch(pf=pf),
                                cache=cache).cache
        pf = PFBatch(tokens=toks[:, cached:],
                     length=jnp.full((B,), S - cached), adapter=base,
                     block_tables=tbl,
                     cached_len=jnp.full((B,), cached, jnp.int32))
        return np.asarray(unified_forward(cfg, params, UnifiedBatch(pf=pf),
                                          cache=cache).pf_logits)

    monkeypatch.delenv("REPRO_PAGED_ATTN_KERNEL", raising=False)
    ref = drive()
    monkeypatch.setenv("REPRO_PAGED_ATTN_KERNEL", "interpret")
    got = drive()
    np.testing.assert_allclose(got, ref, rtol=2e-4, atol=2e-4)


# ------------------------------------------------------------------ engine
def _engine(cfg, seed=0, trainers=0, **kw):
    params = init_params(cfg, jax.random.PRNGKey(seed))
    store = AdapterStore(cfg, LCFG, jax.random.PRNGKey(seed + 1))
    store.load_random("serve", jax.random.PRNGKey(seed + 2))
    kw = {"capacity": 4, "pf_capacity": 2, "s_max": 96, "block_size": 16,
          "virtual_time": True, **kw}
    eng = UnifiedEngine(MixedLoraModel(cfg, params, store),
                        EngineConfig(**kw))
    for i in range(trainers):
        name = f"tr{i}"
        store.load_random(name, jax.random.PRNGKey(seed + 10 + i))
        rows, ev = datasets.split_eval(
            datasets.alpaca_like(12, vocab=cfg.vocab, seed=i))
        eng.add_trainer(MixedLoraTrainer(name, store.slot_of(name), rows, ev,
                                         TrainerConfig(rows_per_micro=2,
                                                       accum_steps=2,
                                                       epochs=1)))
    return eng


def _shared_reqs(cfg, n=5, max_new=6, tail=(4, 12), seed=0):
    sys_prompt = np.arange(32, dtype=np.int32) % cfg.vocab
    rng = np.random.default_rng(seed)
    return [Request(rid=i,
                    prompt=np.concatenate([sys_prompt, rng.integers(
                        0, cfg.vocab, rng.integers(*tail)).astype(np.int32)]),
                    adapter="serve", max_new_tokens=max_new,
                    arrival=0.25 * i) for i in range(n)]


def _run(eng, reqs, max_ticks=8000):
    for r in reqs:
        eng.submit(r)
    eng.run(max_ticks=max_ticks)
    return {r.rid: list(r.output) for r in eng.finished}


@pytest.mark.parametrize("arch", ["llama3-8b", "deepseek-v2-236b"])
def test_engine_suffix_prefill_matches_no_dedup(arch):
    """Suffix-only prefill over hash-adopted blocks emits tokens
    byte-identical to the no-dedup engine, and actually skips work — for
    standard attention AND MLA."""
    cfg = get_reduced(arch)
    out_plain = _run(_engine(cfg, hash_dedup=False), _shared_reqs(cfg))
    eng = _engine(cfg)
    out_shared = _run(eng, _shared_reqs(cfg))
    assert len(out_shared) == 5
    assert out_shared == out_plain
    m = eng.metrics
    assert m.reused_prefix_tokens >= 32 * 4   # 2 full blocks x later reqs
    assert m.hash_hits >= 2 * 4               # adopted from the 2nd sighting
    assert m.starved_ticks == 0


def test_engine_chunked_prefill_matches_unchunked_mixed_ft():
    """Chunked prefill (per-tick token budget) co-batched with fine-tune
    rows: byte-identical tokens, every step under the budget, trainers
    still converge their schedule."""
    cfg = get_reduced("llama3-8b")
    ref = _run(_engine(cfg, trainers=1), _shared_reqs(cfg, tail=(20, 40)))
    eng = _engine(cfg, trainers=1, prefill_chunk=16)
    out = _run(eng, _shared_reqs(cfg, tail=(20, 40)))
    assert out == ref
    m = eng.metrics
    assert m.max_pf_tokens_step <= 16
    assert m.starved_ticks == 0
    assert not eng.prefilling                 # no request left mid-prompt
    assert all(not t.pending() for t in eng.trainers.values())


def test_engine_spec_over_reused_prefix_with_chunking_matches_greedy():
    """Speculative decoding on top of suffix-only + chunked prefill stays
    exactly greedy."""
    cfg = get_reduced("llama3-8b")
    ref = _run(_engine(cfg), _shared_reqs(cfg, max_new=10))
    eng = _engine(cfg, prefill_chunk=16,
                  spec=SpecConfig(k_max=3, drafter="ngram"))
    out = _run(eng, _shared_reqs(cfg, max_new=10))
    assert out == ref


def test_engine_hash_dedup_reuses_repeated_heads():
    """Content-hash dedup: repeated prompt heads get published and adopted
    without any caller-side id — from the SECOND sighting (the two-sighting
    auto_prefix heuristic it subsumes only reused from the third) — and
    outputs stay identical to the escape-hatch engine."""
    cfg = get_reduced("llama3-8b")
    reqs = lambda: _shared_reqs(cfg, n=6)
    ref = _run(_engine(cfg, hash_dedup=False), reqs())
    eng = _engine(cfg)
    out = _run(eng, reqs())
    assert out == ref
    assert eng.metrics.reused_prefix_tokens >= 32 * 5  # 2nd request onward
    assert eng.metrics.hash_hits >= 2 * 5
    assert eng.metrics.hash_blocks_resident >= 2


def test_engine_chunked_prefill_keeps_decode_rows_flowing():
    """While a long prompt prefills in chunks, already-decoding requests
    must receive decode rows in EVERY step (no decode-starved ticks) and
    keep emitting tokens between chunk steps."""
    cfg = get_reduced("llama3-8b")
    eng = _engine(cfg, prefill_chunk=16, s_max=128)
    short = Request(rid=0, prompt=np.arange(6, dtype=np.int32),
                    adapter="serve", max_new_tokens=24, arrival=0.0)
    long_r = Request(rid=1, prompt=np.arange(64, dtype=np.int32) % cfg.vocab,
                     adapter="serve", max_new_tokens=4, arrival=0.0)
    eng.submit(short)
    eng.submit(long_r)
    prev_out = 0
    saw_chunk_with_decode = 0
    for _ in range(200):
        busy = eng.tick()
        if eng.prefilling and short.dec_slot in eng.active:
            # a chunk step ran while rid0 decoded: it must have progressed
            if len(short.output) > prev_out:
                saw_chunk_with_decode += 1
        prev_out = len(short.output)
        if not busy:
            break
    assert saw_chunk_with_decode >= 2         # several co-batched chunk steps
    assert eng.metrics.starved_ticks == 0
    assert len(eng.finished) == 2
    assert eng.metrics.max_pf_tokens_step <= 16
