"""Tiered KV memory: HostBlockPool byte accounting and pinning, the
swap-vs-recompute decision rule, swap-out/restore round trips (byte-exact,
CoW-safe around adopted shared blocks), index demote/rehydrate with
two-tier disjointness, int8 host residency, per-class reservation lending,
priority-class victim selection and scheduler tie-breaks, and engine-level
byte-exactness of swap preemption against recompute preemption."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.core.lora import LoRAConfig
from repro.core.virtualization import AdapterStore, MixedLoraModel
from repro.errors import ConfigInvariantError
from repro.models.schema import init_params
from repro.serving.clock import CostModel
from repro.serving.engine import EngineConfig, UnifiedEngine
from repro.serving.kvcache import (STATE_KEYS, HostBlockPool,
                                   PagedCacheManager, _blocks_write,
                                   swap_beats_recompute)
from repro.serving.request import (PRIORITY_CLASSES, Request, State,
                                   priority_rank)
from repro.serving.scheduler import Scheduler, SchedulerConfig

LCFG = LoRAConfig(n_slots=4, r=4)


def _mgr(capacity=4, n_blocks=12, s_max=64, bs=8, **kw):
    cfg = get_reduced("llama3-8b")
    return PagedCacheManager(cfg, capacity, 2, s_max, block_size=bs,
                             n_blocks=n_blocks, **kw)


def _scribble(m, bids, seed=0):
    """Fill ``bids`` with deterministic non-zero K/V so round trips compare
    real payloads, not the zero-initialized pool.  Returns the written raw
    payload (per-layer dicts, block axis second) — ``_raw_np`` layout."""
    rng = np.random.default_rng(seed)
    payload = tuple(
        {k: jnp.asarray(rng.standard_normal(
            (v.shape[0], len(bids)) + v.shape[2:]).astype(v.dtype))
         for k, v in d.items() if k not in STATE_KEYS}
        for d in m.cache["layers"])
    m.cache = _blocks_write(m.cache, jnp.asarray(bids, jnp.int32), payload)
    return tuple({k: np.asarray(v) for k, v in d.items()} for d in payload)


def _raw_np(m, bids):
    """Device-tier block payloads read straight off the pool (raw floats
    regardless of host-tier quantization)."""
    idx = jnp.asarray(bids, jnp.int32)
    return tuple({k: np.asarray(v[:, idx]) for k, v in d.items()
                  if k not in STATE_KEYS}
                 for d in m.cache["layers"])


def _payloads_equal(a, b, exact=True, tol=0.0):
    for da, db in zip(a, b):
        assert set(da) == set(db)
        for k in da:
            if exact:
                np.testing.assert_array_equal(da[k], db[k])
            else:
                assert float(np.max(np.abs(da[k] - db[k]))) <= tol


# ------------------------------------------------------------ HostBlockPool
def test_host_pool_rejects_empty_budget():
    with pytest.raises(ConfigInvariantError):
        HostBlockPool(0)


def test_host_pool_swap_sets_are_pinned_demoted_are_lru():
    hp = HostBlockPool(100)
    sid = hp.put_swap({"layers": (), "n": 2, "tokens": 16, "bytes": 60})
    assert sid is not None and hp.used_bytes == 60
    # demoted entries fill the rest, oldest evicted first under pressure
    assert hp.put_demoted("a", {"layers": (), "n": 1, "bytes": 20})
    assert hp.put_demoted("b", {"layers": (), "n": 1, "bytes": 20})
    assert hp.free_bytes == 0
    assert hp.put_demoted("c", {"layers": (), "n": 1, "bytes": 20})
    assert hp.evictions == 1 and not hp.has_demoted("a")
    assert hp.demoted_keys() == {"b", "c"}
    # a swap set is NEVER evicted: a second set needing its bytes refuses
    assert hp.put_swap({"layers": (), "n": 2, "bytes": 80}) is None
    assert hp.n_swap_sets == 1 and hp.demoted_keys() == set()
    assert hp.evictions == 3                  # the refusal flushed b and c
    # re-putting an existing key refreshes in place, no double-charge
    assert hp.put_demoted("d", {"layers": (), "n": 1, "bytes": 20})
    assert hp.put_demoted("d", {"layers": (), "n": 1, "bytes": 20})
    assert hp.used_bytes == 80 and hp.n_demoted == 1
    assert hp.pop_swap(sid)["bytes"] == 60
    with pytest.raises(Exception):
        hp.pop_swap(sid)                      # unknown sid is loud...
    assert hp.pop_swap(sid, missing_ok=True) is None   # ...unless opted out
    assert hp.flush_demoted() == 1
    assert hp.used_bytes == 0 and hp.peak_used_bytes == 100


def test_swap_rule_is_strict_ties_recompute():
    c = dataclasses.replace(CostModel(), d2h_per_byte=1.0, h2d_per_byte=1.0,
                            prefill_per_tok=2.0)
    assert not swap_beats_recompute(1, 1, c)      # 2 == 2: tie -> recompute
    assert swap_beats_recompute(1, 2, c)          # 2 < 4: transfer wins
    assert not swap_beats_recompute(2, 1, c)
    # default cost model: one raw 16 KiB block beats 16 tokens of prefill
    assert swap_beats_recompute(16384, 16, CostModel())


# ------------------------------------------------------ swap-out / restore
def test_swap_roundtrip_restores_bytes_and_depublishes():
    m = _mgr(host_blocks=8)
    prompt = np.arange(20, dtype=np.int32)        # 2 full blocks + tail
    s, _ = m.try_admit(prompt, max_new=4)
    m.commit_prefill([(0, s)], [20])
    bids = list(m.tables[s])
    before = _scribble(m, bids)
    assert m.hash_blocks_resident == 2
    sid = m.swap_out(s)
    assert sid is not None and m.kv_swap_outs == 1
    # this slot privately owned its published blocks (ref == 2): swap-out
    # de-published them so the free actually reclaims the device tier
    assert m.hash_blocks_resident == 0
    m.free(s)
    assert not m.pristine                         # a live swap set is debt
    s2, reused = m.try_admit(prompt, max_new=4)
    assert reused == 0                            # nothing index-resident
    covered = m.restore_swap(s2, sid)
    # stored 20 tokens clip to seq_len - 1 = 19: suffix prefill keeps a
    # live query token, and 19 tokens still span all 3 payload blocks
    assert covered == 19
    assert m.host_pool.n_swap_sets == 0 and m.host_pool.used_bytes == 0
    _payloads_equal(_raw_np(m, list(m.tables[s2])[:3]), before)
    m.free(s2)
    assert m.pristine
    m.flush_index()
    assert m.allocator.n_free == m.allocator.usable


def test_restore_skips_adopted_shared_blocks():
    """A re-admission that adopted index-resident blocks must NOT have its
    restore write them: they may be CoW-shared with a live sibling, and
    refcount adoption already guarantees their content."""
    m = _mgr(host_blocks=8, n_blocks=16)
    prompt = np.arange(20, dtype=np.int32)
    sa, _ = m.try_admit(prompt, max_new=4)        # the surviving sibling
    m.commit_prefill([(0, sa)], [20])
    _scribble(m, list(m.tables[sa])[:2], seed=1)
    sb, reused = m.try_admit(prompt, max_new=12)
    assert reused == 16                           # adopted both full blocks
    m.grow(sb, 28)
    m.commit_tokens(sb, np.arange(8, dtype=np.int32))
    shared_bids = list(m.tables[sb])[:2]
    assert shared_bids == list(m.tables[sa])[:2]
    sid = m.swap_out(sb)
    assert sid is not None
    # shared blocks have other holders -> still published for re-adoption
    assert m.hash_blocks_resident >= 2
    m.free(sb)
    rolled = np.arange(28, dtype=np.int32)        # prompt + emitted tokens
    rolled[:20] = prompt
    sc, reused = m.try_admit(rolled, max_new=4)
    assert reused == 16 and m.shared_count[sc] == 2
    sibling_before = _raw_np(m, shared_bids)
    b0 = m.kv_restore_bytes
    covered = m.restore_swap(sc, sid)
    assert covered == 27                          # 28-token store clips to 27
    # only the blocks BEYOND the adopted run were written H2D...
    assert m.kv_restore_bytes - b0 == 2 * m.host_block_bytes
    # ...and the sibling's (shared) payload is bit-for-bit untouched
    _payloads_equal(_raw_np(m, shared_bids), sibling_before)
    m.free(sc)
    m.free(sa)
    assert m.pristine


def test_drop_swap_is_idempotent():
    m = _mgr(host_blocks=4)
    s, _ = m.try_admit(np.arange(10, dtype=np.int32), max_new=4)
    m.commit_prefill([(0, s)], [10])
    sid = m.swap_out(s)
    m.free(s)
    assert m.drop_swap(sid) and m.kv_swap_drops == 1
    assert not m.drop_swap(sid)                   # double-release is a no-op
    assert not m.drop_swap(None)
    assert m.pristine and m.host_pool.used_bytes == 0


# ------------------------------------------------------ demote / rehydrate
def _publish(m, prompt, max_new=4):
    s, _ = m.try_admit(np.asarray(prompt, np.int32), max_new=max_new)
    m.commit_prefill([(0, s)], [m._seq_len[s]])
    payload = _scribble(m, list(m.tables[s])[:len(prompt) // m.block_size],
                        seed=7)
    m.free(s)
    return payload


def test_shed_demotes_and_admission_rehydrates_byte_identical():
    m = _mgr(host_blocks=8)
    prompt = np.arange(20, dtype=np.int32)
    payload = _publish(m, prompt)
    keys = [m._hashed[b] for b in
            [m._index[k] for k in m._index]]      # snapshot published keys
    assert len(keys) == 2
    while m._shed_any():                          # pressure: shed everything
        pass
    assert m.hash_blocks_resident == 0 and m.kv_demotions == 2
    # two-tier disjointness: the keys moved, they did not fork
    assert set(keys) == m.host_pool.demoted_keys()
    assert not (set(m._index) & m.host_pool.demoted_keys())
    s, reused = m.try_admit(prompt, max_new=4)
    assert reused == 16 and m.kv_rehydrations == 2
    assert m.host_pool.n_demoted == 0             # moved back, not copied
    _payloads_equal(_raw_np(m, list(m.tables[s])[:2]), payload)
    m.free(s)
    assert m.pristine
    m.flush_index()
    m.flush_host()
    assert m.allocator.n_free == m.allocator.usable


def test_import_block_drops_stale_demoted_twin():
    """A key arriving on-device through ANY publish path must evict its
    demoted host copy: one key, one tier."""
    src = _mgr()
    dst = _mgr(host_blocks=8)
    prompt = np.arange(20, dtype=np.int32)
    _publish(src, prompt)
    _publish(dst, prompt)
    while dst._shed_any():
        pass
    key = src.chain_keys(prompt)[0]
    assert dst.host_pool.has_demoted(key)
    bid = dst.import_block(key, src, src._index[key])
    assert bid is not None
    assert not dst.host_pool.has_demoted(key)
    assert not (set(dst._index) & dst.host_pool.demoted_keys())


# --------------------------------------------------- priority-class lending
def test_class_debt_lending_order():
    """``charged_debt`` lends from batch reservations first, then standard;
    interactive debt is never lent — and all-standard traffic reduces to
    the classless ``ceil(debt / over_admit)`` exactly."""
    prompt = np.zeros((8,), np.int32)             # 4-block life: 1 held,
    mk = lambda: _mgr(n_blocks=32, bs=16, over_admit=2.0)  # noqa: E731

    m = mk()                                      # 3 debt per admit
    m.try_admit(prompt, max_new=56, priority="interactive")
    m.try_admit(prompt, max_new=56, priority="batch")
    assert m.reserved_debt == 6
    # lend = 6 - ceil(6/2) = 3, all of it from the batch request
    assert m.charged_debt == 3

    m = mk()
    m.try_admit(prompt, max_new=56, priority="interactive")
    m.try_admit(prompt, max_new=56, priority="interactive")
    assert m.reserved_debt == 6
    assert m.charged_debt == 6                    # interactive is never lent

    m = mk()
    s1, _ = m.try_admit(prompt, max_new=56)
    m.try_admit(prompt, max_new=56)
    assert m.charged_debt == 3                    # classless baseline
    m.free(s1)                                    # debt retires with its class
    assert m.reserved_debt == 3 and m.charged_debt == 2


def test_pick_victim_prefers_batch_class():
    cfg = get_reduced("llama3-8b")
    params = init_params(cfg, jax.random.PRNGKey(0))
    store = AdapterStore(cfg, LCFG, jax.random.PRNGKey(1))
    store.load_random("serve", jax.random.PRNGKey(2))
    eng = UnifiedEngine(MixedLoraModel(cfg, params, store), EngineConfig(
        capacity=4, pf_capacity=4, s_max=64, virtual_time=True, paged=True,
        block_size=16, n_blocks=40))
    rng = np.random.default_rng(11)
    # interactive arrives LAST: classless order would evict it first
    reqs = [Request(rid=i, prompt=rng.integers(0, cfg.vocab, 8)
                    .astype(np.int32), adapter="serve", max_new_tokens=20,
                    arrival=0.1 * i, priority_class=pc)
            for i, pc in enumerate(("batch", "standard", "interactive"))]
    for r in reqs:
        eng.submit(r)
    for _ in range(200):
        eng.tick()
        if all(r.state is State.DECODE for r in reqs):
            break
    assert all(r.state is State.DECODE for r in reqs)
    assert eng.active[eng._pick_victim(frozenset())].priority_class \
        == "batch"
    # with the batch resident shielded, standard goes before interactive
    batch_slot = reqs[0].dec_slot
    assert eng.active[eng._pick_victim(frozenset([batch_slot]))] \
        .priority_class == "standard"


def test_scheduler_admits_interactive_first_on_score_ties():
    sched = Scheduler(SchedulerConfig(), capacity=8)
    rs = [Request(rid=i, prompt=np.zeros((8,), np.int32), adapter="",
                  max_new_tokens=8, arrival=0.0, priority_class=pc)
          for i, pc in enumerate(("batch", "standard", "interactive"))]
    d = sched.decide(rs, 0, 8, 4, False, probe_fn=lambda r: 0, now=0.0)
    assert [r.priority_class for r in d.admit] \
        == ["interactive", "standard", "batch"]


def test_unknown_priority_class_ranks_as_standard():
    """A misspelled class must not silently become un-preemptable (rank
    0) or permanently lendable (rank 2): it ranks as standard."""
    assert PRIORITY_CLASSES == ("interactive", "standard", "batch")
    assert priority_rank("urgent") == priority_rank("standard") == 1
    m = _mgr(over_admit=2.0)
    s, _ = m.try_admit(np.zeros((4,), np.int32), max_new=4,
                       priority="urgent")
    assert m._slot_rank[s] == 1


# ------------------------------------------------------- int8 host tier
def test_quant_host_tier_halves_block_bytes_and_roundtrips():
    m = _mgr(host_blocks=4, host_quant=True)
    raw = _mgr(host_blocks=4)
    # same RAW byte budget, smaller per-entry footprint = more entries
    assert m.host_pool.capacity_bytes == raw.host_pool.capacity_bytes
    assert m.host_block_bytes < raw.host_block_bytes
    prompt = np.arange(20, dtype=np.int32)
    s, _ = m.try_admit(prompt, max_new=4)
    m.commit_prefill([(0, s)], [20])
    bids = list(m.tables[s])
    before = _scribble(m, bids, seed=3)
    sid = m.swap_out(s)
    assert m.kv_swap_out_bytes == 3 * m.host_block_bytes
    m.free(s)
    s2, _ = m.try_admit(prompt, max_new=4)
    assert m.restore_swap(s2, sid) == 19
    # int8 residency is NOT bit-identical (that is the exactness-exempt
    # deal): per-group symmetric quantization bounds the error at half a
    # step of the per-(-2)-axis scale
    tol = max(float(np.max(np.abs(v))) for d in before for v in d.values()) \
        / 126.0
    _payloads_equal(_raw_np(m, list(m.tables[s2])[:3]), before,
                    exact=False, tol=tol)
    m.free(s2)
    assert m.pristine


# ----------------------------------------------------- engine byte-exactness
def _engine(cfg, **kw):
    params = init_params(cfg, jax.random.PRNGKey(0))
    store = AdapterStore(cfg, LCFG, jax.random.PRNGKey(1))
    store.load_random("serve", jax.random.PRNGKey(2))
    kw = {"capacity": 4, "pf_capacity": 2, "s_max": 64, "virtual_time": True,
          "paged": True, "block_size": 16, **kw}
    return UnifiedEngine(MixedLoraModel(cfg, params, store),
                         EngineConfig(**kw))


def test_swap_preemption_byte_identical_to_recompute():
    """The tentpole contract end to end: with a host pool, preemption
    swaps out and re-admission restores — and the outputs must be
    byte-identical to recompute preemption, which itself matches the
    conservative no-preemption gate."""
    cfg = get_reduced("llama3-8b")
    rng = np.random.default_rng(11)
    mk_reqs = lambda: [Request(                    # noqa: E731
        rid=i, prompt=rng.integers(0, cfg.vocab, 8).astype(np.int32),
        adapter="serve", max_new_tokens=40, arrival=0.1 * i)
        for i in range(3)]
    outs, engines = [], []
    for kw in ({"n_blocks": 8},                            # conservative
               {"n_blocks": 8, "over_admit": 2.0},         # recompute
               {"n_blocks": 8, "over_admit": 2.0,          # swap-restore
                "kv_host_blocks": 8}):
        rng = np.random.default_rng(11)
        eng = _engine(cfg, **kw)
        for r in mk_reqs():
            eng.submit(r)
        eng.run(max_ticks=5000)
        assert len(eng.finished) == 3
        assert all(r.state is State.DONE for r in eng.finished)
        outs.append({r.rid: r.output for r in eng.finished})
        engines.append(eng)
    assert outs[0] == outs[1] == outs[2]
    recompute, swap = engines[1], engines[2]
    assert recompute.metrics.preemptions >= 1
    assert swap.metrics.preemptions >= 1
    assert swap.metrics.kv_swap_outs >= 1
    assert swap.metrics.kv_restores == swap.metrics.kv_swap_outs
    assert swap.metrics.kv_restored_tokens > 0
    # the restore displaced recompute: strictly fewer re-prefilled tokens
    assert swap.metrics.preempted_tokens_recomputed \
        < recompute.metrics.preempted_tokens_recomputed
    # transfers were charged to the virtual clock, not modeled free
    assert swap.metrics.host_bytes_peak > 0
    for eng in engines:
        mgr = eng.cachemgr
        assert mgr.pristine
        mgr.flush_index()
        mgr.flush_host()
        assert mgr.allocator.n_free == mgr.allocator.usable
        assert mgr.reserved_debt == 0 and not mgr.tables
        if mgr.host_pool is not None:
            assert mgr.host_pool.used_bytes == 0
