"""Runtime substrates: engine, scheduler, trainers, optimizer, virtualization,
checkpointing, flow planner (with hypothesis properties)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyputil import given, hyp as _hyp, settings, st

from repro.configs import get_reduced
from repro.core import flow
from repro.core.lora import LoRAConfig
from repro.core.virtualization import AdapterStore, MixedLoraModel, VirtualModel
from repro.checkpoint import io
from repro.data import datasets, workload
from repro.models.schema import init_params
from repro.serving.engine import EngineConfig, UnifiedEngine
from repro.serving.request import Request, State
from repro.serving.scheduler import Scheduler, SchedulerConfig
from repro.serving.slo import SLOConfig, slo_attainment
from repro.training.optimizer import (AdamWConfig, adamw_apply, adamw_init)
from repro.training.trainer import MixedLoraTrainer, TrainerConfig

LCFG = LoRAConfig(n_slots=4, r=4)


# ---------------------------------------------------------------- flow planner
@_hyp(lambda: [settings(max_examples=30, deadline=None),
               given(lens=st.lists(st.integers(1, 60), min_size=1,
                                   max_size=9),
                     block_t=st.sampled_from([4, 8, 16]),
                     seed=st.integers(0, 99))])
def test_flow_planner_alignment_property(lens, block_t, seed):
    rng = np.random.default_rng(seed)
    fcfg = flow.FlowConfig(block_t=block_t)
    rows = [flow.FTRow(tokens=rng.integers(0, 50, L),
                       labels=rng.integers(0, 50, L),
                       slot=int(rng.integers(-1, 4)))
            for L in lens]
    pfs = [flow.PFReq(tokens=rng.integers(0, 50, L),
                      slot=int(rng.integers(-1, 4))) for L in lens[:3]]
    batch = flow.assemble(rows, pfs, np.array([1, 2]), np.array([0, 5]),
                          np.array([0, -1]), fcfg)
    assert flow.smlm_tile_aligned(batch, block_t)
    # padding rows are inert: weight 0, adapter -1
    Bf = batch.ft.tokens.shape[0]
    for i in range(len(rows), Bf):
        assert float(batch.ft.weight[i]) == 0.0
        assert int(batch.ft.adapter[i]) == -1
    # payload recoverable
    for i, r in enumerate(rows):
        L = len(r.tokens)
        np.testing.assert_array_equal(np.asarray(batch.ft.tokens[i, :L]),
                                      r.tokens)
        assert bool(batch.ft.mask[i, :L].all())
        assert not bool(batch.ft.mask[i, L:].any())


# ------------------------------------------------------------------ optimizer
def test_masked_adamw_isolation_and_correctness():
    key = jax.random.PRNGKey(0)
    params = {"w": {"a": jax.random.normal(key, (2, 3, 8, 4))}}  # slot axis -3
    grads = jax.tree_util.tree_map(jnp.ones_like, params)
    state = adamw_init(params, 3)
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0, grad_clip=0.0)
    mask = jnp.array([1.0, 0.0, 1.0])
    new_p, new_s = adamw_apply(cfg, grads, state, params, mask)
    dp = np.asarray(new_p["w"]["a"] - params["w"]["a"])
    assert np.abs(dp[:, 1]).max() == 0.0          # masked slot frozen
    # unmasked slots take ~lr-sized first Adam step
    np.testing.assert_allclose(np.abs(dp[:, 0]), 0.1, rtol=1e-3)
    assert list(np.asarray(new_s.t)) == [1, 0, 1]


@_hyp(lambda: [settings(max_examples=15, deadline=None),
               given(seed=st.integers(0, 100))])
def test_adamw_sequential_masks_commute(seed):
    """Updating slot A then slot B == updating both with separate masks, when
    gradients are identical (per-slot moments are independent)."""
    ks = jax.random.split(jax.random.PRNGKey(seed), 2)
    params = {"a": jax.random.normal(ks[0], (4, 6, 2))}
    grads = {"a": jax.random.normal(ks[1], (4, 6, 2))}
    cfg = AdamWConfig(lr=0.01, grad_clip=0.0)
    s0 = adamw_init(params, 4)
    pA, sA = adamw_apply(cfg, grads, s0, params, jnp.array([1., 0, 0, 0]))
    pAB, _ = adamw_apply(cfg, grads, sA, pA, jnp.array([0., 1, 0, 0]))
    pBoth, _ = adamw_apply(cfg, grads, s0, params, jnp.array([1., 1, 0, 0]))
    np.testing.assert_allclose(np.asarray(pAB["a"]), np.asarray(pBoth["a"]),
                               rtol=1e-5, atol=1e-6)


# -------------------------------------------------------------- virtualization
def test_store_lifecycle_and_base_immutability():
    cfg = get_reduced("llama3-8b")
    params = init_params(cfg, jax.random.PRNGKey(0))
    leaf_before = np.asarray(params["embed"]).copy()
    store = AdapterStore(cfg, LCFG, jax.random.PRNGKey(1))
    for i in range(LCFG.n_slots):
        store.load_random(f"a{i}", jax.random.PRNGKey(i))
    with pytest.raises(RuntimeError):
        store.load_random("overflow", jax.random.PRNGKey(99))
    store.unload("a1")
    slot = store.load_random("fresh", jax.random.PRNGKey(50))
    assert slot == 1                                 # freed slot reused
    np.testing.assert_array_equal(np.asarray(params["embed"]), leaf_before)


def test_void_unvoid_roundtrip_and_blob():
    cfg = get_reduced("llama3-8b")
    params = init_params(cfg, jax.random.PRNGKey(0))
    store = AdapterStore(cfg, LCFG, jax.random.PRNGKey(1))
    store.load_random("m", jax.random.PRNGKey(2), scale=1.5)
    vm = VirtualModel("m", params, store)
    voided = vm.void()
    blob = io.serialize_pytree(voided.adapter)
    voided.adapter = io.deserialize_pytree(blob, voided.adapter)
    store2 = AdapterStore(cfg, LCFG, jax.random.PRNGKey(3))
    vm2 = VirtualModel.unvoid(voided, params, store2)
    a1, a2 = store.get_adapter("m"), store2.get_adapter("m")
    d = jax.tree_util.tree_map(lambda x, y: float(jnp.abs(x - y).max()), a1, a2)
    assert max(jax.tree_util.tree_leaves(d)) == 0.0
    assert float(store2.scale[vm2.slot]) == 1.5


def test_checkpoint_roundtrip():
    cfg = get_reduced("phi3-medium-14b")
    params = init_params(cfg, jax.random.PRNGKey(0))
    import tempfile, os
    with tempfile.TemporaryDirectory() as td:
        path = os.path.join(td, "ckpt.npz")
        n = io.save_pytree(path, params)
        assert n > 0
        loaded = io.load_pytree(path, params)
        d = jax.tree_util.tree_map(lambda a, b: float(jnp.abs(a - b).max()),
                                   params, loaded)
        assert max(jax.tree_util.tree_leaves(d)) == 0.0


# ------------------------------------------------------------------ scheduler
def test_scheduler_mutable_capacity_concession():
    sched = Scheduler(SchedulerConfig(ft_rows_max=4, concede_at_queue=2),
                      capacity=8)
    idle = sched.decide([], 0, 8, 4, trainers_pending=True)
    assert idle.ft_rows == 4                         # full budget when idle
    reqs = [Request(rid=i, prompt=np.zeros(8, np.int32), adapter="")
            for i in range(12)]
    busy = sched.decide(reqs, 8, 0, 4, trainers_pending=True)
    assert busy.ft_rows == 0                         # fine-tuning concedes
    assert len(busy.admit) == 0                      # no free slots
    recovered = sched.decide([], 2, 6, 4, trainers_pending=True)
    assert 0 < recovered.ft_rows <= 4                # and recovers


# ---------------------------------------------------------------- engine e2e
def _mk_engine(cfg, trainers=0, seed=0):
    params = init_params(cfg, jax.random.PRNGKey(seed))
    store = AdapterStore(cfg, LCFG, jax.random.PRNGKey(seed + 1))
    store.load_random("serve", jax.random.PRNGKey(seed + 2))
    eng = UnifiedEngine(MixedLoraModel(cfg, params, store),
                        EngineConfig(capacity=4, pf_capacity=2, s_max=96,
                                     virtual_time=True))
    for i in range(trainers):
        name = f"tr{i}"
        store.load_random(name, jax.random.PRNGKey(seed + 10 + i))
        rows, ev = datasets.split_eval(
            datasets.alpaca_like(16, vocab=cfg.vocab, seed=i))
        eng.add_trainer(MixedLoraTrainer(name, store.slot_of(name), rows, ev,
                                         TrainerConfig(rows_per_micro=2,
                                                       accum_steps=2,
                                                       epochs=1)))
    return eng


def test_engine_serves_all_requests_with_slo():
    cfg = get_reduced("llama3-8b")
    eng = _mk_engine(cfg)
    prompts = datasets.sharegpt_prompts(8, vocab=cfg.vocab, len_lo=6,
                                        len_hi=20)
    arr = workload.poisson_arrivals(2.0, 8, seed=1)
    for i, (p, t) in enumerate(zip(prompts, arr)):
        eng.submit(Request(rid=i, prompt=p, adapter="serve",
                           max_new_tokens=6, arrival=float(t)))
    eng.run(max_ticks=10000)
    assert len(eng.finished) == 8
    assert all(len(r.output) == 6 for r in eng.finished)
    assert slo_attainment(eng.finished, SLOConfig()) == 1.0


def test_engine_unified_trains_and_serves():
    cfg = get_reduced("llama3-8b")
    eng = _mk_engine(cfg, trainers=2)
    prompts = datasets.sharegpt_prompts(4, vocab=cfg.vocab, len_lo=6,
                                        len_hi=16)
    for i, p in enumerate(prompts):
        eng.submit(Request(rid=i, prompt=p, adapter="serve",
                           max_new_tokens=4, arrival=0.3 * i))
    m = eng.run(max_ticks=20000)
    assert len(eng.finished) == 4
    for tr in eng.trainers.values():
        assert not tr.pending()
        assert tr.optimizer_steps >= 1
        assert tr.tokens_trained > 0
    assert m.finetune_tokens > 0 and m.decode_tokens > 0


def test_trainer_interruptibility():
    """A trainer given zero budget for arbitrarily many ticks resumes exactly
    where it stopped (cursor/accumulation preserved)."""
    rows = datasets.alpaca_like(8, vocab=64, seed=0)
    tr = MixedLoraTrainer("t", 0, rows, [],
                          TrainerConfig(rows_per_micro=2, accum_steps=2,
                                        epochs=1, eval_each_epoch=False))
    got = tr.next_rows(2)
    assert len(got) == 2 and tr.cursor == 2
    for _ in range(50):
        assert tr.next_rows(0) == []                 # interrupted
    assert tr.cursor == 2
    tr.record(got, [1.0, 1.0], [10, 10])
    got2 = tr.next_rows(2)
    np.testing.assert_array_equal(got2[0].tokens, rows[2][0])
