"""Paged KV-cache memory layer: block allocator lifecycle, block-budget
admission, prefix sharing + copy-on-write, paged-vs-dense numerical
equivalence (model forward, Pallas kernel, and full engine), and the
adapter-pool eviction that completes the unified-paging picture."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.core.lora import LoRAConfig
from repro.core.virtualization import AdapterStore, MixedLoraModel
from repro.models.model import init_cache, init_paged_cache, unified_forward
from repro.models.schema import init_params
from repro.models.stream import DECBatch, PFBatch, UnifiedBatch
from repro.serving.engine import EngineConfig, UnifiedEngine
from repro.serving.kvcache import (BlockAllocator, KVAccountingError,
                                   PagedCacheManager)
from repro.serving.request import Request, State

LCFG = LoRAConfig(n_slots=4, r=4)


# ------------------------------------------------------------- allocator
def test_block_allocator_lifecycle():
    a = BlockAllocator(8)                       # 7 usable (block 0 reserved)
    assert a.usable == 7 and a.n_free == 7
    bids = a.alloc_many(7)
    assert sorted(bids) == list(range(1, 8))
    assert a.alloc() is None and a.alloc_many(1) is None
    a.incref(bids[0])
    a.decref(bids[0])
    assert a.n_free == 0                        # still held once
    a.decref(bids[0])
    assert a.n_free == 1                        # now returned to the pool
    got = a.alloc()
    assert got == bids[0] and a.ref[got] == 1
    assert a.peak_used == 7


def test_block_allocator_null_block_reserved():
    a = BlockAllocator(4)
    assert 0 not in a.alloc_many(3)
    # a real exception, not an assert: the invariant must survive python -O
    with pytest.raises(KVAccountingError):
        a.decref(0)
    with pytest.raises(KVAccountingError):
        a.incref(0)


# ------------------------------------------------------------- manager
def _mgr(capacity=4, n_blocks=0, s_max=64, bs=16):
    cfg = get_reduced("llama3-8b")
    return PagedCacheManager(cfg, capacity, 2, s_max, block_size=bs,
                             n_blocks=n_blocks)


def test_admission_refused_when_out_of_blocks():
    # 5 usable blocks of 16 tokens; each request projects 2 blocks
    m = _mgr(capacity=4, n_blocks=6)
    prompt = np.zeros((20,), np.int32)
    a1 = m.try_admit(prompt, max_new=8)
    a2 = m.try_admit(prompt, max_new=8)
    assert a1 is not None and a2 is not None
    s1, reused = a1
    assert reused == 0                    # no registered prefix to reuse
    assert m.free_blocks == 1
    assert m.try_admit(prompt, max_new=8) is None     # needs 2, only 1 free
    m.free(s1)
    assert m.free_blocks == 3
    assert m.try_admit(prompt, max_new=8) is not None  # blocks recycled


def test_admission_refused_when_out_of_state_slots():
    m = _mgr(capacity=1, n_blocks=32)
    assert m.try_admit(np.zeros((4,), np.int32), 4) is not None
    assert m.try_admit(np.zeros((4,), np.int32), 4) is None


def _commit_full(m, slot):
    """Engine commit analog: land the slot's whole prompt, publishing its
    full blocks into the content-hash index."""
    m.commit_prefill([(0, slot)], [m._seq_len[slot]])


def test_hash_sharing_and_copy_on_write():
    m = _mgr(capacity=4, n_blocks=16, bs=8)
    prompt = np.arange(20, dtype=np.int32)            # 2 full blocks + tail
    s1, r1 = m.try_admit(prompt, max_new=8, adapter="a")
    assert r1 == 0                                    # nothing resident yet
    _commit_full(m, s1)                               # publishes 2 blocks
    assert m.hash_blocks_resident == 2
    used_before = m.allocator.n_used
    s2, reused = m.try_admit(prompt, max_new=8, adapter="a")
    # the two full prefix blocks are adopted, only the tail + growth fresh
    assert reused == 16                               # 2 blocks of 8 reused
    assert m.tables[s2][:2] == m.tables[s1][:2]
    assert m.allocator.n_used == used_before + (len(m.tables[s2]) - 2)
    assert m.hash_hits == 2
    shared_bid = m.tables[s2][0]
    assert m.allocator.is_shared(shared_bid)
    # a different adapter must NOT adopt (K/V depend on the LoRA — the
    # adapter is baked into the block key)
    s3, r3 = m.try_admit(prompt, max_new=8, adapter="b")
    assert r3 == 0
    assert m.tables[s3][0] != m.tables[s1][0]
    # copy-on-write: force a write into the shared block
    new_bid = m.ensure_writable(s2, pos=0)
    assert new_bid != shared_bid and m.tables[s2][0] == new_bid
    assert not m.allocator.is_shared(new_bid)
    assert m.tables[s1][0] == shared_bid              # owner untouched
    # freeing all requests keeps index blocks alive (pure cache, ref == 1);
    # pool pressure sheds them on demand
    m.free(s1), m.free(s2), m.free(s3)
    assert m.allocator.ref[shared_bid] >= 1           # index's refcount
    assert m.pristine
    while m.try_admit(np.zeros((64,), np.int32), 0) is not None:
        pass                                          # drain the pool
    assert m.probe(prompt, adapter="a") == 0          # entries were shed


def test_cow_copies_block_payload():
    m = _mgr(capacity=2, n_blocks=8, bs=16)
    prompt = np.arange(20, dtype=np.int32)            # 1 full block + tail
    s1, _ = m.try_admit(prompt, 8)
    _commit_full(m, s1)
    bid = m.tables[s1][0]
    # write a recognizable payload into the shared block of one pool leaf
    leaf = m.cache["layers"][0]["k"]
    m.cache["layers"][0]["k"] = leaf.at[:, bid].set(7.0)
    s2, reused = m.try_admit(prompt, 8)
    assert reused == 16
    new_bid = m.ensure_writable(s2, pos=0)
    assert new_bid != bid
    got = np.asarray(m.cache["layers"][0]["k"][:, new_bid])
    np.testing.assert_array_equal(got, np.full_like(got, 7.0))


# --------------------------------------------------- forward equivalence
@pytest.mark.parametrize("arch", ["llama3-8b", "deepseek-v2-236b",
                                  "jamba-1.5-large-398b"])
def test_paged_forward_matches_dense(arch):
    """Prefill + multi-step decode through scattered, non-contiguous blocks
    must produce the same logits as the dense row cache (attention, MLA, and
    hybrid mamba+attention state rows)."""
    cfg = get_reduced(arch)
    params = init_params(cfg, jax.random.PRNGKey(0))
    B, S, extra = 2, 10, 3
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S + extra),
                              0, cfg.vocab)
    base = jnp.full((B,), -1)

    def drive(cache, tables):
        pf = PFBatch(tokens=toks[:, :S], length=jnp.full((B,), S),
                     adapter=base, block_tables=tables)
        out = unified_forward(cfg, params, UnifiedBatch(pf=pf), cache=cache)
        logits, cache = [out.pf_logits], out.cache
        for i in range(extra):
            dec = DECBatch(tokens=toks[:, S + i], pos=jnp.full((B,), S + i),
                           adapter=base, block_tables=tables)
            out = unified_forward(cfg, params, UnifiedBatch(dec=dec),
                                  cache=cache)
            cache = out.cache
            logits.append(out.dec_logits)
        return logits

    dense = drive(init_cache(cfg, B, 32), None)
    # deliberately interleaved block ids: contiguity must not matter
    tbl = jnp.asarray(np.array([[3, 1, 7, 5], [2, 6, 4, 8]], np.int32))
    paged = drive(init_paged_cache(cfg, 9, 8, B), tbl)
    for a, b in zip(dense, paged):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-4, atol=2e-4)


# ----------------------------------------------------- engine equivalence
def _engine(cfg, paged, seed=0, **kw):
    params = init_params(cfg, jax.random.PRNGKey(seed))
    store = AdapterStore(cfg, LCFG, jax.random.PRNGKey(seed + 1))
    store.load_random("serve", jax.random.PRNGKey(seed + 2))
    return UnifiedEngine(MixedLoraModel(cfg, params, store),
                         EngineConfig(capacity=4, pf_capacity=2, s_max=64,
                                      virtual_time=True, paged=paged, **kw))


def _reqs(cfg, n=6, seed=3):
    rng = np.random.default_rng(seed)
    return [Request(rid=i, prompt=rng.integers(0, cfg.vocab, rng.integers(
                        4, 20)).astype(np.int32),
                    adapter="serve", max_new_tokens=5, arrival=0.2 * i)
            for i in range(n)]


def test_engine_paged_matches_dense_outputs():
    """Greedy decoding through the paged engine must produce token-for-token
    the same outputs as the dense engine on the same request stream."""
    cfg = get_reduced("llama3-8b")
    eng_d = _engine(cfg, paged=False)
    eng_p = _engine(cfg, paged=True, block_size=16)
    for eng in (eng_d, eng_p):
        for r in _reqs(cfg):
            eng.submit(r)
        eng.run(max_ticks=5000)
        assert len(eng.finished) == 6
    out_d = {r.rid: r.output for r in eng_d.finished}
    out_p = {r.rid: r.output for r in eng_p.finished}
    assert out_d == out_p


def test_engine_hash_dedup_reduces_block_usage():
    """Identical prompt heads dedup automatically (no caller-side id): the
    dedup engine peaks at fewer live blocks than the escape-hatch engine,
    with byte-identical outputs."""
    cfg = get_reduced("llama3-8b")
    sys_prompt = np.arange(32, dtype=np.int32)

    def mk(n):
        rng = np.random.default_rng(0)
        return [Request(rid=i,
                        prompt=np.concatenate([sys_prompt, rng.integers(
                            0, cfg.vocab, 8).astype(np.int32)]),
                        adapter="serve", max_new_tokens=4,
                        arrival=0.2 * i) for i in range(n)]

    eng_shared = _engine(cfg, paged=True, block_size=16)
    for r in mk(4):
        eng_shared.submit(r)
    eng_shared.run(max_ticks=5000)
    eng_plain = _engine(cfg, paged=True, block_size=16, hash_dedup=False)
    for r in mk(4):
        eng_plain.submit(r)
    eng_plain.run(max_ticks=5000)
    assert len(eng_shared.finished) == len(eng_plain.finished) == 4
    assert eng_shared.metrics.hash_hits > 0
    assert eng_shared.metrics.reused_prefix_tokens >= 32 * 3
    assert eng_plain.metrics.hash_hits == 0
    # deduped and plain engines decode identically (same params/seed)
    assert ({r.rid: r.output for r in eng_shared.finished}
            == {r.rid: r.output for r in eng_plain.finished})


def test_index_shedding_skips_actively_held_blocks():
    """Shedding an index entry whose block an active consumer still holds
    (ref >= 2) frees nothing — the shed loop must keep such entries (the
    sharing metadata stays useful) and admission must simply refuse."""
    m = _mgr(capacity=8, n_blocks=5, bs=16)           # 4 usable blocks
    prompt = np.arange(33, dtype=np.int32)            # 2 full blocks + tail
    s1, _ = m.try_admit(prompt, max_new=0)
    _commit_full(m, s1)
    s2, reused = m.try_admit(prompt, max_new=0)       # adopts 2 blocks
    assert reused == 32
    assert m.tables[s2][:2] == m.tables[s1][:2]
    m.free(s1)                                        # consumer s2 remains
    # pool: 2 shared blocks (ref: s2 + index) + s2's tail + 1 free; a
    # 3-block request (distinct content — no adoption) must refuse WITHOUT
    # destroying the still-consumed index entries
    cold = np.full((48,), 7, np.int32)
    assert m.try_admit(cold, 0) is None
    assert m.hash_blocks_resident == 2
    m.free(s2)                                        # now only index holds
    assert m.try_admit(cold, 0) is not None
    # shed exactly what the admission needed, keep the rest cached
    assert m.hash_blocks_resident == 1


def test_hash_index_does_not_starve_admission():
    """Index-held blocks must be shed under pressure, not wedge the
    admission gate: a stream of DISTINCT prompts each leaving published
    blocks refcounted in the index must keep being admitted."""
    cfg = get_reduced("llama3-8b")
    eng = _engine(cfg, paged=True, block_size=16, n_blocks=17)  # 16 usable
    rng = np.random.default_rng(1)
    reqs = [Request(rid=i, prompt=rng.integers(0, cfg.vocab, 32)
                    .astype(np.int32), adapter="serve", max_new_tokens=4,
                    arrival=0.5 * i)
            for i in range(10)]
    for r in reqs:
        eng.submit(r)
    eng.run(max_ticks=3000)
    assert len(eng.finished) == 10
    assert all(r.state is State.DONE for r in eng.finished)
    assert eng.cachemgr.pristine                      # cache, not a leak


def test_cow_leaves_state_rows_untouched():
    """Copy-on-write is a pool-block copy: on hybrid models the dense state
    rows (SSM/conv state, indexed by request slot, not block id) must not be
    rewritten."""
    cfg = get_reduced("jamba-1.5-large-398b")
    m = PagedCacheManager(cfg, 2, 2, 64, block_size=16, n_blocks=8)
    s1, _ = m.try_admit(np.arange(20, dtype=np.int32), 8)
    # publish the full prompt block by hand (commit_prefill would row-copy
    # the painted state rows we are about to assert on)
    m.lens[s1] = 20
    m._publish_upto(s1)
    # paint every state row so any stray write is visible
    for i, d in enumerate(m.cache["layers"]):
        for k in d:
            if k in ("h", "conv_x", "conv_bc"):
                m.cache["layers"][i][k] = d[k] + 3.0
    before = {k: np.asarray(v) for k, v in enumerate(
        [d.get("h") for d in m.cache["layers"]]) if v is not None}
    s2, _ = m.try_admit(np.arange(20, dtype=np.int32), 8)
    new_bid = m.ensure_writable(s2, pos=0)
    assert new_bid != m.tables[s1][0]
    after = {k: np.asarray(v) for k, v in enumerate(
        [d.get("h") for d in m.cache["layers"]]) if v is not None}
    for k in before:
        np.testing.assert_array_equal(before[k], after[k])


# ------------------------------------------- on-demand growth + truncate
def test_blocks_allocated_on_demand_with_reservation():
    """Admission allocates only the prompt's blocks; the rest of the
    projected life is a reservation the gate must not spend, and ``grow``
    converts to real blocks as the sequence advances."""
    m = _mgr(capacity=4, n_blocks=9, bs=16)           # 8 usable
    s, _ = m.try_admit(np.zeros((20,), np.int32), max_new=24)  # 44 tok -> 3
    assert len(m.tables[s]) == 2                      # ceil(20/16) held now
    assert m.reserved[s] == 3 and m.reserved_debt == 1
    assert m.free_blocks == 8 - 3                     # debt is not spendable
    cap = m.grow(s, 33)                               # into the 3rd block
    assert cap >= 33 and len(m.tables[s]) == 3
    assert m.reserved_debt == 0 and m.free_blocks == 5
    m.free(s)
    assert m.free_blocks == 8 and m.reserved_debt == 0


def test_truncate_releases_blocks_and_restores_reservation():
    """Speculation rollback: tail blocks written by rejected drafts return
    to the pool and the reservation debt reappears (the request can still
    grow to its projected life later)."""
    m = _mgr(capacity=4, n_blocks=9, bs=16)
    s, _ = m.try_admit(np.zeros((20,), np.int32), max_new=24, headroom=8)
    assert m.reserved[s] == 4                         # 20+24+8 tok -> 4 blk
    m.grow(s, 52)                                     # draft overshoot
    assert len(m.tables[s]) == 4 and m.reserved_debt == 0
    used = m.allocator.n_used
    m.truncate(s, 22)                                 # roll back to 2 blocks
    assert m.lens[s] == 22
    assert len(m.tables[s]) == 2
    assert m.allocator.n_used == used - 2
    assert m.reserved_debt == 2                       # earmarked again
    assert m.grow(s, 52) >= 52                        # and re-growable


def test_truncate_shared_prefix_blocks_survive_rollback():
    """Rolling back through a refcounted shared-prefix block must only
    decref it: the index (and any sibling request) keeps it alive, and
    the survivor's table is untouched."""
    m = _mgr(capacity=4, n_blocks=16, bs=8)
    prompt = np.arange(17, dtype=np.int32)            # 2 full blocks + tail
    s1, _ = m.try_admit(prompt, max_new=8)
    _commit_full(m, s1)
    s2, reused = m.try_admit(prompt, max_new=8)
    assert reused == 16
    shared = list(m.tables[s2])
    assert shared[:2] == m.tables[s1][:2]
    assert m.allocator.ref[shared[0]] == 3            # s1 + s2 + index
    m.grow(s2, 24)
    m.truncate(s2, 4)                                 # roll back INTO block 0
    assert m.tables[s2] == shared[:1]
    assert m.shared_count[s2] == 1
    assert m.allocator.ref[shared[0]] == 3            # survivor untouched
    assert m.allocator.ref[shared[1]] == 2            # s2's ref released
    assert m.tables[s1][:2] == shared[:2]             # sibling intact
    # the survivor's payload is still addressable: re-admitting adopts it
    s3, _ = m.try_admit(prompt, max_new=8)
    assert m.tables[s3][:2] == shared[:2]


def test_truncate_through_shared_blocks_keeps_debt_invariant():
    """Rolling back through refcounted shared blocks must not re-credit
    debt for blocks that never returned to the pool: on a fully committed
    pool the invariant n_free >= debt (and therefore grow()'s
    within-reservation guarantee) has to survive."""
    m = _mgr(capacity=8, n_blocks=8, bs=8)            # 7 usable
    prompt = np.arange(17, dtype=np.int32)            # 2 full blocks + tail
    s1, _ = m.try_admit(prompt, max_new=7)            # 3 held
    _commit_full(m, s1)
    s2, reused = m.try_admit(prompt, max_new=7)
    assert reused == 16                               # shares 2, owns tail
    filler, _ = m.try_admit(np.arange(8, dtype=np.int32), max_new=16)
    assert filler is not None                         # 1 held + 2 debt
    assert m.free_blocks == 0                         # pool fully committed
    m.truncate(s2, 4)                                 # back through shared
    assert m.allocator.n_free >= m.reserved_debt
    assert m.free_blocks >= 0
    # every within-reservation grow must still succeed: s1 to its full
    # projected life, s2 to its (shared-drop-reduced) reservation
    assert m.grow(s1, 24) >= 24
    assert m.grow(s2, m.reserved[s2] * 8) >= m.reserved[s2] * 8
    assert m.grow(filler, 24) >= 24


def test_truncate_adopted_index_blocks_never_frees_them():
    """Speculative rollback on a request that ADOPTED index blocks
    (refcount came from the index, not a CoW fork): repeated grow/
    truncate cycles — including truncating all the way back into the
    shared span — must never drop an index-held block's refcount to
    zero, and the blocks must stay adoptable afterwards."""
    m = _mgr(capacity=4, n_blocks=16, bs=8)
    prompt = np.arange(17, dtype=np.int32)            # 2 full blocks + tail
    s1, _ = m.try_admit(prompt, max_new=8)
    _commit_full(m, s1)
    m.free(s1)                                        # only index holds
    idx_bids = [m._index[k] for k in m.chain_keys(prompt)]
    assert all(m.allocator.ref[b] == 1 for b in idx_bids)
    s2, reused = m.try_admit(prompt, max_new=8)
    assert reused == 16 and m.tables[s2][:2] == idx_bids
    # spec-decode shape: grow over draft positions, then roll back —
    # repeatedly, and finally into the shared prefix itself
    for new_len in (20, 18, 17, 4):
        m.grow(s2, 24)
        m.truncate(s2, new_len)
        assert all(m.allocator.ref[b] >= 1 for b in idx_bids), new_len
        assert m.allocator.n_free >= m.reserved_debt
    m.free(s2)
    assert all(m.allocator.ref[b] == 1 for b in idx_bids)  # index's ref
    assert m.hash_blocks_resident == 2
    s3, r3 = m.try_admit(prompt, max_new=8)
    assert r3 == 16 and m.tables[s3][:2] == idx_bids  # still adoptable


def test_engine_spec_truncate_over_adopted_prefix_matches_greedy():
    """End-to-end regression for hash adoption x speculative truncate:
    spec decoding over ADOPTED index blocks must roll back only its own
    draft blocks (never index-held blocks) and emit exactly the
    plain-greedy outputs."""
    from repro.spec import SpecConfig
    cfg = get_reduced("llama3-8b")
    sys_prompt = np.arange(32, dtype=np.int32)

    def mk(n):
        rng = np.random.default_rng(7)
        return [Request(rid=i,
                        prompt=np.concatenate([sys_prompt, rng.integers(
                            0, cfg.vocab, 5 + i).astype(np.int32)]),
                        adapter="serve", max_new_tokens=8,
                        arrival=0.3 * i) for i in range(4)]

    plain = _engine(cfg, paged=True, block_size=16)
    for r in mk(4):
        plain.submit(r)
    plain.run(max_ticks=5000)
    spec = _engine(cfg, paged=True, block_size=16,
                   spec=SpecConfig(k_max=3, drafter="ngram"))
    for r in mk(4):
        spec.submit(r)
    spec.run(max_ticks=5000)
    assert len(spec.finished) == len(plain.finished) == 4
    assert spec.metrics.hash_hits >= 2        # the shared head was adopted
    assert ({r.rid: r.output for r in spec.finished}
            == {r.rid: r.output for r in plain.finished})
    # the shared head survived every rollback: its blocks are still alive
    # under the index's refcount
    mgr = spec.cachemgr
    head_keys = mgr.chain_keys(sys_prompt, adapter="serve")
    assert len(head_keys) == 1                # 32 tokens, bs 16, 1-tok cap
    assert head_keys[0] in mgr._index
    assert mgr.allocator.ref[mgr._index[head_keys[0]]] >= 1


def test_dense_truncate_rolls_length_only():
    from repro.serving.kvcache import CacheManager
    cfg = get_reduced("llama3-8b")
    m = CacheManager(cfg, 2, 1, 64)
    slot = m.alloc()
    m.lens[slot] = 30
    m.truncate(slot, 21)
    assert m.lens[slot] == 21


# ------------------------------------------------------- adapter eviction
def test_adapter_store_lru_eviction_and_reload():
    cfg = get_reduced("llama3-8b")
    store = AdapterStore(cfg, LoRAConfig(n_slots=2, r=4),
                         jax.random.PRNGKey(0))
    store.load_random("a", jax.random.PRNGKey(1), scale=1.25)
    store.load_random("b", jax.random.PRNGKey(2))
    ref_a = jax.tree_util.tree_map(np.asarray, store.get_adapter("a"))
    store.acquire("a")                               # a is now most recent
    with pytest.raises(RuntimeError):
        store.load_random("c", jax.random.PRNGKey(3))   # strict load raises
    store.load("c", store.get_adapter("a"), evict=True)
    assert "b" in store.voided and "b" not in store.resident
    assert store.evictions == 1
    # voided adapter transparently reloads (evicting the LRU idle one)
    store.acquire("b")
    assert "b" in store.resident and store.reloads == 1
    # a round-trip through eviction preserves the adapter payload exactly
    back_a = store.acquire("a")
    got = jax.tree_util.tree_map(np.asarray, store.get_adapter("a"))
    for x, y in zip(jax.tree_util.tree_leaves(ref_a),
                    jax.tree_util.tree_leaves(got)):
        np.testing.assert_array_equal(x, y)
    assert float(store.scale[back_a]) == 1.25


def test_adapter_store_pin_and_retain_block_eviction():
    cfg = get_reduced("llama3-8b")
    store = AdapterStore(cfg, LoRAConfig(n_slots=2, r=4),
                         jax.random.PRNGKey(0))
    store.load_random("train", jax.random.PRNGKey(1))
    store.load_random("serve", jax.random.PRNGKey(2))
    store.pin("train")
    store.retain("serve")
    with pytest.raises(RuntimeError):
        store.load("x", store.get_adapter("serve"), evict=True)
    store.release("serve")
    store.load("x", store.get_adapter("serve"), evict=True)
    assert "serve" in store.voided and "train" in store.resident
