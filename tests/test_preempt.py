"""Over-admission + recompute preemption: the lending gate
(``EngineConfig.over_admit``), the growth-failure signal, engine preemption
exactness (byte-identical outputs vs the conservative gate), the
fresh_need-based unservable check, the scheduler's budget clamp and
lent-fraction fine-tuning concession, and a hypothesis property test for
block conservation under randomized admit/grow/preempt/truncate/finish
sequences."""
import jax
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.core.lora import LoRAConfig
from repro.core.virtualization import AdapterStore, MixedLoraModel
from repro.models.schema import init_params
from repro.serving.engine import EngineConfig, UnifiedEngine
from repro.serving.kvcache import (KVAccountingError, PagedCacheManager,
                                   projected_blocks)
from repro.serving.request import Request, State
from repro.serving.scheduler import Scheduler, SchedulerConfig
from _hyputil import given, hyp as _hyp, settings, st

LCFG = LoRAConfig(n_slots=4, r=4)


def _mgr(capacity=4, n_blocks=8, s_max=64, bs=16, over_admit=1.0, **kw):
    cfg = get_reduced("llama3-8b")
    return PagedCacheManager(cfg, capacity, 2, s_max, block_size=bs,
                             n_blocks=n_blocks, over_admit=over_admit, **kw)


# --------------------------------------------------------- lending gate
def test_over_admit_rejects_sub_one_factor():
    with pytest.raises(ValueError):
        _mgr(over_admit=0.5)


def test_charged_debt_is_a_slice_of_reservations():
    m = _mgr(n_blocks=16, bs=16, over_admit=2.0)          # 15 usable
    s, _ = m.try_admit(np.zeros((8,), np.int32), max_new=56)  # 4 blk life
    assert len(m.tables[s]) == 1 and m.reserved_debt == 3
    assert m.charged_debt == 2                            # ceil(3 / 2.0)
    assert m.free_blocks == 15 - 1 - 2
    assert m.lent_blocks == 0                             # nothing claimed yet


def test_over_admission_admits_what_conservative_refuses():
    prompt = np.zeros((8,), np.int32)
    cons = _mgr(n_blocks=8, bs=16)                        # 7 usable
    assert cons.try_admit(prompt, max_new=40) is not None  # 3-block life
    assert cons.try_admit(prompt, max_new=40) is not None
    assert cons.try_admit(prompt, max_new=40) is None     # debt 4, free 1
    lend = _mgr(n_blocks=8, bs=16, over_admit=2.0)
    s0, _ = lend.try_admit(prompt, max_new=40)
    s1, _ = lend.try_admit(prompt, max_new=40)
    s2, _ = lend.try_admit(prompt, max_new=40)
    assert s2 is not None                                 # lent capacity
    # growth within the first two reservations still succeeds...
    assert lend.grow(s0, 48) >= 48
    assert lend.grow(s1, 48) >= 48
    # ...but s2's earmarked blocks were lent out: growth fails SHORT (the
    # preemption signal), it does not raise
    assert lend.grow(s2, 48) < 48
    assert lend.lent_blocks > 0 and lend.lent_blocks_peak > 0
    # freeing a resident repays the loan and growth completes
    lend.free(s0)
    assert lend.grow(s2, 48) >= 48


def test_conservative_grow_violation_raises_real_exception():
    """Under the conservative gate a within-reservation grow finding an
    empty pool is an accounting bug and must raise even under python -O."""
    m = _mgr(n_blocks=8, bs=16)
    s, _ = m.try_admit(np.zeros((8,), np.int32), max_new=40)  # debt 2
    while m.allocator.alloc() is not None:                # corrupt: drain
        pass                                              # the free list
    with pytest.raises(KVAccountingError):
        m.grow(s, 48)


# ----------------------------------------------------------- scheduler
def test_scheduler_budget_clamp_never_negative():
    """An over-budget FIRST request is still admitted (unchunked prefill
    cannot split it) but must not drive the token budget negative: a
    follow-up whose suffix is fully cached (0 computed tokens) is free and
    must still admit."""
    sched = Scheduler(SchedulerConfig(max_prefill_tokens=64), capacity=8)
    rs = [Request(rid=0, prompt=np.zeros((100,), np.int32), adapter=""),
          Request(rid=1, prompt=np.zeros((60,), np.int32), adapter=""),
          Request(rid=2, prompt=np.zeros((60,), np.int32), adapter="")]
    suffix = {0: 100, 1: 0, 2: 5}
    d = sched.decide(rs, 0, 8, 4, False, free_blocks=1000, total_blocks=1000,
                     block_size=16, s_max=256,
                     suffix_fn=lambda r: suffix[r.rid])
    # rid 0 over-budget (admitted alone previously drove budget to -36 and
    # vetoed the free rid 1); rid 2 still costs tokens and must wait
    assert [r.rid for r in d.admit] == [0, 1]


def test_scheduler_lent_fraction_concedes_finetune_first():
    sched = Scheduler(SchedulerConfig(), capacity=8)
    idle = sched.decide([], 0, 8, 4, True)
    assert idle.ft_rows == SchedulerConfig().ft_rows_max
    part = sched.decide([], 0, 8, 4, True, lent_frac=0.125)
    assert 0 < part.ft_rows < idle.ft_rows                # ramping down
    full = sched.decide([], 0, 8, 4, True, lent_frac=0.3)
    assert full.ft_rows == 0 and full.load == 1.0         # yields before
    #                                                       any preemption


def test_scheduler_load_saturates_when_lending_claimed():
    """free_blocks goes negative while lent reservations are claimed; load
    and ft_rows must saturate instead of overshooting/undershooting."""
    sched = Scheduler(SchedulerConfig(), capacity=8)
    d = sched.decide([], 2, 8, 4, True, free_blocks=-3, total_blocks=16,
                     block_size=16, s_max=64)
    assert d.load == 1.0 and d.ft_rows == 0


# ------------------------------------------------------------- engine
def _engine(cfg, seed=0, **kw):
    params = init_params(cfg, jax.random.PRNGKey(seed))
    store = AdapterStore(cfg, LCFG, jax.random.PRNGKey(seed + 1))
    store.load_random("serve", jax.random.PRNGKey(seed + 2))
    kw = {"capacity": 4, "pf_capacity": 2, "s_max": 64, "virtual_time": True,
          "paged": True, "block_size": 16, **kw}
    return UnifiedEngine(MixedLoraModel(cfg, params, store),
                         EngineConfig(**kw))


def _overload_reqs(n=3, prompt_len=8, max_new=40):
    rng = np.random.default_rng(11)
    cfg = get_reduced("llama3-8b")
    return [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab, prompt_len)
                    .astype(np.int32),
                    adapter="serve", max_new_tokens=max_new,
                    arrival=0.1 * i) for i in range(n)]


def test_forced_preemption_outputs_byte_identical():
    """Three 3-block-life requests in a 7-block pool: the conservative gate
    serves two then the third; over-admission serves all three and must
    preempt mid-decode when the lent reservations come due.  Outputs must
    be byte-identical — preemption changes WHEN tokens are computed, never
    WHAT is computed — and the pool must drain leak-free."""
    cfg = get_reduced("llama3-8b")
    eng_c = _engine(cfg, n_blocks=8)
    eng_o = _engine(cfg, n_blocks=8, over_admit=2.0)
    for eng in (eng_c, eng_o):
        for r in _overload_reqs():
            eng.submit(r)
        eng.run(max_ticks=5000)
        assert len(eng.finished) == 3
        assert all(r.state is State.DONE for r in eng.finished)
        # zero leaks: after drain only the hash index holds blocks (pure
        # cache) and flushing it returns the allocator to fully free
        mgr = eng.cachemgr
        assert mgr.pristine
        mgr.flush_index()
        assert mgr.allocator.n_free == mgr.allocator.usable
        assert mgr.reserved_debt == 0 and not mgr.tables
    assert eng_c.metrics.preemptions == 0
    assert eng_o.metrics.preemptions >= 1
    assert eng_o.metrics.preempted_tokens_recomputed > 0
    assert eng_o.metrics.lent_blocks_peak > 0
    assert ({r.rid: r.output for r in eng_o.finished}
            == {r.rid: r.output for r in eng_c.finished})


def test_preemption_is_latency_not_a_reset():
    """A preempted request keeps its arrival and first-token time: the
    preemption must surface as a decode-latency gap, never as a new TTFT."""
    cfg = get_reduced("llama3-8b")
    eng = _engine(cfg, n_blocks=8, over_admit=2.0)
    for r in _overload_reqs():
        eng.submit(r)
    eng.run(max_ticks=5000)
    assert eng.metrics.preemptions >= 1
    victims = [r for r in eng.finished if r.preemptions > 0]
    assert victims
    for r in victims:
        assert r.state is State.DONE
        assert r.t_first_token is not None
        # token times span the preemption: monotone, with the re-prefill
        # gap charged as inter-token latency
        tt = np.asarray(r.token_times)
        assert len(tt) == len(r.output)
        assert (np.diff(tt) >= 0).all()
        # the rolled-in prompt absorbed the pre-preemption context; the
        # output stream is still the full requested generation
        assert len(r.output) == r.max_new_tokens


def test_unservable_check_uses_fresh_need():
    """A long prompt whose RAW projection exceeds the pool must not be
    insta-FAILED while a resident sibling shares its registered prefix
    (fresh_need subtracts ref>=2 shared blocks); it fails only once the
    sharing evaporates and the need is definitively unmeetable."""
    cfg = get_reduced("llama3-8b")
    eng = _engine(cfg, n_blocks=8, s_max=176)             # 7 usable blocks
    sys_prompt = np.arange(64, dtype=np.int32)            # 4 full blocks
    rng = np.random.default_rng(5)
    a = Request(rid=0,
                prompt=np.concatenate([sys_prompt, rng.integers(
                    0, cfg.vocab, 4).astype(np.int32)]),
                adapter="serve", max_new_tokens=24,
                arrival=0.0)
    # raw projection: ceil((72 + 104) / 16) = 11 > 7 usable -> the old gate
    # FAILED this instantly; with 4 hash-published blocks shared at
    # ref >= 2 the fresh need is 7 <= 7 and it must stay queued.  b arrives
    # after a's prefill has published the head (well before a finishes).
    b = Request(rid=1,
                prompt=np.concatenate([sys_prompt, rng.integers(
                    0, cfg.vocab, 8).astype(np.int32)]),
                adapter="serve", max_new_tokens=104,
                arrival=0.2)
    assert projected_blocks(b.prompt_len, b.max_new_tokens, 16, 176) == 11
    eng.submit(a)
    eng.submit(b)
    for _ in range(12):                                   # a registers "sys"
        eng.tick()                                        # and decodes; b
        assert b.state is not State.FAILED                # arrives and waits
    assert a.state is State.DECODE and b.state is State.WAITING
    eng.run(max_ticks=5000)
    assert a.state is State.DONE
    # once a finished, the prefix dropped to registry-only (ref == 1), the
    # discount vanished and b's 11-block need is definitively unservable
    assert b.state is State.FAILED


def test_preempted_request_readmits_with_remaining_budget():
    """After preemption the emitted tokens live in the prompt; admission
    must project prompt + REMAINING tokens, or a resumed request near its
    context limit would wrongly appear unservable."""
    r = Request(rid=0, prompt=np.zeros((8,), np.int32), adapter="",
                max_new_tokens=40)
    r.output = list(range(24))
    r.prompt = np.concatenate([r.prompt, np.asarray(r.output, np.int32)])
    assert r.remaining_new == 16
    # raw re-projection (32 + 40 tokens) would claim 5 blocks; the true
    # remaining life (32 + 16) needs only 3
    assert projected_blocks(r.prompt_len, r.max_new_tokens, 16, 96) == 5
    assert projected_blocks(r.prompt_len, r.remaining_new, 16, 96) == 3


def test_double_preemption_never_duplicates_rolled_tokens():
    """A request preempted TWICE must roll only the not-yet-rolled output
    tail into its prompt each time — re-concatenating the whole output
    would duplicate tokens inside the prompt, corrupting the re-prefill
    and breaking byte-exactness."""
    cfg = get_reduced("llama3-8b")
    clean = _engine(cfg, n_blocks=40)
    victim_src = _overload_reqs(n=2, max_new=24)
    for r in victim_src:
        clean.submit(r)
    clean.run(max_ticks=5000)
    expect = {r.rid: r.output for r in clean.finished}

    eng = _engine(cfg, n_blocks=40)
    reqs = _overload_reqs(n=2, max_new=24)
    for r in reqs:
        eng.submit(r)
    victim = reqs[1]
    orig_len = victim.prompt_len
    hits = 0
    for _ in range(2000):
        eng.tick()
        if (hits < 2 and victim.state is State.DECODE
                and len(victim.output) >= 2 + hits * 3):
            eng._preempt(victim.dec_slot)
            hits += 1
            # prompt grew by exactly the newly-rolled tail, no duplicates
            assert victim.prompt_len == orig_len + victim.rolled
            assert victim.rolled == len(victim.output)
        if all(r.done for r in reqs):
            break
    assert hits == 2 and victim.preemptions == 2
    assert {r.rid: r.output for r in eng.finished} == expect
    assert eng.metrics.preempted_tokens_recomputed > 0


def test_suffix_drafter_survives_preemption():
    """Trace-replay speculation across a preemption: the drafter context is
    prompt + output[rolled:], so the reference-stream position index stays
    aligned after the emitted tokens move into the prompt — acceptance must
    stay high on resume, and outputs stay exact."""
    from repro.spec import SpecConfig
    cfg = get_reduced("llama3-8b")
    clean = _engine(cfg, n_blocks=40)
    src = _overload_reqs(n=1, max_new=24)
    for r in src:
        clean.submit(r)
    clean.run(max_ticks=5000)
    expect = {r.rid: r.output for r in clean.finished}

    eng = _engine(cfg, n_blocks=40,
                  spec=SpecConfig(k_max=3, drafter="suffix"))
    reqs = _overload_reqs(n=1, max_new=24)
    reqs[0].draft_suffix = np.concatenate(
        [reqs[0].prompt, np.asarray(expect[0], np.int64)])
    eng.submit(reqs[0])
    preempted = False
    for _ in range(2000):
        eng.tick()
        if (not preempted and reqs[0].state is State.DECODE
                and len(reqs[0].output) >= 4):
            eng._preempt(reqs[0].dec_slot)
            preempted = True
        if reqs[0].done:
            break
    assert preempted
    assert {r.rid: r.output for r in eng.finished} == expect
    # a drifted position index would reject every post-resume draft
    assert eng.metrics.spec_drafted > 0
    assert eng.metrics.acceptance_rate > 0.9


def test_cow_under_lending_spares_index_blocks():
    """With over-admission, free_blocks sits <= 0 while the free list is
    non-empty; a copy-on-write fork must spend a truly free block WITHOUT
    shedding index-resident blocks (they are what makes preemption cheap)."""
    m = _mgr(capacity=6, n_blocks=12, bs=8, s_max=96, over_admit=2.0)
    prompt = np.arange(17, dtype=np.int32)                # 2 full blocks+tail
    s1, _ = m.try_admit(prompt, max_new=0)
    m.commit_prefill([(0, s1)], [len(prompt)])            # publishes 2
    s2, reused = m.try_admit(prompt, max_new=0)
    assert reused == 16
    short = np.zeros((8,), np.int32)
    s3, _ = m.try_admit(short, max_new=24)                # 1 held + 3 debt
    s4, _ = m.try_admit(short, max_new=24)
    assert s3 is not None and s4 is not None
    assert m.grow(s3, 32) >= 32                           # claim lent blocks
    assert m.free_blocks <= 0 < m.allocator.n_free        # lending active
    assert m.hash_blocks_resident == 2
    new_bid = m.ensure_writable(s2, pos=0)                # CoW the shared blk
    assert new_bid != m.tables[s1][0]
    assert m.hash_blocks_resident == 2, \
        "CoW shed index blocks it did not need to"


def test_grow_sheds_idle_index_blocks_before_failing():
    """A pool-dry grow must shed idle index blocks (ref == 1) before
    signaling growth failure: dropping a cache entry is free, preempting a
    resident recomputes a whole context."""
    m = _mgr(capacity=6, n_blocks=8, bs=16, over_admit=2.0)   # 7 usable
    prompt = np.arange(17, dtype=np.int32)
    s1, _ = m.try_admit(prompt, max_new=0)
    m.commit_prefill([(0, s1)], [len(prompt)])                # 1 full block
    m.free(s1)                                                # idle: ref 1
    s2, _ = m.try_admit(np.zeros((8,), np.int32), max_new=56)  # 4-block life
    while m.allocator.alloc() is not None:                    # pool dry,
        pass                                                  # index idle
    assert m.hash_blocks_resident == 1
    # s2's within-reservation grow finds the free list empty; the idle
    # index block must be shed and fuel the growth — one block's worth, no
    # failure signal for it, no engine preemption
    assert m.grow(s2, 64) == 32
    assert m.hash_blocks_resident == 0


def test_preempted_request_readopts_its_own_published_blocks():
    """A preemption victim's published full blocks (prompt AND generated
    content — content-addressed, so rolled-in output is perfectly valid
    cache) survive in the index at ref == 1; its re-admission walks the
    rolled prompt's key chain and adopts them back, so the re-prefill is
    suffix-only and outputs stay byte-identical."""
    cfg = get_reduced("llama3-8b")
    clean = _engine(cfg, n_blocks=40)
    src = _overload_reqs(n=1, prompt_len=20, max_new=24)
    for r in src:
        clean.submit(r)
    clean.run(max_ticks=5000)
    expect = {r.rid: r.output for r in clean.finished}

    eng = _engine(cfg, n_blocks=40)
    reqs = _overload_reqs(n=1, prompt_len=20, max_new=24)
    eng.submit(reqs[0])
    hits_before = 0
    preempted = False
    for _ in range(2000):
        eng.tick()
        # preempt once the victim has committed enough full blocks (bs 16)
        # for its rolled prompt to have an adoptable head
        if (not preempted and reqs[0].state is State.DECODE
                and len(reqs[0].output) >= 16):
            hits_before = eng.cachemgr.hash_hits
            eng._preempt(reqs[0].dec_slot)
            preempted = True
        if reqs[0].done:
            break
    assert preempted
    # re-admission adopted index-resident blocks instead of recomputing
    assert eng.cachemgr.hash_hits > hits_before
    assert eng.metrics.reused_prefix_tokens >= 16
    assert {r.rid: r.output for r in eng.finished} == expect


# ------------------------------------------- block-conservation property
def _check_conservation(m: PagedCacheManager, over_admit: float):
    a = m.allocator
    held: dict = {}
    for t in m.tables.values():
        for b in t:
            held[b] = held.get(b, 0) + 1
    for b in m._hashed:                    # the index holds one ref per entry
        held[b] = held.get(b, 0) + 1
    for t in m.adapter_tables.values():    # adapter payloads: one ref per
        for b in t:                        # table entry, same pool
            held[b] = held.get(b, 0) + 1
    free = set(a._free)
    assert len(free) == len(a._free), "free list holds duplicates"
    for bid in range(1, a.n_blocks):
        assert int(a.ref[bid]) == held.get(bid, 0), \
            f"refcount drift on block {bid}"
        assert (int(a.ref[bid]) == 0) == (bid in free), \
            f"free-list drift on block {bid}"
    assert a.n_used == sum(1 for bid in range(1, a.n_blocks)
                           if held.get(bid, 0) > 0)
    # index integrity: key <-> block is a bijection, no entry names a free
    # or dead block (de-publish left nothing stale behind)
    assert len(m._index) == len(m._hashed)
    for key, bid in m._index.items():
        assert m._hashed.get(bid) == key, "index/inverse drift"
        assert int(a.ref[bid]) >= 1 and bid not in free, \
            f"stale index entry for block {bid}"
    for slot, chain in m._chains.items():
        assert len(chain) <= len(m.tables[slot]), "chain outran its table"
    assert m.reserved_debt == sum(m._debt_of(s) for s in m.tables)
    assert m.reserved_debt >= 0
    if over_admit <= 1.0:
        assert a.n_free >= m.reserved_debt, "conservative invariant broken"
    assert len(m.tables) + len(m._free_slots) == m.capacity, "slot leak"
    # per-class reservation debt mirrors the total exactly (the lending
    # order reshapes charged_debt but never invents or loses debt)
    class_debt = getattr(m, "_class_debt", None)
    if class_debt is not None:
        assert sum(class_debt) == m.reserved_debt, "class-debt drift"
        assert all(d >= 0 for d in class_debt)
        assert 0 <= m.charged_debt <= m.reserved_debt
    # tiered host pool (guarded: test_fleet reuses this checker on
    # managers without a host tier)
    hp = getattr(m, "host_pool", None)
    if hp is not None:
        booked = (sum(e["bytes"] for e in hp._swap_sets.values())
                  + sum(e["bytes"] for e in hp._demoted.values()))
        assert hp.used_bytes == booked, "host byte-accounting drift"
        assert hp.used_bytes <= hp.capacity_bytes, "host budget overrun"
        assert hp.peak_used_bytes >= hp.used_bytes
        # two-tier disjointness: a content key is device-resident XOR
        # host-demoted, never both
        both = set(m._index) & hp.demoted_keys()
        assert not both, f"keys resident in both tiers: {both}"


@_hyp(lambda: [settings(max_examples=20, deadline=None),
              given(ops=st.lists(st.tuples(st.integers(0, 11),
                                           st.integers(0, 7),
                                           st.integers(0, 80)),
                                 min_size=1, max_size=60),
                    over_admit=st.sampled_from([1.0, 1.75]),
                    host_blocks=st.sampled_from([0, 6]))])
def test_block_conservation_property(ops, over_admit, host_blocks):
    """Randomized admit(+adopt)/commit(publish)/grow/truncate/finish
    sequences over the content-hash index — PLUS adapter-block-class ops
    (admit / pin / unpin / shed) over the same pool: refcounts must equal
    table + index + adapter-table holds exactly, the free list must mirror
    ref==0, the index must stay a stale-free bijection, debt must track
    per-slot reservations (never spendable), no state slot may leak, a
    pinned adapter must never be shed (by explicit shed OR by KV-pressure
    shedding inside try_admit/grow), surviving adapter payloads must
    gather back byte-identical, and a full drain + flush must return the
    pool to pristine.  Prompts draw from a 3-symbol alphabet so hash
    chains collide often and adoption / publish-collision paths are
    actually exercised.

    With a host tier in the mix (``host_blocks > 0``) the op set extends
    to swap-out/restore/drop of preemption victims and demote/rehydrate
    (which also ride shed and admit implicitly): host byte accounting
    must track entries exactly, a content key must never be resident in
    both tiers, and the drain must retire every outstanding swap set
    before the pool can be pristine."""
    m = _mgr(capacity=6, n_blocks=13, s_max=96, bs=8, over_admit=over_admit,
             host_blocks=host_blocks)
    live: list = []
    payloads: dict = {}                    # name -> bytes we admitted
    pins: dict = {}                        # name -> our pin count
    sids: list = []                        # outstanding swap-set ids
    rng = np.random.default_rng(0)

    def _adapters_ok():
        for name in m.adapter_tables:
            got = m.adapter_gather(name)
            assert np.array_equal(got, payloads[name]), \
                f"adapter {name} payload corrupted"

    for kind, pick, amount in ops:
        pinned_resident = {n for n, c in pins.items()
                           if c > 0 and n in m.adapter_tables}
        if kind == 0:                            # admit (+ adopt/rehydrate)
            prompt = rng.integers(0, 3, 1 + amount % 40).astype(np.int32)
            got = m.try_admit(prompt, max_new=amount % 48,
                              priority=("interactive", "standard",
                                        "batch")[amount % 3])
            if got is not None:
                live.append(got[0])
        elif kind == 1 and live:                          # decode advance
            slot = live[pick % len(live)]
            cap = m.grow(slot, int(m.lens[slot]) + 1 + amount % 24)
            assert cap <= m.s_max
            n = min(cap, int(m.lens[slot]) + 1 + amount % 24) \
                - m._seq_len[slot]
            if n > 0:                                     # commit + publish
                m.commit_tokens(slot, rng.integers(0, 3, n))
        elif kind == 2 and live:                          # truncate (spec)
            slot = live[pick % len(live)]
            m.truncate(slot, max(int(m.lens[slot]) - amount % 16, 0))
        elif kind == 3 and live:                          # preempt / finish
            m.free(live.pop(pick % len(live)))
        elif kind == 4 and live:                          # commit the prompt
            slot = live[pick % len(live)]
            n = min(m._seq_len[slot], len(m.tables[slot]) * m.block_size)
            m.commit_prefill([(0, slot)], [n])
        elif kind == 5 and live:                          # grow to capacity
            slot = live[pick % len(live)]
            m.grow(slot, m.reserved.get(slot, 1) * m.block_size)
        elif kind == 6:                                   # adapter admit
            name = f"A{pick % 4}"
            if name not in m.adapter_tables:
                # variable footprints: 1..3 blocks at this pool's geometry
                nb = 1 + (amount * 211) % (3 * m.adapter_block_bytes - 1)
                pay = rng.integers(0, 256, nb).astype(np.uint8)
                if m.adapter_admit(name, pay):
                    payloads[name] = pay
        elif kind == 7:                                   # pin (pre-residency
            name = f"A{pick % 4}"                         # pinning is legal)
            m.adapter_pin(name)
            pins[name] = pins.get(name, 0) + 1
        elif kind == 8 and pins.get(f"A{pick % 4}", 0):   # unpin
            name = f"A{pick % 4}"
            m.adapter_unpin(name)
            pins[name] -= 1
        elif kind == 9:                                   # explicit pressure
            m._shed_any()                  # (demotes into the host tier
            #                                when one is attached)
        elif kind == 10 and live:                         # swap-out preempt
            slot = live.pop(pick % len(live))
            sid = m.swap_out(slot)         # engine order: gather THEN free
            m.free(slot)
            if sid is not None:
                sids.append(sid)
        elif kind == 11 and sids:                         # restore or drop
            sid = sids.pop(pick % len(sids))
            # the engine contract: restore lands in a FRESH admission
            # (before any commit), never an arbitrary mid-life slot
            got = (m.try_admit(rng.integers(0, 3, 1 + amount % 40)
                               .astype(np.int32), max_new=amount % 48)
                   if amount % 2 else None)
            if got is not None:
                live.append(got[0])
                m.restore_swap(got[0], sid)
            else:
                m.drop_swap(sid)
        assert pinned_resident <= set(m.adapter_tables), \
            "a pinned adapter was shed"
        _adapters_ok()
        _check_conservation(m, over_admit)
    for slot in live:                                     # drain
        m.free(slot)
    for sid in sids:                       # retire outstanding swap sets
        m.drop_swap(sid)                   # (a failed victim's _drop_swap)
    _check_conservation(m, over_admit)
    for name, c in list(pins.items()):     # drop our pins: leftovers are
        for _ in range(c):                 # then pure cache...
            m.adapter_unpin(name)
    assert m.pristine
    m.flush_adapters()
    m.flush_index()                        # ...and flushing reclaims all
    m.flush_host()
    assert m.allocator.n_free == m.allocator.usable
    assert m.reserved_debt == 0
    assert not m._index and not m._hashed
    assert not m.adapter_tables and not m._adapter_pins
    if m.host_pool is not None:
        assert m.host_pool.used_bytes == 0
        assert m.host_pool.n_swap_sets == 0 and m.host_pool.n_demoted == 0
