"""Flash-decoding split-K paged attention: kernels vs oracles, LSE-merge
algebra (property-based), the autotune table/heuristic, kernel-mode env
validation, model-level dispatch, and engine-level byte-exactness."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import autotune
from repro.kernels.autotune import AttnConfig
from repro.kernels.decode_attn import (paged_decode_attention,
                                       paged_verify_attention)
from repro.kernels.ref import paged_decode_ref, paged_verify_ref
from repro.kernels.splitk import (lse_merge, paged_decode_attention_splitk,
                                  paged_verify_attention_splitk)
from _hyputil import given, hyp as _hyp, settings, st

NEG = -1e30


def _paged_setup(B, g, hd, bs, nbt, n_blocks, pos, seed=0, Sq=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    k_pool = jax.random.normal(ks[0], (n_blocks, bs, g, hd))
    v_pool = jax.random.normal(ks[1], (n_blocks, bs, g, hd))
    rng = np.random.default_rng(seed)
    tables = np.zeros((B, nbt), np.int32)
    for b in range(B):
        need = (pos[b] + max(Sq, 1)) // bs + 1
        tables[b, :need] = rng.choice(np.arange(1, n_blocks), size=need,
                                      replace=False)
    return k_pool, v_pool, jnp.asarray(tables), ks[2]


# ------------------------------------------------------------------ kernels
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("ns", [1, 2, 4, 7])
def test_splitk_decode_matches_ref(dtype, ns):
    """Split-K decode == gather-then-attend oracle for every fan-out,
    including a non-divisor split count, over scattered tables."""
    B, h, g, hd, bs, nbt = 3, 8, 2, 16, 8, 5
    pos = np.minimum(np.arange(B) * 13 + 3, nbt * bs - 1)
    k_pool, v_pool, tables, kq = _paged_setup(B, g, hd, bs, nbt,
                                              nbt * B + 2, pos)
    q = jax.random.normal(kq, (B, h, hd)).astype(dtype)
    k_pool, v_pool = k_pool.astype(dtype), v_pool.astype(dtype)
    posj = jnp.asarray(pos, jnp.int32)
    y = paged_decode_attention_splitk(q, k_pool, v_pool, tables, posj,
                                      num_splits=ns, interpret=True)
    yr = paged_decode_ref(q, k_pool, v_pool, tables, posj)
    tol = 2e-5 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(yr, np.float32), rtol=tol, atol=tol)


@pytest.mark.parametrize("ns", [1, 2, 4, 7])
def test_splitk_verify_matches_ref(ns):
    """Split-K verify (multi-token chunk, ragged lens incl. a padding row)
    == oracle for every fan-out."""
    B, h, g, hd, bs, nbt, Sq = 3, 8, 2, 16, 8, 5, 4
    rng = np.random.default_rng(ns)
    pos = np.minimum(np.arange(B) * 5 + 2, nbt * bs - Sq - 1)
    lens = np.array([Sq, 2, 0])          # full, partial, padding row
    k_pool, v_pool, tables, kq = _paged_setup(B, g, hd, bs, nbt, nbt * B + 2,
                                              pos, seed=ns, Sq=Sq)
    q = jax.random.normal(kq, (B, Sq, h, hd))
    posj, lensj = jnp.asarray(pos, jnp.int32), jnp.asarray(lens, jnp.int32)
    y = paged_verify_attention_splitk(q, k_pool, v_pool, tables, posj, lensj,
                                      num_splits=ns, interpret=True)
    yr = paged_verify_ref(q, k_pool, v_pool, tables, posj, lensj)
    valid = lens[:, None] > np.arange(Sq)[None, :]     # padding rows/slots
    np.testing.assert_allclose(np.asarray(y)[valid], np.asarray(yr)[valid],
                               rtol=3e-5, atol=3e-5)


def test_splitk_matches_sequential_kernel():
    """Split-K and the sequential walk are the SAME attention — compare the
    two Pallas kernels directly (not just both-vs-oracle)."""
    B, h, g, hd, bs, nbt = 2, 4, 2, 16, 8, 4
    pos = np.array([13, 30])
    k_pool, v_pool, tables, kq = _paged_setup(B, g, hd, bs, nbt, 16, pos)
    q = jax.random.normal(kq, (B, h, hd))
    posj = jnp.asarray(pos, jnp.int32)
    y_seq = paged_decode_attention(q, k_pool, v_pool, tables, posj,
                                   interpret=True)
    y_spl = paged_decode_attention_splitk(q, k_pool, v_pool, tables, posj,
                                          num_splits=4, interpret=True)
    np.testing.assert_allclose(np.asarray(y_spl), np.asarray(y_seq),
                               rtol=2e-5, atol=2e-5)
    lens = jnp.asarray([3, 1], jnp.int32)
    qv = jax.random.normal(kq, (B, 4, h, hd))
    yv_seq = paged_verify_attention(qv, k_pool, v_pool, tables, posj, lens,
                                    interpret=True)
    yv_spl = paged_verify_attention_splitk(qv, k_pool, v_pool, tables, posj,
                                           lens, num_splits=3, interpret=True)
    valid = np.asarray(lens)[:, None] > np.arange(4)[None, :]
    np.testing.assert_allclose(np.asarray(yv_spl)[valid],
                               np.asarray(yv_seq)[valid],
                               rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------- lse_merge
def _single_pass(scores, values):
    """Plain softmax-weighted sum — the oracle lse_merge must reproduce."""
    m = scores.max()
    w = np.exp(scores - m)
    return (w[:, None] * values).sum(0) / w.sum()


def _partials(scores, values, cuts):
    """Build per-split (o, m, l) exactly as the kernel's online softmax
    emits them: un-normalized, split-local maxima, -inf/0 when empty."""
    o, ms, ls = [], [], []
    for lo, hi in cuts:
        s, v = scores[lo:hi], values[lo:hi]
        if len(s) == 0:
            o.append(np.zeros(values.shape[1])); ms.append(NEG); ls.append(0.)
            continue
        m = s.max()
        w = np.exp(s - m)
        o.append((w[:, None] * v).sum(0)); ms.append(m); ls.append(w.sum())
    return np.stack(o), np.array(ms), np.array(ls)


def _merge_np(o, m, l):
    """lse_merge on a single (ns,)-indexed problem via the jnp kernel."""
    out = lse_merge(jnp.asarray(o, jnp.float32)[None, :, None, None],
                    jnp.asarray(m, jnp.float32)[None, :, None, None],
                    jnp.asarray(l, jnp.float32)[None, :, None, None])
    return np.asarray(out)[0, 0, 0]


def test_lse_merge_all_empty_degenerates_to_zero():
    """ALL-masked splits (m = -inf, l = 0 everywhere) must merge to exactly
    zero — matching the sequential kernels' all-masked finalize — without
    NaNs from the 0/0."""
    o = np.zeros((3, 8))
    out = _merge_np(o, np.full(3, NEG), np.zeros(3))
    assert np.all(out == 0.0) and not np.any(np.isnan(out))


def test_lse_merge_empty_split_is_inert():
    """An empty split among non-empty ones must not perturb the result."""
    rng = np.random.default_rng(0)
    s, v = rng.standard_normal(12), rng.standard_normal((12, 8))
    o, m, l = _partials(s, v, [(0, 7), (7, 7), (7, 12)])  # middle split empty
    np.testing.assert_allclose(_merge_np(o, m, l), _single_pass(s, v),
                               rtol=1e-6, atol=1e-6)


@_hyp(lambda: [settings(max_examples=40, deadline=None),
               given(n=st.integers(1, 48), ns=st.sampled_from([1, 2, 4, 7]),
                     seed=st.integers(0, 2**16), shift=st.floats(-50, 50))])
def test_lse_merge_equals_single_pass(n, ns, seed, shift):
    """PROPERTY: merge-of-partials == single-pass softmax for any ragged
    split of any score sequence, including large uniform shifts (the case
    naive exp() overflows on and the m-subtraction must absorb)."""
    rng = np.random.default_rng(seed)
    s = rng.standard_normal(n) * 3 + shift
    v = rng.standard_normal((n, 4))
    edges = np.sort(rng.integers(0, n + 1, ns - 1)) if ns > 1 else np.array([], int)
    bounds = [0, *edges.tolist(), n]
    cuts = list(zip(bounds[:-1], bounds[1:]))          # may include empties
    o, m, l = _partials(s, v, cuts)
    np.testing.assert_allclose(_merge_np(o, m, l), _single_pass(s, v),
                               rtol=1e-5, atol=1e-5)


@_hyp(lambda: [settings(max_examples=12, deadline=None),
               given(B=st.integers(1, 3), g=st.sampled_from([1, 2, 4]),
                     ns=st.sampled_from([1, 2, 4, 7]),
                     seed=st.integers(0, 2**16), bf16=st.booleans())])
def test_splitk_kernel_property(B, g, ns, seed, bf16):
    """PROPERTY: the split-K kernel == oracle across batch sizes, GQA group
    sizes, fan-outs, pool dtypes, and random ragged positions."""
    h, hd, bs, nbt = 4, 8, 8, 5
    rng = np.random.default_rng(seed)
    pos = rng.integers(0, nbt * bs, B)
    k_pool, v_pool, tables, kq = _paged_setup(B, g, hd, bs, nbt,
                                              nbt * B + 2, pos, seed=seed)
    dtype = jnp.bfloat16 if bf16 else jnp.float32
    q = jax.random.normal(kq, (B, h, hd)).astype(dtype)
    k_pool, v_pool = k_pool.astype(dtype), v_pool.astype(dtype)
    posj = jnp.asarray(pos, jnp.int32)
    y = paged_decode_attention_splitk(q, k_pool, v_pool, tables, posj,
                                      num_splits=ns, interpret=True)
    yr = paged_decode_ref(q, k_pool, v_pool, tables, posj)
    tol = 2e-5 if not bf16 else 5e-2
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(yr, np.float32), rtol=tol, atol=tol)


# ----------------------------------------------------------------- autotune
def test_heuristic_splits_small_batch_only():
    """Long context + small batch -> split; bh >= lanes -> sequential; a
    short table is never sharded below MIN_BLOCKS_PER_SPLIT blocks."""
    assert autotune.heuristic(64, 16, 32, 4).num_splits > 1
    assert autotune.heuristic(64, 16, 32, 64).num_splits == 1
    assert autotune.heuristic(64, 16, 4, 1).num_splits == 1
    for nbt in (4, 32):
        for ns in autotune.candidate_splits(nbt):
            assert ns == 1 or -(-nbt // ns) >= autotune.MIN_BLOCKS_PER_SPLIT


def test_modeled_time_monotone_in_waves():
    """The occupancy model must reward splitting exactly while extra splits
    still fill idle lanes, then punish past saturation."""
    t1 = autotune.modeled_grid_time(4, 32, 1)
    t4 = autotune.modeled_grid_time(4, 32, 4)
    t16 = autotune.modeled_grid_time(4, 32, 16)
    assert t4 < t1                       # 4 cells can't fill 16 lanes
    assert t16 > t4                      # 64 cells oversubscribe them


def test_table_overrides_heuristic_and_bumps_version():
    key = (64, 16, 32, 4)
    try:
        v0 = autotune.table_version()
        assert autotune.choose(*key).num_splits > 1     # heuristic
        autotune.put_config(key, AttnConfig(256, 1))
        assert autotune.table_version() == v0 + 1       # cache-key bump
        assert autotune.choose(*key) == AttnConfig(256, 1)
    finally:
        autotune.clear_table()
    assert autotune.choose(*key).num_splits > 1         # fallback restored


def test_lanes_env_override_shifts_heuristic(monkeypatch, tmp_path):
    """REPRO_ATTN_LANES calibrates the occupancy model per device: a bigger
    lane count makes the same shape under-occupied, flipping the heuristic
    from sequential to split; the persisted table records the lanes the
    sweep modeled with; garbage values fail loudly instead of silently
    falling back to the default."""
    monkeypatch.delenv(autotune.ENV_LANES, raising=False)
    assert autotune.effective_lanes() == autotune.LANES
    # bh = 16 fills 16 default lanes (no split)...
    assert autotune.heuristic(64, 16, 32, 16).num_splits == 1
    monkeypatch.setenv(autotune.ENV_LANES, "64")
    assert autotune.effective_lanes() == 64
    # ...but cannot fill 64 — the SAME shape now wants a split, and the
    # explicit-lanes argument matches what the env default resolves to
    assert autotune.heuristic(64, 16, 32, 16).num_splits > 1
    assert autotune.heuristic(64, 16, 32, 16) \
        == autotune.heuristic(64, 16, 32, 16, lanes=64)
    p = str(tmp_path / "tune.json")
    try:
        autotune.put_config((64, 16, 32, 16), AttnConfig(512, 4))
        autotune.save_table(p)
    finally:
        autotune.clear_table()
    import json
    assert json.load(open(p))["lanes"] == 64
    # validation: non-integers and non-positive counts raise, with the
    # variable named so the error is actionable; empty means default
    monkeypatch.setenv(autotune.ENV_LANES, "sixteen")
    with pytest.raises(ValueError, match="REPRO_ATTN_LANES"):
        autotune.effective_lanes()
    with pytest.raises(ValueError):
        autotune.heuristic(64, 16, 32, 16)     # reaches every choice path
    monkeypatch.setenv(autotune.ENV_LANES, "0")
    with pytest.raises(ValueError, match=">= 1"):
        autotune.effective_lanes()
    monkeypatch.setenv(autotune.ENV_LANES, "  ")
    assert autotune.effective_lanes() == autotune.LANES


def test_table_save_load_roundtrip(tmp_path):
    p = str(tmp_path / "tune.json")
    try:
        autotune.put_config((64, 16, 32, 4), AttnConfig(512, 4))
        autotune.put_config((32, 8, 8, 16), AttnConfig(256, 1))
        assert autotune.save_table(p) == 2
        autotune.clear_table()
        assert autotune.get_config((64, 16, 32, 4)) is None
        assert autotune.load_table(p) == 2
        assert autotune.get_config((64, 16, 32, 4)) == AttnConfig(512, 4)
        assert autotune.get_config((32, 8, 8, 16)) == AttnConfig(256, 1)
    finally:
        autotune.clear_table()


def test_load_table_rejects_malformed(tmp_path):
    p = tmp_path / "bad.json"
    p.write_text('{"entries": {"64,16,32": [512, 4]}}')
    with pytest.raises(ValueError, match="malformed"):
        autotune.load_table(str(p))


def test_sweep_populates_table():
    try:
        chosen = autotune.sweep([(64, 16, 32, 4), (64, 16, 32, 64)])
        assert chosen[(64, 16, 32, 4)].num_splits > 1
        assert chosen[(64, 16, 32, 64)].num_splits == 1
        assert autotune.get_config((64, 16, 32, 4)) is not None
        # a measure= hook (real-TPU wall clock) overrides the model
        flat = autotune.sweep([(64, 16, 32, 4)],
                              measure=lambda key, cfg: float(cfg.num_splits))
        assert flat[(64, 16, 32, 4)].num_splits == 1    # smaller is "faster"
    finally:
        autotune.clear_table()


# ------------------------------------------------------------- env plumbing
def test_kernel_mode_env_validation(monkeypatch):
    """Unrecognized REPRO_PAGED_ATTN_KERNEL values must fail LOUDLY — a typo
    silently selecting the compiled-TPU path was the prior behavior."""
    from repro.models.model import _paged_kernel_mode
    for v, want in [("", ""), ("0", ""), ("off", ""), ("false", ""),
                    ("1", "tpu"), ("tpu", "tpu"), ("interpret", "interpret"),
                    ("splitk", "splitk"),
                    ("Splitk-Interpret", "splitk-interpret")]:
        monkeypatch.setenv("REPRO_PAGED_ATTN_KERNEL", v)
        assert _paged_kernel_mode() == want
    for bad in ("interpert", "split-k", "yes", "pallas"):
        monkeypatch.setenv("REPRO_PAGED_ATTN_KERNEL", bad)
        with pytest.raises(ValueError, match="REPRO_PAGED_ATTN_KERNEL"):
            _paged_kernel_mode()


# ------------------------------------------------------------ model dispatch
def _drive_decode(cfg, params, toks, tbl, B, S):
    from repro.models.model import init_paged_cache, unified_forward
    from repro.models.stream import DECBatch, PFBatch, UnifiedBatch
    base = jnp.full((B,), -1)
    cache = init_paged_cache(cfg, 9, 8, B)
    pf = PFBatch(tokens=toks[:, :S], length=jnp.full((B,), S),
                 adapter=base, block_tables=tbl)
    cache = unified_forward(cfg, params, UnifiedBatch(pf=pf),
                            cache=cache).cache
    dec = DECBatch(tokens=toks[:, S], pos=jnp.full((B,), S),
                   adapter=base, block_tables=tbl)
    return np.asarray(unified_forward(cfg, params, UnifiedBatch(dec=dec),
                                      cache=cache).dec_logits)


def test_model_decode_bucket_splitk_flag(monkeypatch):
    """splitk-interpret must reproduce the jnp gather-view logits through
    the model — with the fan-out FORCED to a non-trivial value via the
    tuning table (the reduced config's heuristic might pick ns = 1)."""
    from repro.configs import get_reduced
    cfg = get_reduced("llama3-8b")
    from repro.models.schema import init_params
    params = init_params(cfg, jax.random.PRNGKey(0))
    B, S = 2, 10
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S + 1), 0,
                              cfg.vocab)
    tbl = jnp.asarray(np.array([[3, 1, 7, 5], [2, 6, 4, 8]], np.int32))
    monkeypatch.delenv("REPRO_PAGED_ATTN_KERNEL", raising=False)
    ref = _drive_decode(cfg, params, toks, tbl, B, S)
    try:
        # key: (cfg.hd, block_size=pool bs (init_paged_cache -> 8), nbt=4,
        # bh = B * n_heads); ns=3 exercises the non-divisor path in-model
        autotune.put_config((cfg.hd, 8, 4, B * cfg.n_heads),
                            AttnConfig(512, 3))
        monkeypatch.setenv("REPRO_PAGED_ATTN_KERNEL", "splitk-interpret")
        got = _drive_decode(cfg, params, toks, tbl, B, S)
    finally:
        autotune.clear_table()
    np.testing.assert_allclose(got, ref, rtol=2e-4, atol=2e-4)


def test_model_verify_bucket_splitk_flag(monkeypatch):
    """The verify bucket under splitk-interpret == the jnp path, valid rows
    only (ragged lens; padding slots are never read by the engine)."""
    from repro.configs import get_reduced
    from repro.models.model import init_paged_cache, unified_forward
    from repro.models.schema import init_params
    from repro.models.stream import DECBatch, PFBatch, UnifiedBatch
    cfg = get_reduced("llama3-8b")
    params = init_params(cfg, jax.random.PRNGKey(0))
    B, S, k = 2, 10, 3
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S + k + 1), 0,
                              cfg.vocab)
    base = jnp.full((B,), -1)
    tbl = jnp.asarray(np.array([[3, 1, 7, 5], [2, 6, 4, 8]], np.int32))
    lens = np.array([k + 1, k])

    def drive():
        cache = init_paged_cache(cfg, 9, 8, B)
        pf = PFBatch(tokens=toks[:, :S], length=jnp.full((B,), S),
                     adapter=base, block_tables=tbl)
        cache = unified_forward(cfg, params, UnifiedBatch(pf=pf),
                                cache=cache).cache
        dec = DECBatch(tokens=toks[:, S:S + k + 1], pos=jnp.full((B,), S),
                       adapter=base, block_tables=tbl,
                       length=jnp.asarray(lens, jnp.int32))
        return np.asarray(unified_forward(cfg, params, UnifiedBatch(dec=dec),
                                          cache=cache).dec_logits)

    monkeypatch.delenv("REPRO_PAGED_ATTN_KERNEL", raising=False)
    ref = drive()
    try:
        autotune.put_config((cfg.hd, 8, 4, B * cfg.n_heads),
                            AttnConfig(512, 2))
        monkeypatch.setenv("REPRO_PAGED_ATTN_KERNEL", "splitk-interpret")
        got = drive()
    finally:
        autotune.clear_table()
    valid = lens[:, None] > np.arange(k + 1)[None, :]
    np.testing.assert_allclose(got[valid], ref[valid], rtol=2e-4, atol=2e-4)


# ------------------------------------------------------------------- engine
def test_engine_byte_identical_across_kernel_modes(monkeypatch):
    """End-to-end greedy outputs must be BYTE-identical with the kernel
    family off, sequential, and split-K — same engine, same workload, spec
    decoding on (exercises decode AND verify buckets)."""
    from repro.configs import get_reduced
    from repro.core.lora import LoRAConfig
    from repro.core.virtualization import AdapterStore, MixedLoraModel
    from repro.models.schema import init_params
    from repro.serving.engine import EngineConfig, UnifiedEngine
    from repro.serving.request import Request
    from repro.spec import SpecConfig

    cfg = get_reduced("llama3-8b")
    rng = np.random.default_rng(3)
    prompts = [rng.integers(0, cfg.vocab, rng.integers(6, 24)).astype(
        np.int32) for _ in range(3)]

    def run():
        params = init_params(cfg, jax.random.PRNGKey(0))
        store = AdapterStore(cfg, LoRAConfig(n_slots=4, r=4),
                             jax.random.PRNGKey(1))
        store.load_random("serve", jax.random.PRNGKey(2))
        eng = UnifiedEngine(MixedLoraModel(cfg, params, store),
                            EngineConfig(capacity=4, pf_capacity=2, s_max=96,
                                         block_size=16, virtual_time=True,
                                         spec=SpecConfig(k_max=3,
                                                         drafter="ngram")))
        for i, p in enumerate(prompts):
            eng.submit(Request(rid=i, prompt=p, adapter="serve",
                               max_new_tokens=6, arrival=0.2 * i))
        eng.run(max_ticks=5000)
        assert len(eng.finished) == 3
        return {r.rid: list(r.output) for r in eng.finished}

    outs = {}
    for mode in ("", "interpret", "splitk-interpret"):
        if mode:
            monkeypatch.setenv("REPRO_PAGED_ATTN_KERNEL", mode)
        else:
            monkeypatch.delenv("REPRO_PAGED_ATTN_KERNEL", raising=False)
        outs[mode] = run()
    assert outs[""] == outs["interpret"] == outs["splitk-interpret"]
