"""Per-architecture smoke tests: every assigned arch in REDUCED form runs one
unified forward (ft+pf+dec) and one fine-tuning step on CPU — shapes correct,
no NaNs, loss finite, gradients flow to the LoRA bank only."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, get_reduced
from repro.core.lora import LoRAConfig
from repro.core.unified import make_train_step
from repro.core.virtualization import AdapterStore
from repro.models.model import init_cache, unified_forward
from repro.models.schema import init_params
from repro.models.stream import DECBatch, FTBatch, PFBatch, UnifiedBatch
from repro.training.optimizer import AdamWConfig, adamw_init

LCFG = LoRAConfig(n_slots=3, r=4)


def _aux(cfg, b, key):
    if cfg.encoder is not None:
        return jax.random.normal(key, (b, cfg.encoder.n_frames, cfg.d_model)) * 0.1
    if cfg.cross_attn_every:
        return jax.random.normal(key, (b, cfg.n_img_tokens, cfg.d_model)) * 0.1
    return None


def _batch(cfg, Bf=2, Sf=16, Bp=2, Sp=8, Bd=3):
    key = jax.random.PRNGKey(42)
    ks = jax.random.split(key, 6)
    ft = FTBatch(tokens=jax.random.randint(ks[0], (Bf, Sf), 0, cfg.vocab),
                 mask=jnp.ones((Bf, Sf), bool),
                 labels=jax.random.randint(ks[1], (Bf, Sf), 0, cfg.vocab),
                 adapter=jnp.array([0, 1]), weight=jnp.ones((Bf,)),
                 aux_embed=_aux(cfg, Bf, ks[2]))
    pf = PFBatch(tokens=jax.random.randint(ks[3], (Bp, Sp), 0, cfg.vocab),
                 length=jnp.array([Sp, Sp - 3]), adapter=jnp.array([0, -1]),
                 aux_embed=_aux(cfg, Bp, ks[4]))
    dec = DECBatch(tokens=jnp.ones((Bd,), jnp.int32),
                   pos=jnp.array([3, 0, 7]), adapter=jnp.array([1, 2, 0]))
    return UnifiedBatch(ft=ft, pf=pf, dec=dec)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_full_config_matches_assignment(arch):
    cfg = get_config(arch)
    cfg.validate()
    assert cfg.n_layers >= 1 and cfg.vocab > 0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_reduced_unified_forward(arch):
    cfg = get_reduced(arch)
    assert cfg.d_model <= 512 and cfg.n_layers <= 5
    if cfg.moe:
        assert cfg.moe.num_experts <= 4
    params = init_params(cfg, jax.random.PRNGKey(0))
    store = AdapterStore(cfg, LCFG, jax.random.PRNGKey(1))
    store.load_random("a", jax.random.PRNGKey(2))
    store.load_random("b", jax.random.PRNGKey(3))
    batch = _batch(cfg)
    cache = init_cache(cfg, 3 + 2, 32)
    out = unified_forward(cfg, params, batch, cache=cache, loras=store.bank,
                          lora_scale=store.scale)
    assert out.pf_logits.shape == (2, cfg.vocab)
    assert out.dec_logits.shape == (3, cfg.vocab)
    assert out.ft_loss_sum.shape == (2,)
    for t in (out.pf_logits, out.dec_logits, out.ft_loss_sum):
        assert bool(jnp.isfinite(t).all()), arch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_reduced_train_step(arch):
    cfg = get_reduced(arch)
    params = init_params(cfg, jax.random.PRNGKey(0))
    store = AdapterStore(cfg, LCFG, jax.random.PRNGKey(1))
    store.load_random("a", jax.random.PRNGKey(2))
    batch = UnifiedBatch(ft=_batch(cfg).ft)
    step = make_train_step(cfg, AdamWConfig(lr=1e-3))
    opt_state = adamw_init(store.bank, LCFG.n_slots)
    mask = store.slot_mask(["a"])
    loss, new_bank, new_state, aux = step(params, store.bank, store.scale,
                                          opt_state, batch, mask)
    assert bool(jnp.isfinite(loss)), arch
    # gradients flowed: slot 0 moved, slot 2 (empty) did not
    def delta(slot):
        d = jax.tree_util.tree_map(
            lambda a, b: float(jnp.abs(a[..., slot, :, :]
                                       - b[..., slot, :, :]).max()),
            store.bank, new_bank)
        return max(jax.tree_util.tree_leaves(d))
    assert delta(0) > 0, arch
    assert delta(2) == 0, arch
