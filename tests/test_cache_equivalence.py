"""Prefill + decode through the cache must equal one full forward — per
architecture family, including multi-step decode and windowed caches."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.models.model import init_cache, unified_forward
from repro.models.schema import init_params
from repro.models.stream import DECBatch, PFBatch, UnifiedBatch

FAMILIES = ["llama3-8b", "mamba2-1.3b", "deepseek-v2-236b",
            "jamba-1.5-large-398b", "llama-3.2-vision-90b", "whisper-base",
            "llama4-maverick-400b-a17b", "qwen1.5-110b"]


def _aux(cfg, b):
    if cfg.encoder is not None:
        return jax.random.normal(jax.random.PRNGKey(9),
                                 (b, cfg.encoder.n_frames, cfg.d_model)) * 0.1
    if cfg.cross_attn_every:
        return jax.random.normal(jax.random.PRNGKey(9),
                                 (b, cfg.n_img_tokens, cfg.d_model)) * 0.1
    return None


@pytest.mark.parametrize("arch", FAMILIES)
def test_prefill_then_decode_matches_full(arch):
    cfg = get_reduced(arch)
    params = init_params(cfg, jax.random.PRNGKey(0))
    B, S, extra = 2, 10, 3
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S + extra),
                              0, cfg.vocab)
    aux = _aux(cfg, B)
    base = jnp.full((B,), -1)

    cache = init_cache(cfg, B, 32)
    pf = PFBatch(tokens=toks[:, :S], length=jnp.full((B,), S), adapter=base,
                 aux_embed=aux)
    out = unified_forward(cfg, params, UnifiedBatch(pf=pf), cache=cache)
    cache = out.cache
    logits = out.pf_logits
    # decode `extra` tokens one at a time
    for i in range(extra):
        dec = DECBatch(tokens=toks[:, S + i], pos=jnp.full((B,), S + i),
                       adapter=base)
        out = unified_forward(cfg, params, UnifiedBatch(dec=dec), cache=cache)
        cache = out.cache
        logits = out.dec_logits

    # reference: prefill the whole sequence at once
    cache2 = init_cache(cfg, B, 32)
    pf2 = PFBatch(tokens=toks, length=jnp.full((B,), S + extra), adapter=base,
                  aux_embed=aux)
    ref = unified_forward(cfg, params, UnifiedBatch(pf=pf2), cache=cache2)
    np.testing.assert_allclose(np.asarray(logits), np.asarray(ref.pf_logits),
                               rtol=2e-4, atol=2e-4)


def test_windowed_decode_matches_full_within_window():
    """Sliding-window cache: decode logits must equal a full forward whose
    attention is windowed the same way."""
    cfg = get_reduced("llama3-8b").replace(sliding_window=8)
    params = init_params(cfg, jax.random.PRNGKey(0))
    B, S = 1, 20
    toks = jax.random.randint(jax.random.PRNGKey(2), (B, S + 1), 0, cfg.vocab)
    base = jnp.full((B,), -1)
    # path A: prefill S (rolling cache holds last 8), decode token S
    cache = init_cache(cfg, B, cfg.sliding_window)
    pf = PFBatch(tokens=toks[:, :S], length=jnp.full((B,), S), adapter=base)
    out = unified_forward(cfg, params, UnifiedBatch(pf=pf), cache=cache)
    dec = DECBatch(tokens=toks[:, S], pos=jnp.full((B,), S), adapter=base)
    outA = unified_forward(cfg, params, UnifiedBatch(dec=dec), cache=out.cache)
    # path B: full windowed prefill of S+1
    cache2 = init_cache(cfg, B, cfg.sliding_window)
    pf2 = PFBatch(tokens=toks, length=jnp.full((B,), S + 1), adapter=base)
    outB = unified_forward(cfg, params, UnifiedBatch(pf=pf2), cache=cache2)
    np.testing.assert_allclose(np.asarray(outA.dec_logits),
                               np.asarray(outB.pf_logits),
                               rtol=2e-4, atol=2e-4)


def test_padded_prefill_rows_do_not_corrupt():
    """Right-padded prefill rows produce the same logits as tight rows."""
    cfg = get_reduced("llama3-8b")
    params = init_params(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(3), (1, 8), 0, cfg.vocab)
    base = jnp.full((1,), -1)
    cache = init_cache(cfg, 1, 32)
    pf_tight = PFBatch(tokens=toks, length=jnp.array([8]), adapter=base)
    a = unified_forward(cfg, params, UnifiedBatch(pf=pf_tight), cache=cache)
    padded = jnp.concatenate([toks, jnp.full((1, 8), 7, jnp.int32)], 1)
    cache2 = init_cache(cfg, 1, 32)
    pf_pad = PFBatch(tokens=padded, length=jnp.array([8]), adapter=base)
    b = unified_forward(cfg, params, UnifiedBatch(pf=pf_pad), cache=cache2)
    np.testing.assert_allclose(np.asarray(a.pf_logits),
                               np.asarray(b.pf_logits), rtol=2e-5, atol=2e-5)
