"""Unified KV + adapter paging: true-rank flatten/unflatten exactness,
variable block footprints, shed/pin semantics over the shared pool,
adapter-residency-aware scheduling (co-batching, starvation bound,
preemption anti-thrash), swap-in clock charges, and end-to-end
byte-exactness of serving with paging on vs off."""
import jax
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.core.lora import LoRAConfig
from repro.core.virtualization import AdapterStore, MixedLoraModel
from repro.models.schema import init_params
from repro.serving.clock import CostModel, VirtualClock
from repro.serving.engine import EngineConfig, UnifiedEngine
from repro.serving.kvcache import PagedCacheManager
from repro.serving.request import Request, State
from repro.serving.scheduler import Scheduler, SchedulerConfig

CFG = get_reduced("llama3-8b")


def _mgr(capacity=4, n_blocks=32, s_max=64, bs=16):
    return PagedCacheManager(CFG, capacity, 2, s_max, block_size=bs,
                             n_blocks=n_blocks)


def _store(n_slots=3, r=8, seed=0):
    return AdapterStore(CFG, LoRAConfig(n_slots=n_slots, r=r),
                        jax.random.PRNGKey(seed))


def _load(store, names_ranks, seed=100):
    for i, (name, rk) in enumerate(names_ranks):
        store.load_random(name, jax.random.PRNGKey(seed + i), rank=rk,
                          evict=True)


def _bank_np(store):
    return jax.tree_util.tree_leaves(
        jax.tree_util.tree_map(np.asarray, store.bank))


# ------------------------------------------------- flatten / unflatten
def test_true_rank_round_trip_bit_exact():
    """The paged view must be lossless: bank contents are byte-identical
    whether an adapter arrives via direct load or via the pool round-trip
    (flatten -> blocks -> gather -> unflatten), at every true rank."""
    pairs = [("a1", 1), ("a2", 3), ("a3", 8)]
    s_plain = _store()
    _load(s_plain, pairs)
    s_paged = _store()
    _load(s_paged, pairs)
    m = _mgr()
    s_paged.attach_pager(m)
    for a, b in zip(_bank_np(s_plain), _bank_np(s_paged)):
        assert np.array_equal(a, b)
    # pool payload is exactly the archived byte image
    for name, _ in pairs:
        assert np.array_equal(m.adapter_gather(name),
                              s_paged._archive[name][0])
    # full retire (bank + pool) then re-acquire must restore the bank
    # byte-for-byte through a counted swap-in
    before = _bank_np(s_paged)
    s_paged.unload("a2")
    while m.adapter_resident("a2"):
        assert m._shed_adapter(frozenset())
    swaps0 = s_paged.swap_ins
    s_paged.acquire("a2")
    assert s_paged.swap_ins == swaps0 + 1
    for a, b in zip(before, _bank_np(s_paged)):
        assert np.array_equal(a, b)


def test_variable_block_counts_by_rank():
    """Heterogeneous true ranks cost proportionally many pool blocks: a
    rank-2k adapter's payload is exactly twice a rank-k one's."""
    s = _store(r=8)
    _load(s, [("r2", 2), ("r4", 4), ("r8", 8)])
    m = _mgr(n_blocks=64)
    s.attach_pager(m)
    n2 = len(m.adapter_tables["r2"])
    n4 = len(m.adapter_tables["r4"])
    n8 = len(m.adapter_tables["r8"])
    assert s.adapter_nbytes("r4") == 2 * s.adapter_nbytes("r2")
    assert s.adapter_nbytes("r8") == 4 * s.adapter_nbytes("r2")
    assert n2 <= n4 <= n8 and n8 > n2
    assert m.adapter_blocks_resident == n2 + n4 + n8
    # and the pool meters them against the same accounting KV blocks use
    assert m.reclaimable_adapter_blocks == n2 + n4 + n8
    assert m.reclaimable_blocks >= n2 + n4 + n8


# -------------------------------------------------- shed / pin semantics
def test_pinned_adapter_never_shed_under_kv_pressure():
    """KV admission sheds cold adapters for blocks — but never a pinned
    one, no matter the pressure."""
    m = _mgr(n_blocks=12, bs=16)
    pay = np.arange(3 * m.adapter_block_bytes, dtype=np.uint8)
    assert m.adapter_admit("pinned", pay)
    assert m.adapter_admit("cold", pay[: m.adapter_block_bytes])
    m.adapter_pin("pinned")
    prompt = np.zeros((16,), np.int32)
    admitted = 0
    while m.try_admit(prompt, max_new=48, adapter=str(admitted)) is not None:
        admitted += 1
    assert admitted >= 1
    assert m.adapter_resident("pinned"), "pinned adapter was shed"
    assert not m.adapter_resident("cold"), "pressure never reached adapters"
    assert np.array_equal(m.adapter_gather("pinned"), pay)
    m.adapter_unpin("pinned")


def test_redundant_pool_copies_shed_first():
    """Victim order: a bank-materialized clean adapter's pool copy is free
    to drop (the bank copy lives) and must go before a colder pool-only
    adapter."""
    m = _mgr(n_blocks=32)
    pay = np.arange(m.adapter_block_bytes, dtype=np.uint8)
    m.adapter_admit("older", pay)       # colder, NOT redundant
    m.adapter_admit("newer", pay)       # hotter, but redundant
    m.adapter_redundant_fn = lambda n: n == "newer"
    assert m._shed_adapter(frozenset())
    assert m.adapter_resident("older")
    assert not m.adapter_resident("newer")


def test_acquire_raises_when_pool_and_bank_are_saturated():
    """With the pool fully held by KV working state and every bank slot
    retained, a host-archived adapter cannot come in: acquire raises
    RuntimeError and the engine defers the request (no crash, no leak)."""
    s = _store(n_slots=2, r=8)
    _load(s, [("a", 8), ("b", 8), ("c", 8)])      # c evicts a from the bank
    m = _mgr(capacity=2, n_blocks=6, bs=16, s_max=64)
    s.attach_pager(m)
    m.flush_adapters()                             # pool: adapters out
    for name in list(s.resident):
        s.retain(name)                             # bank: all slots held
    victim = next(n for n in ("a", "b", "c") if n not in s._slots)
    # occupy every pool block with KV state (tables hold refs, not index)
    got = m.try_admit(np.zeros((16,), np.int32), max_new=64)
    assert got is not None
    m.grow(got[0], 64)
    with pytest.raises(RuntimeError):
        s.acquire(victim)
    for name in list(s.resident):
        s.release(name)


# ------------------------------------------- residency-aware scheduling
def _req(rid, adapter, arrival=0.0, plen=8, max_new=4):
    return Request(rid=rid, prompt=np.zeros((plen,), np.int32),
                   adapter=adapter, max_new_tokens=max_new, arrival=arrival)


def test_scheduler_cobatches_same_adapter():
    """Greedy selection: once a cold adapter's first request is picked,
    same-adapter waiters become warm and cluster into the same tick —
    one swap-in amortized over the co-batch."""
    sched = Scheduler(SchedulerConfig(max_prefill_per_tick=4), capacity=8)
    waiting = [_req(0, "A"), _req(1, "B"), _req(2, "A")]
    d = sched.decide(waiting, 0, 8, 4, False,
                     adapter_fn=lambda r: False, now=0.0)
    assert [r.rid for r in d.admit] == [0, 2, 1]


def test_scheduler_prefers_resident_adapters():
    """A resident-adapter waiter outranks an earlier-but-cold one (within
    the fairness ramp)."""
    sched = Scheduler(SchedulerConfig(max_prefill_per_tick=2), capacity=8)
    waiting = [_req(0, "cold", arrival=0.0), _req(1, "warm", arrival=0.1)]
    d = sched.decide(waiting, 0, 8, 4, False,
                     adapter_fn=lambda r: r.adapter == "warm", now=0.2)
    assert [r.rid for r in d.admit] == [1, 0]


def test_scheduler_cold_adapter_cannot_starve_past_ramp():
    """The affinity bonus is capped strictly below the ramp's saturation:
    a cold request that has waited past ``prefix_ramp_s`` outranks every
    fresh resident-adapter arrival."""
    c = SchedulerConfig(max_prefill_per_tick=1, prefix_ramp_s=1.0)
    sched = Scheduler(c, capacity=8)
    cold = _req(0, "cold", arrival=0.0)
    warm = [_req(i, "warm", arrival=1.95) for i in range(1, 4)]
    d = sched.decide([cold] + warm, 0, 8, 4, False,
                     adapter_fn=lambda r: r.adapter == "warm", now=2.0)
    assert [r.rid for r in d.admit] == [0]


def test_scheduler_static_order_unchanged_without_adapter_fn():
    """adapter_fn=None must reproduce the pre-paging admission order
    byte-for-byte (the static-partition baseline is untouched)."""
    sched = Scheduler(SchedulerConfig(max_prefill_per_tick=4), capacity=8)
    waiting = [_req(i, "x", arrival=0.01 * i) for i in range(4)]
    d = sched.decide(list(waiting), 0, 8, 4, False, now=1.0)
    assert [r.rid for r in d.admit] == [0, 1, 2, 3]


# ------------------------------------------------------------- engine e2e
def _engine(adapter_paging, n_adapters=6, n_slots=3, seed=0, **kw):
    params = init_params(CFG, jax.random.PRNGKey(seed))
    store = AdapterStore(CFG, LoRAConfig(n_slots=n_slots, r=4),
                         jax.random.PRNGKey(seed + 1))
    eng = UnifiedEngine(MixedLoraModel(CFG, params, store), EngineConfig(
        capacity=4, pf_capacity=2, s_max=64, virtual_time=True,
        block_size=16, adapter_paging=adapter_paging,
        **{"n_blocks": 48, **kw}))
    ranks = [1, 2, 4]
    for i in range(n_adapters):
        store.load_random(f"ad{i}", jax.random.PRNGKey(10 + i),
                          rank=ranks[i % 3], evict=True)
    return eng


def _submit_zipf(eng, n=12, n_adapters=6, plen=8, max_new=6, seed=7):
    rng = np.random.default_rng(seed)
    for i in range(n):
        eng.submit(Request(
            rid=i, prompt=rng.integers(0, CFG.vocab, plen).astype(np.int32),
            adapter=f"ad{i % n_adapters}", max_new_tokens=max_new,
            arrival=0.02 * i))


def test_e2e_byte_exact_paging_on_vs_off():
    """Unified paging changes WHERE adapter bytes live and WHEN requests
    are scheduled — never what they compute.  Same trace, both arms:
    byte-identical outputs, no pin leaks, pool drains pristine."""
    outs = {}
    for paging in (False, True):
        eng = _engine(paging)
        _submit_zipf(eng)
        m = eng.run(max_ticks=4000)
        assert len(eng.finished) == 12
        assert all(r.state is State.DONE for r in eng.finished)
        outs[paging] = {r.rid: list(r.output) for r in eng.finished}
        cm = eng.cachemgr
        assert all(v == 0 for v in cm._adapter_pins.values()), "pin leak"
        assert cm.pristine
        if paging:
            # resident gathers served the hot set without host traffic
            assert m.adapter_resident_hits > 0
            assert m.adapter_peak_coresident >= 3
            assert m.adapter_blocks_resident > 0
            # with the pool holding all six adapters the unified arm never
            # re-pays a swap the static bank would have charged
            assert m.adapter_swap_ins <= eng.metrics.adapter_swap_ins
    assert outs[False] == outs[True]


def test_cobatched_requests_amortize_one_swap_in():
    """Three same-adapter requests arriving together: the tick's batch
    resolve acquires the adapter ONCE, so exactly one swap-in is counted
    (and clock-charged) for the whole co-batch."""
    eng = _engine(True, n_adapters=3, n_slots=2)
    store = eng.model.store
    # archive-retire ad0 everywhere: next acquire must swap in
    if "ad0" in store._slots:
        store.unload("ad0")
    while eng.cachemgr.adapter_resident("ad0"):
        assert eng.cachemgr._shed_adapter(frozenset())
    assert not store.is_resident("ad0")
    swaps0 = store.swap_ins
    rng = np.random.default_rng(3)
    for i in range(3):
        eng.submit(Request(
            rid=i, prompt=rng.integers(0, CFG.vocab, 8).astype(np.int32),
            adapter="ad0", max_new_tokens=4, arrival=0.0))
    assert eng.tick()
    admitted = sum(1 for r in list(eng.active.values())
                   + list(eng.prefilling.values()) if r.adapter == "ad0")
    assert admitted >= 2
    assert store.swap_ins == swaps0 + 1, "co-batch paid more than one swap"
    eng.run(max_ticks=2000)
    assert store.swap_ins == swaps0 + 1
    assert len(eng.finished) == 3


def test_preemption_keeps_victims_adapter_resident():
    """Recompute preemption must not thrash the victim's adapter: the
    retain is kept across the requeue, so the adapter can be neither
    bank-evicted nor pool-shed while the victim waits, and resuming costs
    zero swap-ins."""
    eng = _engine(True, n_adapters=2, n_slots=2, n_blocks=8,
                  over_admit=2.0)
    store = eng.model.store
    rng = np.random.default_rng(11)
    for i in range(3):
        eng.submit(Request(
            rid=i, prompt=rng.integers(0, CFG.vocab, 8).astype(np.int32),
            adapter="ad0", max_new_tokens=40, arrival=0.1 * i))
    swaps0 = store.swap_ins
    eng.run(max_ticks=5000)
    assert eng.metrics.preemptions >= 1
    assert len(eng.finished) == 3
    assert all(r.state is State.DONE for r in eng.finished)
    assert store.swap_ins == swaps0, "preemption thrashed the adapter"
    assert all(not r.adapter_retained for r in eng.finished)
    assert all(v == 0 for v in eng.cachemgr._adapter_pins.values())


def test_unknown_adapter_fails_cleanly_without_leaks():
    eng = _engine(True, n_adapters=1)
    eng.submit(Request(rid=0, prompt=np.zeros((8,), np.int32),
                       adapter="nope", max_new_tokens=4, arrival=0.0))
    eng.submit(Request(rid=1, prompt=np.zeros((8,), np.int32),
                       adapter="ad0", max_new_tokens=4, arrival=0.0))
    eng.run(max_ticks=2000)
    by = {r.rid: r for r in eng.finished}
    assert by[0].state is State.FAILED
    assert by[1].state is State.DONE
    assert all(v == 0 for v in eng.cachemgr._adapter_pins.values())
    assert eng.cachemgr.pristine


def test_midtick_exception_leaks_no_adapter_holds():
    """The per-tick adapter holds taken by ``_resolve`` must be released
    even when admission explodes mid-loop (engine.py's try/finally around
    ``_admit_loop``).  Inject a failure into ``try_admit``, let the tick
    abort, and check the store's refcounts show ONLY the retains owned by
    in-flight requests — then recover and drain to a pristine pool."""
    from collections import Counter

    eng = _engine(True)
    _submit_zipf(eng)
    for _ in range(3):     # get some requests mid-flight holding retains
        eng.tick()
    store = eng.model.store
    real_try_admit = eng.cachemgr.try_admit

    def boom(*a, **kw):
        raise RuntimeError("injected mid-tick failure")

    eng.cachemgr.try_admit = boom
    # the failing tick needs a pending admission for try_admit to fire;
    # ticks where the scheduler admits nothing pass through harmlessly
    with pytest.raises(RuntimeError, match="injected"):
        for _ in range(200):
            eng.tick()

    live = (list(eng.waiting) + list(eng.prefilling.values())
            + list(eng.active.values()))
    expected = Counter(r.adapter for r in live if r.adapter_retained)
    assert store._refs == dict(expected), \
        "temporary _resolve holds leaked past the aborted tick"

    # recovery: the aborted tick lost no request state — restore try_admit
    # and every submitted request still runs to completion
    eng.cachemgr.try_admit = real_try_admit
    eng.run(max_ticks=4000)
    assert len(eng.finished) == 12
    assert all(r.state is State.DONE for r in eng.finished)
    assert store._refs == {}
    assert all(v == 0 for v in eng.cachemgr._adapter_pins.values())
    assert eng.cachemgr.pristine


# ------------------------------------------------------------- clock
def test_clock_charges_adapter_swaps():
    clk = VirtualClock(CostModel())
    c = clk.cost
    assert clk.step_cost(0, 0, 0) == 0.0
    got = clk.step_cost(0, 0, 0, adapter_swaps=2, adapter_swap_bytes=1000)
    assert got == pytest.approx(c.fixed + 2 * c.adapter_swap_fixed
                                + 1000 * c.adapter_h2d_per_byte)
    # swap charges stack on top of compute charges
    base = clk.step_cost(16, 2, 0)
    with_swap = clk.step_cost(16, 2, 0, adapter_swaps=1)
    assert with_swap == pytest.approx(base + c.adapter_swap_fixed)


def test_trained_adapter_syncs_before_shed():
    """mark_dirty + pool shed must write the bank's newer weights back to
    the host archive, so a later swap-in restores the TRAINED adapter."""
    s = _store(n_slots=2, r=4)
    _load(s, [("tr", 4)])
    m = _mgr(n_blocks=32)
    s.attach_pager(m)
    # simulate a training update: perturb the bank slot, mark dirty
    slot = s.slot_of("tr")
    s.bank = jax.tree_util.tree_map(
        lambda x: x.at[..., slot, :, :].add(1.0), s.bank)
    s.mark_dirty("tr")
    trained = [np.asarray(x) for x in
               jax.tree_util.tree_leaves(s.get_adapter("tr"))]
    while m.adapter_resident("tr"):
        assert m._shed_adapter(frozenset())     # fires the sync callback
    s.unload("tr")                              # retire the bank copy too
    s.acquire("tr")                             # swap back in from archive
    got = [np.asarray(x) for x in
           jax.tree_util.tree_leaves(s.get_adapter("tr"))]
    for a, b in zip(trained, got):
        assert np.array_equal(a, b)
