"""benchmarks/gate.py — the unit-tested CI bench gate: path resolution
(dotted / wildcard / interpolated), operator semantics, loud failures on
dangling paths and missing artifacts, and schema sanity of the checked-in
gates.json (every bench the CI matrix runs has a non-empty gate; run.py
registers a matching artifact)."""
import json
import os
import sys

import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks.gate import GateError, resolve, run_check, run_gate  # noqa: E402

DOC = {
    "exact": True,
    "speedup": 1.8,
    "prefill_chunk": 128,
    "best_factor": 1.5,
    "nested": {"chunked": {"gap": 0.05, "steps": 0},
               "unchunked": {"gap": 0.14}},
    "arms": {"1": {"DTPS": 70.0, "leak_free": True},
             "1.5": {"DTPS": 77.0, "leak_free": True}},
}


# --------------------------------------------------------------- resolve
def test_resolve_dotted_and_wildcard():
    assert resolve(DOC, "speedup") == [1.8]
    assert resolve(DOC, "nested.chunked.gap") == [0.05]
    assert sorted(resolve(DOC, "arms.*.DTPS")) == [70.0, 77.0]


def test_resolve_interpolated_segment():
    # {best_factor} -> 1.5 -> key "1.5" (float keys via %g, so 1.0 -> "1")
    assert resolve(DOC, "arms.{best_factor}.DTPS") == [77.0]
    one = dict(DOC, best_factor=1.0, arms={"1": {"DTPS": 70.0}})
    assert resolve(one, "arms.{best_factor}.DTPS") == [70.0]


def test_resolve_dangling_path_fails_loudly():
    with pytest.raises(GateError):
        resolve(DOC, "nested.missing.gap")
    with pytest.raises(GateError):
        resolve(DOC, "speedup.deeper")


# ------------------------------------------------------------- run_check
def test_check_ops_pass_and_fail():
    run_check(DOC, {"lhs": "speedup", "op": ">=", "rhs": 1.5})
    run_check(DOC, {"lhs": "exact", "op": "truthy"})
    run_check(DOC, {"lhs": "nested.chunked.steps", "op": "==", "rhs": 0})
    with pytest.raises(GateError):
        run_check(DOC, {"lhs": "speedup", "op": ">=", "rhs": 2.5})
    with pytest.raises(GateError):
        run_check(DOC, {"lhs": "nested.chunked.steps", "op": "truthy"})


def test_check_rhs_path_and_wildcard_all_semantics():
    # path rhs: chunked gap must beat unchunked gap
    run_check(DOC, {"lhs": "nested.chunked.gap", "op": "<",
                    "rhs": "nested.unchunked.gap"})
    # wildcard lhs: must hold for EVERY arm
    run_check(DOC, {"lhs": "arms.*.leak_free", "op": "truthy"})
    leaky = json.loads(json.dumps(DOC))
    leaky["arms"]["1.5"]["leak_free"] = False
    with pytest.raises(GateError):
        run_check(leaky, {"lhs": "arms.*.leak_free", "op": "truthy"})
    # interpolated lhs against a path rhs: best arm beats the baseline
    run_check(DOC, {"lhs": "arms.{best_factor}.DTPS", "op": ">",
                    "rhs": "arms.1.DTPS"})


def test_unknown_op_rejected():
    with pytest.raises(GateError):
        run_check(DOC, {"lhs": "speedup", "op": "~=", "rhs": 1.0})


# -------------------------------------------------------------- run_gate
def _write(tmp_path, name, obj):
    p = tmp_path / name
    p.write_text(json.dumps(obj))
    return str(p)


def test_run_gate_end_to_end(tmp_path):
    gates = _write(tmp_path, "gates.json", {
        "toy": {"artifact": "BENCH_toy.json",
                "checks": [{"lhs": "exact", "op": "truthy"},
                           {"lhs": "speedup", "op": ">=", "rhs": 1.2}]}})
    _write(tmp_path, "BENCH_toy.json", {"exact": True, "speedup": 1.3})
    assert run_gate("toy", gates, str(tmp_path)) == 2


def test_run_gate_missing_artifact_or_bench_fails(tmp_path):
    gates = _write(tmp_path, "gates.json", {
        "toy": {"artifact": "BENCH_toy.json",
                "checks": [{"lhs": "exact", "op": "truthy"}]},
        "hollow": {"artifact": "BENCH_hollow.json", "checks": []}})
    with pytest.raises(GateError, match="missing"):
        run_gate("toy", gates, str(tmp_path))          # artifact absent
    with pytest.raises(GateError, match="no gate"):
        run_gate("nope", gates, str(tmp_path))
    _write(tmp_path, "BENCH_hollow.json", {})
    with pytest.raises(GateError, match="no checks"):  # vacuous gate = fail
        run_gate("hollow", gates, str(tmp_path))


# ------------------------------------------------- checked-in gates.json
def _repo(*parts):
    return os.path.join(os.path.dirname(__file__), "..", *parts)


def _ci_bench_matrix() -> set:
    """The ``bench:`` matrix list parsed out of ci.yml (flow sequence, may
    wrap lines).  Parsed, not substring-matched: the consistency assertions
    below must fail when EITHER side drifts — a matrix entry without a
    gate, or a gate no matrix job ever runs."""
    import re
    with open(_repo(".github", "workflows", "ci.yml")) as f:
        ci = f.read()
    assert "benchmarks/gate.py" in ci
    m = re.search(r"bench:\s*\[([^\]]*)\]", ci, re.DOTALL)
    assert m, "ci.yml bench matrix not found"
    return {s.strip() for s in m.group(1).split(",") if s.strip()}


def test_checked_in_gates_cover_the_ci_matrix():
    """BIDIRECTIONAL matrix <-> gates <-> run.py consistency: every bench
    the CI matrix runs has a non-empty gate whose artifact run.py
    registers, every gate is exercised by a matrix job, and every
    registered BENCH artifact is gated.  Adding any one of the three
    without the other two fails loudly here."""
    with open(_repo("benchmarks", "gates.json")) as f:
        gates = json.load(f)
    matrix = _ci_bench_matrix()
    assert matrix == set(gates), (
        f"ci.yml bench matrix {sorted(matrix)} != gates.json keys "
        f"{sorted(gates)} — a matrix entry without a gate (or a gate no "
        f"job runs) ships unchecked numbers")
    for name in sorted(gates):
        assert gates[name]["checks"], f"gate {name} is vacuous"
        assert gates[name]["artifact"] == f"BENCH_{name}.json"
    from benchmarks.run import TABLES
    registered = {a for _, _, a in TABLES if a}
    gated = {g["artifact"] for g in gates.values()}
    assert gated == registered, (
        f"run.py registers {sorted(registered)} but gates.json covers "
        f"{sorted(gated)} — an ungated artifact green-passes on any "
        f"regression")


def test_run_py_summary():
    """Every registered BENCH artifact charts a headline metric, and the
    --summarize-only path folds whatever artifacts exist into
    BENCH_summary.json (and fails loudly when there are none)."""
    import json as _json
    from benchmarks.run import (HEADLINES, SUMMARY, TABLES, headline_of,
                                summarize_only)
    registered = {a for _, _, a in TABLES if a}
    assert set(HEADLINES) == registered
    import tempfile
    with tempfile.TemporaryDirectory() as d:
        assert summarize_only(d) == 1          # nothing to summarize = fail
        with open(os.path.join(d, "BENCH_spec.json"), "w") as f:
            _json.dump({"speedup": 2.0, "exact": True}, f)
        assert headline_of("BENCH_spec.json", d) == ("speedup", 2.0)
        assert summarize_only(d) == 0
        with open(os.path.join(d, SUMMARY)) as f:
            rows = _json.load(f)["benches"]
        assert rows == {"spec_decode": {"artifact": "BENCH_spec.json",
                                        "headline": "speedup",
                                        "value": 2.0, "wall_s": None}}


def test_run_py_artifact_check():
    """run.py must flag a registered benchmark that wrote no artifact."""
    import time
    from benchmarks.run import check_artifact
    assert check_artifact(None, time.time()) == ""
    assert "wrote no" in check_artifact("BENCH_does_not_exist.json",
                                        time.time())
    probe = _repo("BENCH_paged.json")       # exists, but predates this run
    if os.path.exists(probe):
        assert "stale" in check_artifact(probe, time.time() + 1)
