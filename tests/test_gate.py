"""benchmarks/gate.py — the unit-tested CI bench gate: path resolution
(dotted / wildcard / interpolated), operator semantics, loud failures on
dangling paths and missing artifacts, and schema sanity of the checked-in
gates.json (every bench the CI matrix runs has a non-empty gate; run.py
registers a matching artifact)."""
import json
import os
import sys

import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks.gate import GateError, resolve, run_check, run_gate  # noqa: E402

DOC = {
    "exact": True,
    "speedup": 1.8,
    "prefill_chunk": 128,
    "best_factor": 1.5,
    "nested": {"chunked": {"gap": 0.05, "steps": 0},
               "unchunked": {"gap": 0.14}},
    "arms": {"1": {"DTPS": 70.0, "leak_free": True},
             "1.5": {"DTPS": 77.0, "leak_free": True}},
}


# --------------------------------------------------------------- resolve
def test_resolve_dotted_and_wildcard():
    assert resolve(DOC, "speedup") == [1.8]
    assert resolve(DOC, "nested.chunked.gap") == [0.05]
    assert sorted(resolve(DOC, "arms.*.DTPS")) == [70.0, 77.0]


def test_resolve_interpolated_segment():
    # {best_factor} -> 1.5 -> key "1.5" (float keys via %g, so 1.0 -> "1")
    assert resolve(DOC, "arms.{best_factor}.DTPS") == [77.0]
    one = dict(DOC, best_factor=1.0, arms={"1": {"DTPS": 70.0}})
    assert resolve(one, "arms.{best_factor}.DTPS") == [70.0]


def test_resolve_dangling_path_fails_loudly():
    with pytest.raises(GateError):
        resolve(DOC, "nested.missing.gap")
    with pytest.raises(GateError):
        resolve(DOC, "speedup.deeper")


# ------------------------------------------------------------- run_check
def test_check_ops_pass_and_fail():
    run_check(DOC, {"lhs": "speedup", "op": ">=", "rhs": 1.5})
    run_check(DOC, {"lhs": "exact", "op": "truthy"})
    run_check(DOC, {"lhs": "nested.chunked.steps", "op": "==", "rhs": 0})
    with pytest.raises(GateError):
        run_check(DOC, {"lhs": "speedup", "op": ">=", "rhs": 2.5})
    with pytest.raises(GateError):
        run_check(DOC, {"lhs": "nested.chunked.steps", "op": "truthy"})


def test_check_rhs_path_and_wildcard_all_semantics():
    # path rhs: chunked gap must beat unchunked gap
    run_check(DOC, {"lhs": "nested.chunked.gap", "op": "<",
                    "rhs": "nested.unchunked.gap"})
    # wildcard lhs: must hold for EVERY arm
    run_check(DOC, {"lhs": "arms.*.leak_free", "op": "truthy"})
    leaky = json.loads(json.dumps(DOC))
    leaky["arms"]["1.5"]["leak_free"] = False
    with pytest.raises(GateError):
        run_check(leaky, {"lhs": "arms.*.leak_free", "op": "truthy"})
    # interpolated lhs against a path rhs: best arm beats the baseline
    run_check(DOC, {"lhs": "arms.{best_factor}.DTPS", "op": ">",
                    "rhs": "arms.1.DTPS"})


def test_unknown_op_rejected():
    with pytest.raises(GateError):
        run_check(DOC, {"lhs": "speedup", "op": "~=", "rhs": 1.0})


# -------------------------------------------------------------- run_gate
def _write(tmp_path, name, obj):
    p = tmp_path / name
    p.write_text(json.dumps(obj))
    return str(p)


def test_run_gate_end_to_end(tmp_path):
    gates = _write(tmp_path, "gates.json", {
        "toy": {"artifact": "BENCH_toy.json",
                "checks": [{"lhs": "exact", "op": "truthy"},
                           {"lhs": "speedup", "op": ">=", "rhs": 1.2}]}})
    _write(tmp_path, "BENCH_toy.json", {"exact": True, "speedup": 1.3})
    assert run_gate("toy", gates, str(tmp_path)) == 2


def test_run_gate_missing_artifact_or_bench_fails(tmp_path):
    gates = _write(tmp_path, "gates.json", {
        "toy": {"artifact": "BENCH_toy.json",
                "checks": [{"lhs": "exact", "op": "truthy"}]},
        "hollow": {"artifact": "BENCH_hollow.json", "checks": []}})
    with pytest.raises(GateError, match="missing"):
        run_gate("toy", gates, str(tmp_path))          # artifact absent
    with pytest.raises(GateError, match="no gate"):
        run_gate("nope", gates, str(tmp_path))
    _write(tmp_path, "BENCH_hollow.json", {})
    with pytest.raises(GateError, match="no checks"):  # vacuous gate = fail
        run_gate("hollow", gates, str(tmp_path))


# ------------------------------------------------- checked-in gates.json
def _repo(*parts):
    return os.path.join(os.path.dirname(__file__), "..", *parts)


def test_checked_in_gates_cover_the_ci_matrix():
    """Every benchmark the CI matrix runs has a non-empty gate whose
    artifact matches what run.py registers for that bench."""
    with open(_repo("benchmarks", "gates.json")) as f:
        gates = json.load(f)
    expected = {"paged", "spec", "prefix", "preempt", "dedup", "kernels",
                "fleet", "adapters"}
    assert expected <= set(gates)
    for name in expected:
        assert gates[name]["checks"], f"gate {name} is vacuous"
        assert gates[name]["artifact"] == f"BENCH_{name}.json"
    from benchmarks.run import TABLES
    registered = {a for _, _, a in TABLES if a}
    assert {g["artifact"] for g in gates.values()} <= registered
    # the workflow itself references the same matrix (no silent drift)
    with open(_repo(".github", "workflows", "ci.yml")) as f:
        ci = f.read()
    assert ("[paged, spec, prefix, preempt, dedup, kernels, fleet, "
            "adapters]" in ci)
    assert "benchmarks/gate.py" in ci


def test_run_py_artifact_check():
    """run.py must flag a registered benchmark that wrote no artifact."""
    import time
    from benchmarks.run import check_artifact
    assert check_artifact(None, time.time()) == ""
    assert "wrote no" in check_artifact("BENCH_does_not_exist.json",
                                        time.time())
    probe = _repo("BENCH_paged.json")       # exists, but predates this run
    if os.path.exists(probe):
        assert "stale" in check_artifact(probe, time.time() + 1)
