"""int8 weight-only serving path (beyond-paper): numerics + layout."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.models import quant
from repro.models.model import init_cache, unified_forward
from repro.models.schema import init_params
from repro.models.stream import PFBatch, DECBatch, UnifiedBatch


@pytest.mark.parametrize("arch", ["llama3-8b", "mamba2-1.3b",
                                  "deepseek-v2-236b"])
def test_int8_forward_close_to_bf16(arch):
    cfg = get_reduced(arch)
    params = init_params(cfg, jax.random.PRNGKey(0))
    qparams = quant.quantize_params(cfg, params)
    assert quant.has_q8(qparams)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 12), 0, cfg.vocab)
    pf = PFBatch(tokens=toks, length=jnp.full((2,), 12),
                 adapter=jnp.full((2,), -1))
    a = unified_forward(cfg, params, UnifiedBatch(pf=pf),
                        cache=init_cache(cfg, 2, 16))
    b = unified_forward(cfg, qparams, UnifiedBatch(pf=pf),
                        cache=init_cache(cfg, 2, 16))
    # per-channel symmetric int8: small logit drift, same argmax
    assert float(jnp.abs(a.pf_logits - b.pf_logits).max()) < 0.2
    agree = (a.pf_logits.argmax(-1) == b.pf_logits.argmax(-1)).mean()
    assert float(agree) >= 0.5


def test_quant_roundtrip_error_bound():
    w = jax.random.normal(jax.random.PRNGKey(0), (64, 128)) * 0.05
    q = quant.quantize_leaf(w)
    w2 = quant.dequant_leaf(q, jnp.float32)
    # per-channel absmax/127 quantization error bound: scale/2 per element
    bound = np.asarray(q["_qs"])[0] / 2 + 1e-6
    err = np.abs(np.asarray(w - w2))
    assert (err <= bound).all()


def test_decode_with_quantized_cacheless_state():
    """Prefill+decode through the cache still matches full forward under
    int8 weights (the dequant-in-scan path is cache-transparent)."""
    cfg = get_reduced("llama3-8b")
    params = quant.quantize_params(cfg, init_params(cfg, jax.random.PRNGKey(0)))
    B, S = 1, 8
    toks = jax.random.randint(jax.random.PRNGKey(2), (B, S + 1), 0, cfg.vocab)
    base = jnp.full((B,), -1)
    out = unified_forward(cfg, params, UnifiedBatch(
        pf=PFBatch(tokens=toks[:, :S], length=jnp.full((B,), S), adapter=base)),
        cache=init_cache(cfg, B, 16))
    out2 = unified_forward(cfg, params, UnifiedBatch(
        dec=DECBatch(tokens=toks[:, S], pos=jnp.full((B,), S), adapter=base)),
        cache=out.cache)
    ref = unified_forward(cfg, params, UnifiedBatch(
        pf=PFBatch(tokens=toks, length=jnp.full((B,), S + 1), adapter=base)),
        cache=init_cache(cfg, B, 16))
    np.testing.assert_allclose(np.asarray(out2.dec_logits),
                               np.asarray(ref.pf_logits), rtol=2e-4, atol=2e-4)
