"""Content-hash block dedup + prefix-aware admission: chained block keys
(adapter- and context-pinned), probe/adoption semantics, stale-entry-free
de-publish on CoW/truncate, engine byte-exactness of dedup-on vs dedup-off
across mixed fine-tune/prefill/decode/verify batches (attn AND MLA), and the
scheduler's residency-scored admission with its starvation-proof fairness
ramp."""
import jax
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.core.lora import LoRAConfig
from repro.core.virtualization import AdapterStore, MixedLoraModel
from repro.data import datasets
from repro.models.schema import init_params
from repro.serving.engine import EngineConfig, UnifiedEngine
from repro.serving.kvcache import (CacheManager, PagedCacheManager,
                                   block_key, request_chain_keys)
from repro.serving.request import Request
from repro.serving.scheduler import Scheduler, SchedulerConfig
from repro.spec import SpecConfig
from repro.training.trainer import MixedLoraTrainer, TrainerConfig

LCFG = LoRAConfig(n_slots=4, r=4)


def _mgr(capacity=4, n_blocks=16, s_max=64, bs=8, **kw):
    cfg = get_reduced("llama3-8b")
    return PagedCacheManager(cfg, capacity, 2, s_max, block_size=bs,
                             n_blocks=n_blocks, **kw)


def _commit_full(m, slot):
    m.commit_prefill([(0, slot)], [m._seq_len[slot]])


# ------------------------------------------------------------- block keys
def test_block_key_pins_adapter_and_context():
    toks = np.arange(8)
    k = block_key("a", "", toks)
    assert k == block_key("a", "", toks)                  # deterministic
    assert k != block_key("b", "", toks)                  # adapter in key
    assert k != block_key("a", "parent", toks)            # context in key
    assert k != block_key("a", "", np.arange(1, 9))       # tokens in key


def test_chain_keys_cap_and_chaining():
    m = _mgr(bs=8)
    p = np.arange(17, dtype=np.int32)                     # 2 full + 1 token
    keys = m.chain_keys(p)
    assert len(keys) == 2
    # exactly-two-blocks prompt leaves >= 1 token uncached: only 1 key
    assert len(m.chain_keys(p[:16])) == 1
    assert len(m.chain_keys(p[:8])) == 0
    # the chain pins position: the SAME tokens in block 1 hash differently
    # than they would in block 0 (parent differs)
    pp = np.concatenate([p[8:16], p[8:16], [0]])
    assert m.chain_keys(pp)[1] != keys[1]
    # identical heads agree regardless of what follows
    assert m.chain_keys(np.concatenate([p[:16], [99, 98]]))[0] == keys[0]


def test_probe_is_side_effect_free():
    m = _mgr(bs=8)
    p = np.arange(20, dtype=np.int32)
    assert m.probe(p) == 0
    s, _ = m.try_admit(p, max_new=4)
    _commit_full(m, s)
    hits0 = m.hash_hits
    assert m.probe(p) == 16
    assert m.probe(p, adapter="other") == 0
    assert m.hash_hits == hits0                           # pure preview
    # divergence mid-chain: only the matching head counts
    q = np.concatenate([p[:8], np.full((12,), 7, np.int32)])
    assert m.probe(q) == 8
    # dedup off: probe reports nothing
    off = _mgr(bs=8, hash_dedup=False)
    s2, _ = off.try_admit(p, max_new=4)
    _commit_full(off, s2)
    assert off.probe(p) == 0 and off.hash_blocks_resident == 0


def test_depublish_on_truncate_and_cow_leaves_no_stale_entries():
    """Rolling back into a published block and rewriting it must fork the
    block (copy-on-write), never mutate the indexed payload: the index
    entry keeps naming the ORIGINAL block, the slot's chain shrinks, and a
    re-fill with different content publishes NEW keys."""
    m = _mgr(capacity=2, n_blocks=16, bs=8, s_max=64)
    p = np.arange(24, dtype=np.int32)
    s, _ = m.try_admit(p, max_new=24)
    _commit_full(m, s)                                    # publishes 2
    keys = list(m._chains[s])
    old_b1 = m.tables[s][1]
    assert m._index[keys[1]] == old_b1
    # spec-style rollback INTO block 1, then regrow with different tokens
    m.truncate(s, 10)
    assert m._chains[s] == keys[:1]                       # chain de-published
    m.prepare_write(s, 10, 8)                             # CoW's block 1
    new_b1 = m.tables[s][1]
    assert new_b1 != old_b1, "write would have mutated an indexed block"
    m.commit_tokens(s, np.full((8,), 9, np.int64))        # refill: 18 tokens
    # the old entry still names the old block (payload untouched), the new
    # content got a NEW key on the forked block
    assert m._index[keys[1]] == old_b1
    new_keys = m._chains[s]
    assert len(new_keys) == 2 and new_keys[1] != keys[1]
    assert m._index[new_keys[1]] == new_b1
    # both contents now adoptable: old via the original prompt, new via the
    # rewritten history
    assert m.probe(p) == 16
    assert m.probe(np.concatenate([p[:10], np.full((8,), 9), [0]])) == 16
    for key, bid in m._index.items():
        assert m._hashed[bid] == key
        assert m.allocator.ref[bid] >= 1


def test_publish_collision_keeps_incumbent():
    """Two slots that independently compute identical content must not both
    publish: the incumbent entry survives, the second copy stays private,
    and freeing the second slot frees its copy entirely."""
    m = _mgr(capacity=2, n_blocks=16, bs=8, hash_dedup=True)
    p = np.arange(20, dtype=np.int32)
    sa, _ = m.try_admit(p, max_new=4)
    sb, _ = m.try_admit(p, max_new=4)                     # nothing published
    _commit_full(m, sa)
    _commit_full(m, sb)                                   # collides: private
    assert m.hash_blocks_resident == 2                    # one entry per key
    key0 = m._chains[sa][0]
    assert m._index[key0] == m.tables[sa][0]
    assert m.tables[sb][0] not in m._hashed
    used = m.allocator.n_used
    m.free(sb)                                            # private copy dies
    assert m.allocator.n_used == used - len(m.tables[sa])


def test_shed_aging_stale_template_loses_to_warm_one():
    """Hit-count aging: each shed scan halves every entry's count AFTER
    victim selection, so a once-hot template that stopped being adopted
    decays toward zero under sustained pressure while a currently-warm
    template keeps its counts replenished — and eventually the stale one
    is evicted first DESPITE its historically higher raw hit total."""
    m = _mgr(capacity=4, n_blocks=10, bs=8)

    def publish(tmpl):
        s, _ = m.try_admit(tmpl, max_new=4)
        _commit_full(m, s)
        m.free(s)

    def adopt(tmpl):
        s, reused = m.try_admit(tmpl, max_new=4)
        assert reused == 8
        m.free(s)

    stale = np.arange(9, dtype=np.int32)                  # 1 full block
    warm = np.arange(50, 59, dtype=np.int32)
    publish(stale)
    for _ in range(8):
        adopt(stale)                                      # hits: stale = 8
    for i in range(4):                                    # zero-hit fillers
        publish(np.full((9,), 100 + i, np.int32))
    publish(warm)
    k_stale, k_warm = m.chain_keys(stale)[0], m.chain_keys(warm)[0]
    # four rounds of pressure: each shed evicts a zero-hit filler (warm
    # was just re-adopted, so it is never the zero-hit minimum), and the
    # post-selection decay halves stale 8 -> 4 -> 2 -> 1 -> 0
    for _ in range(4):
        adopt(warm)
        assert m._shed_one()
        assert k_stale in m._index and k_warm in m._index
    assert m._hits[k_stale] == 0
    adopt(warm)                                           # warm again: 1
    assert m._shed_one()                                  # the decisive scan
    assert k_stale not in m._index, "stale template should lose"
    assert k_warm in m._index, "warm template should survive"


def test_request_chain_keys_memoized_across_callers():
    """The shared per-request memo: router probe and engine admission must
    hash each prompt once between them — the second call returns the SAME
    list object — and the memo invalidates when preemption rolls emitted
    tokens into the prompt (prompt_len changes)."""
    r = Request(rid=0, prompt=np.arange(20, dtype=np.int32), adapter="a",
                max_new_tokens=4)
    k1 = request_chain_keys(r, 8)
    assert len(k1) == 2
    assert request_chain_keys(r, 8) is k1                 # memo hit
    m = _mgr(bs=8)
    assert k1 == m.chain_keys(r.prompt, "a")              # same chain
    # a different block size is a different chain — recomputed, not served
    # from the stale memo
    assert len(request_chain_keys(r, 4)) == 4
    # preemption rolls output into the prompt: longer prompt, fresh keys
    r.prompt = np.arange(30, dtype=np.int32)
    k2 = request_chain_keys(r, 8)
    assert k2 is not k1 and len(k2) == 3 and k2[:2] == k1


def test_dense_manager_commit_tokens_advances_length():
    cfg = get_reduced("llama3-8b")
    m = CacheManager(cfg, 2, 1, 64)
    slot = m.alloc()
    m.lens[slot] = 10
    m.commit_tokens(slot, [1, 2, 3])
    assert m.lens[slot] == 13


# ------------------------------------------------- engine byte-exactness
def _engine(cfg, seed=0, trainers=0, **kw):
    params = init_params(cfg, jax.random.PRNGKey(seed))
    store = AdapterStore(cfg, LCFG, jax.random.PRNGKey(seed + 1))
    store.load_random("serve", jax.random.PRNGKey(seed + 2))
    kw = {"capacity": 4, "pf_capacity": 2, "s_max": 96, "block_size": 16,
          "virtual_time": True, **kw}
    eng = UnifiedEngine(MixedLoraModel(cfg, params, store),
                        EngineConfig(**kw))
    for i in range(trainers):
        name = f"tr{i}"
        store.load_random(name, jax.random.PRNGKey(seed + 10 + i))
        rows, ev = datasets.split_eval(
            datasets.alpaca_like(12, vocab=cfg.vocab, seed=i))
        eng.add_trainer(MixedLoraTrainer(name, store.slot_of(name), rows, ev,
                                         TrainerConfig(rows_per_micro=2,
                                                       accum_steps=2,
                                                       epochs=1)))
    return eng


def _shared_reqs(cfg, n=5, max_new=6, seed=0):
    head = np.arange(32, dtype=np.int32) % cfg.vocab
    rng = np.random.default_rng(seed)
    return [Request(rid=i,
                    prompt=np.concatenate([head, rng.integers(
                        0, cfg.vocab, rng.integers(4, 12))
                        .astype(np.int32)]),
                    adapter="serve", max_new_tokens=max_new,
                    arrival=0.25 * i) for i in range(n)]


def _run(eng, reqs, max_ticks=8000):
    for r in reqs:
        eng.submit(r)
    eng.run(max_ticks=max_ticks)
    return {r.rid: list(r.output) for r in eng.finished}


@pytest.mark.parametrize("arch", ["llama3-8b", "deepseek-v2-236b"])
def test_hash_adoption_matches_explicit_reuse_span(arch):
    """Hash-chain adoption must equal what explicit prefix registration
    used to deliver, byte-for-byte AND span-for-span (attn + MLA): every
    request after the first reuses the full shared head (2 blocks of 16),
    exactly the span an explicit prefix_id registration granted."""
    cfg = get_reduced(arch)
    n = 5
    ref = _run(_engine(cfg, hash_dedup=False), _shared_reqs(cfg, n=n))
    eng = _engine(cfg)
    out = _run(eng, _shared_reqs(cfg, n=n))
    assert out == ref and len(out) == n
    # the explicit-registry contract: requests 2..n each reuse the entire
    # 32-token registered head — adoption must serve exactly that span
    assert eng.metrics.reused_prefix_tokens == 32 * (n - 1)
    assert eng.metrics.hash_hits == 2 * (n - 1)


def test_dedup_exact_across_mixed_ft_prefill_decode_verify():
    """One engine co-running fine-tune rows, chunked prefill, plain decode
    and speculative verify chunks: dedup on vs off must be byte-identical
    while actually deduping."""
    cfg = get_reduced("llama3-8b")

    def mk():
        return _shared_reqs(cfg, n=5, max_new=10)

    ref = _run(_engine(cfg, hash_dedup=False, trainers=1, prefill_chunk=16,
                       spec=SpecConfig(k_max=3, drafter="ngram")), mk())
    eng = _engine(cfg, trainers=1, prefill_chunk=16,
                  spec=SpecConfig(k_max=3, drafter="ngram"))
    out = _run(eng, mk())
    assert out == ref and len(out) == 5
    assert eng.metrics.hash_hits > 0
    assert all(not t.pending() for t in eng.trainers.values())
    assert eng.cachemgr.pristine


def test_dedup_with_preemption_exact_and_stale_free():
    """Over-admission preemption on top of dedup: byte-identical outputs,
    and the index never holds a stale or dangling entry afterwards."""
    cfg = get_reduced("llama3-8b")
    rng = np.random.default_rng(11)
    head = np.arange(16, dtype=np.int32)

    def mk():
        return [Request(rid=i,
                        prompt=np.concatenate([head, rng.integers(
                            0, cfg.vocab, 4).astype(np.int32)]),
                        adapter="serve", max_new_tokens=40,
                        arrival=0.1 * i) for i in range(3)]

    rng = np.random.default_rng(11)
    ref = _run(_engine(cfg, n_blocks=12, hash_dedup=False,
                       over_admit=2.0), mk())
    rng = np.random.default_rng(11)
    eng = _engine(cfg, n_blocks=12, over_admit=2.0)
    out = _run(eng, mk())
    assert out == ref and len(out) == 3
    m = eng.cachemgr
    for key, bid in m._index.items():
        assert m._hashed[bid] == key
        assert m.allocator.ref[bid] >= 1
        assert bid not in set(m.allocator._free)
    assert m.pristine


def test_aux_embed_requests_never_share():
    """Modality-embedding requests must neither adopt nor publish:
    identical tokens under different aux embeddings have different K/V, a
    distinction the (adapter, tokens) content identity cannot capture."""
    cfg = get_reduced("llama3-8b")
    eng = _engine(cfg)
    p = np.arange(40, dtype=np.int32)
    aux = np.zeros((4, cfg.d_model), np.float32)
    b = Request(rid=1, prompt=p.copy(), adapter="serve", max_new_tokens=2,
                aux_embed=aux)
    assert eng._keys_of(b) is None                        # no chain at all
    assert eng._resident_tokens(b) == 0
    # manager level: a shareable sibling published the same tokens...
    m = eng.cachemgr
    s, _ = m.try_admit(p, max_new=2)
    _commit_full(m, s)
    assert m.probe(p) == 32                               # resident
    # ...but an unshareable admission must not adopt it, and its own
    # commits must not publish
    s2, reused = m.try_admit(p, max_new=2, shareable=False)
    assert reused == 0
    assert m.shared_count[s2] == 0
    resident_before = m.hash_blocks_resident
    m.commit_prefill([(0, s2)], [len(p)])
    assert m.hash_blocks_resident == resident_before


# --------------------------------------------- prefix-aware admission
def test_scheduler_prefers_resident_prefixes():
    """With block budget for one admit, the high-residency request jumps
    the FIFO queue (and the jump is counted as a probe admission)."""
    sched = Scheduler(SchedulerConfig(max_prefill_per_tick=1), capacity=8)
    cold = Request(rid=0, prompt=np.zeros((64,), np.int32), adapter="",
                   arrival=0.0)
    hot = Request(rid=1, prompt=np.ones((64,), np.int32), adapter="",
                  arrival=0.1)
    resid = {0: 0, 1: 48}
    d = sched.decide([cold, hot], 0, 8, 4, False, free_blocks=100,
                     total_blocks=100, block_size=16, s_max=256,
                     probe_fn=lambda r: resid[r.rid], now=0.2)
    assert [r.rid for r in d.admit] == [1]
    assert d.probe_admissions == 1


def test_scheduler_fairness_ramp_prevents_starvation():
    """A zero-residency request waiting past the ramp outranks EVERY fresh
    fully-resident arrival: its score saturates at 1.0, strictly above any
    residency fraction (at least one prompt token is never cached)."""
    cfg = SchedulerConfig(max_prefill_per_tick=1, prefix_ramp_s=1.0)
    sched = Scheduler(cfg, capacity=8)
    cold = Request(rid=0, prompt=np.zeros((64,), np.int32), adapter="",
                   arrival=0.0)
    now = 0.0
    admitted_at = None
    waiting = [cold]
    for tick in range(20):
        now += 0.25
        # a fresh maximal-residency competitor arrives every tick
        waiting.append(Request(rid=100 + tick,
                               prompt=np.ones((64,), np.int32), adapter="",
                               arrival=now))
        d = sched.decide(waiting, 0, 8, 4, False, free_blocks=1000,
                         total_blocks=1000, block_size=16, s_max=256,
                         probe_fn=lambda r: 0 if r.rid == 0 else 48,
                         now=now)
        assert len(d.admit) == 1
        got = d.admit[0]
        waiting.remove(got)
        if got.rid == 0:
            admitted_at = now
            break
    assert admitted_at is not None, "cold request starved"
    # admitted at the first decision after its wait crossed the ramp
    assert admitted_at - cold.arrival <= cfg.prefix_ramp_s + 0.25


def test_engine_counts_probe_admissions():
    """End-to-end: when a hot-prefix request arrives behind a cold one and
    the pool only fits one of them, the hot one is admitted first and the
    reorder lands in Metrics.probe_admissions."""
    cfg = get_reduced("llama3-8b")
    eng = _engine(cfg, n_blocks=13, s_max=64,
                  scheduler=SchedulerConfig(max_prefill_per_tick=1,
                                            prefix_ramp_s=5.0))
    head = np.arange(32, dtype=np.int32)
    rng = np.random.default_rng(0)
    first = Request(rid=0, prompt=np.concatenate(
        [head, rng.integers(0, cfg.vocab, 4).astype(np.int32)]),
        adapter="serve", max_new_tokens=28, arrival=0.0)
    cold = Request(rid=1, prompt=rng.integers(100, cfg.vocab, 36)
                   .astype(np.int32), adapter="serve", max_new_tokens=28,
                   arrival=0.5)
    # same arrival as cold: FIFO (rid order) would admit cold first; the
    # residency score must flip that
    hot = Request(rid=2, prompt=np.concatenate(
        [head, rng.integers(0, cfg.vocab, 4).astype(np.int32)]),
        adapter="serve", max_new_tokens=28, arrival=0.5)
    out = _run(eng, [first, cold, hot])
    assert len(out) == 3
    assert eng.metrics.probe_admissions >= 1
    # the hot request overtook the cold one into the prefill bucket
    assert hot.t_first_token < cold.t_first_token
