"""SSD/Mamba2 and MoE substrate correctness."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyputil import given, hyp as _hyp, settings, st

from repro.models.configs import MoEConfig
from repro.models.mamba import causal_conv, ssd_chunked, ssd_step
from repro.models.moe import moe_apply, moe_apply_dense_ref


def _ssd_inputs(seed, B, S, nh, hd, ds):
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    x = jax.random.normal(ks[0], (B, S, nh, hd)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, nh)))
    A = -jnp.exp(jax.random.normal(ks[2], (nh,)) * 0.3)
    Bm = jax.random.normal(ks[3], (B, S, nh, ds)) * 0.3
    Cm = jax.random.normal(ks[4], (B, S, nh, ds)) * 0.3
    return x, dt, A, Bm, Cm


@_hyp(lambda: [settings(max_examples=12, deadline=None),
               given(S=st.integers(1, 40), chunk=st.sampled_from([4, 8, 16]),
                     seed=st.integers(0, 100))])
def test_ssd_chunked_equals_stepwise(S, chunk, seed):
    B, nh, hd, ds = 2, 3, 8, 8
    x, dt, A, Bm, Cm = _ssd_inputs(seed, B, S, nh, hd, ds)
    y_c, h_c = ssd_chunked(x, dt, A, Bm, Cm, chunk)
    h = jnp.zeros((B, nh, hd, ds))
    ys = []
    for t in range(S):
        y_t, h = ssd_step(h, x[:, t], dt[:, t], A, Bm[:, t], Cm[:, t])
        ys.append(y_t)
    np.testing.assert_allclose(np.asarray(y_c), np.asarray(jnp.stack(ys, 1)),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(h_c), np.asarray(h),
                               rtol=2e-4, atol=2e-4)


def test_ssd_initial_state_continuation():
    """Splitting a sequence and carrying state == processing it whole."""
    B, S, nh, hd, ds, Q = 1, 24, 2, 4, 8, 8
    x, dt, A, Bm, Cm = _ssd_inputs(7, B, S, nh, hd, ds)
    y_all, h_all = ssd_chunked(x, dt, A, Bm, Cm, Q)
    cut = 16
    y1, h1 = ssd_chunked(x[:, :cut], dt[:, :cut], A, Bm[:, :cut],
                         Cm[:, :cut], Q)
    y2, h2 = ssd_chunked(x[:, cut:], dt[:, cut:], A, Bm[:, cut:],
                         Cm[:, cut:], Q, h0=h1)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], 1)),
                               np.asarray(y_all), rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(h2), np.asarray(h_all),
                               rtol=2e-4, atol=2e-4)


def test_ssd_pad_tokens_are_noops():
    """dt=0 on pad tokens leaves the state untouched."""
    B, S, nh, hd, ds, Q = 1, 16, 2, 4, 8, 8
    x, dt, A, Bm, Cm = _ssd_inputs(9, B, S, nh, hd, ds)
    valid = jnp.arange(S) < 10
    dt_m = dt * valid[None, :, None]
    _, h_m = ssd_chunked(x, dt_m, A, Bm, Cm, Q)
    _, h_trunc = ssd_chunked(x[:, :10], dt[:, :10], A, Bm[:, :10],
                             Cm[:, :10], Q)
    np.testing.assert_allclose(np.asarray(h_m), np.asarray(h_trunc),
                               rtol=2e-4, atol=2e-4)


def test_causal_conv_step_equals_seq():
    B, S, C, K = 2, 12, 6, 4
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    x = jax.random.normal(ks[0], (B, S, C))
    w = jax.random.normal(ks[1], (K, C))
    b = jax.random.normal(ks[2], (C,)) * 0.1
    y_seq, state_seq = causal_conv(x, w, b, None)
    state = jnp.zeros((B, K - 1, C))
    ys = []
    for t in range(S):
        y_t, state = causal_conv(x[:, t:t + 1], w, b, state)
        ys.append(y_t)
    np.testing.assert_allclose(np.asarray(jnp.concatenate(ys, 1)),
                               np.asarray(y_seq), rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(state), np.asarray(state_seq),
                               rtol=1e-5, atol=1e-5)


def _moe_params(seed, d, E, f):
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    return {"router": jax.random.normal(ks[0], (d, E)) * 0.02,
            "w_gate": jax.random.normal(ks[1], (E, d, f)) * 0.05,
            "w_up": jax.random.normal(ks[2], (E, d, f)) * 0.05,
            "w_down": jax.random.normal(ks[3], (E, f, d)) * 0.05}


def test_moe_matches_dense_oracle_with_headroom():
    T, d, E, f = 128, 16, 4, 32
    m = MoEConfig(num_experts=E, top_k=2, d_ff_expert=f, capacity_factor=8.0)
    params = _moe_params(0, d, E, f)
    x = jax.random.normal(jax.random.PRNGKey(9), (T, d))
    y, aux = moe_apply(x, params, m)
    yr = moe_apply_dense_ref(x, params, m)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                               rtol=1e-4, atol=1e-4)
    assert float(aux) > 0


def test_moe_capacity_drops_bounded():
    """With cf=1.0 some tokens drop, but outputs stay finite and the drop
    fraction is bounded by the imbalance."""
    T, d, E, f = 256, 16, 8, 32
    m = MoEConfig(num_experts=E, top_k=2, d_ff_expert=f, capacity_factor=1.0)
    params = _moe_params(1, d, E, f)
    x = jax.random.normal(jax.random.PRNGKey(10), (T, d))
    y, _ = moe_apply(x, params, m)
    assert bool(jnp.isfinite(y).all())


def test_moe_grouping_invariance():
    """Grouped dispatch with generous capacity == dense oracle regardless of
    group count (GROUP_TOKENS boundary behaviour)."""
    import repro.models.moe as moe_mod
    T, d, E, f = 96, 8, 4, 16
    m = MoEConfig(num_experts=E, top_k=1, d_ff_expert=f, capacity_factor=8.0)
    params = _moe_params(2, d, E, f)
    x = jax.random.normal(jax.random.PRNGKey(11), (T, d))
    old = moe_mod.GROUP_TOKENS
    try:
        moe_mod.GROUP_TOKENS = 32
        y_g, _ = moe_apply(x, params, m)
    finally:
        moe_mod.GROUP_TOKENS = old
    yr = moe_apply_dense_ref(x, params, m)
    np.testing.assert_allclose(np.asarray(y_g), np.asarray(yr),
                               rtol=1e-4, atol=1e-4)
