"""Distribution-path tests.  These run in SUBPROCESSES because they need
``--xla_force_host_platform_device_count`` which must be set before jax
initialises (and must NOT leak into the rest of the suite)."""
import os
import subprocess
import sys
import textwrap

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run(code: str, devices: int = 8, timeout: int = 420):
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + f" --xla_force_host_platform_device_count={devices}")
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, timeout=timeout,
                         env=env)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def test_shardmap_moe_matches_single_device():
    """Explicit expert-parallel MoE (shard_map + all-to-all) must equal the
    single-device grouped-vmap path bit-for-bit."""
    out = _run("""
        import jax, jax.numpy as jnp
        from repro.models.moe import moe_apply
        from repro.models.moe_dist import moe_apply_auto
        from repro.models.configs import MoEConfig
        ks = jax.random.split(jax.random.PRNGKey(0), 5)
        T, d, E, f = 256, 32, 8, 48
        m = MoEConfig(num_experts=E, top_k=2, d_ff_expert=f,
                      capacity_factor=8.0)
        params = {"router": jax.random.normal(ks[0], (d, E)) * 0.02,
                  "w_gate": jax.random.normal(ks[1], (E, d, f)) * 0.05,
                  "w_up": jax.random.normal(ks[2], (E, d, f)) * 0.05,
                  "w_down": jax.random.normal(ks[3], (E, f, d)) * 0.05}
        x = jax.random.normal(ks[4], (T, d))
        y_ref, aux_ref = moe_apply(x, params, m)
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        from repro.distributed.sharding import mesh_context
        with mesh_context(mesh):
            y, aux = jax.jit(lambda a, b: moe_apply_auto(a, b, m,
                                                         fsdp=False))(x, params)
        err = float(jnp.abs(y - y_ref).max())
        assert err < 1e-5, err
        assert abs(float(aux - aux_ref)) < 1e-6
        print("OK", err)
    """)
    assert "OK" in out


def test_unified_forward_under_mesh_matches_single_device():
    """The whole unified forward (reduced MoE+attn arch) sharded over a 2x4
    mesh must match the unsharded result."""
    out = _run("""
        import jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        import dataclasses
        from repro.configs import get_reduced
        from repro.models.schema import init_params
        from repro.models.model import unified_forward, init_cache
        from repro.models.stream import PFBatch, UnifiedBatch
        cfg = get_reduced("llama4-maverick-400b-a17b")
        # generous capacity: the shard_map path packs per LOCAL shard, so a
        # tight capacity factor drops different tokens than the global pack
        cfg = cfg.replace(moe=dataclasses.replace(cfg.moe,
                                                  capacity_factor=8.0))
        params = init_params(cfg, jax.random.PRNGKey(0))
        toks = jax.random.randint(jax.random.PRNGKey(1), (4, 16), 0, cfg.vocab)
        pf = PFBatch(tokens=toks, length=jnp.full((4,), 16),
                     adapter=jnp.full((4,), -1))
        ref = unified_forward(cfg, params, UnifiedBatch(pf=pf),
                              cache=init_cache(cfg, 4, 32))
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        from repro.distributed.sharding import mesh_context
        with mesh_context(mesh):
            got = jax.jit(lambda p, b, c: unified_forward(cfg, p, b, c))(
                params, UnifiedBatch(pf=pf), init_cache(cfg, 4, 32))
        err = float(jnp.abs(got.pf_logits - ref.pf_logits).max())
        assert err < 2e-4, err
        print("OK", err)
    """)
    assert "OK" in out


@pytest.mark.slow
def test_dryrun_lowers_whisper_long_context():
    """End-to-end dry-run smoke: lower+compile one real combo on the
    512-device production mesh inside a subprocess."""
    out = _run("""
        import sys
        sys.argv = ["dryrun", "--arch", "whisper-base", "--shape",
                    "long_500k", "--out", "/tmp/dryrun_test_ci"]
        import shutil; shutil.rmtree("/tmp/dryrun_test_ci", ignore_errors=True)
        import runpy
        try:
            runpy.run_module("repro.launch.dryrun", run_name="__main__")
        except SystemExit as e:
            assert e.code in (0, None), e.code
        import json, glob
        rec = json.load(open(glob.glob("/tmp/dryrun_test_ci/*.json")[0]))
        assert rec["status"] == "ok", rec
        assert rec["chips"] == 256
        print("OK compile_s", rec["compile_s"])
    """, devices=512, timeout=560)
    assert "OK" in out
