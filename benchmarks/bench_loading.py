"""Table 2 — model loading: time + ADDITIONAL storage footprint.

Loquetier virtualizes in place (0 B extra); a FlexLLM-like system must write
a transformed copy of the base weights to disk before it can serve."""
from __future__ import annotations

import os
import tempfile
import time

import jax

from benchmarks.common import LCFG, csv
from repro.checkpoint import io
from repro.configs import get_reduced
from repro.core.virtualization import AdapterStore
from repro.models.schema import init_params


def main(arch: str = "llama3-8b"):
    cfg = get_reduced(arch)
    t0 = time.monotonic()
    params = init_params(cfg, jax.random.PRNGKey(0))
    jax.block_until_ready(params["embed"])
    t_base = time.monotonic() - t0

    # Loquetier: virtualize + load one LoRA (0 B extra storage)
    t0 = time.monotonic()
    store = AdapterStore(cfg, LCFG, jax.random.PRNGKey(1))
    store.load_random("a", jax.random.PRNGKey(2))
    jax.block_until_ready(store.bank)
    t_lora = time.monotonic() - t0

    # FlexLLM-like: transform + cache base weights on disk first
    with tempfile.TemporaryDirectory() as td:
        path = os.path.join(td, "transformed.npz")
        t0 = time.monotonic()
        nbytes = io.save_pytree(path, params)
        _ = io.load_pytree(path, params)
        t_flex = time.monotonic() - t0

    csv("loading/loquetier_base_s", t_base * 1e6, f"storage_extra_B=0")
    csv("loading/loquetier_lora_s", t_lora * 1e6, f"storage_extra_B=0")
    csv("loading/flexllm_like_transform_s", t_flex * 1e6,
        f"storage_extra_B={nbytes}")
    csv("loading/speedup", 0.0,
        f"loquetier_total={t_base + t_lora:.3f}s_vs_flex={t_base + t_flex:.3f}s")


if __name__ == "__main__":
    main()
