"""Figure 6 — simulated real-world workload: BurstGPT-like slices (Table 8
statistics) replayed against the unified runtime with a co-running
fine-tuning job.  Paper result: 92.37% overall SLO, misses only inside
transient >5 RPS spikes."""
from __future__ import annotations

from benchmarks.common import SLO, build_engine, build_model, csv, slo_attainment
from repro.data import datasets, workload
from repro.serving.request import Request
from repro.training.trainer import MixedLoraTrainer, TrainerConfig

PERIODS = ("d29_13h", "d29_15h", "d33_1140")   # low / high / high load


def main(scale: float = 0.06, duration: float = 90.0, max_new: int = 8):
    for period in PERIODS:
        model = build_model(n_adapters=4)
        vocab = model.cfg.vocab
        eng = build_engine(model)
        arr = workload.burstgpt_like(period, duration=duration, seed=3,
                                     scale=scale * 20)
        arr = arr[arr < duration]
        prompts = datasets.sharegpt_prompts(len(arr), vocab=vocab, seed=5)
        for i, (t, p) in enumerate(zip(arr, prompts)):
            eng.submit(Request(rid=i, prompt=p, adapter=f"lora{i % 3}",
                               max_new_tokens=max_new, arrival=float(t)))
        rows, ev = datasets.split_eval(datasets.alpaca_like(200, vocab=vocab))
        eng.add_trainer(MixedLoraTrainer("lora3", model.store.slot_of("lora3"),
                                         rows, ev,
                                         TrainerConfig(rows_per_micro=2,
                                                       accum_steps=4,
                                                       epochs=2)))
        m = eng.run(max_ticks=500000)
        att = slo_attainment(eng.finished, SLO)
        st = workload.BURSTGPT_PERIODS[period]
        csv(f"realworld/{period}", 0.0,
            f"SLO={att:.3f};n={len(eng.finished)};"
            f"mean_rps={len(arr)/duration:.2f};"
            f"paper_mean_rps={st['mean_rps']:.2f};"
            f"FTPS={m.rates()['FTPS']:.0f};DTPS={m.rates()['DTPS']:.0f}")


if __name__ == "__main__":
    main()
