"""Figure 2 — inference-only: SLO attainment + decode throughput vs request
rate, single- and multi-LoRA, Loquetier vs the PEFT-like baseline."""
from __future__ import annotations

from benchmarks.common import (PeftLikeServer, build_model, csv,
                               make_requests, run_engine_inference,
                               slo_attainment, SLO)


def main(rates=(1.0, 2.0, 3.0, 4.0), n_per_rps: int = 15, max_new: int = 48):
    model = build_model(n_adapters=4)
    vocab = model.cfg.vocab
    for multi, n_ad in (("single", 1), ("multi", 4)):
        for rps in rates:
            n = int(n_per_rps * rps)
            reqs = make_requests(n, rps, vocab, n_ad, max_new=max_new,
                                 seed=int(rps * 10))
            res = run_engine_inference(model, reqs, capacity=16)
            csv(f"inference/loquetier_{multi}_rps{rps:g}",
                res["wall"] / max(res["finished"], 1) * 1e6,
                f"SLO={res['slo']:.3f};DTPS={res['DTPS']:.1f}")
            # PEFT-like baseline on the identical request stream
            reqs2 = make_requests(n, rps, vocab, n_ad, max_new=max_new,
                                  seed=int(rps * 10))
            done, stats = PeftLikeServer().serve(reqs2)
            csv(f"inference/peft_like_{multi}_rps{rps:g}", 0.0,
                f"SLO={slo_attainment(done, SLO):.3f};"
                f"DTPS={stats['DTPS']:.1f}")


if __name__ == "__main__":
    main()
