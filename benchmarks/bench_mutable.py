"""Figure 5 — mutable capacity allocation: fine-tuning concedes to inference
load spikes and recovers, per the Table 7 phase schedule (scaled)."""
from __future__ import annotations

import numpy as np

from benchmarks.common import SLO, build_engine, build_model, csv, slo_attainment
from repro.data import datasets, workload
from repro.serving.request import Request
from repro.training.trainer import MixedLoraTrainer, TrainerConfig


def main(time_scale: float = 0.1, max_new: int = 8):
    """time_scale compresses the 420 s schedule for CPU runs."""
    model = build_model(n_adapters=4)
    vocab = model.cfg.vocab
    eng = build_engine(model)
    arrivals = workload.phased_arrivals(workload.MUTABLE_PHASES, seed=0)
    prompts = datasets.sharegpt_prompts(len(arrivals), vocab=vocab, seed=0)
    for i, ((t, ad), p) in enumerate(zip(arrivals, prompts)):
        eng.submit(Request(rid=i, prompt=p, adapter=f"lora{ad}",
                           max_new_tokens=max_new,
                           arrival=float(t) * time_scale))
    rows, ev = datasets.split_eval(datasets.alpaca_like(400, vocab=vocab))
    eng.add_trainer(MixedLoraTrainer("lora0", model.store.slot_of("lora0"),
                                     rows, ev,
                                     TrainerConfig(rows_per_micro=2,
                                                   accum_steps=4, epochs=4)))
    # sample FTPS/DTPS over time windows while running
    window = 60.0 * time_scale
    samples = []
    last = (0, 0, 0.0)
    while True:
        busy = eng.tick()
        now = eng.clock.now()
        if now - last[2] >= window:
            d_ft = eng.metrics.finetune_tokens - last[0]
            d_dec = eng.metrics.decode_tokens - last[1]
            dt = now - last[2]
            samples.append((now, d_ft / dt, d_dec / dt))
            last = (eng.metrics.finetune_tokens, eng.metrics.decode_tokens,
                    now)
        drained = (not eng.waiting and not eng.active and not eng.future)
        if drained or len(samples) > 60:
            break
    att = slo_attainment(eng.finished, SLO)
    csv("mutable/slo", 0.0, f"SLO={att:.3f};finished={len(eng.finished)}")
    ftps = [s[1] for s in samples]
    if ftps:
        lo_idx = int(np.argmin(ftps))
        csv("mutable/concession", 0.0,
            f"ftps_min={min(ftps):.0f}@t={samples[lo_idx][0]:.1f};"
            f"ftps_max={max(ftps):.0f};"
            f"recovers={'yes' if ftps[-1] > min(ftps) else 'no'}")
    for t, f, d in samples:
        csv("mutable/timeline", 0.0, f"t={t:.1f};FTPS={f:.0f};DTPS={d:.0f}")


if __name__ == "__main__":
    main()
