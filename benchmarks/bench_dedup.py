"""Content-hash block dedup benchmark.

Templated traffic (one hot system/few-shot prefix + per-request tail) over
the SAME substrate, virtual-clock cost model (prefill-bound regime) and
EQUAL HBM budget (same block pool in every arm):

* ``plain``  — ``hash_dedup=False`` escape hatch: every request recomputes
  and re-stores its whole prompt.
* ``dedup``  — content-hash index: the first request publishes its full
  blocks at commit, every later request adopts them at admission (no id,
  no sighting threshold) and prefills suffix-only.

Exactness is asserted FIRST (byte-identical outputs), then the headline:
prompt tokens per second and the hash hit rate (adopted / addressable full
blocks).  The JSON also carries ``auto_prefix_equiv`` — the throughput the
subsumed two-sighting ``auto_prefix`` heuristic would have reached on this
trace, computed from the SAME measured run and cost model (reuse began at
the THIRD sighting and was capped at its default 4 hashed blocks; the
skipped span rebate is ``prefill_per_tok`` per token, exactly what the
virtual clock charges) — the CI gate asserts the hash index beats it at
equal HBM.  A preemption arm exercises dedup x over-admission: a preempted
victim re-adopts its own published blocks, so recompute shrinks and outputs
stay byte-identical.

Emits ``BENCH_dedup.json`` for the run.py harness / CI gate (gate.py +
gates.json).
"""
from __future__ import annotations

import json

import numpy as np

from benchmarks.common import build_model, csv
from repro.serving.clock import CostModel
from repro.serving.engine import EngineConfig, UnifiedEngine
from repro.serving.request import Request

COST = CostModel(prefill_per_tok=1e-4)     # prefill-bound serving regime
PROMPT = 1024
PREFIX = 832                               # 26 blocks of 32 -> 81.25% share
BLOCK = 32
N_REQUESTS = 6
AUTO_PREFIX_BLOCKS = 4                     # the subsumed heuristic's cap


def _requests(vocab: int, n: int, seed: int) -> list:
    """Templated prompts: one hot prefix + per-request tail.  The first
    request arrives alone so its commit publishes the head before the rest
    admit."""
    rng = np.random.default_rng(seed)
    prefix = rng.integers(0, vocab, PREFIX).astype(np.int32)
    out = []
    for i in range(n):
        tail = rng.integers(0, vocab, PROMPT - PREFIX).astype(np.int32)
        out.append(Request(rid=i, prompt=np.concatenate([prefix, tail]),
                           adapter="lora0", max_new_tokens=1,
                           arrival=0.0 if i == 0 else 0.3))
    return out


def _engine(model, **kw):
    kw = {"capacity": 6, "pf_capacity": 4, "s_max": PROMPT + BLOCK,
          "block_size": BLOCK, "virtual_time": True, "cost": COST, **kw}
    return UnifiedEngine(model, EngineConfig(**kw))


def _run_arm(model, reqs, **kw):
    eng = _engine(model, **kw)
    for r in reqs:
        eng.submit(r)
    eng.run(max_ticks=100000)
    m = eng.metrics
    mgr = eng.cachemgr
    prompt_tok = m.prefill_tokens + m.reused_prefix_tokens
    return {"prompt_tokens": int(prompt_tok),
            "computed_tokens": int(m.prefill_tokens),
            "reused_tokens": int(m.reused_prefix_tokens),
            "hash_hits": int(m.hash_hits),
            "hash_blocks_resident": int(m.hash_blocks_resident),
            "elapsed_virtual": float(m.elapsed),
            "PTPS": prompt_tok / max(m.elapsed, 1e-9),
            "steps": int(m.steps),
            "preemptions": int(m.preemptions),
            "leak_free": bool(mgr.pristine),
            "outputs": {r.rid: list(r.output) for r in eng.finished},
            "finished": len(eng.finished)}


def _strip(d):
    return {k: v for k, v in d.items() if k != "outputs"}


def _auto_prefix_equiv(plain, dedup):
    """The subsumed two-sighting heuristic, replayed analytically on the
    measured trace: requests 3..n would have reused at most
    ``AUTO_PREFIX_BLOCKS`` leading blocks each (requests 1 AND 2 compute
    everything — the second sighting only *registers*), so its elapsed time
    is the plain arm's minus the rebate the virtual clock charges per
    skipped prefill token."""
    reuse_auto = max(N_REQUESTS - 2, 0) * min(PREFIX,
                                              AUTO_PREFIX_BLOCKS * BLOCK)
    elapsed = plain["elapsed_virtual"] - reuse_auto * COST.prefill_per_tok
    return {"reused_tokens": int(reuse_auto),
            "elapsed_virtual": float(elapsed),
            "PTPS": plain["prompt_tokens"] / max(elapsed, 1e-9),
            "note": "two-sighting auto_prefix heuristic replayed on the "
                    "measured plain arm (reuse from 3rd sighting, capped "
                    "at 4 blocks) — subsumed by the hash index"}


def _preempt_resume_arm(model):
    """dedup x over-admission: force lending-driven preemption and check
    the victim re-adopts its own published blocks (recompute < a full
    re-prefill) with byte-identical outputs."""
    def reqs(vocab):
        rng = np.random.default_rng(3)
        head = rng.integers(0, vocab, 16).astype(np.int32)
        return [Request(rid=i, prompt=np.concatenate(
                    [head, rng.integers(0, vocab, 4).astype(np.int32)]),
                    adapter="lora0", max_new_tokens=40, arrival=0.05 * i)
                for i in range(3)]

    # 9 usable blocks: even WITH the shared head deduped (3 x 4-block lives
    # minus 2 adopted = 10 distinct) the pool is one block short, so a lent
    # reservation must come due and preempt
    base = _engine(model, capacity=4, s_max=96, block_size=16, n_blocks=10,
                   hash_dedup=False)
    over = _engine(model, capacity=4, s_max=96, block_size=16, n_blocks=10,
                   over_admit=2.0)
    outs = []
    for eng in (base, over):
        for r in reqs(model.cfg.vocab):
            eng.submit(r)
        eng.run(max_ticks=100000)
        outs.append({r.rid: list(r.output) for r in eng.finished})
    assert outs[0] == outs[1], "dedup x preemption broke exactness"
    m = over.metrics
    return {"preemptions": int(m.preemptions),
            "recomputed_tokens": int(m.preempted_tokens_recomputed),
            "hash_hits": int(m.hash_hits),
            "leak_free": bool(over.cachemgr.pristine),
            "exact": True}


def _admission_arm(model):
    """Prefix-aware admission: a cold and a hot request contend for one
    admission slot per tick; the hot one (head resident from the first
    request) must be reordered ahead of FIFO, and the reorder must land in
    ``Metrics.probe_admissions``."""
    from repro.serving.scheduler import SchedulerConfig
    eng = _engine(model, capacity=4, s_max=64, block_size=16, n_blocks=13,
                  scheduler=SchedulerConfig(max_prefill_per_tick=1,
                                            prefix_ramp_s=5.0))
    vocab = model.cfg.vocab
    head = np.arange(32, dtype=np.int32) % vocab
    rng = np.random.default_rng(0)
    first = Request(rid=0, prompt=np.concatenate(
        [head, rng.integers(0, vocab, 4).astype(np.int32)]),
        adapter="lora0", max_new_tokens=24, arrival=0.0)
    cold = Request(rid=1, prompt=rng.integers(0, vocab, 36)
                   .astype(np.int32), adapter="lora0", max_new_tokens=24,
                   arrival=0.5)
    hot = Request(rid=2, prompt=np.concatenate(
        [head, rng.integers(0, vocab, 4).astype(np.int32)]),
        adapter="lora0", max_new_tokens=24, arrival=0.5)
    for r in (first, cold, hot):
        eng.submit(r)
    eng.run(max_ticks=100000)
    assert len(eng.finished) == 3
    return {"probe_admissions": int(eng.metrics.probe_admissions),
            "hot_overtook_cold": bool(hot.t_first_token
                                      < cold.t_first_token)}


def main(n_requests: int = N_REQUESTS):
    model = build_model(n_adapters=1)
    vocab = model.cfg.vocab

    plain = _run_arm(model, _requests(vocab, n_requests, seed=3),
                     hash_dedup=False)
    dedup = _run_arm(model, _requests(vocab, n_requests, seed=3))
    # exactness before any throughput claim
    assert dedup["outputs"] == plain["outputs"], \
        "hash dedup broke byte-exactness"
    assert plain["finished"] == dedup["finished"] == n_requests
    assert plain["hash_hits"] == 0 and dedup["hash_hits"] > 0

    speedup = dedup["PTPS"] / max(plain["PTPS"], 1e-9)
    # hit rate: adopted full blocks / the addressable full blocks of every
    # prompt that had a published sibling (requests 2..n, PREFIX//BLOCK
    # shared blocks each)
    addressable = (n_requests - 1) * (PREFIX // BLOCK)
    hit_rate = dedup["hash_hits"] / max(addressable, 1)
    auto = _auto_prefix_equiv(plain, dedup)
    preempt = _preempt_resume_arm(model)
    admission = _admission_arm(model)

    csv("dedup/plain", 0.0, f"PTPS={plain['PTPS']:.0f};"
        f"steps={plain['steps']}")
    csv("dedup/dedup", 0.0, f"PTPS={dedup['PTPS']:.0f};"
        f"hits={dedup['hash_hits']};hit_rate={hit_rate:.2f};"
        f"speedup={speedup:.2f}")
    csv("dedup/auto_prefix_equiv", 0.0, f"PTPS={auto['PTPS']:.0f};"
        f"reused={auto['reused_tokens']}")
    csv("dedup/preempt_resume", 0.0,
        f"preemptions={preempt['preemptions']};"
        f"recomputed={preempt['recomputed_tokens']}")
    csv("dedup/admission", 0.0,
        f"probe_admissions={admission['probe_admissions']}")

    out = {"exact": True, "speedup": float(speedup),
           "hit_rate": float(hit_rate),
           "reuse_vs_auto_ratio": (dedup["reused_tokens"]
                                   / max(auto["reused_tokens"], 1)),
           "arms_leak_free": bool(plain["leak_free"]
                                  and dedup["leak_free"]
                                  and preempt["leak_free"]),
           "block_size": BLOCK,
           "workload": {"n_requests": n_requests, "prompt": PROMPT,
                        "prefix": PREFIX, "kind": "templated-prompts"},
           "plain": _strip(plain), "dedup": _strip(dedup),
           "auto_prefix_equiv": auto,
           "preempt_resume": preempt,
           "admission": admission}
    with open("BENCH_dedup.json", "w") as f:
        json.dump(out, f, indent=2)
    csv("dedup/summary", 0.0,
        f"speedup={speedup:.2f};hit_rate={hit_rate:.2f};exact=True")
    return out


if __name__ == "__main__":
    print(json.dumps(main(), indent=2))
