"""Over-admission + recompute preemption benchmark.

The conservative reservation gate charges every request's worst-case block
need up front, so a long-``max_new`` trace whose requests usually stop early
(eos) strands most of the pool: reserved-but-unfilled debt is never lent
out.  This benchmark replays exactly that trace at EQUAL HBM budget (same
block pool in every arm) with the lending factor swept over
``over_admit in {1.0, 1.25, 1.5}``:

* exactness is asserted FIRST: every arm must emit byte-identical outputs —
  over-admission (and any preemption it triggers) may change *when* tokens
  are computed, never *what* is computed;
* the conservative arm must show the stranding this fixes (>= 25% of the
  pool idle on average);
* the lending arms must convert that idle capacity into admitted
  concurrency and decode throughput (fixed per-step cost amortizes over
  more resident rows);
* every arm must drain leak-free (allocator fully free, zero debt).

The eos token is picked by probing the model's own greedy output on the
first prompt, so actual generation lengths spread out (some requests stop
early, some run to ``max_new``) while reservations stay worst-case — the
exact gap over-admission exploits.  Emits ``BENCH_preempt.json`` for the
run.py harness / CI gate.
"""
from __future__ import annotations

import json
from collections import Counter

import numpy as np

from benchmarks.common import build_model, csv
from repro.serving.clock import CostModel
from repro.serving.engine import EngineConfig, UnifiedEngine
from repro.serving.request import Request, State
from repro.serving.slo import SLOConfig, slo_attainment

COST = CostModel()                     # decode-bound serving regime
BLOCK = 16
S_MAX = 96
MAX_NEW = 80                           # worst-case reservation: 6 blocks/req
N_BLOCKS = 20                          # 19 usable at equal HBM in every arm
N_REQUESTS = 10
FACTORS = (1.0, 1.25, 1.5)


def _requests(vocab: int, eos: int) -> list:
    rng = np.random.default_rng(9)
    return [Request(rid=i,
                    prompt=rng.integers(0, vocab, 12).astype(np.int32),
                    adapter="lora0", max_new_tokens=MAX_NEW, eos_token=eos,
                    arrival=0.05 * i)
            for i in range(N_REQUESTS)]


def _engine(model, over_admit: float) -> UnifiedEngine:
    # hash dedup is off so the utilization sweep measures over-admission
    # alone: index-held cache blocks would count as "used" and mask the
    # idle-pool stranding this bench exists to show (dedup x preemption
    # interplay is covered by bench_dedup's preempt_resume arm)
    return UnifiedEngine(model, EngineConfig(
        capacity=8, pf_capacity=4, s_max=S_MAX, block_size=BLOCK,
        n_blocks=N_BLOCKS, over_admit=over_admit, virtual_time=True,
        cost=COST, hash_dedup=False))


def _probe_eos(model) -> int:
    """The model's own most-repeated greedy token on the first prompt: a
    realistic stop token that some requests emit early and others never."""
    eng = _engine(model, 1.0)
    probe = _requests(model.cfg.vocab, eos=-1)[0]
    eng.submit(probe)
    eng.run(max_ticks=10000)
    common = Counter(probe.output).most_common(1)
    return int(common[0][0])


def _run_arm(model, over_admit: float, eos: int):
    eng = _engine(model, over_admit)
    for r in _requests(model.cfg.vocab, eos):
        eng.submit(r)
    utils, residents = [], []
    mgr = eng.cachemgr
    for _ in range(100000):
        busy = eng.tick()
        utils.append(mgr.allocator.n_used / mgr.allocator.usable)
        residents.append(len(eng.active) + len(eng.prefilling))
        if (not eng.waiting and not eng.active and not eng.prefilling
                and not eng.future):
            break
        if not busy:
            break
    m = eng.metrics
    assert len(eng.finished) == N_REQUESTS
    assert all(r.state is State.DONE for r in eng.finished)
    leak_free = (mgr.allocator.n_free == mgr.allocator.usable
                 and mgr.reserved_debt == 0 and not mgr.tables)
    return {"over_admit": over_admit,
            "mean_util": float(np.mean(utils)),
            "peak_util": float(np.max(utils)),
            "peak_residents": int(np.max(residents)),
            "decode_tokens": int(m.decode_tokens),
            "elapsed_virtual": float(m.elapsed),
            "DTPS": m.decode_tokens / max(m.elapsed, 1e-9),
            "slo_attainment": float(slo_attainment(eng.finished,
                                                   SLOConfig())),
            "preemptions": int(m.preemptions),
            "preemption_rate": m.preemptions / N_REQUESTS,
            "preempted_tokens_recomputed": int(
                m.preempted_tokens_recomputed),
            "lent_blocks_peak": int(m.lent_blocks_peak),
            "leak_free": bool(leak_free),
            "outputs": {r.rid: list(r.output) for r in eng.finished}}


def main():
    model = build_model(n_adapters=1)
    eos = _probe_eos(model)

    arms = {f"{f:g}": _run_arm(model, f, eos) for f in FACTORS}
    base = arms["1"]
    best = max(arms.values(), key=lambda a: a["DTPS"])

    # exactness before any throughput claim: preemption must change WHEN
    # tokens are computed, never WHAT is computed
    for name, arm in arms.items():
        assert arm["outputs"] == base["outputs"], \
            f"over-admission arm {name} broke exactness"
        assert arm["leak_free"], f"arm {name} leaked blocks"
    # the stranding this PR fixes, and the recovery that fixes it
    idle = 1.0 - base["mean_util"]
    assert idle >= 0.25, f"conservative arm not stranded enough: {idle:.2f}"
    assert best["over_admit"] > 1.0
    assert best["peak_residents"] > base["peak_residents"]
    assert best["DTPS"] > base["DTPS"]

    for name, arm in arms.items():
        csv(f"preempt/over_admit_{name}", 0.0,
            f"DTPS={arm['DTPS']:.0f};util={arm['mean_util']:.2f};"
            f"residents={arm['peak_residents']};"
            f"preempt={arm['preemptions']};slo={arm['slo_attainment']:.2f}")

    speedup = best["DTPS"] / max(base["DTPS"], 1e-9)
    out = {"exact": True,
           "conservative_idle_frac": float(idle),
           "speedup": float(speedup),
           "best_factor": float(best["over_admit"]),
           "block_size": BLOCK, "n_blocks": N_BLOCKS,
           "workload": {"n_requests": N_REQUESTS, "max_new": MAX_NEW,
                        "eos_probe": eos, "kind": "long-max_new-early-stop"},
           "arms": {k: {kk: vv for kk, vv in v.items() if kk != "outputs"}
                    for k, v in arms.items()}}
    with open("BENCH_preempt.json", "w") as f:
        json.dump(out, f, indent=2)
    csv("preempt/summary", 0.0,
        f"speedup={speedup:.2f}@over_admit={best['over_admit']:g};"
        f"idle_recovered={idle:.0%};exact=True")
    return out


if __name__ == "__main__":
    print(json.dumps(main(), indent=2))
