# Tiered KV memory: swap-to-host preemption vs recompute preemption.
#
# Four arms replay the SAME trace (one eos-probed greedy workload) through a
# paged engine under enough block pressure to force preemptions:
#
#   recompute  host pool off (``kv_host_blocks=0``) — the PR-4 baseline:
#              every preemption discards the victim's KV and re-prefills.
#   swap       host pool on.  At preemption the victim's committed blocks
#              D2H-copy into the HostBlockPool; re-admission restores them
#              H2D and suffix-prefill computes only the final token.  The
#              cost model prices the transfer cheaper than the recompute, so
#              the decision rule chooses swap every time.
#   decline    host pool on, but with D2H/H2D per-byte cost inflated until
#              transfer loses to recompute.  The decision rule must now
#              decline EVERY swap (``kv_swap_skips == preemptions``,
#              ``kv_swap_outs == 0``) and the arm must behave byte-
#              identically to the recompute baseline — the rule, not the
#              pool, owns the choice.
#   quant      swap arm with ``kv_host_quant`` — host residency stored
#              int8.  EXACTNESS-EXEMPT by design (dequantized KV is not
#              bit-identical); gated on completion + leak-freedom + the
#              capacity claim (quantized per-block bytes < raw).
#
# Exactness is asserted FIRST: the swap and decline arms must produce
# byte-identical outputs to the recompute baseline at equal HBM — tiering
# changes *when* KV is materialized, never *what* the model computes.
# Every logged swap decision is then replayed through the analytic rule
# (``swap_beats_recompute`` on statically-known bytes/tokens) and must
# match what the engine actually chose: hit rate 1.0 or the gate fails.
from __future__ import annotations

import dataclasses
import json
from collections import Counter

import numpy as np

from benchmarks.common import build_model, csv
from repro.serving.clock import CostModel
from repro.serving.engine import EngineConfig, UnifiedEngine
from repro.serving.kvcache import swap_beats_recompute
from repro.serving.request import Request, State
from repro.serving.slo import SLOConfig, slo_attainment

COST = CostModel()
# transfer priced ~3 orders above recompute: the rule must decline
COST_DECLINE = dataclasses.replace(COST, d2h_per_byte=1e-3, h2d_per_byte=1e-3)
BLOCK = 16
S_MAX = 96
MAX_NEW = 80
N_BLOCKS = 16        # tight enough that over-admission must preempt
N_REQUESTS = 12
HOST_BLOCKS = 24
OVER_ADMIT = 1.5


def _requests(vocab: int, eos: int):
    rng = np.random.default_rng(9)
    return [Request(rid=i,
                    prompt=rng.integers(0, vocab, 12).astype(np.int32),
                    adapter="lora0", max_new_tokens=MAX_NEW, eos_token=eos,
                    arrival=0.05 * i)
            for i in range(N_REQUESTS)]


def _engine(model, host_blocks: int, cost: CostModel, quant: bool = False):
    return UnifiedEngine(model, EngineConfig(
        capacity=8, pf_capacity=4, s_max=S_MAX, block_size=BLOCK,
        n_blocks=N_BLOCKS, over_admit=OVER_ADMIT, virtual_time=True,
        cost=cost, hash_dedup=False, prefill_chunk=BLOCK,
        kv_host_blocks=host_blocks, kv_host_quant=quant))


def _probe_eos(model) -> int:
    """Most-common greedy token of a probe request = an eos that actually
    fires, so arms finish early and preemption victims get re-admitted."""
    eng = _engine(model, 0, COST)
    probe = _requests(model.cfg.vocab, eos=-1)[0]
    eng.submit(probe)
    eng.run(max_ticks=20000)
    return int(Counter(probe.output).most_common(1)[0][0])


def _run_arm(model, eos: int, host_blocks: int, cost: CostModel,
             quant: bool = False) -> dict:
    eng = _engine(model, host_blocks, cost, quant)
    for r in _requests(model.cfg.vocab, eos):
        eng.submit(r)
    m = eng.run(max_ticks=200000)
    assert len(eng.finished) == N_REQUESTS
    assert all(r.state is State.DONE for r in eng.finished)

    # leak audit covers BOTH tiers: after draining the run and flushing
    # every cache (hash index, adapter residency, host pool) the allocator
    # must be fully free with zero reservation debt, and the host pool must
    # hold neither swap sets nor demoted bytes
    mgr = eng.cachemgr
    pristine = mgr.pristine
    mgr.flush_index()
    mgr.flush_adapters()
    mgr.flush_host()
    hp = mgr.host_pool
    leak_free = (pristine
                 and mgr.allocator.n_free == mgr.allocator.usable
                 and mgr.reserved_debt == 0
                 and not mgr.tables
                 and (hp is None or (hp.used_bytes == 0
                                     and hp.n_swap_sets == 0
                                     and hp.n_demoted == 0)))
    return {
        "finished": len(eng.finished),
        "elapsed": m.elapsed,
        "DTPS": m.decode_tokens / max(m.elapsed, 1e-9),
        "slo_attainment": slo_attainment(eng.finished, SLOConfig()),
        "preemptions": m.preemptions,
        "recompute_tokens": m.preempted_tokens_recomputed,
        "kv_swap_outs": m.kv_swap_outs,
        "kv_swap_out_bytes": m.kv_swap_out_bytes,
        "kv_swap_skips": m.kv_swap_skips,
        "kv_restores": m.kv_restores,
        "kv_restored_tokens": m.kv_restored_tokens,
        "host_bytes_peak": m.host_bytes_peak,
        "host_block_bytes": mgr.host_block_bytes,
        "leak_free": leak_free,
        "outputs": {r.rid: [int(t) for t in r.output] for r in eng.finished},
        "decisions": eng.swap_decisions,
    }


def _replay_decisions(arm: dict, cost: CostModel):
    """Re-derive every swap-or-recompute choice from statically-known
    quantities (block count x per-block host bytes vs suffix tokens) and
    count mismatches with what the engine actually did.  ``swapped`` must
    also equal ``chose_swap``: a host pool refusal would silently degrade
    the arm to recompute and still be byte-exact, so only this replay
    catches it."""
    hits = total = 0
    for d in arm["decisions"]:
        expected = d["blocks"] > 0 and swap_beats_recompute(
            d["blocks"] * arm["host_block_bytes"],
            d["recompute_tokens"], cost)
        hits += (expected == d["chose_swap"]
                 and d["swapped"] == d["chose_swap"])
        total += 1
    return hits, total


def main() -> None:
    model = build_model(n_adapters=1)
    eos = _probe_eos(model)

    base = _run_arm(model, eos, 0, COST)
    swap = _run_arm(model, eos, HOST_BLOCKS, COST)
    decline = _run_arm(model, eos, HOST_BLOCKS, COST_DECLINE)
    quant = _run_arm(model, eos, HOST_BLOCKS, COST, quant=True)

    # exactness FIRST: restored-KV decode must be byte-identical to
    # recompute decode, and a declined swap must be indistinguishable from
    # never having had a host pool
    exact = swap["outputs"] == base["outputs"]
    decline_exact = decline["outputs"] == base["outputs"]
    assert exact, "swap-restore arm diverged from recompute baseline"
    assert decline_exact, "decline arm diverged from recompute baseline"

    hits_s, total_s = _replay_decisions(swap, COST)
    hits_d, total_d = _replay_decisions(decline, COST_DECLINE)
    decisions_total = total_s + total_d
    hit_rate = ((hits_s + hits_d) / decisions_total
                if decisions_total else 0.0)

    speedup = base["elapsed"] / max(swap["elapsed"], 1e-9)
    quant_ratio = quant["host_block_bytes"] / max(swap["host_block_bytes"], 1)
    doc = {
        "exact": exact,
        "decline_exact": decline_exact,
        "decision_hit_rate": hit_rate,
        "decisions_total": decisions_total,
        "speedup": speedup,
        "quant_bytes_ratio": quant_ratio,
        "host_quant_exempt": True,   # quant arm is exactness-exempt by flag
        "workload": {"n_requests": N_REQUESTS, "n_blocks": N_BLOCKS,
                     "host_blocks": HOST_BLOCKS, "over_admit": OVER_ADMIT,
                     "block_size": BLOCK},
        "arms": {name: {k: v for k, v in arm.items()
                        if k not in ("outputs", "decisions")}
                 for name, arm in (("recompute", base), ("swap", swap),
                                   ("decline", decline), ("quant", quant))},
    }
    with open("BENCH_tiers.json", "w") as f:
        json.dump(doc, f, indent=1)

    csv("tiers_exact", 0.0, f"swap==recompute={exact}")
    csv("tiers_decision_hit_rate", 0.0,
        f"{hit_rate:.2f} over {decisions_total} decisions")
    csv("tiers_recompute_tokens", 0.0,
        f"base={base['recompute_tokens']} swap={swap['recompute_tokens']}")
    csv("tiers_slo", 0.0,
        f"base={base['slo_attainment']:.2f} swap={swap['slo_attainment']:.2f}")
    csv("tiers_speedup", 0.0, f"{speedup:.3f}x")
    csv("tiers_quant_bytes", 0.0,
        f"ratio={quant_ratio:.2f} ({quant['host_block_bytes']}B vs "
        f"{swap['host_block_bytes']}B)")


if __name__ == "__main__":
    main()
