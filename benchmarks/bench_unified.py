"""Figure 4 — unified fine-tuning + inference: SLO maintained while a share
of fine-tuning throughput survives; vs S-LoRA+PEFT coarse time-slicing and
PEFT-serial baselines."""
from __future__ import annotations

from benchmarks.common import (PeftLikeServer, SLO, build_model, csv,
                               make_requests, run_engine_inference,
                               slo_attainment)
from repro.data import datasets
from repro.training.trainer import MixedLoraTrainer, TrainerConfig


def main(rates=(1.0, 2.0), n_per_rps: int = 12, max_new: int = 48):
    for label, n_ft, n_inf in (("1ft_1inf", 1, 1), ("multi", 2, 2)):
        model = build_model(n_adapters=4)
        vocab = model.cfg.vocab
        for rps in rates:
            n = int(n_per_rps * rps)
            # ours: co-scheduled in ONE runtime
            model = build_model(n_adapters=4)
            reqs = make_requests(n, rps, vocab, n_inf, max_new=max_new,
                                 seed=int(rps * 7))
            trainers = []
            for i in range(n_ft):
                name = f"lora{2 + i}"
                rows, ev = datasets.split_eval(datasets.alpaca_like(
                    300, vocab=vocab, seed=i))
                trainers.append(MixedLoraTrainer(
                    name, model.store.slot_of(name), rows, ev,
                    TrainerConfig(rows_per_micro=2, accum_steps=4, epochs=1)))
            from benchmarks.common import build_engine
            eng = build_engine(model, capacity=16)
            for r in reqs:
                eng.submit(r)
            for t in trainers:
                eng.add_trainer(t)
            m = eng.run(max_ticks=500000)
            rr = m.rates()
            att = slo_attainment(eng.finished, SLO)
            csv(f"unified/loquetier_{label}_rps{rps:g}", 0.0,
                f"SLO={att:.3f};DTPS={rr['DTPS']:.1f};FTPS={rr['FTPS']:.1f}")

            # S-LoRA+PEFT: inference first-class, fine-tuning only in the
            # leftover idle window (coarse slicing -> FTPS collapses under
            # sustained load)
            reqs2 = make_requests(n, rps, vocab, n_inf, max_new=max_new,
                                  seed=int(rps * 7))
            res = run_engine_inference(build_model(n_adapters=4), reqs2)
            eng2 = res["engine"]
            span = max(res["elapsed_virtual"], 1e-9)
            idle_frac = max(0.0, 1.0 - eng2.metrics.busy_time / span)
            ftps_solo = PeftLikeServer(batch_size=2).finetune_tokens_per_s(
                datasets.alpaca_like(300, vocab=vocab, seed=0))
            csv(f"unified/slora_peft_{label}_rps{rps:g}", 0.0,
                f"SLO={res['slo']:.3f};DTPS={res['DTPS']:.1f};"
                f"FTPS={idle_frac * ftps_solo:.1f}")

            # PEFT: fine-tuning hogs the device; inference queues behind it
            reqs3 = make_requests(n, rps, vocab, n_inf, max_new=max_new,
                                  seed=int(rps * 7))
            ft_rows = datasets.alpaca_like(300, vocab=vocab, seed=0)
            ft_time = 2 * sum(len(t) for t, _ in ft_rows) / max(
                PeftLikeServer(batch_size=2).finetune_tokens_per_s(ft_rows),
                1e-9)
            # requests queue (original arrival clocks keep ticking) until
            # the fine-tuning job releases the device
            done, stats = PeftLikeServer().serve(reqs3, start_at=ft_time)
            csv(f"unified/peft_{label}_rps{rps:g}", 0.0,
                f"SLO={slo_attainment(done, SLO):.3f};"
                f"DTPS={stats['DTPS']:.1f};ft_blocks_for={ft_time:.1f}s")


if __name__ == "__main__":
    main()
