"""Bench-gate runner: the single place CI thresholds live.

Each benchmark writes a ``BENCH_<name>.json`` artifact; this script loads
the artifact named by ``benchmarks/gates.json`` for the given bench and
asserts every declared check.  One checked-in, unit-tested gate instead of
four copy-pasted YAML heredocs: thresholds are data, not workflow strings.

    python benchmarks/gate.py dedup [--gates benchmarks/gates.json] [--dir .]

Check schema (``gates.json``):

    {"<bench>": {"artifact": "BENCH_<bench>.json",
                 "checks": [{"lhs": "<path>", "op": "<op>"[, "rhs": <v>]}]}}

* ``lhs`` is a dotted path into the artifact.  A segment may be ``*``
  (fan out over every value of a dict — the check must hold for ALL
  matches) or ``{other.path}`` (interpolated from the artifact root,
  floats formatted with ``%g`` — e.g. ``arms.{best_factor}.DTPS`` selects
  the best arm recorded by the bench itself).
* ``op`` is one of ``>= > <= < == != truthy``.
* ``rhs`` is a literal, or a path string resolved the same way as ``lhs``
  (must resolve to exactly one value).
"""
from __future__ import annotations

import argparse
import json
import operator
import os
import sys
from typing import Any, List

OPS = {">=": operator.ge, ">": operator.gt, "<=": operator.le,
       "<": operator.lt, "==": operator.eq, "!=": operator.ne}


class GateError(AssertionError):
    """A gate check failed or could not be evaluated."""


def _fmt(v: Any) -> str:
    """Dict-key form of an interpolated value (floats via %g, so the
    ``1.5`` a bench stored as ``best_factor`` finds its ``"1.5"`` arm and
    ``1.0`` finds ``"1"`` — mirroring the f"{x:g}" keys benches emit)."""
    if isinstance(v, bool):
        return str(v).lower()
    if isinstance(v, float):
        return f"{v:g}"
    return str(v)


def resolve(doc: Any, path: str) -> List[Any]:
    """All values at ``path`` (one value unless a ``*`` segment fans out).
    Raises GateError on a dangling path — a gate that checks nothing must
    fail loudly, not pass vacuously."""
    nodes = [doc]
    for seg in path.split("."):
        if seg.startswith("{") and seg.endswith("}"):
            inner = resolve(doc, seg[1:-1])
            if len(inner) != 1:
                raise GateError(f"interpolation {seg} in {path!r} must "
                                f"resolve to exactly one value")
            seg = _fmt(inner[0])
        nxt: List[Any] = []
        for node in nodes:
            if seg == "*":
                if not isinstance(node, dict):
                    raise GateError(f"wildcard over non-object at {path!r}")
                nxt.extend(node.values())
            elif isinstance(node, dict) and seg in node:
                nxt.append(node[seg])
            else:
                raise GateError(f"path {path!r} missing segment {seg!r}")
        nodes = nxt
    return nodes


def run_check(doc: Any, check: dict) -> str:
    """Evaluate one check; returns a human line, raises GateError on fail."""
    lhs_path = check["lhs"]
    lhs = resolve(doc, lhs_path)
    op = check["op"]
    if op == "truthy":
        bad = [v for v in lhs if not v]
        if bad:
            raise GateError(f"{lhs_path} not truthy: {bad!r}")
        return f"ok  {lhs_path} truthy ({len(lhs)} value(s))"
    if op not in OPS:
        raise GateError(f"unknown op {op!r} for {lhs_path}")
    rhs = check["rhs"]
    rhs_disp = rhs
    if isinstance(rhs, str):
        got = resolve(doc, rhs)
        if len(got) != 1:
            raise GateError(f"rhs path {rhs!r} must resolve to one value")
        rhs_disp = f"{rhs}={got[0]!r}"
        rhs = got[0]
    bad = [v for v in lhs if not OPS[op](v, rhs)]
    if bad:
        raise GateError(f"{lhs_path} {op} {rhs_disp}: violated by {bad!r}")
    return f"ok  {lhs_path} {op} {rhs_disp} (got {lhs!r})"


def run_gate(bench: str, gates_path: str, artifact_dir: str = ".") -> int:
    with open(gates_path) as f:
        gates = json.load(f)
    if bench not in gates:
        raise GateError(f"no gate defined for bench {bench!r} "
                        f"(have: {sorted(gates)})")
    spec = gates[bench]
    artifact = os.path.join(artifact_dir, spec["artifact"])
    if not os.path.exists(artifact):
        raise GateError(f"artifact {artifact} missing — did the benchmark "
                        f"run (and write its BENCH json)?")
    with open(artifact) as f:
        doc = json.load(f)
    checks = spec["checks"]
    if not checks:
        raise GateError(f"gate for {bench!r} declares no checks")
    for check in checks:
        print(run_check(doc, check))
    print(f"PASS {bench}: {len(checks)} check(s) against {spec['artifact']}")
    return len(checks)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("bench", help="gate name (key in gates.json)")
    ap.add_argument("--gates", default=os.path.join(
        os.path.dirname(os.path.abspath(__file__)), "gates.json"))
    ap.add_argument("--dir", default=".", help="artifact directory")
    args = ap.parse_args(argv)
    try:
        run_gate(args.bench, args.gates, args.dir)
    except GateError as e:
        print(f"FAIL {args.bench}: {e}", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
