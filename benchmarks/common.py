"""Shared benchmark plumbing: model/engine builders and the baseline systems
the paper compares against, all running the SAME substrate and the SAME
virtual-clock cost model, differing only in the behaviours the paper
attributes to them:

* ``peft_like``  — HF Transformers+PEFT: FIFO padded batches (costs charged
  on PADDED tokens), one adapter per pass (multi-LoRA inference is serial),
  fine-tuning and inference cannot share a step, no continuous batching —
  a batch must fully finish before the next starts.
* ``slora_like`` — S-LoRA+PEFT: multi-LoRA continuous-batching INFERENCE
  (same engine as ours) but fine-tuning runs in a separate runtime that gets
  the device only while no inference work exists (coarse time-slicing).
* ``static_merge`` — FlexLLM-flavoured axis we can express: one adapter
  merged into the base weights; fast single-adapter serving, but adapter
  swap = re-merge (downtime) and no concurrent multi-adapter path.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Tuple

import jax
import numpy as np

from repro.configs import get_reduced
from repro.core.lora import LoRAConfig
from repro.core.virtualization import AdapterStore, MixedLoraModel
from repro.data import datasets
from repro.models.schema import init_params
from repro.serving.clock import CostModel, VirtualClock
from repro.serving.engine import EngineConfig, UnifiedEngine
from repro.serving.request import Request, State
from repro.serving.slo import SLOConfig, slo_attainment

LCFG = LoRAConfig(n_slots=4, r=8)
SLO = SLOConfig()


def build_model(arch: str = "llama3-8b", n_adapters: int = 2, seed: int = 0
                ) -> MixedLoraModel:
    cfg = get_reduced(arch)
    params = init_params(cfg, jax.random.PRNGKey(seed))
    store = AdapterStore(cfg, LCFG, jax.random.PRNGKey(seed + 1))
    for i in range(n_adapters):
        store.load_random(f"lora{i}", jax.random.PRNGKey(100 + i))
    return MixedLoraModel(cfg, params, store)


def build_engine(model: MixedLoraModel, capacity: int = 8,
                 s_max: int = 192) -> UnifiedEngine:
    return UnifiedEngine(model, EngineConfig(capacity=capacity,
                                             pf_capacity=4, s_max=s_max,
                                             virtual_time=True))


def make_requests(n: int, rps: float, vocab: int, n_adapters: int,
                  max_new: int = 16, seed: int = 0) -> List[Request]:
    from repro.data import workload
    prompts = datasets.sharegpt_prompts(n, vocab=vocab, seed=seed)
    arr = workload.poisson_arrivals(rps, n, seed=seed)
    return [Request(rid=i, prompt=p, adapter=f"lora{i % n_adapters}",
                    max_new_tokens=max_new, arrival=float(t))
            for i, (p, t) in enumerate(zip(prompts, arr))]


# ---------------------------------------------------------------------------
# PEFT-like baseline (cost-model simulation over the same request stream)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class PeftLikeServer:
    """FIFO padded batching, serial per-adapter, run-to-completion batches.
    Charged on the shared CostModel; SLO accounting identical to ours."""
    cost: CostModel = dataclasses.field(default_factory=CostModel)
    batch_size: int = 4          # paper: PEFT's batch is memory-constrained

    def serve(self, requests: List[Request], start_at: float = 0.0
              ) -> Tuple[List[Request], Dict]:
        clock = VirtualClock(self.cost)
        clock.advance_to(start_at)       # e.g. blocked behind a fine-tune job
        pending = sorted(requests, key=lambda r: r.arrival)
        done: List[Request] = []
        dec_tokens = 0
        while pending:
            now = max(clock.now(), pending[0].arrival)
            clock.advance_to(now)
            # one adapter per pass (serial multi-LoRA)
            adapter = pending[0].adapter
            batch = [r for r in pending if r.adapter == adapter
                     and r.arrival <= now][:self.batch_size]
            if not batch:
                batch = [pending[0]]
            for r in batch:
                pending.remove(r)
            s_pad = max(r.prompt_len for r in batch)
            b = len(batch)
            # padded prefill
            clock.charge(self.cost.fixed + self.cost.prefill_per_tok
                         * b * s_pad)
            for r in batch:
                r.t_first_token = clock.now()
                r.token_times.append(clock.now())
                r.output.append(0)
            # padded decode: every row steps until the LONGEST finishes
            steps = max(r.max_new_tokens for r in batch) - 1
            for _ in range(steps):
                clock.charge(self.cost.fixed + self.cost.decode_per_row * b)
                for r in batch:
                    if len(r.output) < r.max_new_tokens:
                        r.output.append(0)
                        r.token_times.append(clock.now())
                        dec_tokens += 1
            for r in batch:
                r.state = State.DONE
                r.t_finish = clock.now()
                done.append(r)
        elapsed = max(clock.now(), 1e-9)
        return done, {"DTPS": dec_tokens / elapsed, "elapsed": elapsed}

    def finetune_tokens_per_s(self, rows, adapters_serial: int = 1) -> float:
        """PEFT fine-tunes one adapter at a time: cumulative cost."""
        clock = VirtualClock(self.cost)
        total = 0
        for _ in range(adapters_serial):
            for i in range(0, len(rows), self.batch_size):
                batch = rows[i:i + self.batch_size]
                s_pad = max(len(t) for t, _ in batch)
                clock.charge(self.cost.fixed
                             + self.cost.ft_per_tok * len(batch) * s_pad)
                total += sum(len(t) for t, _ in batch)
        return total / max(clock.now(), 1e-9)


def run_engine_inference(model: MixedLoraModel, requests: List[Request],
                         trainer=None, capacity: int = 8) -> Dict:
    eng = build_engine(model, capacity=capacity)
    for r in requests:
        eng.submit(r)
    if trainer is not None:
        eng.add_trainer(trainer)
    t0 = time.monotonic()
    m = eng.run(max_ticks=500000)
    wall = time.monotonic() - t0
    rates = m.rates()
    return {"slo": slo_attainment(eng.finished, SLO),
            "finished": len(eng.finished), "DTPS": rates["DTPS"],
            "FTPS": rates["FTPS"], "ETPS": rates["ETPS"],
            "elapsed_virtual": m.elapsed, "wall": wall,
            "engine": eng}


def csv(name: str, us_per_call: float, derived: str):
    print(f"{name},{us_per_call:.1f},{derived}")
