"""Roofline table reader: summarises the dry-run artifacts
(experiments/dryrun/*.json) into the §Roofline table."""
from __future__ import annotations

import glob
import json
import os

from benchmarks.common import csv

DEFAULT_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                           "dryrun")


def main(art_dir: str = DEFAULT_DIR, mesh: str = "16x16"):
    files = sorted(glob.glob(os.path.join(art_dir, f"*__{mesh}.json")))
    if not files:
        csv("roofline/missing", 0.0, f"no artifacts in {art_dir}")
        return
    for f in files:
        r = json.load(open(f))
        if r.get("status") != "ok":
            csv(f"roofline/{r['arch']}__{r['shape']}", 0.0, "FAILED")
            continue
        ro = r["roofline"]
        mem = r["memory"]["peak_estimate_bytes"] / 2**30
        csv(f"roofline/{r['arch']}__{r['shape']}", 0.0,
            f"dom={ro['dominant']};c={ro['compute_s']:.4f}s;"
            f"m={ro['memory_s']:.4f}s;n={ro['collective_s']:.4f}s;"
            f"useful={ro['useful_flops_ratio']:.2f};peakGiB={mem:.1f}")


if __name__ == "__main__":
    main()
