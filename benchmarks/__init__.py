"""Benchmark harness: one module per paper table/figure, plus kernel
microbenches and the roofline reader.  All runnable on CPU with reduced
models; SLO comparisons use the shared virtual-clock cost model so relative
claims reproduce deterministically."""
