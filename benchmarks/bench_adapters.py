"""Unified KV + adapter paging benchmark: one shared block pool for KV
cache AND adapter weights vs the static HBM partition.

A LoRA-Land-style workload — 120 adapters at heterogeneous true ranks
(2/4/8), a cold tail sweep that touches every adapter once, then a
Zipf-popular hot phase — served by two arms at EQUAL TOTAL HBM, metered in
pool-block units (one block = one KV block's bytes; a full-rank bank slot
costs ``slot_blocks`` of them):

* ``static``  — the S-LoRA-baseline partition: a LARGE fixed adapter bank
  (28 full-rank slots, paid for up front whether occupied or not) next to
  a SMALL KV pool.  Adapters beyond the bank spill to host and every
  re-acquire is a clock-charged swap-in.
* ``unified`` — a small staging bank (12 slots) plus one big pool where KV
  blocks and true-rank adapter payloads share a free list: HBM flows to
  whatever the workload needs, the scheduler prefers resident-adapter
  waiters and co-batches same-adapter requests (one swap amortized per
  tick), and cold adapters shed LRU under KV pressure.

Same total HBM, same virtual-clock cost model (both arms pay the same H2D
price per swap-in), same request trace.  Byte-exactness is asserted FIRST
— paging moves bytes and reorders admissions, never changes what a request
computes — then the headline: decode tokens/s, gated >= 1.2x, with the win
coming from the KV concurrency the static partition strands (its idle bank
slots cannot hold KV) plus swap amortization.

Emits ``BENCH_adapters.json`` for the run.py harness / CI gate.
"""
from __future__ import annotations

import json

import jax
import numpy as np

from benchmarks.common import csv
from repro.configs import get_reduced
from repro.core.lora import LoRAConfig, init_lora_bank
from repro.core.virtualization import AdapterStore, MixedLoraModel
from repro.models.schema import lora_targets
from repro.models.schema import init_params
from repro.serving.engine import EngineConfig, UnifiedEngine
from repro.serving.request import Request, State

ARCH = "llama3-8b"
N_ADAPTERS = 120                 # >= 100: the LoRA-Land regime
RANKS = [2, 4, 8]                # heterogeneous true ranks, cycled
BANK_R = 8                       # bank (full) rank
STATIC_SLOTS = 28                # static arm: big fixed adapter partition
UNIFIED_SLOTS = 12               # unified arm: small staging bank
STATIC_POOL = 12                 # static arm: what's left for KV
BLOCK = 16
PROMPT = 16
MAX_NEW = 16
N_SWEEP = N_ADAPTERS             # one cold request per adapter
N_HOT = 60                       # Zipf-popular phase
ZIPF_S = 1.1
CAPACITY = 8
S_MAX = 48


def _trace(vocab: int, seed: int = 0):
    """Cold tail sweep (every adapter exactly once, shuffled) then a
    Zipf-hot burst — the LoRA-Land shape: a long tail of rarely-used
    adapters under a popular head."""
    rng = np.random.default_rng(seed)
    order = rng.permutation(N_ADAPTERS)
    w = 1.0 / np.arange(1, N_ADAPTERS + 1) ** ZIPF_S
    hot = rng.choice(N_ADAPTERS, size=N_HOT, p=w / w.sum())
    reqs = []
    for rid, a in enumerate(list(order) + list(hot)):
        reqs.append(Request(
            rid=rid,
            prompt=rng.integers(0, vocab, PROMPT).astype(np.int32),
            adapter=f"lora{a}", max_new_tokens=MAX_NEW,
            arrival=0.01 * rid))
    return reqs


def _build(unified: bool, seed: int = 0):
    cfg = get_reduced(ARCH)
    params = init_params(cfg, jax.random.PRNGKey(seed))
    n_slots = UNIFIED_SLOTS if unified else STATIC_SLOTS
    store = AdapterStore(cfg, LoRAConfig(n_slots=n_slots, r=BANK_R),
                         jax.random.PRNGKey(seed + 1))
    # equal total HBM in pool-block units: a full-rank bank slot costs
    # slot_blocks pool blocks, so the unified arm's smaller bank buys it a
    # bigger pool — the same bytes, allocated where the workload needs them
    slot_bytes = store.adapter_nbytes(rank=BANK_R)
    model = MixedLoraModel(cfg, params, store)
    return cfg, store, model, slot_bytes


def _run(unified: bool, seed: int = 0):
    cfg, store, model, slot_bytes = _build(unified, seed)
    # one probe manager tells us the block byte-size => slot cost in blocks
    probe = UnifiedEngine(model, EngineConfig(
        capacity=2, pf_capacity=1, s_max=S_MAX, block_size=BLOCK,
        n_blocks=4, virtual_time=True))
    slot_blocks = -(-slot_bytes // probe.cachemgr.adapter_block_bytes)
    del probe
    hbm_total = STATIC_POOL + STATIC_SLOTS * slot_blocks
    n_slots = UNIFIED_SLOTS if unified else STATIC_SLOTS
    pool = hbm_total - n_slots * slot_blocks
    eng = UnifiedEngine(model, EngineConfig(
        capacity=CAPACITY, pf_capacity=4, s_max=S_MAX, block_size=BLOCK,
        n_blocks=pool, virtual_time=True, adapter_paging=unified))
    # generate adapter weights from a FIXED single-slot config so both
    # arms load bit-identical pytrees (a bank-shaped random init would
    # entangle the draws with n_slots, which differs across arms)
    gen = LoRAConfig(n_slots=1, r=BANK_R)
    targets = lora_targets(cfg, gen.targets)
    for i in range(N_ADAPTERS):
        fresh = init_lora_bank(jax.random.PRNGKey(1000 + i), targets, gen,
                               gaussian_b=True)
        store.load(f"lora{i}",
                   jax.tree_util.tree_map(lambda x: x[..., 0, :, :], fresh),
                   rank=RANKS[i % len(RANKS)], evict=True)
    for r in _trace(cfg.vocab, seed):
        eng.submit(r)
    m = eng.run(max_ticks=500000)
    n = N_SWEEP + N_HOT
    assert len(eng.finished) == n, f"{len(eng.finished)}/{n} finished"
    assert all(r.state is State.DONE for r in eng.finished)
    cm = eng.cachemgr
    leak_free = bool(cm.pristine
                     and all(v == 0 for v in cm._adapter_pins.values()))
    if unified:
        cm.flush_adapters()
        cm.flush_index()
        leak_free = leak_free and cm.allocator.n_free == cm.allocator.usable
    return {"DTPS": m.rates()["DTPS"],
            "elapsed_virtual": float(m.elapsed),
            "decode_tokens": int(m.decode_tokens),
            "adapter_swap_ins": int(m.adapter_swap_ins),
            "adapter_swap_in_bytes": int(m.adapter_swap_in_bytes),
            "adapter_resident_hits": int(m.adapter_resident_hits),
            "adapter_peak_coresident": int(m.adapter_peak_coresident),
            "adapter_blocks_resident": int(m.adapter_blocks_resident),
            "pool_blocks": int(cm.total_blocks),
            "bank_slots": int(n_slots),
            "slot_blocks": int(slot_blocks),
            "hbm_blocks": int(cm.total_blocks + n_slots * slot_blocks),
            "steps": int(m.steps),
            "leak_free": leak_free,
            "outputs": {r.rid: list(r.output) for r in eng.finished}}


def _strip(d):
    return {k: v for k, v in d.items() if k != "outputs"}


def main(seed: int = 0):
    static = _run(False, seed)
    unified = _run(True, seed)

    # exactness before any throughput claim: unified paging relocates
    # adapter bytes and reorders admissions, never changes the math
    exact = static["outputs"] == unified["outputs"]
    assert exact, "unified paging broke byte-exactness"
    equal_hbm = static["hbm_blocks"] == unified["hbm_blocks"]
    speedup = unified["DTPS"] / max(static["DTPS"], 1e-9)

    csv("adapters/static", 0.0,
        f"DTPS={static['DTPS']:.0f};swaps={static['adapter_swap_ins']};"
        f"pool={static['pool_blocks']};bank={static['bank_slots']}")
    csv("adapters/unified", 0.0,
        f"DTPS={unified['DTPS']:.0f};swaps={unified['adapter_swap_ins']};"
        f"hits={unified['adapter_resident_hits']};"
        f"pool={unified['pool_blocks']};speedup={speedup:.2f}")

    out = {"exact": bool(exact), "speedup": float(speedup),
           "equal_hbm": bool(equal_hbm),
           "arms_leak_free": bool(static["leak_free"]
                                  and unified["leak_free"]),
           "workload": {"n_adapters": N_ADAPTERS, "ranks": RANKS,
                        "n_requests": N_SWEEP + N_HOT, "zipf_s": ZIPF_S,
                        "prompt": PROMPT, "max_new": MAX_NEW,
                        "kind": "lora-land-tail-sweep+zipf-hot"},
           "static": _strip(static), "unified": _strip(unified)}
    with open("BENCH_adapters.json", "w") as f:
        json.dump(out, f, indent=2, sort_keys=True)
    print(f"# BENCH_adapters.json: speedup={speedup:.2f} "
          f"swaps static={static['adapter_swap_ins']} "
          f"unified={unified['adapter_swap_ins']} "
          f"hits={unified['adapter_resident_hits']}")


if __name__ == "__main__":
    main()
