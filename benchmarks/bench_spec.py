"""Speculative decoding vs plain greedy decode — tokens/s and acceptance on
a repetitive-text workload (the regime prompt-lookup drafting targets:
templated prompts and decode loops where history predicts the future).

Three arms over the SAME request stream and virtual-clock cost model:

* ``plain``  — ordinary one-token-per-tick greedy decode;
* ``ngram``  — prompt-lookup drafting from each request's own history;
* ``trace``  — static-suffix drafting from the recorded plain-greedy trace
  (replayed traffic: the acceptance-1.0 upper bound of the pipeline).

Exactness is asserted (every arm must emit byte-identical tokens) before any
throughput is reported.  Emits ``BENCH_spec.json`` for the run.py harness.
"""
from __future__ import annotations

import json

import numpy as np

from benchmarks.common import build_model, csv
from repro.serving.engine import EngineConfig, UnifiedEngine
from repro.serving.request import Request
from repro.spec import SpecConfig


def _repetitive_requests(vocab: int, n: int, seed: int,
                         max_new: int) -> list:
    """Templated prompts: a short phrase tiled several times, plus a shared
    boilerplate prefix — the shape of real prompt traffic (system prompts,
    few-shot blocks, code)."""
    rng = np.random.default_rng(seed)
    boiler = rng.integers(0, vocab, 8)
    out = []
    for i in range(n):
        phrase = rng.integers(0, vocab, rng.integers(4, 9))
        reps = rng.integers(3, 6)
        prompt = np.concatenate([boiler] + [phrase] * reps).astype(np.int32)
        out.append(Request(rid=i, prompt=prompt, adapter="lora0",
                           max_new_tokens=max_new, arrival=0.05 * i))
    return out


def _run(model, reqs, spec, *, s_max: int, capacity: int = 6):
    eng = UnifiedEngine(model, EngineConfig(
        capacity=capacity, pf_capacity=4, s_max=s_max, virtual_time=True,
        block_size=16, spec=spec))
    for r in reqs:
        eng.submit(r)
    eng.run(max_ticks=200000)
    m = eng.metrics
    return {"DTPS": m.decode_tokens / max(m.elapsed, 1e-9),
            "decode_tokens": int(m.decode_tokens), "steps": int(m.steps),
            "acceptance": float(m.acceptance_rate),
            "drafted": int(m.spec_drafted),
            "elapsed_virtual": float(m.elapsed),
            "outputs": {r.rid: list(r.output) for r in eng.finished},
            "finished": len(eng.finished)}


def main(n_requests: int = 12, max_new: int = 48, s_max: int = 192,
         k_max: int = 6):
    model = build_model(n_adapters=1)
    vocab = model.cfg.vocab

    def reqs():
        return _repetitive_requests(vocab, n_requests, seed=11,
                                    max_new=max_new)

    plain = _run(model, reqs(), None, s_max=s_max)
    csv("spec/plain", 0.0, f"DTPS={plain['DTPS']:.1f};steps={plain['steps']}")

    ngram = _run(model, reqs(),
                 SpecConfig(k_max=k_max, drafter="ngram"), s_max=s_max)
    assert ngram["outputs"] == plain["outputs"], "spec broke greedy exactness"
    csv("spec/ngram", 0.0,
        f"DTPS={ngram['DTPS']:.1f};acc={ngram['acceptance']:.2f};"
        f"steps={ngram['steps']}")

    trace_reqs = reqs()
    for r in trace_reqs:
        r.draft_suffix = np.concatenate(
            [r.prompt, np.asarray(plain["outputs"][r.rid], np.int64)])
    trace = _run(model, trace_reqs,
                 SpecConfig(k_max=k_max, drafter="suffix", adaptive=False),
                 s_max=s_max)
    assert trace["outputs"] == plain["outputs"], "trace broke greedy exactness"
    csv("spec/trace", 0.0,
        f"DTPS={trace['DTPS']:.1f};acc={trace['acceptance']:.2f};"
        f"steps={trace['steps']}")

    def arm(d):
        return {k: d[k] for k in ("DTPS", "decode_tokens", "steps",
                                  "acceptance", "drafted", "elapsed_virtual",
                                  "finished")}

    ng_speed = ngram["DTPS"] / max(plain["DTPS"], 1e-9)
    tr_speed = trace["DTPS"] / max(plain["DTPS"], 1e-9)
    # headline: the ngram (self-drafting) arm when it clears the bar on this
    # workload, else the trace-replay arm
    if ng_speed >= 1.5 and ngram["acceptance"] >= 0.6:
        head, head_name = (ng_speed, ngram["acceptance"]), "ngram"
    else:
        head, head_name = (tr_speed, trace["acceptance"]), "trace"
    out = {"speedup": float(head[0]), "acceptance": float(head[1]),
           "headline_arm": head_name, "k_max": k_max,
           "workload": {"n_requests": n_requests, "max_new": max_new,
                        "kind": "repetitive-text"},
           "exact": True,
           "plain": arm(plain),
           "ngram": {**arm(ngram), "speedup": float(ng_speed)},
           "trace": {**arm(trace), "speedup": float(tr_speed)}}
    with open("BENCH_spec.json", "w") as f:
        json.dump(out, f, indent=2)
    csv("spec/summary", 0.0,
        f"speedup={out['speedup']:.2f};acceptance={out['acceptance']:.2f};"
        f"arm={head_name}")
    return out


if __name__ == "__main__":
    print(json.dumps(main(), indent=2))
