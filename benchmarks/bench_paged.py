"""Paged vs dense KV cache — max concurrent residents and decode throughput
at a fixed simulated HBM budget, mixed-length prompts.

Two measurements:

* ``capacity``: how many of a mixed-length request stream can be resident at
  once under the same KV-byte budget.  Dense charges every request a full
  ``s_max`` row; paged charges ``ceil(min(prompt+max_new, s_max)/bs)``
  blocks (and shared prefixes once).
* ``engine``: two real engines, same KV-byte budget, same request stream,
  virtual clock.  Reports peak concurrently-decoding requests and decode
  throughput.

Emits ``BENCH_paged.json`` next to the CSV lines for the run.py harness.
"""
from __future__ import annotations

import json

import jax
import numpy as np

from benchmarks.common import LCFG, build_model, csv, make_requests
from repro.models.model import abstract_cache, init_paged_cache
from repro.serving.engine import EngineConfig, UnifiedEngine
from repro.serving.request import Request


def _tree_bytes(tree) -> int:
    return int(sum(x.size * x.dtype.itemsize
                   for x in jax.tree_util.tree_leaves(tree)))


def _dense_row_bytes(cfg, s_max: int) -> int:
    return _tree_bytes(abstract_cache(cfg, 1, s_max))


def _block_bytes(cfg, block_size: int) -> int:
    pool = jax.eval_shape(lambda: init_paged_cache(cfg, 1, block_size, 0))
    return _tree_bytes(pool)


def capacity_at_budget(cfg, requests, budget_bytes: int, s_max: int,
                       block_size: int):
    """Greedy residency packing (arrival order) under the byte budget."""
    row_b = _dense_row_bytes(cfg, s_max)
    blk_b = _block_bytes(cfg, block_size)
    dense = budget_bytes // row_b                    # every row costs s_max
    paged = 0
    spent = 0
    for r in requests:
        need = -(-min(r.prompt_len + r.max_new_tokens, s_max) // block_size)
        if spent + need * blk_b > budget_bytes:
            break
        spent += need * blk_b
        paged += 1
    return int(dense), int(paged), row_b, blk_b


def engine_peak_and_dtps(model, requests, *, paged: bool, capacity: int,
                         s_max: int, block_size: int, n_blocks: int = 0):
    eng = UnifiedEngine(model, EngineConfig(
        capacity=capacity, pf_capacity=4, s_max=s_max, virtual_time=True,
        paged=paged, block_size=block_size, n_blocks=n_blocks))
    for r in requests:
        eng.submit(r)
    peak = 0
    for _ in range(200000):
        busy = eng.tick()
        peak = max(peak, len(eng.active))
        if (not eng.waiting and not eng.active and not eng.future
                and not busy):
            break
    m = eng.metrics
    dtps = m.decode_tokens / max(m.elapsed, 1e-9)
    return {"peak_resident": peak, "decode_tokens": int(m.decode_tokens),
            "finished": len([r for r in eng.finished if r.output]),
            "elapsed_virtual": float(m.elapsed), "DTPS": float(dtps)}


def main(n_requests: int = 48, s_max: int = 192, block_size: int = 16,
         dense_rows: int = 6, max_new: int = 12):
    model = build_model(n_adapters=2)
    cfg = model.cfg
    # mixed-length stream: bursty arrivals so residency, not arrival rate,
    # is the binding constraint
    reqs = make_requests(n_requests, rps=50.0, vocab=cfg.vocab, n_adapters=2,
                         max_new=max_new, seed=7)
    budget = dense_rows * _dense_row_bytes(cfg, s_max)
    dense_cap, paged_cap, row_b, blk_b = capacity_at_budget(
        cfg, reqs, budget, s_max, block_size)
    csv("paged/capacity_dense", 0.0, f"residents={dense_cap}")
    csv("paged/capacity_paged", 0.0,
        f"residents={paged_cap};ratio={paged_cap / max(dense_cap, 1):.2f}")

    n_blocks = 1 + budget // blk_b                   # same bytes as dense
    res_d = engine_peak_and_dtps(model,
                                 make_requests(n_requests, 50.0, cfg.vocab, 2,
                                               max_new=max_new, seed=7),
                                 paged=False, capacity=dense_rows,
                                 s_max=s_max, block_size=block_size)
    res_p = engine_peak_and_dtps(model,
                                 make_requests(n_requests, 50.0, cfg.vocab, 2,
                                               max_new=max_new, seed=7),
                                 paged=True, capacity=4 * dense_rows,
                                 s_max=s_max, block_size=block_size,
                                 n_blocks=int(n_blocks))
    csv("paged/engine_dense", 0.0,
        f"peak={res_d['peak_resident']};DTPS={res_d['DTPS']:.1f}")
    csv("paged/engine_paged", 0.0,
        f"peak={res_p['peak_resident']};DTPS={res_p['DTPS']:.1f}")

    out = {"budget_bytes": int(budget), "s_max": s_max,
           "block_size": block_size,
           "dense_row_bytes": int(row_b), "block_bytes": int(blk_b),
           "capacity": {"dense": dense_cap, "paged": paged_cap,
                        "ratio": paged_cap / max(dense_cap, 1)},
           "engine": {"dense": res_d, "paged": res_p,
                      "peak_ratio": (res_p["peak_resident"]
                                     / max(res_d["peak_resident"], 1))}}
    with open("BENCH_paged.json", "w") as f:
        json.dump(out, f, indent=2)
    csv("paged/summary", 0.0,
        f"capacity_ratio={out['capacity']['ratio']:.2f};"
        f"peak_ratio={out['engine']['peak_ratio']:.2f}")
    return out


if __name__ == "__main__":
    print(json.dumps(main(), indent=2))
