"""Kernel-level microbenches.

Part 1 — SMLM: ONE batched multi-LoRA call (the SMLM design) vs the
traditional serial per-adapter loop the paper replaces (Section 3.3).
Measured with the jnp oracle on CPU (the Pallas kernel targets TPU); also
reports kernel-invocation counts, the paper's other win.

Part 2 — paged attention: sequential block-table walk vs the flash-decoding
split-K family (``kernels.splitk``), swept over decode/verify shapes.
Exactness is REAL (both kernels run in interpret mode against each other
and the jnp oracle, same KV pool — equal HBM by construction); throughput
is the occupancy model from ``kernels.autotune`` (waves of concurrent grid
cells), because grid parallelism is not observable on the CPU interpreter —
on a real TPU, pass a wall-clock ``measure`` to ``autotune.sweep``.  The
sweep also populates the autotune table and writes it to ``attn_tune.json``
(load with ``serve.py --attn-tune-file``).

Emits ``BENCH_kernels.json`` for the run.py harness / CI gate.
"""
from __future__ import annotations

import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import csv
from repro.kernels import autotune, ref
from repro.kernels.decode_attn import (paged_decode_attention,
                                       paged_verify_attention)
from repro.kernels.splitk import (paged_decode_attention_splitk,
                                  paged_verify_attention_splitk)


def _serial_loop(x, a, b, ids, n):
    """One matmul pair PER ADAPTER (masked) — 2n kernel calls."""
    y = jnp.zeros((x.shape[0], b.shape[-1]), x.dtype)
    for i in range(n):
        m = (ids == i)[:, None].astype(x.dtype)
        y = y + ((x * m) @ a[i]) @ b[i]
    return y


def _bench(fn, *args, iters=20):
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else \
        jax.block_until_ready(fn(*args))
    t0 = time.monotonic()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.monotonic() - t0) / iters


def smlm_micro(T: int = 4096, d: int = 512, r: int = 8, o: int = 512):
    for n in (2, 4, 8):
        ks = jax.random.split(jax.random.PRNGKey(n), 4)
        x = jax.random.normal(ks[0], (T, d))
        a = jax.random.normal(ks[1], (n, d, r))
        b = jax.random.normal(ks[2], (n, r, o))
        ids = jax.random.randint(ks[3], (T,), 0, n)
        scale = jnp.ones((T,))
        batched = jax.jit(lambda x, a, b, i: ref.bgmv_ref(x, a, b, i, scale))
        serial = jax.jit(lambda x, a, b, i: _serial_loop(x, a, b, i, n))
        tb = _bench(batched, x, a, b, ids)
        ts = _bench(serial, x, a, b, ids)
        csv(f"kernels/smlm_batched_n{n}", tb * 1e6,
            f"serial_us={ts * 1e6:.0f};speedup={ts / tb:.2f}x;"
            f"kernel_calls=1_vs_{2 * n}")


# --------------------------------------------------- split-K attention sweep

# decode arms: (B, h, g, hd, bs, nbt).  The long-context/small-batch arm is
# the one flash decoding exists for (B*h alone cannot fill the lanes); the
# batched arm shows the heuristic correctly declining to split.
ARMS = {
    "long_ctx_small_batch": dict(B=1, h=4, g=2, hd=64, bs=16, nbt=32),
    "long_ctx_batched": dict(B=8, h=4, g=2, hd=64, bs=16, nbt=32),
    "short_ctx_small_batch": dict(B=2, h=4, g=2, hd=32, bs=16, nbt=4),
}


def _paged_problem(B, h, g, hd, bs, nbt, seed=0, Sq=0):
    """Random pool + scattered non-contiguous tables + ragged positions."""
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    n_blocks = nbt * B + 2
    k_pool = jax.random.normal(ks[0], (n_blocks, bs, g, hd))
    v_pool = jax.random.normal(ks[1], (n_blocks, bs, g, hd))
    rng = np.random.default_rng(seed)
    span = nbt * bs - max(Sq, 1)
    pos = np.array([span - 1 - rng.integers(0, max(span // 3, 1))
                    for _ in range(B)], np.int64)
    tables = np.zeros((B, nbt), np.int32)
    for b in range(B):
        need = (pos[b] + max(Sq, 1)) // bs + 1
        tables[b, :need] = rng.choice(np.arange(1, n_blocks), size=need,
                                      replace=False)
    qshape = (B, h, hd) if Sq == 0 else (B, Sq, h, hd)
    q = jax.random.normal(ks[2], qshape)
    return q, k_pool, v_pool, jnp.asarray(tables), jnp.asarray(pos, jnp.int32)


def _allclose(a, b, tol=2e-5):
    return bool(np.allclose(np.asarray(a, np.float32),
                            np.asarray(b, np.float32), rtol=tol, atol=tol))


def _arm_result(name, spec):
    B, h, g, hd, bs, nbt = (spec[k] for k in ("B", "h", "g", "hd", "bs",
                                              "nbt"))
    bh = B * h
    cfg = autotune.choose(hd, bs, nbt, bh)
    ns = cfg.num_splits

    # decode exactness: split-K vs the sequential kernel vs the jnp oracle,
    # over the SAME pool (equal HBM — split-K adds only O(ns*B*h*hd) fp32
    # partials, transient epilogue traffic, not pool residency)
    q, kp, vp, tbl, pos = _paged_problem(B, h, g, hd, bs, nbt, seed=hash(name) % 1000)
    y_seq = paged_decode_attention(q, kp, vp, tbl, pos, interpret=True)
    y_spl = paged_decode_attention_splitk(q, kp, vp, tbl, pos,
                                          num_splits=max(ns, 2),
                                          interpret=True)
    y_ref = ref.paged_decode_ref(q, kp, vp, tbl, pos)
    exact = (_allclose(y_spl, y_seq) and _allclose(y_spl, y_ref))

    # verify-chunk exactness on the same geometry (Sq = 4, ragged lens)
    Sq = 4
    qv, kpv, vpv, tblv, posv = _paged_problem(B, h, g, hd, bs, nbt, seed=7,
                                              Sq=Sq)
    lens = jnp.asarray(np.random.default_rng(7).integers(1, Sq + 1, B),
                       jnp.int32)
    yv_seq = paged_verify_attention(qv, kpv, vpv, tblv, posv, lens,
                                    interpret=True)
    yv_spl = paged_verify_attention_splitk(qv, kpv, vpv, tblv, posv, lens,
                                           num_splits=max(ns, 2),
                                           interpret=True)
    exact = exact and _allclose(yv_spl, yv_seq, tol=3e-5)

    t_seq = autotune.modeled_grid_time(bh, nbt, 1)
    t_spl = autotune.modeled_grid_time(bh, nbt, ns)
    speedup = t_seq / t_spl
    csv(f"kernels/splitk_{name}", t_spl,
        f"seq_t={t_seq:.2f};num_splits={ns};speedup={speedup:.2f}x;"
        f"exact={exact}")
    return {"B": B, "h": h, "bh": bh, "hd": hd, "bs": bs, "nbt": nbt,
            "num_splits": ns, "exact": exact,
            "seq_modeled_t": t_seq, "splitk_modeled_t": t_spl,
            "speedup": round(speedup, 3), "equal_hbm": True,
            "pool_bytes": int(kp.size * kp.dtype.itemsize * 2)}


def splitk_sweep():
    arms = {name: _arm_result(name, spec) for name, spec in ARMS.items()}

    # populate + persist the autotune table for the swept shapes (occupancy
    # model on CPU; a TPU run passes measure= for wall-clock scoring)
    shapes = [(s["hd"], s["bs"], s["nbt"], s["bh"]) for s in arms.values()]
    autotune.sweep(shapes)
    n_entries = autotune.save_table("attn_tune.json")

    long_ctx = arms["long_ctx_small_batch"]
    doc = {
        "exact": all(a["exact"] for a in arms.values()),
        "arms": arms,
        "long_ctx": {"nbt": long_ctx["nbt"], "speedup": long_ctx["speedup"],
                     "num_splits": long_ctx["num_splits"],
                     "equal_hbm": long_ctx["equal_hbm"],
                     "exact": long_ctx["exact"]},
        "tuned_entries": n_entries,
        "tuning_table": "attn_tune.json",
        "throughput_model": "autotune.modeled_grid_time (occupancy waves); "
                            "exactness is measured, interpret-mode kernels",
    }
    with open("BENCH_kernels.json", "w") as f:
        json.dump(doc, f, indent=2, sort_keys=True)
        f.write("\n")
    csv("kernels/splitk_long_ctx", long_ctx["splitk_modeled_t"],
        f"speedup={long_ctx['speedup']:.2f}x;"
        f"num_splits={long_ctx['num_splits']};exact={doc['exact']}")


def main(T: int = 4096, d: int = 512, r: int = 8, o: int = 512):
    smlm_micro(T, d, r, o)
    splitk_sweep()


if __name__ == "__main__":
    main()
