"""Kernel-level microbench: ONE batched multi-LoRA call (the SMLM design)
vs the traditional serial per-adapter loop the paper replaces (Section 3.3).
Measured with the jnp oracle on CPU (the Pallas kernel targets TPU); also
reports kernel-invocation counts, the paper's other win."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from benchmarks.common import csv
from repro.kernels import ref


def _serial_loop(x, a, b, ids, n):
    """One matmul pair PER ADAPTER (masked) — 2n kernel calls."""
    y = jnp.zeros((x.shape[0], b.shape[-1]), x.dtype)
    for i in range(n):
        m = (ids == i)[:, None].astype(x.dtype)
        y = y + ((x * m) @ a[i]) @ b[i]
    return y


def _bench(fn, *args, iters=20):
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else \
        jax.block_until_ready(fn(*args))
    t0 = time.monotonic()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.monotonic() - t0) / iters


def main(T: int = 4096, d: int = 512, r: int = 8, o: int = 512):
    for n in (2, 4, 8):
        ks = jax.random.split(jax.random.PRNGKey(n), 4)
        x = jax.random.normal(ks[0], (T, d))
        a = jax.random.normal(ks[1], (n, d, r))
        b = jax.random.normal(ks[2], (n, r, o))
        ids = jax.random.randint(ks[3], (T,), 0, n)
        scale = jnp.ones((T,))
        batched = jax.jit(lambda x, a, b, i: ref.bgmv_ref(x, a, b, i, scale))
        serial = jax.jit(lambda x, a, b, i: _serial_loop(x, a, b, i, n))
        tb = _bench(batched, x, a, b, ids)
        ts = _bench(serial, x, a, b, ids)
        csv(f"kernels/smlm_batched_n{n}", tb * 1e6,
            f"serial_us={ts * 1e6:.0f};speedup={ts / tb:.2f}x;"
            f"kernel_calls=1_vs_{2 * n}")


if __name__ == "__main__":
    main()
