"""Figure 3 — fine-tuning-only: FTPS for single and concurrent multi-LoRA.
PEFT can only fine-tune one adapter at a time (cumulative time); Loquetier
shares one backward pass across trainers."""
from __future__ import annotations

from benchmarks.common import (PeftLikeServer, build_engine, build_model,
                               csv)
from repro.data import datasets
from repro.training.trainer import MixedLoraTrainer, TrainerConfig


def main(n_rows: int = 32, epochs: int = 1):
    for label, n_tr in (("single", 1), ("multi", 2)):
        model = build_model(n_adapters=max(2, n_tr))
        eng = build_engine(model, capacity=2)
        rows_by_tr = []
        for i in range(n_tr):
            rows, ev = datasets.split_eval(
                datasets.gsm8k_like(n_rows, vocab=model.cfg.vocab, seed=i))
            rows_by_tr.append(rows)
            # paper Table 5: per_device_train_batch_size=2 (1 when multi)
            eng.add_trainer(MixedLoraTrainer(
                f"lora{i}", model.store.slot_of(f"lora{i}"), rows, ev,
                TrainerConfig(rows_per_micro=2 if n_tr == 1 else 1,
                              accum_steps=4, epochs=epochs)))
        m = eng.run(max_ticks=500000)
        rates = m.rates()
        losses = {n: (t.train_losses[0], t.train_losses[-1])
                  for n, t in eng.trainers.items()}
        csv(f"finetune/loquetier_{label}", 0.0,
            f"FTPS={rates['FTPS']:.1f};ETPS={rates['ETPS']:.1f};"
            f"loss0={losses['lora0'][0]:.3f};lossN={losses['lora0'][1]:.3f}")
        # PEFT: serial per adapter -> cumulative time (same microbatch=2)
        ftps = PeftLikeServer(batch_size=2).finetune_tokens_per_s(
            rows_by_tr[0] * epochs, adapters_serial=n_tr)
        ftps_eff = ftps / n_tr if n_tr > 1 else ftps
        csv(f"finetune/peft_like_{label}", 0.0,
            f"FTPS={ftps_eff:.1f} (serial x{n_tr})")


if __name__ == "__main__":
    main()
