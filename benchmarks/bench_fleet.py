"""Fleet serving benchmark: affinity routing + fleet-wide block index vs
locality-blind replication.

A Zipf-popular multi-adapter template trace (T templates, each its own
adapter + hot head, per-request random tail; one seeder per template
publishes its head, then the remaining requests arrive as one burst) over
THREE fleet arms at EQUAL TOTAL HBM (identical per-replica pools, same
replica count) plus a single-engine exactness reference:

* ``round_robin``   — independent replicas, local dedup only (no fleet
  index traffic): the locality-blind baseline.  Every replica recomputes
  every template head it meets.
* ``affinity``      — prefix/adapter-affinity routing + remote block fetch:
  hot-template requests land where the head lives; overflow spills to cold
  replicas (the router's load penalty is unbounded) which FETCH the head
  over the modeled interconnect instead of recomputing it.
* ``rr_fetch``      — round-robin WITH remote fetch: isolates the fleet
  index from routing.  Placement is forced off-template, so every
  first-encounter of a (replica, template) pair whose head is published
  elsewhere must fetch — the analytically-expected fetch count, which the
  measured count is gated against (``fetch_hit_rate``).

Byte-exactness of every arm against the single engine is asserted FIRST
(remote fetch copies published CoW-immutable K/V; replicas share base
weights by reference and carry identically-loaded adapters), then the
headline: fleet prompt tokens/s, gated >= 1.3x over round_robin.

Emits ``BENCH_fleet.json`` for the run.py harness / CI gate (gate.py +
gates.json).
"""
from __future__ import annotations

import json

import numpy as np

from benchmarks.common import build_model, csv
from repro.fleet import FleetConfig, RouterConfig, build_fleet
from repro.serving.clock import CostModel
from repro.serving.engine import EngineConfig, UnifiedEngine
from repro.serving.request import Request

COST = CostModel(fixed=1e-3, prefill_per_tok=1e-4)   # prefill-bound regime
REPLICAS = 3
TEMPLATES = 3
HEAD = 832                                  # 26 blocks of 32
PROMPT = 1024
BLOCK = 32
N_REQUESTS = 18
ZIPF_S = 1.1
BURST_AT = 0.2                              # seeders publish, then the flood
# burst-tuned router: with the whole flood queued at once, a stronger load
# penalty lets hot-template pressure spill to cold replicas (which then
# remote-fetch the head instead of recomputing it)
LOAD_PENALTY = 0.25


def _trace(vocab: int, seed: int = 0):
    """Seeder per template (sequenced so each head is published before the
    burst), then Zipf-drawn template picks arriving at once.  Returns the
    requests and the rid-ordered template assignment."""
    rng = np.random.default_rng(seed)
    heads = [rng.integers(0, vocab, HEAD).astype(np.int32)
             for _ in range(TEMPLATES)]
    w = 1.0 / np.arange(1, TEMPLATES + 1) ** ZIPF_S
    picks = rng.choice(TEMPLATES, size=N_REQUESTS - TEMPLATES, p=w / w.sum())
    templates = list(range(TEMPLATES)) + picks.tolist()
    reqs = []
    for rid, t in enumerate(templates):
        tail = rng.integers(0, vocab, PROMPT - HEAD).astype(np.int32)
        # seeders all arrive at t=0: dispatched back-to-back before any
        # engine ticks, the depth penalty spreads them one per replica, so
        # each template's head is published on its own engine
        arrival = 0.0 if rid < TEMPLATES else BURST_AT
        reqs.append(Request(rid=rid, prompt=np.concatenate([heads[t], tail]),
                            adapter=f"lora{t}", max_new_tokens=1,
                            arrival=arrival))
    return reqs, templates


def _ecfg():
    return EngineConfig(capacity=6, pf_capacity=4, s_max=PROMPT + BLOCK,
                        block_size=BLOCK, virtual_time=True, cost=COST)


def _outputs(finished):
    return {r.rid: list(r.output) for r in finished}


def _run_single(model, vocab, seed):
    eng = UnifiedEngine(model, _ecfg())
    reqs, _ = _trace(vocab, seed)
    for r in reqs:
        eng.submit(r)
    eng.run(max_ticks=200000)
    assert len(eng.finished) == N_REQUESTS
    return _outputs(eng.finished)


def _run_fleet(vocab, seed, policy, remote_fetch):
    model = build_model(n_adapters=TEMPLATES)
    fleet = build_fleet(model, _ecfg(), FleetConfig(
        replicas=REPLICAS,
        router=RouterConfig(policy=policy, load_penalty=LOAD_PENALTY),
        remote_fetch=remote_fetch))
    reqs, _ = _trace(vocab, seed)
    for r in reqs:
        fleet.submit(r)
    fm = fleet.run(max_ticks=200000)
    fleet.index.check_bijection()           # zero stale entries, ever
    finished = [r for e in fleet.engines for r in e.finished]
    assert len(finished) == N_REQUESTS
    prompt_tok = fm.prefill_tokens + fm.reused_prefix_tokens
    return {"prompt_tokens": int(prompt_tok),
            "computed_tokens": int(fm.prefill_tokens),
            "reused_tokens": int(fm.reused_prefix_tokens),
            "hash_hits": int(fm.hash_hits),
            "remote_fetch_blocks": int(fm.remote_fetch_blocks),
            "remote_fetch_time": float(fm.remote_fetch_time),
            "elapsed_virtual": float(fm.elapsed),
            "PTPS": prompt_tok / max(fm.elapsed, 1e-9),
            "steps": int(fm.steps),
            "routed": {str(k): int(v) for k, v in fleet.routed.items()},
            "leak_free": bool(all(e.cachemgr.pristine
                                  for e in fleet.engines)),
            "pool_blocks_per_replica": int(
                fleet.engines[0].cachemgr.total_blocks),
            "outputs": _outputs(finished)}


def _expected_rr_fetches(templates) -> int:
    """Analytic fetch count for round-robin + fetch: the first time each
    replica meets a template whose head was published (by an earlier rid,
    anywhere) it imports all adoptable head blocks; later encounters adopt
    locally.  The chain cap never binds (the tail keeps >= 1 token
    computable past the 26 head blocks)."""
    head_blocks = HEAD // BLOCK
    seen_global, seen_replica = set(), [set() for _ in range(REPLICAS)]
    expected = 0
    for rid, t in enumerate(templates):
        rep = rid % REPLICAS
        if t in seen_global and t not in seen_replica[rep]:
            expected += head_blocks
        seen_global.add(t)
        seen_replica[rep].add(t)
    return expected


def _strip(d):
    return {k: v for k, v in d.items() if k != "outputs"}


def main(seed: int = 0):
    model = build_model(n_adapters=TEMPLATES)
    vocab = model.cfg.vocab
    reqs, templates = _trace(vocab, seed)
    del reqs

    ref = _run_single(model, vocab, seed)
    rr = _run_fleet(vocab, seed, "round-robin", remote_fetch=False)
    af = _run_fleet(vocab, seed, "affinity", remote_fetch=True)
    rrf = _run_fleet(vocab, seed, "round-robin", remote_fetch=True)

    # exactness before any throughput claim: replica placement, remote
    # fetch, and router policy must all be invisible in the bytes
    for name, arm in (("round_robin", rr), ("affinity", af),
                      ("rr_fetch", rrf)):
        assert arm["outputs"] == ref, f"{name} broke byte-exactness"
    assert rr["remote_fetch_blocks"] == 0          # fetch disabled
    assert af["remote_fetch_blocks"] > 0           # spillover fetched
    equal_hbm = (rr["pool_blocks_per_replica"]
                 == af["pool_blocks_per_replica"]
                 == rrf["pool_blocks_per_replica"])

    speedup = af["PTPS"] / max(rr["PTPS"], 1e-9)
    expected = _expected_rr_fetches(templates)
    fetch_hit_rate = rrf["remote_fetch_blocks"] / max(expected, 1)

    csv("fleet/round_robin", 0.0, f"PTPS={rr['PTPS']:.0f};"
        f"computed={rr['computed_tokens']};steps={rr['steps']}")
    csv("fleet/affinity", 0.0, f"PTPS={af['PTPS']:.0f};"
        f"computed={af['computed_tokens']};"
        f"fetched={af['remote_fetch_blocks']};speedup={speedup:.2f}")
    csv("fleet/rr_fetch", 0.0, f"PTPS={rrf['PTPS']:.0f};"
        f"fetched={rrf['remote_fetch_blocks']};"
        f"expected={expected};hit_rate={fetch_hit_rate:.2f}")

    out = {"exact": True, "speedup": float(speedup),
           "fetch_hit_rate": float(fetch_hit_rate),
           "expected_rr_fetches": int(expected),
           "arms_leak_free": bool(rr["leak_free"] and af["leak_free"]
                                  and rrf["leak_free"]),
           "equal_hbm": bool(equal_hbm),
           "replicas": REPLICAS, "block_size": BLOCK,
           "workload": {"n_requests": N_REQUESTS, "templates": TEMPLATES,
                        "zipf_s": ZIPF_S, "prompt": PROMPT, "head": HEAD,
                        "kind": "zipf-multi-adapter-templates"},
           "round_robin": _strip(rr), "affinity": _strip(af),
           "rr_fetch": _strip(rrf)}
    with open("BENCH_fleet.json", "w") as f:
        json.dump(out, f, indent=2)
    csv("fleet/summary", 0.0, f"speedup={speedup:.2f};"
        f"fetch_hit_rate={fetch_hit_rate:.2f};exact=True")
    return out


if __name__ == "__main__":
    print(json.dumps(main(), indent=2))
