"""Prefix-aware suffix-only prefill + chunked prefill benchmarks.

Two workloads over the SAME substrate and virtual-clock cost model (a
prefill-bound regime: 0.1 ms/prefill-token, the long-prompt serving shape
prefix caching targets), at EQUAL HBM budget (same block pool in every arm):

* ``throughput`` — templated traffic: N prompts sharing an ~80% prefix.
  Arms: ``plain`` (``hash_dedup=False`` — every prompt recomputes
  everything), ``shared`` (content-hash adoption -> suffix-only prefill),
  and ``chunked`` (suffix-only + per-tick prefill-token budget).
  Exactness is asserted (all arms byte-identical tokens) before any
  throughput is reported; the headline is prompt tokens per second —
  suffix-only compute serves the same prompt tokens in less time.
* ``ttft_under_load`` — a long prompt lands while short requests decode.
  Unchunked, its whole prefill rides one step and every decoder stalls
  behind it; chunked, the budget bounds each step and decode rows flow in
  EVERY step (asserted: no decode-starved ticks, per-step prefill tokens
  <= budget, smaller worst-case decode gap).

Emits ``BENCH_prefix.json`` for the run.py harness / CI gate.
"""
from __future__ import annotations

import json

import numpy as np

from benchmarks.common import build_model, csv
from repro.serving.clock import CostModel
from repro.serving.engine import EngineConfig, UnifiedEngine
from repro.serving.request import Request

COST = CostModel(prefill_per_tok=1e-4)     # prefill-bound serving regime
PROMPT = 1024
PREFIX = 832                               # 26 blocks of 32 -> 81.25% share
BLOCK = 32


def _shared_requests(vocab: int, n: int, seed: int) -> list:
    """Templated prompts: one hot system/few-shot prefix + per-request
    tail.  The first request arrives alone so its prefill publishes the
    prefix blocks before the rest admit."""
    rng = np.random.default_rng(seed)
    prefix = rng.integers(0, vocab, PREFIX).astype(np.int32)
    out = []
    for i in range(n):
        tail = rng.integers(0, vocab, PROMPT - PREFIX).astype(np.int32)
        out.append(Request(rid=i, prompt=np.concatenate([prefix, tail]),
                           adapter="lora0", max_new_tokens=1,
                           arrival=0.0 if i == 0 else 0.3))
    return out


def _engine(model, **kw):
    kw = {"capacity": 6, "pf_capacity": 4, "s_max": PROMPT + BLOCK,
          "block_size": BLOCK, "virtual_time": True, "cost": COST, **kw}
    return UnifiedEngine(model, EngineConfig(**kw))


def _run_arm(model, reqs, **kw):
    eng = _engine(model, **kw)
    for r in reqs:
        eng.submit(r)
    eng.run(max_ticks=100000)
    m = eng.metrics
    prompt_tok = m.prefill_tokens + m.reused_prefix_tokens
    return {"prompt_tokens": int(prompt_tok),
            "computed_tokens": int(m.prefill_tokens),
            "reused_tokens": int(m.reused_prefix_tokens),
            "elapsed_virtual": float(m.elapsed),
            "PTPS": prompt_tok / max(m.elapsed, 1e-9),
            "steps": int(m.steps),
            "max_pf_tokens_step": int(m.max_pf_tokens_step),
            "starved_ticks": int(m.starved_ticks),
            "outputs": {r.rid: list(r.output) for r in eng.finished},
            "finished": len(eng.finished)}


def _strip(d):
    return {k: v for k, v in d.items() if k != "outputs"}


def _ttft_arm(model, prefill_chunk: int):
    eng = _engine(model, capacity=4, prefill_chunk=prefill_chunk)
    back = [Request(rid=i, prompt=np.arange(16, dtype=np.int32),
                    adapter="lora0", max_new_tokens=30, arrival=0.0)
            for i in range(3)]
    rng = np.random.default_rng(5)
    long_r = Request(rid=9, prompt=rng.integers(0, model.cfg.vocab, PROMPT)
                     .astype(np.int32), adapter="lora0", max_new_tokens=2,
                     arrival=0.3)
    for r in back + [long_r]:
        eng.submit(r)
    eng.run(max_ticks=100000)
    m = eng.metrics
    gaps = [r.decode_latencies() for r in eng.finished if r.rid != 9]
    max_gap = float(max(g.max() for g in gaps if g.size))
    return {"max_decode_gap_s": max_gap,
            "ttft_long_s": float(long_r.waiting_time()),
            "max_pf_tokens_step": int(m.max_pf_tokens_step),
            "starved_ticks": int(m.starved_ticks),
            "outputs": {r.rid: list(r.output) for r in eng.finished},
            "finished": len(eng.finished)}


def main(n_requests: int = 6, chunk: int = 128):
    model = build_model(n_adapters=1)
    vocab = model.cfg.vocab

    def reqs():
        return _shared_requests(vocab, n_requests, seed=3)

    plain = _run_arm(model, reqs(), hash_dedup=False)
    shared = _run_arm(model, reqs())
    chunked = _run_arm(model, reqs(), prefill_chunk=chunk)
    # exactness first: suffix-only and chunked prefill must be
    # byte-identical to full-prompt prefill
    assert shared["outputs"] == plain["outputs"], \
        "suffix-only prefill broke exactness"
    assert chunked["outputs"] == plain["outputs"], \
        "chunked prefill broke exactness"
    assert plain["finished"] == shared["finished"] == n_requests
    speedup = shared["PTPS"] / max(plain["PTPS"], 1e-9)
    share = PREFIX / PROMPT
    csv("prefix/plain", 0.0, f"PTPS={plain['PTPS']:.0f};"
        f"steps={plain['steps']}")
    csv("prefix/shared", 0.0, f"PTPS={shared['PTPS']:.0f};"
        f"reused={shared['reused_tokens']};speedup={speedup:.2f}")
    csv("prefix/chunked", 0.0, f"PTPS={chunked['PTPS']:.0f};"
        f"max_pf_step={chunked['max_pf_tokens_step']}")

    ttft_plain = _ttft_arm(model, prefill_chunk=0)
    ttft_chunk = _ttft_arm(model, prefill_chunk=chunk)
    assert ttft_chunk["outputs"] == ttft_plain["outputs"], \
        "chunked prefill broke exactness under decode load"
    assert ttft_chunk["starved_ticks"] == 0
    assert ttft_chunk["max_pf_tokens_step"] <= chunk
    csv("prefix/ttft", 0.0,
        f"gap_unchunked={ttft_plain['max_decode_gap_s'] * 1e3:.0f}ms;"
        f"gap_chunked={ttft_chunk['max_decode_gap_s'] * 1e3:.0f}ms")

    out = {"speedup": float(speedup), "prefix_share": float(share),
           "exact": True, "block_size": BLOCK, "prefill_chunk": chunk,
           "workload": {"n_requests": n_requests, "prompt": PROMPT,
                        "prefix": PREFIX, "kind": "templated-prompts"},
           "plain": _strip(plain), "shared": _strip(shared),
           "chunked": {**_strip(chunked),
                       "speedup": float(chunked["PTPS"]
                                        / max(plain["PTPS"], 1e-9))},
           "ttft_under_load": {"unchunked": _strip(ttft_plain),
                               "chunked": _strip(ttft_chunk)}}
    with open("BENCH_prefix.json", "w") as f:
        json.dump(out, f, indent=2)
    csv("prefix/summary", 0.0,
        f"speedup={speedup:.2f}@{share:.0%}-share;exact=True")
    return out


if __name__ == "__main__":
    print(json.dumps(main(), indent=2))
