# One function per paper table/figure. Prints ``name,us_per_call,derived`` CSV.
from __future__ import annotations

import sys
import time
import traceback

from benchmarks import (bench_finetune, bench_inference, bench_kernels,
                        bench_loading, bench_mutable, bench_paged,
                        bench_preempt, bench_prefix, bench_realworld,
                        bench_roofline, bench_spec, bench_unified)

TABLES = [
    ("table2_loading", bench_loading.main),
    ("fig2_inference", bench_inference.main),
    ("fig3_finetune", bench_finetune.main),
    ("fig4_unified", bench_unified.main),
    ("fig5_mutable", bench_mutable.main),
    ("fig6_realworld", bench_realworld.main),
    ("kernels_micro", bench_kernels.main),
    ("roofline_table", bench_roofline.main),
    ("paged_cache", bench_paged.main),
    ("spec_decode", bench_spec.main),
    ("prefix_prefill", bench_prefix.main),
    ("preempt_overadmit", bench_preempt.main),
]


def main() -> None:
    print("name,us_per_call,derived")
    failures = 0
    for name, fn in TABLES:
        t0 = time.monotonic()
        print(f"# --- {name} ---")
        try:
            fn()
        except Exception as e:  # noqa: BLE001
            failures += 1
            traceback.print_exc()
            print(f"{name},0.0,ERROR={type(e).__name__}")
        print(f"# {name} took {time.monotonic() - t0:.1f}s")
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
