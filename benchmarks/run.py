# One function per paper table/figure. Prints ``name,us_per_call,derived`` CSV.
from __future__ import annotations

import os
import sys
import time
import traceback

from benchmarks import (bench_adapters, bench_dedup, bench_finetune,
                        bench_fleet, bench_inference, bench_kernels,
                        bench_loading, bench_mutable, bench_paged,
                        bench_preempt, bench_prefix, bench_realworld,
                        bench_roofline, bench_spec, bench_unified)

# (table name, entry point, BENCH artifact the run must (re)write — None
# for CSV-only benches).  A registered artifact that is missing or stale
# after the run is a FAILURE: the CI bench matrix gates on these files, and
# a silently-skipped write would green-pass on yesterday's numbers.
TABLES = [
    ("table2_loading", bench_loading.main, None),
    ("fig2_inference", bench_inference.main, None),
    ("fig3_finetune", bench_finetune.main, None),
    ("fig4_unified", bench_unified.main, None),
    ("fig5_mutable", bench_mutable.main, None),
    ("fig6_realworld", bench_realworld.main, None),
    ("kernels_micro", bench_kernels.main, "BENCH_kernels.json"),
    ("roofline_table", bench_roofline.main, None),
    ("paged_cache", bench_paged.main, "BENCH_paged.json"),
    ("spec_decode", bench_spec.main, "BENCH_spec.json"),
    ("prefix_prefill", bench_prefix.main, "BENCH_prefix.json"),
    ("preempt_overadmit", bench_preempt.main, "BENCH_preempt.json"),
    ("hash_dedup", bench_dedup.main, "BENCH_dedup.json"),
    ("fleet_serving", bench_fleet.main, "BENCH_fleet.json"),
    ("adapter_paging", bench_adapters.main, "BENCH_adapters.json"),
]


def check_artifact(artifact, started_at: float) -> str:
    """'' when the registered artifact exists and was written during this
    run; otherwise a reason string (missing, or stale from an earlier
    run)."""
    if artifact is None:
        return ""
    if not os.path.exists(artifact):
        return f"benchmark wrote no {artifact}"
    if os.path.getmtime(artifact) < started_at:
        return f"{artifact} is stale (not rewritten by this run)"
    return ""


def main() -> None:
    print("name,us_per_call,derived")
    failures = 0
    for name, fn, artifact in TABLES:
        t0 = time.monotonic()
        wall0 = time.time()
        print(f"# --- {name} ---")
        try:
            fn()
        except Exception as e:  # noqa: BLE001
            failures += 1
            traceback.print_exc()
            print(f"{name},0.0,ERROR={type(e).__name__}")
        else:
            reason = check_artifact(artifact, wall0)
            if reason:
                failures += 1
                print(f"{name},0.0,ERROR=MissingArtifact ({reason})")
        print(f"# {name} took {time.monotonic() - t0:.1f}s")
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
