# One function per paper table/figure. Prints ``name,us_per_call,derived``
# CSV, and writes ``BENCH_summary.json`` — one trend row per bench (headline
# metric + wall time) so CI can publish a single cross-bench artifact.
from __future__ import annotations

import argparse
import json
import os
import sys
import time
import traceback

from benchmarks import (bench_adapters, bench_dedup, bench_finetune,
                        bench_fleet, bench_inference, bench_kernels,
                        bench_loading, bench_mutable, bench_paged,
                        bench_preempt, bench_prefix, bench_realworld,
                        bench_roofline, bench_spec, bench_tiers,
                        bench_unified)
from benchmarks.gate import GateError, resolve

# (table name, entry point, BENCH artifact the run must (re)write — None
# for CSV-only benches).  A registered artifact that is missing or stale
# after the run is a FAILURE: the CI bench matrix gates on these files, and
# a silently-skipped write would green-pass on yesterday's numbers.
TABLES = [
    ("table2_loading", bench_loading.main, None),
    ("fig2_inference", bench_inference.main, None),
    ("fig3_finetune", bench_finetune.main, None),
    ("fig4_unified", bench_unified.main, None),
    ("fig5_mutable", bench_mutable.main, None),
    ("fig6_realworld", bench_realworld.main, None),
    ("kernels_micro", bench_kernels.main, "BENCH_kernels.json"),
    ("roofline_table", bench_roofline.main, None),
    ("paged_cache", bench_paged.main, "BENCH_paged.json"),
    ("spec_decode", bench_spec.main, "BENCH_spec.json"),
    ("prefix_prefill", bench_prefix.main, "BENCH_prefix.json"),
    ("preempt_overadmit", bench_preempt.main, "BENCH_preempt.json"),
    ("hash_dedup", bench_dedup.main, "BENCH_dedup.json"),
    ("fleet_serving", bench_fleet.main, "BENCH_fleet.json"),
    ("adapter_paging", bench_adapters.main, "BENCH_adapters.json"),
    ("tiers_memory", bench_tiers.main, "BENCH_tiers.json"),
]

# headline metric per artifact: the one number a trend dashboard plots.
# Resolved with the gate's own path resolver so a renamed field fails
# loudly here too instead of silently charting nulls.
HEADLINES = {
    "BENCH_kernels.json": "long_ctx.speedup",
    "BENCH_paged.json": "engine.peak_ratio",
    "BENCH_spec.json": "speedup",
    "BENCH_prefix.json": "speedup",
    "BENCH_preempt.json": "speedup",
    "BENCH_dedup.json": "speedup",
    "BENCH_fleet.json": "speedup",
    "BENCH_adapters.json": "speedup",
    "BENCH_tiers.json": "speedup",
}

SUMMARY = "BENCH_summary.json"


def check_artifact(artifact, started_at: float) -> str:
    """'' when the registered artifact exists and was written during this
    run; otherwise a reason string (missing, or stale from an earlier
    run)."""
    if artifact is None:
        return ""
    if not os.path.exists(artifact):
        return f"benchmark wrote no {artifact}"
    if os.path.getmtime(artifact) < started_at:
        return f"{artifact} is stale (not rewritten by this run)"
    return ""


def headline_of(artifact: str, artifact_dir: str = "."):
    """(path, value) headline for an artifact, or (None, None) when the
    bench has no registered headline or the artifact is absent."""
    path = HEADLINES.get(artifact)
    p = os.path.join(artifact_dir, artifact)
    if path is None or not os.path.exists(p):
        return None, None
    with open(p) as f:
        doc = json.load(f)
    return path, resolve(doc, path)[0]


def write_summary(rows, artifact_dir: str = ".") -> None:
    out = os.path.join(artifact_dir, SUMMARY)
    with open(out, "w") as f:
        json.dump({"benches": rows}, f, indent=1)
    print(f"# wrote {SUMMARY} ({len(rows)} row(s))")


def summarize_only(artifact_dir: str = ".") -> int:
    """Rebuild BENCH_summary.json from whatever artifacts already exist —
    the CI summary job downloads the matrix artifacts and calls this; no
    benchmark runs.  Fails if NO registered artifact is present (a summary
    of nothing is a broken pipeline, not a quiet success)."""
    rows = {}
    for name, _, artifact in TABLES:
        if artifact is None:
            continue
        path, value = headline_of(artifact, artifact_dir)
        if path is None:
            continue
        rows[name] = {"artifact": artifact, "headline": path,
                      "value": value, "wall_s": None}
    if not rows:
        print("no BENCH_*.json artifacts found to summarize",
              file=sys.stderr)
        return 1
    write_summary(rows, artifact_dir)
    return 0


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--summarize-only", action="store_true",
                    help="rebuild BENCH_summary.json from existing "
                         "artifacts without running any benchmark")
    ap.add_argument("--dir", default=".", help="artifact directory")
    args = ap.parse_args(argv)
    if args.summarize_only:
        sys.exit(summarize_only(args.dir))

    print("name,us_per_call,derived")
    failures = 0
    rows = {}
    for name, fn, artifact in TABLES:
        t0 = time.monotonic()
        wall0 = time.time()
        print(f"# --- {name} ---")
        status = "ok"
        try:
            fn()
        except Exception as e:  # noqa: BLE001
            failures += 1
            status = f"ERROR={type(e).__name__}"
            traceback.print_exc()
            print(f"{name},0.0,{status}")
        else:
            reason = check_artifact(artifact, wall0)
            if reason:
                failures += 1
                status = "ERROR=MissingArtifact"
                print(f"{name},0.0,{status} ({reason})")
        wall = time.monotonic() - t0
        print(f"# {name} took {wall:.1f}s")
        row = {"artifact": artifact, "wall_s": round(wall, 2),
               "status": status}
        if artifact is not None and status == "ok":
            try:
                row["headline"], row["value"] = headline_of(artifact)
            except GateError as e:
                failures += 1
                row["status"] = "ERROR=Headline"
                print(f"{name},0.0,ERROR=Headline ({e})")
        rows[name] = row
    write_summary(rows)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
