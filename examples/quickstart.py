"""Quickstart: load a base model, attach two virtual LoRA models, and run a
mixed batch (two adapters + base) through the unified flow in one step.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_reduced
from repro.core.lora import LoRAConfig
from repro.core.virtualization import AdapterStore, MixedLoraModel
from repro.models.model import init_cache
from repro.models.schema import init_params
from repro.models.stream import PFBatch, UnifiedBatch


def main():
    cfg = get_reduced("llama3-8b")
    params = init_params(cfg, jax.random.PRNGKey(0))

    # one shared base model, two isolated virtual LoRA models ("0 B" extra)
    store = AdapterStore(cfg, LoRAConfig(n_slots=4, r=8), jax.random.PRNGKey(1))
    store.load_random("chat", jax.random.PRNGKey(2))
    store.load_random("math", jax.random.PRNGKey(3))
    model = MixedLoraModel(cfg, params, store)
    print("resident adapters:", store.resident)

    # one unified prefill step: row 0 -> chat, row 1 -> math, row 2 -> base
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (3, 12)), jnp.int32)
    pf = PFBatch(tokens=toks, length=jnp.array([12, 12, 12]),
                 adapter=jnp.array([store.slot_of("chat"),
                                    store.slot_of("math"), -1]))
    cache = init_cache(cfg, 3, 64)
    out = model.forward(UnifiedBatch(pf=pf), cache=cache)
    next_tokens = jnp.argmax(out.pf_logits, axis=-1)
    print("next tokens per adapter:", np.asarray(next_tokens))

    # hot-swap: unload "chat", load a new adapter into the freed slot
    store.unload("chat")
    store.load_random("code", jax.random.PRNGKey(4))
    print("after hot-swap:", store.resident)

    # migration: void "math" (base excluded), unvoid into a fresh store
    vm = model.virtual("math")
    blob = vm.void()
    store2 = AdapterStore(cfg, LoRAConfig(n_slots=4, r=8), jax.random.PRNGKey(9))
    vm2 = vm.unvoid(blob, params, store2)
    print("migrated adapter slot:", vm2.slot)


if __name__ == "__main__":
    main()
