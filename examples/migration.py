"""Instance-to-instance migration: void a fine-tuning adapter mid-run,
serialize it (base model NOT included), unvoid it on a second runtime, and
keep training — no kernel restart, no base duplication (paper Section 3.2).

    PYTHONPATH=src python examples/migration.py
"""
import jax
import numpy as np

from repro.checkpoint import io
from repro.configs import get_reduced
from repro.core.lora import LoRAConfig
from repro.core.virtualization import AdapterStore, MixedLoraModel, VirtualModel
from repro.data import datasets
from repro.models.schema import init_params
from repro.serving.engine import EngineConfig, UnifiedEngine
from repro.training.trainer import MixedLoraTrainer, TrainerConfig


def main():
    cfg = get_reduced("llama3-8b")
    params = init_params(cfg, jax.random.PRNGKey(0))
    lcfg = LoRAConfig(n_slots=4, r=8)

    # runtime A: train for half the epochs
    storeA = AdapterStore(cfg, lcfg, jax.random.PRNGKey(1))
    storeA.load_random("job", jax.random.PRNGKey(2))
    engA = UnifiedEngine(MixedLoraModel(cfg, params, storeA),
                         EngineConfig(capacity=2, pf_capacity=2, s_max=64))
    rows, ev = datasets.split_eval(datasets.alpaca_like(32, vocab=cfg.vocab))
    trA = MixedLoraTrainer("job", storeA.slot_of("job"), rows, ev,
                           TrainerConfig(rows_per_micro=2, accum_steps=2,
                                         epochs=1))
    engA.add_trainer(trA)
    engA.run(max_ticks=100000)
    lossA = np.mean(trA.train_losses[-4:])
    print(f"runtime A: trained {trA.tokens_trained} tokens, loss {lossA:.3f}")

    # void + serialize (adapter only — the paper's "0 B" base sharing)
    voided = VirtualModel("job", params, storeA).void()
    blob = io.serialize_pytree(voided.adapter)
    print(f"migration payload: {len(blob)/2**20:.2f} MiB (base excluded)")

    # runtime B: unvoid and continue training where A stopped
    storeB = AdapterStore(cfg, lcfg, jax.random.PRNGKey(3))
    voided.adapter = io.deserialize_pytree(blob, voided.adapter)
    VirtualModel.unvoid(voided, params, storeB)
    engB = UnifiedEngine(MixedLoraModel(cfg, params, storeB),
                         EngineConfig(capacity=2, pf_capacity=2, s_max=64))
    trB = MixedLoraTrainer("job", storeB.slot_of("job"), rows, ev,
                           TrainerConfig(rows_per_micro=2, accum_steps=2,
                                         epochs=1))
    engB.add_trainer(trB)
    engB.run(max_ticks=100000)
    print(f"runtime B: continued, loss {np.mean(trB.train_losses[:4]):.3f} "
          f"-> {np.mean(trB.train_losses[-4:]):.3f}")


if __name__ == "__main__":
    main()
