"""End-to-end driver: serve batched multi-LoRA inference requests while a
fine-tuning job trains a third adapter in the SAME unified runtime —
the paper's headline scenario (Figure 4).

    PYTHONPATH=src python examples/unified_serving.py
"""
import jax
import numpy as np

from repro.configs import get_reduced
from repro.core.lora import LoRAConfig
from repro.core.virtualization import AdapterStore, MixedLoraModel
from repro.data import datasets, workload
from repro.models.schema import init_params
from repro.serving.engine import EngineConfig, UnifiedEngine
from repro.serving.request import Request
from repro.serving.slo import SLOConfig, slo_attainment
from repro.training.trainer import MixedLoraTrainer, TrainerConfig


def main():
    cfg = get_reduced("llama3-8b")
    params = init_params(cfg, jax.random.PRNGKey(0))
    store = AdapterStore(cfg, LoRAConfig(n_slots=4, r=8), jax.random.PRNGKey(1))
    for name, seed in (("chat", 2), ("math", 3), ("student", 4)):
        store.load_random(name, jax.random.PRNGKey(seed))
    eng = UnifiedEngine(MixedLoraModel(cfg, params, store),
                        EngineConfig(capacity=6, pf_capacity=2, s_max=192,
                                     virtual_time=True))

    # inference load: 30 requests at ~2 RPS across two serving adapters
    prompts = datasets.sharegpt_prompts(30, vocab=cfg.vocab, seed=7)
    arrivals = workload.poisson_arrivals(2.0, 30, seed=7)
    for i, (p, t) in enumerate(zip(prompts, arrivals)):
        eng.submit(Request(rid=i, prompt=p,
                           adapter=("chat", "math")[i % 2],
                           max_new_tokens=12, arrival=float(t)))

    # concurrent fine-tuning of "student" (its own grad accumulation)
    rows = datasets.gsm8k_like(40, vocab=cfg.vocab, seed=11)
    tr_rows, ev_rows = datasets.split_eval(rows)
    eng.add_trainer(MixedLoraTrainer("student", store.slot_of("student"),
                                     tr_rows, ev_rows,
                                     TrainerConfig(rows_per_micro=2,
                                                   accum_steps=4, epochs=1)))

    m = eng.run(max_ticks=200000)
    tr = eng.trainers["student"]
    print(f"SLO attainment: {slo_attainment(eng.finished, SLOConfig()):.3f} "
          f"({len(eng.finished)}/30 finished)")
    print(f"throughput: {m.rates()}")
    print(f"student: {tr.tokens_trained} tokens trained, "
          f"{tr.optimizer_steps} optimizer steps, "
          f"loss {np.mean(tr.train_losses[:4]):.3f} -> "
          f"{np.mean(tr.train_losses[-4:]):.3f}")


if __name__ == "__main__":
    main()
