"""Concurrent multi-LoRA fine-tuning: two trainers, one shared backward pass
per unified step, isolated masked optimizer updates (paper Figure 3's
multi-LoRA setting, which PEFT cannot run concurrently).

    PYTHONPATH=src python examples/multi_finetune.py
"""
import jax
import numpy as np

from repro.configs import get_reduced
from repro.core.lora import LoRAConfig
from repro.core.virtualization import AdapterStore, MixedLoraModel
from repro.data import datasets
from repro.models.schema import init_params
from repro.serving.engine import EngineConfig, UnifiedEngine
from repro.training.trainer import MixedLoraTrainer, TrainerConfig


def main():
    cfg = get_reduced("llama3-8b")
    params = init_params(cfg, jax.random.PRNGKey(0))
    store = AdapterStore(cfg, LoRAConfig(n_slots=4, r=8), jax.random.PRNGKey(1))
    store.load_random("alpaca", jax.random.PRNGKey(2))
    store.load_random("gsm8k", jax.random.PRNGKey(3))
    eng = UnifiedEngine(MixedLoraModel(cfg, params, store),
                        EngineConfig(capacity=2, pf_capacity=2, s_max=64))

    a_rows, a_ev = datasets.split_eval(datasets.alpaca_like(48, vocab=cfg.vocab))
    g_rows, g_ev = datasets.split_eval(datasets.gsm8k_like(48, vocab=cfg.vocab))
    tcfg = TrainerConfig(rows_per_micro=2, accum_steps=4, epochs=2)
    eng.add_trainer(MixedLoraTrainer("alpaca", store.slot_of("alpaca"),
                                     a_rows, a_ev, tcfg))
    eng.add_trainer(MixedLoraTrainer("gsm8k", store.slot_of("gsm8k"),
                                     g_rows, g_ev, tcfg))

    m = eng.run(max_ticks=200000)
    print(f"throughput: {m.rates()}")
    for name, tr in eng.trainers.items():
        print(f"{name}: loss {np.mean(tr.train_losses[:6]):.3f} -> "
              f"{np.mean(tr.train_losses[-6:]):.3f}, eval "
              f"{np.mean(tr.eval_losses[-6:]):.3f}, "
              f"opt_steps={tr.optimizer_steps}")


if __name__ == "__main__":
    main()
