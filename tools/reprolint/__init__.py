"""reprolint — AST/CFG static analysis that mechanically enforces this
repo's hard-won serving-engine invariants (see INVARIANTS.md).

Run from the repo root:

    python -m reprolint src tests

Each rule encodes a defect class PRs 1-8 hit by hand: bare asserts erased
by ``python -O``, Pallas kernels with no ``*_ref`` oracle, host syncs
inside the tick loop, unpaired refcount acquires, and jit step caches
keyed without the trace-time inputs that can go stale.
"""
from reprolint.core import Finding, Project, SourceFile  # noqa: F401
from reprolint.registry import all_rules, register  # noqa: F401

__version__ = "1.0"
