"""Source model: files, roles, annotations, findings.

Annotations are magic comments of the form

    # reprolint: token[, token ...]

attached to the line they sit on; a pure-comment annotation line also
attaches to the next code line.  Tokens:

    disable=<rule-id>       suppress that rule's findings on this line
    sync-point              declared host-sync boundary (host-sync rule)
    ownership-transfer      the acquired ref is handed to a data structure
                            whose owner releases it (refcount rule)
    oracle=<name>           explicit oracle pairing (kernel-oracle rule)
    allow-assert            a deliberate trace-time/shape assert
    cache-key-exempt        cache provably independent of kernel mode

Roles classify what rules apply where.  A file's role normally derives
from its repo-relative path; a fixture can override it with a header
comment ``# reprolint-fixture: role=<role>`` so the rule corpus under
``tools/reprolint/tests/fixtures/`` exercises every rule without living
inside ``src/``.
"""
from __future__ import annotations

import ast
import dataclasses
import os
import re
from typing import Dict, Iterable, List, Optional, Set

_ANN_RE = re.compile(r"#\s*reprolint:\s*(?P<body>[^#]*)")
_ROLE_RE = re.compile(r"#\s*reprolint-fixture:\s*role=(?P<role>[\w-]+)")

# role vocabulary
ENGINE = "engine"      # src/repro/{serving,core,fleet} — stateful runtime
KERNELS = "kernels"    # src/repro/kernels — Pallas entry points + oracles
SRC = "src"            # anything under src/repro
TESTS = "tests"        # test files (oracle-pairing evidence)


@dataclasses.dataclass(frozen=True)
class Finding:
    rule: str
    path: str          # repo-relative posix path
    line: int
    message: str
    symbol: str = ""   # enclosing qualname, baseline identity

    @property
    def key(self) -> tuple:
        # line numbers are deliberately NOT identity: a baseline must
        # survive unrelated edits above the finding
        return (self.rule, self.path, self.symbol, self.message)

    def render(self) -> str:
        sym = f" [{self.symbol}]" if self.symbol else ""
        return f"{self.path}:{self.line}: {self.rule}: {self.message}{sym}"


def _parse_tokens(body: str) -> Set[str]:
    return {t for t in re.split(r"[\s,]+", body.strip()) if t}


class SourceFile:
    def __init__(self, path: str, rel: str, text: str):
        self.path = path
        self.rel = rel.replace(os.sep, "/")
        self.text = text
        self.lines = text.splitlines()
        self.tree = ast.parse(text, filename=rel)
        self.annotations: Dict[int, Set[str]] = {}
        self._collect_annotations()
        self.roles = self._roles()

    # -- annotations -------------------------------------------------------
    def _collect_annotations(self):
        pending: Set[str] = set()
        for i, line in enumerate(self.lines, start=1):
            m = _ANN_RE.search(line)
            tokens = _parse_tokens(m.group("body")) if m else set()
            if line.strip().startswith("#"):
                # standalone comment: accumulate for the next code line
                pending |= tokens
                continue
            if tokens or pending:
                self.annotations[i] = tokens | pending
            pending = set()

    def tokens_at(self, line: int) -> Set[str]:
        return self.annotations.get(line, set())

    def has_token(self, line: int, token: str) -> bool:
        return token in self.tokens_at(line)

    def is_disabled(self, line: int, rule: str) -> bool:
        return f"disable={rule}" in self.tokens_at(line)

    # -- roles -------------------------------------------------------------
    def _roles(self) -> Set[str]:
        for line in self.lines[:5]:
            m = _ROLE_RE.search(line)
            if m:
                role = m.group("role")
                out = {role}
                if role in (ENGINE, KERNELS):
                    out.add(SRC)
                return out
        rel = self.rel
        out: Set[str] = set()
        if rel.startswith(("src/repro/serving/", "src/repro/core/",
                           "src/repro/fleet/")):
            out |= {ENGINE, SRC}
        elif rel.startswith("src/repro/kernels/"):
            out |= {KERNELS, SRC}
        elif rel.startswith("src/"):
            out.add(SRC)
        base = os.path.basename(rel)
        if rel.startswith("tests/") or base.startswith("test_"):
            out.add(TESTS)
        return out


class Project:
    """Everything a rule sees: the parsed files plus shared AST helpers."""

    def __init__(self, files: List[SourceFile]):
        self.files = files

    def with_role(self, role: str) -> List[SourceFile]:
        return [f for f in self.files if role in f.roles]


# -- shared AST helpers ----------------------------------------------------

def iter_functions(tree: ast.AST):
    """Yield (qualname, FunctionDef) for every function, including nested
    ones and methods."""
    def walk(node, prefix):
        for child in ast.iter_child_nodes(node):
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef)):
                q = f"{prefix}.{child.name}" if prefix else child.name
                yield q, child
                yield from walk(child, q)
            elif isinstance(child, ast.ClassDef):
                q = f"{prefix}.{child.name}" if prefix else child.name
                yield from walk(child, q)
            else:
                yield from walk(child, prefix)
    yield from walk(tree, "")


def attr_chain(node: ast.AST) -> Optional[str]:
    """Dotted name of an attribute/name expression, or None."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def call_name(call: ast.Call) -> str:
    """Trailing identifier of the called object (``a.b.c()`` -> ``c``)."""
    f = call.func
    if isinstance(f, ast.Attribute):
        return f.attr
    if isinstance(f, ast.Name):
        return f.id
    return ""


def mentions(tree: ast.AST) -> Set[str]:
    """All identifiers a module references: names, attribute tails, and
    import aliases.  Used as the oracle-pairing test-evidence relation —
    robust to both ``from m import f`` and ``m.f`` styles."""
    out: Set[str] = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.Name):
            out.add(node.id)
        elif isinstance(node, ast.Attribute):
            out.add(node.attr)
        elif isinstance(node, (ast.Import, ast.ImportFrom)):
            for alias in node.names:
                out.add(alias.name.split(".")[-1])
    return out


def load_files(root: str, paths: Iterable[str]) -> List[SourceFile]:
    files: List[SourceFile] = []
    seen: Set[str] = set()
    for p in paths:
        absd = p if os.path.isabs(p) else os.path.join(root, p)
        if os.path.isfile(absd):
            cand = [absd]
        else:
            cand = []
            for dirpath, dirnames, filenames in os.walk(absd):
                dirnames[:] = [d for d in sorted(dirnames)
                               if d not in ("__pycache__", ".git")]
                cand.extend(os.path.join(dirpath, f)
                            for f in sorted(filenames) if f.endswith(".py"))
        for fp in cand:
            fp = os.path.abspath(fp)
            if fp in seen:
                continue
            seen.add(fp)
            rel = os.path.relpath(fp, root)
            with open(fp, "r", encoding="utf-8") as fh:
                files.append(SourceFile(fp, rel, fh.read()))
    return files
