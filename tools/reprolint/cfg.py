"""A small per-function control-flow graph for the refcount-pairing rule.

One node per AST statement.  The graph models explicit control flow
(``if``/``for``/``while``/``try``/``return``/``raise``/``break``/
``continue``) plus two conservative exception approximations:

* every statement inside a ``try`` body may jump to each of its handlers
  (an exception can occur anywhere in the body);
* abrupt exits (``return``/``raise``/``break``/``continue``) route through
  every enclosing ``finally`` body before leaving.

The only query the linter needs is reachability with a kill-set: "starting
just after statement A, can the function exit be reached along a path on
which no statement matches ``release``?"  Conservative extra edges can
produce false positives, never false negatives — the right polarity for a
leak detector whose escape hatch is an explicit annotation.
"""
from __future__ import annotations

import ast
from typing import Callable, Dict, List, Set

EXIT = -1


class CFG:
    def __init__(self):
        self.stmts: Dict[int, ast.stmt] = {}
        self.succ: Dict[int, Set[int]] = {EXIT: set()}
        self._next = 0

    def new_node(self, stmt: ast.stmt) -> int:
        nid = self._next
        self._next += 1
        self.stmts[nid] = stmt
        self.succ[nid] = set()
        return nid

    def edge(self, a: int, b: int):
        if a != EXIT:
            self.succ[a].add(b)

    def nodes_for(self, pred: Callable[[ast.stmt], bool]) -> Set[int]:
        return {nid for nid, s in self.stmts.items() if pred(s)}

    def reaches_exit_avoiding(self, start_after: int,
                              avoid: Set[int]) -> bool:
        """True if EXIT is reachable from the successors of
        ``start_after`` without passing through any node in ``avoid``."""
        stack = [s for s in self.succ.get(start_after, ())]
        seen: Set[int] = set()
        while stack:
            nid = stack.pop()
            if nid in seen or nid in avoid:
                continue
            if nid == EXIT:
                return True
            seen.add(nid)
            stack.extend(self.succ[nid])
        return False


class _Builder:
    def __init__(self):
        self.cfg = CFG()
        # stack of (break_sinks, continue_target) per enclosing loop
        self.loops: List[tuple] = []
        # stack of pending-abrupt-exit lists per enclosing try-with-finally;
        # entries are node ids whose flow must route through the finally
        self.finallies: List[List[int]] = []

    # ------------------------------------------------------------------
    def build(self, fn: ast.FunctionDef) -> CFG:
        exits = self._seq(fn.body, ["ENTRY"])
        for e in exits:
            self._to_exit(e)
        return self.cfg

    def _to_exit(self, nid):
        if nid == "ENTRY":
            return
        self.cfg.edge(nid, EXIT)

    def _link(self, preds, nid: int):
        for p in preds:
            if p == "ENTRY":
                continue
            self.cfg.edge(p, nid)

    def _abrupt(self, nid: int, targets: List[int]):
        """Route an abrupt exit: through the innermost pending finally if
        any, else straight to its targets (EXIT / loop header / sinks)."""
        if self.finallies:
            self.finallies[-1].append(nid)
        else:
            for t in targets:
                self.cfg.edge(nid, t)

    # ------------------------------------------------------------------
    def _seq(self, stmts: List[ast.stmt], preds):
        for s in stmts:
            if not preds:
                break  # unreachable tail
            preds = self._stmt(s, preds)
        return preds

    def _stmt(self, s: ast.stmt, preds):
        nid = self.cfg.new_node(s)
        self._link(preds, nid)

        if isinstance(s, ast.If):
            body = self._seq(s.body, [nid])
            orelse = self._seq(s.orelse, [nid]) if s.orelse else [nid]
            return body + orelse

        if isinstance(s, (ast.For, ast.While, ast.AsyncFor)):
            breaks: List[int] = []
            self.loops.append((breaks, nid))
            body = self._seq(s.body, [nid])
            for e in body:
                if e != "ENTRY":
                    self.cfg.edge(e, nid)  # loop back
            self.loops.pop()
            orelse = self._seq(s.orelse, [nid]) if s.orelse else [nid]
            return orelse + breaks

        if isinstance(s, (ast.Try, getattr(ast, "TryStar", ast.Try))):
            has_finally = bool(s.finalbody)
            if has_finally:
                self.finallies.append([])
            body_start = len(self.cfg.stmts)
            body = self._seq(s.body, [nid])
            body_nodes = list(range(body_start, len(self.cfg.stmts)))
            handler_exits: List = []
            handler_entries: List[int] = []
            for h in s.handlers:
                h_start = len(self.cfg.stmts)
                h_exits = self._seq(h.body, [nid])
                h_nodes = list(range(h_start, len(self.cfg.stmts)))
                if h_nodes:
                    handler_entries.append(h_nodes[0])
                handler_exits.extend(h_exits)
            # conservative: any body statement may raise into any handler
            for b in body_nodes:
                for h in handler_entries:
                    self.cfg.edge(b, h)
            orelse = self._seq(s.orelse, body) if s.orelse else body
            normal = orelse + handler_exits
            if has_finally:
                pending = self.finallies.pop()
                fin_preds = normal + pending
                # an unhandled exception in the body also reaches finally
                fin_preds = fin_preds + body_nodes
                fin = self._seq(s.finalbody, fin_preds or [nid])
                # abrupt entries leave through the finally: approximate by
                # letting the finally's exits ALSO reach EXIT when any
                # pending abrupt exit was routed through it
                if pending:
                    for e in fin:
                        self._to_exit(e)
                return fin
            return normal

        if isinstance(s, (ast.With, ast.AsyncWith)):
            return self._seq(s.body, [nid])

        if isinstance(s, ast.Return):
            self._abrupt(nid, [EXIT])
            return []
        if isinstance(s, ast.Raise):
            self._abrupt(nid, [EXIT])
            return []
        if isinstance(s, ast.Break):
            if self.loops:
                self.loops[-1][0].append(nid)
                if self.finallies:
                    self.finallies[-1].append(nid)
            else:
                self._abrupt(nid, [EXIT])
            return []
        if isinstance(s, ast.Continue):
            if self.loops:
                target = self.loops[-1][1]
                if self.finallies:
                    self.finallies[-1].append(nid)
                else:
                    self.cfg.edge(nid, target)
            return []

        # plain statement (nested defs are opaque single nodes: their
        # bodies get their own CFG when the rule visits them)
        return [nid]


def build_cfg(fn: ast.FunctionDef) -> CFG:
    return _Builder().build(fn)
