"""Importing this package registers every rule."""
from reprolint.rules import (bare_assert, cache_keys, host_sync,  # noqa: F401
                             oracle_pairing, refcount_pairing)
