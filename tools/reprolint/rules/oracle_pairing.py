"""kernel-oracle-pairing: every ``pl.pallas_call`` entry point must have a
pure-jnp ``*_ref`` oracle and at least one test exercising both.

The repo's exactness story (byte-identical optimized paths, PRs 1-6) only
holds while every kernel is allclose-gated against an oracle.  An entry
point is any public function whose body issues a ``pallas_call``.  Pairing
is by name: an oracle ``<base>_ref`` covers entries named ``<base>`` or
``<base>_*`` (so ``paged_decode_ref`` covers both ``paged_decode_attention``
and its split-K variant); an explicit ``# reprolint: oracle=<name>`` on the
entry's ``def`` line overrides.  Test evidence is a single test module that
mentions both the entry and its oracle.
"""
from __future__ import annotations

import ast
from typing import Dict, List, Optional, Set, Tuple

from reprolint.core import (KERNELS, TESTS, Finding, Project, SourceFile,
                            call_name, iter_functions, mentions)
from reprolint.registry import register

RULE = "kernel-oracle-pairing"


def _contains_pallas_call(fn: ast.FunctionDef) -> bool:
    for node in ast.walk(fn):
        if isinstance(node, ast.Call) and call_name(node) == "pallas_call":
            return True
    return False


def _explicit_oracle(f: SourceFile, fn: ast.FunctionDef) -> Optional[str]:
    for tok in f.tokens_at(fn.lineno):
        if tok.startswith("oracle="):
            return tok.split("=", 1)[1]
    return None


def _match_oracle(entry: str, oracle_bases: Set[str]) -> Optional[str]:
    """Longest oracle base covering this entry name."""
    best = None
    for base in oracle_bases:
        if entry == base or entry.startswith(base + "_"):
            if best is None or len(base) > len(best):
                best = base
    return best


@register(RULE, "pallas_call entry points need a *_ref oracle and a test")
def check(project: Project):
    entries: List[Tuple[SourceFile, str, ast.FunctionDef]] = []
    oracles: Dict[str, str] = {}  # base name -> oracle function name
    for f in project.with_role(KERNELS):
        for qual, fn in iter_functions(f.tree):
            if fn.name.endswith("_ref"):
                oracles[fn.name[:-4]] = fn.name
            if fn.name.startswith("_"):
                continue
            if _contains_pallas_call(fn):
                entries.append((f, qual, fn))

    evidence = [mentions(t.tree) for t in project.with_role(TESTS)]

    for f, qual, fn in entries:
        line = fn.lineno
        if f.is_disabled(line, RULE):
            continue
        explicit = _explicit_oracle(f, fn)
        if explicit is not None:
            oracle = explicit
            known = explicit in oracles.values()
        else:
            base = _match_oracle(fn.name, set(oracles))
            oracle = oracles.get(base) if base else None
            known = oracle is not None
        if not known:
            yield Finding(
                rule=RULE, path=f.rel, line=line,
                message=(f"kernel entry point `{fn.name}` has no matching "
                         "*_ref oracle (add one to kernels/ref.py or "
                         "annotate `# reprolint: oracle=<name>`)"),
                symbol=qual)
            continue
        if not any(fn.name in m and oracle in m for m in evidence):
            yield Finding(
                rule=RULE, path=f.rel, line=line,
                message=(f"no test exercises `{fn.name}` against its "
                         f"oracle `{oracle}`"),
                symbol=qual)
