"""no-bare-invariant-assert: runtime invariants in the stateful engine
layers (serving/core/fleet) must raise typed exceptions.

The CI tier-1 matrix runs ``python -O``, which strips ``assert``
statements — a bare assert guarding block accounting or adapter state is
load-bearing control flow that silently vanishes in exactly the
configuration closest to production.  ``KVAccountingError`` /
``InvariantError`` are the precedent.  A deliberate trace-time shape
assert can be kept with ``# reprolint: allow-assert``.
"""
from __future__ import annotations

import ast

from reprolint.core import ENGINE, Finding, Project
from reprolint.registry import register

RULE = "no-bare-invariant-assert"


@register(RULE, "engine invariants must raise typed exceptions, not assert")
def check(project: Project):
    for f in project.with_role(ENGINE):
        for node in ast.walk(f.tree):
            if not isinstance(node, ast.Assert):
                continue
            line = node.lineno
            if (f.is_disabled(line, RULE)
                    or f.has_token(line, "allow-assert")):
                continue
            test = ast.unparse(node.test)
            if len(test) > 40:
                test = test[:37] + "..."
            yield Finding(
                rule=RULE, path=f.rel, line=line,
                message=(f"bare `assert {test}` is erased under python -O; "
                         "raise an InvariantError subclass instead"),
                symbol=_enclosing(f.tree, node))


def _enclosing(tree: ast.AST, target: ast.AST) -> str:
    best = ""
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.ClassDef)):
            if (node.lineno <= target.lineno
                    and target.lineno <= max(getattr(node, "end_lineno",
                                                     node.lineno),
                                             node.lineno)):
                best = node.name if not best else f"{best}.{node.name}"
    return best
