"""host-sync-in-hot-path: device->host synchronization must be declared.

Two checks:

(a) Inside traced code — functions compiled with ``jax.jit`` (directly or
    through ``functools.partial``) and Pallas kernel bodies — ``.item()``,
    ``np.asarray(...)``, and ``float()``/``bool()`` over non-literal values
    are either trace-time errors or silent recompile/sync hazards.

(b) In the engine's per-tick assembly (serving/core/fleet), a host sync on
    a device value — ``jax.block_until_ready(...)`` or ``np.asarray``
    applied to a known device-valued expression (a ``ModelOut`` logits /
    loss field or the paged adapter pool) — stalls the dispatch pipeline.
    The ~6 legitimate boundaries (the engine must read logits to schedule
    the next step) carry an explicit ``# reprolint: sync-point``
    annotation; anything unannotated is a new sync creeping into the hot
    path.

The device-rooted attribute list is deliberately an under-approximation:
this lints the engine we have, not arbitrary programs.
"""
from __future__ import annotations

import ast
from typing import Dict, Set

from reprolint.core import (ENGINE, SRC, Finding, Project, SourceFile,
                            attr_chain, call_name, iter_functions)
from reprolint.registry import register

RULE = "host-sync-in-hot-path"

# ModelOut fields that hold device arrays, plus the device-resident
# adapter byte pool: np.asarray over any expression touching these is a
# device->host transfer.
DEVICE_ATTRS = {"pf_logits", "dec_logits", "ft_loss_sum", "ft_tok_count",
                "_adapter_pool"}


def _is_jitted(fn: ast.FunctionDef) -> bool:
    for dec in fn.decorator_list:
        chain = attr_chain(dec)
        if chain in ("jax.jit", "jit"):
            return True
        if isinstance(dec, ast.Call):
            fchain = attr_chain(dec.func)
            if fchain in ("jax.jit", "jit"):
                return True
            if fchain in ("functools.partial", "partial") and dec.args:
                if attr_chain(dec.args[0]) in ("jax.jit", "jit"):
                    return True
    return False


def _pallas_kernel_names(tree: ast.AST) -> Set[str]:
    """Function names traced by pallas_call: names passed directly, or via
    a ``kern = functools.partial(<name>, ...)`` local binding."""
    partial_of: Dict[str, str] = {}
    for node in ast.walk(tree):
        if isinstance(node, ast.Assign) and isinstance(node.value, ast.Call):
            fchain = attr_chain(node.value.func)
            if fchain in ("functools.partial", "partial") and node.value.args:
                src = node.value.args[0]
                if isinstance(src, ast.Name) and len(node.targets) == 1 \
                        and isinstance(node.targets[0], ast.Name):
                    partial_of[node.targets[0].id] = src.id
    out: Set[str] = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.Call) and call_name(node) == "pallas_call":
            for arg in node.args:
                if isinstance(arg, ast.Name):
                    out.add(partial_of.get(arg.id, arg.id))
                elif isinstance(arg, ast.Call):
                    fchain = attr_chain(arg.func)
                    if fchain in ("functools.partial", "partial") and arg.args:
                        if isinstance(arg.args[0], ast.Name):
                            out.add(arg.args[0].id)
    return out


def _is_shape_like(node: ast.expr) -> bool:
    """float()/bool() over shapes/dtypes/constants is trace-time, fine."""
    if isinstance(node, ast.Constant):
        return True
    for sub in ast.walk(node):
        if isinstance(sub, ast.Attribute) and sub.attr in (
                "shape", "ndim", "size", "dtype"):
            return True
        if isinstance(sub, ast.Call) and call_name(sub) in ("len",):
            return True
    return False


def _sync_calls_in_traced(f: SourceFile, fn: ast.FunctionDef, qual: str):
    for node in ast.walk(fn):
        if not isinstance(node, ast.Call):
            continue
        line = node.lineno
        if f.is_disabled(line, RULE) or f.has_token(line, "sync-point"):
            continue
        name = call_name(node)
        chain = attr_chain(node.func)
        if name == "item" and isinstance(node.func, ast.Attribute):
            what = ".item()"
        elif chain in ("np.asarray", "numpy.asarray", "np.array",
                       "numpy.array"):
            what = f"{chain}(...)"
        elif isinstance(node.func, ast.Name) and name in ("float", "bool") \
                and node.args and not _is_shape_like(node.args[0]):
            what = f"{name}() on a traced value"
        else:
            continue
        yield Finding(
            rule=RULE, path=f.rel, line=line,
            message=(f"{what} inside traced function `{fn.name}` forces a "
                     "host sync (or fails to trace)"),
            symbol=qual)


def _touches_device_attr(node: ast.expr) -> bool:
    for sub in ast.walk(node):
        if isinstance(sub, ast.Attribute) and sub.attr in DEVICE_ATTRS:
            return True
    return False


@register(RULE, "host syncs in jitted code / the tick loop need annotation")
def check(project: Project):
    # (a) traced functions anywhere under src
    for f in project.with_role(SRC):
        kernel_names = _pallas_kernel_names(f.tree)
        for qual, fn in iter_functions(f.tree):
            if _is_jitted(fn) or fn.name in kernel_names:
                yield from _sync_calls_in_traced(f, fn, qual)

    # (b) engine hot-path assembly
    for f in project.with_role(ENGINE):
        for node in ast.walk(f.tree):
            if not isinstance(node, ast.Call):
                continue
            line = node.lineno
            if f.is_disabled(line, RULE) or f.has_token(line, "sync-point"):
                continue
            chain = attr_chain(node.func)
            if chain in ("jax.block_until_ready", "block_until_ready"):
                yield Finding(
                    rule=RULE, path=f.rel, line=line,
                    message=("jax.block_until_ready is a host sync; "
                             "annotate `# reprolint: sync-point` if this "
                             "boundary is deliberate"))
            elif chain in ("np.asarray", "numpy.asarray") and node.args \
                    and _touches_device_attr(node.args[0]):
                yield Finding(
                    rule=RULE, path=f.rel, line=line,
                    message=("np.asarray over a device-valued expression "
                             "is a host sync; annotate "
                             "`# reprolint: sync-point` if deliberate"))
