"""refcount-retain-pairing: every acquire-family call must be released on
all exit paths, or be an explicit ownership transfer.

The engine's correctness under preemption/CoW churn rests on exact
refcount conservation (``BlockAllocator`` refs, ``AdapterStore`` retains,
pager pins).  For every function that calls an acquire-family method, a
CFG walk checks that no explicit path from the acquire to the function
exit avoids a matching release-family call — the try/finally shape the
tick loop uses.  Acquires whose reference is handed to a long-lived data
structure (a block table, the hash index, a request) are not leaks: they
carry ``# reprolint: ownership-transfer`` (on the call line or the
enclosing ``def``), which documents who releases later.

Functions named like the resource layer itself (``acquire``, ``retain``,
``pin``, ``incref``, ...) are exempt: their body IS the acquisition.
"""
from __future__ import annotations

import ast
from typing import Optional, Set

from reprolint.core import ENGINE, Finding, Project, iter_functions
from reprolint.registry import register
from reprolint.cfg import build_cfg

RULE = "refcount-retain-pairing"

# acquire attr -> matching release attrs
FAMILIES = {
    "incref": {"decref"},
    "acquire": {"release"},
    "retain": {"release"},
    "pin": {"unpin", "adapter_unpin"},
    "adapter_pin": {"adapter_unpin", "unpin"},
}
RESOURCE_LAYER_NAMES = set(FAMILIES) | {
    "release", "unpin", "decref", "adapter_unpin", "_drop_retain"}


def _acquire_attr(call: ast.Call) -> Optional[str]:
    if isinstance(call.func, ast.Attribute) and call.func.attr in FAMILIES:
        return call.func.attr
    return None


def _calls_in_stmt(stmt: ast.stmt) -> list:
    """Calls executed directly BY this statement: nested statements belong
    to their own CFG node, and nested function bodies don't run here."""
    out = []
    stack = [stmt]
    first = True
    while stack:
        node = stack.pop()
        if not first and isinstance(node, (ast.stmt, ast.Lambda)):
            continue
        first = False
        if isinstance(node, ast.Call):
            out.append(node)
        stack.extend(ast.iter_child_nodes(node))
    return out


def _releases(stmt: ast.stmt, release_attrs: Set[str]) -> bool:
    return any(isinstance(c.func, ast.Attribute)
               and c.func.attr in release_attrs
               for c in _calls_in_stmt(stmt))


@register(RULE, "acquire/retain/incref must pair with release on all paths")
def check(project: Project):
    for f in project.with_role(ENGINE):
        for qual, fn in iter_functions(f.tree):
            if fn.name in RESOURCE_LAYER_NAMES:
                continue
            acquires = []
            for stmt in ast.walk(fn):
                if not isinstance(stmt, ast.stmt):
                    continue
                for call in _calls_in_stmt(stmt):
                    attr = _acquire_attr(call)
                    if attr:
                        acquires.append((stmt, call, attr))
            if not acquires:
                continue
            cfg = None
            for stmt, call, attr in acquires:
                line = call.lineno
                if (f.is_disabled(line, RULE)
                        or f.has_token(line, "ownership-transfer")
                        or f.has_token(fn.lineno, "ownership-transfer")):
                    continue
                if cfg is None:
                    cfg = build_cfg(fn)
                node_ids = [nid for nid, s in cfg.stmts.items()
                            if s is stmt]
                if not node_ids:
                    continue  # statement inside a nested def's own scope
                release_attrs = FAMILIES[attr]
                releases = cfg.nodes_for(
                    lambda s: _releases(s, release_attrs))
                if any(cfg.reaches_exit_avoiding(nid, releases)
                       for nid in node_ids):
                    yield Finding(
                        rule=RULE, path=f.rel, line=line,
                        message=(f"`.{attr}(...)` has an exit path with no "
                                 f"matching {sorted(release_attrs)} release; "
                                 "use try/finally or annotate "
                                 "`# reprolint: ownership-transfer`"),
                        symbol=qual)
