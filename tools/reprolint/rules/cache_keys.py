"""jit-cache-key-hygiene: trace caches must key on every trace-time input,
and jitted functions must not close over mutable module state.

The PR 6 defect class: ``unified_forward`` reads the paged-attention
backend flag and the autotune table at TRACE time, so any cache of jitted
step functions that omits ``_paged_kernel_mode()`` or
``autotune.table_version()`` from its key serves stale traces after a flag
flip or a tuning-table load.  Two checks:

(a) In modules that define a ``*_CACHE`` dict, every literal-tuple cache
    key (stored by subscript or passed to a ``_cached`` helper) must
    contain calls to BOTH ``_paged_kernel_mode`` and ``table_version``.
    Keys that are opaque parameters (the memo helper itself) are skipped —
    construction sites are where the hygiene lives.  A cache that is
    provably independent of kernel selection can annotate
    ``# reprolint: cache-key-exempt``.

(b) A ``@jax.jit`` function whose body reads a module-level MUTABLE
    global (dict/list/set literal, or a name rebound via ``global``) has
    baked that value into its trace — mutations after first call are
    silently ignored.
"""
from __future__ import annotations

import ast
from typing import Dict, List, Optional, Set, Tuple

from reprolint.core import (SRC, Finding, Project, SourceFile, attr_chain,
                            call_name, iter_functions)
from reprolint.registry import register
from reprolint.rules.host_sync import _is_jitted

RULE = "jit-cache-key-hygiene"

REQUIRED_KEY_CALLS = ("_paged_kernel_mode", "table_version")
MUTABLE_CTORS = {"dict", "list", "set", "deque", "defaultdict",
                 "OrderedDict", "Counter"}


def _module_cache_names(tree: ast.Module) -> Set[str]:
    out: Set[str] = set()
    for node in tree.body:
        targets = []
        if isinstance(node, ast.Assign):
            targets = node.targets
        elif isinstance(node, ast.AnnAssign) and node.value is not None:
            targets = [node.target]
        for t in targets:
            if isinstance(t, ast.Name) and t.id.endswith("_CACHE"):
                out.add(t.id)
    return out


def _mutable_globals(tree: ast.Module) -> Set[str]:
    out: Set[str] = set()
    for node in tree.body:
        targets: List[ast.expr] = []
        value: Optional[ast.expr] = None
        if isinstance(node, ast.Assign):
            targets, value = node.targets, node.value
        elif isinstance(node, ast.AnnAssign) and node.value is not None:
            targets, value = [node.target], node.value
        elif isinstance(node, ast.AugAssign):
            targets, value = [node.target], None
        for t in targets:
            if not isinstance(t, ast.Name):
                continue
            if value is None or isinstance(value, (
                    ast.Dict, ast.List, ast.Set, ast.DictComp, ast.ListComp,
                    ast.SetComp)):
                out.add(t.id)
            elif isinstance(value, ast.Call) \
                    and call_name(value) in MUTABLE_CTORS:
                out.add(t.id)
    # names any function rebinds via `global` are mutable module state
    for node in ast.walk(tree):
        if isinstance(node, ast.Global):
            out.update(node.names)
    return out


def _resolve_key_expr(fn: ast.FunctionDef,
                      expr: ast.expr) -> Optional[ast.expr]:
    """Follow one local assignment hop: ``key = (...)`` then ``CACHE[key]``.
    Returns a Tuple literal to inspect, or None when the key is opaque
    (a parameter, a starred splat, ...)."""
    if isinstance(expr, ast.Tuple):
        if any(isinstance(e, ast.Starred) for e in expr.elts):
            return None
        return expr
    if isinstance(expr, ast.Name):
        for node in ast.walk(fn):
            if isinstance(node, ast.Assign) \
                    and len(node.targets) == 1 \
                    and isinstance(node.targets[0], ast.Name) \
                    and node.targets[0].id == expr.id:
                return _resolve_key_expr(fn, node.value)
    return None


def _key_sites(fn: ast.FunctionDef,
               cache_names: Set[str]) -> List[Tuple[int, ast.expr]]:
    """(line, key-expression) for every cache-key construction in ``fn``."""
    sites = []
    for node in ast.walk(fn):
        if isinstance(node, ast.Subscript) \
                and isinstance(node.value, ast.Name) \
                and node.value.id in cache_names:
            sites.append((node.lineno, node.slice))
        elif isinstance(node, ast.Call) and call_name(node) == "_cached":
            # _cached(kind, key, build) — the key is the tuple argument
            for arg in node.args:
                if isinstance(arg, (ast.Tuple, ast.Name)):
                    sites.append((node.lineno, arg))
                    break
    return sites


def _free_loads(fn: ast.FunctionDef) -> Set[str]:
    bound: Set[str] = set()
    args = fn.args
    for a in (args.posonlyargs + args.args + args.kwonlyargs
              + ([args.vararg] if args.vararg else [])
              + ([args.kwarg] if args.kwarg else [])):
        bound.add(a.arg)
    loads: Set[str] = set()
    for node in ast.walk(fn):
        if isinstance(node, ast.Name):
            if isinstance(node.ctx, (ast.Store, ast.Del)):
                bound.add(node.id)
            else:
                loads.add(node.id)
        elif isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)) \
                and node is not fn:
            bound.add(node.name)
    return loads - bound


@register(RULE, "step caches key on kernel mode + table version; no jit "
                "closures over mutable globals")
def check(project: Project):
    for f in project.with_role(SRC):
        if not isinstance(f.tree, ast.Module):
            continue
        cache_names = _module_cache_names(f.tree)
        mutable = _mutable_globals(f.tree)

        for qual, fn in iter_functions(f.tree):
            # (a) key hygiene at construction sites
            if cache_names:
                for line, key_expr in _key_sites(fn, cache_names):
                    if (f.is_disabled(line, RULE)
                            or f.has_token(line, "cache-key-exempt")
                            or f.has_token(fn.lineno, "cache-key-exempt")):
                        continue
                    tup = _resolve_key_expr(fn, key_expr)
                    if tup is None:
                        continue  # opaque key: constructed by the caller
                    if (f.has_token(tup.lineno, "cache-key-exempt")
                            or f.is_disabled(tup.lineno, RULE)):
                        continue  # annotated at the key construction site
                    present = {call_name(n) for n in ast.walk(tup)
                               if isinstance(n, ast.Call)}
                    missing = [c for c in REQUIRED_KEY_CALLS
                               if c not in present]
                    if missing:
                        yield Finding(
                            rule=RULE, path=f.rel, line=line,
                            message=("step-cache key omits trace-time "
                                     f"input(s) {missing}: stale traces "
                                     "survive flag flips / table loads"),
                            symbol=qual)

            # (b) jitted closures over mutable module globals
            if _is_jitted(fn):
                leaked = sorted(_free_loads(fn) & mutable)
                for name in leaked:
                    line = fn.lineno
                    if f.is_disabled(line, RULE):
                        continue
                    yield Finding(
                        rule=RULE, path=f.rel, line=line,
                        message=(f"@jax.jit function `{fn.name}` closes "
                                 f"over mutable module global `{name}` — "
                                 "its value is frozen into the trace"),
                        symbol=qual)
