"""Baseline file: pre-existing findings tolerated while they're burned
down.  Same philosophy as ``benchmarks/gate.py``: a baseline entry that no
longer matches anything is a DANGLING entry and fails the run loudly — a
gate that checks nothing must not pass vacuously.

Identity is (rule, path, symbol, message) — deliberately not the line
number, so unrelated edits above a finding don't churn the file.  The
checked-in baseline (``tools/reprolint/baseline.json``) is empty: this PR
fixed every true positive instead of baselining it.
"""
from __future__ import annotations

import json
from typing import Dict, List, Tuple

from reprolint.core import Finding


def load(path: str) -> List[Dict[str, str]]:
    with open(path, "r", encoding="utf-8") as fh:
        doc = json.load(fh)
    entries = doc.get("findings", doc if isinstance(doc, list) else None)
    if entries is None or not isinstance(entries, list):
        raise ValueError(f"{path}: expected {{'findings': [...]}}")
    for e in entries:
        missing = {"rule", "path", "symbol", "message"} - set(e)
        if missing:
            raise ValueError(f"{path}: baseline entry missing {missing}")
    return entries


def split(findings: List[Finding], entries: List[Dict[str, str]]
          ) -> Tuple[List[Finding], List[Finding], List[Dict[str, str]]]:
    """-> (new findings, baselined findings, dangling entries)."""
    keys = {(e["rule"], e["path"], e["symbol"], e["message"])
            for e in entries}
    new = [f for f in findings if f.key not in keys]
    old = [f for f in findings if f.key in keys]
    live = {f.key for f in old}
    dangling = [e for e in entries
                if (e["rule"], e["path"], e["symbol"], e["message"])
                not in live]
    return new, old, dangling
