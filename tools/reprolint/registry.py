"""Rule registry: a rule is a generator ``check(project) -> Finding``
registered under a stable kebab-case id (the id is what suppressions,
baselines, and INVARIANTS.md refer to)."""
from __future__ import annotations

from typing import Callable, Dict, Iterator, NamedTuple

from reprolint.core import Finding, Project


class Rule(NamedTuple):
    rule_id: str
    description: str
    check: Callable[[Project], Iterator[Finding]]


_RULES: Dict[str, Rule] = {}


def register(rule_id: str, description: str):
    def deco(fn):
        if rule_id in _RULES:
            raise ValueError(f"duplicate rule id {rule_id!r}")
        _RULES[rule_id] = Rule(rule_id, description, fn)
        return fn
    return deco


def all_rules() -> Dict[str, Rule]:
    # importing the rules package populates the registry
    import reprolint.rules  # noqa: F401
    return dict(_RULES)
