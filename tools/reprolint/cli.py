"""Command line: ``python -m reprolint [paths...]``.

Exit codes: 0 clean (or everything baselined), 1 unsuppressed findings or
dangling baseline entries or a failed self-check, 2 usage error.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
from typing import List, Optional

from reprolint import baseline as baseline_mod
from reprolint.core import Finding, Project, load_files
from reprolint.registry import all_rules

DEFAULT_PATHS = ["src", "tests"]


def run_paths(root: str, paths: List[str]) -> List[Finding]:
    project = Project(load_files(root, paths))
    findings: List[Finding] = []
    for rule in all_rules().values():
        findings.extend(rule.check(project))
    findings.sort(key=lambda f: (f.path, f.line, f.rule))
    return findings


def _default_baseline() -> str:
    return os.path.join(os.path.dirname(os.path.abspath(__file__)),
                        "baseline.json")


def self_check(root: str) -> int:
    """Lint the fixture corpus: every rule must catch >=1 seeded violation
    in the ``bad_*`` fixtures and none in the ``clean_*`` ones.  This is
    CI's guard against a silently-broken linter passing green."""
    fixtures = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                            "tests", "fixtures")
    findings = run_paths(root, [fixtures])
    by_rule = {rid: [] for rid in all_rules()}
    ok = True
    for f in findings:
        base = os.path.basename(f.path)
        if base.startswith("clean_"):
            print(f"SELF-CHECK FAIL: clean fixture flagged: {f.render()}")
            ok = False
        elif base.startswith("bad_"):
            by_rule.setdefault(f.rule, []).append(f)
    for rid, hits in sorted(by_rule.items()):
        status = f"{len(hits)} seeded violation(s) caught"
        if not hits:
            print(f"SELF-CHECK FAIL: rule {rid} caught nothing in the "
                  "bad fixtures")
            ok = False
        else:
            print(f"self-check: {rid}: {status}")
    print("self-check: " + ("OK" if ok else "FAILED"))
    return 0 if ok else 1


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="reprolint",
        description="AST/CFG invariant linter for this repo "
                    "(see INVARIANTS.md)")
    ap.add_argument("paths", nargs="*", default=None,
                    help=f"files/dirs to lint (default: {DEFAULT_PATHS})")
    ap.add_argument("--root", default=os.getcwd(),
                    help="repo root for relative paths (default: cwd)")
    ap.add_argument("--baseline", default=None,
                    help="baseline JSON (default: tools/reprolint/"
                         "baseline.json)")
    ap.add_argument("--no-baseline", action="store_true",
                    help="ignore the baseline file")
    ap.add_argument("--json", action="store_true", dest="as_json",
                    help="emit findings as JSON")
    ap.add_argument("--list-rules", action="store_true")
    ap.add_argument("--self-check", action="store_true",
                    help="lint the fixture corpus; fail unless every rule "
                         "catches its seeded violation")
    args = ap.parse_args(argv)

    if args.list_rules:
        for rule in all_rules().values():
            print(f"{rule.rule_id}: {rule.description}")
        return 0
    if args.self_check:
        return self_check(args.root)

    paths = args.paths or DEFAULT_PATHS
    try:
        findings = run_paths(args.root, paths)
    except (OSError, SyntaxError) as e:
        print(f"reprolint: error: {e}", file=sys.stderr)
        return 2

    dangling: List[dict] = []
    baselined: List[Finding] = []
    if not args.no_baseline:
        bpath = args.baseline or _default_baseline()
        if os.path.exists(bpath):
            entries = baseline_mod.load(bpath)
            findings, baselined, dangling = baseline_mod.split(
                findings, entries)
        elif args.baseline is not None:
            print(f"reprolint: error: baseline {bpath} not found",
                  file=sys.stderr)
            return 2

    if args.as_json:
        print(json.dumps({
            "findings": [f.__dict__ for f in findings],
            "baselined": [f.__dict__ for f in baselined],
            "dangling": dangling,
        }, indent=2))
    else:
        for f in findings:
            print(f.render())
        for e in dangling:
            print(f"DANGLING baseline entry (fixed or moved — remove it): "
                  f"{e['rule']}: {e['path']} [{e['symbol']}]")
        n_files = "src/tests" if paths == DEFAULT_PATHS else ",".join(paths)
        print(f"reprolint: {len(findings)} finding(s), "
              f"{len(baselined)} baselined, {len(dangling)} dangling "
              f"baseline entr(ies) over {n_files}")
    return 1 if (findings or dangling) else 0
