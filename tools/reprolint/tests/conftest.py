import os
import sys

_TOOLS = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", ".."))
if _TOOLS not in sys.path:
    sys.path.insert(0, _TOOLS)
