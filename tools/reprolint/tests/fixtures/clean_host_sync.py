# reprolint-fixture: role=engine
"""Clean counterpart: the engine's deliberate sync boundary is annotated;
the jitted function stays on device."""
import jax
import jax.numpy as jnp
import numpy as np


class Engine:
    def tick(self, out):
        # scheduling must read the sampled token: a declared boundary
        jax.block_until_ready(out.dec_logits)    # reprolint: sync-point
        logits = np.asarray(out.dec_logits)      # reprolint: sync-point
        host_meta = np.asarray(out.lengths_host)  # numpy in, numpy out: ok
        return logits.argmax(), host_meta


@jax.jit
def good_step(x):
    s = jnp.sum(x)
    d = float(x.shape[-1])      # shape math is trace-time, fine
    return s / d
