# reprolint-fixture: role=engine
"""Seeded violations: an unannotated host sync in the tick assembly and a
device->host transfer inside a jitted function."""
import functools

import jax
import numpy as np


class Engine:
    def tick(self, out):
        jax.block_until_ready(out.dec_logits)       # unannotated barrier
        logits = np.asarray(out.dec_logits)         # unannotated transfer
        return logits.argmax()


@functools.partial(jax.jit, static_argnames=("n",))
def bad_step(x, n):
    host = np.asarray(x)        # sync inside a trace
    s = float(x.sum())          # traced value forced to host
    return host, s, x.item()    # and an .item()
