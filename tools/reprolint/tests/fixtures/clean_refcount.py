# reprolint-fixture: role=engine
"""Clean counterpart: try/finally pairing, and an explicit ownership
transfer for a ref that a long-lived table owns."""


class Admitter:
    def admit_paired(self, store, name, budget):
        slot = store.acquire(name)
        try:
            if budget <= 0:
                return None
            return slot
        finally:
            store.release(name)

    def adopt_into_table(self, allocator, table, bids):
        for bid in bids:
            # reprolint: ownership-transfer — the table owns the ref;
            # free() decrefs when the slot is released
            allocator.incref(bid)
            table.append(bid)
        return len(bids)
