# reprolint-fixture: role=tests
"""Test-evidence fixture: mentions the clean kernel AND its oracle, so
the pairing rule sees the clean entry as fully covered.  (Deliberately
not named test_*.py — pytest must not collect fixture code.)"""
from clean_oracle_pairing import fused_rowsum, fused_rowsum_ref


def check_fused_rowsum_matches_ref():
    x = [[1.0, 2.0]]
    assert fused_rowsum(x) == fused_rowsum_ref(x)
