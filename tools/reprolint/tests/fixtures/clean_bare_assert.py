# reprolint-fixture: role=engine
"""Clean counterpart: typed exceptions survive python -O; a deliberate
trace-time assert is annotated."""


class InvariantError(RuntimeError):
    pass


class Pool:
    def __init__(self, n_blocks):
        if n_blocks < 2:
            raise InvariantError("need a usable block")
        self.n_blocks = n_blocks

    def check_shape(self, x, d):
        assert x.shape[-1] == d  # reprolint: allow-assert
        return x
