# reprolint-fixture: role=kernels
"""Clean counterpart: the entry has a name-matched oracle and the
evidence_tests fixture mentions both."""
from jax.experimental import pallas as pl


def fused_rowsum(x):
    return pl.pallas_call(_kern, out_shape=None)(x)


def fused_rowsum_ref(x):
    return x.sum(axis=-1)


def _kern(x_ref, o_ref):
    o_ref[...] = x_ref[...].sum(axis=-1)
