# reprolint-fixture: role=kernels
"""Seeded violations: a pallas_call entry point with no *_ref oracle, and
one whose oracle exists but no test exercises the pair."""
from jax.experimental import pallas as pl


def orphan_matmul(x, w):
    # no orphan_matmul_ref anywhere
    return pl.pallas_call(_kern, out_shape=None)(x, w)


def untested_scan(x):
    # untested_scan_ref exists below, but no tests-role file mentions both
    return pl.pallas_call(_kern, out_shape=None)(x)


def untested_scan_ref(x):
    return x


def _kern(x_ref, o_ref):
    o_ref[...] = x_ref[...]
