# reprolint-fixture: role=src
"""Clean counterpart: the key carries both trace-time inputs; the jitted
function takes its tuning input as an argument."""
import jax

from somewhere import _paged_kernel_mode, table_version, build  # noqa

_STEP_CACHE: dict = {}


def make_step(cfg, remat):
    key = ("fwd", cfg, remat, _paged_kernel_mode(), table_version())
    if key not in _STEP_CACHE:
        _STEP_CACHE[key] = build(cfg, remat)
    return _STEP_CACHE[key]


def make_eval_step(cfg):
    # a cache whose entries never call the kernel-selecting forward
    key = ("tok", cfg)  # reprolint: cache-key-exempt
    if key not in _STEP_CACHE:
        _STEP_CACHE[key] = build(cfg, False)
    return _STEP_CACHE[key]


@jax.jit
def lanes_step(x, lanes):
    return x * lanes
