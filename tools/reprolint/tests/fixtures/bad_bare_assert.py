# reprolint-fixture: role=engine
"""Seeded violation: a runtime invariant guarded by a bare assert."""


class Pool:
    def __init__(self, n_blocks):
        assert n_blocks >= 2, "need a usable block"  # erased under -O
        self.n_blocks = n_blocks

    def free(self, bid, ref):
        assert ref[bid] > 0
        ref[bid] -= 1
