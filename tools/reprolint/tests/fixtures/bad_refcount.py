# reprolint-fixture: role=engine
"""Seeded violations: acquires with leaky exit paths."""


class Admitter:
    def admit_leaky(self, store, name, budget):
        slot = store.acquire(name)
        if budget <= 0:
            return None          # leaks the acquire on this path
        store.release(name)
        return slot

    def adopt_unpaired(self, allocator, bids):
        for bid in bids:
            allocator.incref(bid)   # no decref on any path, no annotation
        return len(bids)
