# reprolint-fixture: role=src
"""Seeded violations: a step cache keyed without the trace-time inputs,
and a jitted function closing over a mutable module global."""
import jax

from somewhere import _paged_kernel_mode, table_version, build  # noqa

_STEP_CACHE: dict = {}
_TUNING_TABLE = {"lanes": 4}


def make_step_stale(cfg, remat):
    key = ("fwd", cfg, remat, _paged_kernel_mode())   # missing table_version
    if key not in _STEP_CACHE:
        _STEP_CACHE[key] = build(cfg, remat)
    return _STEP_CACHE[key]


@jax.jit
def frozen_lanes_step(x):
    return x * _TUNING_TABLE["lanes"]   # value baked into the first trace
