"""Tests for the reprolint linter itself: the fixture corpus, the
annotation machinery, the baseline gate, and a meta-test pinning the real
tree to the checked-in baseline."""
import json
import os

import pytest

from reprolint import baseline as baseline_mod
from reprolint.cli import main, run_paths, self_check
from reprolint.core import Finding, Project, SourceFile
from reprolint.registry import all_rules

HERE = os.path.dirname(os.path.abspath(__file__))
FIXTURES = os.path.join(HERE, "fixtures")
REPO_ROOT = os.path.abspath(os.path.join(HERE, "..", "..", ".."))

ALL_RULES = (
    "no-bare-invariant-assert",
    "kernel-oracle-pairing",
    "host-sync-in-hot-path",
    "refcount-retain-pairing",
    "jit-cache-key-hygiene",
)


def _fixture_findings():
    return run_paths(REPO_ROOT, [FIXTURES])


def _by_file(findings):
    out = {}
    for f in findings:
        out.setdefault(os.path.basename(f.path), []).append(f)
    return out


# -- fixture corpus ---------------------------------------------------------

def test_registry_has_all_rules():
    assert set(all_rules()) == set(ALL_RULES)


def test_every_rule_catches_a_seeded_violation():
    by_rule = {}
    for f in _fixture_findings():
        if os.path.basename(f.path).startswith("bad_"):
            by_rule.setdefault(f.rule, []).append(f)
    for rule in ALL_RULES:
        assert by_rule.get(rule), f"rule {rule} caught nothing in bad_*"


def test_clean_fixtures_are_silent():
    flagged = [f for f in _fixture_findings()
               if os.path.basename(f.path).startswith("clean_")]
    assert flagged == [], [f.render() for f in flagged]


def test_self_check_passes_on_shipped_corpus(capsys):
    assert self_check(REPO_ROOT) == 0
    assert "self-check: OK" in capsys.readouterr().out


def test_bare_assert_findings_name_the_symbols():
    hits = _by_file(_fixture_findings())["bad_bare_assert.py"]
    assert all(f.rule == "no-bare-invariant-assert" for f in hits)
    assert len(hits) == 2
    assert all("python -O" in f.message for f in hits)


def test_oracle_pairing_distinguishes_missing_oracle_from_missing_test():
    hits = _by_file(_fixture_findings())["bad_oracle_pairing.py"]
    msgs = {f.symbol: f.message for f in hits}
    assert "no matching *_ref oracle" in msgs["orphan_matmul"]
    assert "no test exercises" in msgs["untested_scan"]


def test_refcount_rule_flags_leaky_path_and_unpaired_incref():
    hits = _by_file(_fixture_findings())["bad_refcount.py"]
    syms = {f.symbol for f in hits}
    assert "Admitter.admit_leaky" in syms
    assert "Admitter.adopt_unpaired" in syms


def test_cache_key_rule_names_the_missing_call():
    hits = _by_file(_fixture_findings())["bad_cache_keys.py"]
    key_hits = [f for f in hits if "omits trace-time" in f.message]
    closure_hits = [f for f in hits if "mutable module global" in f.message]
    assert any("table_version" in f.message for f in key_hits)
    assert any("_TUNING_TABLE" in f.message for f in closure_hits)


# -- annotation machinery ---------------------------------------------------

def _sf(text, rel="src/repro/serving/fake.py"):
    return SourceFile(path=rel, rel=rel, text=text)


def test_inline_annotation_attaches_to_its_line():
    sf = _sf("x = 1  # reprolint: sync-point\n")
    assert sf.has_token(1, "sync-point")
    assert not sf.has_token(2, "sync-point")


def test_standalone_comment_attaches_to_next_code_line():
    sf = _sf("# reprolint: ownership-transfer\n"
             "# more prose about why\n"
             "store.incref(b)\n")
    assert sf.has_token(3, "ownership-transfer")


def test_disable_is_per_rule():
    sf = _sf("assert x  # reprolint: disable=no-bare-invariant-assert\n")
    assert sf.is_disabled(1, "no-bare-invariant-assert")
    assert not sf.is_disabled(1, "host-sync-in-hot-path")


def test_disable_suppresses_a_bare_assert():
    text = ("class P:\n"
            "    def f(self, n):\n"
            "        assert n > 0  "
            "# reprolint: disable=no-bare-invariant-assert\n")
    project = Project([_sf(text)])
    rule = all_rules()["no-bare-invariant-assert"]
    assert list(rule.check(project)) == []


def test_role_override_header():
    sf = _sf("# reprolint-fixture: role=kernels\nx = 1\n",
             rel="tools/whatever/snippet.py")
    assert "kernels" in sf.roles and "src" in sf.roles


def test_roles_from_path():
    assert "engine" in _sf("x = 1\n", "src/repro/fleet/router.py").roles
    assert "kernels" in _sf("x = 1\n", "src/repro/kernels/ops.py").roles
    assert "tests" in _sf("x = 1\n", "tests/test_ops.py").roles


# -- baseline gate ----------------------------------------------------------

def _finding(**kw):
    base = dict(rule="no-bare-invariant-assert", path="src/a.py", line=3,
                message="m", symbol="f")
    base.update(kw)
    return Finding(**base)


def test_baseline_split_matches_on_identity_not_line():
    f = _finding(line=99)
    entry = {"rule": f.rule, "path": f.path, "symbol": f.symbol,
             "message": f.message}
    new, old, dangling = baseline_mod.split([f], [entry])
    assert new == [] and old == [f] and dangling == []


def test_dangling_baseline_entry_is_reported():
    entry = {"rule": "r", "path": "gone.py", "symbol": "f", "message": "m"}
    new, old, dangling = baseline_mod.split([], [entry])
    assert dangling == [entry]


def test_dangling_baseline_entry_fails_the_cli(tmp_path, capsys):
    bl = tmp_path / "baseline.json"
    bl.write_text(json.dumps({"findings": [
        {"rule": "no-bare-invariant-assert", "path": "gone.py",
         "symbol": "f", "message": "m"}]}))
    src = tmp_path / "src"
    src.mkdir()
    (src / "ok.py").write_text("x = 1\n")
    rc = main([str(src), "--root", str(tmp_path), "--baseline", str(bl)])
    assert rc == 1
    assert "DANGLING" in capsys.readouterr().out


def test_baseline_load_rejects_malformed(tmp_path):
    p = tmp_path / "b.json"
    p.write_text(json.dumps({"findings": [{"rule": "r"}]}))
    with pytest.raises(ValueError):
        baseline_mod.load(str(p))


def test_cli_exit_codes(tmp_path, capsys):
    clean = tmp_path / "clean.py"
    clean.write_text("x = 1\n")
    assert main([str(clean), "--root", str(tmp_path),
                 "--no-baseline"]) == 0
    capsys.readouterr()
    assert main([FIXTURES, "--root", REPO_ROOT, "--no-baseline"]) == 1
    capsys.readouterr()
    assert main(["--baseline", str(tmp_path / "nope.json"), "src",
                 "--root", REPO_ROOT]) == 2


# -- meta-test: the real tree matches the checked-in baseline ---------------

def test_repo_matches_checked_in_baseline():
    """A fresh run over src/ + tests/ must agree exactly with
    tools/reprolint/baseline.json: no new findings, no dangling entries.
    The shipped baseline is empty — the tree is lint-clean."""
    findings = run_paths(REPO_ROOT, ["src", "tests"])
    bpath = os.path.join(REPO_ROOT, "tools", "reprolint", "baseline.json")
    entries = baseline_mod.load(bpath)
    new, old, dangling = baseline_mod.split(findings, entries)
    assert new == [], [f.render() for f in new]
    assert dangling == []
    assert entries == []  # this PR fixed every finding instead of baselining
