"""Render EXPERIMENTS.md §Dry-run / §Roofline tables from the artifacts."""
import glob
import json
import os
import sys

ART = os.path.join(os.path.dirname(__file__), "dryrun")


def fmt(v, nd=3):
    return f"{v:.{nd}f}"


def main():
    rows = {}
    for f in sorted(glob.glob(os.path.join(ART, "*.json"))):
        r = json.load(open(f))
        rows[(r["arch"], r["shape"], r["mesh"])] = r

    archs = sorted({k[0] for k in rows})
    shapes = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]

    print("### §Dry-run — 10 archs x 4 shapes x {16x16, 2x16x16}: "
          f"{len(rows)} combos, "
          f"{sum(1 for r in rows.values() if r['status'] == 'ok')} compile OK\n")
    print("| arch | shape | mesh | compile s | args GiB/dev | peak GiB/dev | "
          "HLO coll scaled GB/dev |")
    print("|---|---|---|---|---|---|---|")
    for a in archs:
        for s in shapes:
            for m in ("16x16", "2x16x16"):
                r = rows.get((a, s, m))
                if not r:
                    continue
                if r["status"] != "ok":
                    print(f"| {a} | {s} | {m} | FAIL | | | |")
                    continue
                mem = r["memory"]
                coll = sum(r["collectives_hlo"]["scaled"].values()) / 1e9
                print(f"| {a} | {s} | {m} | {r['compile_s']} | "
                      f"{mem['argument_size_in_bytes']/2**30:.2f} | "
                      f"{mem['peak_estimate_bytes']/2**30:.2f} | "
                      f"{coll:.1f} |")

    print("\n### §Roofline — single-pod 16x16 (terms in seconds/step, "
          "v5e: 197 TF/s bf16, 819 GB/s HBM, 50 GB/s/link)\n")
    print("| arch | shape | compute s | memory s | collective s | dominant | "
          "useful-FLOPs ratio | one-line lever |")
    print("|---|---|---|---|---|---|---|---|")
    LEVERS = {
        "compute": "raise MXU utilisation: larger per-device tile / fewer "
                   "replicated-head archs / fused kernels",
        "memory": "shrink resident+streamed bytes: cache layout, quantised "
                  "weights, better remat policy",
        "collective": "cut wire bytes: seq-sharded caches, fewer weight "
                      "re-gathers, bf16->int8 gathers, AG/compute overlap",
    }
    for a in archs:
        for s in shapes:
            r = rows.get((a, s, "16x16"))
            if not r or r["status"] != "ok":
                continue
            ro = r["roofline"]
            print(f"| {a} | {s} | {fmt(ro['compute_s'],4)} | "
                  f"{fmt(ro['memory_s'],4)} | {fmt(ro['collective_s'],4)} | "
                  f"{ro['dominant']} | {fmt(ro['useful_flops_ratio'],2)} | "
                  f"{LEVERS[ro['dominant']]} |")


if __name__ == "__main__":
    main()
