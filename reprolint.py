"""Repo-root shim so ``python -m reprolint src tests`` works without
installing anything: the real package lives in ``tools/reprolint`` (kept
out of ``src/`` — it lints the product, it isn't part of it).

Run via ``-m`` this file executes as ``__main__`` and the top-level name
``reprolint`` stays free for the real package; imported by name, it
replaces itself in ``sys.modules`` with the real package.
"""
import os
import sys

_TOOLS = os.path.join(os.path.dirname(os.path.abspath(__file__)), "tools")
if _TOOLS not in sys.path:
    sys.path.insert(0, _TOOLS)

if __name__ == "__main__":
    sys.modules.pop("reprolint", None)
    from reprolint.cli import main
    sys.exit(main())
else:
    sys.modules.pop("reprolint", None)
    import reprolint  # noqa: F401  (re-resolves to tools/reprolint)
